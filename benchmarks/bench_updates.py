"""Streaming-update benchmark: batched maintenance vs full rebuild.

Acceptance target (ISSUE 1): a batched 1k-edge update on a >=100k-vertex
Erdős–Rényi graph — graph edit + ``update_dbindex_batch`` + incremental
``patch_plan_dbindex`` — must beat a full ``build_dbindex`` +
``plan_from_dbindex`` by >= 5x.  Results land in ``BENCH_updates.json``
(via :func:`benchmarks.common.emit_json`) plus the usual CSV rows.

A secondary section measures localized I-Index maintenance on a
pathway-shaped DAG (bounded edge span keeps windows, and thus the
rebuild, tractable at bench scale).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, emit_json
from repro.core import engine_jax as ej
from repro.core import updates as U
from repro.core.dbindex import build_dbindex
from repro.core.iindex import build_iindex
from repro.core.updates import UpdateBatch
from repro.core.windows import KHopWindow
from repro.graphs.generators import erdos_renyi, random_dag, with_random_attrs


def _fresh_edge_batch(g, rng, size: int) -> UpdateBatch:
    s = rng.integers(0, g.n, size * 3).astype(np.int32)
    d = rng.integers(0, g.n, size * 3).astype(np.int32)
    ok = (s != d) & ~g.contains_edges(s, d)
    _, first = np.unique(g.edge_keys(s, d), return_index=True)
    pick = np.intersect1d(np.flatnonzero(ok), first)[:size]
    return UpdateBatch.inserts(s[pick], d[pick])


def _t(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def run(n: int = 100_000, deg: float = 8.0, k: int = 1, batch_edges: int = 1000,
        json_path: str = "BENCH_updates.json") -> dict:
    rng = np.random.default_rng(0)
    g = with_random_attrs(erdos_renyi(n, deg, directed=False, seed=0), seed=1)
    w = KHopWindow(k)

    idx, t_build0 = _t(lambda: build_dbindex(g, w, method="emc"))
    plan, t_plan0 = _t(lambda: ej.plan_from_dbindex(idx))
    emit(f"updates/initial_build/n{n}", t_build0 * 1e6, f"k={k},deg={deg}")
    emit(f"updates/initial_plan/n{n}", t_plan0 * 1e6, "")

    batch = _fresh_edge_batch(g, rng, batch_edges)
    g2, t_apply = _t(lambda: U.apply_batch(g, batch))
    (idx2, owners), t_update = _t(lambda: U.update_dbindex_batch(idx, g2, w, batch))
    plan2, t_patch = _t(lambda: ej.patch_plan_dbindex(plan, idx2, owners))
    batched_s = t_apply + t_update + t_patch

    idx_f, t_rebuild = _t(lambda: build_dbindex(g2, w, method="emc"))
    plan_f, t_replan = _t(lambda: ej.plan_from_dbindex(idx_f))
    rebuild_s = t_rebuild + t_replan
    speedup = rebuild_s / max(batched_s, 1e-12)

    emit(f"updates/batched_{batch.size}edges/n{n}", batched_s * 1e6,
         f"affected={owners.size}")
    emit(f"updates/full_rebuild/n{n}", rebuild_s * 1e6, "")
    emit(f"updates/speedup/n{n}", speedup, "x_batched_vs_rebuild")

    # sanity: both paths answer identically on device (XLA path, CPU-safe)
    got = np.asarray(ej.query_dbindex(plan2, g2.attrs["val"], "sum", use_pallas=False))
    ref = np.asarray(ej.query_dbindex(
        ej.plan_from_dbindex(idx2, block_capacity=plan2.block_capacity),
        g2.attrs["val"], "sum", use_pallas=False))
    assert np.array_equal(got, ref), "patched plan diverged from fresh plan"

    # ---------------- I-Index localized maintenance ------------------- #
    n_dag = max(n // 5, 2000)
    gd = with_random_attrs(random_dag(n_dag, 2.0, seed=2, locality=64), seed=3)
    ii, t_ibuild = _t(lambda: build_iindex(gd))
    iplan, t_iplan = _t(lambda: ej.plan_from_iindex(ii))
    order = gd.topological_order()
    rank = np.empty(gd.n, np.int64)
    rank[order] = np.arange(gd.n)
    # edits land in the last decile of the topological order so the
    # descendant cones stay localized (random heads on a connected DAG
    # union to ~the whole graph, which just measures the rebuild fallback)
    s = order[rng.integers(int(gd.n * 0.9), gd.n - 1, batch_edges // 10)]
    span = rng.integers(1, 64, s.size)
    hi = order[np.minimum(rank[s] + span, gd.n - 1)].astype(np.int32)
    ok = (rank[s] < rank[hi]) & ~gd.contains_edges(s, hi)
    ib = UpdateBatch.inserts(s[ok].astype(np.int32), hi[ok])
    gd2, t_iapply = _t(lambda: U.apply_batch(gd, ib))
    (ii2, cone), t_iupdate = _t(lambda: U.update_iindex_batch(ii, gd2, ib))
    _, t_ipatch = _t(lambda: ej.patch_plan_iindex(iplan, ii2, cone))
    i_batched = t_iapply + t_iupdate + t_ipatch
    i_rebuild = _t(lambda: build_iindex(gd2))[1] + _t(lambda: ej.plan_from_iindex(ii2))[1]
    emit(f"updates/iindex_batched/n{n_dag}", i_batched * 1e6, f"cone={cone.size}")
    emit(f"updates/iindex_rebuild/n{n_dag}", i_rebuild * 1e6, "")
    emit(f"updates/iindex_speedup/n{n_dag}", i_rebuild / max(i_batched, 1e-12), "x")

    payload = {
        "config": {"n": n, "avg_degree": deg, "k": k,
                   "batch_edges": int(batch.size), "method": "emc"},
        "dbindex": {
            "initial_build_s": t_build0,
            "initial_plan_s": t_plan0,
            "batch_apply_s": t_apply,
            "batch_update_index_s": t_update,
            "batch_patch_plan_s": t_patch,
            "batched_total_s": batched_s,
            "full_rebuild_s": t_rebuild,
            "full_replan_s": t_replan,
            "full_rebuild_total_s": rebuild_s,
            "speedup_batched_vs_rebuild": speedup,
            "affected_owners": int(owners.size),
            "secondary_blocks": int(idx2.stats.get("last_secondary_blocks", 0)),
        },
        "iindex": {
            "n": n_dag,
            "batch_edges": int(ib.size),
            "cone_size": int(cone.size),
            "batched_total_s": i_batched,
            "full_rebuild_total_s": i_rebuild,
            "speedup_batched_vs_rebuild": i_rebuild / max(i_batched, 1e-12),
        },
    }
    emit_json(json_path, payload)
    return payload


if __name__ == "__main__":
    run()
