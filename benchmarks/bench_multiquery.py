"""Multi-query fusion benchmark: fused multi-channel plan vs per-query loop.

Acceptance target (ISSUE 2): a fused 4-aggregate (sum/count/min/avg)
DBIndex device query over one window must run >= 2x faster than four
sequential ``query_dbindex`` calls, with bit-identical results, and a
``Session`` must stay oracle-correct across >= 20 streamed
``UpdateBatch``es without recompiling the fused plan.  Results land in
``BENCH_multiquery.json``.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import best_of, emit, emit_json, mixed_update_batch
from repro.core import engine_jax as ej
from repro.core.api import QuerySpec, Session
from repro.core.dbindex import build_dbindex
from repro.core.query import GraphWindowQuery
from repro.core.windows import KHopWindow
from repro.graphs.generators import erdos_renyi, with_random_attrs

AGGS = ("sum", "count", "min", "avg")


def run(n: int = 20_000, deg: float = 6.0, k: int = 1, stream_batches: int = 20,
        json_path: str = "BENCH_multiquery.json") -> dict:
    import jax

    rng = np.random.default_rng(0)
    g = with_random_attrs(erdos_renyi(n, deg, directed=False, seed=0), seed=1)
    w = KHopWindow(k)
    idx = build_dbindex(g, w, method="emc")
    plan = ej.plan_from_dbindex(idx)
    vals = g.attrs["val"]

    # ------------- fused vs per-aggregate sequential loop -------------- #
    def sequential():
        return [
            jax.block_until_ready(ej.query_dbindex(plan, vals, a, use_pallas=False))
            for a in AGGS
        ]

    def fused():
        return jax.block_until_ready(
            ej.query_dbindex_multi(plan, vals, AGGS, use_pallas=False)
        )

    seq_outs, fused_outs = sequential(), fused()
    bit_identical = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(seq_outs, fused_outs)
    )
    assert bit_identical, "fused plan diverged from per-aggregate answers"

    us_seq = best_of(sequential, repeats=20, warmup=3)
    us_fused = best_of(fused, repeats=20, warmup=3)
    speedup = us_seq / max(us_fused, 1e-9)
    emit(f"multiquery/sequential_{len(AGGS)}agg/n{n}", us_seq, f"k={k}")
    emit(f"multiquery/fused_{len(AGGS)}agg/n{n}", us_fused, f"k={k}")
    emit(f"multiquery/speedup/n{n}", speedup, "x_fused_vs_sequential")

    # ------------- Session under a 20-batch update stream -------------- #
    specs = [QuerySpec(("khop", k), a) for a in AGGS]
    sess = Session(g, specs, device=True, use_pallas=False, plan_headroom=1.0)
    sess.run()
    cache0 = ej.query_dbindex_multi._cache_size()
    oracle_checks = 0
    for step in range(stream_batches):
        sess.update(mixed_update_batch(sess.graph, rng, 4, 2))
        res = sess.run()
        if step % 5 == 4 or step == stream_batches - 1:
            for s, r in zip(specs, res):
                ref = GraphWindowQuery(s.window, s.agg).run(sess.graph,
                                                            engine="bitset")
                assert np.allclose(r, ref, rtol=1e-5, atol=1e-3), (step, s.agg)
                oracle_checks += 1
    recompiles = ej.query_dbindex_multi._cache_size() - cache0
    emit(f"multiquery/stream_recompiles/{stream_batches}batches", recompiles, "")

    payload = {
        "config": {"n": n, "avg_degree": deg, "k": k, "aggs": list(AGGS),
                   "stream_batches": stream_batches},
        "fused": {
            "sequential_us": us_seq,
            "fused_us": us_fused,
            "speedup_fused_vs_sequential": speedup,
            "bit_identical": bool(bit_identical),
        },
        "session_stream": {
            "batches": stream_batches,
            "fused_plan_recompiles": int(recompiles),
            "oracle_checks_passed": oracle_checks,
        },
    }
    emit_json(json_path, payload)
    return payload


if __name__ == "__main__":
    run()
