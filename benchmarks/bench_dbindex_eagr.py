"""Paper Fig. 9/10: DBIndex vs EAGR — index time and query time, 1/2-hop.

Scaled-down real-shaped graphs (power-law social networks).  EAGR runs its
paper configuration (10 iterations); the memory-limit failure mode (paper:
LiveJournal/Orkut 2-hop OOM) is reproduced with a proportional cap.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.dbindex import build_dbindex
from repro.core.eagr import build_eagr
from repro.core.windows import KHopWindow
from repro.graphs.generators import barabasi_albert, with_random_attrs


def run(n: int = 2000, hops=(1, 2)):
    g = with_random_attrs(barabasi_albert(n, 5, seed=3), seed=4)
    vals = g.attrs["val"]
    for k in hops:
        w = KHopWindow(k)
        idx = build_dbindex(g, w, method="emc")
        emit(f"fig9_index_time/dbindex/k{k}", idx.stats["t_total_s"] * 1e6,
             f"n={n}")
        us = timeit(lambda: idx.query(vals, "sum"))
        emit(f"fig9_query/dbindex/k{k}", us, "")
        try:
            eagr = build_eagr(g, w, iterations=10, chunk_size=256,
                              memory_limit_bytes=200 * 2**20)
            emit(f"fig9_index_time/eagr/k{k}", eagr.stats["t_total_s"] * 1e6,
                 f"virtual={eagr.stats['num_virtual']}")
            us = timeit(lambda: eagr.query(vals, "sum"), repeats=1)
            emit(f"fig9_query/eagr/k{k}", us, "")
        except MemoryError as e:
            emit(f"fig9_index_time/eagr/k{k}", float("nan"), f"OOM:{e}")


if __name__ == "__main__":
    run()
