"""Serving-layer benchmark: WindowService vs per-request Session.run().

Acceptance targets (ISSUE 4), asserted here and recorded in
``BENCH_service.json``:

* the micro-batched service sustains **>= 5x the QPS** of per-request
  ``Session.run()`` calls on point-window traffic with a concurrent
  update stream (both sides replay the identical update + request trace);
* **every served result is bit-identical** to an oracle fresh-Session
  evaluation at the pinned version (attribute values are small integers,
  so f32 monoid reductions are exact under any evaluation order — cached,
  batched-padded, and freshly-planned executions must agree bitwise);
* **zero executable recompiles** across >= 20 scheduler flushes (the
  fixed-bucket padding + plan-patching no-retrace contract).

Run: ``PYTHONPATH=src python -m benchmarks.bench_service [--smoke]``.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, emit_json, mixed_update_batch, _obs_snapshot


def _percentiles_us(lat_s):
    lat = np.asarray(lat_s) * 1e6
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 99))


def run(n: int = 20_000, deg: float = 6.0, k: int = 1, ticks: int = 20,
        point_q: int = 256, explicit_q: int = 8, bucket: int = 8,
        oracle_ticks=(0, 10, 19), smoke: bool = False,
        json_path: str = "BENCH_service.json") -> dict:
    from repro.core import engine_jax as ej
    from repro.core.api import QuerySpec, Session, run_many_cache_size
    from repro.graphs.generators import erdos_renyi
    from repro.serve import WindowService

    if smoke:  # smaller graph/load, but still >= 20 flushes (acceptance)
        n, point_q, explicit_q = 2_000, 32, 4
        oracle_ticks = (0, ticks - 1)

    rng = np.random.default_rng(0)
    g = erdos_renyi(n, deg, directed=False, seed=0)
    # small-integer attributes: bit-identity across plan shapes is exact
    g = g.with_attr("val", rng.integers(0, 100, g.n).astype(np.float64))
    aggs = ("sum", "count", "min", "avg")
    specs = [QuerySpec(("khop", k), a) for a in aggs]

    def make_session():
        return Session(g, specs, device=True, use_pallas=False,
                       plan_headroom=1.0)

    # one request trace shared by both sides: per tick, one mixed update
    # batch + point reads (current attrs) + explicit-values rows
    sess = make_session()
    svc = WindowService(sess, bucket=bucket)
    trace = []
    for t in range(ticks):
        points = [(int(rng.integers(len(specs))), int(rng.integers(n)))
                  for _ in range(point_q)]
        explicit = [
            (int(rng.integers(len(specs))), int(rng.integers(n)),
             rng.integers(0, 100, n).astype(np.float64))
            for _ in range(explicit_q)
        ]
        trace.append((points, explicit))

    # ----------------------- service side ------------------------------ #
    # warmup: compile the [n] refresh + the [bucket, n] batched executable
    svc.query(0, vertex=0)
    svc.submit(0, values=trace[0][1][0][2])
    svc.flush()
    compiles0 = run_many_cache_size() + ej.query_dbindex_multi._cache_size()
    flushes0 = svc.flushes

    batches, tick_graphs, served = [], [], []
    svc_lat = []
    t0 = time.perf_counter()
    for t in range(ticks):
        batch = mixed_update_batch(svc.session.graph, rng, 8, 4)
        batches.append(batch)
        svc.update(batch)
        points, explicit = trace[t]
        tickets = [svc.submit(si, vertex=v) for si, v in points]
        tickets += [svc.submit(si, vertex=v, values=vals)
                    for si, v, vals in explicit]
        svc.flush()
        svc_lat.extend(tk.latency_s for tk in tickets)
        tick_graphs.append(svc.session.graph)
        served.append([(tk.spec_index, tk.vertex, tk.values, tk.result)
                       for tk in tickets])
    svc_wall = time.perf_counter() - t0
    recompiles = (run_many_cache_size() + ej.query_dbindex_multi._cache_size()
                  - compiles0)
    n_req = ticks * (point_q + explicit_q)
    qps_svc = n_req / svc_wall
    assert svc.flushes - flushes0 >= 20, "need >= 20 scheduler flushes"
    assert recompiles == 0, f"{recompiles} recompiles across the stream"

    # ----------------------- direct baseline --------------------------- #
    # identical update stream + request trace, one blocking Session.run()
    # per request (the pre-serving-layer calling convention)
    direct = make_session()
    direct_lat = []
    t0 = time.perf_counter()
    for t in range(ticks):
        direct.update(batches[t])
        points, explicit = trace[t]
        for si, v in points:
            q0 = time.perf_counter()
            res = direct.run()
            _ = np.asarray(res[si])[v]
            direct_lat.append(time.perf_counter() - q0)
        for si, v, vals in explicit:
            q0 = time.perf_counter()
            res = direct.run(values=vals)
            _ = np.asarray(res[si])[v]
            direct_lat.append(time.perf_counter() - q0)
    direct_wall = time.perf_counter() - t0
    qps_direct = n_req / direct_wall
    speedup = qps_svc / qps_direct
    if not smoke:  # at smoke scale (n=2k) the margin straddles 5x on a
        # loaded CI box; the acceptance number is the full-scale run
        assert speedup >= 5.0, f"service QPS only {speedup:.1f}x direct"

    # ----------------------- bit-identity oracle ------------------------ #
    # fresh, un-cached Sessions at the pinned versions (deferred past the
    # recompile count: fresh plans have fresh shapes and may trace anew)
    oracle_checks = 0
    for t in oracle_ticks:
        fresh = Session(tick_graphs[t], specs, device=True, use_pallas=False)
        refs = [np.asarray(r) for r in fresh.run()]
        by_vals = {}
        for si, v, vals, result in served[t]:
            if vals is None:
                ref = refs[si]
            else:
                key = id(vals)
                if key not in by_vals:
                    by_vals[key] = [np.asarray(r)
                                    for r in fresh.run(values=vals)]
                ref = by_vals[key][si]
            want = ref[v] if v is not None else ref
            assert np.array_equal(np.asarray(result), want), (t, si, v)
            oracle_checks += 1

    svc_p50, svc_p99 = _percentiles_us(svc_lat)
    dir_p50, dir_p99 = _percentiles_us(direct_lat)
    emit(f"service/direct_qps/n{n}", 1e6 / qps_direct, f"{qps_direct:.0f}qps")
    emit(f"service/batched_qps/n{n}", 1e6 / qps_svc, f"{qps_svc:.0f}qps")
    emit(f"service/speedup/n{n}", speedup, "x_qps_vs_per_request")
    emit(f"service/recompiles/{svc.flushes - flushes0}flushes", recompiles, "")

    stats = svc.stats
    payload = {
        "config": {"n": n, "avg_degree": deg, "k": k, "aggs": list(aggs),
                   "ticks": ticks, "point_queries_per_tick": point_q,
                   "explicit_queries_per_tick": explicit_q, "bucket": bucket,
                   "update_batch": "8 inserts + 4 deletes per tick"},
        "direct": {"qps": qps_direct, "p50_us": dir_p50, "p99_us": dir_p99},
        "service": {
            "qps": qps_svc, "p50_us": svc_p50, "p99_us": svc_p99,
            "flushes": svc.flushes - flushes0,
            "batched_launches": stats["batched_launches"],
            "cache_hit_rate": stats["point_hit_rate"],
            "recompiles": int(recompiles),
        },
        "speedup_qps": speedup,
        "bit_identical": True,
        "oracle": {"checks": oracle_checks,
                   "ticks_checked": list(oracle_ticks)},
        # empty when obs is disabled (the default for timed runs)
        "obs_snapshot": _obs_snapshot(),
    }
    emit_json(json_path, payload)
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep for CI (n=2k, lighter ticks; still "
                         "20 flushes so the no-recompile acceptance runs)")
    args = ap.parse_args()
    run(smoke=args.smoke)
