"""Kernel-level benches: device query data plane vs NumPy, plus roofline
bytes accounting for the segment-reduce primitive (the TPU hot path)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import engine_jax as ej
from repro.core.dbindex import build_dbindex
from repro.core.iindex import build_iindex
from repro.core.windows import KHopWindow, TopologicalWindow
from repro.graphs.generators import erdos_renyi, random_dag, with_random_attrs


def run():
    g = with_random_attrs(erdos_renyi(30_000, 10.0, seed=21), seed=22)
    idx = build_dbindex(g, KHopWindow(2), method="emc")
    plan = ej.plan_from_dbindex(idx)
    vals = jnp.asarray(g.attrs["val"], jnp.float32)

    us_np = timeit(lambda: idx.query(g.attrs["val"], "sum"))
    emit("engine/dbindex_query_numpy", us_np, "")
    fn = jax.jit(lambda v: ej.query_dbindex(plan, v, "sum", use_pallas=False))
    fn(vals).block_until_ready()
    us_xla = timeit(lambda: fn(vals).block_until_ready())
    members = int(idx.stats["num_members"])
    bytes_moved = members * 4 * 2 + idx.stats["num_links"] * 4 * 2
    emit("engine/dbindex_query_xla_cpu", us_xla,
         f"members={members};approx_bytes={bytes_moved};"
         f"tpu_roofline_us={bytes_moved/819e9*1e6:.1f}")

    dag = with_random_attrs(random_dag(30_000, 5.0, seed=23, locality=200), seed=24)
    ii = build_iindex(dag)
    iplan = ej.plan_from_iindex(ii)
    dvals = jnp.asarray(dag.attrs["val"], jnp.float32)
    for sched in ("level", "doubling"):
        f = jax.jit(lambda v, s=sched: ej.query_iindex(iplan, v, schedule=s,
                                                       use_pallas=False))
        f(dvals).block_until_ready()
        us = timeit(lambda: f(dvals).block_until_ready())
        emit(f"engine/iindex_query_{sched}", us,
             f"max_level={iplan.max_level}")


if __name__ == "__main__":
    run()
