"""Benchmark harness utilities: timing + CSV/JSON emission.

Output contract (benchmarks/run.py): ``name,us_per_call,derived`` rows.
Benchmarks that need structured results (e.g. ``bench_updates`` →
``BENCH_updates.json``) additionally call :func:`emit_json`.
"""

from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def timeit(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def emit_json(path: str, payload: Dict) -> None:
    """Write a structured benchmark result file (sorted keys, trailing NL)."""
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}")


def flush_csv(path: str = None):
    if path:
        with open(path, "w") as f:
            f.write("name,us_per_call,derived\n")
            for n, u, d in ROWS:
                f.write(f"{n},{u:.1f},{d}\n")
