"""Benchmark harness utilities: timing + CSV/JSON emission.

Output contract (benchmarks/run.py): ``name,us_per_call,derived`` rows.
Benchmarks that need structured results (e.g. ``bench_updates`` →
``BENCH_updates.json``) additionally call :func:`emit_json`.
"""

from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def timeit(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def emit_json(path: str, payload: Dict) -> None:
    """Write a structured benchmark result file (sorted keys, trailing NL)."""
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}")


def flush_csv(path: str = None):
    if path:
        with open(path, "w") as f:
            f.write("name,us_per_call,derived\n")
            for n, u, d in ROWS:
                f.write(f"{n},{u:.1f},{d}\n")


def mixed_update_batch(g, rng, n_ins: int, n_del: int):
    """Random mixed UpdateBatch for stream benchmarks: ``n_ins`` fresh
    non-duplicate inserts + ``n_del`` deletes of existing edges (shared by
    bench_multiquery and bench_sharded_stream)."""
    import numpy as np

    from repro.core.updates import UpdateBatch

    s = rng.integers(0, g.n, n_ins * 4).astype(np.int32)
    d = rng.integers(0, g.n, n_ins * 4).astype(np.int32)
    ok = (s != d) & ~g.contains_edges(s, d)
    _, first = np.unique(g.edge_keys(s, d), return_index=True)
    pick = np.intersect1d(np.flatnonzero(ok), first)[:n_ins]
    ins = UpdateBatch.inserts(s[pick], d[pick])
    ei = rng.choice(g.n_edges, min(n_del, g.n_edges), replace=False)
    return UpdateBatch.concat([ins, UpdateBatch.deletes(g.src[ei], g.dst[ei])])


def _obs_snapshot() -> Dict:
    """The global obs registry snapshot for bench payloads — {} when obs
    is disabled, so timed runs stay uninstrumented by default."""
    from repro import obs

    return obs.get_registry().snapshot()


def best_of(fn: Callable, repeats: int = 10, warmup: int = 2) -> float:
    """Min wall time in microseconds — the robust estimator on shared boxes
    (noise only ever adds time; the min is the closest sample to the true
    cost, and both sides of a comparison are measured the same way)."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best
