"""Online-auditing overhead + detection latency (ISSUE 9).

Acceptance, asserted here and recorded in ``BENCH_audit.json``:

* **overhead** — attaching a :class:`~repro.obs.audit.ShadowAuditor` at 1%
  sampling costs **< 5% QPS** on the serving hot path and triggers **zero
  recompiles** (the oracle is pure NumPy).  Two identical `WindowService`
  stacks replay the same request/update trace in interleaved rounds, each
  side scored by its best round (same estimator as
  ``bench_obs_overhead``); zero mismatches on the clean stream is the
  **zero-false-positive** record.
* **detection** — one byte flipped in a sealed WAL record and one element
  poisoned in a served result vector are both detected, with the finding
  attributing the exact version / WAL byte offset / vertex, and the
  wall-clock corruption-to-finding latency recorded.
* **replication** — a 20-batch leader stream with per-version digest
  stamping replays into a follower whose locally recomputed digest matches
  the leader's for **every** version (digest_checks == versions,
  divergence None).

Run: ``PYTHONPATH=src python -m benchmarks.bench_audit [--smoke]``.
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks.common import emit, emit_json, mixed_update_batch

MAX_OVERHEAD = 0.05
SAMPLE_RATE = 0.01


def run(n: int = 8_000, deg: float = 5.0, rounds: int = 7, ticks: int = 4,
        point_q: int = 64, bucket: int = 8, stream_batches: int = 20,
        smoke: bool = False, json_path: str = "BENCH_audit.json") -> dict:
    from repro.core import api
    from repro.core.api import QuerySpec, Session
    from repro.graphs.generators import erdos_renyi
    from repro.obs.audit import ShadowAuditor, WalScrubber
    from repro.serve import AsyncWindowService, ReadReplica, WindowService
    from repro.serve.wal import _REC_HDR, scan_wal_entries

    if smoke:
        n, rounds, ticks, point_q = 2_000, 3, 2, 24

    rng = np.random.default_rng(0)
    g = erdos_renyi(n, deg, directed=False, seed=0)
    g = g.with_attr("val", rng.integers(0, 100, g.n).astype(np.float64))
    specs = [QuerySpec(("khop", 1), "sum"), QuerySpec(("khop", 1), "min")]

    # ------------------------------------------------------------------ #
    #  1% sampling overhead: identical trace, interleaved best-of-rounds
    # ------------------------------------------------------------------ #
    trace = [[(int(rng.integers(len(specs))), int(rng.integers(n)))
              for _ in range(point_q)] for _ in range(ticks)]
    batch_seed = int(rng.integers(2**31))

    def build():
        sess = Session(g, specs, device=True, use_pallas=False,
                       plan_headroom=1.0)
        return WindowService(sess, bucket=bucket)

    def play(svc):
        r = np.random.default_rng(batch_seed)
        n_served = 0
        for t in range(ticks):
            svc.update(mixed_update_batch(svc.session.graph, r, 6, 3))
            tickets = [svc.submit(si, vertex=v) for si, v in trace[t]]
            svc.flush()
            n_served += sum(tk.error is None for tk in tickets)
        assert n_served == ticks * point_q
        return n_served

    svc_base = build()
    svc_audited = build()
    auditor = ShadowAuditor(sample_rate=SAMPLE_RATE)
    svc_audited.attach_auditor(auditor)
    auditor.start()
    for svc in (svc_base, svc_audited):  # warm every executor shape
        play(svc)
    recompiles_before = api.recompile_count()

    n_req = ticks * point_q
    best = {"base": float("inf"), "audited": float("inf")}
    for _ in range(rounds):  # interleaved A/B: same weather for both
        for key, svc in (("base", svc_base), ("audited", svc_audited)):
            t0 = time.perf_counter()
            play(svc)
            best[key] = min(best[key], time.perf_counter() - t0)

    auditor.drain(timeout=60)
    auditor.stop()
    recompiles = api.recompile_count() - recompiles_before
    qps_base = n_req / best["base"]
    qps_audited = n_req / best["audited"]
    overhead = best["audited"] / best["base"] - 1.0
    emit(f"audit/base_qps/n{n}", 1e6 / qps_base, f"{qps_base:.0f}qps")
    emit(f"audit/audited_qps/n{n}", 1e6 / qps_audited,
         f"{qps_audited:.0f}qps")
    emit(f"audit/overhead/n{n}",
         best["audited"] * 1e6 - best["base"] * 1e6,
         f"{overhead * 100:.2f}pct")
    assert overhead < MAX_OVERHEAD, (
        f"audit overhead {overhead * 100:.2f}% exceeds "
        f"{MAX_OVERHEAD * 100:.0f}% "
        f"({qps_audited:.0f} vs {qps_base:.0f} qps)")
    assert recompiles == 0, f"auditing recompiled {recompiles}x"
    assert auditor.mismatches == 0, (
        f"false positives on a clean stream: {auditor.stats['findings']}")

    # ------------------------------------------------------------------ #
    #  detection: sealed-WAL byte flip + poisoned served vector
    # ------------------------------------------------------------------ #
    with tempfile.TemporaryDirectory() as tmp:
        wal_path = os.path.join(tmp, "leader.wal")
        svc = AsyncWindowService(
            Session(g, specs, device=True, use_pallas=False,
                    plan_headroom=1.0),
            bucket=bucket, wal=wal_path).start()
        r = np.random.default_rng(1)
        for _ in range(stream_batches):
            svc.update(mixed_update_batch(svc.session.graph, r, 6, 3))
        svc.stop()
        svc.wal.sync()

        target = [e for e in scan_wal_entries(wal_path)[0]
                  if e["kind"] == "batch"][stream_batches // 2]
        t_corrupt = time.perf_counter()
        with open(wal_path, "r+b") as f:
            f.seek(target["offset"] + _REC_HDR.size + 3)
            b = f.read(1)
            f.seek(-1, 1)
            f.write(bytes([b[0] ^ 0xFF]))
        scrub = WalScrubber(wal_path)
        found = scrub.scrub_once()
        scrub_latency = time.perf_counter() - t_corrupt
        assert len(found) == 1 and found[0].version == target["version"] \
            and found[0].wal_offset == target["offset"]
        emit("audit/scrub_detect", scrub_latency * 1e6,
             f"v{found[0].version}@{found[0].wal_offset}")

        # poisoned served vector: cache hit serves the bad byte, the
        # shadow oracle catches it
        det = ShadowAuditor(sample_rate=1.0).start()
        svc2 = WindowService(
            Session(g, specs, device=True, use_pallas=False,
                    plan_headroom=1.0), bucket=bucket)
        svc2.attach_auditor(det)
        svc2.query(0)  # warm the full vector the cache will serve from
        t_corrupt = time.perf_counter()
        svc2.cache._entries[0]["vectors"]["sum"][7] += 1.0
        svc2.query(0, vertex=7)
        det.drain(timeout=60)
        oracle_latency = time.perf_counter() - t_corrupt
        det.stop()
        assert det.mismatches == 1 and det.findings[0].vertex == 7
        emit("audit/oracle_detect", oracle_latency * 1e6,
             f"vertex{det.findings[0].vertex}")

        detection = {
            "wal_scrub": {
                "detected": True,
                "version": int(found[0].version),
                "wal_offset": int(found[0].wal_offset),
                "latency_s": scrub_latency,
            },
            "oracle": {
                "detected": True,
                "vertex": int(det.findings[0].vertex),
                "version": int(det.findings[0].version),
                "latency_s": oracle_latency,
            },
        }

        # -------------------------------------------------------------- #
        #  replication: every version's digest matches bitwise
        # -------------------------------------------------------------- #
        rep_path = os.path.join(tmp, "digested.wal")
        leader = AsyncWindowService(
            Session(g, specs, device=True, use_pallas=False,
                    plan_headroom=1.0),
            bucket=bucket, wal=rep_path).start()
        r = np.random.default_rng(2)
        for _ in range(stream_batches):
            leader.update(mixed_update_batch(leader.session.graph, r, 6, 3))
        leader.stop()
        leader.wal.sync()
        follower = ReadReplica(g, specs, rep_path, device=True,
                               use_pallas=False, plan_headroom=1.0)
        t0 = time.perf_counter()
        applied = follower.catch_up()
        catchup_s = time.perf_counter() - t0
        assert applied == stream_batches
        assert follower.digest_checks == stream_batches, (
            f"only {follower.digest_checks}/{stream_batches} digests checked")
        assert follower.divergence is None, follower.divergence
        emit(f"audit/replication_catchup/b{stream_batches}", catchup_s * 1e6,
             f"{follower.digest_checks}digests")

    payload = {
        "config": {"n": n, "avg_degree": deg, "rounds": rounds,
                   "ticks_per_round": ticks,
                   "point_queries_per_tick": point_q, "bucket": bucket,
                   "stream_batches": stream_batches,
                   "estimator": "best-of-rounds, interleaved"},
        "audit": {
            "sample_rate": SAMPLE_RATE,
            "qps_base": qps_base,
            "qps_audited": qps_audited,
            "overhead_fraction": overhead,
            "max_overhead_fraction": MAX_OVERHEAD,
            "samples": auditor.sampled,
            "audited": auditor.audited,
            "dropped_samples": auditor.dropped_samples,
            "false_positives": auditor.mismatches,
            "recompiles": recompiles,
        },
        "detection": detection,
        "replication": {
            "versions": stream_batches,
            "digest_checks": follower.digest_checks,
            "digests_matched": follower.divergence is None,
            "divergences": 0 if follower.divergence is None else 1,
            "catchup_s": catchup_s,
        },
    }
    emit_json(json_path, payload)
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep for CI (n=2k, 3 rounds)")
    args = ap.parse_args()
    run(smoke=args.smoke)
