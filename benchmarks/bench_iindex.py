"""Paper Fig. 14-16: I-Index vs DBIndex vs non-index on DAGs.

Degree and |V| sweeps on DAGGER-style random DAGs (locality-bounded so the
ancestor sets match the paper's pathway-graph regime), plus the index-size
ratio (Fig 16)."""

from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core.dbindex import build_dbindex
from repro.core.iindex import build_iindex
from repro.core.nonindex import query_batched_bitset
from repro.core.windows import TopologicalWindow
from repro.graphs.generators import random_dag, with_random_attrs


def run(fast: bool = False):
    w = TopologicalWindow()
    # Fig 14: degree sweep at fixed |V| (paper 30k/60k; here 5k/10k)
    for n in ((5_000,) if fast else (5_000, 10_000)):
        for deg in ((3, 10) if fast else (3, 5, 10)):
            g = with_random_attrs(random_dag(n, float(deg), seed=deg, locality=200),
                                  seed=deg + 1)
            ii = build_iindex(g)
            emit(f"fig14_index_time/iindex/n{n}/deg{deg}",
                 ii.stats["t_total_s"] * 1e6, f"maxlvl={ii.stats['max_level']}")
            db = build_dbindex(g, w)
            emit(f"fig14_index_time/dbindex/n{n}/deg{deg}",
                 db.stats["t_total_s"] * 1e6, "")
            us = timeit(lambda: ii.query(g.attrs["val"], "sum"))
            emit(f"fig14_query/iindex/n{n}/deg{deg}", us, "")
            us = timeit(lambda: db.query(g.attrs["val"], "sum"))
            emit(f"fig14_query/dbindex/n{n}/deg{deg}", us, "")
            us = timeit(lambda: query_batched_bitset(g, w, g.attrs["val"], "sum"),
                        repeats=1)
            emit(f"fig14_query/nonindex/n{n}/deg{deg}", us, "")
    # Fig 15: |V| sweep at fixed degree
    for deg in ((10,) if fast else (10, 20)):
        for n in ((10_000,) if fast else (10_000, 25_000, 50_000)):
            g = with_random_attrs(random_dag(n, float(deg), seed=n + deg,
                                             locality=200), seed=n)
            ii = build_iindex(g)
            emit(f"fig15_index_time/deg{deg}/n{n}", ii.stats["t_total_s"] * 1e6, "")
            us = timeit(lambda: ii.query(g.attrs["val"], "sum"))
            emit(f"fig15_query/deg{deg}/n{n}", us, "")
    # Fig 16: index size ratio across degrees
    for n in ((10_000,) if fast else (10_000, 30_000)):
        gsize = None
        for deg in (3, 5, 10, 20):
            g = random_dag(n, float(deg), seed=deg, locality=200)
            gsize = g.src.nbytes + g.dst.nbytes
            ii = build_iindex(g)
            emit(f"fig16_size_ratio/n{n}/deg{deg}", ii.size_bytes(),
                 f"ratio={ii.size_bytes()/gsize:.2f}")


if __name__ == "__main__":
    run()
