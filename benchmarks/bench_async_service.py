"""Async serving tier benchmark: deadline batching, shedding, recovery.

Acceptance targets (ISSUE 6), asserted here and recorded in
``BENCH_async_service.json``:

* **p50/p99 latency per request class** for >= 64 concurrent client
  threads against a live concurrent update stream (every update is also
  WAL-appended — durability is on the measured path);
* **deadline flushing beats fill-only flushing on p99 at low load**: a
  trickle of point reads is bounded by the class deadline instead of
  waiting for the bucket to fill;
* **load shedding engages under overload** (sheddable full-graph scans
  evicted, point reads never) and the shed rate is reported;
* **crash recovery replay time**: rebuilding the session by replaying the
  WAL written during the benchmark, verified bit-identical at head.

Run: ``PYTHONPATH=src python -m benchmarks.bench_async_service [--smoke]``.
"""

from __future__ import annotations

import os
import tempfile
import threading
import time

import numpy as np

from benchmarks.common import emit, emit_json, mixed_update_batch, _obs_snapshot


def _pcts(lat_s):
    lat = np.asarray(lat_s, np.float64) * 1e6
    if lat.size == 0:
        return 0.0, 0.0
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 99))


def run(n: int = 20_000, deg: float = 6.0, k: int = 1, clients: int = 64,
        bucket: int = 16, updates: int = 8, max_reqs_per_client: int = 5_000,
        smoke: bool = False,
        json_path: str = "BENCH_async_service.json") -> dict:
    from repro.core.api import QuerySpec, Session
    from repro.graphs.generators import erdos_renyi
    from repro.serve import (
        AsyncWindowService,
        LoadShedError,
        RequestClass,
        WindowService,
    )

    if smoke:
        n, updates = 2_000, 12
    assert clients >= 64, "acceptance: >= 64 concurrent clients"

    rng = np.random.default_rng(0)
    g = erdos_renyi(n, deg, directed=False, seed=0)
    g = g.with_attr("val", rng.integers(0, 100, g.n).astype(np.float64))
    specs = [QuerySpec(("khop", k), a) for a in ("sum", "min")]

    def make_session():
        return Session(g, specs, device=True, use_pallas=False,
                       plan_headroom=1.0)

    wal_path = os.path.join(tempfile.mkdtemp(prefix="bench_wal_"), "svc.wal")

    # ------------- phase 1: concurrent clients + update stream ---------- #
    svc = AsyncWindowService(make_session(), bucket=bucket, wal=wal_path,
                             max_pending=8 * clients)
    svc.query(0, vertex=0)  # warm the query compile caches off the clock
    # warm the maintenance path too (first update compiles the affected-
    # owner BFS + patch executables); it is WAL-logged like any other
    svc.update(mixed_update_batch(svc.session.graph,
                                  np.random.default_rng(99), 8, 4))
    done = threading.Event()
    n_updates = [1]

    def writer():
        # the writer is the phase clock: back-to-back updates (index/plan
        # maintenance is the pacing), clients hammer reads the whole time
        wrng = np.random.default_rng(1)
        while n_updates[0] < updates:
            svc.update(mixed_update_batch(svc.session.graph, wrng, 8, 4))
            n_updates[0] += 1
        done.set()

    tickets_by_class = {"point": [], "interactive": [], "batch": []}
    lock = threading.Lock()

    def client(cid: int):
        crng = np.random.default_rng(100 + cid)
        mine = {"point": [], "interactive": [], "batch": []}
        for i in range(max_reqs_per_client):
            if done.is_set():
                break
            # 80% point reads, 15% interactive point reads, 5% batch scans
            r = crng.random()
            if r < 0.80:
                cls = "point"
                t = svc.submit(int(crng.integers(len(specs))),
                               vertex=int(crng.integers(n)))
            elif r < 0.95:
                cls = "interactive"
                t = svc.submit(int(crng.integers(len(specs))),
                               vertex=int(crng.integers(n)),
                               request_class="interactive")
            else:
                cls = "batch"
                try:
                    t = svc.submit(int(crng.integers(len(specs))),
                                   request_class="batch")
                except LoadShedError:
                    continue
            try:
                t.get(timeout=60)
                mine[cls].append(t)
            except LoadShedError:
                pass
            time.sleep(float(crng.random()) * 2e-3)
        with lock:
            for c, ts in mine.items():
                tickets_by_class[c].extend(ts)

    with svc:
        wt = threading.Thread(target=writer)
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(clients)]
        t0 = time.perf_counter()
        wt.start()
        for t in threads:
            t.start()
        wt.join()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        stats = svc.stats
    svc.wal.close()

    served = sum(len(v) for v in tickets_by_class.values())
    per_class = {}
    for cls, ts in tickets_by_class.items():
        p50, p99 = _pcts([t.latency_s for t in ts])
        per_class[cls] = {"count": len(ts), "p50_us": p50, "p99_us": p99}
        emit(f"async_service/{cls}_p99/n{n}c{clients}", p99,
             f"p50={p50:.0f}us")
    qps = served / wall
    emit(f"async_service/qps/n{n}c{clients}", 1e6 / max(qps, 1e-9),
         f"{qps:.0f}qps")

    # ------------- phase 2: crash recovery replay time ------------------ #
    head = np.asarray(WindowService(
        Session.restore_from_wal(g, specs, wal_path, device=True,
                                 use_pallas=False, plan_headroom=1.0)
    ).query(0))  # smoke the whole pipeline once before timing
    t0 = time.perf_counter()
    recovered = Session.restore_from_wal(g, specs, wal_path, device=True,
                                         use_pallas=False, plan_headroom=1.0)
    replay_s = time.perf_counter() - t0
    assert recovered.version == n_updates[0]
    assert np.array_equal(np.asarray(recovered.run()[0]), head), \
        "recovery is not deterministic"
    emit(f"async_service/recovery_replay/{n_updates[0]}batches",
         replay_s * 1e6, f"{replay_s:.3f}s")

    # ------------- phase 3: shed rate under overload -------------------- #
    shed_svc = AsyncWindowService(make_session(), bucket=4, max_pending=8)
    shed_svc._flush_lock.acquire()  # stall the flusher: forced overload
    shed_svc.start()
    submitted = 64
    held = []
    for i in range(submitted):
        try:
            held.append(shed_svc.submit(0, request_class="batch"))
        except LoadShedError:
            pass
    shed = shed_svc.shed
    shed_svc._flush_lock.release()
    shed_svc.stop()
    shed_rate = shed / submitted
    assert shed > 0, "overload never shed anything"
    emit(f"async_service/shed_rate/{submitted}scans", shed_rate * 1e2,
         f"{shed}shed")

    # ------------- phase 4: deadline vs fill-only at low load ----------- #
    def trickle(classes, cls_name, n_req=40, gap_s=0.01):
        s = AsyncWindowService(make_session(), bucket=8, classes=classes)
        s.query(0, vertex=0)
        lat = []
        with s:
            ts = []
            for i in range(n_req):
                ts.append(s.submit(0, vertex=i % n, request_class=cls_name))
                time.sleep(gap_s)
            for t in ts:
                t.get(timeout=60)
                lat.append(t.latency_s)
        return lat

    dl_lat = trickle(None, "point")  # 2 ms deadline class
    fill_only = {"fill": RequestClass("fill", max_delay_ms=600_000.0,
                                      priority=100, sheddable=False)}
    fo_lat = trickle(fill_only, "fill")  # completes only on bucket fill
    dl_p50, dl_p99 = _pcts(dl_lat)
    fo_p50, fo_p99 = _pcts(fo_lat)
    assert dl_p99 < fo_p99, (
        f"deadline p99 {dl_p99:.0f}us must beat fill-only {fo_p99:.0f}us "
        f"at low load")
    emit("async_service/lowload_deadline_p99", dl_p99, f"p50={dl_p50:.0f}us")
    emit("async_service/lowload_fillonly_p99", fo_p99, f"p50={fo_p50:.0f}us")

    payload = {
        "config": {"n": n, "avg_degree": deg, "k": k, "clients": clients,
                   "updates": updates, "bucket": bucket,
                   "update_batch": "8 inserts + 4 deletes per tick",
                   "smoke": smoke},
        "concurrent": {
            "qps": qps, "wall_s": wall, "served": served,
            "updates_applied": n_updates[0],
            "per_class": per_class,
            "deadline_flushes": stats["deadline_flushes"],
            "fill_flushes": stats["fill_flushes"],
            "shed": stats["shed"],
            "backpressure_waits": stats["backpressure_waits"],
            "cache_hit_rate": stats["point_hit_rate"],
        },
        "recovery": {"replay_s": replay_s, "batches": n_updates[0],
                     "wal_bytes": os.path.getsize(wal_path),
                     "bit_identical": True},
        "shedding": {"submitted": submitted, "shed": shed,
                     "rate": shed_rate},
        "low_load": {"deadline_p50_us": dl_p50, "deadline_p99_us": dl_p99,
                     "fillonly_p50_us": fo_p50, "fillonly_p99_us": fo_p99,
                     "deadline_beats_fillonly": bool(dl_p99 < fo_p99)},
        # empty when obs is disabled (the default for timed runs)
        "obs_snapshot": _obs_snapshot(),
    }
    emit_json(json_path, payload)
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep for CI (n=2k; still 64 concurrent "
                         "clients, shedding, recovery, and the "
                         "deadline-vs-fill-only acceptance)")
    args = ap.parse_args()
    run(smoke=args.smoke)
