"""Sharded streaming benchmark: mesh query + per-shard patch wire format.

Acceptance targets (ISSUE 3):

* the sharded fused multi-aggregate query is **bit-identical** to the
  single-host fused path on a multi-device (forced host-platform) mesh;
* a streamed batch ships only changed tile groups per shard — asserted
  ``patch bytes < full plan bytes`` — with **zero recompiles** of the
  sharded fused query across >= 10 batches.

Results land in ``BENCH_sharded.json``: single-host vs sharded query wall
time (CPU meshes pay collective overhead — the number documents the cost
model, the win is the memory/scale headroom) and patch-bytes-shipped vs a
full-plan re-upload per batch.
"""

from __future__ import annotations

import os

# must be set before jax initializes (first jax import below)
os.environ.setdefault("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in os.environ["XLA_FLAGS"]:
    os.environ["XLA_FLAGS"] += " --xla_force_host_platform_device_count=8"

import numpy as np

from benchmarks.common import best_of, emit, emit_json, mixed_update_batch

AGGS = ("sum", "count", "min", "avg")


def run(n: int = 20_000, deg: float = 5.0, k: int = 1, shards: int = 4,
        stream_batches: int = 12,
        json_path: str = "BENCH_sharded.json") -> dict:
    import jax

    from repro.core import engine_jax as ej
    from repro.core.api import QuerySpec, Session
    from repro.core.dbindex import build_dbindex
    from repro.core.windows import KHopWindow
    from repro.distributed import window_runtime as wr
    from repro.graphs.generators import erdos_renyi, with_random_attrs

    assert len(jax.devices()) >= shards, (
        f"need {shards} host-platform devices (XLA_FLAGS), "
        f"have {len(jax.devices())}")
    mesh = jax.make_mesh((shards,), ("data",))
    rng = np.random.default_rng(0)
    g = with_random_attrs(erdos_renyi(n, deg, directed=False, seed=0), seed=1)
    w = KHopWindow(k)
    idx = build_dbindex(g, w, method="emc")
    plan = ej.plan_from_dbindex(idx)
    splan = wr.build_sharded_plan(plan, mesh, "data")
    vals = g.attrs["val"]

    # ------------- sharded fused vs single-host fused ------------------ #
    def single_host():
        return jax.block_until_ready(
            ej.query_dbindex_multi(plan, vals, AGGS, use_pallas=False))

    def sharded():
        return jax.block_until_ready(wr.query_sharded_multi(splan, vals, AGGS))

    host_outs, shard_outs = single_host(), sharded()
    bit_identical = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(host_outs, shard_outs)
    )
    assert bit_identical, "sharded fused query diverged from single host"
    us_host = best_of(single_host)
    us_shard = best_of(sharded)
    emit(f"sharded/single_host_{len(AGGS)}agg/n{n}", us_host, f"k={k}")
    emit(f"sharded/mesh{shards}_{len(AGGS)}agg/n{n}", us_shard, f"k={k}")
    emit(f"sharded/speedup/n{n}", us_host / max(us_shard, 1e-9),
         "x_single_host_vs_sharded")

    # ------------- streamed updates: patch bytes vs full re-upload ----- #
    specs = [QuerySpec(("khop", k), a) for a in AGGS]
    sess = Session(g, specs, mesh=mesh, plan_headroom=1.0)
    sess.run()
    cache0 = wr.query_cache_size()
    patch_bytes, full_bytes, per_shard = [], None, []
    for _ in range(stream_batches):
        reports = sess.update(mixed_update_batch(sess.graph, rng, 32, 16))
        rep = next(iter(reports.values()))
        # a policy reorganize legitimately re-uploads the full plan; every
        # incremental batch must ship strictly less than the plan
        assert rep["reorganized"] or (
            0 < rep["patch_bytes"] < rep["full_plan_bytes"]), rep
        patch_bytes.append(rep["patch_bytes"])
        per_shard.append(rep["patch_bytes_per_shard"])
        full_bytes = rep["full_plan_bytes"]
        sess.run()
    recompiles = wr.query_cache_size() - cache0
    assert recompiles == 0, f"{recompiles} recompiles across the stream"
    mean_patch = float(np.mean(patch_bytes))
    emit(f"sharded/stream_patch_bytes/{stream_batches}batches", mean_patch,
         f"vs_full_{full_bytes}B")
    emit(f"sharded/stream_recompiles/{stream_batches}batches", recompiles, "")

    payload = {
        "config": {"n": n, "avg_degree": deg, "k": k, "shards": shards,
                   "aggs": list(AGGS), "stream_batches": stream_batches},
        "query": {
            "single_host_us": us_host,
            "sharded_us": us_shard,
            "bit_identical": bool(bit_identical),
        },
        "stream": {
            "batches": stream_batches,
            "mean_patch_bytes": mean_patch,
            "max_patch_bytes": int(max(patch_bytes)),
            "full_plan_bytes": int(full_bytes),
            "patch_to_full_ratio": mean_patch / full_bytes,
            "mean_patch_bytes_per_shard": [
                float(x) for x in np.mean(np.asarray(per_shard), axis=0)
            ],
            "recompiles": int(recompiles),
        },
    }
    emit_json(json_path, payload)
    return payload


if __name__ == "__main__":
    run()
