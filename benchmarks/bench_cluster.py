"""Replica cluster tier: read scaling, bounded-tail recovery, SLO adaptation
(ISSUE 10).

Acceptance, asserted here and recorded in ``BENCH_cluster.json``:

* **scaling** — one writer streams WAL'd updates while 1 / 2 / 4 read
  replicas each tail the log and serve an equal share of a fixed
  cache-busting read load (full-graph reads with explicit value vectors,
  the uncached path).  The cluster model is honest about the single
  process: every replica pays the full apply cost (replication is not
  sharding) and a tick's latency is the *slowest* replica's
  ``catch_up + reads`` time — exactly the parallel wall-clock, serialized
  for measurement.  QPS must scale **>= 1.7x at 2** and **>= 3x at 4**
  replicas, every replica's final state is **bitwise identical** to a
  fresh WAL replay, and the serving read path compiles **zero** new
  executables after warm-up.
* **recovery** — rebuilding a session by checkpoint-load + bounded tail
  replay (``restore_from_wal(checkpoint=...)``) must beat full-log replay
  while producing bitwise-identical results.
* **adaptive** — under a deadline-dominated trickle (single interactive
  tickets, bucket never fills), a static service parks every ticket for
  the declared ``max_delay_ms`` while the :class:`SLOController` tightens
  the effective delay within declared bounds: adaptive p99 must come in
  below static p99, and the effective delay must stay inside
  ``[min_delay_ms, declared]``.

Run: ``PYTHONPATH=src python -m benchmarks.bench_cluster [--smoke]``.
"""

from __future__ import annotations

import gc
import os
import tempfile
import time

import numpy as np

from benchmarks.common import emit, emit_json, mixed_update_batch

MIN_SPEEDUP_2 = 1.7
MIN_SPEEDUP_4 = 3.0


def _final_bytes(session) -> list:
    return [np.asarray(r).tobytes() for r in session.run()]


def run(n: int = 4_000, deg: float = 4.0, ticks: int = 6,
        reads_per_tick: int = 128, recovery_batches: int = 30,
        adaptive_tickets: int = 80, smoke: bool = False,
        json_path: str = "BENCH_cluster.json") -> dict:
    from repro.core import api
    from repro.core.api import QuerySpec, Session
    from repro.serve import ReplicaSet, SLOController
    from repro.serve.wal import SegmentedWriteAheadLog

    if smoke:
        n, ticks, reads_per_tick = 2_500, 7, 128
        recovery_batches, adaptive_tickets = 12, 40

    rng = np.random.default_rng(0)
    from repro.graphs.generators import erdos_renyi
    g = erdos_renyi(n, deg, directed=False, seed=0)
    g = g.with_attr("val", rng.integers(0, 100, g.n).astype(np.float64))
    specs = [QuerySpec(("khop", 1), "sum"), QuerySpec(("khop", 1), "min")]
    payload: dict = {"config": {
        "n": n, "deg": deg, "ticks": ticks,
        "reads_per_tick": reads_per_tick,
        "recovery_batches": recovery_batches,
        "adaptive_tickets": adaptive_tickets, "smoke": bool(smoke)}}

    # ------------------------------------------------------------------ #
    #  1. read QPS scaling: 1 / 2 / 4 replicas over one WAL'd stream
    # ------------------------------------------------------------------ #
    # identical update + read trace for every cluster size: edge-neutral
    # churn (capacity plans never grow -> no legitimate retraces) and
    # explicit value vectors (every read recomputes; nothing hides in the
    # result cache)
    batch_seed = int(rng.integers(2 ** 31))
    read_values = [rng.random(g.n) for _ in range(reads_per_tick)]

    def serving_compiles() -> int:
        import repro.core.engine_jax as ej
        return (api.run_many_cache_size()
                + ej.query_dbindex_multi._cache_size()
                + ej.query_iindex_multi._cache_size())

    qps: dict = {}
    bit_identical = True
    tmp = tempfile.mkdtemp(prefix="bench_cluster_")
    sets = {}
    for n_replicas in (1, 2, 4):
        rs = ReplicaSet(g, specs, os.path.join(tmp, f"c{n_replicas}"),
                        n_replicas=n_replicas, checkpoint_every=0,
                        wal_digests=False, use_pallas=False)
        reps = list(rs.replicas.values())
        shares = [read_values[i::n_replicas] for i in range(n_replicas)]
        rc = np.random.default_rng(batch_seed)
        # warm-up tick: trace every executor before the timed stream
        rs.update(mixed_update_batch(rs.writer.session.graph, rc, 4, 4))
        rs.wal.sync()
        for rep, share in zip(reps, shares):
            rep.catch_up()
            for v in share:
                rep.query(0, values=v)
        sets[n_replicas] = (rs, reps, shares, rc, [])

    compiles0 = serving_compiles()
    # all cluster sizes advance in lockstep, one tick each, so every
    # per-tick speedup ratio compares walls measured seconds apart —
    # background load drifts hit each config equally instead of whichever
    # config happened to run last
    for _ in range(ticks):
        for rs, reps, shares, rc, walls in sets.values():
            rs.update(mixed_update_batch(rs.writer.session.graph, rc, 4, 4))
            rs.wal.sync()
            gc.collect()
            gc.disable()  # a collection pause inside one replica's slice
            try:          # would poison the max-over-replicas wall
                applies, serves = [], []
                for rep, share in zip(reps, shares):
                    t0 = time.perf_counter()
                    rep.catch_up()
                    applies.append(time.perf_counter() - t0)
                    # reads are pure (explicit values, no state change):
                    # best of two passes keeps scheduler jitter out of
                    # the wall-clock
                    t_reads = float("inf")
                    for _ in range(2):
                        t0 = time.perf_counter()
                        for v in share:
                            rep.query(0, values=v)
                        t_reads = min(t_reads, time.perf_counter() - t0)
                    serves.append(t_reads)
                # replicas apply identical batches: the *typical* apply
                # plus the straggler's reads is the tick's wall — one
                # replica's one-off apply stall is noise, not workload
                walls.append(float(np.median(applies)) + max(serves))
            finally:
                gc.enable()
    recompiles = serving_compiles() - compiles0

    for n_replicas, (rs, reps, shares, rc, walls) in sets.items():
        # median tick: one stalled tick must not define the config's QPS
        qps[str(n_replicas)] = reads_per_tick / float(np.median(walls))
        # bit-identity: every replica's final state equals a fresh replay
        oracle = _final_bytes(Session.restore_from_wal(
            g, specs, rs.wal_dir, use_pallas=False))
        for rep in reps:
            bit_identical &= _final_bytes(rep.session) == oracle
        rs.close()
        emit(f"cluster/qps/{n_replicas}rep",
             1e6 / qps[str(n_replicas)], f"{qps[str(n_replicas)]:.1f} qps")

    # speedups from per-tick ratios (same-instant pairs), median over ticks
    w1, w2, w4 = (sets[k][4] for k in (1, 2, 4))
    speedup_2 = float(np.median([a / b for a, b in zip(w1, w2)]))
    speedup_4 = float(np.median([a / b for a, b in zip(w1, w4)]))
    assert bit_identical, "replica state diverged from the WAL replay"
    assert recompiles == 0, \
        f"{recompiles} serving-path recompiles across the streams"
    assert speedup_2 >= MIN_SPEEDUP_2, \
        f"2-replica speedup {speedup_2:.2f}x < {MIN_SPEEDUP_2}x"
    assert speedup_4 >= MIN_SPEEDUP_4, \
        f"4-replica speedup {speedup_4:.2f}x < {MIN_SPEEDUP_4}x"
    emit("cluster/speedup/2rep", speedup_2, f"{speedup_2:.2f}x")
    emit("cluster/speedup/4rep", speedup_4, f"{speedup_4:.2f}x")
    payload["scaling"] = {
        "qps": {k: round(v, 1) for k, v in qps.items()},
        "speedup_2": round(speedup_2, 3), "speedup_4": round(speedup_4, 3),
        "bit_identical": bool(bit_identical), "recompiles": int(recompiles)}

    # ------------------------------------------------------------------ #
    #  2. recovery: checkpoint + bounded tail vs full WAL replay
    # ------------------------------------------------------------------ #
    wal_dir = os.path.join(tmp, "recovery", "wal")
    ckpt_dir = os.path.join(tmp, "recovery", "ck")
    leader = Session(g, specs, use_pallas=False)
    ckpt_at = recovery_batches - max(recovery_batches // 10, 2)
    r = np.random.default_rng(1)
    with SegmentedWriteAheadLog(wal_dir, rotate_records=8) as wal:
        for i in range(recovery_batches):
            b = mixed_update_batch(leader.graph, r, 6, 6)
            wal.append(b)
            leader.update(b)
            if leader.version == ckpt_at:
                leader.save_checkpoint(ckpt_dir)
        wal.sync()
    oracle = _final_bytes(leader)

    t0 = time.perf_counter()
    full = Session.restore_from_wal(g, specs, wal_dir, use_pallas=False)
    full_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    fast = Session.restore_from_wal(g, specs, wal_dir, checkpoint=ckpt_dir,
                                    use_pallas=False)
    fast_s = time.perf_counter() - t0
    rec_identical = (_final_bytes(full) == oracle
                     and _final_bytes(fast) == oracle)
    rec_speedup = full_s / fast_s
    assert rec_identical, "recovery paths disagree with the leader"
    assert rec_speedup > 1.0, \
        f"checkpoint+tail ({fast_s:.3f}s) no faster than replay ({full_s:.3f}s)"
    emit("cluster/recovery/full_replay", full_s * 1e6,
         f"{recovery_batches} batches")
    emit("cluster/recovery/checkpoint_tail", fast_s * 1e6,
         f"{recovery_batches - ckpt_at} tail batches, {rec_speedup:.2f}x")
    payload["recovery"] = {
        "batches": recovery_batches, "checkpoint_version": ckpt_at,
        "tail_records": recovery_batches - ckpt_at,
        "full_replay_s": round(full_s, 4),
        "checkpoint_tail_s": round(fast_s, 4),
        "speedup": round(rec_speedup, 3),
        "bit_identical": bool(rec_identical)}

    # ------------------------------------------------------------------ #
    #  3. adaptive vs static p99 under a deadline-dominated trickle
    # ------------------------------------------------------------------ #
    from repro.obs import MetricsRegistry
    from repro.serve import AsyncWindowService

    # one static and one adaptive service over identical sessions, fed
    # the same trickle in lockstep: every static/adaptive sample pair is
    # measured under the same instantaneous host conditions.  Explicit-
    # values reads make the execution cost real (a few ms, never
    # result-cached): the declared 5 ms budget is then unattainable, so
    # the controller converges monotonically toward its floor instead of
    # oscillating around the target.
    reg_s, reg_a = MetricsRegistry(), MetricsRegistry()
    svc_s = AsyncWindowService(Session(g, specs, use_pallas=False),
                               bucket=8, obs=reg_s).start()
    svc_a = AsyncWindowService(Session(g, specs, use_pallas=False),
                               bucket=8, obs=reg_a).start()
    ctl = SLOController(svc_a, min_samples=4, hysteresis=2,
                        min_delay_ms=0.25, obs=reg_a)
    lats_s, lats_a = [], []
    try:
        def one(svc, i):
            t = svc.submit(0, values=read_values[i % len(read_values)],
                           request_class="interactive")
            t.get(timeout=30)
            return t

        # phase 1: let the controller converge (the static service runs
        # the same traffic so both measure equally warmed executors)
        for i in range(adaptive_tickets):
            one(svc_s, i)
            one(svc_a, i)
            if (i + 1) % 4 == 0:
                ctl.step()
        # phase 2: steady state is what the p99 scores
        gc.collect()
        gc.disable()
        try:
            for i in range(adaptive_tickets):
                lats_s.append(one(svc_s, i).latency_s)
                lats_a.append(one(svc_a, i).latency_s)
        finally:
            gc.enable()
    finally:
        svc_s.stop()
        svc_a.stop()
    att_static = svc_s.slo.report()["interactive"]["attainment"]
    att_adaptive = svc_a.slo.report()["interactive"]["attainment"]
    eff_ms = ctl.effective_delay_ms("interactive")
    declared = 5.0  # DEFAULT_REQUEST_CLASSES["interactive"].max_delay_ms
    p99_static = float(np.percentile(np.asarray(lats_s) * 1e3, 99))
    p99_adaptive = float(np.percentile(np.asarray(lats_a) * 1e3, 99))
    assert 0.25 <= eff_ms <= declared, \
        f"effective delay {eff_ms:.3f}ms escaped its declared bounds"
    assert p99_adaptive < p99_static, \
        f"adaptive p99 {p99_adaptive:.2f}ms !< static {p99_static:.2f}ms"
    emit("cluster/p99/static", p99_static * 1e3, f"{p99_static:.2f} ms")
    emit("cluster/p99/adaptive", p99_adaptive * 1e3,
         f"{p99_adaptive:.2f} ms, eff delay {eff_ms:.2f} ms")
    payload["adaptive"] = {
        "declared_delay_ms": declared,
        "p99_static_ms": round(float(p99_static), 3),
        "p99_adaptive_ms": round(float(p99_adaptive), 3),
        "p99_improved": bool(p99_adaptive < p99_static),
        "attainment_static": (None if att_static is None
                              else round(float(att_static), 3)),
        "attainment_adaptive": (None if att_adaptive is None
                                else round(float(att_adaptive), 3)),
        "effective_delay_ms": round(float(eff_ms), 3)}

    emit_json(json_path, payload)
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default="BENCH_cluster.json")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)
