"""Window-algebra benchmark: algebraic fast path vs materialize-then-query.

Acceptance target (ISSUE 5): the algebraic fast path must beat the generic
materialize-then-query lowering by >= 1.5x, bit-identically.  Scenario: a
Session already serves the two k-hop leaves (their materializations exist
and their executors are warm); a *composite* union query arrives.

* **Idempotent union** (min/max — the headline): the fast path evaluates
  ``combine(result(A), result(B))`` over the existing leaf plans — zero
  new materialization; materialize-then-query pays union window
  evaluation + DBIndex build + device plan + compile + query.
* **Inclusion–exclusion** (sum/avg): the fast path materializes only the
  (far smaller) intersection term; reported as total cost to serve the
  first result plus an amortized 50-query serving window.
* **Derived aggregates** (var/mean_sq/l2): registered aggregates ride
  extra fused channels of ONE multi-channel launch vs per-aggregate
  queries.

Results land in ``BENCH_window_algebra.json``.

Run:  PYTHONPATH=src python -m benchmarks.bench_window_algebra [--smoke]
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import best_of, emit, emit_json
from repro.core import engine_jax as ej
from repro.core.api import _combine_program, plan_window_program
from repro.core.dbindex import build_dbindex
from repro.core.windows import KHop, Union, canonicalize
from repro.graphs.generators import erdos_renyi

IDEM_AGGS = ("min", "max")
SUM_AGGS = ("sum", "avg")
DERIVED = ("var", "mean_sq", "l2")
SERVE_QUERIES = 50


def run(n: int = 20_000, deg: float = 6.0, k: int = 2,
        json_path: str = "BENCH_window_algebra.json") -> dict:
    import jax

    rng = np.random.default_rng(0)
    g = erdos_renyi(n, deg, directed=True, seed=0)
    g = g.with_attr("val", rng.integers(0, 100, g.n).astype(np.float64))
    vals = g.attrs["val"]
    A = canonicalize(KHop(k, "in"))
    B = canonicalize(KHop(k, "out"))
    union = canonicalize(Union(KHop(k, "in"), KHop(k, "out")))

    def q(plan, aggs):
        return jax.block_until_ready(
            ej.query_dbindex_multi(plan, vals, tuple(aggs), use_pallas=False))

    # setup (untimed): the leaves already serve their own queries — their
    # materializations exist and their fused executors are warm
    leaf_plans = {w: ej.plan_from_dbindex(build_dbindex(g, w)) for w in (A, B)}
    for w in (A, B):
        q(leaf_plans[w], IDEM_AGGS)
        q(leaf_plans[w], ("count", "sum"))

    # ---- materialize-then-query: union windows -> index -> plan -> query #
    t0 = time.perf_counter()
    union_plan = ej.plan_from_dbindex(build_dbindex(g, union))
    mat_idem = dict(zip(IDEM_AGGS, q(union_plan, IDEM_AGGS)))
    mat_first_s = time.perf_counter() - t0
    us_mat_query = best_of(lambda: q(union_plan, IDEM_AGGS), repeats=10,
                           warmup=2)

    # ---- idempotent-union fast path: combine over existing leaf plans --- #
    prog_idem = plan_window_program(union, IDEM_AGGS)
    assert prog_idem is not None and len(prog_idem.terms) == 2

    def fast_idem():
        outs = [dict(zip(prog_idem.term_aggs, q(leaf_plans[t],
                                                prog_idem.term_aggs)))
                for t in prog_idem.terms]
        return _combine_program(prog_idem, IDEM_AGGS, outs)

    t0 = time.perf_counter()
    fast_res = fast_idem()
    fast_first_s = time.perf_counter() - t0
    us_fast_query = best_of(fast_idem, repeats=10, warmup=2)
    for a in IDEM_AGGS:
        assert np.array_equal(np.asarray(fast_res[a], np.float32),
                              np.asarray(mat_idem[a], np.float32)), a

    speedup = mat_first_s / max(fast_first_s, 1e-9)
    emit(f"window_algebra/idem_fast_first/n{n}", fast_first_s * 1e6, f"k={k}")
    emit(f"window_algebra/idem_materialize_then_query/n{n}",
         mat_first_s * 1e6, f"k={k}")
    emit(f"window_algebra/idem_speedup/n{n}", speedup, "x_fast_vs_materialized")
    assert speedup >= 1.5, (
        f"idempotent-union fast path only {speedup:.2f}x vs "
        f"materialize-then-query (need >= 1.5x)")

    # ---- inclusion–exclusion: only the intersection is materialized ----- #
    prog_sum = plan_window_program(union, SUM_AGGS)
    inter = prog_sum.terms[2]

    t0 = time.perf_counter()
    inter_plan = ej.plan_from_dbindex(build_dbindex(g, inter))
    plans = {**leaf_plans, inter: inter_plan}

    def fast_sum():
        outs = [dict(zip(prog_sum.term_aggs, q(plans[t], prog_sum.term_aggs)))
                for t in prog_sum.terms]
        return _combine_program(prog_sum, SUM_AGGS, outs)

    fast_sum_res = fast_sum()
    fast_sum_first_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    mat_sum = dict(zip(SUM_AGGS, q(union_plan, SUM_AGGS)))
    mat_sum_query_s = time.perf_counter() - t0
    for a in SUM_AGGS:
        assert np.array_equal(np.asarray(fast_sum_res[a], np.float32),
                              np.asarray(mat_sum[a], np.float32)), a
    us_fast_sum = best_of(fast_sum, repeats=10, warmup=2)
    us_mat_sum = best_of(lambda: q(union_plan, SUM_AGGS), repeats=10, warmup=2)
    # total cost to materialize + serve a 50-query window (the union build
    # time from the idempotent scenario is the mat side's materialization)
    fast_total = fast_sum_first_s + SERVE_QUERIES * us_fast_sum / 1e6
    mat_total = mat_first_s + mat_sum_query_s + SERVE_QUERIES * us_mat_sum / 1e6
    ie_speedup = mat_total / max(fast_total, 1e-9)
    emit(f"window_algebra/inclexcl_first/n{n}", fast_sum_first_s * 1e6, "")
    emit(f"window_algebra/inclexcl_steady/n{n}", us_fast_sum, "")
    emit(f"window_algebra/inclexcl_serve{SERVE_QUERIES}_speedup/n{n}",
         ie_speedup, "x_fast_vs_materialized")

    # ---- derived aggregates: extra fused channels vs per-agg loop ------- #
    fused_aggs = ("sum", "count") + DERIVED
    leaf_plan = leaf_plans[B]

    def fused():
        return q(leaf_plan, fused_aggs)

    def per_agg():
        return [q(leaf_plan, (a,))[0] for a in fused_aggs]

    f_out, p_out = fused(), per_agg()
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(f_out, p_out))
    us_fused = best_of(fused, repeats=10, warmup=2)
    us_per_agg = best_of(per_agg, repeats=10, warmup=2)
    derived_speedup = us_per_agg / max(us_fused, 1e-9)
    emit(f"window_algebra/derived_fused_{len(fused_aggs)}agg/n{n}", us_fused, "")
    emit(f"window_algebra/derived_per_agg/n{n}", us_per_agg, "")
    emit(f"window_algebra/derived_fusion_speedup/n{n}", derived_speedup,
         "x_fused_vs_per_agg")

    payload = {
        "config": {"n": n, "avg_degree": deg, "k": k, "union": union.name(),
                   "serve_queries": SERVE_QUERIES},
        "idempotent_union": {
            "fast_first_s": fast_first_s,
            "materialize_then_query_s": mat_first_s,
            "speedup": speedup,
            "steady_fast_us": us_fast_query,
            "steady_materialized_us": us_mat_query,
            "bit_identical": True,
        },
        "inclusion_exclusion": {
            "fast_first_s": fast_sum_first_s,
            "steady_fast_us": us_fast_sum,
            "steady_materialized_us": us_mat_sum,
            f"serve{SERVE_QUERIES}_speedup": ie_speedup,
            "bit_identical": True,
        },
        "derived_aggregates": {
            "fused_us": us_fused,
            "per_agg_us": us_per_agg,
            "fusion_speedup": derived_speedup,
        },
    }
    emit_json(json_path, payload)
    return payload


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI-sized run (same assertions)")
    args = ap.parse_args(argv)
    run(n=4_000 if args.smoke else 20_000)


if __name__ == "__main__":
    main()
