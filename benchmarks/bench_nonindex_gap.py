"""Paper §6.1 headline: index-based vs non-indexed query gap.

The paper reports up to 4 orders of magnitude at hundreds-of-millions
scale; we measure the gap at container scale and report the ratio (the gap
grows with k and graph size — both shown)."""

from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core.dbindex import build_dbindex
from repro.core.nonindex import query_pervertex
from repro.core.windows import KHopWindow
from repro.graphs.generators import barabasi_albert, with_random_attrs


def run(n: int = 8_000):
    g = with_random_attrs(barabasi_albert(n, 4, seed=9), seed=10)
    vals = g.attrs["val"]
    for k in (1, 2, 3):
        w = KHopWindow(k)
        idx = build_dbindex(g, w, method="emc")
        q_idx = timeit(lambda: idx.query(vals, "sum"))
        # paper-style non-index: per-vertex BFS; extrapolate from 500 vertices
        sample = 200
        q_non_sample = timeit(lambda: query_pervertex(g, w, vals, "sum",
                                                      limit=sample), repeats=1)
        q_non = q_non_sample * (n / sample)
        emit(f"nonindex_gap/k{k}", q_idx,
             f"nonindex_us={q_non:.0f};speedup={q_non/q_idx:.0f}x")


if __name__ == "__main__":
    run()
