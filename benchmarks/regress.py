"""Bench regression gate: diff fresh BENCH_*.json against committed baselines.

Nine benchmark result files are committed at the repo root; CI re-runs
seven of them (smoke mode) and overwrites the workspace copies.  This gate
then checks, per file:

* **absolute invariants** — properties that must hold in ANY run at ANY
  scale and are noise-free by construction: ``bit_identical`` flags,
  ``recompiles == 0``, overhead under its own embedded budget, shed rate
  in range, incremental-vs-rebuild speedups >= 1.  A violated invariant
  is a real regression, never noise — these always fail hard.
* **noise-aware ratio checks** — only when the fresh run's ``config``
  block matches the baseline's (same scale ⇒ comparable numbers): each
  tracked ratio must stay above ``rel_frac × baseline`` (default 0.4×;
  ``--smoke`` loosens to 0.25× for shared-CI-runner noise).  A config
  mismatch (CI smoke vs committed full run) skips these rather than
  comparing apples to oranges.

Baselines come from ``git show HEAD:<file>`` so the gate works *after*
the bench steps overwrote the workspace copies; outside a git checkout it
falls back to the on-disk file (invariants still checked).

Usage::

    python -m benchmarks.regress            # strict ratios (0.4x)
    python -m benchmarks.regress --smoke    # CI: lenient ratios (0.25x)
    python -m benchmarks.regress --check-only  # baselines only, no ratios

Exit status 0 = all checks passed, 1 = any failure (CI gates on this).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional, Tuple

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: every committed baseline this gate knows about
BASELINES = (
    "BENCH_updates.json",
    "BENCH_multiquery.json",
    "BENCH_service.json",
    "BENCH_async_service.json",
    "BENCH_window_algebra.json",
    "BENCH_obs_overhead.json",
    "BENCH_sharded.json",
    "BENCH_audit.json",
    "BENCH_cluster.json",
)


def _get(d: Dict, path: str):
    """Dotted-path lookup; returns None when any hop is missing."""
    cur = d
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


# ---------------------------------------------------------------------- #
#  Check table
# ---------------------------------------------------------------------- #
# (file, path, kind, arg):
#   kind "true"    — value must be truthy                (invariant)
#   kind "eq0"     — value must equal 0                  (invariant)
#   kind "floor"   — value must be >= arg                (invariant)
#   kind "ceil"    — value must be <= arg                (invariant)
#   kind "budget"  — value must be < the file's own value at path `arg`
#   kind "ratio"   — fresh >= rel_frac * baseline        (noise-aware)
INVARIANTS: Tuple = (
    ("BENCH_updates.json", "dbindex.speedup_batched_vs_rebuild", "floor", 1.0),
    ("BENCH_updates.json", "iindex.speedup_batched_vs_rebuild", "floor", 1.0),
    ("BENCH_multiquery.json", "fused.bit_identical", "true", None),
    ("BENCH_multiquery.json", "session_stream.fused_plan_recompiles",
     "eq0", None),
    ("BENCH_multiquery.json", "fused.speedup_fused_vs_sequential",
     "floor", 1.0),
    ("BENCH_service.json", "bit_identical", "true", None),
    ("BENCH_service.json", "service.recompiles", "eq0", None),
    ("BENCH_service.json", "speedup_qps", "floor", 1.0),
    ("BENCH_async_service.json", "recovery.bit_identical", "true", None),
    ("BENCH_async_service.json", "low_load.deadline_beats_fillonly",
     "true", None),
    ("BENCH_async_service.json", "shedding.rate", "floor", 0.0),
    ("BENCH_async_service.json", "shedding.rate", "ceil", 1.0),
    ("BENCH_window_algebra.json", "idempotent_union.bit_identical",
     "true", None),
    ("BENCH_window_algebra.json", "inclusion_exclusion.bit_identical",
     "true", None),
    ("BENCH_window_algebra.json", "idempotent_union.speedup", "floor", 1.0),
    ("BENCH_window_algebra.json", "derived_aggregates.fusion_speedup",
     "floor", 1.0),
    ("BENCH_obs_overhead.json", "overhead_fraction", "budget",
     "max_overhead_fraction"),
    ("BENCH_sharded.json", "query.bit_identical", "true", None),
    ("BENCH_sharded.json", "stream.recompiles", "eq0", None),
    ("BENCH_sharded.json", "stream.patch_to_full_ratio", "ceil", 1.0),
    ("BENCH_audit.json", "audit.overhead_fraction", "budget",
     "audit.max_overhead_fraction"),
    ("BENCH_audit.json", "audit.recompiles", "eq0", None),
    ("BENCH_audit.json", "audit.false_positives", "eq0", None),
    ("BENCH_audit.json", "detection.wal_scrub.detected", "true", None),
    ("BENCH_audit.json", "detection.oracle.detected", "true", None),
    ("BENCH_audit.json", "replication.digests_matched", "true", None),
    ("BENCH_cluster.json", "scaling.bit_identical", "true", None),
    ("BENCH_cluster.json", "scaling.recompiles", "eq0", None),
    ("BENCH_cluster.json", "scaling.speedup_2", "floor", 1.7),
    ("BENCH_cluster.json", "scaling.speedup_4", "floor", 3.0),
    ("BENCH_cluster.json", "recovery.bit_identical", "true", None),
    ("BENCH_cluster.json", "recovery.speedup", "floor", 1.0),
    ("BENCH_cluster.json", "adaptive.p99_improved", "true", None),
)

#: ratios worth tracking across runs of the SAME config (higher = better)
RATIOS: Tuple = (
    ("BENCH_updates.json", "dbindex.speedup_batched_vs_rebuild"),
    ("BENCH_updates.json", "iindex.speedup_batched_vs_rebuild"),
    ("BENCH_multiquery.json", "fused.speedup_fused_vs_sequential"),
    ("BENCH_service.json", "speedup_qps"),
    ("BENCH_async_service.json", "concurrent.qps"),
    ("BENCH_window_algebra.json", "idempotent_union.speedup"),
    ("BENCH_window_algebra.json", "derived_aggregates.fusion_speedup"),
    ("BENCH_audit.json", "audit.qps_audited"),
    ("BENCH_cluster.json", "scaling.qps.4"),
    ("BENCH_cluster.json", "recovery.speedup"),
)


# ---------------------------------------------------------------------- #
def load_baseline(name: str, root: str = ROOT) -> Optional[Dict]:
    """The committed version of ``name`` (``git show HEAD:<name>``), or
    the on-disk file outside a git checkout, or None if neither exists."""
    try:
        blob = subprocess.run(
            ["git", "show", f"HEAD:{name}"], cwd=root,
            capture_output=True, timeout=30,
        )
        if blob.returncode == 0:
            return json.loads(blob.stdout.decode())
    except (OSError, subprocess.TimeoutExpired, json.JSONDecodeError):
        pass
    return load_fresh(name, root)


def load_fresh(name: str, root: str = ROOT) -> Optional[Dict]:
    path = os.path.join(root, name)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def check_invariants(name: str, data: Dict) -> List[Tuple[str, bool, str]]:
    """[(label, ok, detail)] for every invariant registered on ``name``."""
    rows = []
    for fname, path, kind, arg in INVARIANTS:
        if fname != name:
            continue
        v = _get(data, path)
        label = f"{name}:{path}"
        if v is None:
            rows.append((label, False, "key missing"))
            continue
        if kind == "true":
            rows.append((label, bool(v), f"= {v}"))
        elif kind == "eq0":
            rows.append((label, v == 0, f"= {v} (must be 0)"))
        elif kind == "floor":
            rows.append((label, v >= arg, f"= {v:.4g} (floor {arg})"))
        elif kind == "ceil":
            rows.append((label, v <= arg, f"= {v:.4g} (ceil {arg})"))
        elif kind == "budget":
            budget = _get(data, arg)
            ok = budget is not None and v < budget
            rows.append((label, ok, f"= {v:.4g} (budget {budget})"))
    return rows


def check_ratios(name: str, fresh: Dict, base: Dict,
                 rel_frac: float) -> List[Tuple[str, bool, str]]:
    """Noise-aware ratio checks; skipped (empty) unless configs match."""
    if fresh.get("config") != base.get("config"):
        return [(f"{name}:ratios", True,
                 "config differs from baseline — ratio checks skipped")]
    rows = []
    for fname, path in RATIOS:
        if fname != name:
            continue
        fv, bv = _get(fresh, path), _get(base, path)
        label = f"{name}:{path}"
        if fv is None or bv is None:
            rows.append((label, False, "key missing"))
            continue
        floor = rel_frac * bv
        rows.append((label, fv >= floor,
                     f"= {fv:.4g} vs baseline {bv:.4g} "
                     f"(floor {rel_frac:.2f}x = {floor:.4g})"))
    return rows


def run_gate(root: str = ROOT, rel_frac: float = 0.4,
             check_only: bool = False,
             require_all: bool = False) -> Tuple[List, List]:
    """Run every check.  Returns (rows, failures); each row is
    ``(label, ok, detail)``.  Files absent on disk are skipped unless
    ``require_all`` (CI has all nine: seven fresh + two committed)."""
    rows: List[Tuple[str, bool, str]] = []
    for name in BASELINES:
        fresh = load_fresh(name, root)
        base = load_baseline(name, root)
        if base is None and fresh is None:
            rows.append((f"{name}", not require_all, "missing"))
            continue
        if fresh is None:
            # not re-run this round: the committed baseline self-checks
            rows.extend(check_invariants(name, base))
            continue
        rows.extend(check_invariants(name, fresh))
        if not check_only and base is not None and base is not fresh:
            rows.extend(check_ratios(name, fresh, base, rel_frac))
    failures = [r for r in rows if not r[1]]
    return rows, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="lenient ratio floor (0.25x) for shared CI runners")
    ap.add_argument("--check-only", action="store_true",
                    help="validate invariants only; skip baseline ratios")
    ap.add_argument("--rel-frac", type=float, default=None,
                    help="override the ratio floor fraction")
    ap.add_argument("--root", default=ROOT,
                    help="directory holding the BENCH_*.json files")
    ap.add_argument("--require-all", action="store_true",
                    help="fail if any of the nine files is missing")
    args = ap.parse_args(argv)
    rel_frac = (args.rel_frac if args.rel_frac is not None
                else (0.25 if args.smoke else 0.4))
    rows, failures = run_gate(root=args.root, rel_frac=rel_frac,
                              check_only=args.check_only,
                              require_all=args.require_all)
    width = max((len(r[0]) for r in rows), default=20)
    for label, ok, detail in rows:
        print(f"{'PASS' if ok else 'FAIL'}  {label:<{width}}  {detail}")
    print(f"\n{len(rows) - len(failures)}/{len(rows)} checks passed"
          f" (ratio floor {rel_frac:.2f}x)")
    if failures:
        print("REGRESSION GATE FAILED:")
        for label, _, detail in failures:
            print(f"  {label}: {detail}")
        return 1
    print("regression gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
