"""Observability overhead: instrumented vs NullRegistry serving QPS.

Acceptance (ISSUE 7), asserted here and recorded in
``BENCH_obs_overhead.json``: enabling the full obs stack (metrics
registry + tracer + SLO accounting) costs **< 5% QPS** on the serving
hot path.  Two identical `WindowService` stacks are built — one bound to
the `NullRegistry`/`NullTracer` (obs disabled: every instrument call is
a no-op on a shared singleton), one bound to live instruments — and the
same request/update trace is replayed through both in **interleaved
rounds**, scoring each side by its best round (noise only ever adds
time, and interleaving exposes both sides to the same machine weather).

The instrumented side's full metrics snapshot is attached to the JSON
payload, so the bench doubles as a regression fixture for the metric-name
schema.

Run: ``PYTHONPATH=src python -m benchmarks.bench_obs_overhead [--smoke]``.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, emit_json, mixed_update_batch

MAX_OVERHEAD = 0.05


def run(n: int = 8_000, deg: float = 5.0, rounds: int = 7, ticks: int = 4,
        point_q: int = 64, bucket: int = 8, smoke: bool = False,
        json_path: str = "BENCH_obs_overhead.json") -> dict:
    from repro import obs
    from repro.core.api import QuerySpec, Session
    from repro.graphs.generators import erdos_renyi
    from repro.serve import WindowService

    if smoke:
        n, rounds, ticks, point_q = 2_000, 3, 2, 24

    rng = np.random.default_rng(0)
    g = erdos_renyi(n, deg, directed=False, seed=0)
    g = g.with_attr("val", rng.integers(0, 100, g.n).astype(np.float64))
    specs = [QuerySpec(("khop", 1), "sum"), QuerySpec(("khop", 1), "min")]

    # identical request/update trace for both sides
    trace = []
    for t in range(ticks):
        trace.append([(int(rng.integers(len(specs))), int(rng.integers(n)))
                      for _ in range(point_q)])
    batch_seed = int(rng.integers(2**31))

    def build(enabled):
        if enabled:
            obs.enable()
        else:
            obs.disable()
        sess = Session(g, specs, device=True, use_pallas=False,
                       plan_headroom=1.0)
        return WindowService(sess, bucket=bucket)

    def play(svc):
        """One full round: ticks x (update + point storm + flush)."""
        r = np.random.default_rng(batch_seed)
        n_served = 0
        for t in range(ticks):
            svc.update(mixed_update_batch(svc.session.graph, r, 6, 3))
            tickets = [svc.submit(si, vertex=v) for si, v in trace[t]]
            svc.flush()
            n_served += sum(tk.error is None for tk in tickets)
        assert n_served == ticks * point_q
        return n_served

    # builds capture the global registry at construction: the Null side
    # must be built while obs is disabled, the live side while enabled
    svc_null = build(enabled=False)
    svc_obs = build(enabled=True)
    live_registry = obs.get_registry()
    for svc in (svc_null, svc_obs):  # warm every executor shape
        play(svc)

    n_req = ticks * point_q
    best = {"null": float("inf"), "obs": float("inf")}
    for _ in range(rounds):  # interleaved A/B: same weather for both
        for key, svc in (("null", svc_null), ("obs", svc_obs)):
            t0 = time.perf_counter()
            play(svc)
            best[key] = min(best[key], time.perf_counter() - t0)

    qps_null = n_req / best["null"]
    qps_obs = n_req / best["obs"]
    overhead = best["obs"] / best["null"] - 1.0
    emit(f"obs/null_qps/n{n}", 1e6 / qps_null, f"{qps_null:.0f}qps")
    emit(f"obs/instrumented_qps/n{n}", 1e6 / qps_obs, f"{qps_obs:.0f}qps")
    emit(f"obs/overhead/n{n}", best["obs"] * 1e6 - best["null"] * 1e6,
         f"{overhead * 100:.2f}pct")
    assert overhead < MAX_OVERHEAD, (
        f"obs overhead {overhead * 100:.2f}% exceeds "
        f"{MAX_OVERHEAD * 100:.0f}% "
        f"({qps_obs:.0f} vs {qps_null:.0f} qps)")

    snapshot = live_registry.snapshot()
    obs.disable()
    payload = {
        "config": {"n": n, "avg_degree": deg, "rounds": rounds,
                   "ticks_per_round": ticks, "point_queries_per_tick": point_q,
                   "bucket": bucket, "estimator": "best-of-rounds, interleaved"},
        "null_qps": qps_null,
        "instrumented_qps": qps_obs,
        "overhead_fraction": overhead,
        "max_overhead_fraction": MAX_OVERHEAD,
        "obs_snapshot": snapshot,
    }
    emit_json(json_path, payload)
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep for CI (n=2k, 3 rounds)")
    args = ap.parse_args()
    run(smoke=args.smoke)
