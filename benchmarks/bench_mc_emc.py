"""Paper Fig. 7 + Fig. 8: MC vs EMC index construction time / size / query.

Scaled to this container (the paper's Amazon/Stanford-web graphs at 1/8
scale, same degree regime).  Also reports our beyond-paper `mc`
(message-passing signatures) against the paper-faithful `mc_paper`.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.dbindex import build_dbindex
from repro.core.windows import KHopWindow
from repro.graphs.generators import barabasi_albert, with_random_attrs


def run(n: int = 40_000, hops=(1, 2, 3, 4)):
    g = with_random_attrs(barabasi_albert(n, 4, seed=1), seed=2)
    gsize = g.src.nbytes + g.dst.nbytes
    for k in hops:
        w = KHopWindow(k)
        for method in ("mc_paper", "emc", "mc"):
            idx = build_dbindex(g, w, method=method)
            st = idx.stats
            emit(
                f"fig7_index_time/{method}/k{k}",
                st["t_total_s"] * 1e6,
                f"hash_s={st['t_hash_s']:.2f};blocks_s={st['t_blocks_s']:.2f};"
                f"dense={st['num_dense_blocks']}",
            )
            emit(
                f"fig7_index_size/{method}/k{k}",
                idx.size_bytes(),
                f"ratio_to_graph={idx.size_bytes()/gsize:.2f}",
            )
            us = timeit(lambda: idx.query(g.attrs["val"], "sum"))
            emit(f"fig8_query/{method}/k{k}", us,
                 f"members={st['num_members']};links={st['num_links']}")


if __name__ == "__main__":
    run()
