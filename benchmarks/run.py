"""Benchmark driver — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--fast]``
prints ``name,us_per_call,derived`` CSV rows (also saved to
benchmarks/results.csv).
"""

from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller sweeps")
    ap.add_argument("--only", default=None, help="comma list of module names")
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_async_service,
        bench_audit,
        bench_cluster,
        bench_dbindex_eagr,
        bench_iindex,
        bench_kernels,
        bench_mc_emc,
        bench_multiquery,
        bench_nonindex_gap,
        bench_obs_overhead,
        bench_scalability,
        bench_service,
        bench_updates,
        bench_window_algebra,
    )
    from benchmarks.common import flush_csv

    t0 = time.time()
    print("name,us_per_call,derived")
    mods = {
        "mc_emc": lambda: bench_mc_emc.run(n=8_000 if args.fast else 20_000,
                                           hops=(1, 2) if args.fast else (1, 2, 3)),
        "dbindex_eagr": lambda: bench_dbindex_eagr.run(n=800 if args.fast else 2000),
        "scalability": bench_scalability.run if not args.fast else (lambda: None),
        "iindex": lambda: bench_iindex.run(fast=args.fast),
        "nonindex_gap": lambda: bench_nonindex_gap.run(n=5_000 if args.fast else 8_000),
        "kernels": bench_kernels.run,
        "updates": lambda: bench_updates.run(n=20_000 if args.fast else 100_000),
        "multiquery": lambda: bench_multiquery.run(n=8_000 if args.fast else 20_000),
        "service": lambda: bench_service.run(smoke=args.fast),
        "async_service": lambda: bench_async_service.run(smoke=args.fast),
        "window_algebra": lambda: bench_window_algebra.run(
            n=4_000 if args.fast else 20_000),
        "obs_overhead": lambda: bench_obs_overhead.run(smoke=args.fast),
        "audit": lambda: bench_audit.run(smoke=args.fast),
        "cluster": lambda: bench_cluster.run(smoke=args.fast),
    }
    # bench_sharded_stream is deliberately NOT in this table: it must force
    # the host-platform device count before jax initializes, so it runs
    # standalone (`python -m benchmarks.bench_sharded_stream`, see the
    # sharded CI job).
    only = set(args.only.split(",")) if args.only else None
    for name, fn in mods.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---")
        fn()
    flush_csv("benchmarks/results.csv")
    print(f"# total {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
