"""Paper Fig. 11-13: DBIndex scalability — |V| sweep and degree sweeps
(sparse and dense regimes), Erdős–Rényi per the paper's generator."""

from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core.dbindex import build_dbindex
from repro.core.windows import KHopWindow
from repro.graphs.generators import erdos_renyi, with_random_attrs


def run():
    # Fig 11: vary |V|, degree 10 (paper: 2M-10M; here 1/100 scale)
    for n in (20_000, 50_000, 100_000):
        g = with_random_attrs(erdos_renyi(n, 10.0, seed=n), seed=n + 1)
        idx = build_dbindex(g, KHopWindow(1), method="emc")
        emit(f"fig11_index_time/n{n}", idx.stats["t_total_s"] * 1e6, "k=1,deg=10")
        us = timeit(lambda: idx.query(g.attrs["val"], "sum"))
        emit(f"fig11_query/n{n}", us, "")
    # Fig 12: degree sweep on sparse graphs (2M -> 20k nodes)
    for deg in (5, 10, 20):
        g = with_random_attrs(erdos_renyi(20_000, float(deg), seed=deg), seed=deg + 1)
        for k in (1, 2):
            idx = build_dbindex(g, KHopWindow(k), method="emc")
            emit(f"fig12_index_time/deg{deg}/k{k}", idx.stats["t_total_s"] * 1e6, "")
            us = timeit(lambda: idx.query(g.attrs["val"], "sum"))
            emit(f"fig12_query/deg{deg}/k{k}", us, "")
    # Fig 13: dense graphs (200k -> 2k nodes, degree 80-200)
    for deg in (80, 140, 200):
        g = with_random_attrs(erdos_renyi(2_000, float(deg), seed=deg), seed=deg + 1)
        for k in (1, 2):
            idx = build_dbindex(g, KHopWindow(k), method="emc")
            emit(f"fig13_index_time/deg{deg}/k{k}", idx.stats["t_total_s"] * 1e6, "")
            us = timeit(lambda: idx.query(g.attrs["val"], "sum"))
            emit(f"fig13_query/deg{deg}/k{k}", us, "")


if __name__ == "__main__":
    run()
