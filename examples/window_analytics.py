"""Dynamic-graph window analytics on the Session API.

The paper's §4.3/§5.3 workflow — build once, stream edge updates, answer
queries continuously, reorganize periodically — behind the declarative
facade: a `Session` owns the graph, the DBIndex, and the fused device
plan, and keeps all three fresh under `UpdateBatch` streams via the
incremental maintenance path (batched index update + tile-group plan
patching + staleness policy).

Run:  PYTHONPATH=src python examples/window_analytics.py
"""

import numpy as np

from repro.core.api import QuerySpec, Session
from repro.core.query import brute_force
from repro.core.streaming import StalenessPolicy
from repro.core.updates import UpdateBatch
from repro.graphs.generators import erdos_renyi, with_random_attrs

rng = np.random.default_rng(0)
g = with_random_attrs(erdos_renyi(2_000, 6.0, seed=4), seed=5)

specs = [QuerySpec(("khop", 2), a) for a in ("sum", "count", "avg")]
sess = Session(
    g, specs, device=True, use_pallas=False, plan_headroom=0.5,
    # 2-hop phase-1 merges shed sharing quickly; let a few batches amortize
    policy=StalenessPolicy(max_link_ratio=4.0, max_garbage_ratio=0.5,
                           min_batches=3),
)
for grp in sess.compiled.groups:
    print(f"compiled: engine={grp.engine}, window={grp.window.name()}, "
          f"fused aggs={grp.aggs}")

for step in range(8):
    src = rng.integers(0, g.n, 6).astype(np.int32)
    dst = rng.integers(0, g.n, 6).astype(np.int32)
    ok = (src != dst) & ~sess.graph.contains_edges(src, dst)
    reports = sess.update(UpdateBatch.inserts(src[ok], dst[ok]))  # phase-1
    rep = reports["khop[2]/dbindex"]
    s, c, avg = sess.run()
    ref = brute_force(sess.graph, specs[0].window, sess.graph.attrs["val"], "sum")
    assert np.allclose(s, ref, rtol=1e-5, atol=1e-3)
    print(f"step {step}: +{rep['batch_size']} edges -> {rep['affected']} "
          f"windows touched, queries still exact"
          + (" [reorganized]" if rep["reorganized"] else ""))

# phase-2 telemetry: the staleness policy watches sharing loss AND garbage
print(f"staleness after stream: {sess.staleness}")

# Serving many concurrent callers?  Don't call run() once per request —
# front the Session with the serving layer (examples/window_service.py):
# point reads become affected-owner-cache hits, explicit-values requests
# coalesce into fixed-bucket padded launches, and reads are version-pinned
# snapshots that never block on (or observe half of) an update.
from repro.serve import WindowService  # noqa: E402

svc = WindowService(sess, bucket=8)
t = svc.submit(specs[0], vertex=7)  # point read: O(1) hit in steady state
svc.flush()
print(f"served sum(7)={t.result} at version {t.version}; "
      f"point hit rate so far: {svc.stats['point_hit_rate']:.2f}")
