"""Dynamic-graph window analytics on the WindowExpr algebra.

The paper fixes two window instantiations (k-hop, topological) — but its
index is window-agnostic, so the query front end is an *algebra*: leaves
`KHop(k, direction=...)` / `Topo()`, combinators `Union` / `Intersect` /
`Diff`, and an attribute mask `Filter`.  Expressions canonicalize
(commutative sort, dedup, containment rewrites: `Union(KHop(1), KHop(2))`
IS `KHop(2)`), lower onto the existing DBIndex/device-plan pipeline, and
— where the algebra allows — skip materialization entirely: idempotent
monoids evaluate a Union as `combine(result(A), result(B))`, sum monoids
ride inclusion–exclusion.

Aggregates are an *open registry* too: `register_aggregate` adds derived
aggregates (variance, L2, ...) as extra fused monoid channels with a pure
finalizer — every engine (host, device, sharded, serving) picks them up
without edits.

Migration from the PR-2 API: `QuerySpec(("khop", 2), ...)` still works —
`KHopWindow` / `TopologicalWindow` are the canonical leaves of the same
algebra, and `GraphWindowQuery` remains a one-query shim.

Run:  PYTHONPATH=src python examples/window_analytics.py
"""

import numpy as np

from repro.core.aggregates import AGGREGATES, register_aggregate
from repro.core.api import QuerySpec, Session
from repro.core.query import brute_force
from repro.core.streaming import StalenessPolicy
from repro.core.updates import UpdateBatch
from repro.core.windows import Filter, KHop, KHopWindow, Union, canonicalize
from repro.graphs.generators import erdos_renyi, with_random_attrs

rng = np.random.default_rng(0)
g = with_random_attrs(erdos_renyi(2_000, 6.0, directed=True, seed=4), seed=5)
g = g.with_attr("premium", (rng.random(g.n) < 0.3).astype(np.int64))

# a derived aggregate: population std-dev, three fused channels + finalizer
if "std" not in AGGREGATES:
    register_aggregate(
        "std", ("sum", "sum", "sum"), ("square", "value", "ones"),
        finalize=lambda xp, s2, s, c: xp.sqrt(
            xp.maximum(s2 / xp.maximum(c, 1e-30)
                       - (s / xp.maximum(c, 1e-30)) ** 2, 0.0)),
    )

# composite windows: the 2-hop *neighborhood* (out ∪ in) and its premium slice
nbhd = Union(KHop(2, "out"), KHop(2, "in"))
premium_nbhd = Filter(nbhd, "premium")
print(f"canonical: {canonicalize(nbhd).name()}")
print(f"contained: Union(KHop(1), KHop(2)) -> "
      f"{canonicalize(Union(KHop(1), KHop(2))).name()}")  # reuse the larger

specs = [
    QuerySpec(nbhd, "sum"),        # algebraic: sum(A∪B) = ΣA + ΣB − Σ(A∩B)
    QuerySpec(nbhd, "min"),        # algebraic: min(A∪B) = min(minA, minB)
    QuerySpec(nbhd, "std"),        # derived aggregate, fused channels
    QuerySpec(premium_nbhd, "avg"),  # generic lowering: materialized blocks
    QuerySpec(KHopWindow(2), "count"),  # classic paper window, same Session
]
sess = Session(
    g, specs, device=True, use_pallas=False, plan_headroom=0.5,
    policy=StalenessPolicy(max_link_ratio=4.0, max_garbage_ratio=0.5,
                           min_batches=3),
)
for gi, grp in enumerate(sess.compiled.groups):
    mode = "algebraic" if sess._programs[gi] else "generic"
    print(f"compiled: engine={grp.engine}, window={grp.window.name()}, "
          f"aggs={grp.aggs}, lowering={mode}")

for step in range(6):
    src = rng.integers(0, g.n, 6).astype(np.int32)
    dst = rng.integers(0, g.n, 6).astype(np.int32)
    ok = (src != dst) & ~sess.graph.contains_edges(src, dst)
    reports = sess.update(UpdateBatch.inserts(src[ok], dst[ok]))  # phase-1
    res = sess.run()
    ref = brute_force(sess.graph, specs[0].window, sess.graph.attrs["val"],
                      "sum", dtype=np.float32)
    assert np.array_equal(np.asarray(res[0], np.float32), ref)
    touched = max(r["affected"] for r in reports.values())
    print(f"step {step}: +{int(ok.sum())} edges -> <= {touched} windows "
          f"touched per term, composite queries still exact")

# attribute-value edits skip index maintenance entirely and invalidate
# caches through the DBIndex reverse link map (owners containing the vertex)
sess.update(UpdateBatch.attr_set("val", [1, 2, 3], [100.0, 101.0, 102.0]))
res = sess.run()
ref = brute_force(sess.graph, premium_nbhd, sess.graph.attrs["val"], "avg",
                  dtype=np.float32)
assert np.array_equal(np.asarray(res[3], np.float32), ref)
print(f"attr edit applied; staleness: {sess.staleness}")

# Serving many concurrent callers?  Front the Session with the serving
# layer (examples/window_service.py): point reads become affected-owner
# cache hits — attr edits invalidate only the containing owners.
from repro.serve import WindowService  # noqa: E402

svc = WindowService(sess, bucket=8)
t = svc.submit(specs[0], vertex=7)
svc.flush()
print(f"served sum(7)={t.result} at version {t.version}")
