"""Dynamic-graph window analytics: incremental index maintenance.

The paper's §4.3/§5.3 workflow: build once, stream edge updates, answer
queries continuously, reorganize periodically.

Run:  PYTHONPATH=src python examples/window_analytics.py
"""

import numpy as np

from repro.core import updates
from repro.core.dbindex import build_dbindex
from repro.core.query import brute_force
from repro.core.windows import KHopWindow
from repro.graphs.generators import erdos_renyi, with_random_attrs

rng = np.random.default_rng(0)
g = with_random_attrs(erdos_renyi(2_000, 6.0, seed=4), seed=5)
w = KHopWindow(2)

idx = build_dbindex(g, w, method="emc")
print(f"initial index: {idx.num_blocks} blocks, {idx.stats['num_links']} links")

for step in range(8):
    s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
    if s == t:
        continue
    g = updates.insert_edge(g, s, t)
    idx = updates.update_dbindex(idx, g, w, s, t)  # phase-1 incremental
    ans = idx.query(g.attrs["val"], "sum")
    assert np.allclose(ans, brute_force(g, w, g.attrs["val"], "sum"))
    print(f"step {step}: +edge ({s},{t}) -> {idx.stats['last_affected_owners']} "
          f"windows touched, query still exact")

# phase-2: periodic reorganization restores sharing quality
reorg = updates.reorganize(g, w)
print(f"reorganized: links {idx.stats['num_links']} -> {reorg.stats['num_links']}")
