"""Quickstart: declarative graph window queries end to end.

The paper's GWQ(G, W, Σ, A) as an API: declare `QuerySpec`s, let the
capability registry pick engines, and let the compiler fuse every
aggregate sharing a window into one multi-channel device plan.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.api import DEFAULT_REGISTRY, QuerySpec, Session
from repro.core.query import GraphWindowQuery
from repro.graphs.generators import erdos_renyi, random_dag, with_random_attrs

# --- a social-network-shaped graph with a per-user attribute ----------- #
g = with_random_attrs(erdos_renyi(5_000, 8.0, seed=0), seed=1)

# four aggregates over one 2-hop window: the compiler dedups the window and
# fuses them into ONE gather + stacked monoid segment-reduces on device
specs = [QuerySpec(("khop", 2), a) for a in ("sum", "count", "min", "avg")]
sess = Session(g, specs, device=True, use_pallas=False)
for grp in sess.compiled.groups:
    print(f"fused group: engine={grp.engine}, aggs={grp.aggs}")
s, c, mn, avg = sess.run()
print(f"2-hop circles: sum -> {s[:4]}, avg -> {avg[:4]}")

# serving-style traffic: a batch of attribute vectors, vmapped on device
batch = np.random.default_rng(2).normal(size=(8, g.n))
outs = sess.run_many(batch)
print(f"run_many: {len(outs)} specs x {outs[0].shape} answers")

# --- topological windows on a DAG (pathway-graph analytics) ------------ #
dag = with_random_attrs(random_dag(3_000, 4.0, seed=2), seed=3)
dag_specs = [QuerySpec("topological", "count"),
             QuerySpec("topological", "max")]
dsess = Session(dag, dag_specs, device=True, use_pallas=False)
counts, maxes = dsess.run()
print(f"I-Index inheritance: ancestor counts -> {counts[:5]}")

# the registry is introspectable: every backend declares its capability
for cap in DEFAULT_REGISTRY.capabilities():
    print(f"  engine {cap.name:12s} windows={cap.windows} "
          f"device={cap.device} sharded={cap.sharded}")

# legacy one-query facade still works (thin shim over the registry)
ref = GraphWindowQuery(dag_specs[0].window, agg="count").run(dag, engine="bitset")
assert np.allclose(counts, ref)
print("matches the non-indexed baseline; see benchmarks/ for the speedups")
