"""Quickstart: graph window queries end to end (the paper in 40 lines).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import engine_jax as ej
from repro.core.dbindex import build_dbindex
from repro.core.iindex import build_iindex
from repro.core.query import GraphWindowQuery
from repro.core.windows import KHopWindow, TopologicalWindow
from repro.graphs.generators import erdos_renyi, random_dag, with_random_attrs

# --- a social-network-shaped graph with a per-user attribute ----------- #
g = with_random_attrs(erdos_renyi(5_000, 8.0, seed=0), seed=1)

# GWQ(G, W_2hop, SUM, val): for every user, total `val` in their 2-hop circle
q = GraphWindowQuery(KHopWindow(2), agg="sum", attr="val")

# Dense Block Index (EMC construction) + shared two-stage evaluation
idx = build_dbindex(g, q.window, method="emc")
ans = idx.query(g.attrs["val"], "sum")
print(f"DBIndex: {idx.num_blocks} blocks, "
      f"{idx.stats['num_dense_blocks']} dense, query -> {ans[:5]}")

# same query on the JAX data plane (Pallas segment-sum kernels on TPU)
plan = ej.plan_from_dbindex(idx)
ans_dev = np.asarray(ej.query_dbindex(plan, g.attrs["val"], "sum"))
assert np.allclose(ans, ans_dev, atol=1e-3)
print("device data plane matches host result")

# --- topological windows on a DAG (pathway-graph analytics) ------------ #
dag = with_random_attrs(random_dag(3_000, 4.0, seed=2), seed=3)
ii = build_iindex(dag)
counts = ii.query(dag.attrs["val"], "count")
print(f"I-Index: max inheritance depth {ii.stats['max_level']}, "
      f"ancestor counts -> {counts[:5]}")

# non-indexed baseline for comparison (the gap the paper measures)
qt = GraphWindowQuery(TopologicalWindow(), agg="count")
ref = qt.run(dag, engine="bitset")
assert np.allclose(counts, ref)
print("matches the non-indexed baseline; see benchmarks/ for the speedups")
