"""The async serving tier: deadlines, backpressure, and durability.

`AsyncWindowService` wraps the micro-batched `WindowService` with a
continuous-batching front end:

* **deadline-driven flushing** — a background flusher launches a bucket
  when it fills OR when the earliest request's per-class deadline
  (`max_delay_ms`) expires.  A lone point read is served within ~2 ms
  instead of waiting for 7 more requests to show up;
* **backpressure + load shedding** — when the queue hits the admission
  window (which *shrinks* as the index's staleness approaches the
  `StalenessPolicy` reorganize thresholds), the lowest-priority sheddable
  full-graph scan is evicted first, and point reads are never shed;
* **write-ahead logging** — every `update()` is appended to the WAL
  *before* it is applied (append-before-apply, fsync-batched group
  commit), so a crashed service is rebuilt bit-identically by
  `Session.restore_from_wal`, and any follower tailing the log file is a
  cheap read replica (`ReadReplica`: pinned reads while behind, explicit
  `catch_up()`).

WAL file format: `GWAL1\\n\\0\\0` header, then per record
`WREC | version u64 | payload_len u64 | crc32 u32 | payload`, where the
payload is the pickle-free `UpdateBatch` codec (`UB1\\0` magic).  A torn
tail from a mid-append crash is detected by length/CRC and truncated on
reopen.

Run:  PYTHONPATH=src python examples/async_service.py
"""

import os
import tempfile
import time

import numpy as np

from repro.core.api import QuerySpec, Session
from repro.core.updates import UpdateBatch
from repro.graphs.generators import erdos_renyi
from repro.serve import AsyncWindowService, LoadShedError, ReadReplica

rng = np.random.default_rng(0)
g = erdos_renyi(2_000, 6.0, seed=4)
g = g.with_attr("val", rng.integers(0, 100, g.n).astype(np.float64))

specs = [QuerySpec(("khop", 1), a) for a in ("sum", "min")]
wal_path = os.path.join(tempfile.mkdtemp(prefix="async_svc_"), "service.wal")

sess = Session(g, specs, device=True, use_pallas=False, plan_headroom=1.0)

# ---- deadline flushing: sub-bucket requests don't wait ----------------- #
with AsyncWindowService(sess, bucket=64, wal=wal_path) as svc:
    svc.submit(1, vertex=0).get(timeout=30)  # warm the compile cache
    t0 = time.perf_counter()
    ticket = svc.submit(0, vertex=42)  # "point" class: 2 ms deadline
    answer = ticket.get(timeout=5.0)
    print(f"lone point read served in {(time.perf_counter() - t0) * 1e3:.1f} "
          f"ms by a deadline flush (bucket of 64 never filled); "
          f"sum(W(42)) = {answer}")

    # ---- durable update stream ---------------------------------------- #
    for step in range(5):
        s = rng.integers(0, g.n, 8).astype(np.int32)
        d = rng.integers(0, g.n, 8).astype(np.int32)
        ok = (s != d) & ~svc.session.graph.contains_edges(s, d)
        svc.update(UpdateBatch.inserts(s[ok], d[ok]))  # WAL'd, then applied
    head = svc.submit(0).get(timeout=5.0)  # full-scan at the head version
    stats = svc.stats
    w = stats["wal"]
    print(f"5 updates applied; wal = {w['records']} records, "
          f"{w['bytes']} bytes, {w['torn_truncations']} torn-tail "
          f"truncations, last fsync {w['last_fsync_s'] * 1e3:.2f} ms; "
          f"flushes: {stats['deadline_flushes']} deadline / "
          f"{stats['fill_flushes']} fill")

    # ---- load shedding under overload ---------------------------------- #
    # priorities: point(100, never shed) > interactive(10) > batch(0)
    shed = 0
    with AsyncWindowService(Session(g, specs, use_pallas=False),
                            bucket=4, max_pending=8) as tiny:
        for i in range(32):  # submit far faster than full scans serve
            try:
                tiny.submit(0, request_class="batch")  # sheddable scans
            except LoadShedError:
                shed += 1
    print(f"overload: {shed}/32 batch scans shed at admission "
          f"(point reads would all have been admitted)")

# ---- crash recovery: replay the WAL into a fresh session --------------- #
recovered = Session.restore_from_wal(g, specs, wal_path, device=True,
                                     use_pallas=False, plan_headroom=1.0)
same = np.array_equal(np.asarray(recovered.run()[0]), head)
print(f"recovered session at v{recovered.version}; bit-identical to the "
      f"live head: {same}")

# ---- read replica: tail the log, serve pinned, catch up ---------------- #
replica = ReadReplica(g, specs, wal_path, use_pallas=False)
print(f"replica starts at v{replica.version}, "
      f"{replica.lag['behind_bytes']} bytes behind")
replica.catch_up()
lag = replica.lag  # also publishes repro_replica_lag_{bytes,versions} gauges
same = np.array_equal(np.asarray(replica.query(0)), head)
print(f"replica caught up to v{replica.version}; lag = "
      f"{lag['behind_bytes']} bytes / {lag['unpublished_versions']} "
      f"unpublished versions; bit-identical: {same}")
