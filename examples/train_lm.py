"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
checkpointing, preemption recovery, and gradient compression.

Run:  PYTHONPATH=src python examples/train_lm.py  [--steps 300]
"""

import argparse
import tempfile

import numpy as np

from repro.launch.train import build_trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    with tempfile.TemporaryDirectory() as ckpt:
        tr = build_trainer(
            "qwen3-0.6b", smoke=True, batch=8, seq=128,
            steps=args.steps, ckpt_dir=ckpt, microbatch=2,
            grad_compression=True,
        )
        # simulate a mid-run preemption + restart
        tr.run(args.steps // 2)
        tr.save()
        tr.monitor.request_preemption()
        tr.run(10)  # exits immediately
        resumed_at = tr.resume()
        print(f"preempted; resumed from checkpoint step {resumed_at}")
        out = tr.run(args.steps - tr.step)
        hist = out["history"]
        print(f"steps={out['step']}  loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
        assert hist[-1]["loss"] < hist[0]["loss"], "loss must decrease"
        assert np.isfinite(hist[-1]["loss"])


if __name__ == "__main__":
    main()
