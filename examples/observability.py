"""Observability end to end: metrics, request tracing, SLO accounting.

`repro.obs` is a zero-dependency observability layer threaded through the
whole serving stack — `Session` maintenance, the `WindowService`
schedulers, the WAL, replicas, and the sharded runtime.  It is off by
default: every instrumented class falls back to a process-global
`NullRegistry`/`NullTracer` whose operations are no-ops, so the hot path
pays one attribute call per event.  `obs.enable()` swaps in live
implementations; call it BEFORE constructing sessions/services (classes
capture the registry at construction).

This demo drives an `AsyncWindowService` with a concurrent update stream
while three request classes compete, then reads everything back out:

* per-class SLO attainment (fraction of ok requests within their class
  `max_delay_ms`), p50/p95/p99 latency from fixed-bucket histograms;
* the affected-owner cache hit rate and invalidation traffic;
* the unified recompile counter — flat across the whole streamed run;
* a Prometheus text exposition;
* a Chrome `trace_event` JSON (load it at chrome://tracing or
  https://ui.perfetto.dev) with the full span hierarchy:
  flush > launch > query.group > query.term on the read path and
  service.update > session.update > maintain > index.update/plan.patch
  on the write path, plus one detached "request" span per ticket.

Reading the metrics
-------------------
Every instrument is prefixed ``repro_`` and follows the Prometheus
conventions: counters end in ``_total``, durations are ``_seconds``
histograms, sizes are ``_bytes``/``_records``, and gauges are bare nouns.
Label keys are closed vocabularies:

* ``cls``     — request class name (``interactive``, ``point``, ...);
* ``outcome`` — ``ok`` | ``error`` | ``shed`` (on ``repro_requests_total``);
* ``reason``  — ``fill`` | ``deadline`` | ``manual`` (on
  ``repro_flushes_total``: what triggered the launch);
* ``event``   — ``hit`` | ``miss`` | ``invalidate`` | ``drop`` (on
  ``repro_cache_events_total``);
* ``kind`` / ``action`` — index kind and maintenance action
  (``attr_only`` | ``refilter`` | ``patch`` | ``reorganize``) on
  ``repro_maintenance_total``.

The ones to alert on: ``repro_slo_within_target_total / ok`` per class
(attainment), ``repro_recompiles`` (a moving value means retraces in
steady state — the one thing this stack promises never happens),
``repro_wal_fsync_seconds`` p99 (durability stalls), and
``repro_replica_lag_bytes`` (follower health).

Run:  PYTHONPATH=src python examples/observability.py
"""

import json
import os
import tempfile
import threading
import time

import numpy as np

from repro import obs

# enable FIRST: instrumented classes bind the registry at construction
registry, tracer = obs.enable()

from repro.core.api import QuerySpec, Session, recompile_count  # noqa: E402
from repro.core.updates import UpdateBatch  # noqa: E402
from repro.graphs.generators import erdos_renyi  # noqa: E402
from repro.serve import AsyncWindowService  # noqa: E402

rng = np.random.default_rng(0)
g = erdos_renyi(1_500, 5.0, seed=4)
g = g.with_attr("val", rng.integers(0, 100, g.n).astype(np.float64))
specs = [QuerySpec(("khop", 1), "sum"), QuerySpec(("khop", 1), "min")]
out_dir = tempfile.mkdtemp(prefix="repro_obs_")

sess = Session(g, specs, device=True, use_pallas=False, plan_headroom=1.0)

with AsyncWindowService(sess, bucket=8,
                        wal=os.path.join(out_dir, "service.wal")) as svc:
    # ---- warmup: compile every executor shape the run will use -------- #
    svc.submit(0).get(timeout=60)
    svc.submit(0, vertex=0).get(timeout=60)
    svc.update(UpdateBatch.inserts(np.array([1], np.int32),
                                   np.array([2], np.int32)))
    svc.submit(1).get(timeout=60)
    warm = recompile_count()

    # ---- concurrent update stream ------------------------------------- #
    stop = threading.Event()

    def writer():
        r = np.random.default_rng(7)
        while not stop.is_set():
            s = r.integers(0, g.n, 4).astype(np.int32)
            d = r.integers(0, g.n, 4).astype(np.int32)
            ok = (s != d) & ~svc.session.graph.contains_edges(s, d)
            if ok.any():
                svc.update(UpdateBatch.inserts(s[ok], d[ok]))
            time.sleep(0.002)

    th = threading.Thread(target=writer, name="update-stream")
    th.start()

    # ---- mixed request classes under load ----------------------------- #
    tickets = []
    for i in range(96):
        if i % 3 == 0:
            tickets.append(svc.submit(0, vertex=int(rng.integers(g.n))))
        elif i % 3 == 1:
            tickets.append(svc.submit(i % 2, request_class="interactive"))
        else:
            tickets.append(svc.submit(i % 2, request_class="batch"))
    served = sum(1 for t in tickets if t.get(timeout=60.0) is not None)
    stop.set()
    th.join()

    stats = svc.stats

# ---- the one invariant dashboards page on: zero recompiles ------------- #
assert recompile_count() == warm, "steady-state stream must never retrace"
print(f"{served}/96 requests served under a concurrent update stream; "
      f"recompiles after warmup: {recompile_count() - warm}")

# ---- SLO attainment per request class ---------------------------------- #
print("\nSLO report (per request class):")
for cls, rep in sorted(stats["slo"].items()):
    att = ("n/a" if rep["attainment"] is None
           else f"{rep['attainment'] * 100:.1f}%")
    tgt = "-" if rep["target_ms"] is None else f"{rep['target_ms']:.0f} ms"
    print(f"  {cls:<12} target {tgt:>7}  attainment {att:>6}  "
          f"ok/err/shed {rep['ok']}/{rep['error']}/{rep['shed']}  "
          f"p50 {rep['p50_ms']:.1f} ms  p95 {rep['p95_ms']:.1f} ms  "
          f"p99 {rep['p99_ms']:.1f} ms")

# ---- cache + WAL + maintenance counters from the snapshot -------------- #
snap = registry.snapshot()


def fam(name, **labels):
    for row in snap.get(name, {}).get("values", []):
        if all(row["labels"].get(k) == v for k, v in labels.items()):
            return row["value"]
    return 0.0


hits = fam("repro_cache_events_total", event="hit")
misses = fam("repro_cache_events_total", event="miss")
rate = hits / max(hits + misses, 1)
print(f"\naffected-owner cache: {hits:.0f} hits / {misses:.0f} misses "
      f"({rate * 100:.1f}% hit rate), "
      f"{fam('repro_cache_events_total', event='invalidate'):.0f} owner "
      f"invalidations")
print(f"flush triggers: {fam('repro_flushes_total', reason='fill'):.0f} fill "
      f"/ {fam('repro_flushes_total', reason='deadline'):.0f} deadline "
      f"/ {fam('repro_flushes_total', reason='manual'):.0f} manual; "
      f"wal appends: {fam('repro_wal_appends_total'):.0f}")
maint = snap["repro_maintenance_total"]["values"]
print("maintenance:", ", ".join(
    f"{r['labels']['kind']}/{r['labels']['action']}={r['value']:.0f}"
    for r in maint))

# ---- exporters --------------------------------------------------------- #
prom_path = os.path.join(out_dir, "metrics.prom")
with open(prom_path, "w") as f:
    f.write(registry.prometheus())
trace_path = tracer.dump(os.path.join(out_dir, "trace.json"))

with open(trace_path) as f:
    doc = json.load(f)
depth = tracer.max_depth()
assert depth >= 4, f"expected >= 4 span levels, got {depth}"
print(f"\nwrote {prom_path} ({sum(1 for _ in open(prom_path))} lines) and "
      f"{trace_path} ({len(doc['traceEvents'])} events, span depth {depth})"
      f" — load the trace at chrome://tracing")

obs.disable()
