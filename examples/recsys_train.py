"""FM recsys training + the three serving modes (p99 / bulk / retrieval).

Run:  PYTHONPATH=src python examples/recsys_train.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.data.pipeline import RecsysStream
from repro.models import recsys as R
from repro.optim.optimizers import adamw

cfg = get_arch("fm").smoke_cfg
params = R.init(jax.random.PRNGKey(0), cfg)
opt = adamw(1e-2)
opt_state = opt.init(params)
stream = RecsysStream(n_fields=cfg.n_fields, batch=256, seed=0)


@jax.jit
def step(params, opt_state, batch):
    loss, grads = jax.value_and_grad(lambda p: R.loss_fn(p, batch, cfg))(params)
    params, opt_state, _ = opt.update(grads, opt_state, params)
    return params, opt_state, loss


losses = []
for it in range(50):
    b = stream.next()
    params, opt_state, loss = step(params, opt_state,
                                   {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])})
    losses.append(float(loss))
print(f"train: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
assert losses[-1] <= losses[0]

# serving modes
b = stream.next()
p99 = R.forward(params, jnp.asarray(b["x"][:32]), cfg)
print(f"serve_p99 logits: {np.asarray(p99)[:4].round(3)}")
scores = R.retrieval_scores(params, jnp.asarray(b["x"][:1]), jnp.arange(1000), cfg)
top = np.argsort(np.asarray(scores))[-5:]
print(f"retrieval top-5 candidates: {top.tolist()}")
