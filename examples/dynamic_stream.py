"""Dynamic window analytics over a timestamped edge stream.

Demonstrates the streaming subsystem end to end (the paper's title
scenario): a social-network-shaped graph receives a timestamped stream of
edge insertions and deletions; the stream is replayed in time-window
batches with window-aggregate queries interleaved after every tick.  The
DBIndex and its device plan are maintained incrementally; the staleness
policy triggers paper-§4.3 Phase-2 reorganizations when phase-1 merges
have eroded sharing.

Run:  PYTHONPATH=src python examples/dynamic_stream.py [--n 20000]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.query import brute_force
from repro.core.streaming import StalenessPolicy, StreamingEngine
from repro.core.updates import UpdateBatch
from repro.core.windows import KHopWindow
from repro.graphs.generators import erdos_renyi, with_random_attrs


def make_stream(g, rng, n_events: int, t_end: float, delete_frac: float = 0.3):
    """Timestamped event list: mostly inserts of fresh edges, a fraction of
    deletions of (currently) existing edges.  Timestamps are uniform; the
    replay below buckets them into fixed ticks."""
    events = []
    live_src = list(map(int, g.src))
    live_dst = list(map(int, g.dst))
    ts = np.sort(rng.uniform(0.0, t_end, n_events))
    for t in ts:
        if rng.random() < delete_frac and live_src:
            i = int(rng.integers(len(live_src)))
            events.append((float(t), -1, live_src.pop(i), live_dst.pop(i)))
        else:
            while True:
                s, d = int(rng.integers(g.n)), int(rng.integers(g.n))
                if s != d:
                    break
            events.append((float(t), +1, s, d))
            live_src.append(s)
            live_dst.append(d)
    return events


def replay(engine: StreamingEngine, events, tick: float, query_agg: str = "sum",
           verify_every: int = 0):
    """Group events into [i*tick, (i+1)*tick) batches; query after each."""
    events = sorted(events)
    i, n_ticks = 0, 0
    t_update = t_query = 0.0
    while i < len(events):
        t_lo = events[i][0] // tick * tick
        j = i
        while j < len(events) and events[j][0] < t_lo + tick:
            j += 1
        chunk = events[i:j]
        ops = np.array([e[1] for e in chunk], np.int8)
        src = np.array([e[2] for e in chunk], np.int32)
        dst = np.array([e[3] for e in chunk], np.int32)
        # drop deletes of edges that no longer exist at this point
        # (stream generation tracked liveness, but batching reorders within
        # a tick; filter defensively)
        dels = ops < 0
        present = engine.graph.contains_edges(src, dst)
        keep = ~dels | present
        batch = UpdateBatch(src[keep], dst[keep], ops[keep],
                            np.array([e[0] for e in chunk], np.float64)[keep])
        t0 = time.perf_counter()
        rep = engine.apply(batch)
        t_update += time.perf_counter() - t0
        t0 = time.perf_counter()
        ans = engine.query(query_agg)
        t_query += time.perf_counter() - t0
        n_ticks += 1
        flag = " [reorganized]" if rep["reorganized"] else ""
        print(f"tick {n_ticks:3d}: {batch.size:4d} edits, "
              f"{rep['affected']:5d} affected owners, "
              f"index {rep['t_index_s']*1e3:7.1f} ms, "
              f"plan {rep['t_plan_s']*1e3:7.1f} ms, "
              f"top owner sum={float(np.max(ans)):.0f}{flag}")
        if verify_every and n_ticks % verify_every == 0:
            ref = brute_force(engine.graph, engine.window,
                              engine.graph.attrs["val"], query_agg)
            assert np.allclose(ans, ref, rtol=1e-5, atol=1e-3), "divergence!"
            print(f"          verified against brute force at tick {n_ticks}")
        i = j
    return n_ticks, t_update, t_query


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--deg", type=float, default=6.0)
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--events", type=int, default=4_000)
    ap.add_argument("--ticks", type=int, default=10)
    ap.add_argument("--verify-every", type=int, default=0,
                    help="brute-force check every N ticks (slow; 0 = off)")
    ap.add_argument("--host", action="store_true", help="NumPy executor only")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    g = with_random_attrs(erdos_renyi(args.n, args.deg, seed=0), seed=1)
    print(f"graph: n={g.n} edges={g.n_edges}, window=khop[{args.k}]")

    t0 = time.perf_counter()
    engine = StreamingEngine(
        g, KHopWindow(args.k), device=not args.host, use_pallas=False,
        policy=StalenessPolicy(max_link_ratio=1.5, min_batches=2),
    )
    print(f"initial build+plan: {time.perf_counter()-t0:.2f}s "
          f"({engine.index.num_blocks} blocks)")

    events = make_stream(engine.graph, rng, args.events, t_end=float(args.ticks))
    ticks, t_update, t_query = replay(
        engine, events, tick=1.0, verify_every=args.verify_every
    )
    print(f"\nreplayed {len(events)} events in {ticks} ticks: "
          f"maintenance {t_update:.2f}s, queries {t_query:.2f}s, "
          f"{engine.reorg_count} reorganizations, "
          f"staleness now {engine.staleness}")


if __name__ == "__main__":
    main()
