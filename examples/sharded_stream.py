"""Sharded streaming window analytics: a mesh Session over a live graph.

Demonstrates the distributed runtime end to end:

1. build a mesh (forced host-platform devices off-TPU) and hand it to
   ``Session(mesh=...)`` — planning selects the ``jax-sharded`` capability
   and the DBIndex device plan is laid out as per-shard tile groups;
2. stream 20 ``UpdateBatch``es: each batch's affected-owner BFS runs one
   seed slice per shard, and only the *changed tile groups* are shipped to
   the shard that owns them (watch ``patch_bytes`` vs the full plan);
3. serve fused multi-aggregate queries across the mesh the whole time,
   with zero recompiles of the sharded executor.

Run: ``PYTHONPATH=src python examples/sharded_stream.py``
"""

import os

# must be set before jax initializes
os.environ.setdefault("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in os.environ["XLA_FLAGS"]:
    os.environ["XLA_FLAGS"] += " --xla_force_host_platform_device_count=4"

import numpy as np


def main():
    import jax

    from repro.core.api import QuerySpec, Session
    from repro.core.updates import UpdateBatch
    from repro.distributed import window_runtime as wr
    from repro.graphs.generators import erdos_renyi, with_random_attrs

    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    print(f"mesh: {mesh.shape}")

    g = with_random_attrs(erdos_renyi(3_000, 5.0, directed=False, seed=0),
                          seed=1)
    specs = [QuerySpec(("khop", 1), a) for a in ("sum", "count", "min", "avg")]
    sess = Session(g, specs, mesh=mesh, plan_headroom=1.0)
    assert isinstance(sess, wr.ShardedSession)

    s, c, mn, avg = sess.run()
    print(f"initial:  sum[0]={s[0]:.2f} count[0]={c[0]:.0f} "
          f"min[0]={mn[0]:.2f} avg[0]={avg[0]:.2f}")
    cache0 = wr.query_cache_size()

    rng = np.random.default_rng(2)
    for step in range(20):
        src = rng.integers(0, g.n, 8).astype(np.int32)
        dst = rng.integers(0, g.n, 8).astype(np.int32)
        keep = src != dst
        reports = sess.update(UpdateBatch.inserts(src[keep], dst[keep]))
        rep = next(iter(reports.values()))
        s, c, mn, avg = sess.run()
        if step % 5 == 0 or step == 19:
            print(f"batch {step:2d}: affected/shard={rep['affected_per_shard']}"
                  f" patch={rep['patch_bytes']:,}B"
                  f" (full plan {rep['full_plan_bytes']:,}B)"
                  f" sum[0]={s[0]:.2f}")

    recompiles = wr.query_cache_size() - cache0
    print(f"recompiles across the stream: {recompiles}")
    assert recompiles == 0, "sharded fused query retraced during the stream"
    print(f"staleness: {sess.staleness}")


if __name__ == "__main__":
    main()
