"""Serving window analytics to many concurrent callers.

A `Session` answers one blocking `run()` at a time; the serving layer
fronts it for real traffic:

* point reads (`vertex=`) are O(1) affected-owner-cache hits in steady
  state — an update invalidates only the ~|affected| vertices whose
  windows actually changed;
* callers bringing their own feature vectors (`values=`) are coalesced
  per plan group into fixed-bucket padded launches, so one compiled
  [bucket, n] executable serves every flush (zero retraces);
* reads are version-pinned snapshots: with `auto_flip=False` a burst of
  updates lands on the write head while readers keep answering at their
  version, and `flip()` publishes atomically.

Run:  PYTHONPATH=src python examples/window_service.py
"""

import numpy as np

from repro.core.api import QuerySpec, Session
from repro.core.updates import UpdateBatch
from repro.graphs.generators import erdos_renyi
from repro.serve import WindowService

rng = np.random.default_rng(0)
g = erdos_renyi(2_000, 6.0, seed=4)
g = g.with_attr("val", rng.integers(0, 100, g.n).astype(np.float64))

specs = [QuerySpec(("khop", 1), a) for a in ("sum", "count", "min", "avg")]
sess = Session(g, specs, device=True, use_pallas=False, plan_headroom=1.0)
svc = WindowService(sess, bucket=8)

# ---- point traffic: cache warms on the first read, then it's O(1) ------ #
for v in (3, 17, 42, 3, 17, 42):
    svc.query(0, vertex=v)
print(f"point reads: {svc.point_hits} hits / {svc.point_misses} miss "
      f"(first read refreshed the whole group vector in one launch)")

# ---- update stream: invalidation is surgical --------------------------- #
for step in range(5):
    s = rng.integers(0, g.n, 8).astype(np.int32)
    d = rng.integers(0, g.n, 8).astype(np.int32)
    ok = (s != d) & ~svc.session.graph.contains_edges(s, d)
    reports = svc.update(UpdateBatch.inserts(s[ok], d[ok]))
    rep = next(iter(reports.values()))
    answers = [svc.query(i, vertex=42) for i in range(len(specs))]
    print(f"v{rep['version']}: {rep['affected']} windows invalidated of "
          f"{g.n}; vertex 42 -> {dict(zip(('sum', 'cnt', 'min', 'avg'), answers))}")

# ---- callers with their own feature vectors: coalesced launches -------- #
tickets = [svc.submit(0, vertex=7, values=rng.integers(0, 100, g.n))
           for _ in range(13)]
svc.flush()
print(f"13 explicit-values requests -> {svc.batched_launches} padded "
      f"launches of bucket={svc.bucket} (padded rows: {svc.padded_rows})")

# ---- versioned reads: pin during a burst, publish once ----------------- #
svc.auto_flip = False
before = svc.query(1, vertex=7)
for _ in range(3):
    s = rng.integers(0, g.n, 4).astype(np.int32)
    d = rng.integers(0, g.n, 4).astype(np.int32)
    ok = (s != d) & ~svc.session.graph.contains_edges(s, d)
    svc.update(UpdateBatch.inserts(s[ok], d[ok]))
pinned = svc.query(1, vertex=7)
print(f"pinned at v{svc.version} while head is v{svc.head_version}: "
      f"count(7) stays {pinned} (== {before})")
svc.flip()
print(f"flipped to v{svc.version}: count(7) now {svc.query(1, vertex=7)}")
print(f"service stats: {svc.stats}")
