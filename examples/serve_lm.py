"""Serve a small LM with batched requests (prefill + batched greedy decode).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine

cfg = get_arch("qwen3-0.6b").smoke_cfg
params = T.init(jax.random.PRNGKey(0), cfg)
engine = ServeEngine(params, cfg, T, max_seq=64, slots=4)

rng = np.random.default_rng(0)
requests = [
    Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=rng.integers(4, 12)).astype(np.int32),
            max_new=8)
    for i in range(4)
]
outs = engine.generate(requests)
for rid, toks in sorted(outs.items()):
    print(f"request {rid}: generated {toks.tolist()}")
print("batched serve ok")
