"""GraphSAGE training with the host-side neighbor sampler, plus the paper's
DBIndex-shared k-hop feature aggregation as an input augmentation.

Run:  PYTHONPATH=src python examples/gnn_train.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dbindex import build_dbindex
from repro.core.engine_jax import plan_from_dbindex, query_dbindex
from repro.core.windows import KHopWindow
from repro.data.pipeline import NeighborSampler
from repro.graphs.generators import erdos_renyi
from repro.models import gnn as G
from repro.optim.optimizers import adamw

rng = np.random.default_rng(0)
g = erdos_renyi(5_000, 10.0, seed=6)
feats = rng.standard_normal((g.n, 32)).astype(np.float32)
labels = rng.integers(0, 5, g.n).astype(np.int32)

# --- the paper's technique as a feature operator ----------------------- #
# 2-hop window SUM of features, shared via dense blocks (one build, reused)
idx = build_dbindex(g, KHopWindow(2), method="emc")
plan = plan_from_dbindex(idx)
window_feats = np.asarray(query_dbindex(plan, feats, "sum", use_pallas=False))
x = np.concatenate([feats, window_feats / (1 + window_feats.std())], axis=1)
print(f"augmented features with DBIndex 2-hop window sums: {x.shape}")

cfg = G.GNNConfig(name="sage", kind="sage", n_layers=2, d_in=x.shape[1],
                  d_hidden=64, d_out=5)
params = G.sage_init(jax.random.PRNGKey(0), cfg)
opt = adamw(1e-2)
opt_state = opt.init(params)
sampler = NeighborSampler(g, fanouts=(10, 5))


n_targets = 64
N_SUB = NeighborSampler(g, fanouts=(10, 5)).sample(n_targets)["sub_n"]


@jax.jit
def step(params, opt_state, feats_sub, es, ed, y):
    def loss_fn(p):
        out = G.sage_forward(p, feats_sub, es, ed, N_SUB, cfg)
        logits = out[:n_targets].astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - ll)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt_state, _ = opt.update(grads, opt_state, params)
    return params, opt_state, loss


for it in range(30):
    sub = sampler.sample(n_targets)
    fs = jnp.asarray(x[sub["node_ids"]])
    y = jnp.asarray(labels[sub["node_ids"][:n_targets]])
    params, opt_state, loss = step(
        params, opt_state, fs, jnp.asarray(sub["edge_src"]),
        jnp.asarray(sub["edge_dst"]), y
    )
    if it % 10 == 0:
        print(f"iter {it}: loss {float(loss):.3f}")
print("graphsage minibatch training ok")
