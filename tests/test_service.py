"""Window-analytics serving layer (ISSUE 4).

Covers the tentpole contracts:

* **bit-identity** — every served result (cached point reads after K
  interleaved update batches, coalesced explicit-values launches, pinned
  snapshot reads) is bit-identical to a fresh, un-cached ``Session.run()``
  oracle at the same version.  Attribute values are small integers, so
  every f32 monoid reduction is exact regardless of evaluation order —
  patched plans, fresh plans, vmapped and sharded executions must agree
  bit-for-bit, not just approximately;
* **scheduler executable reuse** — padded fixed-bucket launches never
  recompile across >= 20 flushes of varying request counts;
* **versioned snapshot reads** — with ``auto_flip=False`` readers stay
  pinned (bitwise) at their version while updates land, and ``flip()``
  publishes the head;
* **affected-owner cache** — an update invalidates exactly the affected
  owners; a vertex whose window overlaps the affected boundary (neighbor
  of an owner) stays cached AND bit-correct;
* **sharded serving** — ``ShardedSession.run_many`` serves a [B, n] bucket
  in one launch (no per-row executable replay), and the per-shard pass-1
  compaction keeps delete-dominated streams patch-only (tier-1 runs the
  full code path on a 1-device mesh; the multi-device variant lives behind
  the ``sharded`` marker).
"""

import subprocess
import sys
import textwrap

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import api  # noqa: E402
from repro.core.api import QuerySpec, Session  # noqa: E402
from repro.core.query import brute_force  # noqa: E402
from repro.core.streaming import StalenessPolicy  # noqa: E402
from repro.core.updates import UpdateBatch  # noqa: E402
from repro.graphs.generators import erdos_renyi  # noqa: E402
from repro.serve import WindowService  # noqa: E402

from test_updates import mixed  # noqa: E402  (stream helpers)

ALL_AGGS = ("sum", "count", "min", "avg")


def int_graph(n, deg, seed, lo=0, hi=50):
    """Graph with small-integer 'val' attrs: every monoid reduce is exact
    in f32, so differently-shaped plans must agree bit-for-bit."""
    g = erdos_renyi(n, deg, directed=False, seed=seed)
    vals = np.random.default_rng(seed + 1).integers(lo, hi, g.n)
    return g.with_attr("val", vals.astype(np.float64))


def int_vec(rng, n, lo=0, hi=50):
    return rng.integers(lo, hi, n).astype(np.float64)


# --------------------- differential cache correctness ------------------ #
def test_served_bit_identical_after_interleaved_updates():
    """The satellite differential test: a served point query after K
    interleaved update batches is bit-identical to a fresh un-cached
    ``Session.run()`` at the same version — including the adversarial
    boundary case where an update touches a vertex whose cached window
    overlaps the affected-set boundary."""
    g = int_graph(300, 4.0, seed=7)
    specs = [QuerySpec(("khop", 1), a) for a in ALL_AGGS] + [
        QuerySpec(("khop", 2), "sum")
    ]
    sess = Session(g, specs, device=True, use_pallas=False, plan_headroom=1.0)
    svc = WindowService(sess, bucket=4)
    gi1 = sess.compiled.spec_slots[0][0]  # the fused khop[1] group
    rng = np.random.default_rng(8)
    sample = rng.integers(0, g.n, 12)
    boundary_checked = 0
    for step in range(6):
        # pre-populate the cache so the update's invalidation is observable
        for si in range(len(specs)):
            svc.query(si)
        reports = svc.update(mixed(svc.session.graph, rng, 4, 2))
        owners = reports["khop[1]/dbindex"]["affected_owners"]
        owners_set = set(map(int, owners))

        # adversarial boundary: a neighbor of an affected owner is OUTSIDE
        # the affected set — its window contains affected vertices but its
        # own membership did not change, so its cache entry must survive
        # the invalidation and still be bit-correct
        g_cur = svc.session.graph
        v_out = next(
            (int(u) for o in owners for u in g_cur.out_neighbors(int(o))
             if int(u) not in owners_set),
            None,
        )
        entry = svc.cache._entries.get(gi1)
        if v_out is not None and entry is not None and owners.size:
            assert entry["valid"][v_out], "boundary vertex wrongly invalidated"
            assert not entry["valid"][int(owners[0])], "owner not invalidated"
            boundary_checked += 1

        # fresh, un-cached oracle at the served version
        fresh = Session(g_cur, specs, device=True, use_pallas=False)
        refs = [np.asarray(r) for r in fresh.run()]
        check = list(sample) + ([v_out, int(owners[0])] if v_out is not None
                                and owners.size else [])
        for si in range(len(specs)):
            for v in check:
                t = svc.submit(si, vertex=int(v))
                svc.flush()
                assert t.result == refs[si][v], (step, si, v)
                assert t.version == svc.session.version
        if v_out is not None:
            t = svc.submit(0, vertex=v_out)
            svc.flush()
            assert t.cache_hit  # boundary vertex served straight from cache
    assert boundary_checked > 0, "adversarial boundary case never exercised"
    assert svc.stats["point_hit_rate"] > 0.5  # steady-state traffic hits


def test_cache_invalidates_only_affected():
    g = int_graph(250, 4.0, seed=11)
    w = ("khop", 1)
    sess = Session(g, [QuerySpec(w, "sum")], device=True, use_pallas=False,
                   plan_headroom=1.0)
    svc = WindowService(sess, bucket=2)
    svc.query(0)  # populate
    rng = np.random.default_rng(12)
    rep = next(iter(svc.update(mixed(svc.session.graph, rng, 3, 1)).values()))
    owners = rep["affected_owners"]
    assert 0 < owners.size < g.n
    assert svc.cache.invalidated == owners.size
    gi = sess.compiled.spec_slots[0][0]
    assert svc.cache.valid_fraction(gi) == pytest.approx(1 - owners.size / g.n)
    # a point read on an unaffected vertex is served without any launch
    entry = svc.cache._entries[gi]
    v = int(np.flatnonzero(entry["valid"])[0])
    misses0 = svc.point_misses
    svc.query(0, vertex=v)
    assert svc.point_misses == misses0
    # version bookkeeping rode along
    assert rep["version"] == sess.version == svc.cache.version
    assert rep["plan_version"] >= 1


# ----------------------- scheduler: fixed-bucket ------------------------ #
def test_scheduler_fixed_bucket_zero_recompiles():
    """>= 20 flushes of varying request counts (point + full, two specs)
    coalesce into bucket-padded launches that never recompile after
    warmup, and every answer is bit-identical to a direct Session.run."""
    g = int_graph(200, 3.0, seed=21)
    specs = [QuerySpec(("khop", 1), "sum"), QuerySpec(("khop", 1), "min")]
    sess = Session(g, specs, device=True, use_pallas=False)
    svc = WindowService(sess, bucket=4)
    rng = np.random.default_rng(22)
    # warmup compiles the [bucket, n] executable once; the un-batched
    # spot-check path below gets its compile here too, so the unified
    # counter is warm across every executor the test will touch
    svc.submit(0, values=int_vec(rng, g.n))
    svc.flush()
    sess.run(values=int_vec(rng, g.n))
    # the unified counter covers run_many plus every other fused executor:
    # flat here means NOTHING in the process recompiled, not just run_many
    cache0 = api.recompile_count()
    assert api.run_many_cache_size() > 0
    flushes0 = svc.flushes
    for f in range(21):
        k = 1 + (f % 7)  # 1..7 requests: padding keeps the shape fixed
        tickets = []
        for j in range(k):
            tickets.append(svc.submit(
                (f + j) % 2,
                vertex=None if j % 3 == 0 else int(rng.integers(g.n)),
                values=int_vec(rng, g.n),
            ))
        svc.flush()
        if f % 5 == 0:  # spot-check bitwise against the un-batched path
            for t in tickets:
                ref = np.asarray(sess.run(values=t.values)[t.spec_index])
                got = t.result if t.vertex is None else np.asarray([t.result])
                want = ref if t.vertex is None else ref[[t.vertex]]
                assert np.array_equal(np.atleast_1d(got), want), (f, t.rid)
    assert svc.flushes - flushes0 >= 21
    assert api.recompile_count() == cache0  # zero recompiles anywhere
    assert svc.batched_launches >= 21
    assert svc.padded_rows > 0  # partial buckets really were padded


def test_submit_validates_without_poisoning_the_flush():
    """A malformed request fails its own submit(); queued tickets from
    other callers are unaffected and still served by the next flush."""
    g = int_graph(150, 3.0, seed=25)
    sess = Session(g, [QuerySpec(("khop", 1), "sum")], device=True,
                   use_pallas=False)
    svc = WindowService(sess, bucket=2)
    ok = svc.submit(0, vertex=3)
    with pytest.raises(IndexError, match="out of range"):
        svc.submit(0, vertex=g.n)  # would wrap/raise only at flush time
    with pytest.raises(IndexError, match="out of range"):
        svc.submit(0, vertex=-1)  # numpy would silently wrap to n-1
    with pytest.raises(ValueError, match="shape"):
        svc.submit(0, values=np.ones(g.n + 5))
    with pytest.raises(KeyError):
        svc.submit(QuerySpec(("khop", 2), "sum"))  # not compiled
    svc.flush()
    assert ok.done
    ref = brute_force(g, sess.compiled.groups[0].window, g.attrs["val"], "sum")
    assert ok.result == np.float32(ref[3])


def test_host_groups_skip_bucket_padding():
    """Padding buys executable reuse only on jitted device paths; a host
    group must not pay one full sequential query per pad row."""
    g = int_graph(120, 3.0, seed=26)
    sess = Session(g, [QuerySpec(("khop", 1), "sum", engine="bitset")],
                   use_pallas=False)
    svc = WindowService(sess, bucket=8)
    rng = np.random.default_rng(27)
    vals = int_vec(rng, g.n)
    t = svc.submit(0, vertex=4, values=vals)
    svc.flush()
    assert svc.padded_rows == 0  # 1-row batch, not 8
    ref = brute_force(g, sess.compiled.groups[0].window, vals, "sum")
    assert np.allclose(t.result, ref[4])
    # non-numeric values fail their own submit, not the shared flush
    with pytest.raises((TypeError, ValueError)):
        svc.submit(0, values=np.array(["x"] * g.n))


def test_pinned_point_reads_share_one_launch_per_flush():
    """With readers pinned behind the write head the versioned cache is
    bypassed — N point reads of one group in a flush must still cost one
    fused launch (flush-local memo), not N."""
    g = int_graph(150, 3.0, seed=27)
    sess = Session(g, [QuerySpec(("khop", 1), "sum")], device=True,
                   use_pallas=False, plan_headroom=1.0)
    svc = WindowService(sess, bucket=2, auto_flip=False)
    rng = np.random.default_rng(28)
    svc.update(mixed(svc.session.graph, rng, 3, 1))  # head moves, reader pinned
    assert svc.version < svc.head_version
    calls = []
    orig = sess._exec_group
    sess._exec_group = lambda *a, **k: calls.append(1) or orig(*a, **k)
    try:
        tickets = [svc.submit(0, vertex=v) for v in (1, 5, 9, 13, 21, 33)]
        svc.flush()
    finally:
        sess._exec_group = orig
    assert len(calls) == 1, f"{len(calls)} launches for one pinned flush"
    # and the pinned answers are the v0 answers (g is the v0 graph)
    ref = brute_force(g, sess.compiled.groups[0].window, g.attrs["val"], "sum")
    for t, v in zip(tickets, (1, 5, 9, 13, 21, 33)):
        assert t.version == 0 and t.result == np.float32(ref[v])


# -------------------- versioned snapshot reads -------------------------- #
def test_versioned_snapshot_pinned_reads():
    g = int_graph(250, 4.0, seed=31)
    specs = [QuerySpec(("khop", 1), "sum")]
    sess = Session(g, specs, device=True, use_pallas=False, plan_headroom=1.0)
    svc = WindowService(sess, bucket=2, auto_flip=False)
    base = svc.query(0)
    assert svc.version == svc.head_version == 0
    rng = np.random.default_rng(32)
    svc.update(mixed(svc.session.graph, rng, 4, 2))
    # the write head advanced; readers stay pinned
    assert svc.head_version == 1 and svc.version == 0
    pinned = svc.query(0)
    assert np.array_equal(pinned, base)  # bitwise: same artifacts, same result
    # flip publishes v1 atomically; answers now match a fresh v1 oracle
    assert svc.flip() == 1 and svc.version == 1
    fresh = Session(svc.session.graph, specs, device=True, use_pallas=False)
    assert np.array_equal(svc.query(0), np.asarray(fresh.run()[0]))


def test_session_snapshot_is_immutable_under_updates():
    """Session-level hook: a snapshot keeps answering at its version while
    update() patches the next one (the MVCC property the service rides)."""
    g = int_graph(250, 4.0, seed=41)
    sess = Session(g, [QuerySpec(("khop", 1), "sum")], device=True,
                   use_pallas=False, plan_headroom=1.0)
    view = sess.snapshot()
    before = np.asarray(view.run()[0])
    rng = np.random.default_rng(42)
    sess.update(mixed(sess.graph, rng, 5, 2))
    assert sess.version == 1 and view.version == 0
    assert np.array_equal(np.asarray(view.run()[0]), before)
    # the head moved on
    head = np.asarray(sess.run()[0])
    ref = brute_force(sess.graph, sess.compiled.groups[0].window,
                      sess.graph.attrs["val"], "sum")
    assert np.array_equal(head, ref.astype(np.float32))


# ------------------- sharded serving (1-device mesh) -------------------- #
def test_sharded_run_many_single_launch():
    """ShardedSession.run_many rides the batched values axis: one launch
    per group for the whole [B, n] bucket, no recompiles on replay, rows
    bit-identical to per-row run()."""
    from repro.distributed import window_runtime as wr

    g = int_graph(250, 3.0, seed=51)
    specs = [QuerySpec(("khop", 1), a) for a in ("sum", "min", "avg")]
    mesh = jax.make_mesh((1,), ("data",))
    sess = Session(g, specs, mesh=mesh, plan_headroom=1.0)
    rng = np.random.default_rng(52)
    vb = np.stack([int_vec(rng, g.n) for _ in range(5)])
    outs = sess.run_many(vb)  # warm the [n, B] executable
    per_row = [np.asarray(sess.run(values=v)) for v in vb]  # warm [n]
    c0 = wr.query_cache_size()
    outs = sess.run_many(vb)
    assert wr.query_cache_size() == c0  # replay, no recompile
    for si in range(len(specs)):
        assert outs[si].shape == (5, g.n)
        for b in range(5):
            assert np.array_equal(outs[si][b], per_row[b][si]), (si, b)
    # the service coalesces sharded traffic the same way
    svc = WindowService(sess, bucket=4)
    t = svc.submit(0, vertex=3, values=vb[0])
    svc.flush()
    assert t.result == per_row[0][0][3]


def test_sharded_patch_compaction_keeps_stream_patch_only():
    """Delete-dominated sharded stream: once the garbage-block fraction
    crosses ``compact_garbage`` the patcher re-packs pass-1 shards in
    place (no rebuild, no recompile), and answers stay exact."""
    from repro.distributed import window_runtime as wr

    g = int_graph(400, 5.0, seed=61)
    w = ("khop", 1)
    mesh = jax.make_mesh((1,), ("data",))
    sess = Session(
        g, [QuerySpec(w, "sum"), QuerySpec(w, "count")], mesh=mesh,
        plan_headroom=1.0, compact_garbage=0.02,
        policy=StalenessPolicy(max_link_ratio=1e9, max_block_ratio=1e9,
                               max_garbage_ratio=0.99),
    )
    sess.run()
    cache0 = wr.query_cache_size()
    rng = np.random.default_rng(62)
    state = next(iter(sess._states.values()))
    for step in range(8):
        g_cur = sess.graph
        ei = rng.choice(g_cur.n_edges, 5, replace=False)
        rep = next(iter(sess.update(
            UpdateBatch.deletes(g_cur.src[ei], g_cur.dst[ei])).values()))
        assert not rep["plan_rebuilt"], (step, rep)
        assert 0 < rep["patch_bytes"] < rep["full_plan_bytes"]
        got = np.asarray(sess.run()[0])
        ref = brute_force(sess.graph, state.window,
                          sess.graph.attrs["val"], "sum")
        assert np.array_equal(got, ref.astype(np.float32)), step
    assert state.plan.stats.get("p1_compactions", 0) >= 1
    assert state.plan.stats.get("rebuilds", 0) == 0
    assert wr.query_cache_size() == cache0  # compaction never retraced
    assert state.plan.stats["version"] == 8  # one patch per batch
    # the ledger of device-dropped garbage rows exists, so later batches
    # only ship groups with FRESH garbage instead of recompacting all
    assert len(state.plan.stats["p1_compacted_ids"]) > 0
    # a batch touching no blocks ships no pass-1 groups despite the index
    # still being above the garbage threshold (ledger prevents re-shipping)
    from repro.distributed.window_runtime import patch_sharded_plan

    before = state.plan.stats.get("p1_compactions", 0)
    replayed = patch_sharded_plan(state.plan, state.index,
                                  np.empty(0, np.int64),
                                  compact_garbage=0.02)
    assert replayed.stats.get("p1_compactions", 0) == before


def test_sharded_compaction_default_fires_before_policy_rebuild():
    """The sharded compaction is shape-stable, so its default threshold
    must sit BELOW the StalenessPolicy garbage rebuild threshold —
    otherwise the policy's full rebuild always wins and the patch-only
    promise of per-shard compaction is unreachable with default kwargs."""
    import inspect

    from repro.distributed.window_runtime import (
        ShardedStreamState,
        patch_sharded_plan,
    )

    policy_thresh = StalenessPolicy().max_garbage_ratio
    for fn in (ShardedStreamState.__init__, patch_sharded_plan):
        default = inspect.signature(fn).parameters["compact_garbage"].default
        assert default < policy_thresh, fn


# ------------------- sharded serving (multi-device) --------------------- #
@pytest.mark.sharded
def test_service_over_sharded_session_multi_device():
    """2-shard mesh (subprocess — device count must be set before jax
    initializes): the service's coalesced bucket rides ONE sharded launch,
    point reads hit the affected-owner cache across updates, and every
    answer matches the oracle."""
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
            import numpy as np, jax
            from repro.core.api import QuerySpec, Session
            from repro.core.query import brute_force
            from repro.core.updates import UpdateBatch
            from repro.distributed import window_runtime as wr
            from repro.graphs.generators import erdos_renyi
            from repro.serve import WindowService

            mesh = jax.make_mesh((2,), ("data",))
            rng = np.random.default_rng(71)
            g = erdos_renyi(150, 3.0, directed=False, seed=71)
            g = g.with_attr("val", rng.integers(0, 50, g.n).astype(np.float64))
            specs = [QuerySpec(("khop", 1), a) for a in ("sum", "min")]
            sess = Session(g, specs, mesh=mesh, plan_headroom=1.0)
            svc = WindowService(sess, bucket=4)

            vb = rng.integers(0, 50, size=(3, g.n)).astype(np.float64)
            ts = [svc.submit(0, values=vb[i]) for i in range(3)]
            svc.flush()
            launches0 = svc.batched_launches
            assert launches0 == 1, launches0  # one coalesced sharded launch
            for i, t in enumerate(ts):
                ref = brute_force(g, specs[0].window, vb[i], "sum")
                assert np.array_equal(np.asarray(t.result),
                                      ref.astype(np.float32)), i

            # update stream + cached point reads
            for step in range(3):
                s = rng.integers(0, g.n, 4).astype(np.int32)
                d = rng.integers(0, g.n, 4).astype(np.int32)
                ok = (s != d) & ~svc.session.graph.contains_edges(s, d)
                svc.update(UpdateBatch.inserts(s[ok], d[ok]))
                vals = svc.session.graph.attrs["val"]
                refs = [brute_force(svc.session.graph, sp.window, vals,
                                    sp.agg) for sp in specs]
                for si in range(2):
                    for v in (1, 7, 42):
                        got = svc.query(si, vertex=v)
                        assert got == np.float32(refs[si][v]), (step, si, v)
            assert svc.point_hits > 0
            print("SERVICE_SHARDED_OK")
        """)],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
             "HOME": "/root"},
        cwd="/root/repo",
    )
    assert "SERVICE_SHARDED_OK" in r.stdout, (
        r.stdout[-2000:] + r.stderr[-2000:])
