"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness asserts (assignment requirement f)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_arch
from repro.models import gnn as G
from repro.models import moe as MoE
from repro.models import recsys as R
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def _finite(tree):
    return all(np.isfinite(np.asarray(x)).all() for x in jax.tree_util.tree_leaves(tree))


LM_ARCHS = ["minitron-4b", "qwen3-0.6b", "minitron-8b", "grok-1-314b", "qwen2-moe-a2.7b"]


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_smoke_train_step(name):
    arch = get_arch(name)
    cfg = arch.smoke_cfg
    mod = MoE if isinstance(cfg, MoE.MoEConfig) else T
    params = mod.init(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    loss, grads = jax.value_and_grad(lambda p: mod.loss_fn(p, batch, cfg))(params)
    assert np.isfinite(float(loss)) and _finite(grads)
    logits = mod.forward(params, toks, cfg)
    logits = logits[0] if isinstance(logits, tuple) else logits
    assert logits.shape == (2, 16, cfg.vocab)
    assert _finite(logits)


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_smoke_serve(name):
    arch = get_arch(name)
    cfg = arch.smoke_cfg
    mod = MoE if isinstance(cfg, MoE.MoEConfig) else T
    params = mod.init(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab)
    kv, logits = mod.prefill(params, toks, cfg)
    assert logits.shape == (2, cfg.vocab) and _finite(logits)
    kvpad = {k: jnp.pad(v, ((0, 0),) * 3 + ((0, 4), (0, 0))) for k, v in kv.items()}
    nxt = jnp.argmax(logits, -1)
    logits2, kv2 = mod.decode_step(params, nxt, kvpad, 8, cfg)
    assert logits2.shape == (2, cfg.vocab) and _finite(logits2)
    # decode consistency vs full forward
    full = mod.forward(params, jnp.concatenate([toks, nxt[:, None]], 1), cfg)
    full = full[0] if isinstance(full, tuple) else full
    np.testing.assert_allclose(
        np.asarray(logits2), np.asarray(full[:, -1]), atol=0.06, rtol=0.05
    )


GNN_ARCHS = ["graphsage-reddit", "meshgraphnet", "gcn-cora", "gat-cora"]


@pytest.mark.parametrize("name", GNN_ARCHS)
def test_gnn_smoke_train_step(name):
    arch = get_arch(name)
    cfg = arch.smoke_cfg
    n, e = 40, 120
    rng = np.random.default_rng(7)
    es = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    ed = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    feats = jnp.asarray(rng.normal(size=(n, cfg.d_in)), jnp.float32)

    if cfg.kind == "gcn":
        params = G.gcn_init(KEY, cfg)
        fwd = lambda p: G.gcn_forward(p, feats, es, ed, jnp.full((e,), 0.1), n, cfg)
    elif cfg.kind == "sage":
        params = G.sage_init(KEY, cfg)
        fwd = lambda p: G.sage_forward(p, feats, es, ed, n, cfg)
    elif cfg.kind == "gat":
        params = G.gat_init(KEY, cfg)
        fwd = lambda p: G.gat_forward(p, feats, es, ed, n, cfg)
    else:
        params = G.mgn_init(KEY, cfg)
        ef = jnp.asarray(rng.normal(size=(e, 3)), jnp.float32)
        fwd = lambda p: G.mgn_forward(p, feats, ef, es, ed, n, cfg)

    out = fwd(params)
    assert out.shape == (n, cfg.d_out) and _finite(out)
    loss, grads = jax.value_and_grad(lambda p: jnp.mean(jnp.square(fwd(p))))(params)
    assert np.isfinite(float(loss)) and _finite(grads)


def test_fm_smoke_train_step():
    arch = get_arch("fm")
    cfg = arch.smoke_cfg
    params = R.init(KEY, cfg)
    rng = np.random.default_rng(9)
    batch = {
        "x": jnp.asarray(rng.integers(0, 2**30, (32, cfg.n_fields)), jnp.int32),
        "y": jnp.asarray(rng.random(32) < 0.3, jnp.float32),
    }
    loss, grads = jax.value_and_grad(lambda p: R.loss_fn(p, batch, cfg))(params)
    assert np.isfinite(float(loss)) and _finite(grads)
    scores = R.retrieval_scores(params, batch["x"][:1], jnp.arange(100), cfg)
    assert scores.shape == (100,) and _finite(scores)


def test_fm_pallas_path_matches():
    arch = get_arch("fm")
    cfg = arch.smoke_cfg
    params = R.init(KEY, cfg)
    x = jnp.asarray(np.random.default_rng(3).integers(0, 999, (16, cfg.n_fields)), jnp.int32)
    a = R.forward(params, x, cfg, use_pallas_fm=False)
    b = R.forward(params, x, cfg, use_pallas_fm=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_all_archs_registered():
    names = ARCHS()
    assert len(names) == 11  # 10 assigned + paper-gwq
    for n in names:
        arch = get_arch(n)
        assert arch.shapes, n


def test_param_counts_match_public_configs():
    """Sanity: derived parameter counts are in the right ballpark."""
    assert 3.5e9 < get_arch("minitron-4b").model_cfg.n_params() < 6.5e9
    assert 0.4e9 < get_arch("qwen3-0.6b").model_cfg.n_params() < 0.9e9
    assert 7e9 < get_arch("minitron-8b").model_cfg.n_params() < 10.5e9
    g = get_arch("grok-1-314b").model_cfg
    assert 280e9 < g.n_params() < 340e9
    q = get_arch("qwen2-moe-a2.7b").model_cfg
    assert 10e9 < q.n_params() < 20e9  # 14.3B total
    assert 2e9 < q.n_active_params() < 4e9  # ~2.7B active
