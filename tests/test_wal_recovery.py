"""WAL durability + crash recovery + read replicas (ISSUE 6).

Contracts:

* **codec** — ``UpdateBatch`` byte round-trips exactly (structural ops,
  timestamps, multi-dtype attribute edits, empty batches);
* **WAL** — append-before-apply records survive a crash: the valid prefix
  replays exactly, a torn tail is ignored (and truncated on resume), and
  version numbering resumes monotonically;
* **crash recovery** — a session killed after K batches is reconstructed
  bit-identically by replaying the WAL into a fresh ``Session`` — for
  every engine path and every registered aggregate, against the
  set-evaluation oracle, with zero recompiles across >= 20 streamed
  batches (compile-counter-asserted);
* **replica lag** — a follower tailing the log serves its pinned version
  while behind, then catches up and flips to the leader's exact vectors.

Attribute values are small integers: every f32 monoid reduce is exact, so
"bit-identical" is asserted with ``array_equal``, not ``allclose``.
"""

import os

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import api  # noqa: E402
from repro.core.aggregates import AGGREGATES  # noqa: E402
from repro.core.api import QuerySpec, Session  # noqa: E402
from repro.core.query import brute_force  # noqa: E402
from repro.core.updates import (  # noqa: E402
    AttrEdit,
    UpdateBatch,
    decode_update_batch,
    encode_update_batch,
)
from repro.core.windows import KHopWindow, TopologicalWindow  # noqa: E402
from repro.graphs.generators import erdos_renyi, random_dag  # noqa: E402
from repro.serve import (  # noqa: E402
    AsyncWindowService,
    ReadReplica,
    WindowService,
    WriteAheadLog,
    read_wal_records,
)

from test_updates import mixed  # noqa: E402  (stream helpers)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st


def int_graph(n, deg, seed, directed=False, dag=False):
    if dag:
        g = random_dag(n, deg, seed=seed)
    else:
        g = erdos_renyi(n, deg, directed=directed, seed=seed)
    vals = np.random.default_rng(seed + 1).integers(0, 50, g.n)
    return g.with_attr("val", vals.astype(np.float64))


# ---------------------------------------------------------------------- #
#  UpdateBatch codec
# ---------------------------------------------------------------------- #
def _assert_batch_equal(a: UpdateBatch, b: UpdateBatch):
    assert np.array_equal(a.src, b.src) and a.src.dtype == b.src.dtype
    assert np.array_equal(a.dst, b.dst) and a.dst.dtype == b.dst.dtype
    assert np.array_equal(a.op, b.op)
    if a.ts is None:
        assert b.ts is None
    else:
        assert np.array_equal(a.ts, b.ts)
    assert len(a.attr_edits) == len(b.attr_edits)
    for ea, eb in zip(a.attr_edits, b.attr_edits):
        assert ea.name == eb.name
        assert np.array_equal(ea.vertices, eb.vertices)
        assert np.array_equal(ea.values, eb.values)
        assert ea.values.dtype == eb.values.dtype


def test_codec_roundtrip_structural_and_attrs():
    b = UpdateBatch(
        np.array([1, 2, 3], np.int32), np.array([4, 5, 6], np.int32),
        np.array([1, -1, 1], np.int8), np.array([0.5, 1.5, 2.5]),
        attr_edits=(
            AttrEdit("val", [0, 7], np.array([9.0, 3.0])),
            AttrEdit("flag", [2], np.array([1], np.int32)),
        ),
    )
    _assert_batch_equal(b, decode_update_batch(encode_update_batch(b)))
    _assert_batch_equal(b, UpdateBatch.from_bytes(b.to_bytes()))


def test_codec_roundtrip_empty_and_no_ts():
    empty = UpdateBatch.inserts([], [])
    _assert_batch_equal(empty, UpdateBatch.from_bytes(empty.to_bytes()))
    plain = UpdateBatch.deletes([3], [4])
    assert plain.ts is None
    _assert_batch_equal(plain, UpdateBatch.from_bytes(plain.to_bytes()))


def test_codec_rejects_corruption():
    data = UpdateBatch.inserts([1], [2]).to_bytes()
    with pytest.raises(ValueError):
        decode_update_batch(b"XXXX" + data[4:])
    with pytest.raises(ValueError):
        decode_update_batch(data[:-2])
    with pytest.raises(ValueError):
        decode_update_batch(data + b"\x00")


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(min_value=0, max_value=40),
    with_ts=st.booleans(),
    n_edits=st.integers(min_value=0, max_value=3),
)
def test_codec_roundtrip_random(m, with_ts, n_edits):
    rng = np.random.default_rng(m * 7 + n_edits * 131 + int(with_ts))
    edits = tuple(
        AttrEdit(f"a{i}", rng.integers(0, 100, 5),
                 rng.integers(-9, 9, 5).astype(
                     [np.float64, np.int32, np.float32][i % 3]))
        for i in range(n_edits)
    )
    b = UpdateBatch(
        rng.integers(0, 100, m).astype(np.int32),
        rng.integers(0, 100, m).astype(np.int32),
        rng.choice([np.int8(1), np.int8(-1)], m),
        rng.random(m) if with_ts else None,
        edits,
    )
    _assert_batch_equal(b, UpdateBatch.from_bytes(b.to_bytes()))


# ---------------------------------------------------------------------- #
#  WAL file behavior
# ---------------------------------------------------------------------- #
def test_wal_append_replay_and_resume(tmp_path):
    path = tmp_path / "w.wal"
    batches = [UpdateBatch.inserts([i], [i + 1]) for i in range(5)]
    with WriteAheadLog(path) as wal:
        for b in batches:
            wal.append(b)
        assert wal.last_version == 5
    records, end = read_wal_records(path)
    assert [v for v, _ in records] == [1, 2, 3, 4, 5]
    for (_, got), want in zip(records, batches):
        _assert_batch_equal(got, want)
    # resume continues version numbering
    with WriteAheadLog(path) as wal:
        assert wal.last_version == 5
        assert wal.append(UpdateBatch.deletes([0], [1])) == 6
    assert [v for v, _ in read_wal_records(path)[0]] == [1, 2, 3, 4, 5, 6]


def test_wal_torn_tail_is_ignored_and_truncated(tmp_path):
    path = tmp_path / "w.wal"
    with WriteAheadLog(path) as wal:
        wal.append(UpdateBatch.inserts([1], [2]))
        wal.append(UpdateBatch.inserts([3], [4]))
    size = os.path.getsize(path)
    with open(path, "ab") as f:  # simulate a crash mid-append
        f.write(b"WREC" + b"\x07" * 11)
    records, end = read_wal_records(path)
    assert len(records) == 2 and end == size
    # resume truncates the torn tail and keeps appending cleanly
    with WriteAheadLog(path) as wal:
        assert os.path.getsize(path) == size
        wal.append(UpdateBatch.inserts([5], [6]))
    assert len(read_wal_records(path)[0]) == 3


def test_wal_offset_tailing(tmp_path):
    path = tmp_path / "w.wal"
    wal = WriteAheadLog(path)
    wal.append(UpdateBatch.inserts([1], [2]), sync=True)
    first, off1 = read_wal_records(path)
    assert len(first) == 1
    wal.append(UpdateBatch.inserts([3], [4]), sync=True)
    more, off2 = read_wal_records(path, off1)
    assert len(more) == 1 and off2 > off1
    assert more[0][0] == 2
    # polling at the tail is empty, not an error
    assert read_wal_records(path, off2)[0] == []
    wal.close()


# ---------------------------------------------------------------------- #
#  Crash-recovery differential suite
# ---------------------------------------------------------------------- #
ENGINE_SESSIONS = [
    pytest.param({"device": True, "use_pallas": False}, False,
                 id="dbindex-device"),
    pytest.param({"device": False}, False, id="dbindex-host"),
    pytest.param({"device": True, "use_pallas": False}, True,
                 id="iindex-topological"),
]


@pytest.mark.parametrize("session_kw,topo", ENGINE_SESSIONS)
def test_crash_recovery_bit_identical_all_aggregates(tmp_path, session_kw,
                                                     topo):
    """Kill after K batches; WAL replay must reproduce the live session's
    results bit-identically for every registered aggregate, and both must
    match the set-evaluation oracle for the exact-monoid aggregates."""
    g = int_graph(150, 3.0, seed=21, dag=topo)
    window = TopologicalWindow() if topo else KHopWindow(2)
    aggs = sorted(AGGREGATES)
    specs = [QuerySpec(window, a) for a in aggs]
    path = tmp_path / "svc.wal"
    rng = np.random.default_rng(22)

    live = Session(g, specs, **session_kw)
    K = 8
    with WriteAheadLog(path) as wal:
        for _ in range(K):
            b = mixed(live.graph, rng, 4, 2, dag=topo)
            wal.append(b, version=live.version + 1)
            live.update(b)
    # "crash": the live session object is all we have to compare against;
    # a fresh process would re-run exactly this constructor + replay
    restored = Session.restore_from_wal(g, specs, path, **session_kw)
    assert restored.version == live.version == K

    vals = np.asarray(live.graph.attrs["val"], np.float64)
    out_live = live.run()
    out_rest = restored.run()
    for i, spec in enumerate(specs):
        a, b = np.asarray(out_live[i]), np.asarray(out_rest[i])
        assert np.array_equal(a, b), f"restore mismatch for {spec.agg}"
        if spec.agg in ("sum", "count", "min", "max"):
            oracle = brute_force(live.graph, window, vals, spec.agg,
                                 dtype=np.float32)
            assert np.array_equal(a, oracle), f"oracle mismatch {spec.agg}"


def test_recovery_zero_recompiles_across_20_batches(tmp_path):
    """The recovered session replays >= 20 batches through the same
    incremental patching as the live one: the fused executable cache must
    not grow during replay (zero recompiles), and the recovered results
    stay bit-identical to the uninterrupted session's."""
    from repro.core import engine_jax as ej

    g = int_graph(200, 2.0, seed=31)
    specs = [QuerySpec(KHopWindow(2), "sum"), QuerySpec(KHopWindow(2), "min")]
    path = tmp_path / "svc.wal"
    rng = np.random.default_rng(32)

    live = Session(g, specs, use_pallas=False, plan_headroom=1.0)
    live.run()  # compile once
    with WriteAheadLog(path) as wal:
        for _ in range(22):
            b = mixed(live.graph, rng, 3, 1)
            wal.append(b)
            live.update(b)
    live_out = live.run()  # serve at head — compiles the head shape once

    c0 = ej.query_dbindex_multi._cache_size()
    restored = Session.restore_from_wal(
        g, specs, path, use_pallas=False, plan_headroom=1.0)
    out = restored.run()
    assert ej.query_dbindex_multi._cache_size() == c0, \
        "WAL replay recompiled the fused executable"
    for i in range(len(specs)):
        assert np.array_equal(np.asarray(out[i]), np.asarray(live_out[i]))


def test_restore_upto_version_point_in_time(tmp_path):
    g = int_graph(100, 2.5, seed=41)
    specs = [QuerySpec(KHopWindow(1), "sum")]
    path = tmp_path / "svc.wal"
    rng = np.random.default_rng(42)

    live = Session(g, specs, use_pallas=False)
    snapshots = {}
    with WriteAheadLog(path) as wal:
        for i in range(6):
            b = mixed(live.graph, rng, 3, 1)
            wal.append(b)
            live.update(b)
            snapshots[live.version] = np.asarray(live.run()[0])
    for v in (2, 4, 6):
        at_v = Session.restore_from_wal(g, specs, path, upto_version=v,
                                        use_pallas=False)
        assert at_v.version == v
        assert np.array_equal(np.asarray(at_v.run()[0]), snapshots[v])


def test_async_service_wal_covers_everything_served(tmp_path):
    """Append-before-apply through the service: after any number of
    updates, a recovery from the WAL answers exactly like the live
    service — nothing applied is ever missing from the log."""
    g = int_graph(120, 2.5, seed=51)
    specs = [QuerySpec(KHopWindow(2), "sum")]
    path = tmp_path / "svc.wal"
    rng = np.random.default_rng(52)

    svc = AsyncWindowService(Session(g, specs, use_pallas=False), wal=path)
    for _ in range(5):
        svc.update(mixed(svc.session.graph, rng, 3, 1))
    live_vec = svc.query(0)
    svc.close()

    restored = Session.restore_from_wal(g, specs, path, use_pallas=False)
    assert restored.version == 5
    assert np.array_equal(WindowService(restored).query(0), live_vec)


# ---------------------------------------------------------------------- #
#  Read replicas
# ---------------------------------------------------------------------- #
def test_replica_lag_pinned_then_catch_up(tmp_path):
    """The pinned follower serves the old version bit-stably while the
    leader streams ahead; catch_up applies the backlog and flip publishes
    the leader's exact vectors."""
    g = int_graph(120, 2.5, seed=61)
    specs = [QuerySpec(KHopWindow(2), "sum"), QuerySpec(KHopWindow(2), "min")]
    path = tmp_path / "svc.wal"
    rng = np.random.default_rng(62)

    leader = AsyncWindowService(Session(g, specs, use_pallas=False),
                                wal=path)
    replica = ReadReplica(g, specs, path, use_pallas=False)
    v0_sum = replica.query(0)

    for _ in range(4):
        leader.update(mixed(leader.session.graph, rng, 3, 1))
    leader.wal.sync()

    # poll applies at the head; reads stay pinned at the published version
    applied = replica.poll()
    assert applied == 4
    assert replica.version == 0 and replica.head_version == 4
    assert replica.lag["unpublished_versions"] == 4
    assert np.array_equal(replica.query(0), v0_sum), \
        "pinned replica must keep serving its published version"

    replica.flip()
    assert replica.version == 4
    for si in (0, 1):
        assert np.array_equal(replica.query(si), leader.query(si)), \
            "caught-up replica must match the leader bit-for-bit"

    # incremental tail: more leader traffic, catch_up in one call
    leader.update(mixed(leader.session.graph, rng, 2, 1))
    leader.wal.sync()
    assert replica.catch_up() == 1
    assert np.array_equal(replica.query(0), leader.query(0))
    assert replica.lag["behind_bytes"] == 0
    leader.close()


def test_replica_upto_version_holds_then_resumes(tmp_path):
    g = int_graph(80, 2.0, seed=71)
    specs = [QuerySpec(KHopWindow(1), "sum")]
    path = tmp_path / "svc.wal"
    rng = np.random.default_rng(72)

    live = Session(g, specs, use_pallas=False)
    with WriteAheadLog(path) as wal:
        for _ in range(6):
            b = mixed(live.graph, rng, 2, 1)
            wal.append(b)
            live.update(b)

    replica = ReadReplica(g, specs, path, use_pallas=False)
    assert replica.poll(upto_version=3) == 3
    assert replica.head_version == 3
    # the offset stopped at the record boundary: resuming applies the rest
    assert replica.poll() == 3
    assert replica.head_version == 6
    replica.flip()
    assert np.array_equal(replica.query(0), np.asarray(live.run()[0]))
