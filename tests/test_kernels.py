"""Pallas kernel sweeps vs ref.py oracles (interpret mode on CPU).

Shapes are swept to cover the boundary cases the tile plans create:
segments straddling tile edges, empty segments, singleton blocks, D not a
lane multiple, empty inputs.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels.segment_reduce.ops import build_tile_plan, segment_sum  # noqa: E402
from repro.kernels.segment_reduce.ref import segment_reduce_ref  # noqa: E402


RNG = np.random.default_rng(0)


@pytest.mark.parametrize(
    "n,m,s,d",
    [
        (50, 200, 17, 1),
        (100, 1000, 100, 4),
        (1000, 5000, 600, 8),  # multiple output tiles
        (300, 700, 513, 3),  # segments straddle the TS=512 boundary
        (64, 0, 10, 4),  # empty input
        (128, 512, 1, 2),  # single segment
        (2000, 3000, 1200, 130),  # D > 128 lanes
    ],
)
def test_segment_sum_sweep(n, m, s, d):
    vals = RNG.normal(size=(n, d)).astype(np.float32)
    seg = np.sort(RNG.integers(0, s, m)).astype(np.int32)
    gidx = RNG.integers(0, n, m).astype(np.int32)
    plan = build_tile_plan(gidx, seg, s)
    out = segment_sum(plan, jnp.asarray(vals))
    ref = segment_reduce_ref(jnp.asarray(vals), jnp.asarray(gidx),
                             jnp.asarray(seg), s, "add")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32])
def test_segment_sum_dtypes(dtype):
    vals = (RNG.normal(size=(100, 4)) * 10).astype(dtype)
    seg = np.sort(RNG.integers(0, 30, 400)).astype(np.int32)
    gidx = RNG.integers(0, 100, 400).astype(np.int32)
    plan = build_tile_plan(gidx, seg, 30)
    out = segment_sum(plan, jnp.asarray(vals))
    ref = segment_reduce_ref(
        jnp.asarray(vals, jnp.float32), jnp.asarray(gidx), jnp.asarray(seg), 30, "add"
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_segment_sum_empty_segments_are_identity():
    # segments 3..9 get no rows -> exact zeros
    seg = np.array([0, 0, 1, 2, 10, 10], np.int32)
    gidx = np.arange(6, dtype=np.int32)
    vals = np.ones((6, 2), np.float32)
    plan = build_tile_plan(gidx, seg, 12)
    out = np.asarray(segment_sum(plan, jnp.asarray(vals)))
    assert np.allclose(out[3:10], 0)
    assert np.allclose(out[0], 2) and np.allclose(out[10], 2)


def test_segment_min_max_fallback():
    from repro.kernels.segment_reduce.ops import segment_reduce

    vals = RNG.normal(size=(80, 3)).astype(np.float32)
    seg = np.sort(RNG.integers(0, 20, 200)).astype(np.int32)
    gidx = RNG.integers(0, 80, 200).astype(np.int32)
    for op in ("min", "max"):
        out = segment_reduce(jnp.asarray(vals), jnp.asarray(gidx),
                             jnp.asarray(seg), 20, op=op)
        ref = segment_reduce_ref(jnp.asarray(vals), jnp.asarray(gidx),
                                 jnp.asarray(seg), 20, op)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref))


# ------------------------------ bitset ------------------------------- #
@pytest.mark.parametrize("n,deg,k", [(200, 4.0, 1), (300, 6.0, 2), (150, 3.0, 3)])
def test_bitset_expand_sweep(n, deg, k):
    from repro.graphs.generators import erdos_renyi
    from repro.kernels.bitset_expand.ops import build_expand_plan, khop_reach
    from repro.kernels.bitset_expand.ref import khop_reach_ref

    g = erdos_renyi(n, deg, seed=int(n + k))
    src = np.concatenate([g.src, g.dst])
    dst = np.concatenate([g.dst, g.src])
    order = np.argsort(dst, kind="stable")
    es, ed = src[order], dst[order]
    plan = build_expand_plan(es, ed, n, tm=256, ts=256)
    sources = np.arange(min(96, n), dtype=np.int32)
    got = np.asarray(khop_reach(plan, n, sources, k))
    reach0 = np.zeros((n, 128), dtype=np.uint32)
    cols = np.arange(sources.size)
    reach0[sources, cols // 32] |= np.uint32(1) << (cols % 32).astype(np.uint32)
    ref = khop_reach_ref(reach0, es, ed, n, k)
    assert np.array_equal(got, ref)


def test_bitset_matches_host_bfs():
    from repro.core.windows import khop_window_single
    from repro.graphs.generators import erdos_renyi
    from repro.kernels.bitset_expand.ops import build_expand_plan, khop_reach

    g = erdos_renyi(250, 5.0, seed=42)
    src = np.concatenate([g.src, g.dst])
    dst = np.concatenate([g.dst, g.src])
    order = np.argsort(dst, kind="stable")
    plan = build_expand_plan(src[order], dst[order], g.n, tm=256, ts=256)
    got = np.asarray(khop_reach(plan, g.n, np.arange(64, dtype=np.int32), 2))
    for v in (0, 17, 63):
        members = np.flatnonzero((got[:, v // 32] >> np.uint32(v % 32)) & 1)
        assert np.array_equal(members, khop_window_single(g, 2, v))


# -------------------------------- fm --------------------------------- #
@pytest.mark.parametrize("b,f,k", [(64, 39, 10), (100, 8, 16), (256, 5, 3)])
def test_fm_interaction_sweep(b, f, k):
    from repro.kernels.fm_interaction.fm_interaction import fm_interaction
    from repro.kernels.fm_interaction.ref import fm_interaction_ref

    emb = jnp.asarray(RNG.normal(size=(b, f, k)), jnp.float32)
    out = fm_interaction(emb, interpret=True)
    ref = fm_interaction_ref(emb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_fm_equals_explicit_pairwise():
    """sum-square trick == O(F^2) pairwise dots (Rendle's identity)."""
    from repro.kernels.fm_interaction.ref import fm_interaction_ref

    emb = RNG.normal(size=(10, 6, 4)).astype(np.float32)
    ref = np.asarray(fm_interaction_ref(jnp.asarray(emb)))
    explicit = np.zeros(10)
    for i in range(6):
        for j in range(i + 1, 6):
            explicit += np.sum(emb[:, i] * emb[:, j], axis=-1)
    np.testing.assert_allclose(ref, explicit, rtol=1e-4, atol=1e-4)


# ---------------------------- attention ------------------------------ #
@pytest.mark.parametrize(
    "b,hq,hkv,s,d,bq,bk",
    [(1, 4, 2, 256, 64, 128, 128), (2, 2, 1, 128, 128, 64, 64),
     (1, 8, 8, 128, 32, 64, 64)],
)
def test_flash_attention_sweep(b, hq, hkv, s, d, bq, bk):
    from repro.kernels.flash_attention.flash_attention import flash_attention
    from repro.kernels.flash_attention.ref import mha_ref

    q = jnp.asarray(RNG.normal(size=(b, hq, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
    ref = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_flash_jnp_matches_naive():
    from repro.kernels.flash_attention.ref import mha_ref
    from repro.models.attention import flash_jnp

    q = jnp.asarray(RNG.normal(size=(2, 4, 256, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 2, 256, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 2, 256, 32)), jnp.float32)
    out = flash_jnp(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    ref = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_decode_matches_full_attention():
    from repro.kernels.flash_attention.ref import decode_ref, mha_ref

    b, hq, hkv, s, d = 2, 6, 2, 32, 16
    q = jnp.asarray(RNG.normal(size=(b, hq, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    full = mha_ref(q, k, v, causal=True)
    dec = decode_ref(q[:, :, -1], k, v, s)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, :, -1]),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_decode():
    from repro.kernels.flash_attention.ref import decode_ref, mha_ref

    b, h, s, d = 1, 2, 64, 16
    q = jnp.asarray(RNG.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, h, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, h, s, d)), jnp.float32)
    full = mha_ref(q, k, v, causal=True, local_window=16)
    dec = decode_ref(q[:, :, -1], k, v, s, window=16)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, :, -1]),
                               rtol=2e-3, atol=2e-3)
