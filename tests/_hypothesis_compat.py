"""Offline-safe stand-in for the `hypothesis` subset this suite uses.

The container image has no network, so `pip install hypothesis` is not an
option; the tier-1 suite must still collect and run.  Test modules import
the real library when present and fall back to this shim:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, strategies as st

Differences from real hypothesis (deliberate, documented):

* **No shrinking** — a failing example is reported as-is.
* **Deterministic** — the RNG is seeded from the test's qualified name, so
  every run draws the same examples (CI-reproducible by construction).
* **Boundary probing** — the first examples pin strategy bounds (hypothesis
  probes corners too; random-only sampling would miss off-by-one bugs).
* Only the strategies this repo needs: ``integers``, ``floats``,
  ``booleans``, ``sampled_from``, ``just``, ``lists``, ``tuples``.
"""

from __future__ import annotations

import random
from typing import Any, List, Sequence


# ----------------------------- strategies ----------------------------- #
class SearchStrategy:
    """A value generator: ``example(rng, i)`` draws the i-th example."""

    def example(self, rng: random.Random, i: int = 0) -> Any:
        raise NotImplementedError


class _Integers(SearchStrategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = -(2**31) if min_value is None else int(min_value)
        self.hi = 2**31 - 1 if max_value is None else int(max_value)
        assert self.lo <= self.hi

    def example(self, rng, i=0):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _Floats(SearchStrategy):
    def __init__(self, min_value=0.0, max_value=1.0, **_):
        self.lo, self.hi = float(min_value), float(max_value)

    def example(self, rng, i=0):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return rng.uniform(self.lo, self.hi)


class _Booleans(SearchStrategy):
    def example(self, rng, i=0):
        return bool(i % 2) if i < 2 else rng.random() < 0.5


class _SampledFrom(SearchStrategy):
    def __init__(self, elements: Sequence):
        self.elements = list(elements)
        assert self.elements

    def example(self, rng, i=0):
        if i < len(self.elements):
            return self.elements[i]
        return rng.choice(self.elements)


class _Just(SearchStrategy):
    def __init__(self, value):
        self.value = value

    def example(self, rng, i=0):
        return self.value


class _Lists(SearchStrategy):
    def __init__(self, elem: SearchStrategy, min_size=0, max_size=10):
        self.elem, self.min_size, self.max_size = elem, min_size, max_size

    def example(self, rng, i=0):
        size = self.min_size if i == 0 else rng.randint(self.min_size, self.max_size)
        return [self.elem.example(rng, 2) for _ in range(size)]


class _Tuples(SearchStrategy):
    def __init__(self, *elems: SearchStrategy):
        self.elems = elems

    def example(self, rng, i=0):
        return tuple(e.example(rng, i) for e in self.elems)


class _Strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    SearchStrategy = SearchStrategy

    @staticmethod
    def integers(min_value=None, max_value=None):
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **kw):
        return _Floats(min_value, max_value, **kw)

    @staticmethod
    def booleans():
        return _Booleans()

    @staticmethod
    def sampled_from(elements):
        return _SampledFrom(elements)

    @staticmethod
    def just(value):
        return _Just(value)

    @staticmethod
    def lists(elem, min_size=0, max_size=10):
        return _Lists(elem, min_size, max_size)

    @staticmethod
    def tuples(*elems):
        return _Tuples(*elems)


strategies = _Strategies()


# --------------------------- given / settings ------------------------- #
_DEFAULT_MAX_EXAMPLES = 10


class _SettingsTag:
    def __init__(self, max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_):
        self.max_examples = int(max_examples)

    def __call__(self, f):
        f._shim_settings = self
        return f


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **kw):
    """Decorator factory: ``@settings(max_examples=..., deadline=...)``."""
    return _SettingsTag(max_examples=max_examples, deadline=deadline, **kw)


def given(*strategies_pos: SearchStrategy, **strategies_kw: SearchStrategy):
    """Run the test once per drawn example (no shrinking, deterministic).

    Works with ``@settings`` stacked above or below.  The wrapper takes no
    arguments so pytest does not mistake strategy parameters for fixtures.
    """

    def decorate(f):
        def wrapper():
            tag = getattr(wrapper, "_shim_settings", None) or getattr(
                f, "_shim_settings", None
            )
            n = tag.max_examples if tag else _DEFAULT_MAX_EXAMPLES
            rng = random.Random(f.__qualname__)
            for i in range(n):
                args = [s.example(rng, i) for s in strategies_pos]
                kwargs = {k: s.example(rng, i) for k, s in strategies_kw.items()}
                try:
                    f(*args, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i}: args={args} kwargs={kwargs}: {e}"
                    ) from e

        wrapper.__name__ = f.__name__
        wrapper.__qualname__ = f.__qualname__
        wrapper.__module__ = f.__module__
        wrapper.__doc__ = f.__doc__
        return wrapper

    return decorate
