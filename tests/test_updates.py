"""Streaming updates: batched maintenance == fresh build == brute force.

Differential tests drive random insert/delete edge streams through
``update_dbindex_batch`` / ``update_iindex_batch`` and check every batch
against two independent oracles: a fresh ``build_*`` on the updated graph
and the per-vertex BFS ``brute_force``.  Covers k-hop (DBIndex) and
topological (I-Index + DBIndex) windows, insertions and deletions, the
batch-application semantics, and the staleness-driven reorganize policy.
Runs fully offline (the property tests use the `_hypothesis_compat` shim
when hypothesis is absent).
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: use the local shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import updates as U
from repro.core.dbindex import build_dbindex
from repro.core.graph import Graph
from repro.core.iindex import build_iindex
from repro.core.query import brute_force
from repro.core.streaming import StalenessPolicy, StreamingEngine
from repro.core.updates import UpdateBatch
from repro.core.windows import KHopWindow, TopologicalWindow
from repro.graphs.generators import erdos_renyi, random_dag, with_random_attrs

AGGS = ("sum", "count", "avg")


# --------------------------- stream helpers --------------------------- #
def random_insert_batch(g: Graph, rng, size: int) -> UpdateBatch:
    """`size` fresh (absent, non-loop, batch-unique) edges."""
    s = rng.integers(0, g.n, size * 4).astype(np.int32)
    d = rng.integers(0, g.n, size * 4).astype(np.int32)
    ok = (s != d) & ~g.contains_edges(s, d)
    _, first = np.unique(g.edge_keys(s, d), return_index=True)
    pick = np.intersect1d(np.flatnonzero(ok), first)[:size]
    return UpdateBatch.inserts(s[pick], d[pick])


def random_delete_batch(g: Graph, rng, size: int) -> UpdateBatch:
    ei = rng.choice(g.n_edges, min(size, g.n_edges), replace=False)
    return UpdateBatch.deletes(g.src[ei], g.dst[ei])


def random_dag_insert_batch(g: Graph, rng, size: int) -> UpdateBatch:
    """Acyclicity-preserving inserts: lower topo rank -> higher."""
    order = g.topological_order()
    rank = np.empty(g.n, np.int64)
    rank[order] = np.arange(g.n)
    s = rng.integers(0, g.n, size * 6)
    d = rng.integers(0, g.n, size * 6)
    lo = np.where(rank[s] < rank[d], s, d).astype(np.int32)
    hi = np.where(rank[s] < rank[d], d, s).astype(np.int32)
    ok = (rank[lo] < rank[hi]) & ~g.contains_edges(lo, hi)
    _, first = np.unique(g.edge_keys(lo, hi), return_index=True)
    pick = np.intersect1d(np.flatnonzero(ok), first)[:size]
    return UpdateBatch.inserts(lo[pick], hi[pick])


def mixed(g, rng, n_ins, n_del, dag=False):
    ins = random_dag_insert_batch(g, rng, n_ins) if dag else random_insert_batch(g, rng, n_ins)
    return UpdateBatch.concat([ins, random_delete_batch(g, rng, n_del)])


# ------------------------- batch application -------------------------- #
def test_apply_batch_matches_sequential():
    rng = np.random.default_rng(0)
    g = erdos_renyi(60, 4.0, directed=False, seed=3)
    b = mixed(g, rng, 8, 5)
    g_batch = U.apply_batch(g, b)
    g_seq = g
    for s, t, op in zip(b.src, b.dst, b.op):
        # deletes first (apply_batch resolves them against the pre-batch list)
        if op < 0:
            g_seq = U.delete_edge(g_seq, int(s), int(t))
    for s, t, op in zip(b.src, b.dst, b.op):
        if op > 0:
            g_seq = U.insert_edge(g_seq, int(s), int(t))
    assert np.array_equal(np.sort(g_batch.edge_keys()), np.sort(g_seq.edge_keys()))


def test_apply_batch_missing_delete_raises():
    g = erdos_renyi(30, 3.0, directed=True, seed=4)
    absent = ~g.contains_edges(np.arange(29), np.arange(1, 30))
    s = int(np.flatnonzero(absent)[0])
    with pytest.raises(KeyError):
        U.apply_batch(g, UpdateBatch.deletes([s], [s + 1]))


def test_apply_batch_undirected_orientation_insensitive():
    g = Graph(n=4, src=np.array([0, 1], np.int32), dst=np.array([1, 2], np.int32),
              directed=False)
    g2 = U.apply_batch(g, UpdateBatch.deletes([1], [0]))  # reversed orientation
    assert g2.n_edges == 1 and g2.contains_edges([1], [2]).all()


def test_apply_batch_duplicate_edge_multiplicity():
    g = Graph(n=3, src=np.array([0, 0], np.int32), dst=np.array([1, 1], np.int32),
              directed=True)
    g2 = U.apply_batch(g, UpdateBatch.deletes([0], [1]))
    assert g2.n_edges == 1  # one of the two copies removed
    g3 = U.apply_batch(g, UpdateBatch.deletes([0, 0], [1, 1]))
    assert g3.n_edges == 0


def test_empty_batch_is_identity(small_undirected):
    g = small_undirected
    w = KHopWindow(1)
    idx = build_dbindex(g, w, method="emc")
    idx2, owners = U.update_dbindex_batch(idx, g, w, UpdateBatch.inserts([], []))
    assert owners.size == 0 and idx2 is idx


# ---------------------- DBIndex k-hop differential -------------------- #
@pytest.mark.parametrize("k", [1, 2])
@pytest.mark.parametrize("directed", [False, True])
def test_dbindex_khop_stream(k, directed):
    rng = np.random.default_rng(10 * k + directed)
    g = with_random_attrs(
        erdos_renyi(150, 4.0, directed=directed, seed=k), seed=k + 1
    )
    w = KHopWindow(k)
    idx = build_dbindex(g, w, method="emc")
    for step in range(4):
        b = mixed(g, rng, 12, 6)
        g = U.apply_batch(g, b)
        idx, owners = U.update_dbindex_batch(idx, g, w, b)
        assert owners.size > 0
        fresh = build_dbindex(g, w, method="emc")
        for agg in AGGS:
            ref = brute_force(g, w, g.attrs["val"], agg)
            assert np.allclose(idx.query(g.attrs["val"], agg), ref), (step, agg)
            assert np.allclose(fresh.query(g.attrs["val"], agg), ref), (step, agg)


def test_dbindex_khop_delete_only_stream():
    rng = np.random.default_rng(77)
    g = with_random_attrs(erdos_renyi(120, 5.0, directed=False, seed=9), seed=10)
    w = KHopWindow(2)
    idx = build_dbindex(g, w, method="emc")
    for step in range(3):
        b = random_delete_batch(g, rng, 15)
        g = U.apply_batch(g, b)
        idx, _ = U.update_dbindex_batch(idx, g, w, b)
        ref = brute_force(g, w, g.attrs["val"], "sum")
        assert np.allclose(idx.query(g.attrs["val"], "sum"), ref), step


# -------------------- topological windows differential ---------------- #
def test_iindex_stream():
    rng = np.random.default_rng(21)
    g = with_random_attrs(random_dag(160, 2.5, seed=11), seed=12)
    ii = build_iindex(g)
    for step in range(4):
        b = mixed(g, rng, 10, 5, dag=True)
        g = U.apply_batch(g, b)
        ii, cone = U.update_iindex_batch(ii, g, b)
        assert cone.size > 0
        fresh = build_iindex(g)
        for agg in AGGS:
            ref = brute_force(g, TopologicalWindow(), g.attrs["val"], agg)
            assert np.allclose(ii.query(g.attrs["val"], agg), ref), (step, agg)
            assert np.allclose(fresh.query(g.attrs["val"], agg), ref), (step, agg)
        # structural invariant: reconstruction still exact after updates
        for v in range(0, g.n, 37):
            from repro.core.windows import topological_window_single

            assert np.array_equal(ii.window_of(v), topological_window_single(g, v))


def test_iindex_large_cone_falls_back_to_rebuild():
    g = with_random_attrs(random_dag(80, 2.0, seed=31), seed=32)
    ii = build_iindex(g)
    order = g.topological_order()
    # edge into the topologically-first vertex's successor cone: huge cone
    s, t = int(order[0]), int(order[1])
    if g.contains_edges([s], [t]).any():
        b = UpdateBatch.deletes([s], [t])
    else:
        b = UpdateBatch.inserts([s], [t])
    g2 = U.apply_batch(g, b)
    ii2, cone = U.update_iindex_batch(ii, g2, b)
    ref = brute_force(g2, TopologicalWindow(), g2.attrs["val"], "sum")
    assert np.allclose(ii2.query(g2.attrs["val"], "sum"), ref)


def test_dbindex_topological_stream():
    rng = np.random.default_rng(41)
    g = with_random_attrs(random_dag(120, 2.0, seed=13), seed=14)
    w = TopologicalWindow()
    idx = build_dbindex(g, w, method="mc")
    for step in range(3):
        b = mixed(g, rng, 8, 4, dag=True)
        g = U.apply_batch(g, b)
        idx, owners = U.update_dbindex_batch(idx, g, w, b)
        for agg in AGGS:
            ref = brute_force(g, w, g.attrs["val"], agg)
            assert np.allclose(idx.query(g.attrs["val"], agg), ref), (step, agg)


# -------------------- affected-set batching equivalence --------------- #
def test_batched_affected_owners_cover_per_edge_union():
    g = erdos_renyi(100, 4.0, directed=True, seed=51)
    rng = np.random.default_rng(52)
    b = random_insert_batch(g, rng, 10)
    g2 = U.apply_batch(g, b)
    batched = U.affected_owners_khop_multi(g2, 3, U._khop_seeds(g2, b))
    per_edge = np.unique(
        np.concatenate(
            [U.affected_owners_khop(g2, 3, int(s), int(t))
             for s, t in zip(b.src, b.dst)]
        )
    )
    assert np.array_equal(batched, per_edge.astype(np.int32))


# ----------------------- streaming engine + policy -------------------- #
def test_streaming_engine_host_correct_and_reorganizes():
    rng = np.random.default_rng(61)
    g = with_random_attrs(erdos_renyi(130, 4.0, directed=False, seed=15), seed=16)
    eng = StreamingEngine(
        g, KHopWindow(1), device=False,
        policy=StalenessPolicy(max_link_ratio=1.15, min_batches=2),
    )
    saw_reorg = False
    for step in range(6):
        b = mixed(eng.graph, rng, 10, 5)
        rep = eng.apply(b)
        saw_reorg |= rep["reorganized"]
        ref = brute_force(eng.graph, eng.window, eng.graph.attrs["val"], "sum")
        assert np.allclose(eng.query("sum"), ref), step
    assert saw_reorg and eng.reorg_count >= 1
    assert eng.staleness["link_ratio"] <= 1.15 * 1.5  # re-baselined after reorg


def test_delete_dominated_stream_trips_garbage_metric():
    """Delete-only streams shrink links (growth ratios never trip) but
    accumulate zero-link garbage blocks; the garbage metric must arm the
    reorganize and answers must stay exact throughout."""
    from repro.core.streaming import garbage_block_fraction

    rng = np.random.default_rng(91)
    g = with_random_attrs(erdos_renyi(140, 6.0, directed=False, seed=19), seed=20)
    eng = StreamingEngine(
        g, KHopWindow(1), device=False,
        policy=StalenessPolicy(max_link_ratio=100.0, max_block_ratio=100.0,
                               max_garbage_ratio=0.25, min_batches=1),
    )
    saw_garbage = saw_reorg = False
    for step in range(8):
        b = random_delete_batch(eng.graph, rng, 30)
        saw_garbage |= garbage_block_fraction(eng.index) > 0.0
        rep = eng.apply(b)
        saw_reorg |= rep["reorganized"]
        ref = brute_force(eng.graph, eng.window, eng.graph.attrs["val"], "sum")
        assert np.allclose(eng.query("sum"), ref), step
    assert saw_garbage, "delete stream never produced garbage blocks"
    assert saw_reorg, "garbage metric never tripped the reorganize"
    assert eng.staleness["garbage_ratio"] <= 0.25  # re-baselined by reorg


def test_staleness_policy_garbage_only_signal():
    """links/blocks both *shrink* under deletes — only the garbage ratio
    fires."""
    pol = StalenessPolicy(max_link_ratio=1.5, max_block_ratio=2.0,
                          max_garbage_ratio=0.4, min_batches=1)

    class ShrunkIdx:
        n = 10
        num_blocks = 10
        stats = {"num_links": 50}
        link_block = np.array([0, 1, 2], np.int32)  # 7/10 blocks garbage

    assert pol.should_reorganize(ShrunkIdx(), 100, 10, 1)
    pol_off = StalenessPolicy(max_link_ratio=1.5, max_block_ratio=2.0,
                              max_garbage_ratio=1.1, min_batches=1)
    assert not pol_off.should_reorganize(ShrunkIdx(), 100, 10, 1)


def test_staleness_policy_thresholds():
    pol = StalenessPolicy(max_link_ratio=1.5, max_block_ratio=2.0, min_batches=3)

    class FakeIdx:
        num_blocks = 100
        stats = {"num_links": 200}

    assert not pol.should_reorganize(FakeIdx(), 100, 100, 2)  # too early
    assert pol.should_reorganize(FakeIdx(), 100, 100, 3)  # links 2x > 1.5x
    assert not pol.should_reorganize(FakeIdx(), 200, 100, 3)  # under both


# -------------------------- property tests ---------------------------- #
@settings(max_examples=8, deadline=None)
@given(st.integers(30, 90), st.integers(2, 5), st.integers(0, 9999),
       st.integers(1, 2))
def test_property_khop_batch_insert_equals_rebuild(n, deg, seed, k):
    rng = np.random.default_rng(seed)
    g = with_random_attrs(erdos_renyi(n, float(deg), seed=seed), seed=seed + 1)
    w = KHopWindow(k)
    idx = build_dbindex(g, w, method="emc")
    b = mixed(g, rng, 6, 3)
    g2 = U.apply_batch(g, b)
    idx2, _ = U.update_dbindex_batch(idx, g2, w, b)
    ref = brute_force(g2, w, g2.attrs["val"], "sum")
    assert np.allclose(idx2.query(g2.attrs["val"], "sum"), ref)


@settings(max_examples=8, deadline=None)
@given(st.integers(25, 80), st.integers(1, 3), st.integers(0, 9999))
def test_property_iindex_batch_equals_rebuild(n, deg, seed):
    rng = np.random.default_rng(seed)
    g = with_random_attrs(random_dag(n, float(deg), seed=seed), seed=seed + 1)
    ii = build_iindex(g)
    b = mixed(g, rng, 5, 2, dag=True)
    g2 = U.apply_batch(g, b)
    ii2, _ = U.update_iindex_batch(ii, g2, b)
    ref = brute_force(g2, TopologicalWindow(), g2.attrs["val"], "sum")
    assert np.allclose(ii2.query(g2.attrs["val"], "sum"), ref)


# ---------------- device-routed affected-owner BFS (Pallas) ------------ #
@pytest.mark.parametrize("directed", [True, False])
@pytest.mark.parametrize("k", [1, 2, 3])
def test_affected_owners_device_bfs_matches_host(directed, k):
    """Routing the multi-source BFS through the ``bitset_expand`` kernel
    (large-batch path) must reproduce the host-NumPy owner set exactly."""
    pytest.importorskip("jax")
    g = erdos_renyi(250, 4.0, directed=directed, seed=3)
    rng = np.random.default_rng(k)
    seeds = rng.integers(0, g.n, 40)
    host = U.affected_owners_khop_multi(g, k, seeds, use_device=False)
    dev = U.affected_owners_khop_multi(g, k, seeds, use_device=True)
    assert np.array_equal(host, dev)


def test_affected_owners_device_threshold_default():
    """Below DEVICE_BFS_MIN_SEEDS the default routing stays on host (the
    per-call expand-plan build would dominate tiny batches)."""
    g = erdos_renyi(60, 3.0, directed=True, seed=4)
    seeds = np.arange(10)
    assert 10 < U.DEVICE_BFS_MIN_SEEDS
    out = U.affected_owners_khop_multi(g, 2, seeds)  # host path, no jax need
    assert out.size >= seeds.size


def test_sharded_affected_owners_union_equals_single_host():
    """Sharding the BFS over seed slices must union to exactly the
    single-host affected set, for both window kinds."""
    rng = np.random.default_rng(5)
    g = with_random_attrs(erdos_renyi(200, 4.0, directed=False, seed=6), seed=7)
    b = mixed(g, rng, 10, 5)
    g2 = U.apply_batch(g, b)
    w = KHopWindow(2)
    ref = U.affected_owners_khop_multi(g2, w.k, U._khop_seeds(g2, b))
    for ndev in (1, 2, 4):
        owners, per_shard = U.sharded_affected_owners(g2, w, b, ndev)
        assert len(per_shard) == ndev
        assert np.array_equal(owners, ref)

    gd = with_random_attrs(random_dag(150, 2.0, seed=8), seed=9)
    bd = mixed(gd, rng, 6, 3, dag=True)
    g2d = U.apply_batch(gd, bd)
    from repro.core.windows import descendants_multi

    ref_t = descendants_multi(g2d, bd.dst.astype(np.int64))
    owners_t, _ = U.sharded_affected_owners(g2d, TopologicalWindow(), bd, 3)
    assert np.array_equal(owners_t, ref_t)


def test_update_dbindex_batch_accepts_precomputed_owners():
    """update_dbindex_batch(owners=...) must match the self-computed path
    (index arrays and stats identical)."""
    rng = np.random.default_rng(10)
    g = with_random_attrs(erdos_renyi(150, 4.0, directed=False, seed=11), seed=12)
    w = KHopWindow(1)
    idx = build_dbindex(g, w, method="emc")
    b = mixed(g, rng, 8, 4)
    g2 = U.apply_batch(g, b)
    auto, ch_a = U.update_dbindex_batch(idx, g2, w, b)
    owners, _ = U.sharded_affected_owners(g2, w, b, 4)
    pre, ch_p = U.update_dbindex_batch(idx, g2, w, b, owners=owners)
    assert np.array_equal(ch_a, ch_p)
    assert np.array_equal(auto.block_members, pre.block_members)
    assert np.array_equal(auto.link_block, pre.link_block)
    assert np.array_equal(auto.link_owner_offsets, pre.link_owner_offsets)
