"""Sharded streaming runtime tests (multi-device via subprocess — the host
device count must be set before jax initializes).

Covers the ISSUE-3 acceptance criteria:

* the sharded fused multi-aggregate query is **bit-identical** to the
  single-host fused path for all monoid aggregates, on both the ELL and
  the masked-tile-layout min/max paths;
* a 2-shard streamed-update oracle: each batch ships only changed tile
  groups per shard (patch bytes < full plan bytes), answers stay
  oracle-correct, and the jitted sharded query never recompiles across
  >= 10 batches.
"""

import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.sharded


def _run(code: str, devices: int = 8):
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "XLA_FLAGS":
                 f"--xla_force_host_platform_device_count={devices}",
             "HOME": "/root"},
        cwd="/root/repo",
    )


def test_sharded_multi_bit_identical_all_aggs():
    r = _run("""
        import dataclasses, numpy as np, jax
        from repro.graphs.generators import erdos_renyi, with_random_attrs
        from repro.core.windows import KHopWindow
        from repro.core.dbindex import build_dbindex
        from repro.core import engine_jax as ej

        g = with_random_attrs(erdos_renyi(400, 6.0, seed=1), seed=2)
        idx = build_dbindex(g, KHopWindow(2), method="emc")
        plan = ej.plan_from_dbindex(idx, tm=64, ts=64)
        aggs = ("sum", "count", "min", "max", "avg")
        mesh = jax.make_mesh((4,), ("data",))
        for p in (plan, dataclasses.replace(plan, p1_ell=None, p2_ell=None)):
            ref = ej.query_dbindex_multi(p, g.attrs["val"], aggs,
                                         use_pallas=False)
            got = ej.query_dbindex_sharded_multi(p, g.attrs["val"], aggs, mesh)
            for a, r_, o in zip(aggs, ref, got):
                assert np.array_equal(np.asarray(r_), np.asarray(o)), (
                    a, p.p1_ell is None)
        print("BITWISE_OK")
    """)
    assert "BITWISE_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


def test_sharded_session_two_shard_stream_oracle():
    """2-shard mesh, 12 streamed batches: oracle-correct answers, per-shard
    tile-group patches strictly smaller than a full plan re-upload, and
    zero recompiles of the sharded fused query after warmup."""
    r = _run("""
        import numpy as np, jax
        from repro.graphs.generators import erdos_renyi, with_random_attrs
        from repro.core.api import QuerySpec, Session
        from repro.core.query import brute_force
        from repro.core.updates import UpdateBatch
        from repro.distributed import window_runtime as wr

        mesh = jax.make_mesh((2,), ("data",))
        g = with_random_attrs(erdos_renyi(500, 4.0, directed=False, seed=11),
                              seed=12)
        specs = [QuerySpec(("khop", 1), a)
                 for a in ("sum", "count", "min", "avg")]
        sess = Session(g, specs, mesh=mesh, plan_headroom=1.0)
        assert isinstance(sess, wr.ShardedSession)
        sess.run()
        cache0 = wr.query_cache_size()

        def mixed(g, rng, n_ins, n_del):
            s = rng.integers(0, g.n, n_ins * 4).astype(np.int32)
            d = rng.integers(0, g.n, n_ins * 4).astype(np.int32)
            ok = (s != d) & ~g.contains_edges(s, d)
            _, first = np.unique(g.edge_keys(s, d), return_index=True)
            pick = np.intersect1d(np.flatnonzero(ok), first)[:n_ins]
            ins = UpdateBatch.inserts(s[pick], d[pick])
            ei = rng.choice(g.n_edges, min(n_del, g.n_edges), replace=False)
            return UpdateBatch.concat(
                [ins, UpdateBatch.deletes(g.src[ei], g.dst[ei])])

        rng = np.random.default_rng(13)
        for step in range(12):
            reports = sess.update(mixed(sess.graph, rng, 4, 2))
            rep = list(reports.values())[0]
            assert rep["reorganized"] or (
                0 < rep["patch_bytes"] < rep["full_plan_bytes"]), (step, rep)
            assert len(rep["affected_per_shard"]) == 2
            assert len(rep["patch_bytes_per_shard"]) == 2
            res = sess.run()
            vals = sess.graph.attrs["val"]
            for s_, r_ in zip(specs, res):
                ref = brute_force(sess.graph, s_.window, vals, s_.agg)
                assert np.allclose(r_, ref, rtol=1e-5, atol=1e-3), (
                    step, s_.agg)
        assert wr.query_cache_size() == cache0  # zero recompiles
        assert sess.updates_applied == 12
        print("STREAM_OK")
    """, devices=2)
    assert "STREAM_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


def test_sharded_run_many_and_registry_route():
    """run_many across the mesh + the widened jax-sharded capability served
    straight through the registry (no Session)."""
    r = _run("""
        import numpy as np, jax
        from repro.graphs.generators import erdos_renyi, with_random_attrs
        from repro.core.api import DEFAULT_REGISTRY, QuerySpec, Session
        from repro.core.query import brute_force
        from repro.core.windows import KHopWindow

        mesh = jax.make_mesh((2,), ("data",))
        g = with_random_attrs(erdos_renyi(150, 3.0, directed=False, seed=14),
                              seed=15)
        w = KHopWindow(1)
        out = DEFAULT_REGISTRY.run("jax-sharded", g, w, g.attrs["val"],
                                   ("min", "avg"), mesh=mesh)
        for a in ("min", "avg"):
            ref = brute_force(g, w, g.attrs["val"], a)
            assert np.allclose(out[a], ref, rtol=1e-5, atol=1e-3), a

        specs = [QuerySpec(w, a) for a in ("sum", "max")]
        sess = Session(g, specs, mesh=mesh)
        vb = np.random.default_rng(16).normal(size=(3, g.n))
        outs = sess.run_many(vb)
        for s_, o in zip(specs, outs):
            assert o.shape == (3, g.n)
            for b in range(3):
                ref = brute_force(g, s_.window, vb[b], s_.agg)
                assert np.allclose(o[b], ref, rtol=1e-5, atol=1e-3), (
                    s_.agg, b)
        print("SERVE_OK")
    """, devices=2)
    assert "SERVE_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


def test_wire_replication_follower_bit_identical():
    """ISSUE 6: the changed-tile-group patch stream doubles as the
    replication message.  A follower holding only the initial plan applies
    every wire message (bytes-roundtripped through the pickle-free codec)
    and answers bit-identically to the leader at each step — through both
    incremental "patch" messages and a full "resync"."""
    r = _run("""
        import numpy as np, jax
        from repro.graphs.generators import erdos_renyi, with_random_attrs
        from repro.core.dbindex import build_dbindex
        from repro.core.updates import UpdateBatch
        from repro.core.windows import KHopWindow
        from repro.core import engine_jax as ej
        from repro.distributed import window_runtime as wr

        mesh = jax.make_mesh((2,), ("data",))
        g = with_random_attrs(erdos_renyi(400, 3.0, directed=False, seed=21),
                              seed=22)
        w = KHopWindow(1)
        leader = wr.ShardedStreamState(g, w, mesh, tm=64, ts=64,
                                       plan_headroom=1.0, capture_wire=True)

        # follower: same base graph -> identical initial plan, then wire-fed
        fidx = build_dbindex(g, w, method=leader.method)
        fplan = wr.build_sharded_plan(
            ej.plan_from_dbindex(fidx, 64, 64, headroom=1.0), mesh, "data",
            headroom=1.0)

        def mixed(g, rng, n_ins, n_del):
            s = rng.integers(0, g.n, n_ins * 4).astype(np.int32)
            d = rng.integers(0, g.n, n_ins * 4).astype(np.int32)
            ok = (s != d) & ~g.contains_edges(s, d)
            _, first = np.unique(g.edge_keys(s, d), return_index=True)
            pick = np.intersect1d(np.flatnonzero(ok), first)[:n_ins]
            ins = UpdateBatch.inserts(s[pick], d[pick])
            ei = rng.choice(g.n_edges, min(n_del, g.n_edges), replace=False)
            return UpdateBatch.concat(
                [ins, UpdateBatch.deletes(g.src[ei], g.dst[ei])])

        rng = np.random.default_rng(23)
        kinds = []
        consumed = 0
        aggs = ("sum", "min")
        from repro.core.updates import apply_batch
        fgraph = g
        for step in range(12):
            b = mixed(leader.graph, rng, 4, 2)
            leader.apply(b)
            fgraph = apply_batch(fgraph, b)
            if step == 7:
                leader._build()  # force one resync message on the wire
            for msg in leader.wire_log[consumed:]:
                msg2 = wr.decode_wire_message(wr.encode_wire_message(msg))
                kinds.append(msg2["kind"])
                fplan = wr.apply_wire_message(fplan, msg2)
            consumed = len(leader.wire_log)
            vals = leader.graph.attrs["val"]
            got = wr.query_sharded_multi(fplan, vals, aggs)
            want = leader.query_multi(aggs)
            for a, x, y in zip(aggs, got, want):
                assert np.array_equal(np.asarray(x), np.asarray(y)), (
                    step, a)
        assert "patch" in kinds and "resync" in kinds, kinds
        assert leader.plan.stats["version"] == fplan.stats["version"]
        print("WIRE_OK", kinds.count("patch"), kinds.count("resync"))
    """, devices=2)
    assert "WIRE_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
