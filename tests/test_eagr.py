"""EAGR baseline: correctness + the paper's memory-limit failure mode."""

import numpy as np
import pytest

from repro.core.eagr import build_eagr
from repro.core.query import brute_force
from repro.core.windows import KHopWindow
from repro.graphs.generators import erdos_renyi, with_random_attrs


@pytest.fixture(scope="module")
def g():
    return with_random_attrs(erdos_renyi(120, 5.0, seed=11), seed=12)


@pytest.mark.parametrize("k", [1, 2])
def test_eagr_query_correct(g, k):
    w = KHopWindow(k)
    idx = build_eagr(g, w, iterations=3, chunk_size=64)
    ref = brute_force(g, w, g.attrs["val"], "sum")
    assert np.allclose(idx.query(g.attrs["val"], "sum"), ref)


def test_eagr_finds_bicliques(g):
    idx = build_eagr(g, KHopWindow(2), iterations=3, chunk_size=64)
    assert idx.stats["num_virtual"] > 0  # overlay actually compressed


def test_eagr_memory_limit_reproduces_paper_oom(g):
    """§6.2: EAGR fails when the vertex-window mapping exceeds memory."""
    with pytest.raises(MemoryError):
        build_eagr(g, KHopWindow(2), memory_limit_bytes=1024)


def test_eagr_vs_dbindex_query_parity(g):
    from repro.core.dbindex import build_dbindex

    w = KHopWindow(2)
    ref = brute_force(g, w, g.attrs["val"], "sum")
    eagr = build_eagr(g, w, iterations=2, chunk_size=64)
    db = build_dbindex(g, w, method="emc")
    assert np.allclose(eagr.query(g.attrs["val"], "sum"), ref)
    assert np.allclose(db.query(g.attrs["val"], "sum"), ref)
