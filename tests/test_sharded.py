"""Multi-device tests (8 host-platform devices via subprocess: the device
count must be set before jax initializes, so these run in a child python).

Marked ``sharded``: each test pays ~minutes of CPU XLA compiles, so CI runs
them as a separate long-timeout job (``pytest -m sharded``) and keeps the
tier-1 job on ``-m "not sharded"``."""

import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.sharded


def _run(code: str):
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "HOME": "/root"},
        cwd="/root/repo",
    )


def test_sharded_dbindex_query_equals_single_device():
    r = _run("""
        import numpy as np, jax
        from jax.sharding import PartitionSpec as P
        from repro.graphs.generators import erdos_renyi, with_random_attrs
        from repro.core.windows import KHopWindow
        from repro.core.dbindex import build_dbindex
        from repro.core import engine_jax as ej
        from repro.core.query import brute_force

        assert len(jax.devices()) == 8
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        g = with_random_attrs(erdos_renyi(400, 6.0, seed=1), seed=2)
        idx = build_dbindex(g, KHopWindow(2), method="emc")
        plan = ej.plan_from_dbindex(idx)
        ref = brute_force(g, KHopWindow(2), g.attrs["val"], "sum")
        with mesh:
            got = np.asarray(ej.query_dbindex_sharded(plan, g.attrs["val"], mesh,
                                                      axis=("data", "model")))
        assert np.allclose(got, ref), np.abs(got - ref).max()
        print("SHARDED_OK")
    """)
    assert "SHARDED_OK" in r.stdout, r.stdout + r.stderr


def test_lm_train_step_runs_sharded():
    r = _run("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import steps
        from repro.configs.registry import get_arch

        mesh = make_debug_mesh(4, 2)
        cfg = get_arch("qwen3-0.6b").smoke_cfg
        import dataclasses
        cfg = dataclasses.replace(cfg, vocab=512)
        built = steps.build_lm_train(cfg, mesh, dict(batch=8, seq=64))
        with mesh:
            compiled = built.lower(mesh).compile()
        # run it with real (tiny) data
        from repro.models import transformer as T
        from repro.optim.optimizers import adamw
        from repro.optim.schedules import cosine_schedule
        params = T.init(jax.random.PRNGKey(0), cfg)
        opt = adamw(cosine_schedule(3e-4, 10, 100))
        opt_state = opt.init(params)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        p2, o2, metrics = compiled(params, opt_state, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), loss
        print("TRAIN_SHARDED_OK", loss)
    """)
    assert "TRAIN_SHARDED_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


def test_moe_shard_map_dispatch_matches_single_device():
    r = _run("""
        import jax, numpy as np, jax.numpy as jnp, dataclasses
        from repro.launch.mesh import make_debug_mesh
        from repro.configs.registry import get_arch
        from repro.models import moe as M
        from repro.distributed.actshard import lm_train_acts

        mesh = make_debug_mesh(4, 2)
        cfg = get_arch("qwen2-moe-a2.7b").smoke_cfg
        cfg = dataclasses.replace(cfg, dispatch_groups=8)
        params = M.init(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        ref = float(M.loss_fn(params, batch, cfg))  # no acts: vmap path
        acts = lm_train_acts(("data",), mesh)
        with mesh:
            got = float(jax.jit(lambda p: M.loss_fn(p, batch, cfg, acts=acts))(params))
        assert abs(got - ref) < 5e-2, (got, ref)
        print("MOE_SHARDMAP_OK", got, ref)
    """)
    assert "MOE_SHARDMAP_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


def test_checkpoint_reshard_on_restore():
    r = _run("""
        import jax, numpy as np, jax.numpy as jnp, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train.checkpoints import CheckpointManager

        mesh_a = jax.make_mesh((8,), ("data",))
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh_a, P("data", None)))
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d)
            cm.save(1, {"x": x})
            tgt = NamedSharding(mesh_b, P("data", "model"))
            restored, _, _ = cm.restore({"x": x}, shardings={"x": tgt})
            assert restored["x"].sharding == tgt
            np.testing.assert_array_equal(np.asarray(restored["x"]),
                                          np.arange(64.0).reshape(8, 8))
        print("RESHARD_OK")
    """)
    assert "RESHARD_OK" in r.stdout, r.stdout + r.stderr
