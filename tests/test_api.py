"""Unified window-analytics API: registry, fused compiler, Session.

Differential suite for :mod:`repro.core.api`:

* every registered engine × every aggregate × both window types against
  the per-vertex ``brute_force`` oracle (one fused runner call per engine
  — the registry interface is multi-aggregate);
* fused multi-aggregate device plans against per-aggregate
  ``query_dbindex`` answers bit-for-bit;
* capability selection + the explicit ``UnsupportedQueryError`` contract;
* ``Session`` update→query round-trips: 20 streamed ``UpdateBatch``es with
  oracle-correct answers and zero recompiles of the fused plan.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import engine_jax as ej  # noqa: E402
from repro.core.api import (  # noqa: E402
    DEFAULT_REGISTRY,
    QuerySpec,
    Session,
    UnsupportedQueryError,
    compile_queries,
    recompile_count,
)
from repro.core.dbindex import build_dbindex  # noqa: E402
from repro.core.iindex import build_iindex  # noqa: E402
from repro.core.query import brute_force  # noqa: E402
from repro.core.streaming import StreamingEngine  # noqa: E402
from repro.core.windows import KHopWindow, TopologicalWindow  # noqa: E402
from repro.graphs.generators import erdos_renyi, random_dag, with_random_attrs  # noqa: E402

from test_updates import mixed  # noqa: E402  (stream helpers)

ALL_AGGS = ("sum", "count", "min", "max", "avg")
KHOP_ENGINES = ("nonindex", "bitset", "eagr", "dbindex", "jax")
TOPO_ENGINES = ("nonindex", "bitset", "eagr", "dbindex", "iindex", "jax",
                "jax-iindex")


@pytest.fixture(scope="module")
def khop_case():
    g = with_random_attrs(erdos_renyi(90, 3.0, directed=False, seed=7), seed=8)
    w = KHopWindow(2)
    refs = {a: brute_force(g, w, g.attrs["val"], a) for a in ALL_AGGS}
    return g, w, refs


@pytest.fixture(scope="module")
def topo_case():
    g = with_random_attrs(random_dag(90, 2.0, seed=9), seed=10)
    w = TopologicalWindow()
    refs = {a: brute_force(g, w, g.attrs["val"], a) for a in ALL_AGGS}
    return g, w, refs


# ----------------------- engine × aggregate sweep --------------------- #
@pytest.mark.parametrize("engine", KHOP_ENGINES)
def test_every_engine_every_agg_khop(engine, khop_case):
    g, w, refs = khop_case
    out = DEFAULT_REGISTRY.run(engine, g, w, g.attrs["val"], ALL_AGGS,
                               use_pallas=False)
    for a in ALL_AGGS:
        assert np.allclose(out[a], refs[a], rtol=1e-5, atol=1e-3), (engine, a)


@pytest.mark.parametrize("engine", TOPO_ENGINES)
def test_every_engine_every_agg_topological(engine, topo_case):
    g, w, refs = topo_case
    out = DEFAULT_REGISTRY.run(engine, g, w, g.attrs["val"], ALL_AGGS,
                               use_pallas=False)
    for a in ALL_AGGS:
        assert np.allclose(out[a], refs[a], rtol=1e-5, atol=1e-3), (engine, a)


# --------------------------- capability model ------------------------- #
def test_registry_selection_by_capability():
    w2, wt = KHopWindow(2), TopologicalWindow()
    assert DEFAULT_REGISTRY.select(w2, ("sum", "avg")) == "jax"
    assert DEFAULT_REGISTRY.select(wt, ("min",), device=True) == "jax-iindex"
    assert DEFAULT_REGISTRY.select(w2, ("sum",), device=False) == "dbindex"
    assert DEFAULT_REGISTRY.select(w2, ("sum",), sharded=True) == "jax-sharded"
    # the stacked-channel sharded executor serves every monoid aggregate
    # (the old SUM-only capability row is gone)
    assert DEFAULT_REGISTRY.select(w2, ("min", "avg", "count"),
                                   sharded=True) == "jax-sharded"
    # explicit pins are validated against the declared capability
    assert DEFAULT_REGISTRY.select(wt, ("max",), engine="iindex") == "iindex"


def test_registry_unsupported_is_explicit():
    w2 = KHopWindow(2)
    with pytest.raises(UnsupportedQueryError, match="iindex"):
        DEFAULT_REGISTRY.select(w2, ("sum",), engine="iindex")
    # no sharded engine is non-incremental: must fail loudly, and the
    # capability table must carry the device/sharded/incremental flags so
    # planner failures are self-explaining
    with pytest.raises(UnsupportedQueryError,
                       match=r"sharded=True.*sharded=True, incremental=True"):
        DEFAULT_REGISTRY.select(w2, ("sum",), sharded=True, incremental=False)
    # pin-mismatch errors carry the engine's full capability row too
    with pytest.raises(UnsupportedQueryError, match="device=False"):
        DEFAULT_REGISTRY.select(w2, ("sum",), engine="iindex")
    with pytest.raises(UnsupportedQueryError, match="unknown engine"):
        DEFAULT_REGISTRY.select(w2, ("sum",), engine="nope")


def test_compile_queries_dedups_and_fuses():
    specs = [
        QuerySpec(("khop", 2), "sum"),
        QuerySpec(("khop", 2), "avg"),
        QuerySpec(("khop", 2), "sum"),  # duplicate collapses
        QuerySpec("topological", "min"),
        QuerySpec(("khop", 2), "count", engine="bitset"),
    ]
    cq = compile_queries(specs, device=True)
    assert [g.aggs for g in cq.groups] == [("sum", "avg"), ("min",), ("count",)]
    assert [g.engine for g in cq.groups] == ["jax", "jax-iindex", "bitset"]
    # spec back-pointers: duplicate sum shares the first slot
    assert cq.spec_slots[0] == cq.spec_slots[2]


# ------------------- fused multi-channel device plans ------------------ #
def test_fused_dbindex_multi_bit_identical_to_per_agg(khop_case):
    g, w, refs = khop_case
    idx = build_dbindex(g, w, method="emc")
    plan = ej.plan_from_dbindex(idx, tm=64, ts=64)
    fused = ej.query_dbindex_multi(plan, g.attrs["val"], ALL_AGGS,
                                   use_pallas=False)
    for a, got in zip(ALL_AGGS, fused):
        single = np.asarray(ej.query_dbindex(plan, g.attrs["val"], a,
                                             use_pallas=False))
        assert np.array_equal(np.asarray(got), single), a  # bit-for-bit
        assert np.allclose(np.asarray(got), refs[a], rtol=1e-5, atol=1e-3), a


def test_fused_dbindex_multi_pallas_interpret(khop_case):
    g, w, refs = khop_case
    idx = build_dbindex(g, w, method="emc")
    plan = ej.plan_from_dbindex(idx, tm=64, ts=64)
    fused = ej.query_dbindex_multi(plan, g.attrs["val"], ("sum", "avg"),
                                   use_pallas=True, interpret=True)
    for a, got in zip(("sum", "avg"), fused):
        assert np.allclose(np.asarray(got), refs[a], rtol=1e-5, atol=1e-3), a


@pytest.mark.parametrize("schedule", ["level", "doubling"])
def test_fused_iindex_multi_all_monoids(schedule, topo_case):
    g, w, refs = topo_case
    ii = build_iindex(g)
    plan = ej.plan_from_iindex(ii, tm=64, ts=64)
    fused = ej.query_iindex_multi(plan, g.attrs["val"], ALL_AGGS,
                                  schedule=schedule, use_pallas=False)
    for a, got in zip(ALL_AGGS, fused):
        assert np.allclose(np.asarray(got), refs[a], rtol=1e-5, atol=1e-3), (
            schedule, a)
    # sum channel is bit-identical to the dedicated SUM kernel path
    s = np.asarray(ej.query_iindex(plan, g.attrs["val"], schedule=schedule,
                                   use_pallas=False))
    assert np.array_equal(np.asarray(fused[0]), s)


def test_streaming_engine_device_iindex_minmax_no_assert(topo_case):
    """The old device I-Index path asserted SUM-only; the registry now
    routes min/max/count/avg through per-monoid level inheritance."""
    g, w, refs = topo_case
    eng = StreamingEngine(g, w, index_kind="iindex", use_pallas=False)
    for a in ALL_AGGS:
        assert np.allclose(eng.query(a), refs[a], rtol=1e-5, atol=1e-3), a
    outs = eng.query_multi(("min", "max", "avg"))
    for a, o in zip(("min", "max", "avg"), outs):
        assert np.allclose(o, refs[a], rtol=1e-5, atol=1e-3), a


# ------------------------------ Session ------------------------------- #
def test_session_update_query_roundtrip_no_recompile():
    """Oracle-correct across >= 20 streamed batches, zero retraces of the
    fused device query (plan patching keeps static shapes stable)."""
    g = with_random_attrs(erdos_renyi(600, 4.0, directed=False, seed=11),
                          seed=12)
    specs = [QuerySpec(("khop", 1), a) for a in ("sum", "count", "min", "avg")]
    sess = Session(g, specs, device=True, use_pallas=False, plan_headroom=1.0)
    sess.run()
    # unified counter spanning every fused executor's jit cache (the old
    # per-executor probe stays as a cross-check that the union attributes
    # a regression to the right executor)
    cache0 = recompile_count()
    dbcache0 = ej.query_dbindex_multi._cache_size()
    rng = np.random.default_rng(13)
    for step in range(20):
        sess.update(mixed(sess.graph, rng, 4, 2))
        res = sess.run()
        vals = sess.graph.attrs["val"]
        for s, r in zip(specs, res):
            ref = brute_force(sess.graph, s.window, vals, s.agg)
            assert np.allclose(r, ref, rtol=1e-5, atol=1e-3), (step, s.agg)
    assert recompile_count() == cache0  # no recompiles, any executor
    assert ej.query_dbindex_multi._cache_size() == dbcache0
    assert sess.updates_applied == 20


def test_session_mixed_windows_and_attrs(topo_case):
    g, w, refs = topo_case
    g = g.with_attr("weight", np.arange(g.n, dtype=np.float64))
    specs = [
        QuerySpec("topological", "sum"),
        QuerySpec(("khop", 1), "max", attr="weight"),
        QuerySpec("topological", "avg"),
    ]
    sess = Session(g, specs, device=True, use_pallas=False)
    res = sess.run()
    for s, r in zip(specs, res):
        ref = brute_force(g, s.window, g.attrs[s.attr], s.agg)
        assert np.allclose(r, ref, rtol=1e-5, atol=1e-3), s
    # one stateful index per distinct (window, kind), shared across groups
    assert len(sess._states) == 2


def test_session_run_many_matches_per_row():
    g = with_random_attrs(erdos_renyi(120, 3.0, directed=False, seed=14),
                          seed=15)
    specs = [QuerySpec(("khop", 1), a) for a in ("sum", "min", "avg")]
    sess = Session(g, specs, device=True, use_pallas=False)
    vb = np.random.default_rng(16).normal(size=(3, g.n))
    outs = sess.run_many(vb)
    for s, o in zip(specs, outs):
        assert o.shape == (3, g.n)
        for b in range(vb.shape[0]):
            ref = brute_force(g, s.window, vb[b], s.agg)
            assert np.allclose(o[b], ref, rtol=1e-5, atol=1e-3), (s.agg, b)


def test_session_shared_state_keeps_device_plan(khop_case):
    """A host-pinned group sharing a window with a device group must not
    strip the compiled plan (state device flag is the OR over groups)."""
    g, w, refs = khop_case
    specs = [
        QuerySpec(w, "sum", engine="dbindex"),  # host
        QuerySpec(w, "avg", engine="jax"),      # device, same window
    ]
    sess = Session(g, specs, use_pallas=False)
    assert sess._states[(w, "dbindex")].plan is not None
    s, avg = sess.run()
    assert np.allclose(s, refs["sum"], rtol=1e-5, atol=1e-3)
    assert np.allclose(avg, refs["avg"], rtol=1e-5, atol=1e-3)


def test_session_update_reports_distinct_windows():
    g = with_random_attrs(erdos_renyi(80, 3.0, directed=False, seed=31), seed=32)
    sess = Session(g, [QuerySpec(("khop", 1), "sum"), QuerySpec(("khop", 2), "sum")],
                   device=True, use_pallas=False)
    from repro.core.updates import UpdateBatch

    reports = sess.update(UpdateBatch.inserts([0, 1], [5, 6]))
    assert set(reports) == {"khop[1]/dbindex", "khop[2]/dbindex"}


def test_registry_rejects_unknown_options(khop_case):
    g, w, refs = khop_case
    with pytest.raises(TypeError, match="unknown engine option"):
        DEFAULT_REGISTRY.run("dbindex", g, w, g.attrs["val"], ("sum",),
                             metod="mc")  # typo must not silently default


def test_legacy_graph_window_query_shim(khop_case):
    from repro.core.query import GraphWindowQuery

    g, w, refs = khop_case
    for engine in ("dbindex", "bitset"):
        got = GraphWindowQuery(w, agg="avg").run(g, engine=engine)
        assert np.allclose(got, refs["avg"], rtol=1e-5, atol=1e-3), engine
    with pytest.raises(UnsupportedQueryError):
        GraphWindowQuery(w, agg="sum").run(g, engine="iindex")


# ------------------- sharded runtime (single-device mesh) -------------- #
# The real multi-device coverage lives in tests/test_sharded_stream.py (own
# CI job, subprocess-forced device count); a 1-device mesh exercises the
# whole sharded code path — layout, shard_map, collectives, patching — in
# tier-1 without the device-count dance.
def test_sharded_multi_single_device_mesh_bit_identical(khop_case):
    g, w, refs = khop_case
    mesh = jax.make_mesh((1,), ("data",))
    idx = build_dbindex(g, w, method="emc")
    plan = ej.plan_from_dbindex(idx, tm=64, ts=64)
    fused = ej.query_dbindex_multi(plan, g.attrs["val"], ALL_AGGS,
                                   use_pallas=False)
    sharded = ej.query_dbindex_sharded_multi(plan, g.attrs["val"], ALL_AGGS,
                                             mesh)
    for a, r, o in zip(ALL_AGGS, fused, sharded):
        assert np.array_equal(np.asarray(r), np.asarray(o)), a


def test_session_mesh_kwarg_builds_sharded_session():
    from repro.distributed.window_runtime import ShardedSession

    # big enough that a small batch stays on the incremental patch path
    # (tiny dense graphs trip the affected>n/2 rebuild / staleness policy)
    g = with_random_attrs(erdos_renyi(300, 3.0, directed=False, seed=21),
                          seed=22)
    w = KHopWindow(1)
    mesh = jax.make_mesh((1,), ("data",))
    sess = Session(g, [QuerySpec(w, "sum"), QuerySpec(w, "min")], mesh=mesh,
                   plan_headroom=1.0)
    assert isinstance(sess, ShardedSession)
    s, mn = sess.run()
    vals = g.attrs["val"]
    assert np.allclose(s, brute_force(g, w, vals, "sum"), rtol=1e-5, atol=1e-3)
    assert np.allclose(mn, brute_force(g, w, vals, "min"), rtol=1e-5, atol=1e-3)
    # streamed update keeps the sharded plan fresh (patch, not re-upload)
    rng = np.random.default_rng(23)
    reports = sess.update(mixed(sess.graph, rng, 4, 2))
    rep = next(iter(reports.values()))
    assert not rep["reorganized"]
    assert 0 < rep["patch_bytes"] < rep["full_plan_bytes"]
    s2, _ = sess.run()
    ref2 = brute_force(sess.graph, w, sess.graph.attrs["val"], "sum")
    assert np.allclose(s2, ref2, rtol=1e-5, atol=1e-3)


def test_sharded_session_mixed_pin_single_host_device_group():
    """A pinned non-sharded device group sharing a window with a sharded
    group must not be handed the ShardedDBPlan (regression: jit crashed on
    the non-array plan) — it gets the shared index and builds its own
    host plan per call."""
    from repro.distributed.window_runtime import ShardedSession

    g = with_random_attrs(erdos_renyi(120, 3.0, directed=False, seed=24),
                          seed=25)
    w = KHopWindow(1)
    mesh = jax.make_mesh((1,), ("data",))
    sess = Session(g, [QuerySpec(w, "sum"), QuerySpec(w, "min", engine="jax")],
                   mesh=mesh, use_pallas=False)
    assert isinstance(sess, ShardedSession)
    s, mn = sess.run()
    vals = g.attrs["val"]
    assert np.allclose(s, brute_force(g, w, vals, "sum"), rtol=1e-5, atol=1e-3)
    assert np.allclose(mn, brute_force(g, w, vals, "min"), rtol=1e-5, atol=1e-3)
