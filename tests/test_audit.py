"""Online correctness auditing (ISSUE 9): shadow-oracle sampling, content
digests, WAL scrubbing, and the health/readiness surface.

The detection tests are *fault-injection* tests: each corrupts exactly one
thing (a byte in a sealed WAL record, one element of a served result
vector, one attribute value of a follower's base graph) and asserts the
matching channel detects it AND attributes it — version, vertex, WAL byte
offset — while the clean paths stay at zero findings, zero recompiles,
and never block serving.
"""

import json
import os
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro import obs  # noqa: E402
from repro.core import api  # noqa: E402
from repro.core.api import QuerySpec, Session  # noqa: E402
from repro.core.query import brute_force  # noqa: E402
from repro.core.windows import KHopWindow  # noqa: E402
from repro.graphs.generators import erdos_renyi  # noqa: E402
from repro.obs import MetricsRegistry  # noqa: E402
from repro.obs.audit import (  # noqa: E402
    AuditFinding,
    ShadowAuditor,
    WalScrubber,
    digests_match,
    oracle_single,
    session_digest,
)
from repro.serve import (  # noqa: E402
    AsyncWindowService,
    HealthMonitor,
    HealthServer,
    ReadReplica,
    WriteAheadLog,
    read_wal_records,
    scan_wal_entries,
)
from repro.serve.wal import _REC_HDR  # noqa: E402

from test_updates import mixed  # noqa: E402


@pytest.fixture(autouse=True)
def _obs_off():
    obs.disable()
    yield
    obs.disable()


def int_graph(n, deg, seed):
    g = erdos_renyi(n, deg, directed=False, seed=seed)
    vals = np.random.default_rng(seed + 1).integers(0, 50, g.n)
    return g.with_attr("val", vals.astype(np.float64))


SPECS = [QuerySpec(KHopWindow(2), "sum"), QuerySpec(KHopWindow(2), "min")]


def make_session(seed=7, n=60):
    g = int_graph(n, 2.5, seed)
    return g, Session(g, SPECS, use_pallas=False)


def stream_wal(wal_path, g, n_batches=3, seed=0, **svc_kw):
    """Run a leader over ``n_batches`` updates, return the closed service."""
    svc = AsyncWindowService(Session(g, SPECS, use_pallas=False), bucket=8,
                             wal=wal_path, **svc_kw).start()
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        svc.update(mixed(svc.session.graph, rng, 3, 1))
    svc.stop()
    svc.wal.sync()
    return svc


# ---------------------------------------------------------------------- #
#  Oracle + digest primitives
# ---------------------------------------------------------------------- #
def test_oracle_single_matches_brute_force_rows():
    g, _ = make_session()
    vals = np.asarray(g.attrs["val"], np.float64)
    for agg in ("sum", "min", "avg"):
        full = brute_force(g, KHopWindow(2), vals, agg, dtype=np.float32)
        for v in (0, 7, 31, g.n - 1):
            one = oracle_single(g, KHopWindow(2), vals, agg, v,
                                dtype=np.float32)
            assert np.asarray(one).tobytes() == np.asarray(
                full[v], dtype=np.asarray(one).dtype).tobytes()


def test_session_digest_deterministic_and_sensitive():
    g, s1 = make_session()
    _, s2 = make_session()
    d1 = session_digest(s1, include_results=True)
    d2 = session_digest(s2, include_results=True)
    assert d1 == d2  # same construction → bitwise-identical digests
    assert {"version", "graph_crc", "plan_crc", "result_crc"} <= set(d1)
    ok, detail = digests_match(d1, d2)
    assert ok and detail == "ok"
    # one attribute value flips the graph digest
    vals = np.asarray(g.attrs["val"]).copy()
    vals[3] += 1.0
    s3 = Session(g.with_attr("val", vals), SPECS, use_pallas=False)
    d3 = session_digest(s3)
    assert d3["graph_crc"] != d1["graph_crc"]
    ok, detail = digests_match(d1, d3)
    assert not ok and "graph_crc" in detail
    # a leader without result digests never fails a follower that has them
    ok, _ = digests_match({"graph_crc": d1["graph_crc"]}, d1)
    assert ok
    # plan component can be opted out (heterogeneous engine configs)
    mismatch_plan = dict(d1, plan_crc=d1["plan_crc"] ^ 1)
    assert not digests_match(d1, mismatch_plan)[0]
    assert digests_match(d1, mismatch_plan, check_plans=False)[0]


# ---------------------------------------------------------------------- #
#  WAL digest records
# ---------------------------------------------------------------------- #
def test_wal_digest_records_interleave_and_old_readers_skip(tmp_path):
    g, _ = make_session()
    path = tmp_path / "leader.wal"
    svc = stream_wal(path, g, n_batches=4, digest_results=True)
    assert svc.wal.digest_appends == 4
    entries, _ = scan_wal_entries(path)
    kinds = [(e["kind"], e["version"]) for e in entries]
    assert kinds == [(k, v) for v in range(1, 5)
                     for k in ("batch", "digest")]
    for e in entries:
        if e["kind"] == "digest":
            assert {"version", "graph_crc", "plan_crc",
                    "result_crc"} <= set(e["digest"])
    # pre-digest readers see only the batches (backward compatibility):
    records, _ = read_wal_records(path)
    assert [v for v, _ in records] == [1, 2, 3, 4]
    # and crash recovery replays a digest-bearing log to the leader state
    restored = Session.restore_from_wal(g, SPECS, path, use_pallas=False)
    assert restored.version == 4
    ok, detail = digests_match(svc.session.digest(include_results=True),
                               restored.digest(include_results=True))
    assert ok, detail


def test_wal_digest_disabled_writes_no_digest_records(tmp_path):
    g, _ = make_session()
    path = tmp_path / "plain.wal"
    svc = stream_wal(path, g, n_batches=2, wal_digests=False)
    assert svc.wal.digest_appends == 0
    assert all(e["kind"] == "batch" for e in scan_wal_entries(path)[0])


# ---------------------------------------------------------------------- #
#  Replica digest self-check
# ---------------------------------------------------------------------- #
def test_replica_digest_checks_clean_20_batch_stream(tmp_path):
    """Acceptance: leader/follower digests match bitwise for every version
    of a 20-batch replication stream."""
    g, _ = make_session()
    path = tmp_path / "leader.wal"
    stream_wal(path, g, n_batches=20)
    rep = ReadReplica(g, SPECS, path, use_pallas=False)
    applied = rep.catch_up()
    assert applied == 20 and rep.version == 20
    assert rep.digest_checks == 20
    assert rep.divergence is None
    assert rep.stats["diverged"] is False


def test_replica_divergence_detected_and_attributed(tmp_path):
    g, _ = make_session()
    path = tmp_path / "leader.wal"
    stream_wal(path, g, n_batches=3)
    # follower boots from a base graph that differs in ONE attribute value
    vals = np.asarray(g.attrs["val"]).copy()
    vals[0] += 1.0
    reg = MetricsRegistry()
    rep = ReadReplica(g.with_attr("val", vals), SPECS, path, obs=reg,
                      use_pallas=False)
    rep.catch_up()
    f = rep.divergence
    assert isinstance(f, AuditFinding) and f.source == "digest"
    assert f.version == 1  # FIRST bad version, not the last
    assert f.wal_offset is not None and f.wal_offset > 0
    assert "graph_crc" in f.detail
    # the digest record it disagreed with really lives at that offset
    entry = [e for e in scan_wal_entries(path)[0]
             if e["offset"] == f.wal_offset]
    assert len(entry) == 1 and entry[0]["kind"] == "digest" \
        and entry[0]["version"] == 1
    assert reg.snapshot()["repro_replica_divergence_total"][
        "values"][0]["value"] == 1.0
    assert any(e["event"] == "divergence"
               for e in rep.service.flight.dump())
    # only the FIRST divergence is quarantined (versions 2, 3 also differ)
    assert rep.digest_checks == 3


def test_replica_verify_digests_off_ignores_divergence(tmp_path):
    g, _ = make_session()
    path = tmp_path / "leader.wal"
    stream_wal(path, g, n_batches=2)
    vals = np.asarray(g.attrs["val"]).copy()
    vals[0] += 1.0
    rep = ReadReplica(g.with_attr("val", vals), SPECS, path,
                      verify_digests=False, use_pallas=False)
    rep.catch_up()
    assert rep.digest_checks == 0 and rep.divergence is None


def test_replica_upto_version_still_replays_held_digests(tmp_path):
    g, _ = make_session()
    path = tmp_path / "leader.wal"
    stream_wal(path, g, n_batches=3)
    rep = ReadReplica(g, SPECS, path, use_pallas=False)
    assert rep.poll(upto_version=1) == 1
    assert rep.digest_checks == 1  # version-1 digest consumed with it
    assert rep.poll() == 2  # resumes exactly at the version-2 record
    assert rep.digest_checks == 3 and rep.divergence is None


# ---------------------------------------------------------------------- #
#  WAL scrubber
# ---------------------------------------------------------------------- #
def test_scrubber_detects_sealed_byte_flip_with_offset(tmp_path):
    g, _ = make_session()
    path = tmp_path / "leader.wal"
    stream_wal(path, g, n_batches=3)
    target = [e for e in scan_wal_entries(path)[0]
              if e["kind"] == "batch"][1]  # the version-2 record
    with open(path, "r+b") as f:  # flip one payload byte at rest
        f.seek(target["offset"] + _REC_HDR.size + 3)
        b = f.read(1)
        f.seek(-1, 1)
        f.write(bytes([b[0] ^ 0xFF]))
    reg = MetricsRegistry()
    scrub = WalScrubber(path, obs=reg)
    new = scrub.scrub_once()
    assert len(new) == 1
    f = new[0]
    assert f.source == "scrub" and f.version == 2 \
        and f.wal_offset == target["offset"]
    assert scrub.corruptions == 1
    assert reg.snapshot()["repro_wal_scrub_corruptions_total"][
        "values"][0]["value"] == 1.0
    # deduped: the same rot is not re-reported every sweep
    assert scrub.scrub_once() == []
    assert scrub.corruptions == 1 and scrub.sweeps == 2


def test_scrubber_clean_log_zero_false_positives(tmp_path):
    g, _ = make_session()
    path = tmp_path / "leader.wal"
    stream_wal(path, g, n_batches=4, digest_results=True)
    scrub = WalScrubber(path)
    for _ in range(3):
        assert scrub.scrub_once() == []
    assert scrub.corruptions == 0
    assert scrub.records_verified == 3 * 8  # 4 batches + 4 digests/sweep


def test_scrubber_never_judges_the_unsealed_tail(tmp_path):
    """Only records wholly below the fsync high-water mark are judged: a
    garbage in-flight tail is a crash artifact, not corruption."""
    g, _ = make_session()
    path = tmp_path / "live.wal"
    rng = np.random.default_rng(0)
    wal = WriteAheadLog(path, fsync_every=1)
    wal.append(mixed(g, rng, 3, 1), version=1)
    sealed = wal.synced_size
    assert sealed == os.path.getsize(path)
    # written-but-unsynced garbage past the mark (fsync_every now huge)
    wal.fsync_every = 10**9
    wal.fsync_interval_s = 10**9
    wal._f.write(b"\xde\xad\xbe\xef" * 8)
    wal._f.flush()
    assert wal.synced_size == sealed < os.path.getsize(path)
    scrub = WalScrubber(wal)
    assert scrub.scrub_once() == []
    assert scrub.corruptions == 0 and scrub.records_verified == 1
    wal._f.close()


def test_scrubber_background_thread_detects(tmp_path):
    g, _ = make_session()
    path = tmp_path / "leader.wal"
    stream_wal(path, g, n_batches=2)
    entry = scan_wal_entries(path)[0][0]
    with open(path, "r+b") as f:
        f.seek(entry["offset"] + _REC_HDR.size)
        b = f.read(1)
        f.seek(-1, 1)
        f.write(bytes([b[0] ^ 0x01]))
    found = threading.Event()
    scrub = WalScrubber(path, interval_s=0.01)
    with scrub:
        for _ in range(500):
            if scrub.corruptions:
                found.set()
                break
            threading.Event().wait(0.01)
    assert found.is_set()
    assert scrub.findings[0].wal_offset == entry["offset"]


# ---------------------------------------------------------------------- #
#  Shadow auditor
# ---------------------------------------------------------------------- #
def test_auditor_clean_run_zero_mismatches_zero_recompiles():
    g, sess = make_session()
    svc = AsyncWindowService(sess, bucket=8)
    aud = ShadowAuditor(sample_rate=1.0, full_row_rate=1.0)
    svc.attach_auditor(aud)
    aud.start()
    rng = np.random.default_rng(0)
    svc.query(0, vertex=1)  # warm every executor before counting
    svc.query(1)
    before = api.recompile_count()
    for _ in range(3):
        svc.update(mixed(svc.session.graph, rng, 3, 1))
        for v in (1, 5, 9):
            svc.query(0, vertex=v)
        svc.query(1)
    assert aud.drain(30)
    aud.stop()
    assert aud.sampled > 0 and aud.audited == aud.sampled
    assert aud.mismatches == 0 and aud.findings == []
    assert api.recompile_count() == before  # auditing is recompile-free
    assert svc.debug_report()["audit"]["mismatches"] == 0


def test_auditor_detects_corrupted_served_vector():
    g, sess = make_session()
    reg = MetricsRegistry()
    svc = AsyncWindowService(sess, bucket=8, obs=reg)
    aud = ShadowAuditor(sample_rate=1.0, obs=reg)
    svc.attach_auditor(aud)
    aud.start()
    svc.query(0)  # warm the cache's full vector for group 0
    svc.cache._entries[0]["vectors"]["sum"][7] += 1.0  # one poisoned cell
    t = svc.submit(0, vertex=7)
    svc.flush()
    t.get(timeout=5)  # serving itself is oblivious: the hit is served
    assert aud.drain(30)
    aud.stop()
    assert aud.mismatches == 1
    f = aud.findings[0]
    assert f.source == "oracle" and f.vertex == 7 and f.version == 0
    assert f.spec == "khop[2]/sum@val"
    assert f.expected != f.got and len(f.expected) == len(f.got) == 4
    d = f.to_dict()
    assert bytes.fromhex(d["expected"]) == f.expected
    assert reg.snapshot()["repro_audit_mismatches_total"][
        "values"][0]["value"] == 1.0
    assert any(e["event"] == "audit" for e in svc.flight.dump())


def test_auditor_sampling_rate_is_exact_and_never_blocks():
    g, sess = make_session()
    svc = AsyncWindowService(sess, bucket=8)
    # worker NOT started and queue of 2: the 3rd+ sample must drop, and no
    # Ticket.get may ever wait on the audit queue
    aud = ShadowAuditor(sample_rate=1.0, max_queue=2)
    svc.attach_auditor(aud)
    for v in range(8):
        t = svc.submit(0, vertex=v)
        svc.flush()
        t.get(timeout=1.0)  # would deadlock if sampling blocked serving
    assert aud.sampled == 8
    assert aud.dropped_samples == 6 and aud._q.qsize() == 2
    # error-diffusion accumulator: 25% of 8 point reads = exactly 2
    aud2 = ShadowAuditor(sample_rate=0.25, max_queue=64)
    svc2 = AsyncWindowService(Session(g, SPECS, use_pallas=False), bucket=8)
    svc2.attach_auditor(aud2)
    for v in range(8):
        svc2.submit(0, vertex=v)
    svc2.flush()
    assert aud2.sampled == 2


# ---------------------------------------------------------------------- #
#  Health monitor + endpoint
# ---------------------------------------------------------------------- #
class _StubReplica:
    divergence = None
    lag = {"behind_bytes": 0, "unpublished_versions": 0}
    stats = {}


class _StubAuditor:
    mismatches = 0
    stats = {}


def test_health_state_machine_soft_vs_hard():
    reg = MetricsRegistry()
    rep, aud = _StubReplica(), _StubAuditor()
    mon = HealthMonitor(replicas=[rep], auditors=[aud], obs=reg,
                        max_lag_bytes=100)
    assert mon.check()["state"] == "ready" and mon.ready
    # soft failure (lag) degrades but does not fail
    rep.lag = {"behind_bytes": 10_000, "unpublished_versions": 0}
    r = mon.check()
    assert r["state"] == "degraded" and not r["ready"] and r["live"]
    assert r["failing"] == ["replica_lag"]
    # hard failure (audit finding) fails even with the soft one cleared
    rep.lag = {"behind_bytes": 0, "unpublished_versions": 0}
    aud.mismatches = 2
    r = mon.check()
    assert r["state"] == "failed" and r["failing"] == ["audit"]
    # divergence is hard too
    aud.mismatches = 0
    rep.divergence = AuditFinding(source="digest", version=3, wal_offset=99,
                                  detail="graph_crc: ...")
    r = mon.check()
    assert r["state"] == "failed" and r["failing"] == ["replica_divergence"]
    snap = reg.snapshot()
    assert snap["repro_health_ready"]["values"][0]["value"] == 0.0
    assert snap["repro_health_live"]["values"][0]["value"] == 1.0


def test_health_endpoint_round_trip_tier1_smoke():
    """CI smoke: ephemeral-port boot, /metrics + /readyz round-trip, and
    readiness flips to 503 when a finding lands."""
    reg, _ = obs.enable()
    g, sess = make_session(n=40)
    svc = AsyncWindowService(sess, bucket=8, obs=reg)
    svc.query(0, vertex=1)
    aud = ShadowAuditor(obs=reg)
    svc.attach_auditor(aud)
    mon = HealthMonitor(service=svc, auditors=[aud], obs=reg)
    with HealthServer(mon) as hs:
        assert hs.running and hs.port > 0
        r = urllib.request.urlopen(hs.url + "/readyz", timeout=5)
        assert r.status == 200
        body = json.loads(r.read())
        assert body["ready"] is True and body["state"] == "ready"
        metrics = urllib.request.urlopen(
            hs.url + "/metrics", timeout=5).read().decode()
        assert "repro_health_ready 1" in metrics
        assert "repro_flushes_total" in metrics
        r = urllib.request.urlopen(hs.url + "/healthz", timeout=5)
        assert json.loads(r.read())["live"] is True
        dbg = json.loads(urllib.request.urlopen(
            hs.url + "/debug", timeout=5).read())
        assert dbg["health"]["state"] == "ready"
        assert "stats" in dbg["service"]
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(hs.url + "/nope", timeout=5)
        assert ei.value.code == 404
        # a quarantined finding flips readiness to 503 (liveness stays 200)
        aud.mismatches = 1
        aud.findings.append(AuditFinding(source="oracle", version=1))
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(hs.url + "/readyz", timeout=5)
        assert ei.value.code == 503
        assert json.loads(ei.value.read())["failing"] == ["audit"]
        r = urllib.request.urlopen(hs.url + "/healthz", timeout=5)
        assert r.status == 200
    assert not hs.running


def test_health_monitor_registered_for_failure_artifacts():
    from repro.serve.health import all_monitors

    mon = HealthMonitor()
    assert mon in all_monitors()
    assert mon.report()["state"] == "ready"  # report() runs a first check


# ---------------------------------------------------------------------- #
#  Wire-format digest stamp
# ---------------------------------------------------------------------- #
def test_wire_message_plan_crc_round_trips():
    from repro.distributed.window_runtime import (
        decode_wire_message,
        encode_wire_message,
    )

    msg = {
        "kind": "patch", "num_blocks": 2, "patches": [],
        "block_ids": np.empty(0, np.int64),
        "block_sizes": np.empty(0, np.int32),
        "e1_ids": np.empty(0, np.int64), "e1_rows": None,
        "e2_ids": np.empty(0, np.int64), "e2_rows": None,
        "plan_crc": 0xDEADBEEF,
    }
    out = decode_wire_message(encode_wire_message(msg))
    assert out["plan_crc"] == 0xDEADBEEF
    # a stamp-free message stays stamp-free (pre-digest compatibility)
    del msg["plan_crc"]
    assert "plan_crc" not in decode_wire_message(encode_wire_message(msg))
