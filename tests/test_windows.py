"""Window semantics: batched bitset BFS == per-vertex BFS == paper examples."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: use the local shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.graph import Graph
from repro.core.windows import (
    KHopWindow,
    TopologicalWindow,
    khop_window_single,
    khop_windows,
    topological_window_single,
    topological_windows,
)
from repro.graphs.generators import erdos_renyi, random_dag


def test_paper_example_1hop(paper_social_graph):
    g = paper_social_graph
    wins = khop_windows(g, 1)
    # W(E) = {A, C, E} (paper §3); ids: A=0..F=5, E=4
    assert set(wins[4].tolist()) == {0, 2, 4}
    # W(B) = {A, B, D, F}
    assert set(wins[1].tolist()) == {0, 1, 3, 5}
    # W(C) = {A, C, D, E, F}
    assert set(wins[2].tolist()) == {0, 2, 3, 4, 5}


def test_paper_example_2hop(paper_social_graph):
    wins = khop_windows(paper_social_graph, 2)
    # 2-hop window of E is everything (paper §3)
    assert set(wins[4].tolist()) == {0, 1, 2, 3, 4, 5}


@pytest.mark.parametrize("k", [1, 2, 3])
def test_khop_batched_equals_single(small_undirected, k):
    g = small_undirected
    wins = khop_windows(g, k)
    for v in range(0, g.n, 17):
        assert np.array_equal(wins[v], khop_window_single(g, k, v)), v


@pytest.mark.parametrize("k", [1, 2])
def test_khop_directed(small_directed, k):
    g = small_directed
    wins = khop_windows(g, k)
    for v in range(0, g.n, 23):
        assert np.array_equal(wins[v], khop_window_single(g, k, v)), v


def test_topological_windows(small_dag):
    g = small_dag
    wins = topological_windows(g)
    for v in range(0, g.n, 13):
        assert np.array_equal(wins[v], topological_window_single(g, v)), v


def test_window_contains_self(small_undirected):
    wins = khop_windows(small_undirected, 1)
    for v in range(small_undirected.n):
        assert v in wins[v]


def test_topo_containment_theorem(small_dag):
    """Theorem 5.1: W_t(parent) subset of W_t(child)."""
    g = small_dag
    wins = topological_windows(g)
    for e in range(0, g.n_edges, 7):
        u, v = int(g.src[e]), int(g.dst[e])
        assert set(wins[u].tolist()) <= set(wins[v].tolist())


@settings(max_examples=20, deadline=None)
@given(st.integers(20, 80), st.integers(2, 6), st.integers(0, 10_000))
def test_khop_property_random_graphs(n, deg, seed):
    g = erdos_renyi(n, float(deg), directed=False, seed=seed)
    wins = khop_windows(g, 2)
    for v in range(0, n, max(n // 5, 1)):
        assert np.array_equal(wins[v], khop_window_single(g, 2, v))


@settings(max_examples=20, deadline=None)
@given(st.integers(20, 80), st.integers(1, 4), st.integers(0, 10_000))
def test_topo_property_random_dags(n, deg, seed):
    g = random_dag(n, float(deg), seed=seed)
    wins = topological_windows(g)
    for v in range(0, n, max(n // 5, 1)):
        assert np.array_equal(wins[v], topological_window_single(g, v))
