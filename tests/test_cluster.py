"""Replica cluster tier (ISSUE 10): WAL segment rotation + snapshot
checkpoints, the freshness/load router with MVCC pinning and failover,
checkpoint+tail rejoin, and SLO-adaptive batching.

The fault-injection tests follow the repo's pattern: each injects exactly
one fault (a torn record tail at a segment boundary, an empty trailing
segment left by a kill mid-rotation, a corrupted checkpoint byte, a dead
replica with in-flight tickets) and asserts the recovery path is exact —
bitwise-identical results, only the in-flight tickets of the dead replica
failed, only the torn tail truncated and never a sealed segment skipped.
Everything is wall-clock-free: replica catch-up is stepped explicitly and
the SLO controller runs on synthesized windows with an injected clock.
"""

import json
import os
import urllib.request

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro import obs  # noqa: E402
from repro.core import api  # noqa: E402
from repro.core.api import QuerySpec, Session  # noqa: E402
from repro.core.windows import KHopWindow  # noqa: E402
from repro.graphs.generators import erdos_renyi  # noqa: E402
from repro.obs import MetricsRegistry  # noqa: E402
from repro.serve import (  # noqa: E402
    AsyncWindowService,
    CheckpointCorruptError,
    CheckpointDigestError,
    HealthMonitor,
    HealthServer,
    ReadReplica,
    ReplicaFailedError,
    ReplicaSet,
    RoutingError,
    SegmentedWriteAheadLog,
    SLOController,
    WalTruncatedError,
    WindowRouter,
    latest_checkpoint,
    list_checkpoints,
    load_checkpoint,
    read_segmented_records,
    scan_segmented_entries,
    seek_segmented,
)
from repro.serve.checkpoint import save_checkpoint, write_checkpoint  # noqa: E402
from repro.serve.wal import (  # noqa: E402
    list_segments,
    read_wal_records,
    scan_wal_entries,
)

from test_updates import mixed  # noqa: E402


@pytest.fixture(autouse=True)
def _obs_off():
    obs.disable()
    yield
    obs.disable()


def int_graph(n, deg, seed):
    g = erdos_renyi(n, deg, directed=False, seed=seed)
    vals = np.random.default_rng(seed + 1).integers(0, 50, g.n)
    return g.with_attr("val", vals.astype(np.float64))


SPECS = [QuerySpec(KHopWindow(2), "sum"), QuerySpec(KHopWindow(2), "min")]


def make_batches(g, n_batches, seed=0):
    """A deterministic batch stream starting from ``g`` (each batch built
    against the graph state the previous one produced)."""
    rng = np.random.default_rng(seed)
    sess = Session(g, [], use_pallas=False)
    out = []
    for _ in range(n_batches):
        b = mixed(sess.graph, rng, 4, 2)
        out.append(b)
        sess.update(b)
    return out


def fill_segments(directory, g, n_batches=7, rotate_records=2, seed=0):
    """Append a deterministic stream through a rotating WAL; returns the
    closed log's segment listing and the batches."""
    batches = make_batches(g, n_batches, seed=seed)
    with SegmentedWriteAheadLog(directory,
                                rotate_records=rotate_records) as wal:
        for b in batches:
            v = wal.append(b)
            wal.append_digest({"version": v, "graph_crc": 0}, version=v)
        wal.sync()
        segs = wal.segments()
    return segs, batches


# ---------------------------------------------------------------------- #
#  Segment rotation, tailing cursors, torn tails (satellite 2)
# ---------------------------------------------------------------------- #
def test_segment_rotation_names_and_replay(tmp_path):
    g = int_graph(40, 2.0, seed=3)
    segs, batches = fill_segments(tmp_path / "wal", g, n_batches=7,
                                  rotate_records=2)
    # rotation is decided before each batch append: 2 records (plus the
    # digest that must share its segment) per sealed segment
    assert [b for b, _ in segs] == [1, 3, 5, 7]
    assert [os.path.basename(p) for _, p in segs] == [
        f"{b:012d}.wal" for b in (1, 3, 5, 7)]
    for base, path in segs:
        recs, _ = read_wal_records(path)
        assert [v for v, _ in recs][0] == base
    got = read_segmented_records(tmp_path / "wal")
    assert [v for v, _ in got] == list(range(1, 8))
    # a record and its digest attestation always share a segment
    entries, _ = scan_segmented_entries(tmp_path / "wal")
    seg_of = {}
    for e in entries:
        seg_of.setdefault((e["version"], e["kind"]), e["segment"])
    for v in range(1, 8):
        assert seg_of[(v, "batch")] == seg_of[(v, "digest")]


def test_cursor_tails_across_segment_boundaries(tmp_path):
    g = int_graph(40, 2.0, seed=4)
    batches = make_batches(g, 6, seed=1)
    wal = SegmentedWriteAheadLog(tmp_path / "wal", rotate_records=2)
    wal.append(batches[0])
    wal.sync()
    entries, cur = scan_segmented_entries(tmp_path / "wal", None)
    assert [e["version"] for e in entries] == [1]
    for b in batches[1:]:
        wal.append(b)
    wal.sync()
    # resume from the saved cursor: only the new records, in order,
    # crossing two sealed boundaries
    entries, cur2 = scan_segmented_entries(tmp_path / "wal", cur)
    assert [e["version"] for e in entries] == [2, 3, 4, 5, 6]
    assert cur2[0] == wal.active_base
    # nothing new: scan is idempotent at the head
    entries, cur3 = scan_segmented_entries(tmp_path / "wal", cur2)
    assert entries == [] and cur3 == cur2
    wal.close()


def test_seek_segmented_bounds_and_truncation_error(tmp_path):
    g = int_graph(40, 2.0, seed=5)
    fill_segments(tmp_path / "wal", g, n_batches=7, rotate_records=2)
    for after in range(0, 8):
        entries, _ = scan_segmented_entries(
            tmp_path / "wal", seek_segmented(tmp_path / "wal", after))
        vs = [e["version"] for e in entries if e["kind"] == "batch"]
        assert vs == list(range(after + 1, 8))
    # delete the oldest segment: history before version 3 is gone
    segs = list_segments(tmp_path / "wal")
    os.unlink(segs[0][1])
    assert seek_segmented(tmp_path / "wal", 2) is not None
    with pytest.raises(WalTruncatedError):
        seek_segmented(tmp_path / "wal", 0)
    with pytest.raises(WalTruncatedError):
        scan_segmented_entries(tmp_path / "wal", (1, 8))


def test_torn_tail_truncates_only_last_segment(tmp_path):
    """Kill mid-append: the partial final record is torn from the LAST
    segment only; sealed segments keep every byte."""
    g = int_graph(40, 2.0, seed=6)
    segs, _ = fill_segments(tmp_path / "wal", g, n_batches=5,
                            rotate_records=2)
    sealed_sizes = {p: os.path.getsize(p) for _, p in segs[:-1]}
    last_path = segs[-1][1]
    entries, _ = scan_wal_entries(last_path)
    rec5 = next(e for e in entries
                if e["kind"] == "batch" and e["version"] == 5)
    with open(last_path, "r+b") as f:  # tear record 5 mid-payload
        f.truncate(rec5["offset"] + 10)
    wal = SegmentedWriteAheadLog(tmp_path / "wal", rotate_records=2)
    assert wal._active.torn_truncations == 1
    assert wal.last_version == 4  # record 5 lost with its torn tail
    for p, size in sealed_sizes.items():
        assert os.path.getsize(p) == size  # sealed segments untouched
    # the log keeps appending where the surviving history ends
    nxt = make_batches(g, 5, seed=0)[4]  # any well-formed batch
    assert wal.append(nxt) == 5
    wal.close()
    assert [v for v, _ in read_segmented_records(tmp_path / "wal")] == \
        [1, 2, 3, 4, 5]


def test_empty_trailing_segment_adopted_as_active(tmp_path):
    """Kill mid-rotation: the new segment file exists but is empty.  On
    resume it becomes the active segment (base - 1 is the last durable
    version) and no sealed history is skipped."""
    g = int_graph(40, 2.0, seed=7)
    fill_segments(tmp_path / "wal", g, n_batches=4, rotate_records=2)
    open(os.path.join(str(tmp_path / "wal"), "000000000005.wal"),
         "wb").close()
    wal = SegmentedWriteAheadLog(tmp_path / "wal", rotate_records=2)
    assert wal.active_base == 5 and wal.last_version == 4
    nxt = make_batches(g, 5, seed=0)[4]
    assert wal.append(nxt) == 5
    wal.sync()
    assert [v for v, _ in read_segmented_records(tmp_path / "wal")] == \
        [1, 2, 3, 4, 5]
    wal.close()


def test_torn_sealed_segment_refuses_resume(tmp_path):
    """A torn tail in a SEALED segment is real corruption (seals are
    fsynced before the next segment exists): resume must refuse rather
    than silently skip history."""
    g = int_graph(40, 2.0, seed=8)
    segs, _ = fill_segments(tmp_path / "wal", g, n_batches=5,
                            rotate_records=2)
    base, sealed_path = segs[1]
    with open(sealed_path, "r+b") as f:
        f.truncate(os.path.getsize(sealed_path) - 5)
    open(os.path.join(str(tmp_path / "wal"), "000000000099.wal"),
         "wb").close()  # plus an empty trailing segment: still refuse
    with pytest.raises(ValueError, match="torn|corrupt"):
        SegmentedWriteAheadLog(tmp_path / "wal", rotate_records=2)


def test_truncate_upto_never_splits_or_kills_active(tmp_path):
    g = int_graph(40, 2.0, seed=9)
    batches = make_batches(g, 7, seed=2)
    wal = SegmentedWriteAheadLog(tmp_path / "wal", rotate_records=2)
    for b in batches:
        wal.append(b)
    wal.sync()
    assert [b for b, _ in wal.segments()] == [1, 3, 5, 7]
    # version 3 falls mid-segment [3,4]: only segment 1 qualifies
    removed = wal.truncate_upto(3)
    assert [b for b, _ in removed] == [1]
    assert [b for b, _ in wal.segments()] == [3, 5, 7]
    # the active segment is never deleted even when wholly covered
    wal.truncate_upto(10 ** 9)
    assert [b for b, _ in wal.segments()] == [7]
    assert wal.truncated_segments == 3
    assert [v for v, _ in read_segmented_records(tmp_path / "wal", 6)] == [7]
    wal.close()


# ---------------------------------------------------------------------- #
#  Checkpoints: codec, verification, bounded-tail recovery
# ---------------------------------------------------------------------- #
def test_checkpoint_roundtrip_bitwise(tmp_path):
    g = int_graph(50, 2.5, seed=11)
    s = Session(g, SPECS, use_pallas=False)
    batches = make_batches(g, 3, seed=3)
    for b in batches:
        s.update(b)
    version, path = save_checkpoint(s, tmp_path / "ck")
    assert version == 3 and os.path.basename(path) == \
        "ckpt-000000000003.gckp"
    got_version, got_graph, digest = load_checkpoint(path)
    assert got_version == 3 and "graph_crc" in digest
    for a, b in ((s.graph.src, got_graph.src), (s.graph.dst, got_graph.dst),
                 (s.graph.attrs["val"], got_graph.attrs["val"])):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
    restored = Session.from_checkpoint(path, SPECS, use_pallas=False)
    assert restored.version == 3
    for mine, theirs in zip(s.run(), restored.run()):
        assert np.asarray(mine).tobytes() == np.asarray(theirs).tobytes()
    assert latest_checkpoint(tmp_path / "ck") == (3, path)
    assert latest_checkpoint(tmp_path / "ck", upto_version=2) is None


def test_checkpoint_corruption_is_attributed(tmp_path):
    g = int_graph(50, 2.5, seed=12)
    s = Session(g, SPECS, use_pallas=False)
    _, path = save_checkpoint(s, tmp_path / "ck")
    # flip one payload byte: the owning section's CRC catches it
    data = bytearray(open(path, "rb").read())
    data[-10] ^= 0xFF
    open(path, "wb").write(bytes(data))
    with pytest.raises(CheckpointCorruptError, match="crc mismatch"):
        load_checkpoint(path)
    # internally consistent but stamped with a digest for different
    # state: the digest check catches what the CRCs cannot
    lie = os.path.join(str(tmp_path / "ck"), "ckpt-000000000009.gckp")
    write_checkpoint(lie, 9, g, digest={"graph_crc": 12345})
    with pytest.raises(CheckpointDigestError, match="graph_crc"):
        load_checkpoint(lie)


def test_restore_from_wal_checkpoint_bounded_tail(tmp_path):
    g = int_graph(50, 2.5, seed=13)
    batches = make_batches(g, 6, seed=4)
    leader = Session(g, SPECS, use_pallas=False)
    wal = SegmentedWriteAheadLog(tmp_path / "wal", rotate_records=2)
    for i, b in enumerate(batches):
        wal.append(b)
        leader.update(b)
        if i == 3:
            save_checkpoint(leader, tmp_path / "ck")
    wal.sync()
    wal.close()
    oracle = [np.asarray(r).tobytes() for r in leader.run()]

    full = Session.restore_from_wal(g, SPECS, tmp_path / "wal",
                                    use_pallas=False)
    fast = Session.restore_from_wal(g, SPECS, tmp_path / "wal",
                                    checkpoint=tmp_path / "ck",
                                    use_pallas=False)
    assert full.version == fast.version == 6
    for s in (full, fast):
        assert [np.asarray(r).tobytes() for r in s.run()] == oracle
    # point-in-time recovery picks a checkpoint at-or-below the target
    pit = Session.restore_from_wal(g, SPECS, tmp_path / "wal",
                                   upto_version=5,
                                   checkpoint=tmp_path / "ck",
                                   use_pallas=False)
    assert pit.version == 5
    # after truncating below the checkpoint, full replay is impossible
    # but checkpoint + bounded tail still restores bitwise
    with SegmentedWriteAheadLog(tmp_path / "wal", rotate_records=2) as w2:
        w2.truncate_upto(4)
    with pytest.raises(WalTruncatedError):
        Session.restore_from_wal(g, SPECS, tmp_path / "wal",
                                 use_pallas=False)
    fast2 = Session.restore_from_wal(g, SPECS, tmp_path / "wal",
                                     checkpoint=tmp_path / "ck",
                                     use_pallas=False)
    assert [np.asarray(r).tobytes() for r in fast2.run()] == oracle


# ---------------------------------------------------------------------- #
#  Replicas tailing a segmented log
# ---------------------------------------------------------------------- #
def test_replica_tails_segments_with_cursor(tmp_path):
    g = int_graph(50, 2.5, seed=14)
    batches = make_batches(g, 6, seed=5)
    leader = Session(g, SPECS, use_pallas=False)
    wal = SegmentedWriteAheadLog(tmp_path / "wal", rotate_records=2)
    rep = ReadReplica(g, SPECS, tmp_path / "wal", use_pallas=False)
    assert rep.cursor["segment"] == 0 and rep.cursor["offset"] == 0
    for b in batches[:3]:
        wal.append(b)
        leader.update(b)
    wal.sync()
    assert rep.catch_up() == 3
    assert rep.version == 3 and rep.cursor["segment"] == wal.active_base
    for b in batches[3:]:
        wal.append(b)
        leader.update(b)
    wal.sync()
    # hold at a point-in-time version: the cursor only advances past
    # applied records, so the remainder is consumed by the next poll
    rep.poll(upto_version=5)
    rep.flip()
    assert rep.version == 5
    assert rep.catch_up() == 1 and rep.version == 6
    for mine, theirs in zip(leader.run(), rep.session.run()):
        assert np.asarray(mine).tobytes() == np.asarray(theirs).tobytes()
    wal.close()


def test_replica_survives_truncation_of_consumed_segments(tmp_path):
    """Truncation deletes a sealed segment a caught-up replica's cursor
    still points into: the replica must re-seek from its own head, not
    error (only a cursor genuinely behind the truncation raises)."""
    g = int_graph(50, 2.5, seed=15)
    batches = make_batches(g, 6, seed=6)
    wal = SegmentedWriteAheadLog(tmp_path / "wal", rotate_records=2)
    rep = ReadReplica(g, SPECS, tmp_path / "wal", use_pallas=False)
    lagger = ReadReplica(g, SPECS, tmp_path / "wal", use_pallas=False,
                         name="lagger")
    for b in batches[:4]:
        wal.append(b)
    wal.sync()
    assert rep.catch_up() == 4
    lagger.poll(upto_version=1)  # stuck replica, cursor in segment 1
    wal.truncate_upto(4)  # rep's cursor segment [3,4] is deleted
    for b in batches[4:]:
        wal.append(b)
    wal.sync()
    assert rep.catch_up() == 2 and rep.version == 6
    with pytest.raises(WalTruncatedError, match="history"):
        lagger.poll()


def test_replica_rejoins_from_checkpoint_bitwise(tmp_path):
    g = int_graph(50, 2.5, seed=16)
    batches = make_batches(g, 6, seed=7)
    leader = Session(g, SPECS, use_pallas=False)
    wal = SegmentedWriteAheadLog(tmp_path / "wal", rotate_records=2)
    for i, b in enumerate(batches):
        v = wal.append(b)
        leader.update(b)
        wal.append_digest(leader.digest(), version=v)
        if i == 3:
            save_checkpoint(leader, tmp_path / "ck")
    wal.sync()
    wal.truncate_upto(4)  # the full history is no longer replayable
    rep = ReadReplica.from_checkpoint(
        SPECS, tmp_path / "wal", tmp_path / "ck", name="back",
        use_pallas=False)
    assert rep.restored_from_version == 4
    assert not rep.check_plan_digest  # fresh plan bytes are legitimate
    assert rep.catch_up() == 2 and rep.version == 6
    assert rep.divergence is None  # graph digests verified along the tail
    for mine, theirs in zip(leader.run(), rep.session.run()):
        assert np.asarray(mine).tobytes() == np.asarray(theirs).tobytes()
    wal.close()


# ---------------------------------------------------------------------- #
#  ReplicaSet + router: the 20-batch acceptance stream
# ---------------------------------------------------------------------- #
def test_cluster_stream_bitwise_with_rotation_kill_rejoin(tmp_path):
    """One sustained stream with everything on: rotation, checkpoints,
    truncation, a mid-stream kill + checkpoint rejoin — every routed read
    bitwise-identical to a mirror session pinned at the ticket's version,
    zero recompiles on the serving path."""
    g = int_graph(60, 2.5, seed=17)
    rs = ReplicaSet(g, SPECS, tmp_path / "c", n_replicas=2,
                    rotate_records=4, checkpoint_every=5,
                    use_pallas=False)
    mirror = Session(g, SPECS, use_pallas=False)  # the bitwise oracle
    history = {0: [np.asarray(r).tobytes() for r in mirror.run()]}
    rng = np.random.default_rng(18)
    recompiles_before = None  # snapshot after the first-batch warm-up
    for i in range(20):
        # edge-neutral churn: the capacity plans never need to grow, so
        # the zero-retrace steady state holds across the whole stream
        b = mixed(mirror.graph, rng, 4, 4)
        mirror.update(b)
        history[mirror.version] = [
            np.asarray(r).tobytes() for r in mirror.run()]
        rs.update(b)
        rs.sync()
        if i == 7:
            assert rs.kill("r0") >= 0
        if i == 12:
            rep = rs.rejoin("r0")
            assert rep.restored_from_version >= 5  # checkpoint, not base
            rs.sync()
        for name, rep in rs.replicas.items():
            if not rep.alive:
                continue
            assert rep.divergence is None
            assert history[rep.version] == [
                np.asarray(r).tobytes()
                for r in rep.service._active.run()]
        # a routed read answers exactly what a pinned session answers
        t = rs.router.submit(0, vertex=int(rng.integers(mirror.graph.n)))
        rs.router.flush()
        got = t.get(timeout=10)
        pinned = np.frombuffer(history[t.version][0], dtype=np.float32)
        assert got == pinned[t.vertex]
        if i == 0:  # serving executors warmed: steady state from here on
            recompiles_before = api.run_many_cache_size()
    assert rs.version == 20
    assert rs.wal.rotations >= 3
    assert rs.wal.truncated_segments >= 1
    assert rs.last_checkpoint_version >= 20 - 5
    assert len(list_checkpoints(rs.checkpoint_dir)) >= 2
    # the zero-retrace serving contract: the batched serving executors
    # never recompiled across rotation, checkpointing, kill and rejoin
    # (full Session.run() oracle replays above are allowed to trace —
    # fresh plans have fresh shapes — exactly like the serving bench)
    assert api.run_many_cache_size() == recompiles_before
    # full-graph routed reads too, at the final version
    full = rs.router.query(1, request_class="interactive")
    assert np.asarray(full).tobytes() == history[20][1]
    rs.close()


def test_router_prefers_freshest_then_least_loaded(tmp_path):
    g = int_graph(50, 2.5, seed=19)
    rs = ReplicaSet(g, SPECS, tmp_path / "c", n_replicas=3,
                    use_pallas=False)
    batches = make_batches(g, 3, seed=8)
    for b in batches:
        rs.update(b)
    rs.wal.sync()
    # r0/r1 catch up fully; r2 stays behind at version 1
    rs.replicas["r0"].catch_up()
    rs.replicas["r1"].catch_up()
    rs.replicas["r2"].poll(upto_version=1)
    rs.replicas["r2"].flip()
    # submits spread across the freshest pool by per-class load and
    # never land on the stale r2
    t_a = rs.router.submit(0, vertex=1)
    t_b = rs.router.submit(0, vertex=2)
    assert {t_a._route_target, t_b._route_target} == {"r0", "r1"}
    # a min_version only r2 cannot meet excludes exactly r2
    assert rs.router.pick("point", min_version=2) in ("r0", "r1")
    # a min_version nobody meets falls back to the writer
    assert rs.router.pick("point", min_version=3) in ("r0", "r1")
    rs.router.flush()
    rs.close()


def test_router_min_version_fallback_and_routing_error(tmp_path):
    g = int_graph(50, 2.5, seed=20)
    rs = ReplicaSet(g, SPECS, tmp_path / "c", n_replicas=1,
                    use_pallas=False)
    for b in make_batches(g, 2, seed=9):
        rs.update(b)
    rs.wal.sync()
    rs.replicas["r0"].poll(upto_version=1)
    rs.replicas["r0"].flip()
    # fresher than any replica: served by the writer instead of failing
    t = rs.router.submit(0, vertex=3, min_version=2)
    assert t._route_target is None
    rs.router.flush()
    assert t.get(timeout=10) is not None and t.version >= 2
    # fresher than even the writer: refuse loudly
    with pytest.raises(RoutingError, match="min_version"):
        rs.router.submit(0, vertex=3, min_version=99)
    rs.close()


def test_router_excludes_diverged_and_dead_replicas(tmp_path):
    g = int_graph(50, 2.5, seed=21)
    rs = ReplicaSet(g, SPECS, tmp_path / "c", n_replicas=2,
                    use_pallas=False)
    for b in make_batches(g, 2, seed=10):
        rs.update(b)
    rs.sync()
    from repro.obs.audit import AuditFinding
    rs.replicas["r0"].divergence = AuditFinding(
        source="digest", version=2, expected=b"x", got=b"y", detail="test")
    assert rs.router.pick("point") == "r1"
    rs.replicas["r1"].kill()
    assert rs.router.pick("point") is None  # writer fallback only
    rs.close()


def test_failover_fails_exactly_the_dead_replicas_tickets(tmp_path):
    g = int_graph(50, 2.5, seed=22)
    reg = MetricsRegistry()
    rs = ReplicaSet(g, SPECS, tmp_path / "c", n_replicas=2,
                    use_pallas=False, obs=reg)
    for b in make_batches(g, 2, seed=11):
        rs.update(b)
    rs.sync()
    doomed = [rs.router.submit(0, vertex=v, target="r0") for v in (1, 2, 3)]
    safe = [rs.router.submit(0, vertex=v, target="r1") for v in (4, 5)]
    assert rs.kill("r0") == 3
    for t in doomed:
        assert t.failed
        with pytest.raises(ReplicaFailedError):
            t.get(timeout=1)
    for t in safe:  # the other replica's in-flight work is untouched
        assert not t.failed
    rs.router.flush()
    mirror = Session.restore_from_wal(g, SPECS, rs.wal_dir,
                                      use_pallas=False)
    expected = np.asarray(mirror.run()[0])
    for t in safe:
        assert t.get(timeout=10) == expected[t.vertex]
    # failed-out replicas never get new placements
    with pytest.raises(ReplicaFailedError):
        rs.router.submit(0, vertex=6, target="r0")
    snap = reg.snapshot()
    assert snap["repro_router_failovers_total"]["values"][0]["value"] == 1.0
    assert snap["repro_router_failover_tickets_total"][
        "values"][0]["value"] == 3.0
    rs.close()


# ---------------------------------------------------------------------- #
#  SLO-adaptive batching (wall-clock-free)
# ---------------------------------------------------------------------- #
def _slo_window(svc, cls, n, within):
    """Synthesize one scoring window: ``n`` ok tickets, attaining the
    class target iff ``within``."""
    target_s = svc.classes[cls].max_delay_ms / 1e3
    lat = target_s * (0.5 if within else 2.0)
    for _ in range(n):
        svc.slo.observe(cls, lat, target_s=target_s, outcome="ok")


def test_slo_controller_converges_within_declared_bounds(tmp_path):
    g = int_graph(40, 2.0, seed=23)
    reg = MetricsRegistry()
    clock = {"t": 0.0}
    svc = AsyncWindowService(Session(g, SPECS, use_pallas=False),
                             bucket=4, obs=reg,
                             now_fn=lambda: clock["t"])
    ctl = SLOController(svc, min_samples=4, hysteresis=2,
                        min_delay_ms=0.25, obs=reg)
    declared = svc.classes["interactive"].max_delay_ms

    def eff():
        return ctl.effective_delay_ms("interactive")

    # a single bad window holds (hysteresis), the second tightens
    _slo_window(svc, "interactive", 8, within=False)
    assert ctl.step()["interactive"] == "hold"
    _slo_window(svc, "interactive", 8, within=False)
    assert ctl.step()["interactive"] == "tighten"
    assert eff() < declared
    # sustained misses converge geometrically onto the floor, never below
    for _ in range(30):
        _slo_window(svc, "interactive", 8, within=False)
        ctl.step()
        assert 0.25 <= eff() <= declared
        assert 1 <= svc.fill_threshold <= svc.bucket
    assert eff() == pytest.approx(0.25)
    assert svc.fill_threshold == 1  # missing class pulled the trigger down
    # recovery relaxes back up, capped at the declared contract
    for _ in range(40):
        _slo_window(svc, "interactive", 8, within=True)
        ctl.step()
        assert eff() <= declared
    assert eff() == pytest.approx(declared)
    assert svc.fill_threshold == svc.bucket
    # under-sampled windows never move the knobs
    _slo_window(svc, "interactive", 2, within=False)
    assert ctl.step()["interactive"] == "hold"
    # every decision is exported
    snap = reg.snapshot()
    acts = {v["labels"]["action"]
            for v in snap["repro_slo_controller_decisions_total"]["values"]}
    assert {"hold", "tighten", "relax"} <= acts
    assert "repro_slo_effective_delay_ms" in snap
    assert snap["repro_slo_fill_threshold"]["values"][0]["value"] == 4.0


def test_slo_controller_never_violates_declared_deadline(tmp_path):
    g = int_graph(40, 2.0, seed=24)
    clock = {"t": 100.0}
    svc = AsyncWindowService(Session(g, SPECS, use_pallas=False),
                             bucket=4, now_fn=lambda: clock["t"])
    declared_s = svc.classes["interactive"].max_delay_ms / 1e3
    # even an absurd override cannot loosen the declared contract ...
    svc.class_delay_ms["interactive"] = 1e9
    t = svc.submit(0, vertex=1, request_class="interactive")
    assert t.deadline_s - clock["t"] <= declared_s + 1e-9
    # ... and a tightened class schedules strictly earlier
    svc.class_delay_ms["interactive"] = 1.0
    t2 = svc.submit(0, vertex=2, request_class="interactive")
    assert t2.deadline_s - clock["t"] == pytest.approx(1.0 / 1e3)
    # the fill threshold triggers launches below a full bucket
    svc.fill_threshold = 2
    assert svc._due_reason()[0] == "fill"
    svc.flush("test")


# ---------------------------------------------------------------------- #
#  Observability re-enable (satellite 6) + health quorum (satellite 1)
# ---------------------------------------------------------------------- #
def test_cluster_metrics_survive_obs_reenable(tmp_path):
    g = int_graph(40, 2.0, seed=25)
    # constructed while observability is OFF ...
    rs = ReplicaSet(g, SPECS, tmp_path / "c", n_replicas=2,
                    use_pallas=False)
    for b in make_batches(g, 2, seed=12):
        rs.update(b)
    rs.sync()
    try:
        reg, _ = obs.enable()  # ... enabled afterwards
        rs.sync()
        for rep in rs.replicas.values():
            rep.lag  # lag gauges are set on read
        t = rs.router.submit(0, vertex=1)
        rs.router.flush()
        t.get(timeout=10)
        snap = reg.snapshot()
        lag = snap["repro_replica_lag_versions"]["values"]
        assert {v["labels"]["replica"] for v in lag} == {"r0", "r1"}
        routed = snap["repro_router_requests_total"]["values"]
        assert all(set(v["labels"]) == {"target", "cls"} for v in routed)
        assert "repro_replica_polls_total" in snap
        prom = reg.prometheus()
        assert 'repro_replica_lag_versions{replica="r0"}' in prom
        assert 'repro_replica_lag_versions{replica="r1"}' in prom
    finally:
        obs.disable()
        rs.close()


def test_health_quorum_and_debug(tmp_path):
    g = int_graph(40, 2.0, seed=26)
    rs = ReplicaSet(g, SPECS, tmp_path / "c", n_replicas=3,
                    use_pallas=False, checkpoint_every=1)
    for b in make_batches(g, 2, seed=13):
        rs.update(b)
    rs.sync()
    mon = HealthMonitor(cluster=rs, max_lag_versions=0)
    assert mon.check()["state"] == "ready"
    # one replica applied-but-unpublished: lagging -> degraded, not failed
    rs.update(make_batches(g, 3, seed=13)[2])
    rs.wal.sync()
    rs.replicas["r0"].catch_up()
    rs.replicas["r1"].catch_up()
    rs.replicas["r2"].poll()  # no flip: unpublished version
    rep = mon.check()
    assert rep["state"] == "degraded" and any(
        k.startswith("replica_lag") for k in rep["failing"])
    rs.replicas["r2"].flip()
    assert mon.check()["state"] == "ready"
    # a dead minority degrades (soft "fleet"), a dead majority fails hard
    rs.kill("r2")
    rep = mon.check()
    assert rep["state"] == "degraded" and "fleet" in rep["failing"]
    assert "dead: ['r2']" in rep["checks"]["quorum"]["detail"]
    rs.kill("r1")
    rep = mon.check()
    assert rep["state"] == "failed" and "quorum" in rep["failing"]
    rs.rejoin("r1")
    rs.rejoin("r2")
    rs.sync()
    assert mon.check()["state"] == "ready"
    # /readyz + /debug over HTTP with the cluster attached
    with HealthServer(mon) as hs:
        body = json.loads(urllib.request.urlopen(
            hs.url + "/readyz", timeout=5).read())
        assert body["ready"] is True
        dbg = json.loads(urllib.request.urlopen(
            hs.url + "/debug", timeout=5).read())
        cluster = dbg["cluster"]
        assert cluster["checkpoints"]["last_version"] == rs.version
        for name in ("r0", "r1", "r2"):
            row = cluster["replicas"][name]
            assert row["alive"] is True
            assert "segment" in row["cursor"] and "lag" in row
    rs.close()
