"""DBIndex: exact cover invariants, MC/EMC/mc_paper equality, updates."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: use the local shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import updates
from repro.core.dbindex import build_dbindex
from repro.core.query import GraphWindowQuery, brute_force
from repro.core.windows import KHopWindow, TopologicalWindow, khop_window_single
from repro.graphs.generators import erdos_renyi, random_dag, with_random_attrs


@pytest.mark.parametrize("method", ["mc", "emc", "mc_paper"])
@pytest.mark.parametrize("k", [1, 2, 3])
def test_query_matches_bruteforce(small_undirected, method, k):
    g = small_undirected
    w = KHopWindow(k)
    ref = brute_force(g, w, g.attrs["val"], "sum")
    idx = build_dbindex(g, w, method=method)
    assert np.allclose(idx.query(g.attrs["val"], "sum"), ref)


@pytest.mark.parametrize("agg", ["sum", "count", "min", "max", "avg"])
def test_all_aggregates(small_undirected, agg):
    g = small_undirected
    w = KHopWindow(2)
    idx = build_dbindex(g, w, method="emc")
    ref = brute_force(g, w, g.attrs["val"], agg)
    assert np.allclose(idx.query(g.attrs["val"], agg), ref)


def test_directed_windows(small_directed):
    g = small_directed
    w = KHopWindow(2)
    idx = build_dbindex(g, w, method="emc")
    ref = brute_force(g, w, g.attrs["val"], "sum")
    assert np.allclose(idx.query(g.attrs["val"], "sum"), ref)


def test_topological_dbindex(small_dag):
    g = small_dag
    w = TopologicalWindow()
    idx = build_dbindex(g, w, method="mc")
    ref = brute_force(g, w, g.attrs["val"], "sum")
    assert np.allclose(idx.query(g.attrs["val"], "sum"), ref)


def test_cover_invariant(small_undirected):
    """Every window is exactly covered by disjoint linked blocks."""
    g = small_undirected
    idx = build_dbindex(g, KHopWindow(2), method="emc")
    for v in range(0, g.n, 11):
        reconstructed = idx.window_of(v)
        assert np.array_equal(reconstructed, khop_window_single(g, 2, v)), v
        # disjointness: reconstruction has no duplicates
        assert np.unique(reconstructed).size == reconstructed.size


def test_emc_vs_mc_same_results_different_cost(small_undirected):
    g = small_undirected
    w = KHopWindow(3)
    i_mc = build_dbindex(g, w, method="mc_paper")
    i_emc = build_dbindex(g, w, method="emc")
    v = g.attrs["val"]
    assert np.allclose(i_mc.query(v, "sum"), i_emc.query(v, "sum"))


def test_paper_example_dense_blocks(paper_social_graph):
    """The paper's running example (Fig. 1 + §3 windows).

    The text gives W(B)={A,B,D,F} and W(E)={A,C,E} explicitly; with the
    Posts column (A..F = 12,15,28,23,26,14) the 1-hop sums are B=64, E=66.
    The full vector is derived from the adjacency the text implies.
    """
    g = paper_social_graph
    idx = build_dbindex(g, KHopWindow(1), method="mc", num_hashes=1)
    got = idx.query(g.attrs["val"], "sum")
    expect = np.array([81, 64, 103, 80, 66, 80], dtype=np.float64)
    assert np.allclose(got, expect)
    # dense block {A, D, F} (shared by W(B), W(C)) must exist (paper §4)
    found = any(
        set(idx.block(b).tolist()) == {0, 3, 5} for b in range(idx.num_blocks)
    ) or idx.stats["num_dense_blocks"] > 0
    assert found


def test_index_stats_sane(small_undirected):
    idx = build_dbindex(small_undirected, KHopWindow(2), method="emc")
    st_ = idx.stats
    assert st_["num_blocks"] == idx.num_blocks
    assert st_["num_members"] == idx.block_members.size
    assert idx.size_bytes() > 0


def test_update_insert_edge(small_undirected):
    g = small_undirected
    w = KHopWindow(2)
    idx = build_dbindex(g, w, method="emc")
    g2 = updates.insert_edge(g, 7, 123)
    idx2 = updates.update_dbindex(idx, g2, w, 7, 123)
    ref = brute_force(g2, w, g2.attrs["val"], "sum")
    assert np.allclose(idx2.query(g2.attrs["val"], "sum"), ref)


def test_update_delete_edge(small_undirected):
    g = small_undirected
    w = KHopWindow(2)
    idx = build_dbindex(g, w, method="emc")
    s, t = int(g.src[0]), int(g.dst[0])
    g2 = updates.delete_edge(g, s, t)
    idx2 = updates.update_dbindex(idx, g2, w, s, t)
    ref = brute_force(g2, w, g2.attrs["val"], "sum")
    assert np.allclose(idx2.query(g2.attrs["val"], "sum"), ref)


def test_update_then_reorganize(small_undirected):
    g = small_undirected
    w = KHopWindow(1)
    idx = build_dbindex(g, w, method="emc")
    for i in range(5):  # a burst of updates, then phase-2 reorganization
        g = updates.insert_edge(g, i, (i * 37 + 11) % g.n)
        idx = updates.update_dbindex(idx, g, w, i, (i * 37 + 11) % g.n)
    ref = brute_force(g, w, g.attrs["val"], "sum")
    assert np.allclose(idx.query(g.attrs["val"], "sum"), ref)
    reorg = updates.reorganize(g, w)
    assert np.allclose(reorg.query(g.attrs["val"], "sum"), ref)
    # reorganized index is at least as shared (not more links than incremental)
    assert reorg.stats["num_links"] <= idx.stats["num_links"] + g.n


def test_attribute_updates_dont_touch_index(small_undirected):
    """§4.3: attribute changes require no index maintenance."""
    g = small_undirected
    idx = build_dbindex(g, KHopWindow(2), method="emc")
    vals2 = g.attrs["val"] * 3 + 1
    ref = brute_force(g, KHopWindow(2), vals2, "sum")
    assert np.allclose(idx.query(vals2, "sum"), ref)


@settings(max_examples=15, deadline=None)
@given(st.integers(20, 100), st.integers(2, 8), st.integers(0, 99999),
       st.sampled_from(["mc", "emc"]), st.integers(1, 3))
def test_property_dbindex_equals_bruteforce(n, deg, seed, method, k):
    g = with_random_attrs(erdos_renyi(n, float(deg), seed=seed), seed=seed + 1)
    w = KHopWindow(k)
    idx = build_dbindex(g, w, method=method)
    ref = brute_force(g, w, g.attrs["val"], "sum")
    assert np.allclose(idx.query(g.attrs["val"], "sum"), ref)


@settings(max_examples=10, deadline=None)
@given(st.integers(20, 80), st.integers(1, 4), st.integers(0, 99999))
def test_property_topo_dbindex(n, deg, seed):
    g = with_random_attrs(random_dag(n, float(deg), seed=seed), seed=seed + 1)
    w = TopologicalWindow()
    idx = build_dbindex(g, w)
    ref = brute_force(g, w, g.attrs["val"], "sum")
    assert np.allclose(idx.query(g.attrs["val"], "sum"), ref)
