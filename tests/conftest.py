import numpy as np
import pytest

from repro.core.graph import Graph
from repro.graphs.generators import erdos_renyi, random_dag, with_random_attrs


@pytest.fixture(scope="session")
def small_undirected():
    return with_random_attrs(erdos_renyi(300, 6.0, directed=False, seed=1), seed=2)


@pytest.fixture(scope="session")
def small_directed():
    return with_random_attrs(erdos_renyi(300, 5.0, directed=True, seed=3), seed=4)


@pytest.fixture(scope="session")
def small_dag():
    return with_random_attrs(random_dag(350, 3.0, seed=5), seed=6)


@pytest.fixture(scope="session")
def paper_social_graph():
    """The paper's Fig. 1 running example (6 users A..F)."""
    # edges from Fig 1/3: windows W(B)={A,B,D,F}, W(C)={A,C,D,E,F},
    # W(E)={A,C,E}, 2-hop W(E)={A,B,C,D,E,F}
    # A-B, A-C, A-E, B-D, B-F, C-D, C-E, C-F, D-F
    src = np.array([0, 0, 0, 1, 1, 2, 2, 2, 3], dtype=np.int32)
    dst = np.array([1, 2, 4, 3, 5, 3, 4, 5, 5], dtype=np.int32)
    g = Graph(n=6, src=src, dst=dst, directed=False)
    posts = np.array([12, 15, 28, 23, 26, 14], dtype=np.float64)
    return g.with_attr("val", posts)


# ---------------------------------------------------------------------- #
#  Failure artifacts (ISSUE 8): when a test fails, dump the observability
#  state — metrics snapshot, Chrome trace, serving flight records — so CI
#  can upload them (actions/upload-artifact with if: failure()).
# ---------------------------------------------------------------------- #
def _artifact_dir():
    import os

    d = os.environ.get("REPRO_FAILURE_ARTIFACTS", "test-failure-artifacts")
    os.makedirs(d, exist_ok=True)
    return d


def _dump_failure_artifacts(test_name: str) -> None:
    import json
    import os
    import re

    slug = re.sub(r"[^A-Za-z0-9_.-]+", "_", test_name)[:80]
    d = _artifact_dir()
    try:  # live metrics registry (present when obs is enabled)
        from repro import obs

        reg = obs.get_registry()
        if getattr(reg, "enabled", False):
            with open(os.path.join(d, f"{slug}.metrics.prom"), "w") as f:
                f.write(reg.prometheus())
            with open(os.path.join(d, f"{slug}.metrics.json"), "w") as f:
                json.dump(reg.snapshot(), f, indent=2, default=str)
        tracer = obs.get_tracer()
        if getattr(tracer, "enabled", False) and len(tracer.events()):
            tracer.dump(os.path.join(d, f"{slug}.trace.json"))
    except Exception:
        pass
    try:  # every live flight recorder, even from services the test built
        from repro.serve.flight import all_recorders

        for i, fr in enumerate(all_recorders()):
            if len(fr):
                fr.dump_json(os.path.join(d, f"{slug}.flight{i}.json"))
    except Exception:
        pass
    try:  # latest health report of every live monitor
        from repro.serve.health import all_monitors

        for i, mon in enumerate(all_monitors()):
            with open(os.path.join(d, f"{slug}.health{i}.json"), "w") as f:
                json.dump(mon.report(), f, indent=2, default=str)
    except Exception:
        pass


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    outcome = yield
    rep = outcome.get_result()
    if rep.when == "call" and rep.failed:
        _dump_failure_artifacts(item.name)
