import numpy as np
import pytest

from repro.core.graph import Graph
from repro.graphs.generators import erdos_renyi, random_dag, with_random_attrs


@pytest.fixture(scope="session")
def small_undirected():
    return with_random_attrs(erdos_renyi(300, 6.0, directed=False, seed=1), seed=2)


@pytest.fixture(scope="session")
def small_directed():
    return with_random_attrs(erdos_renyi(300, 5.0, directed=True, seed=3), seed=4)


@pytest.fixture(scope="session")
def small_dag():
    return with_random_attrs(random_dag(350, 3.0, seed=5), seed=6)


@pytest.fixture(scope="session")
def paper_social_graph():
    """The paper's Fig. 1 running example (6 users A..F)."""
    # edges from Fig 1/3: windows W(B)={A,B,D,F}, W(C)={A,C,D,E,F},
    # W(E)={A,C,E}, 2-hop W(E)={A,B,C,D,E,F}
    # A-B, A-C, A-E, B-D, B-F, C-D, C-E, C-F, D-F
    src = np.array([0, 0, 0, 1, 1, 2, 2, 2, 3], dtype=np.int32)
    dst = np.array([1, 2, 4, 3, 5, 3, 4, 5, 5], dtype=np.int32)
    g = Graph(n=6, src=src, dst=dst, directed=False)
    posts = np.array([12, 15, 28, 23, 26, 14], dtype=np.float64)
    return g.with_attr("val", posts)
