"""Window algebra: composable expressions + the open aggregate registry.

Covers the PR-5 surface:

* canonicalization (flattening, commutative sort + dedup, containment
  rewrites) and "algebraically equal queries hit one cached plan";
* the capability planner on composite expressions (which (expr, agg,
  engine) combos are servable, and the explicit error table otherwise);
* differential **bitwise** sweep: expr-shape x aggregate x engine against
  the per-vertex set-evaluation oracle — integer-valued attributes make
  every monoid partial exact, so evaluation order is irrelevant and any
  mismatch is a real bug (device engines compare against the f32 oracle:
  same exact channel integers, same f32 finalizer);
* the algebraic fast path (idempotent-union combine, inclusion–exclusion)
  against the generic materialize-then-query lowering, bit for bit;
* registered derived aggregates compiling to extra fused channels;
* dtype-safe monoid identities on the integer host paths (no silent float
  upcast);
* attribute-update invalidation via the DBIndex reverse link map;
* streamed updates through composite sessions — single host and a
  1-device mesh — with zero recompiles of the fused executors.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import engine_jax as ej  # noqa: E402
from repro.core.aggregates import AGGREGATES, register_aggregate  # noqa: E402
from repro.core.api import (  # noqa: E402
    DEFAULT_REGISTRY,
    QuerySpec,
    Session,
    UnsupportedQueryError,
    compile_queries,
    plan_window_program,
)
from repro.core.query import brute_force  # noqa: E402
from repro.core.updates import UpdateBatch  # noqa: E402
from repro.core.windows import (  # noqa: E402
    Diff,
    Filter,
    Intersect,
    KHop,
    KHopWindow,
    Topo,
    TopologicalWindow,
    Union,
    canonicalize,
    expr_window_single,
)
from repro.graphs.generators import erdos_renyi, random_dag  # noqa: E402

from test_updates import mixed  # noqa: E402  (stream helpers)

ALL_AGGS = ("sum", "count", "min", "max", "avg", "var")


def int_attrs(g, seed, lo=0, hi=50):
    rng = np.random.default_rng(seed)
    g = g.with_attr("val", rng.integers(lo, hi, g.n).astype(np.float64))
    return g.with_attr("mask", (rng.random(g.n) < 0.7).astype(np.int64))


@pytest.fixture(scope="module")
def dag_case():
    return int_attrs(random_dag(80, 2.0, seed=9), seed=10)


#: the expression shapes the differential sweep pins (all composite kinds:
#: union of direction-variant leaves, intersection, difference, filter)
EXPRS = {
    "union": Union(KHop(2, "in"), KHopWindow(2)),
    "intersect": Intersect(KHopWindow(2), Topo()),
    "diff": Diff(Topo(), KHopWindow(1)),
    "filter": Filter(KHopWindow(2), "mask"),
}


# --------------------------- canonicalization -------------------------- #
def test_canonicalize_commutative_sort_dedup_flatten():
    A, B, T = KHop(2, "in"), KHopWindow(2), TopologicalWindow()
    u1 = canonicalize(Union(A, B, T))
    u2 = canonicalize(Union(T, Union(B, A)))  # nested + reordered
    assert u1 == u2 and hash(u1) == hash(u2)
    assert canonicalize(Union(A, A)) == canonicalize(A)  # dedup unwraps
    assert canonicalize(KHop(3)) == KHopWindow(3)  # leaf spelling
    assert canonicalize(Topo()) == TopologicalWindow()


def test_canonicalize_containment_rewrites():
    # KHop(1) ⊆ KHop(2): the union IS the larger materialization, the
    # intersection the smaller — no composite plan is ever built for them
    assert canonicalize(Union(KHop(1), KHop(2))) == KHopWindow(2)
    assert canonicalize(Intersect(KHop(1), KHop(2))) == KHopWindow(1)
    # direction-variant k-hops are NOT comparable
    u = canonicalize(Union(KHop(1, "in"), KHop(2, "out")))
    assert isinstance(u, Union) and len(u.exprs) == 2
    # nested same-predicate filters collapse
    f = canonicalize(Filter(Filter(KHopWindow(1), "mask"), "mask"))
    assert f == Filter(KHopWindow(1), "mask")


def test_equal_queries_hit_one_cached_plan(dag_case):
    g = dag_case
    A, B = KHop(2, "in"), KHopWindow(2)
    cq = compile_queries(
        [QuerySpec(Union(A, B), "sum"), QuerySpec(Union(B, A), "sum")],
        device=True,
    )
    assert len(cq.groups) == 1  # one fused plan group
    assert cq.spec_slots[0] == cq.spec_slots[1]
    # and one Session materialization per distinct canonical term
    sess = Session(g, [QuerySpec(Union(A, B), "min"),
                       QuerySpec(Union(B, A), "max")],
                   device=True, use_pallas=False)
    assert len(sess.compiled.groups) == 1
    assert len(sess._states) == 2  # the two leaves (idempotent-only union)


# ------------------------- capability planner -------------------------- #
def test_capability_table_on_composite_expressions():
    u = canonicalize(Union(KHop(1, "in"), KHopWindow(1)))
    # servable: the materialized-window engines
    assert DEFAULT_REGISTRY.select(u, ("sum", "var")) == "jax"
    assert DEFAULT_REGISTRY.select(u, ("sum",), device=False) == "dbindex"
    assert DEFAULT_REGISTRY.select(u, ("min",), sharded=True) == "jax-sharded"
    assert DEFAULT_REGISTRY.select(u, ("avg",), engine="bitset") == "bitset"
    # not servable: per-vertex-BFS / structure-specific backends — and the
    # error carries the full capability table naming the composite kind
    for engine in ("nonindex", "eagr", "iindex", "jax-iindex"):
        with pytest.raises(UnsupportedQueryError, match="composite"):
            DEFAULT_REGISTRY.select(u, ("sum",), engine=engine)
    with pytest.raises(UnsupportedQueryError, match="composite"):
        DEFAULT_REGISTRY.select(u, ("sum",), device=True, incremental=False)


def test_planner_decomposition_per_expr_and_monoid():
    A, B = KHop(1, "in"), KHopWindow(1)
    u = canonicalize(Union(A, B))
    # idempotent-only: combine over the children, no intersection term
    prog = plan_window_program(u, ("min", "max"))
    assert prog is not None and len(prog.terms) == 2
    # sum channels ride inclusion–exclusion: + the intersection term
    prog = plan_window_program(u, ("sum", "avg", "min"))
    assert prog is not None and len(prog.terms) == 3
    assert prog.sum_coefs == (1, 1, -1)
    assert canonicalize(Intersect(A, B)) in prog.terms
    # other combinators (and 3-way unions with sums) stay generic
    assert plan_window_program(canonicalize(Intersect(A, B)), ("sum",)) is None
    w3 = canonicalize(Union(A, B, TopologicalWindow()))
    assert plan_window_program(w3, ("sum",)) is None
    assert plan_window_program(w3, ("min",)) is not None  # idempotent: any arity


# ---------------------- differential bitwise sweep --------------------- #
@pytest.mark.parametrize("engine", ("bitset", "dbindex", "jax"))
@pytest.mark.parametrize("ename", sorted(EXPRS))
def test_composite_bitwise_vs_set_oracle(engine, ename, dag_case):
    g = dag_case
    expr = canonicalize(EXPRS[ename])
    vals = g.attrs["val"]
    out = DEFAULT_REGISTRY.run(engine, g, expr, vals, ALL_AGGS,
                               use_pallas=False)
    dtype = np.float32 if engine == "jax" else None
    for a in ALL_AGGS:
        ref = brute_force(g, expr, vals, a, dtype=dtype)
        got = np.asarray(out[a])
        assert np.array_equal(got, np.asarray(ref, got.dtype)), (engine, a)


def test_algebraic_fast_path_bit_identical_to_materialized(dag_case):
    g = dag_case
    u = canonicalize(Union(KHop(2, "in"), KHopWindow(2)))
    vals = g.attrs["val"]
    specs = [QuerySpec(u, a) for a in ALL_AGGS]
    sess = Session(g, specs, device=True, use_pallas=False)
    assert sess._programs[0] is not None  # the fast path engaged
    fast = sess.run()
    # generic lowering: materialize the union windows outright
    gen = DEFAULT_REGISTRY.run("jax", g, u, vals, ALL_AGGS, use_pallas=False)
    for s, got in zip(specs, fast):
        ref = brute_force(g, u, vals, s.agg, dtype=np.float32)
        got = np.asarray(got)
        assert np.array_equal(got, np.asarray(ref, got.dtype)), s.agg
        assert np.array_equal(got, np.asarray(gen[s.agg], got.dtype)), s.agg


def test_sharded_composite_single_device_mesh_bitwise(dag_case):
    g = dag_case
    mesh = jax.make_mesh((1,), ("data",))
    u = canonicalize(Union(KHop(2, "in"), KHopWindow(2)))
    out = DEFAULT_REGISTRY.run("jax-sharded", g, u, g.attrs["val"],
                               ("sum", "min", "var"), mesh=mesh)
    for a in ("sum", "min", "var"):
        ref = brute_force(g, u, g.attrs["val"], a, dtype=np.float32)
        got = np.asarray(out[a])
        assert np.array_equal(got, np.asarray(ref, got.dtype)), a


# --------------------- open aggregate registry ------------------------- #
def test_registered_aggregate_rides_fused_channels(dag_case):
    g = dag_case
    name = "_spread_test"
    register_aggregate(name, ("max", "min"), ("value", "value"),
                       finalize=lambda xp, hi, lo: hi - lo)
    try:
        w = KHopWindow(2)
        # fused with built-ins through the device executor
        out = DEFAULT_REGISTRY.run("jax", g, w, g.attrs["val"],
                                   ("sum", name, "l2"), use_pallas=False)
        for a in ("sum", name, "l2"):
            ref = brute_force(g, w, g.attrs["val"], a, dtype=np.float32)
            got = np.asarray(out[a])
            assert np.array_equal(got, np.asarray(ref, got.dtype)), a
        # and through a composite window's generic path on a host engine
        e = canonicalize(EXPRS["diff"])
        got = DEFAULT_REGISTRY.run("dbindex", g, e, g.attrs["val"], (name,))
        ref = brute_force(g, e, g.attrs["val"], name)
        assert np.array_equal(np.asarray(got[name]), ref)
    finally:
        del AGGREGATES[name]


def test_register_aggregate_validation():
    with pytest.raises(ValueError, match="already registered"):
        register_aggregate("sum", ("sum",))
    with pytest.raises(ValueError, match="unknown channel source"):
        register_aggregate("_bad_src", ("sum",), ("cube",))
    with pytest.raises(ValueError, match="equal length"):
        register_aggregate("_bad_len", ("sum", "sum"), ("value",))
    with pytest.raises(ValueError, match="unknown aggregate"):
        QuerySpec(("khop", 1), "_never_registered")


def test_derived_aggregates_on_all_host_engines(dag_case):
    g = dag_case
    w = TopologicalWindow()
    for engine in ("bitset", "dbindex", "iindex", "eagr"):
        out = DEFAULT_REGISTRY.run(engine, g, w, g.attrs["val"],
                                   ("sum_sq", "mean_sq", "var", "l2"))
        for a in ("sum_sq", "mean_sq", "var", "l2"):
            ref = brute_force(g, w, g.attrs["val"], a)
            assert np.array_equal(np.asarray(out[a]), ref), (engine, a)


# ------------------- dtype-safe monoid identities ---------------------- #
def test_int_attrs_stay_int_on_host_paths(dag_case):
    g = dag_case
    ivals = g.attrs["val"].astype(np.int32)
    w = KHopWindow(1)
    for engine in ("bitset", "dbindex"):
        out = DEFAULT_REGISTRY.run(engine, g, w, ivals,
                                   ("sum", "count", "min", "max", "sum_sq"))
        for a, vec in out.items():
            assert np.asarray(vec).dtype == np.int64, (engine, a)
    # empty windows surface the per-dtype identity, not a float inf:
    # Diff(W, W) empties every window
    e = Diff(KHopWindow(1), KHopWindow(1))
    out = DEFAULT_REGISTRY.run("dbindex", g, e, ivals, ("min", "max", "sum"))
    assert out["min"].dtype == np.int64
    assert (out["min"] == np.iinfo(np.int64).max).all()
    assert (out["max"] == np.iinfo(np.int64).min).all()
    assert (out["sum"] == 0).all()
    # the float path keeps the ±inf identities
    outf = DEFAULT_REGISTRY.run("dbindex", g, e, g.attrs["val"], ("min",))
    assert np.isposinf(outf["min"]).all()


# ------------------ attribute-update invalidation ---------------------- #
def test_attr_edit_invalidates_containing_owners_only():
    from repro.serve import WindowService

    rng = np.random.default_rng(21)
    g = erdos_renyi(150, 3.0, directed=False, seed=21)
    g = g.with_attr("val", rng.integers(0, 50, g.n).astype(np.int64))
    w = KHopWindow(1)
    sess = Session(g, [QuerySpec(w, "sum")], device=True, use_pallas=False,
                   plan_headroom=1.0)
    svc = WindowService(sess, bucket=4)
    svc.query(0)  # warm the cache
    verts = [3, 7]
    svc.update(UpdateBatch.attr_set("val", verts, [999, 1000]))
    # invalidated exactly the owners whose windows contain 3 or 7 — via the
    # DBIndex reverse link map, NOT a whole-vector flush
    state = sess._states[(w, "dbindex")]
    expect = np.sort(state.index.owners_of_members(verts))
    entry = svc.cache._entries[0]
    assert np.array_equal(np.flatnonzero(~entry["valid"]), expect)
    assert 0 < expect.size < g.n  # partial invalidation, vector kept
    # oracle exactness of the reverse map itself
    ref_owners = [v for v in range(g.n)
                  if np.intersect1d(expr_window_single(g, w, v), verts).size]
    assert list(expect) == ref_owners
    # post-edit reads refresh only what changed and stay exact
    got = svc.query(0)
    ref = brute_force(sess.graph, w, sess.graph.attrs["val"], "sum",
                      dtype=np.float32)
    assert np.array_equal(np.asarray(got, np.float32), ref)


def test_attr_only_batch_skips_index_and_plan_maintenance():
    rng = np.random.default_rng(22)
    g = erdos_renyi(120, 3.0, directed=False, seed=22)
    g = g.with_attr("val", rng.integers(0, 50, g.n).astype(np.float64))
    sess = Session(g, [QuerySpec(("khop", 1), "sum")], device=True,
                   use_pallas=False)
    state = next(iter(sess._states.values()))
    idx0, plan0, pv0 = state.index, state.plan, state.plan_version
    rep = sess.update(UpdateBatch.attr_set("val", [1, 2], [5, 6]))
    key = next(iter(rep))
    assert rep[key]["batch_size"] == 0 and rep[key]["attr_edits"] == 2
    assert state.index is idx0 and state.plan is plan0  # untouched
    assert state.plan_version == pv0
    assert sess.graph.attrs["val"][1] == 5  # but the graph moved
    assert sess.version == 1


def test_filter_predicate_edit_rebuilds_membership(dag_case):
    g = dag_case
    f = Filter(KHopWindow(1), "mask")
    sess = Session(g, [QuerySpec(f, "sum")], device=True, use_pallas=False)
    # flip some predicate bits: membership changes for the flipped
    # vertices, and maintenance either re-filters exactly the bounded
    # owner set or (past n/2 owners) rebuilds outright — never a no-op
    flip = [0, 5, 9]
    newbits = 1 - np.asarray(g.attrs["mask"])[flip]
    rep = sess.update(UpdateBatch.attr_set("mask", flip, newbits))
    key = f"{f.name()}/dbindex"
    assert rep[key]["reorganized"] or rep[key]["refiltered"]
    assert 0 < rep[key]["affected"] <= g.n
    got = sess.run()[0]
    ref = brute_force(sess.graph, f, sess.graph.attrs["val"], "sum",
                      dtype=np.float32)
    assert np.array_equal(np.asarray(got, np.float32), ref)


def test_update_batch_attr_edit_container_semantics():
    b1 = UpdateBatch.inserts([0], [1])
    b2 = UpdateBatch.attr_set("val", [2, 3], [9.0, 9.5])
    cat = UpdateBatch.concat([b1, b2])
    assert cat.size == 1 and cat.attr_size == 2
    assert cat.edited_attrs() == ("val",)
    from repro.core.graph import Graph
    from repro.core.updates import apply_batch

    g = Graph(n=4, src=np.array([2], np.int32), dst=np.array([3], np.int32),
              attrs={"val": np.zeros(4)})
    g2 = apply_batch(g, cat)
    assert g2.n_edges == 2 and g2.attrs["val"][2] == 9.0
    assert g.attrs["val"][2] == 0.0  # immutability: the old graph kept


# ----------------- streamed updates, zero recompiles ------------------- #
def test_composite_session_stream_no_recompile_bitwise():
    """>=10 streamed batches through an algebraic-fast-path session: every
    step bit-identical to the set-evaluation oracle, zero retraces of the
    fused device executor (term plans patch in place)."""
    rng = np.random.default_rng(31)
    g = erdos_renyi(300, 3.0, directed=True, seed=31)
    g = g.with_attr("val", rng.integers(0, 30, g.n).astype(np.float64))
    u = canonicalize(Union(KHop(1, "in"), KHop(1, "out")))
    specs = [QuerySpec(u, a) for a in ("sum", "min", "avg")]
    sess = Session(g, specs, device=True, use_pallas=False, plan_headroom=1.0)
    assert sess._programs[0] is not None
    sess.run()
    cache0 = ej.query_dbindex_multi._cache_size()
    for step in range(10):
        sess.update(mixed(sess.graph, rng, 4, 2))
        res = sess.run()
        vals = sess.graph.attrs["val"]
        for s, r in zip(specs, res):
            ref = brute_force(sess.graph, s.window, vals, s.agg,
                              dtype=np.float32)
            r = np.asarray(r)
            assert np.array_equal(r, np.asarray(ref, r.dtype)), (step, s.agg)
    assert ej.query_dbindex_multi._cache_size() == cache0
    assert sess.updates_applied == 10


def test_sharded_composite_session_stream_no_recompile_1dev_mesh():
    """The same stream on a 1-device mesh: the whole sharded code path
    (layout, shard_map, collectives, tile-group patches) stays exact, and
    **patch-only batches never retrace** the sharded fused query.  An
    occasional overflow rebuild (ELL width / tile-group capacity) is a
    recompile-sized event by design and re-baselines the counter; the test
    requires >= 10 consecutive patch-only batches with zero recompiles."""
    from repro.distributed import window_runtime as wr

    rng = np.random.default_rng(33)
    g = erdos_renyi(300, 3.0, directed=True, seed=33)
    g = g.with_attr("val", rng.integers(0, 30, g.n).astype(np.float64))
    mesh = jax.make_mesh((1,), ("data",))
    u = canonicalize(Union(KHop(1, "in"), KHop(1, "out")))
    specs = [QuerySpec(u, a) for a in ("sum", "min", "avg")]
    sess = Session(g, specs, mesh=mesh, plan_headroom=1.0)
    assert isinstance(sess, wr.ShardedSession)
    sess.run()
    baseline = wr.query_cache_size()
    patch_only = 0
    for step in range(30):
        reps = sess.update(mixed(sess.graph, rng, 3, 3))
        rebuilt = any(r.get("plan_rebuilt") or r["reorganized"]
                      for r in reps.values())
        if step % 3 == 0 or rebuilt:
            res = sess.run()
            vals = sess.graph.attrs["val"]
            for s, r in zip(specs, res):
                ref = brute_force(sess.graph, s.window, vals, s.agg,
                                  dtype=np.float32)
                r = np.asarray(r)
                assert np.array_equal(r, np.asarray(ref, r.dtype)), (step, s.agg)
        if rebuilt:
            patch_only = 0
            baseline = wr.query_cache_size()  # legit recompile-sized event
        else:
            patch_only += 1
            assert wr.query_cache_size() == baseline, (
                f"patch-only batch {step} retraced the sharded query")
        if patch_only >= 10:
            break
    assert patch_only >= 10, "never reached 10 consecutive patch-only batches"
