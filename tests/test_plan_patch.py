"""Incremental device-plan maintenance: patched plans == fresh plans.

Regression suite for ``patch_tile_plan`` / ``patch_plan_dbindex`` /
``patch_plan_iindex``: after every batch of a random edit stream, a query
on the incrementally patched plan must match a fresh ``plan_from_*`` build
bit-for-bit (same f32 arithmetic on both paths) and the host brute-force
oracle approximately.  Runs on CPU (XLA fallback for the sweep, one Pallas
interpret-mode case to pin the kernel path).
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import engine_jax as ej  # noqa: E402
from repro.core import updates as U  # noqa: E402
from repro.core.dbindex import build_dbindex  # noqa: E402
from repro.core.iindex import build_iindex  # noqa: E402
from repro.core.query import brute_force  # noqa: E402
from repro.core.streaming import StalenessPolicy, StreamingEngine  # noqa: E402
from repro.core.windows import KHopWindow, TopologicalWindow  # noqa: E402
from repro.graphs.generators import erdos_renyi, random_dag, with_random_attrs  # noqa: E402
from repro.kernels.segment_reduce.ops import (  # noqa: E402
    build_tile_plan,
    patch_tile_plan,
    segment_sum,
)

from test_updates import mixed  # noqa: E402  (stream helpers)


# ------------------------- patch_tile_plan unit ----------------------- #
@pytest.mark.parametrize("tm,ts", [(64, 64), (128, 32)])
def test_patch_tile_plan_matches_rebuild(tm, ts):
    rng = np.random.default_rng(0)
    n, m, s = 500, 3000, 400
    vals = rng.normal(size=n).astype(np.float32)
    seg = np.sort(rng.integers(0, s, m)).astype(np.int64)
    gidx = rng.integers(0, n, m).astype(np.int32)
    plan = build_tile_plan(gidx, seg, s, tm, ts)
    # mutate a sparse set of segments: drop their rows, add new ones
    changed = rng.choice(s, 25, replace=False)
    keep = ~np.isin(seg, changed)
    add_seg = np.repeat(changed, 3)
    add_gidx = rng.integers(0, n, add_seg.size).astype(np.int32)
    seg2 = np.concatenate([seg[keep], add_seg])
    gidx2 = np.concatenate([gidx[keep], add_gidx])
    order = np.argsort(seg2, kind="stable")
    seg2, gidx2 = seg2[order], gidx2[order]
    patched = patch_tile_plan(plan, gidx2, seg2, s, changed)
    fresh = build_tile_plan(gidx2, seg2, s, tm, ts)
    out_p = np.asarray(segment_sum(patched, jnp.asarray(vals), use_pallas=False))
    out_f = np.asarray(segment_sum(fresh, jnp.asarray(vals), use_pallas=False))
    assert np.array_equal(out_p, out_f)


def test_patch_tile_plan_grows_segments():
    rng = np.random.default_rng(1)
    n, m, s = 200, 800, 100
    seg = np.sort(rng.integers(0, s, m)).astype(np.int64)
    gidx = rng.integers(0, n, m).astype(np.int32)
    plan = build_tile_plan(gidx, seg, s, 64, 64)
    # append rows for brand-new segment ids beyond the old num_segments
    s2 = 150
    add_seg = np.sort(rng.integers(s, s2, 120)).astype(np.int64)
    add_gidx = rng.integers(0, n, add_seg.size).astype(np.int32)
    seg2 = np.concatenate([seg, add_seg])
    gidx2 = np.concatenate([gidx, add_gidx])
    patched = patch_tile_plan(plan, gidx2, seg2, s2, np.arange(s, s2))
    fresh = build_tile_plan(gidx2, seg2, s2, 64, 64)
    vals = rng.normal(size=n).astype(np.float32)
    out_p = np.asarray(segment_sum(patched, jnp.asarray(vals), use_pallas=False))
    out_f = np.asarray(segment_sum(fresh, jnp.asarray(vals), use_pallas=False))
    assert np.array_equal(out_p, out_f)


def test_patch_tile_plan_scatter_no_recompile():
    """Shape-stable patches scatter changed tile groups into the live device
    arrays — the static parts are reused verbatim and jitted consumers never
    retrace (asserted via the jit compile counter)."""
    rng = np.random.default_rng(5)
    n, m, s = 300, 2000, 256
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    seg = np.sort(rng.integers(0, s, m)).astype(np.int64)
    gidx = rng.integers(0, n, m).astype(np.int32)
    plan = build_tile_plan(gidx, seg, s, 64, 64)
    np.asarray(segment_sum(plan, vals, use_pallas=False))  # warm the cache
    cache0 = segment_sum._cache_size()
    outs, rows = [], []
    for step in range(3):
        changed = rng.choice(s, 12, replace=False)
        keep = ~np.isin(seg, changed)
        add_seg = np.repeat(changed, 2)
        add_gidx = rng.integers(0, n, add_seg.size).astype(np.int32)
        seg2 = np.concatenate([seg[keep], add_seg])
        gidx2 = np.concatenate([gidx[keep], add_gidx])
        order = np.argsort(seg2, kind="stable")
        seg, gidx = seg2[order], gidx2[order]
        patched = patch_tile_plan(plan, gidx, seg, s, changed)
        # static parts are the same device arrays, not re-uploads
        assert patched.m2out is plan.m2out and patched.first_visit is plan.first_visit
        outs.append(np.asarray(segment_sum(patched, vals, use_pallas=False)))
        rows.append((gidx.copy(), seg.copy()))
        plan = patched
    assert segment_sum._cache_size() == cache0  # scatter path: no retrace
    for (gi, si), out_p in zip(rows, outs):  # rebuild oracle, after the count
        fresh = build_tile_plan(gi, si, s, 64, 64)
        out_f = np.asarray(segment_sum(fresh, vals, use_pallas=False))
        assert np.array_equal(out_p, out_f)


def test_patch_tile_plan_stable_shapes_when_rows_fit():
    """Steady-state streams must not change static shapes (no recompiles)."""
    rng = np.random.default_rng(2)
    n, m, s = 300, 2000, 256
    seg = np.sort(rng.integers(0, s, m)).astype(np.int64)
    gidx = rng.integers(0, n, m).astype(np.int32)
    plan = build_tile_plan(gidx, seg, s, 64, 64)
    # shrink a few segments (rows certainly still fit the old capacity)
    changed = rng.choice(s, 10, replace=False)
    keep = ~np.isin(seg, changed)
    patched = patch_tile_plan(plan, gidx[keep], seg[keep], s, changed)
    assert patched.gather_padded.shape == plan.gather_padded.shape
    assert patched.seg_tiles.shape == plan.seg_tiles.shape
    assert np.array_equal(np.asarray(patched.m2out), np.asarray(plan.m2out))


# --------------------- DBIndex plan parity over streams --------------- #
@pytest.mark.parametrize("k,directed", [(1, False), (2, False), (2, True)])
def test_dbindex_patched_plan_parity(k, directed):
    rng = np.random.default_rng(100 + k)
    g = with_random_attrs(
        erdos_renyi(220, 4.0, directed=directed, seed=k), seed=k + 1
    )
    w = KHopWindow(k)
    idx = build_dbindex(g, w, method="emc")
    plan = ej.plan_from_dbindex(idx, tm=64, ts=64)
    for step in range(3):
        b = mixed(g, rng, 15, 6)
        g = U.apply_batch(g, b)
        idx, owners = U.update_dbindex_batch(idx, g, w, b)
        plan = ej.patch_plan_dbindex(plan, idx, owners)
        fresh = ej.plan_from_dbindex(idx, tm=64, ts=64,
                                     block_capacity=plan.block_capacity)
        for agg in ("sum", "count", "avg"):
            got = np.asarray(ej.query_dbindex(plan, g.attrs["val"], agg,
                                              use_pallas=False))
            ref_plan = np.asarray(ej.query_dbindex(fresh, g.attrs["val"], agg,
                                                   use_pallas=False))
            assert np.array_equal(got, ref_plan), (step, agg)  # bit-for-bit
            oracle = brute_force(g, w, g.attrs["val"], agg)
            assert np.allclose(got, oracle, rtol=1e-5, atol=1e-3), (step, agg)


def test_dbindex_patched_plan_parity_pallas_interpret():
    """One case through the Pallas kernel in interpret mode (CPU-safe)."""
    rng = np.random.default_rng(7)
    g = with_random_attrs(erdos_renyi(150, 3.0, directed=False, seed=7), seed=8)
    w = KHopWindow(1)
    idx = build_dbindex(g, w, method="emc")
    plan = ej.plan_from_dbindex(idx, tm=64, ts=64)
    b = mixed(g, rng, 10, 4)
    g = U.apply_batch(g, b)
    idx, owners = U.update_dbindex_batch(idx, g, w, b)
    plan = ej.patch_plan_dbindex(plan, idx, owners)
    got = np.asarray(ej.query_dbindex(plan, g.attrs["val"], "sum",
                                      use_pallas=True, interpret=True))
    oracle = brute_force(g, w, g.attrs["val"], "sum")
    assert np.allclose(got, oracle, rtol=1e-5, atol=1e-3)


def test_dbindex_plan_capacity_growth_is_pow2():
    rng = np.random.default_rng(8)
    g = with_random_attrs(erdos_renyi(200, 4.0, directed=False, seed=9), seed=10)
    w = KHopWindow(1)
    idx = build_dbindex(g, w, method="emc")
    plan = ej.plan_from_dbindex(idx, tm=64, ts=64)
    caps = [plan.block_capacity]
    for _ in range(4):
        b = mixed(g, rng, 20, 0)
        g = U.apply_batch(g, b)
        idx, owners = U.update_dbindex_batch(idx, g, w, b)
        plan = ej.patch_plan_dbindex(plan, idx, owners)
        caps.append(plan.block_capacity)
        assert plan.block_capacity >= idx.num_blocks
    grown = [c for a, c in zip(caps, caps[1:]) if c != a]
    assert all(c & (c - 1) == 0 for c in grown)  # powers of two only


def test_patch_plan_dbindex_compacts_garbage_blocks():
    """A delete-heavy stream strands zero-link blocks whose member rows
    still occupy pass-1 tiles; crossing ``compact_garbage`` re-lays pass 1
    without them — smaller plan, identical answers."""
    from repro.core.streaming import garbage_block_fraction
    from test_updates import random_delete_batch

    rng = np.random.default_rng(44)
    g = with_random_attrs(erdos_renyi(160, 6.0, directed=False, seed=27), seed=28)
    w = KHopWindow(1)
    idx = build_dbindex(g, w, method="emc")
    plan = ej.plan_from_dbindex(idx, tm=64, ts=64)
    for _ in range(3):
        b = random_delete_batch(g, rng, 40)
        g = U.apply_batch(g, b)
        idx, owners = U.update_dbindex_batch(idx, g, w, b)
    assert garbage_block_fraction(idx) > 0.05, "stream produced no garbage"
    lazy = ej.patch_plan_dbindex(plan, idx, owners, compact_garbage=1.1)
    compacted = ej.patch_plan_dbindex(plan, idx, owners, compact_garbage=0.05)
    assert (compacted.pass1.seg_tiles.size < lazy.pass1.seg_tiles.size)
    for agg in ("sum", "count", "avg", "min"):
        out_c = np.asarray(ej.query_dbindex(compacted, g.attrs["val"], agg,
                                            use_pallas=False))
        out_l = np.asarray(ej.query_dbindex(lazy, g.attrs["val"], agg,
                                            use_pallas=False))
        assert np.array_equal(out_c, out_l), agg  # garbage contributes nothing
        oracle = brute_force(g, w, g.attrs["val"], agg)
        assert np.allclose(out_c, oracle, rtol=1e-5, atol=1e-3), agg


# --------------------- I-Index plan parity over streams --------------- #
@pytest.mark.parametrize("schedule", ["level", "doubling"])
def test_iindex_patched_plan_parity(schedule):
    rng = np.random.default_rng(9)
    g = with_random_attrs(random_dag(180, 2.5, seed=17), seed=18)
    ii = build_iindex(g)
    plan = ej.plan_from_iindex(ii, tm=64, ts=64)
    for step in range(3):
        b = mixed(g, rng, 10, 4, dag=True)
        g = U.apply_batch(g, b)
        ii, cone = U.update_iindex_batch(ii, g, b)
        plan = ej.patch_plan_iindex(plan, ii, cone)
        fresh = ej.plan_from_iindex(ii, tm=64, ts=64)
        got = np.asarray(ej.query_iindex(plan, g.attrs["val"], schedule=schedule,
                                         use_pallas=False))
        ref_plan = np.asarray(ej.query_iindex(fresh, g.attrs["val"],
                                              schedule=schedule, use_pallas=False))
        assert np.array_equal(got, ref_plan), step  # bit-for-bit
        oracle = brute_force(g, TopologicalWindow(), g.attrs["val"], "sum")
        assert np.allclose(got, oracle, rtol=1e-5, atol=1e-3), step


def test_dbindex_large_affected_set_falls_back_and_plan_stays_valid():
    """When >n/2 owners are affected the updater rebuilds outright; the
    appended-prefix invariant then does NOT hold, and patch_plan_dbindex
    must rebuild the plan instead of splicing stale tiles."""
    # chain DAG: descendants of vertex 2 are the whole tail (> n/2)
    from repro.core.graph import Graph

    n = 100
    g = Graph(n=n, src=np.arange(n - 1, dtype=np.int32),
              dst=np.arange(1, n, dtype=np.int32), directed=True)
    g = with_random_attrs(g, seed=34)
    w = TopologicalWindow()
    idx = build_dbindex(g, w, method="mc")
    plan = ej.plan_from_dbindex(idx, tm=64, ts=64)
    b = U.UpdateBatch.inserts([0], [2])  # cone = descendants(2) = n-2 > n/2
    g2 = U.apply_batch(g, b)
    idx2, owners = U.update_dbindex_batch(idx, g2, w, b)
    assert idx2.stats.get("last_full_rebuild") is True
    assert owners.size == g.n
    plan2 = ej.patch_plan_dbindex(plan, idx2, owners)
    got = np.asarray(ej.query_dbindex(plan2, g2.attrs["val"], "sum",
                                      use_pallas=False))
    fresh = np.asarray(ej.query_dbindex(
        ej.plan_from_dbindex(idx2, tm=64, ts=64,
                             block_capacity=plan2.block_capacity),
        g2.attrs["val"], "sum", use_pallas=False))
    assert np.array_equal(got, fresh)
    oracle = brute_force(g2, w, g2.attrs["val"], "sum")
    assert np.allclose(got, oracle, rtol=1e-5, atol=1e-3)
    # and the next (small) batch clears the flag so splicing resumes
    rng = np.random.default_rng(35)
    b2 = mixed(g2, rng, 2, 0, dag=True)
    g3 = U.apply_batch(g2, b2)
    idx3, owners3 = U.update_dbindex_batch(idx2, g3, w, b2)
    if not idx3.stats.get("last_full_rebuild"):
        plan3 = ej.patch_plan_dbindex(plan2, idx3, owners3)
        got3 = np.asarray(ej.query_dbindex(plan3, g3.attrs["val"], "sum",
                                           use_pallas=False))
        assert np.allclose(got3, brute_force(g3, w, g3.attrs["val"], "sum"),
                           rtol=1e-5, atol=1e-3)


# --------------------- engine with device plans ----------------------- #
def test_streaming_engine_device_stream():
    rng = np.random.default_rng(19)
    g = with_random_attrs(erdos_renyi(160, 4.0, directed=False, seed=21), seed=22)
    eng = StreamingEngine(
        g, KHopWindow(1), use_pallas=False,
        policy=StalenessPolicy(max_link_ratio=1.3, min_batches=2),
    )
    for step in range(5):
        b = mixed(eng.graph, rng, 12, 5)
        eng.apply(b)
        ref = brute_force(eng.graph, eng.window, eng.graph.attrs["val"], "sum")
        assert np.allclose(eng.query("sum"), ref, rtol=1e-5, atol=1e-3), step


def test_streaming_engine_device_iindex():
    rng = np.random.default_rng(23)
    g = with_random_attrs(random_dag(140, 2.0, seed=25), seed=26)
    eng = StreamingEngine(g, TopologicalWindow(), index_kind="iindex",
                          use_pallas=False)
    for step in range(3):
        b = mixed(eng.graph, rng, 8, 3, dag=True)
        eng.apply(b)
        ref = brute_force(eng.graph, TopologicalWindow(),
                          eng.graph.attrs["val"], "sum")
        assert np.allclose(eng.query("sum"), ref, rtol=1e-5, atol=1e-3), step
