"""Observability subsystem (ISSUE 7): metrics registry thread safety,
histogram bucket semantics, Null compile-out guarantees, span nesting and
Chrome-trace export, and the differential guarantee that enabling obs
never changes served results.
"""

import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs import (
    DEFAULT_LATENCY_BUCKETS_S,
    Histogram,
    MetricsRegistry,
    NullRegistry,
    NullTracer,
    Tracer,
)
from repro.obs.slo import SLOTracker


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts and ends with the global obs layer disabled."""
    obs.disable()
    yield
    obs.disable()


# ---------------------------------------------------------------------- #
#  Registry + instruments
# ---------------------------------------------------------------------- #
def test_counter_concurrent_writers_lose_nothing():
    """Per-thread shard cells: N writers x M incs must merge to exactly
    N*M — no lost updates, no locks on the write path."""
    reg = MetricsRegistry()
    c = reg.counter("repro_test_total", "t")
    h = reg.histogram("repro_test_seconds", "t", buckets=(0.1, 1.0))
    lab = reg.counter("repro_test_labeled_total", "t", labels=("who",))
    n_threads, n_incs = 8, 10_000
    start = threading.Barrier(n_threads)

    def work(i):
        mine = lab.labels(f"w{i % 2}")
        start.wait()
        for _ in range(n_incs):
            c.inc()
            h.observe(0.05)
            mine.inc(2)

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == n_threads * n_incs
    assert h.count == n_threads * n_incs
    per_label = n_threads // 2 * n_incs * 2
    assert lab.labels("w0").value == per_label
    assert lab.labels("w1").value == per_label


def test_registry_declarations_idempotent_and_clash_checked():
    reg = MetricsRegistry()
    a = reg.counter("repro_x_total", "first")
    b = reg.counter("repro_x_total", "redeclared")
    assert a is b  # same family object: instruments are process-wide names
    with pytest.raises(ValueError):
        reg.gauge("repro_x_total")  # kind clash
    with pytest.raises(ValueError):
        reg.counter("repro_x_total", labels=("cls",))  # labelnames clash


def test_gauge_set_inc_dec():
    reg = MetricsRegistry()
    g = reg.gauge("repro_depth")
    g.set(5)
    g.inc(2)
    g.dec()
    assert g.value == 6


def test_histogram_bucket_edges_and_quantiles():
    """Bucket bounds are inclusive upper edges; quantiles interpolate
    linearly inside the landing bucket and clamp at overflow."""
    h = Histogram(buckets=(1.0, 2.0, 4.0))
    # bisect_left on inclusive upper bounds: 1.0 lands IN the first bucket
    for x in (0.5, 1.0):
        h.observe(x)
    h.observe(3.0)   # third bucket (2, 4]
    h.observe(100.0)  # overflow
    counts, total, n = h.merged()
    assert counts == [2, 0, 1, 1]
    assert n == 4 and total == pytest.approx(104.5)
    # overflow clamps to the last finite bound
    assert h.quantile(1.0) == 4.0
    # q=0.5 -> target 2.0 falls exactly at the end of bucket 0: edge-exact
    assert h.quantile(0.5) == pytest.approx(1.0)
    empty = Histogram(buckets=(1.0,))
    assert empty.quantile(0.99) == 0.0
    with pytest.raises(AssertionError):
        Histogram(buckets=(2.0, 1.0))  # must be strictly increasing


def test_snapshot_and_prometheus_shapes():
    reg = MetricsRegistry()
    reg.counter("repro_reqs_total", "requests", labels=("cls",)
                ).labels("fast").inc(3)
    reg.gauge("repro_lag").set(7)
    reg.histogram("repro_lat_seconds", "latency",
                  buckets=(0.1, 1.0)).observe(0.05)
    snap = reg.snapshot()
    assert snap["repro_reqs_total"]["values"][0] == {
        "labels": {"cls": "fast"}, "value": 3.0}
    assert snap["repro_lag"]["values"][0]["value"] == 7.0
    hist = snap["repro_lat_seconds"]["values"][0]
    assert hist["count"] == 1 and "p99" in hist
    text = reg.prometheus()
    assert '# TYPE repro_reqs_total counter' in text
    assert 'repro_reqs_total{cls="fast"} 3' in text
    # prometheus histograms are cumulative with a +Inf bucket
    assert 'repro_lat_seconds_bucket{le="+Inf"} 1' in text
    assert 'repro_lat_seconds_count 1' in text


def test_null_registry_is_inert():
    reg = NullRegistry()
    assert not reg.enabled
    c = reg.counter("repro_anything_total", labels=("a", "b"))
    # every operation is a no-op returning the singleton
    c.inc()
    c.labels("x", "y").inc(5)
    assert c.labels("x", "y") is c.labels("p", "q")
    assert c.value == 0.0
    h = reg.histogram("repro_h_seconds")
    h.observe(1.0)
    assert h.count == 0 and h.quantile(0.99) == 0.0
    g = reg.gauge("repro_g")
    g.set(9)
    g.dec()
    assert g.value == 0.0
    assert reg.snapshot() == {}
    assert reg.prometheus() == ""


def test_global_enable_disable_swaps_registries():
    assert isinstance(obs.get_registry(), NullRegistry)
    reg, tr = obs.enable()
    assert obs.get_registry() is reg and obs.get_tracer() is tr
    assert reg.enabled and tr.enabled
    reg.counter("repro_t_total").inc()
    obs.disable()
    assert isinstance(obs.get_registry(), NullRegistry)
    assert isinstance(obs.get_tracer(), NullTracer)
    # a fresh enable starts clean: no user metrics carry over — only the
    # built-in collect-on-scrape families are pre-declared
    reg2, _ = obs.enable()
    snap = reg2.snapshot()
    assert "repro_t_total" not in snap
    assert set(snap) <= {"repro_recompiles",
                         "repro_trace_spans_dropped_total"}


# ---------------------------------------------------------------------- #
#  Tracing
# ---------------------------------------------------------------------- #
def test_span_nesting_parents_and_export_round_trip(tmp_path):
    tr = Tracer()
    with tr.span("outer", cat="t", a=1) as outer:
        with tr.span("mid", cat="t"):
            with tr.span("inner", cat="t") as inner:
                inner.set(rows=4)
    detached = tr.start_span("ticket", cat="t", parent=outer.id)
    detached.finish()
    evs = {e["name"]: e for e in tr.events()}
    assert evs["mid"]["args"]["parent_id"] == evs["outer"]["args"]["span_id"]
    assert evs["inner"]["args"]["parent_id"] == evs["mid"]["args"]["span_id"]
    assert evs["ticket"]["args"]["parent_id"] == evs["outer"]["args"]["span_id"]
    assert evs["inner"]["args"]["rows"] == 4
    assert tr.max_depth() == 3
    for e in evs.values():
        assert e["dur"] >= 0

    path = tmp_path / "trace.json"
    tr.dump(path)
    doc = json.loads(path.read_text())
    names = {e.get("name") for e in doc["traceEvents"]}
    assert {"outer", "mid", "inner", "ticket"} <= names
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert all({"ts", "dur", "pid", "tid"} <= set(e) for e in xs)


def test_span_exit_records_error_and_ring_buffer_caps():
    tr = Tracer(capacity=8)
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    assert tr.events()[-1]["args"]["error"] == "RuntimeError"
    for i in range(20):
        with tr.span(f"s{i}"):
            pass
    assert len(tr) == 8  # oldest spans fell off the ring
    null = NullTracer()
    with null.span("n") as sp:
        sp.set(a=1)
    assert null.events() == [] and null.max_depth() == 0


# ---------------------------------------------------------------------- #
#  SLO accounting
# ---------------------------------------------------------------------- #
def test_slo_tracker_attainment_and_outcomes():
    reg = MetricsRegistry()
    slo = SLOTracker(reg)
    for lat in (0.001, 0.002, 0.050):
        slo.observe("interactive", lat, target_s=0.005)
    slo.observe("interactive", 0.1, target_s=0.005, outcome="error")
    slo.observe("interactive", 0.0, target_s=0.005, outcome="shed")
    rep = slo.report()["interactive"]
    assert rep["target_ms"] == pytest.approx(5.0)
    assert rep["ok"] == 3 and rep["error"] == 1 and rep["shed"] == 1
    assert rep["attainment"] == pytest.approx(2 / 3)
    assert rep["p50_ms"] > 0
    slo.observe("batch", 1.0)  # no target: attainment undefined
    assert slo.report()["batch"]["attainment"] is None


# ---------------------------------------------------------------------- #
#  Differential: obs on/off must not change results
# ---------------------------------------------------------------------- #
def test_enabling_obs_does_not_change_results_bitwise():
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.core.api import QuerySpec, Session
    from repro.graphs.generators import erdos_renyi
    from repro.serve import WindowService
    from test_updates import mixed

    def run(enabled):
        if enabled:
            obs.enable()
        else:
            obs.disable()
        g = erdos_renyi(120, 3.0, directed=False, seed=41)
        vals = np.random.default_rng(42).integers(0, 50, g.n)
        g = g.with_attr("val", vals.astype(np.float64))
        sess = Session(g, [QuerySpec(("khop", 2), "sum"),
                           QuerySpec(("khop", 1), "min")],
                       use_pallas=False)
        svc = WindowService(sess, bucket=4)
        rng = np.random.default_rng(43)
        outs = []
        for _ in range(3):
            svc.update(mixed(svc.session.graph, rng, 5, 2))
            tickets = [svc.submit(0), svc.submit(1), svc.submit(0, vertex=7)]
            svc.flush()
            outs.append([np.asarray(t.get(timeout=0)) for t in tickets])
        return outs

    base, instrumented = run(False), run(True)
    snap = obs.get_registry().snapshot()
    assert snap["repro_flushes_total"]["values"], "obs really was on"
    for a, b in zip(base, instrumented):
        for x, y in zip(a, b):
            assert np.array_equal(x, y)


# ---------------------------------------------------------------------- #
#  Label escaping, collect-on-scrape, trace-drop exposure (ISSUE 8)
# ---------------------------------------------------------------------- #
def test_prometheus_hostile_label_value_round_trips():
    """A label value carrying backslashes, quotes, and newlines must stay
    on one exposition line and invert exactly through the escaper."""
    import re

    from repro.obs.metrics import _escape_label_value, _unescape_label_value

    hostile = 'a\\b"c\nd{},= \\" \n\\ e'
    reg = MetricsRegistry()
    reg.counter("repro_hostile_total", "t", labels=("who",)
                ).labels(hostile).inc(3)
    text = reg.prometheus()
    lines = [ln for ln in text.splitlines()
             if ln.startswith("repro_hostile_total{")]
    assert len(lines) == 1, "newline in the value must not split the line"
    line = lines[0]
    m = re.search(r'who="((?:[^"\\]|\\.)*)"', line)
    assert m, line
    assert _unescape_label_value(m.group(1)) == hostile
    assert line.endswith(" 3")
    # escape/unescape is a bijection on every metacharacter alone too
    for v in ("\\", '"', "\n", "", "plain", '\\n'):
        assert _unescape_label_value(_escape_label_value(v)) == v


def test_collectors_run_on_scrape_and_dedupe_by_name():
    reg = MetricsRegistry()
    calls = []

    def fill(r):
        calls.append(1)
        r.gauge("repro_scraped").set(len(calls))

    reg.collect(fill, name="fill")
    reg.collect(fill, name="fill")  # same name: replaces, no double-run
    snap = reg.snapshot()
    assert len(calls) == 1
    assert snap["repro_scraped"]["values"][0]["value"] == 1.0
    reg.prometheus()
    assert len(calls) == 2  # fresh on every scrape

    def broken(r):
        raise RuntimeError("collector bug")

    reg.collect(broken, name="broken")
    reg.snapshot()  # a broken collector must not poison the scrape


def test_recompile_gauge_is_collected_fresh():
    reg, _ = obs.enable()
    from repro.core.api import recompile_count

    snap = reg.snapshot()
    assert snap["repro_recompiles"]["values"][0]["value"] == float(
        recompile_count())
    assert "repro_recompiles" in reg.prometheus()


def test_trace_drop_counter_exposed_and_monotonic():
    tr = Tracer(capacity=4)
    reg, _ = obs.enable(tracer=tr)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    snap = reg.snapshot()
    fam = snap["repro_trace_spans_dropped_total"]
    dropped = fam["values"][0]["value"]
    assert dropped == float(tr.dropped_hint) and dropped > 0
    # monotonic across scrapes: delta-folded, not re-added
    snap2 = reg.snapshot()
    assert snap2["repro_trace_spans_dropped_total"]["values"][0][
        "value"] == dropped
    tr.instant("one-more")  # ring is full: this drops another event
    for _ in range(3):
        with tr.span("x"):
            pass
    snap3 = reg.snapshot()
    assert snap3["repro_trace_spans_dropped_total"]["values"][0][
        "value"] == float(tr.dropped_hint) > dropped
    assert "repro_trace_spans_dropped_total" in reg.prometheus()


def test_reenable_same_registry_does_not_double_count_drops():
    """ISSUE 9 satellite: obs.enable(registry=r, tracer=t) called twice
    must be idempotent — re-running _install_collectors used to reset the
    drop-delta seen-state, folding the whole historical drop count in
    again on the next scrape (double counting)."""
    tr = Tracer(capacity=4)
    reg = MetricsRegistry()
    obs.enable(registry=reg, tracer=tr)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    dropped = reg.snapshot()[
        "repro_trace_spans_dropped_total"]["values"][0]["value"]
    assert dropped == float(tr.dropped_hint) > 0
    # re-enable with the SAME registry + tracer (e.g. a test harness
    # round-tripping enable/disable): nothing may be re-counted
    obs.enable(registry=reg, tracer=tr)
    again = reg.snapshot()[
        "repro_trace_spans_dropped_total"]["values"][0]["value"]
    assert again == dropped
    # and the collector did not stack either: one more drop folds once
    tr.instant("overflow")
    for _ in range(2):
        with tr.span("x"):
            pass
    final = reg.snapshot()[
        "repro_trace_spans_dropped_total"]["values"][0]["value"]
    assert final == float(tr.dropped_hint)


def test_name_thread_metadata_survives_thread_exit():
    """ISSUE 9 satellite: worker threads self-register display names; the
    Chrome export carries `"ph": "M"` thread_name rows for them even after
    the thread has exited (threading.enumerate() no longer sees it)."""
    tr = Tracer()

    def worker():
        tr.name_thread()  # registers "audit-worker-x" by ident
        with tr.span("work"):
            pass

    th = threading.Thread(target=worker, name="audit-worker-x")
    th.start()
    th.join()
    evs = tr.chrome_trace()["traceEvents"]
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "audit-worker-x" in names
    # one process_name row anchors the whole pid in Perfetto
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in evs)
    # explicit-name form wins over the Thread name
    tr.name_thread("custom-role")
    evs = tr.chrome_trace()["traceEvents"]
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "custom-role" in names
    # NullTracer compiles the call out
    NullTracer().name_thread("whatever")


def test_flight_recorder_wall_clock_anchor(tmp_path):
    """ISSUE 9 satellite: dump_json carries anchor_unix_s so the
    perf_counter-relative t_s stamps correlate with wall-clock metric and
    trace timestamps."""
    import time as _time

    from repro.serve.flight import FlightRecorder

    before = _time.time()
    fr = FlightRecorder(capacity=8)
    after = _time.time()
    assert before <= fr.anchor_unix_s <= after
    fr.record("flip", version=1)
    out = json.loads(open(fr.dump_json(tmp_path / "f.json")).read())
    assert out["anchor_unix_s"] == fr.anchor_unix_s
    assert out["events"][0]["t_s"] >= 0.0
