"""I-Index: inheritance invariants, query equality, updates, device plans."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: use the local shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import engine_jax as ej
from repro.core import updates
from repro.core.iindex import build_iindex
from repro.core.query import brute_force
from repro.core.windows import TopologicalWindow, topological_window_single
from repro.graphs.generators import random_dag, with_random_attrs


def test_reconstruction(small_dag):
    g = small_dag
    ii = build_iindex(g)
    for v in range(0, g.n, 9):
        assert np.array_equal(ii.window_of(v), topological_window_single(g, v)), v


def test_pid_is_parent_with_max_window(small_dag):
    g = small_dag
    ii = build_iindex(g)
    from repro.core.windows import topological_windows

    wins = topological_windows(g)
    sizes = np.array([w.size for w in wins])
    for v in range(g.n):
        parents = g.in_neighbors(v)
        if parents.size == 0:
            assert ii.pid[v] == -1
        else:
            assert sizes[ii.pid[v]] == sizes[parents].max()


@pytest.mark.parametrize("agg", ["sum", "count", "min", "max", "avg"])
def test_query_aggregates(small_dag, agg):
    g = small_dag
    ii = build_iindex(g)
    ref = brute_force(g, TopologicalWindow(), g.attrs["val"], agg)
    assert np.allclose(ii.query(g.attrs["val"], agg), ref)


def test_paper_pathway_example():
    """Fig. 2/5: W_t(E)={A,B,C,D,E}, W_t(H)={A,B,D,H} (ids A=0..H=7).

    Edges: A->B? — from the paper: D's window {A,B,D}; E's {A,B,C,D,E};
    H's {A,B,D,H}.  A DAG consistent with those: A->B, B->D, C->E, D->E,
    D->H.
    """
    from repro.core.graph import Graph

    g = Graph(n=8, src=np.array([0, 1, 2, 3, 3], np.int32),
              dst=np.array([1, 3, 4, 4, 7], np.int32), directed=True)
    ii = build_iindex(g)
    assert set(ii.window_of(4).tolist()) == {0, 1, 2, 3, 4}
    assert set(ii.window_of(7).tolist()) == {0, 1, 3, 7}


def test_update_insert(small_dag):
    g = small_dag
    ii = build_iindex(g)
    order = g.topological_order()
    s, t = int(order[0]), int(order[-1])
    g2 = updates.insert_edge(g, s, t)
    ii2 = updates.update_iindex(ii, g2, s, t)
    ref = brute_force(g2, TopologicalWindow(), g2.attrs["val"], "sum")
    assert np.allclose(ii2.query(g2.attrs["val"], "sum"), ref)


def test_update_delete(small_dag):
    g = small_dag
    ii = build_iindex(g)
    s, t = int(g.src[3]), int(g.dst[3])
    g2 = updates.delete_edge(g, s, t)
    ii2 = updates.update_iindex(ii, g2, s, t)
    ref = brute_force(g2, TopologicalWindow(), g2.attrs["val"], "sum")
    assert np.allclose(ii2.query(g2.attrs["val"], "sum"), ref)


@pytest.mark.parametrize("schedule", ["level", "doubling"])
def test_device_plan(small_dag, schedule):
    g = small_dag
    ii = build_iindex(g)
    plan = ej.plan_from_iindex(ii)
    ref = brute_force(g, TopologicalWindow(), g.attrs["val"], "sum")
    got = np.asarray(ej.query_iindex(plan, g.attrs["val"], schedule=schedule))
    assert np.allclose(got, ref, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(st.integers(15, 100), st.integers(1, 5), st.integers(0, 99999))
def test_property_iindex(n, deg, seed):
    g = with_random_attrs(random_dag(n, float(deg), seed=seed), seed=seed + 1)
    ii = build_iindex(g)
    ref = brute_force(g, TopologicalWindow(), g.attrs["val"], "sum")
    assert np.allclose(ii.query(g.attrs["val"], "sum"), ref)
    # containment chain: WD sizes sum to total window content
    total = sum(
        topological_window_single(g, v).size for v in range(g.n)
    )
    assert ii.wd_members.size <= total  # inheritance never stores more
