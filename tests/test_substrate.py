"""Substrate tests: optimizers, schedules, compression, checkpoints,
fault tolerance (preempt->resume identical trajectory), data determinism,
serving engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import NeighborSampler, RecsysStream, TokenStream
from repro.optim.grad_compress import init_error_feedback, int8_compress_hook
from repro.optim.optimizers import adafactor, adamw, clip_by_global_norm, sgd
from repro.optim.schedules import cosine_schedule, linear_warmup
from repro.train.checkpoints import CheckpointManager
from repro.train.fault_tolerance import FaultToleranceMonitor
from repro.train.trainer import TrainConfig, Trainer


# ----------------------------- optimizers ----------------------------- #
@pytest.mark.parametrize("make_opt", [
    lambda: adamw(1e-1), lambda: sgd(1e-2), lambda: adafactor(5e-1),
])
def test_optimizer_minimizes_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 4)), jnp.float32)}
    state = opt.init(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - 3.0))
    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(loss(params)) < 0.5 * l0


def test_adamw_bf16_moments_dtype():
    opt = adamw(1e-3)
    params = {"w": jnp.zeros((4, 4), jnp.float32)}
    state = opt.init(params)
    assert state.mu["w"].dtype == jnp.bfloat16
    assert state.nu["w"].dtype == jnp.bfloat16


def test_global_norm_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 30
    flat = np.asarray(clipped["a"])
    assert np.isclose(np.linalg.norm(flat), 1.0, atol=1e-4)


def test_schedules():
    warm = linear_warmup(1.0, 10)
    assert float(warm(jnp.asarray(5))) == pytest.approx(0.5)
    cos = cosine_schedule(1.0, 10, 100)
    assert float(cos(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-2)
    assert float(cos(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-2)


def test_int8_compression_error_feedback():
    """Residual carries: the *sum* of decompressed grads converges to the
    sum of true grads (the EF property)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    err = init_error_feedback({"g": g_true})["g"]
    total = jnp.zeros_like(g_true)
    for _ in range(50):
        dec, err = int8_compress_hook({"g": g_true}, {"g": err})
        dec, err = dec["g"], err["g"]
        total = total + dec
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g_true),
                               atol=1e-2)


# ----------------------------- checkpoints ---------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path)
    state = {"a": jnp.arange(10, dtype=jnp.float32), "b": {"c": jnp.ones((3, 3))}}
    cm.save(5, state, {"cursor": 42})
    restored, extra, step = cm.restore(state)
    assert step == 5 and extra["cursor"] == 42
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10))


def test_checkpoint_atomicity_and_gc(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2)
    state = {"x": jnp.zeros(4)}
    for s in (1, 2, 3, 4):
        cm.save(s, state)
    assert cm.steps() == [3, 4]  # old ones garbage-collected
    # a stale tmp dir must not be picked up
    (tmp_path / "step_9.tmp").mkdir()
    assert cm.latest_step() == 4


def _make_trainer(tmp_path, seed=0, compression=False):
    params = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(16, 8)), jnp.float32)}

    def loss_fn(p, batch):
        x = batch["tokens"].astype(jnp.float32)
        pred = x[:, :8] @ p["w"][:8]
        return jnp.mean(jnp.square(pred - x[:, :8]))

    data = TokenStream(vocab=50, batch=4, seq=16, seed=seed)
    cfg = TrainConfig(total_steps=10, microbatch=2, checkpoint_every=5,
                      checkpoint_dir=str(tmp_path), grad_compression=compression)
    return Trainer(loss_fn, adamw(1e-2), params, data, cfg)


def test_preempt_resume_identical_trajectory(tmp_path):
    """The fault-tolerance contract: resume == never-crashed."""
    ref = _make_trainer(tmp_path / "ref")
    ref.run(10)
    ref_losses = [h["loss"] for h in ref.history]

    tr = _make_trainer(tmp_path / "crash")
    tr.run(5)  # checkpoint lands at step 5
    tr.monitor.request_preemption()
    tr.run(100)  # exits immediately (preempted)
    # "restart": new trainer object, restore, continue
    tr2 = _make_trainer(tmp_path / "crash")
    tr2.resume()
    assert tr2.step == 5
    tr2.run(5)
    resumed = [h["loss"] for h in tr2.history]
    np.testing.assert_allclose(resumed, ref_losses[5:], rtol=1e-6)


def test_grad_compression_trains(tmp_path):
    tr = _make_trainer(tmp_path, compression=True)
    out = tr.run(10)
    assert out["history"][-1]["loss"] < out["history"][0]["loss"]


def test_straggler_watchdog():
    mon = FaultToleranceMonitor(straggler_factor=3.0)
    for s in range(20):
        mon.observe_step(s, 0.01)
    mon.observe_step(20, 1.0)  # 100x the median
    assert mon.straggler_count() == 1
    assert mon.events.stragglers[0]["step"] == 20


# ------------------------------ data ---------------------------------- #
def test_token_stream_deterministic_resume():
    a = TokenStream(vocab=100, batch=2, seq=8, seed=7)
    batches = [a.next() for _ in range(5)]
    b = TokenStream(vocab=100, batch=2, seq=8, seed=7)
    b.restore({"seed": 7, "step": 3})
    np.testing.assert_array_equal(b.next()["tokens"], batches[3]["tokens"])


def test_recsys_stream():
    s = RecsysStream(n_fields=5, batch=16, seed=1)
    b = s.next()
    assert b["x"].shape == (16, 5) and b["y"].shape == (16,)


def test_neighbor_sampler_shapes():
    from repro.graphs.generators import erdos_renyi

    g = erdos_renyi(500, 8.0, seed=3)
    samp = NeighborSampler(g, fanouts=(5, 3))
    sub = samp.sample(batch_nodes=32)
    assert sub["node_ids"].size == 32 * (1 + 5 + 15)
    assert sub["edge_src"].size == 32 * 5 + 160 * 3
    # every edge destination is in an earlier ring
    assert (sub["edge_dst"] < sub["edge_src"]).all()


# ------------------------------ serving -------------------------------- #
def test_serve_engine_greedy_matches_forward():
    from repro.configs.registry import get_arch
    from repro.models import transformer as T
    from repro.serve.engine import Request, ServeEngine

    cfg = get_arch("qwen3-0.6b").smoke_cfg
    params = T.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, T, max_seq=32, slots=2)
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new=4) for i in range(2)]
    outs = eng.generate(reqs)
    assert set(outs) == {0, 1}
    assert all(o.size == 4 for o in outs.values())
    # greedy decode equals argmax over full forward for the first new token
    full = T.forward(params, jnp.asarray(np.stack([r.prompt for r in reqs])), cfg)
    np.testing.assert_array_equal(
        np.array([outs[0][0], outs[1][0]]),
        np.asarray(jnp.argmax(full[:, -1], -1)),
    )
