"""Filter-predicate maintenance + drained-index robustness (ISSUE 6).

Satellite 1 — a truthiness flip of a Filter predicate attribute changes
only the flipped vertices' *own* membership in any composite window
(k-hop/topological expansion exists only at the leaves, below every
Filter), so the maintenance path may rebuild just the blocks containing
flipped vertices (``DBIndex.owners_of_members`` + a reverse-reachability
sweep for gains) instead of the whole index.  These tests differentially
pin the bounded path against a from-scratch rebuild and the
set-evaluation oracle, and assert the bounded path actually runs.

Satellite 3 — delete-everything streams: ``garbage_block_fraction`` and
pass-1 compaction must tolerate empty and zero-block indices (no division
by zero, no spurious reorganize), across compaction configs.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.query import brute_force  # noqa: E402
from repro.core.streaming import StalenessPolicy, StreamingEngine  # noqa: E402
from repro.core.updates import UpdateBatch  # noqa: E402
from repro.core.windows import (  # noqa: E402
    Diff,
    Filter,
    Intersect,
    KHop,
    KHopWindow,
    Union,
)
from repro.graphs.generators import erdos_renyi  # noqa: E402


def masked_graph(n=300, deg=2.0, seed=3, attrs=("mask",)):
    g = erdos_renyi(n, deg, directed=False, seed=seed)
    rng = np.random.default_rng(seed + 1)
    g = g.with_attr("val", rng.integers(0, 50, n).astype(np.float64))
    for a in attrs:
        g = g.with_attr(a, (rng.random(n) < 0.7).astype(np.float64))
    return g


def flip_batch(g, rng, attr, n_loss, n_gain):
    """Attr-edit batch flipping truthiness: n_loss truthy->0, n_gain 0->1
    (clipped to availability)."""
    vals = np.asarray(g.attrs[attr])
    on, off = np.flatnonzero(vals != 0), np.flatnonzero(vals == 0)
    loss = rng.choice(on, min(n_loss, on.size), replace=False)
    gain = rng.choice(off, min(n_gain, off.size), replace=False)
    verts = np.concatenate([loss, gain])
    new = np.concatenate([np.zeros(loss.size), np.ones(gain.size)])
    return UpdateBatch.attr_set(attr, verts.astype(np.int64), new)


EXPRS = [
    pytest.param(Filter(KHopWindow(2), "mask"), ("mask",), id="filter-khop2"),
    pytest.param(Union(Filter(KHop(1), "mask"), KHopWindow(1)), ("mask",),
                 id="union-filter"),
    pytest.param(Diff(KHopWindow(2), Filter(KHopWindow(1), "mask")),
                 ("mask",), id="diff-filter"),
    pytest.param(
        Intersect(Filter(KHopWindow(2), "mask"),
                  Union(KHopWindow(1), Filter(KHop(1, "in"), "mask2"))),
        ("mask", "mask2"), id="intersect-two-attrs"),
]

FLIP_MIXES = [("loss-only", 3, 0), ("gain-only", 0, 3), ("mixed", 2, 2)]


@pytest.mark.parametrize("expr,attrs", EXPRS)
@pytest.mark.parametrize("mix,n_loss,n_gain",
                         FLIP_MIXES, ids=[m[0] for m in FLIP_MIXES])
def test_bounded_refilter_differential(expr, attrs, mix, n_loss, n_gain):
    """Bounded predicate-flip maintenance is bit-identical to both a full
    rebuild and the set-evaluation oracle, for every flip direction."""
    g = masked_graph(attrs=attrs)
    eng = StreamingEngine(g, expr, device=True, use_pallas=False)
    rng = np.random.default_rng(11)
    bounded = 0
    for step in range(6):
        attr = attrs[step % len(attrs)]
        b = flip_batch(eng.graph, rng, attr, n_loss, n_gain)
        report = eng.apply(b)
        assert report["batch_size"] == 0
        if report["refiltered"]:
            bounded += 1
            assert report["affected"] <= eng.graph.n // 2
        fresh = StreamingEngine(eng.graph, expr, device=True,
                                use_pallas=False)
        vals = np.asarray(eng.graph.attrs["val"], np.float64)
        for agg in ("sum", "count", "min"):
            got = np.asarray(eng.query(agg))
            assert np.array_equal(got, np.asarray(fresh.query(agg))), \
                f"{mix} step {step}: bounded refilter != full rebuild ({agg})"
            assert np.array_equal(
                got, brute_force(eng.graph, expr, vals, agg,
                                 dtype=np.float32)), \
                f"{mix} step {step}: engine != oracle ({agg})"
    assert bounded >= 1, \
        "bounded refilter never ran — the test is exercising only rebuilds"


def test_loss_only_flip_uses_reverse_map_bound():
    """Loss-only flips on a Diff-free expression: the changed owners are
    exactly the flipped vertices' block owners (monotone shrink), so the
    affected count reported must not exceed that bound."""
    g = masked_graph(seed=5)
    expr = Filter(KHopWindow(2), "mask")
    eng = StreamingEngine(g, expr, device=True, use_pallas=False)
    rng = np.random.default_rng(13)
    for _ in range(4):
        vals = np.asarray(eng.graph.attrs["mask"])
        on = np.flatnonzero(vals != 0)
        flipped = rng.choice(on, 2, replace=False)
        bound = eng.index.owners_of_members(flipped.astype(np.int64))
        report = eng.apply(UpdateBatch.attr_set(
            "mask", flipped.astype(np.int64), np.zeros(2)))
        if report["refiltered"]:
            assert report["affected"] <= bound.size
            assert np.isin(report["affected_owners"], bound).all()
        v = np.asarray(eng.graph.attrs["val"], np.float64)
        assert np.array_equal(
            np.asarray(eng.query("sum")),
            brute_force(eng.graph, expr, v, "sum", dtype=np.float32))


def test_noop_truthiness_edit_skips_maintenance():
    """Editing a predicate attr without changing truthiness (3.0 -> 7.0)
    must not rebuild or refilter anything."""
    g = masked_graph(seed=7)
    expr = Filter(KHopWindow(2), "mask")
    eng = StreamingEngine(g, expr, device=True, use_pallas=False)
    on = np.flatnonzero(np.asarray(g.attrs["mask"]) != 0)[:4]
    pv = eng.plan_version
    report = eng.apply(UpdateBatch.attr_set("mask", on.astype(np.int64),
                                            np.full(4, 7.0)))
    assert report["affected"] == 0
    assert not report["reorganized"] and not report["refiltered"]
    assert eng.plan_version == pv
    v = np.asarray(eng.graph.attrs["val"], np.float64)
    assert np.array_equal(
        np.asarray(eng.query("sum")),
        brute_force(eng.graph, expr, v, "sum", dtype=np.float32))


# ---------------------------------------------------------------------- #
#  Delete-everything streams (drained / zero-block indices)
# ---------------------------------------------------------------------- #
def _delete_all_in_batches(eng, per_batch=13):
    """Drain every edge of the engine's graph, checking after each batch."""
    expr, steps = eng.window, 0
    while eng.graph.n_edges > 0:
        src, dst = eng.graph.src[:per_batch], eng.graph.dst[:per_batch]
        eng.apply(UpdateBatch.deletes(src, dst))
        steps += 1
        v = np.asarray(eng.graph.attrs["val"], np.float64)
        for agg in ("sum", "count"):
            assert np.array_equal(
                np.asarray(eng.query(agg)),
                brute_force(eng.graph, expr, v, agg, dtype=np.float32)), \
                f"drain step {steps} ({agg})"
        assert steps < 1000
    return steps


DRAIN_CONFIGS = [
    pytest.param({}, id="default"),
    pytest.param({"compact_garbage": 0.0}, id="compact-every-patch"),
    pytest.param({"policy": StalenessPolicy(max_link_ratio=1.05,
                                            max_block_ratio=1.05,
                                            max_garbage_ratio=0.05)},
                 id="aggressive-policy"),
]


@pytest.mark.parametrize("kw", DRAIN_CONFIGS)
def test_delete_everything_stream(kw):
    g = masked_graph(n=120, deg=2.5, seed=9)
    eng = StreamingEngine(g, KHopWindow(2), device=True, use_pallas=False,
                          **kw)
    _delete_all_in_batches(eng)
    assert eng.graph.n_edges == 0
    # drained index: staleness must be well-defined, never reorganizing
    linked = eng.index.linked_blocks_mask()
    assert eng.index.garbage_block_fraction(linked) >= 0.0
    assert not eng.policy.should_reorganize(
        eng.index, eng._base_links, eng._base_blocks, 5) \
        or eng.index.num_blocks > 0
    # and it keeps accepting traffic: re-insert and stay oracle-correct
    eng.apply(UpdateBatch.inserts([0, 1, 2], [1, 2, 3]))
    v = np.asarray(eng.graph.attrs["val"], np.float64)
    assert np.array_equal(
        np.asarray(eng.query("sum")),
        brute_force(eng.graph, KHopWindow(2), v, "sum", dtype=np.float32))


def test_zero_block_filter_index_is_safe():
    """An all-false predicate can yield an index with no blocks at all:
    staleness, patching, and queries must all survive it."""
    g = masked_graph(n=60, deg=2.0, seed=15)
    g = g.with_attr("mask", np.zeros(g.n))
    expr = Filter(KHopWindow(1), "mask")
    eng = StreamingEngine(g, expr, device=True, use_pallas=False)
    v = np.asarray(g.attrs["val"], np.float64)
    assert np.array_equal(
        np.asarray(eng.query("sum")),
        brute_force(g, expr, v, "sum", dtype=np.float32))
    linked = eng.index.linked_blocks_mask()
    assert eng.index.garbage_block_fraction(linked) == 0.0
    assert not StalenessPolicy().should_reorganize(eng.index, 0, 0, 5)
    # flip some vertices on: gains on a drained index must still work
    rng = np.random.default_rng(16)
    on = rng.choice(g.n, 5, replace=False)
    eng.apply(UpdateBatch.attr_set("mask", on.astype(np.int64), np.ones(5)))
    v = np.asarray(eng.graph.attrs["val"], np.float64)
    for agg in ("sum", "count"):
        assert np.array_equal(
            np.asarray(eng.query(agg)),
            brute_force(eng.graph, expr, v, agg, dtype=np.float32))
