"""AsyncWindowService: deadline flushing, load shedding, backpressure,
and the exception-safe request lifecycle (ISSUE 6).

Threaded tests are structured so the flusher is either *provably idle*
(deadlines far in the future) or *deliberately blocked* (the test holds
``_flush_lock``), never raced: assertions are on ticket completion events
and monotonic counters, not on sleeps.
"""

import threading
import time

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import api  # noqa: E402
from repro.core.api import QuerySpec, Session  # noqa: E402
from repro.core.query import brute_force  # noqa: E402
from repro.core.updates import UpdateBatch  # noqa: E402
from repro.core.windows import KHopWindow  # noqa: E402
from repro.graphs.generators import erdos_renyi  # noqa: E402
from repro.serve import (  # noqa: E402
    AsyncWindowService,
    DEFAULT_REQUEST_CLASSES,
    LoadShedError,
    RequestClass,
    WindowService,
)

from test_updates import mixed  # noqa: E402


def int_graph(n, deg, seed):
    g = erdos_renyi(n, deg, directed=False, seed=seed)
    vals = np.random.default_rng(seed + 1).integers(0, 50, g.n)
    return g.with_attr("val", vals.astype(np.float64))


def make_session(seed=7, n=80):
    g = int_graph(n, 2.5, seed)
    specs = [QuerySpec(KHopWindow(2), "sum"), QuerySpec(KHopWindow(2), "min")]
    return g, specs, Session(g, specs, use_pallas=False)


# a class whose deadline can never fire within a test run: flushes happen
# only on fill (or explicit stop/flush)
NEVER = RequestClass("never", max_delay_ms=600_000.0, priority=5,
                     sheddable=True)
NEVER_POINT = RequestClass("never-point", max_delay_ms=600_000.0,
                           priority=100, sheddable=False)


# ---------------------------------------------------------------------- #
#  Deadline-driven flushing
# ---------------------------------------------------------------------- #
def test_deadline_flush_serves_sub_bucket_request():
    """A single point read in an otherwise idle service must be served by
    its class deadline, not wait for the bucket to fill."""
    g, specs, sess = make_session()
    with AsyncWindowService(sess, bucket=64) as svc:
        t = svc.submit(0, vertex=3)  # point class: 2 ms deadline
        got = t.get(timeout=10.0)
        assert svc.deadline_flushes >= 1
        assert svc.fill_flushes == 0
    oracle = brute_force(g, KHopWindow(2),
                         np.asarray(g.attrs["val"], np.float64), "sum",
                         dtype=np.float32)
    assert got == oracle[3]
    assert t.latency_s is not None and t.request_class.name == "point"


def test_deadline_flush_full_scan_and_classes():
    g, specs, sess = make_session(seed=9)
    with AsyncWindowService(sess, bucket=64) as svc:
        t0 = svc.submit(0)  # default full-scan class: interactive, 5 ms
        t1 = svc.submit(1, request_class="batch")
        a, b = t0.get(timeout=10.0), t1.get(timeout=10.0)
        assert t0.request_class is DEFAULT_REQUEST_CLASSES["interactive"]
        assert t1.request_class is DEFAULT_REQUEST_CLASSES["batch"]
    vals = np.asarray(g.attrs["val"], np.float64)
    assert np.array_equal(
        a, brute_force(g, KHopWindow(2), vals, "sum", dtype=np.float32))
    assert np.array_equal(
        b, brute_force(g, KHopWindow(2), vals, "min", dtype=np.float32))


def test_fill_flush_at_bucket():
    """With deadlines effectively infinite, the bucket filling is the only
    trigger — the flusher must launch on the fill edge."""
    g, specs, sess = make_session(seed=11)
    vals = np.asarray(g.attrs["val"], np.float64)
    oracle = brute_force(g, KHopWindow(2), vals, "sum", dtype=np.float32)
    with AsyncWindowService(sess, bucket=4, classes={"never": NEVER}) as svc:
        tickets = [svc.submit(0, vertex=i, request_class="never")
                   for i in range(4)]
        for i, t in enumerate(tickets):
            assert t.get(timeout=10.0) == oracle[i]
        assert svc.fill_flushes >= 1
        assert svc.deadline_flushes == 0


def test_explicit_values_through_async_path():
    g, specs, sess = make_session(seed=13)
    rng = np.random.default_rng(14)
    with AsyncWindowService(sess, bucket=4) as svc:
        vecs = [rng.integers(0, 9, g.n).astype(np.float64) for _ in range(3)]
        tickets = [svc.submit(0, values=v) for v in vecs]
        for t, v in zip(tickets, vecs):
            got = t.get(timeout=10.0)
            want = brute_force(g, KHopWindow(2), v, "sum", dtype=np.float32)
            assert np.array_equal(got, want)


def test_updates_interleaved_with_async_reads():
    """Reads always see a complete published version while the write head
    advances underneath."""
    g, specs, sess = make_session(seed=15)
    rng = np.random.default_rng(16)
    with AsyncWindowService(sess, bucket=64) as svc:
        for _ in range(4):
            svc.update(mixed(svc.session.graph, rng, 3, 1))
            got = svc.submit(0).get(timeout=10.0)
            gg = svc.session.graph
            want = brute_force(gg, KHopWindow(2),
                               np.asarray(gg.attrs["val"], np.float64),
                               "sum", dtype=np.float32)
            assert np.array_equal(got, want)


# ---------------------------------------------------------------------- #
#  Load shedding + backpressure
# ---------------------------------------------------------------------- #
def test_shed_evicts_lowest_priority_scan_never_point_reads():
    g, specs, sess = make_session(seed=17)
    svc = AsyncWindowService(
        sess, bucket=4, max_pending=8,
        classes={"never": NEVER, "never-point": NEVER_POINT},
        default_class="never",
    )
    # block the flusher so the queue holds still while we assert on it
    svc._flush_lock.acquire()
    try:
        svc.start()
        low = [svc.submit(0, request_class="batch") for _ in range(2)]
        high = [svc.submit(0, request_class="never") for _ in range(6)]
        # queue is now at max_pending=8; a point read must evict the
        # NEWEST lowest-priority sheddable scan, never another point read
        pt = svc.submit(0, vertex=1, request_class="never-point")
        victim = low[1]
        assert victim.done and victim.failed
        assert isinstance(victim.error, LoadShedError)
        with pytest.raises(LoadShedError):
            victim.get(timeout=0)
        assert not low[0].done and not pt.done
        assert svc.shed == 1

        # an incoming request that is itself the lowest-priority sheddable
        # scan is rejected at admission
        with pytest.raises(LoadShedError):
            svc.submit(0, request_class="batch")
        assert svc.shed == 2

        # a higher-priority scan instead evicts the remaining batch ticket
        t2 = svc.submit(0, request_class="never")
        assert low[0].done and isinstance(low[0].error, LoadShedError)
        assert svc.shed == 3

        # queue again full, all sheddable scans outrank "batch": sheds
        # drain down the priority ladder, eventually hitting "never" scans
        t3 = svc.submit(0, vertex=2, request_class="never-point")
        assert svc.shed == 4
        survivors = [t for t in high + [t2, t3, pt] if not t.done]
        assert pt in survivors and t3 in survivors
    finally:
        svc._flush_lock.release()
    # unblocked flusher serves every survivor
    for t in [pt, t3]:
        assert t.get(timeout=10.0) is not None
    svc.stop()
    assert svc.stats["failed"] == svc.shed == 4


def test_backpressure_waits_when_nothing_sheddable():
    """All-point-read queue: nothing is sheddable, so an over-admission
    submit must *wait* for the flusher to drain, then succeed."""
    g, specs, sess = make_session(seed=19)
    svc = AsyncWindowService(
        sess, bucket=4, max_pending=4,
        classes={"never-point": NEVER_POINT}, default_class="never-point",
    )
    svc._flush_lock.acquire()
    release_at = None
    try:
        svc.start()
        pts = [svc.submit(0, vertex=i, request_class="never-point")
               for i in range(4)]
        assert len(svc._pending) == 4
        # free the flusher shortly; the submit below must block until then
        release_at = threading.Timer(0.1, svc._flush_lock.release)
        release_at.start()
        # default "point" class: once admitted, its 2 ms deadline flushes it
        t = svc.submit(0, vertex=9)
        assert svc.backpressure_waits >= 1
        for p in pts + [t]:
            assert p.get(timeout=10.0) is not None
    finally:
        if release_at is None:
            svc._flush_lock.release()
    svc.stop()
    assert svc.shed == 0 and svc.stats["failed"] == 0


def test_pressure_and_effective_window():
    g, specs, sess = make_session(seed=21)
    svc = AsyncWindowService(sess, bucket=4, max_pending=64)
    assert 0.0 <= svc.pressure() <= 1.0
    assert svc.pressure() == 0.0  # fresh index is its own baseline
    assert svc.effective_max_pending() == 64
    rng = np.random.default_rng(22)
    for _ in range(6):
        svc.update(mixed(svc.session.graph, rng, 6, 4))
    p = svc.pressure()
    assert 0.0 <= p <= 1.0
    eff = svc.effective_max_pending()
    assert svc.bucket <= eff <= svc.max_pending
    assert eff == int(4 + 60 * (1.0 - p))
    svc.close()


# ---------------------------------------------------------------------- #
#  Exception-safe flush (satellite: sync WindowService lifecycle)
# ---------------------------------------------------------------------- #
def test_flush_failure_isolated_to_affected_tickets(monkeypatch):
    """A raise mid-flush fails only the tickets whose launch raised; every
    other ticket in the same flush is served, the queue ends empty, and
    the next flush works."""
    g, specs, sess = make_session(seed=23)
    svc = WindowService(sess, bucket=4)
    vals = np.asarray(g.attrs["val"], np.float64)
    oracle = brute_force(g, KHopWindow(2), vals, "sum", dtype=np.float32)

    boom = RuntimeError("injected launch failure")
    real = api.SessionView.run_group_many
    monkeypatch.setattr(api.SessionView, "run_group_many",
                        lambda self, gi, vb: (_ for _ in ()).throw(boom))
    bad = [svc.submit(0, values=vals) for _ in range(2)]
    good = [svc.submit(0, vertex=5), svc.submit(1)]
    served = svc.flush()
    assert len(served) == 4 and len(svc._pending) == 0
    for t in bad:
        assert t.done and t.error is boom
        with pytest.raises(RuntimeError, match="injected"):
            t.get(timeout=0)
    assert good[0].error is None and good[0].result == oracle[5]
    assert good[1].error is None
    assert svc.stats["failed"] == 2 and svc.stats["served"] == 2

    # recovery: the very next flush serves the same shape of request
    monkeypatch.setattr(api.SessionView, "run_group_many", real)
    t = svc.submit(0, values=vals)
    svc.flush()
    assert np.array_equal(t.get(timeout=0), oracle)
    assert svc.stats["failed"] == 2  # no lingering poison


def test_snapshot_launch_failure_poisons_memo_not_queue(monkeypatch):
    """A failing cached-read launch fails every same-group ticket in that
    flush via the memo (one launch attempt, not N), leaves other groups
    served, and clears on the next flush."""
    g, specs, sess = make_session(seed=25)
    svc = WindowService(sess, bucket=4, use_cache=False)
    calls = {"n": 0}
    real = api.SessionView.run_group

    def failing(self, gi, values=None):
        calls["n"] += 1
        raise RuntimeError("injected snapshot failure")

    monkeypatch.setattr(api.SessionView, "run_group", failing)
    tickets = [svc.submit(0, vertex=i) for i in range(3)]
    svc.flush()
    assert calls["n"] == 1, "poisoned memo must prevent repeat launches"
    for t in tickets:
        assert isinstance(t.error, RuntimeError)
    monkeypatch.setattr(api.SessionView, "run_group", real)
    assert svc.query(0, vertex=0) is not None  # clean next flush


def test_malformed_request_fails_at_submit_not_flush():
    g, specs, sess = make_session(seed=27)
    svc = WindowService(sess, bucket=4)
    with pytest.raises(IndexError):
        svc.submit(0, vertex=g.n + 5)
    with pytest.raises(ValueError):
        svc.submit(0, values=np.zeros(g.n - 1))
    with pytest.raises((KeyError, IndexError, TypeError)):
        svc.submit(99)
    assert len(svc._pending) == 0  # nothing half-enqueued
    assert svc.query(0, vertex=0) is not None


def test_ticket_get_timeout_and_error_contract():
    g, specs, sess = make_session(seed=29)
    svc = WindowService(sess, bucket=64)
    t = svc.submit(0, vertex=0)
    assert not t.done
    with pytest.raises(TimeoutError):
        t.get(timeout=0.01)
    svc.flush()
    assert t.done and t.get(timeout=0) is not None


# ---------------------------------------------------------------------- #
#  Lifecycle
# ---------------------------------------------------------------------- #
def test_stop_drain_serves_leftovers():
    g, specs, sess = make_session(seed=31)
    svc = AsyncWindowService(sess, bucket=64, classes={"never": NEVER},
                             default_class="never").start()
    tickets = [svc.submit(0, request_class="never") for _ in range(3)]
    svc.stop(drain=True)
    for t in tickets:
        assert t.done and t.error is None


def test_stop_without_drain_fails_leftovers():
    g, specs, sess = make_session(seed=33)
    svc = AsyncWindowService(sess, bucket=64, classes={"never": NEVER},
                             default_class="never").start()
    tickets = [svc.submit(0, request_class="never") for _ in range(3)]
    svc.stop(drain=False)
    for t in tickets:
        assert t.done and isinstance(t.error, LoadShedError)
    assert svc.stats["failed"] == 3


def test_unstarted_service_degrades_to_synchronous():
    g, specs, sess = make_session(seed=35)
    svc = AsyncWindowService(sess, bucket=2)
    assert not svc.running
    t0 = svc.submit(0, vertex=0)
    t1 = svc.submit(0, vertex=1)  # fill edge: synchronous flush
    assert t0.done and t1.done
    vals = np.asarray(g.attrs["val"], np.float64)
    oracle = brute_force(g, KHopWindow(2), vals, "sum", dtype=np.float32)
    assert t0.get(timeout=0) == oracle[0] and t1.get(timeout=0) == oracle[1]


# ---------------------------------------------------------------------- #
#  Deterministic deadline scheduling (ISSUE 7: injected clock)
# ---------------------------------------------------------------------- #
class FakeClock:
    """A manually advanced monotonic clock injected via ``now_fn`` — the
    scheduling decision (:meth:`AsyncWindowService._due_reason`) runs on
    it, so deadline behavior is asserted exactly, no sleeps or jitter."""

    def __init__(self, t=100.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def test_deadline_fires_exactly_on_fake_clock():
    """Sub-bucket queue: not due one tick before the class deadline, due
    exactly at it — and the trigger is recorded as a deadline flush."""
    g, specs, sess = make_session(seed=39)
    clk = FakeClock()
    svc = AsyncWindowService(sess, bucket=64, now_fn=clk)
    # unstarted service: submit runs flush_if_due synchronously, which on
    # the frozen clock is "not due" — the ticket must still be pending
    t = svc.submit(0, vertex=3)  # point class: 2 ms deadline
    assert not t.done and len(svc._pending) == 1
    reason, dl = svc._due_reason()
    assert reason is None and dl == pytest.approx(clk.t + 0.002)

    clk.advance(0.002 - 1e-6)
    assert svc.flush_if_due() == [] and not t.done
    assert svc.deadline_flushes == 0

    clk.advance(1e-6)  # exactly at the deadline: now >= dl
    served = svc.flush_if_due()
    assert [s.rid for s in served] == [t.rid]
    assert t.done and t.error is None
    assert svc.deadline_flushes == 1 and svc.fill_flushes == 0
    # latency is measured on the same injected clock
    assert t.latency_s == pytest.approx(0.002)


def test_earliest_deadline_wins_across_classes():
    g, specs, sess = make_session(seed=43)
    clk = FakeClock()
    svc = AsyncWindowService(sess, bucket=64, classes={"never": NEVER},
                             now_fn=clk)
    svc.submit(0, request_class="never")     # +600 s deadline
    reason, dl = svc._due_reason()
    assert reason is None and dl == pytest.approx(clk.t + 600.0)
    svc.submit(0, vertex=1)                  # point: +2 ms — new earliest
    reason, dl = svc._due_reason()
    assert reason is None and dl == pytest.approx(clk.t + 0.002)
    clk.advance(0.002)
    served = svc.flush_if_due()
    # a deadline flush serves the WHOLE queue, not just the due ticket
    assert len(served) == 2 and svc.deadline_flushes == 1


def test_fill_beats_deadline_on_fake_clock():
    """At the fill edge the trigger is 'fill' even when deadlines have
    also expired — fill is checked first (it never needs the clock)."""
    g, specs, sess = make_session(seed=45)
    clk = FakeClock()
    svc = AsyncWindowService(sess, bucket=2, now_fn=clk)
    svc._pending.append(svc._make_ticket(0, None, None,
                                         svc.classes["interactive"]))
    clk.advance(60.0)  # way past every deadline
    svc._pending.append(svc._make_ticket(0, None, None,
                                         svc.classes["interactive"]))
    reason, _ = svc._due_reason()
    assert reason == "fill"
    assert len(svc.flush_if_due()) == 2
    assert svc.fill_flushes == 1 and svc.deadline_flushes == 0
    assert svc._due_reason() == (None, None)  # empty queue: nothing due


def test_flusher_survives_flush_exception(monkeypatch):
    """An injected failure inside a background flush must not kill the
    flusher thread — the next request is still served."""
    g, specs, sess = make_session(seed=37)
    with AsyncWindowService(sess, bucket=64) as svc:
        monkeypatch.setattr(
            api.SessionView, "run_group",
            lambda self, gi, values=None:
                (_ for _ in ()).throw(RuntimeError("boom")))
        bad = svc.submit(0, vertex=0)
        with pytest.raises(RuntimeError):
            bad.get(timeout=10.0)
        monkeypatch.undo()
        assert svc.running
        ok = svc.submit(0, vertex=0)
        assert ok.get(timeout=10.0) is not None
