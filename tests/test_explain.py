"""EXPLAIN/ANALYZE for window plans + serving flight recorder (ISSUE 8).

Tentpole contracts:

* **byte-exact memory accounting** — ``plan_nbytes()`` equals the sum of
  the actual ``.nbytes`` of every array the plan holds, for host DBIndex
  plans, I-Index plans, and sharded plans (checked array-by-array, not
  just in total);
* **EXPLAIN without execution** — engine resolution with per-candidate
  rejection reasons, the lowering choice per (expression, monoid set)
  with rejected alternatives, and plan anatomy, all stable across >= 10
  streamed ``UpdateBatch``es (static shapes ⇒ constant footprint);
* **ANALYZE attribution** — one profiled execution attributes >= 95% of
  wall time to named phases without touching the tracked jit caches;
* **flight recorder** — bounded ring of serving events, auto-dumped into
  ``last_flight_record`` when a ticket fails, surfaced (with padding
  waste and the plan footprint) by ``WindowService.debug_report()``.
"""

import json

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.api import (  # noqa: E402
    QuerySpec,
    Session,
    recompile_count,
)
from repro.core.windows import KHop, KHopWindow, Union  # noqa: E402
from repro.graphs.generators import (  # noqa: E402
    erdos_renyi,
    random_dag,
    with_random_attrs,
)
from repro.serve import FlightRecorder, WindowService  # noqa: E402
from repro.serve.flight import EVENT_TYPES  # noqa: E402

from test_updates import mixed  # noqa: E402  (stream helpers)


# ---------------------------------------------------------------------- #
#  Byte-exact plan memory accounting
# ---------------------------------------------------------------------- #
def _tileplan_actual(tp):
    return {"gather_padded": tp.gather_padded.nbytes,
            "seg_tiles": tp.seg_tiles.nbytes,
            "m2out": tp.m2out.nbytes,
            "first_visit": tp.first_visit.nbytes}


def test_dbindex_plan_nbytes_byte_exact():
    g = with_random_attrs(erdos_renyi(300, 4.0, directed=False, seed=1),
                          seed=2)
    sess = Session(g, [QuerySpec(("khop", 1), "sum")], device=True,
                   use_pallas=False)
    plan = next(iter(sess._states.values())).plan
    assert type(plan).__name__ == "DBIndexPlan"
    actual = {}
    for k, v in _tileplan_actual(plan.pass1).items():
        actual[f"pass1.{k}"] = v
    for k, v in _tileplan_actual(plan.pass2).items():
        actual[f"pass2.{k}"] = v
    actual["block_sizes"] = plan.block_sizes.nbytes
    actual["link_counts"] = plan.link_counts.nbytes
    if plan.p1_ell is not None:
        actual["p1_ell"] = plan.p1_ell.nbytes
    if plan.p2_ell is not None:
        actual["p2_ell"] = plan.p2_ell.nbytes
    assert plan.array_nbytes() == actual  # array-by-array, not just total
    assert plan.plan_nbytes() == sum(actual.values())
    # and EXPLAIN carries the same number per term
    rep = sess.explain()
    assert rep.groups[0].terms[0].plan_nbytes == plan.plan_nbytes()
    assert rep.total_plan_nbytes == plan.plan_nbytes()


def test_iindex_plan_nbytes_byte_exact():
    g = with_random_attrs(random_dag(300, 2.5, seed=5), seed=6)
    sess = Session(g, [QuerySpec("topological", "sum")], device=True,
                   use_pallas=False)
    plan = next(iter(sess._states.values())).plan
    assert type(plan).__name__ == "IIndexPlan"
    actual = {f"wd_plan.{k}": v
              for k, v in _tileplan_actual(plan.wd_plan).items()}
    actual["pid"] = plan.pid.nbytes
    actual["level"] = plan.level.nbytes
    assert plan.array_nbytes() == actual
    assert plan.plan_nbytes() == sum(actual.values())
    assert sess.explain().total_plan_nbytes == plan.plan_nbytes()


def test_sharded_plan_nbytes_byte_exact():
    # 1-device CPU mesh: exercises the full sharded code path in tier-1
    mesh = jax.make_mesh((1,), ("data",))
    g = with_random_attrs(erdos_renyi(200, 4.0, seed=1), seed=2)
    sess = Session(g, [QuerySpec(("khop", 1), "sum")], mesh=mesh,
                   use_pallas=False)
    plan = next(iter(sess._states.values())).plan
    assert type(plan).__name__ == "ShardedDBPlan"
    actual = {"p1_gather": plan.p1_gather.nbytes,
              "p1_seg": plan.p1_seg.nbytes,
              "p2_gather": plan.p2_gather.nbytes,
              "p2_seg": plan.p2_seg.nbytes,
              "block_sizes": plan.block_sizes.nbytes}
    if plan.has_ell:
        actual.update(e1=plan.e1.nbytes, e1_ids=plan.e1_ids.nbytes,
                      e2=plan.e2.nbytes, e2_ids=plan.e2_ids.nbytes)
    assert plan.array_nbytes() == actual
    assert plan.plan_nbytes() == sum(actual.values())
    rep = sess.explain()
    assert rep.sharded
    term = rep.groups[0].terms[0]
    assert term.plan_nbytes == plan.plan_nbytes()
    bal = term.plan["shard_balance"]
    assert bal["pass1"]["rows_per_shard"] == [term.plan["rows1_per_shard"]]
    assert bal["pass1"]["balance"] == 1.0  # one shard is trivially balanced


# ---------------------------------------------------------------------- #
#  EXPLAIN: candidates, lowering, stability under streaming
# ---------------------------------------------------------------------- #
def test_explain_candidates_carry_rejection_reasons():
    g = with_random_attrs(erdos_renyi(200, 4.0, directed=False, seed=1),
                          seed=2)
    sess = Session(g, [QuerySpec(("khop", 1), "sum")], device=True,
                   use_pallas=False)
    grp = sess.explain().groups[0]
    assert grp.engine == "jax"
    by_name = {c["name"]: c for c in grp.candidates}
    assert by_name["jax"]["selected"]
    # every non-selected candidate explains itself
    for name, c in by_name.items():
        if not c["selected"]:
            assert c["reason"], name
    assert "priority" in by_name["dbindex"]["reason"]
    assert "not served" in by_name["iindex"]["reason"]
    assert "mesh" in by_name["jax-sharded"]["reason"]


def test_explain_does_not_execute_or_recompile():
    g = with_random_attrs(erdos_renyi(200, 4.0, directed=False, seed=1),
                          seed=2)
    sess = Session(g, [QuerySpec(("khop", 1), "sum")], device=True,
                   use_pallas=False)
    c0 = recompile_count()
    rep = sess.explain()
    assert recompile_count() == c0  # no jitted executor was entered
    json.loads(rep.to_json())  # fully serializable
    assert "engine: jax" in rep.text()


def test_explain_stable_across_streamed_batches():
    g = with_random_attrs(erdos_renyi(400, 4.0, directed=False, seed=11),
                          seed=12)
    specs = [QuerySpec(("khop", 1), a) for a in ("sum", "min", "avg")]
    sess = Session(g, specs, device=True, use_pallas=False,
                   plan_headroom=1.0)
    sess.run()
    first = sess.explain()
    lowering0 = first.groups[0].lowering["choice"]
    nbytes0 = first.total_plan_nbytes
    rng = np.random.default_rng(13)
    for step in range(10):
        sess.update(mixed(sess.graph, rng, 4, 2))
        rep = sess.explain()
        assert rep.groups[0].lowering["choice"] == lowering0
        assert rep.groups[0].engine == first.groups[0].engine
        # static shapes: plan patching never changes the footprint
        assert rep.total_plan_nbytes == nbytes0, step
        assert rep.version == step + 1


def test_composite_lowering_choices():
    g = with_random_attrs(erdos_renyi(250, 4.0, directed=True, seed=3),
                          seed=4)
    u = Union(KHop(2, "in"), KHopWindow(2))
    # same window, one session each: aggs on one window fuse into one group
    s_min = Session(g, [QuerySpec(u, "min")], device=True, use_pallas=False)
    s_sum = Session(g, [QuerySpec(u, "sum")], device=True, use_pallas=False)
    lo_min = s_min.explain().groups[0].lowering
    assert lo_min["choice"] == "idempotent-combine"
    assert len(lo_min["terms"]) == 2  # no intersection term needed
    lo_sum = s_sum.explain().groups[0].lowering
    assert lo_sum["choice"] == "inclusion-exclusion"
    assert len(lo_sum["terms"]) == 3  # A, B, A∩B
    assert sorted(lo_sum["sum_coefs"]) == [-1, 1, 1]
    assert any(r["choice"] == "idempotent-combine"
               for r in lo_sum["rejected"])


def test_explain_spec_filter_selects_one_group():
    g = with_random_attrs(erdos_renyi(200, 4.0, directed=False, seed=1),
                          seed=2)
    specs = [QuerySpec(("khop", 1), "sum"), QuerySpec(("khop", 2), "min")]
    sess = Session(g, specs, device=True, use_pallas=False)
    assert len(sess.explain().groups) == 2
    only = sess.explain(specs[1])
    assert len(only.groups) == 1
    assert only.groups[0].window == "khop[2]"
    with pytest.raises(KeyError):
        sess.explain(QuerySpec(("khop", 3), "sum"))


# ---------------------------------------------------------------------- #
#  ANALYZE: phase attribution
# ---------------------------------------------------------------------- #
def test_analyze_attributes_wall_time_and_keeps_caches_cold():
    # big enough that device phases dominate the fixed Python glue; the
    # attribution contract targets real workloads, not microbenchmarks
    g = with_random_attrs(erdos_renyi(2000, 8.0, directed=False, seed=21),
                          seed=22)
    specs = [QuerySpec(("khop", 1), a) for a in ("sum", "min", "avg")]
    sess = Session(g, specs, device=True, use_pallas=False)
    sess.run()
    c0 = recompile_count()
    sess.analyze()  # warm the eager op-by-op dispatch path
    rep = sess.analyze()
    assert rep.attribution >= 0.95, rep.attribution
    assert recompile_count() == c0  # eager mirror, tracked jits untouched
    phases = {p["phase"] for p in rep.phases}
    assert {"pass1_reduce", "pass2_gather", "pass2_reduce",
            "finalize"} <= phases
    txt = rep.text()
    for name in sorted(phases):
        assert name in txt
    json.loads(rep.to_json())


def test_analyze_iindex_and_composite_phases():
    gd = with_random_attrs(random_dag(300, 2.5, seed=5), seed=6)
    s_topo = Session(gd, [QuerySpec("topological", "sum"),
                          QuerySpec("topological", "min")],
                     device=True, use_pallas=False)
    s_topo.run()
    s_topo.analyze()
    rep = s_topo.analyze()
    assert rep.attribution >= 0.95, rep.attribution
    assert {"gather", "wd_reduce", "inherit",
            "finalize"} <= {p["phase"] for p in rep.phases}

    g = with_random_attrs(erdos_renyi(600, 5.0, directed=True, seed=3),
                          seed=4)
    u = Union(KHop(2, "in"), KHopWindow(2))
    s_u = Session(g, [QuerySpec(u, "sum")], device=True, use_pallas=False)
    s_u.run()
    s_u.analyze()
    rep = max((s_u.analyze() for _ in range(2)),
              key=lambda r: r.attribution)
    assert rep.attribution >= 0.95, rep.attribution
    # three dbindex terms (A, B, A∩B) plus the host-side recombination
    assert "host_combine" in {p["phase"] for p in rep.phases}
    assert len({p["term"] for p in rep.phases}) >= 3


# ---------------------------------------------------------------------- #
#  Flight recorder + debug_report
# ---------------------------------------------------------------------- #
def test_flight_recorder_ring_bounds_and_dump(tmp_path):
    fr = FlightRecorder(capacity=4)
    for i in range(10):
        fr.record("admit", rid=i)
    assert len(fr) == 4 and fr.capacity == 4
    assert fr.dropped == 6
    evs = fr.dump()
    assert [e["rid"] for e in evs] == [6, 7, 8, 9]  # oldest evicted first
    assert [e["seq"] for e in evs] == [7, 8, 9, 10]
    assert all(e["event"] == "admit" for e in evs)
    assert fr.tail(2) == evs[-2:]
    path = fr.dump_json(tmp_path / "flight.json")
    loaded = json.loads(open(path).read())
    assert loaded["dropped"] == 6 and len(loaded["events"]) == 4


def _int_service(n=200, seed=7, bucket=4):
    g = erdos_renyi(n, 4.0, directed=False, seed=seed)
    vals = np.random.default_rng(seed + 1).integers(0, 50, g.n)
    g = g.with_attr("val", vals.astype(np.float64))
    sess = Session(g, [QuerySpec(("khop", 1), "sum")], device=True,
                   use_pallas=False)
    return WindowService(sess, bucket=bucket)


def test_service_flight_events_follow_taxonomy():
    svc = _int_service()
    for v in (3, 5, 9, 11):
        svc.submit(0, v)
    svc.flush()
    rng = np.random.default_rng(9)
    svc.update(mixed(svc.session.graph, rng, 4, 2))
    svc.submit(0, 2)
    svc.flush()
    events = [e["event"] for e in svc.flight.dump()]
    assert set(events) <= set(EVENT_TYPES)
    assert events.count("admit") == 5
    assert "flush" in events and "patch" in events and "flip" in events
    # ordering: the patch lands before the flip that publishes it
    assert events.index("patch") < events.index("flip")
    flush_ev = next(e for e in svc.flight.dump() if e["event"] == "flush")
    assert flush_ev["served"] == 4 and flush_ev["failed"] == 0


def test_ticket_failure_auto_dumps_flight_record():
    svc = _int_service()
    svc.submit(0, 3)
    svc.flush()
    assert svc.last_flight_record is None  # healthy serving: no dump
    # explicit values bypass the result cache: the launch path must run
    vb = np.arange(svc.session.graph.n, dtype=np.float64)
    t = svc.submit(0, 7, values=vb)

    def boom(*a, **k):
        raise RuntimeError("injected failure")

    object.__setattr__(svc._active, "run_group", boom)
    object.__setattr__(svc._active, "run_group_many", boom)
    svc.flush()
    assert isinstance(t.error, RuntimeError)
    rec = svc.last_flight_record
    assert rec is not None
    fails = [e for e in rec if e["event"] == "failure"]
    assert len(fails) == 1
    assert fails[0]["error"] == "RuntimeError"
    assert "injected failure" in fails[0]["detail"]
    # the record carries the causal history, not just the failure
    assert [e["event"] for e in rec][0] == "admit"
    json.dumps(rec)  # CI artifact hook serializes this as-is


def test_debug_report_shape_and_padding_accounting():
    svc = _int_service(bucket=4)
    rng = np.random.default_rng(31)
    # explicit-values requests force batched run_many launches (padding)
    vb = rng.integers(0, 50, svc.session.graph.n).astype(np.float64)
    for _ in range(3):
        svc.submit(0, values=vb)
    svc.flush()
    rep = svc.debug_report()
    assert set(rep) >= {"stats", "padding", "staleness",
                        "plan_footprint_bytes", "flight",
                        "last_flight_record"}
    pad = rep["padding"]
    assert pad["bucket"] == 4
    assert pad["batched_launches"] == 1
    assert pad["padded_rows"] == 1  # 3 requests pad to one bucket of 4
    assert pad["waste_fraction"] == 0.25
    assert rep["plan_footprint_bytes"] == int(
        svc.session.explain().total_plan_nbytes)
    assert rep["flight"]["capacity"] == svc.flight.capacity
    json.dumps(rep["flight"])
