"""Deterministic, resumable data pipelines.

Every stream is keyed by ``(seed, step)`` — restoring a checkpoint with the
same cursor reproduces the exact batch sequence (the fault-tolerance
contract in :mod:`repro.train.fault_tolerance`).  Host-side NumPy only; the
device step receives plain arrays.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass
class TokenStream:
    """Synthetic LM token stream (zipfian unigram over the vocab)."""

    vocab: int
    batch: int
    seq: int
    seed: int = 0
    step: int = 0  # cursor — checkpointed

    def next(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, self.step))
        ranks = rng.zipf(1.2, size=(self.batch, self.seq)).astype(np.int64)
        tokens = (ranks % self.vocab).astype(np.int32)
        self.step += 1
        return {"tokens": tokens, "labels": tokens}

    def state(self):
        return {"seed": self.seed, "step": self.step}

    def restore(self, state):
        self.seed, self.step = int(state["seed"]), int(state["step"])


@dataclasses.dataclass
class RecsysStream:
    """Criteo-shaped click stream: sparse ids + bernoulli labels."""

    n_fields: int
    batch: int
    seed: int = 0
    step: int = 0

    def next(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, self.step))
        x = rng.integers(0, 2**31 - 1, size=(self.batch, self.n_fields), dtype=np.int64)
        y = (rng.random(self.batch) < 0.25).astype(np.float32)
        self.step += 1
        return {"x": x.astype(np.int32), "y": y}

    def state(self):
        return {"seed": self.seed, "step": self.step}

    def restore(self, state):
        self.seed, self.step = int(state["seed"]), int(state["step"])


class NeighborSampler:
    """GraphSAGE-style layered neighbor sampler (minibatch_lg shape).

    Produces a padded subgraph: target nodes + `fanouts` rings, with edges
    (src -> dst) pointing from sampled neighbors into the previous ring.
    Padded entries point at the sink id ``sub_n``.
    """

    def __init__(self, g: Graph, fanouts=(15, 10), seed: int = 0):
        self.g = g
        self.fanouts = tuple(fanouts)
        self.seed = seed
        self.step = 0

    def sample(self, batch_nodes: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, self.step))
        self.step += 1
        g = self.g
        targets = rng.integers(0, g.n, size=batch_nodes).astype(np.int32)
        # ring 0 = targets; ring r+1 = fanout-sampled neighbors of ring r
        rings = [targets]
        edges_src, edges_dst = [], []
        node_list = [targets]
        offset = 0
        next_offset = batch_nodes
        for fan in self.fanouts:
            prev = rings[-1]
            nbrs = np.empty((prev.size, fan), dtype=np.int32)
            for i, v in enumerate(prev):
                nb = g.out_neighbors(int(v))
                if nb.size == 0:
                    nbrs[i] = v
                else:
                    nbrs[i] = nb[rng.integers(0, nb.size, size=fan)]
            flat = nbrs.reshape(-1)
            # local ids: prev ring occupies [offset, offset+prev.size)
            src_local = np.arange(flat.size, dtype=np.int32) + next_offset
            dst_local = np.repeat(
                np.arange(prev.size, dtype=np.int32) + offset, fan
            )
            edges_src.append(src_local)
            edges_dst.append(dst_local)
            node_list.append(flat)
            rings.append(flat)
            offset = next_offset
            next_offset += flat.size
        nodes = np.concatenate(node_list)
        return {
            "node_ids": nodes,  # global ids per local row
            "edge_src": np.concatenate(edges_src),
            "edge_dst": np.concatenate(edges_dst),
            "n_targets": batch_nodes,
            "sub_n": int(nodes.size),
        }

    def state(self):
        return {"seed": self.seed, "step": self.step}

    def restore(self, state):
        self.seed, self.step = int(state["seed"]), int(state["step"])


@dataclasses.dataclass
class GraphBatcher:
    """Full-batch GNN 'stream' (one graph, label mask rotation for epochs)."""

    g: Graph
    d_feat: int
    classes: int
    seed: int = 0
    step: int = 0

    def next(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, self.step))
        self.step += 1
        n = self.g.n
        feats = rng.standard_normal((n, self.d_feat), dtype=np.float32)
        labels = rng.integers(0, self.classes, size=n).astype(np.int32)
        mask = (rng.random(n) < 0.1).astype(np.float32)
        src = np.concatenate([self.g.src, self.g.dst]) if not self.g.directed else self.g.src
        dst = np.concatenate([self.g.dst, self.g.src]) if not self.g.directed else self.g.dst
        return {
            "feats": feats,
            "labels": labels,
            "label_mask": mask,
            "edge_src": src.astype(np.int32),
            "edge_dst": dst.astype(np.int32),
        }

    def state(self):
        return {"seed": self.seed, "step": self.step}

    def restore(self, state):
        self.seed, self.step = int(state["seed"]), int(state["step"])
