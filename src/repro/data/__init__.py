"""Data pipelines: deterministic synthetic streams, shard-aware loaders."""

from repro.data.pipeline import (  # noqa: F401
    TokenStream,
    GraphBatcher,
    RecsysStream,
    NeighborSampler,
)
