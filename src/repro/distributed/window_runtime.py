"""Sharded streaming runtime: distributed window queries + update propagation.

This subsystem makes every prior layer — fused multi-channel queries,
incremental plan patching, capability planning — multi-device at once:

* :class:`ShardedDBPlan` — a DBIndex device plan laid out as *per-shard tile
  groups*.  The single-host plan already groups rows (members→blocks links,
  links→owners) by output tile group; here whole groups are assigned to mesh
  shards (greedy balance over padded rows), so no segment ever straddles a
  shard.  That alignment is what buys **bit-identity** with the single-host
  fused path: each segment's partial is produced by exactly one shard in the
  same row order, and the cross-shard ``psum`` only ever adds exact zeros
  (``pmin``/``pmax`` add exact identities) from the non-owning shards.

* :func:`query_sharded_multi` — the stacked-channel matrix form of
  ``query_dbindex_sharded``: fused SUM/COUNT/AVG channels ride one ``psum``
  per pass, MIN/MAX ride ``pmin``/``pmax`` over sharded ELL row layouts
  (fall back to the masked tile layout when the plan carries no ELL).
  Collective footprint per query: ``|T|·C + |n|·C`` floats, independent of
  window sizes — the paper's sharing structure keeps the wire format tiny.
  :func:`query_sharded_many` batches a whole [B, n] ``run_many`` bucket
  through the same shard-local fn in ONE launch (trailing values axis).

* :func:`patch_sharded_plan` — streamed update propagation.  The changed
  tile groups are the wire format: after a batched index update only the
  groups holding appended secondary blocks (pass 1) and the affected
  owners' link groups (pass 2) are re-laid-out and scattered into the
  device-resident shards via ``jax.Array.at[...].set`` (the same
  shape-stable splice contract as
  :func:`repro.kernels.segment_reduce.ops.patch_tile_plan`), so a batch
  ships a few KB of patches instead of re-uploading the full plan, and the
  jitted sharded query never retraces.

* :class:`ShardedSession` — ``Session(mesh=...)``: owns per-shard plans,
  shards the affected-owner BFS over the data axis (each shard traverses
  only its slice of the batch's touched endpoints), streams batches with
  zero recompiles, and serves ``run`` / ``run_many`` across the mesh.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs as _obs
from repro.core.dbindex import DBIndex, build_dbindex
from repro.core.graph import Graph
from repro.core.streaming import StalenessPolicy
from repro.core.updates import (
    UpdateBatch,
    sharded_affected_owners,
    update_dbindex_batch,
)


def _axes_tuple(axis) -> Tuple[str, ...]:
    return (axis,) if isinstance(axis, str) else tuple(axis)


def _mesh_ndev(mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


# ---------------------------------------------------------------------- #
#  Shard-aligned plan layout
# ---------------------------------------------------------------------- #
def _group_layout(tile_plan) -> Tuple[np.ndarray, np.ndarray]:
    """(tiles_per_group, flat row starts) of a group-aligned tile layout."""
    m2out = np.asarray(tile_plan.m2out)
    tiles = np.bincount(m2out, minlength=tile_plan.num_out_tiles).astype(np.int64)
    starts = np.zeros(tile_plan.num_out_tiles + 1, np.int64)
    np.cumsum(tiles * tile_plan.tm, out=starts[1:])
    return tiles, starts


def _assign_groups(rows_per_group: np.ndarray, ndev: int):
    """Greedy balanced assignment of whole tile groups to shards.

    Groups are placed largest-first on the least-loaded shard (first shard
    wins ties) — deterministic, and within ~1 group of optimal for the
    near-uniform group sizes the headroom-floored layouts produce.  Returns
    ``(shard_of_group, offset_in_shard, rows_per_shard)``; every shard's row
    span is padded to the max load so ``shard_map`` sees equal shards.
    """
    order = np.argsort(-rows_per_group, kind="stable")
    shard_of = np.zeros(rows_per_group.size, np.int64)
    offset = np.zeros(rows_per_group.size, np.int64)
    load = np.zeros(ndev, np.int64)
    for g in order:
        s = int(np.argmin(load))
        shard_of[g] = s
        offset[g] = load[s]
        load[s] += rows_per_group[g]
    return shard_of, offset, max(int(load.max()), 1)


def _pack_shards(src_seg, src_gather, starts, rows_per_group, shard_of, offset,
                 rows_cap: int, ndev: int):
    """Scatter group row spans into equal per-shard flat arrays (pad -1/0)."""
    seg = np.full(ndev * rows_cap, -1, np.int32)
    gather = np.zeros(ndev * rows_cap, np.int32)
    for g in range(rows_per_group.size):
        span = int(rows_per_group[g])
        if span == 0:
            continue
        lo = int(shard_of[g]) * rows_cap + int(offset[g])
        s0 = int(starts[g])
        seg[lo : lo + span] = src_seg[s0 : s0 + span]
        gather[lo : lo + span] = src_gather[s0 : s0 + span]
    return seg, gather


@dataclasses.dataclass(frozen=True)
class ShardedDBPlan:
    """Device-resident DBIndex plan shards plus the host metadata needed to
    route tile-group patches to the shard that owns them.

    Tile rows (pass 1/2) are sharded at whole-group granularity by the
    greedy assignment; ELL rows are sharded by contiguous id chunks (block
    ids for pass 1, owner ids for pass 2) with an explicit per-row id array
    so the local reduce scatters its rows into an identity-filled full
    vector before the ``pmin``/``pmax`` combine.
    """

    mesh: object
    axes: Tuple[str, ...]
    ndev: int
    n: int
    num_blocks: int
    block_capacity: int
    tm: int
    ts: int
    headroom: float
    nb_seg: int  # padded pass-1 segment space (num_out_tiles1 * ts)
    n_seg: int  # padded pass-2 segment space (num_out_tiles2 * ts)
    rows1: int  # per-shard pass-1 rows
    rows2: int  # per-shard pass-2 rows
    # device arrays ([ndev*rows] flats sharded over `axes`; sizes replicated)
    p1_gather: object
    p1_seg: object
    p2_gather: object
    p2_seg: object
    block_sizes: object  # f32 [block_capacity], replicated
    e1: Optional[object] = None  # i32 [ndev*ell_rows1, R1] member ids
    e1_ids: Optional[object] = None  # i32 [ndev*ell_rows1] block id / -1
    e2: Optional[object] = None  # i32 [ndev*ell_rows2, R2] block ids
    e2_ids: Optional[object] = None  # i32 [ndev*ell_rows2] owner id / -1
    # host metadata (patch routing)
    group_shard1: Optional[np.ndarray] = None
    group_off1: Optional[np.ndarray] = None
    group_tiles1: Optional[np.ndarray] = None
    group_shard2: Optional[np.ndarray] = None
    group_off2: Optional[np.ndarray] = None
    group_tiles2: Optional[np.ndarray] = None
    stats: Dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def has_ell(self) -> bool:
        return self.e1 is not None

    def array_nbytes(self) -> Dict:
        """Exact per-array device bytes — the same accounting surface as
        ``DBIndexPlan.array_nbytes`` / ``IIndexPlan.array_nbytes``, so
        EXPLAIN reports one schema across host/device/sharded plans."""
        out = {
            "p1_gather": int(self.p1_gather.nbytes),
            "p1_seg": int(self.p1_seg.nbytes),
            "p2_gather": int(self.p2_gather.nbytes),
            "p2_seg": int(self.p2_seg.nbytes),
            "block_sizes": int(self.block_sizes.nbytes),
        }
        if self.has_ell:
            out["e1"] = int(self.e1.nbytes)
            out["e1_ids"] = int(self.e1_ids.nbytes)
            out["e2"] = int(self.e2.nbytes)
            out["e2_ids"] = int(self.e2_ids.nbytes)
        return out

    def plan_nbytes(self) -> int:
        """Total device bytes held by this plan."""
        return sum(self.array_nbytes().values())

    def size_bytes(self) -> int:
        # kept for pre-existing callers (wire ledger, benches)
        return self.plan_nbytes()

    def shard_row_loads(self) -> Dict:
        """Per-shard real (unpadded) row loads for both passes, from the
        patch-routing metadata — EXPLAIN's shard-balance view.  Empty dict
        when routing metadata was dropped (plans restored without it)."""
        out: Dict = {}
        for name, shard_of, tiles, rows_cap in (
            ("pass1", self.group_shard1, self.group_tiles1, self.rows1),
            ("pass2", self.group_shard2, self.group_tiles2, self.rows2),
        ):
            if shard_of is None or tiles is None:
                continue
            loads = np.zeros(self.ndev, np.int64)
            np.add.at(loads, np.asarray(shard_of, np.int64),
                      np.asarray(tiles, np.int64) * self.tm)
            out[name] = {
                "rows_per_shard": [int(x) for x in loads],
                "rows_capacity": int(rows_cap),
                "balance": (float(loads.min() / loads.max())
                            if loads.max() else 1.0),
            }
        return out


def _shard_put(mesh, axes, arr, sharded: bool):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec = P(axes) if sharded else P()
    return jax.device_put(arr, NamedSharding(mesh, spec))


def _ell_shards(rows_np: np.ndarray, num_ids: int, ndev: int):
    """Pad an [num_ids, R] ELL matrix to equal contiguous id chunks."""
    from repro.core.engine_jax import _ELL_SENTINEL

    per = max(-(-num_ids // ndev), 1)
    pad = per * ndev - num_ids
    if pad:
        rows_np = np.concatenate(
            [rows_np, np.full((pad, rows_np.shape[1]), _ELL_SENTINEL, np.int32)]
        )
    ids = np.full(per * ndev, -1, np.int32)
    ids[:num_ids] = np.arange(num_ids, dtype=np.int32)
    return rows_np, ids


def build_sharded_plan(plan, mesh, axis="data", headroom: float = 0.0,
                       stats: Optional[Dict] = None) -> ShardedDBPlan:
    """Lay a single-host :class:`~repro.core.engine_jax.DBIndexPlan` out as
    device-resident shards (see :class:`ShardedDBPlan`).  ``headroom`` is
    recorded so rebuilds keep the same streaming slack; ``stats`` carries
    counters forward across rebuilds."""
    axes = _axes_tuple(axis)
    ndev = _mesh_ndev(mesh, axes)

    tiles1, starts1 = _group_layout(plan.pass1)
    tiles2, starts2 = _group_layout(plan.pass2)
    rows_g1, rows_g2 = tiles1 * plan.pass1.tm, tiles2 * plan.pass2.tm
    shard1, off1, rows1 = _assign_groups(rows_g1, ndev)
    shard2, off2, rows2 = _assign_groups(rows_g2, ndev)
    p1_seg, p1_gather = _pack_shards(
        np.asarray(plan.pass1.seg_tiles).reshape(-1),
        np.asarray(plan.pass1.gather_padded),
        starts1, rows_g1, shard1, off1, rows1, ndev,
    )
    p2_seg, p2_gather = _pack_shards(
        np.asarray(plan.pass2.seg_tiles).reshape(-1),
        np.asarray(plan.pass2.gather_padded),
        starts2, rows_g2, shard2, off2, rows2, ndev,
    )
    e1 = e1_ids = e2 = e2_ids = None
    if plan.p1_ell is not None:
        e1_np, e1_ids_np = _ell_shards(np.asarray(plan.p1_ell),
                                       plan.block_capacity, ndev)
        e2_np, e2_ids_np = _ell_shards(np.asarray(plan.p2_ell), plan.n, ndev)
        e1 = _shard_put(mesh, axes, e1_np, True)
        e1_ids = _shard_put(mesh, axes, e1_ids_np, True)
        e2 = _shard_put(mesh, axes, e2_np, True)
        e2_ids = _shard_put(mesh, axes, e2_ids_np, True)
    base_stats = dict(stats or {})
    base_stats.setdefault("patched_bytes_total", 0)
    base_stats.setdefault("rebuilds", 0)
    base_stats.setdefault("version", 0)
    # a fresh layout lays out every member row the index holds — any
    # previously device-compacted garbage rows are back, so the ledger
    # the patcher keeps must restart empty
    base_stats.pop("p1_compacted_ids", None)
    splan = ShardedDBPlan(
        mesh=mesh, axes=axes, ndev=ndev,
        n=plan.n, num_blocks=plan.num_blocks,
        block_capacity=plan.block_capacity,
        tm=plan.pass1.tm, ts=plan.pass1.ts,
        headroom=headroom,
        nb_seg=plan.pass1.num_out_tiles * plan.pass1.ts,
        n_seg=plan.pass2.num_out_tiles * plan.pass2.ts,
        rows1=rows1, rows2=rows2,
        p1_gather=_shard_put(mesh, axes, p1_gather, True),
        p1_seg=_shard_put(mesh, axes, p1_seg, True),
        p2_gather=_shard_put(mesh, axes, p2_gather, True),
        p2_seg=_shard_put(mesh, axes, p2_seg, True),
        block_sizes=_shard_put(
            mesh, axes, np.asarray(plan.block_sizes, np.float32), False
        ),
        e1=e1, e1_ids=e1_ids, e2=e2, e2_ids=e2_ids,
        group_shard1=shard1, group_off1=off1, group_tiles1=tiles1,
        group_shard2=shard2, group_off2=off2, group_tiles2=tiles2,
        stats=base_stats,
    )
    base_stats["full_bytes"] = splan.size_bytes()
    return splan


# ---------------------------------------------------------------------- #
#  Sharded fused multi-aggregate query
# ---------------------------------------------------------------------- #
def _sharded_query_impl(sharded, repl, values, mesh, axes, aggs, cfg):
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.aggregates import pack_channels
    from repro.core.engine_jax import _ell_reduce

    n, cap, nb_seg, n_seg, has_ell = cfg
    pack = pack_channels(aggs)
    sum_cols = pack.channels_of("sum")
    minmax_cols = [
        (ci, m, s) for ci, (m, s) in enumerate(pack.channels) if m != "sum"
    ]
    _SEG = {"min": jax.ops.segment_min, "max": jax.ops.segment_max}
    _COMB = {"min": jax.lax.pmin, "max": jax.lax.pmax}
    _FILL = {"min": jnp.inf, "max": -jnp.inf}

    def local(shard_args, repl_args, vals):
        if has_ell:
            p1g, p1s, p2g, p2s, e1, e1i, e2, e2i = shard_args
        else:
            p1g, p1s, p2g, p2s = shard_args
        (bsz,) = repl_args
        # ``vals`` is [n] (one query) or [n, B] (a run_many bucket riding a
        # trailing batched values axis through the same shard-local fn —
        # gathers/segment reduces/collectives all carry the extra axis, so
        # a whole [B, n] batch is ONE launch instead of B replays)
        bat = vals.ndim == 2

        def col(mask):  # broadcast a row mask over the batch axis
            return mask[:, None] if bat else mask

        # ---- pass 1: block partials, one psum for the stacked channels --- #
        # "square" channels (registered derived aggregates) square the
        # gathered rows — take(v², idx) == take(v, idx)², so no extra gather
        t_cols = {}

        def sum_pass1(rows):
            ok1 = p1s >= 0
            part = jax.ops.segment_sum(
                jnp.where(col(ok1), rows, 0.0),
                jnp.where(ok1, p1s, nb_seg),
                num_segments=nb_seg + 1,
            )[:nb_seg]
            return jax.lax.psum(part, axes)[:cap]

        srcs_needed = {pack.channels[ci][1] for ci in sum_cols} - {"ones"}
        if srcs_needed:
            rows1 = jnp.take(vals, p1g, axis=0)
            t_src = {s: sum_pass1(rows1 if s == "value" else rows1 * rows1)
                     for s in srcs_needed}
        for ci in sum_cols:
            # block cardinalities are host-exact replicated metadata
            if pack.channels[ci][1] == "ones":
                t_cols[ci] = (
                    jnp.broadcast_to(bsz[:, None], (bsz.shape[0],) + vals.shape[1:])
                    if bat else bsz
                )
            else:
                t_cols[ci] = t_src[pack.channels[ci][1]]
        for ci, m, s in minmax_cols:
            v_in = vals if s == "value" else vals * vals
            if has_ell:
                red = _ell_reduce(e1, v_in, m)  # [rows/shard(, B)]
                part = _SEG[m](red, jnp.where(e1i >= 0, e1i, cap),
                               num_segments=cap + 1)[:cap]
                t_cols[ci] = _COMB[m](part, axes)
            else:
                ok1 = p1s >= 0
                part = _SEG[m](
                    jnp.where(col(ok1), jnp.take(v_in, p1g, axis=0), _FILL[m]),
                    jnp.where(ok1, p1s, nb_seg),
                    num_segments=nb_seg + 1,
                )[:nb_seg]
                t_cols[ci] = _COMB[m](part, axes)[:cap]

        # ---- pass 2: one gather of the stacked matrix + one psum --------- #
        outs = {}
        if sum_cols:
            t_mat = jnp.stack([t_cols[ci] for ci in sum_cols], axis=1)
            ok2 = p2s >= 0
            g2 = jnp.take(t_mat, p2g, axis=0)
            part = jax.ops.segment_sum(
                jnp.where(ok2[:, None, None] if bat else ok2[:, None], g2, 0.0),
                jnp.where(ok2, p2s, n_seg),
                num_segments=n_seg + 1,
            )[:n_seg]
            red = jax.lax.psum(part, axes)[:n]
            for j, ci in enumerate(sum_cols):
                outs[ci] = red[:, j]
        for ci, m, _ in minmax_cols:
            if has_ell:
                red = _ell_reduce(e2, t_cols[ci], m)
                part = _SEG[m](red, jnp.where(e2i >= 0, e2i, n),
                               num_segments=n + 1)[:n]
                outs[ci] = _COMB[m](part, axes)
            else:
                ok2 = p2s >= 0
                part = _SEG[m](
                    jnp.where(col(ok2), jnp.take(t_cols[ci], p2g, axis=0),
                              _FILL[m]),
                    jnp.where(ok2, p2s, n_seg),
                    num_segments=n_seg + 1,
                )[:n_seg]
                outs[ci] = _COMB[m](part, axes)[:n]
        return tuple(outs[ci] for ci in range(len(pack.channels)))

    sh = P(axes)
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(tuple(sh for _ in sharded), (P(),), P()),
        out_specs=tuple(P() for _ in pack.channels),
        check_rep=False,
    )
    # channel results only — finalizers run eagerly in the public wrappers
    # (XLA fusion may FMA-contract a finalizer and re-round; outside the jit
    # a registered pure finalize matches its NumPy evaluation bit for bit)
    return fn(sharded, repl, values)


_sharded_query = None  # jitted lazily (keeps module import JAX-light)


def _get_sharded_query():
    global _sharded_query
    if _sharded_query is None:
        import functools
        import jax

        _sharded_query = functools.partial(jax.jit, static_argnames=(
            "mesh", "axes", "aggs", "cfg"))(_sharded_query_impl)
    return _sharded_query


def query_cache_size() -> int:
    """Jit cache entries of the sharded fused query (recompile counter)."""
    return _get_sharded_query()._cache_size() if _sharded_query else 0


def _splan_call_args(splan: ShardedDBPlan):
    sharded = (splan.p1_gather, splan.p1_seg, splan.p2_gather, splan.p2_seg)
    if splan.has_ell:
        sharded = sharded + (splan.e1, splan.e1_ids, splan.e2, splan.e2_ids)
    cfg = (splan.n, splan.block_capacity, splan.nb_seg, splan.n_seg,
           splan.has_ell)
    return sharded, cfg


def _finalize_chans(aggs: tuple, chans):
    import jax.numpy as jnp

    from repro.core.aggregates import pack_channels

    pack = pack_channels(aggs)
    return tuple(pack.finalize(i, chans, xp=jnp) for i in range(len(aggs)))


def query_sharded_multi(splan: ShardedDBPlan, values, aggs: Sequence[str]):
    """Fused multi-aggregate sharded query; returns one array per aggregate,
    bit-identical to the single-host ``query_dbindex_multi`` results."""
    import jax.numpy as jnp

    values = jnp.asarray(values, jnp.float32)
    sharded, cfg = _splan_call_args(splan)
    _obs.get_registry().counter(
        "repro_shard_launches_total",
        "per-device launches of the sharded fused query").inc(splan.ndev)
    chans = _get_sharded_query()(
        sharded, (splan.block_sizes,), values,
        mesh=splan.mesh, axes=splan.axes, aggs=tuple(aggs), cfg=cfg,
    )
    return _finalize_chans(tuple(aggs), chans)


def query_sharded_many(splan: ShardedDBPlan, values_batch,
                       aggs: Sequence[str]):
    """[B, n] serving traffic in ONE sharded launch.

    The shard-local fn carries a trailing batched values axis, so
    ``ShardedSession.run_many`` no longer replays the compiled executable
    per batch row (the old ROADMAP open item) — one launch computes every
    row, and the collective footprint stays one ``psum``/``pmin``/``pmax``
    per pass with a ``B``-wide payload.  Returns one [B, n] array per
    aggregate.
    """
    import jax.numpy as jnp

    vb = jnp.asarray(values_batch, jnp.float32)
    assert vb.ndim == 2, "values_batch must be [B, n]"
    sharded, cfg = _splan_call_args(splan)
    _obs.get_registry().counter(
        "repro_shard_launches_total",
        "per-device launches of the sharded fused query").inc(splan.ndev)
    chans = _get_sharded_query()(
        sharded, (splan.block_sizes,), vb.T,
        mesh=splan.mesh, axes=splan.axes, aggs=tuple(aggs), cfg=cfg,
    )
    return tuple(o.T for o in _finalize_chans(tuple(aggs), chans))


# ---------------------------------------------------------------------- #
#  Streamed update propagation: per-shard tile-group patches
# ---------------------------------------------------------------------- #
def _group_rows(sorted_seg: np.ndarray, gather_src: np.ndarray, g: int,
                ts: int, span: int):
    """Padded (seg, gather) rows of one output tile group from the full new
    arrays, or None when the group's rows no longer fit its capacity."""
    lo, hi = np.searchsorted(sorted_seg, (g * ts, (g + 1) * ts))
    if hi - lo > span:
        return None
    seg = np.full(span, -1, np.int32)
    gather = np.zeros(span, np.int32)
    seg[: hi - lo] = sorted_seg[lo:hi]
    gather[: hi - lo] = gather_src[lo:hi]
    return seg, gather


def patch_sharded_plan(
    splan: ShardedDBPlan, index: DBIndex, changed_owners: np.ndarray,
    compact_garbage: float = 0.25, wire: Optional[list] = None,
) -> ShardedDBPlan:
    """Propagate one streamed batch into the device-resident plan shards.

    The wire format is *changed tile groups*: pass 1 ships only the groups
    holding appended secondary block ids, pass 2 only the groups containing
    ``changed_owners``; each patch is scattered into the owning shard's flat
    rows via ``at[...].set`` (shapes never change in steady state, so jitted
    queries never retrace).  ELL rows are row-addressed (block id / owner
    id) and patched the same way.  Falls back to a full rebuild — a
    recompile-sized event, like capacity growth — when the updater rebuilt
    outright, capacity is exceeded, or a group/row no longer fits.

    Delete-dominated streams accumulate *garbage blocks* (zero-link blocks
    whose member rows still occupy pass-1 tiles).  When the garbage
    fraction crosses ``compact_garbage``, pass 1 is re-packed **per shard,
    in place**: every pass-1 group whose block range holds a garbage or
    appended block is re-laid-out from the index with the garbage blocks'
    member rows dropped and scattered into its owning shard's existing
    flat rows (groups without either are bit-identical and ship nothing).
    Shapes never change (no retrace, unlike the single-host compaction
    which rebuilds pass 1), garbage partials simply become identities
    nobody gathers — correctness is untouched because a garbage block by
    definition has no pass-2 link — and the freed tile slots keep future
    appends below the rebuild threshold.

    ``wire``, when a list, receives one serializable *replication message*
    describing exactly what this call shipped to the shards: the changed
    tile groups' flat positions and rows, the appended block sizes and ELL
    rows (kind ``"patch"``), or the full index on a rebuild (kind
    ``"resync"``).  A follower holding the same pre-patch plan replays the
    message with :func:`apply_wire_message` and lands on a bit-identical
    plan — the patch stream *is* the replication stream.
    """
    import jax.numpy as jnp

    ts = splan.ts
    stats = dict(splan.stats)
    stats["version"] = stats.get("version", 0) + 1

    def rebuild():
        from repro.core.engine_jax import plan_from_dbindex

        cap = splan.block_capacity
        if index.num_blocks > cap:
            cap = 1 << (index.num_blocks - 1).bit_length()
        base = plan_from_dbindex(index, splan.tm, ts, block_capacity=cap,
                                 headroom=splan.headroom)
        stats["rebuilds"] = stats.get("rebuilds", 0) + 1
        _obs.get_registry().counter(
            "repro_plan_rebuilds_total",
            "sharded plan full rebuilds (recompile-sized events)").inc()
        stats["last_patch_groups"] = -1
        stats["last_compaction"] = False
        out = build_sharded_plan(base, splan.mesh, splan.axes,
                                 headroom=splan.headroom, stats=stats)
        out.stats["last_patch_bytes"] = out.size_bytes()
        if wire is not None:
            from repro.obs.audit import plan_crc

            # stamp the post-apply content digest: a follower replaying
            # this message self-checks against it (apply_wire_message)
            wire.append({"kind": "resync", "index": index,
                         "plan_crc": plan_crc(out)})
        return out

    if (index.stats.get("last_full_rebuild")
            or index.num_blocks > splan.block_capacity):
        return rebuild()

    owners = np.unique(np.asarray(changed_owners, np.int64))
    new_blocks = np.arange(splan.num_blocks, index.num_blocks, dtype=np.int64)
    if splan.has_ell:
        # width overflow is a rebuild-sized event — detect it before any
        # device scatter is staged (same early-out as the single-host
        # ``_patch_ell``), not after the tile-group work is already done
        r1, r2 = splan.e1.shape[1], splan.e2.shape[1]
        if new_blocks.size and int(
                np.diff(index.block_offsets)[new_blocks].max()) > r1:
            return rebuild()
        if owners.size and int(
                np.diff(index.link_owner_offsets)[owners].max()) > r2:
            return rebuild()
    member_block = np.asarray(index.member_block_ids, np.int64)
    link_owner = np.asarray(index.link_owner_ids, np.int64)

    # per-shard pass-1 garbage compaction.  Only groups whose block range
    # holds *fresh* garbage (rows to drop that are still on device) or an
    # appended block differ from the device content — everything else is
    # bit-identical and ships nothing, so the changed-tile-groups wire
    # format survives compaction.  ``p1_compacted_ids`` records which
    # garbage blocks' rows are already gone from the device shards: the
    # index keeps its garbage until a rebuild, so without the ledger every
    # later batch would re-ship the same compacted groups; it also keeps
    # pass-1 patches on the garbage-free row set once any compaction
    # happened (a plain re-lay-out would resurrect the dropped rows).
    linked = index.linked_blocks_mask()
    garbage = np.flatnonzero(~linked[: index.num_blocks]).astype(np.int64)
    already = np.asarray(stats.get("p1_compacted_ids", []), np.int64)
    fresh_garbage = np.setdiff1d(garbage, already)
    # same threshold semantics as the single-host ``patch_plan_dbindex``:
    # fraction >= threshold compacts (0.0 = compact whenever garbage exists);
    # zero-block indices never compact (nothing to drop, and the fraction
    # is defined as 0.0 for them)
    over = (index.num_blocks > 0
            and index.garbage_block_fraction(linked) >= compact_garbage)
    compacting = over and fresh_garbage.size > 0
    filter_garbage = compacting or already.size > 0
    if filter_garbage:
        keep = linked[member_block]
        p1_seg_src = member_block[keep]
        p1_gather_src = index.block_members[keep]
    else:
        p1_seg_src, p1_gather_src = member_block, index.block_members
    dirty = (
        np.concatenate([fresh_garbage, new_blocks]) if compacting
        else new_blocks
    )
    p1_groups = np.unique(dirty // ts)
    if filter_garbage and p1_groups.size:
        shipped = garbage[np.isin(garbage // ts, p1_groups)]
        stats["p1_compacted_ids"] = np.union1d(already, shipped).tolist()
    if compacting:
        stats["p1_compactions"] = stats.get("p1_compactions", 0) + 1
    stats["last_compaction"] = bool(compacting)

    per_shard = np.zeros(splan.ndev, np.int64)
    patches: List[Tuple] = []  # (pass_name, flat positions, seg, gather)
    groups_patched = 0
    for pass_id, groups, seg_src, gather_src in (
        (1, p1_groups, p1_seg_src, p1_gather_src),
        (2, np.unique(owners // ts), link_owner, index.link_block),
    ):
        if groups.size == 0:
            continue
        tiles = splan.group_tiles1 if pass_id == 1 else splan.group_tiles2
        shard_of = splan.group_shard1 if pass_id == 1 else splan.group_shard2
        offset = splan.group_off1 if pass_id == 1 else splan.group_off2
        rows_cap = splan.rows1 if pass_id == 1 else splan.rows2
        tm = splan.tm
        pos_chunks, seg_chunks, gather_chunks = [], [], []
        for g in groups:
            span = int(tiles[g]) * tm
            rows = _group_rows(seg_src, gather_src, int(g), ts, span)
            if rows is None:  # group outgrew its tile capacity
                return rebuild()
            lo = int(shard_of[g]) * rows_cap + int(offset[g])
            pos_chunks.append(np.arange(lo, lo + span, dtype=np.int64))
            seg_chunks.append(rows[0])
            gather_chunks.append(rows[1])
            per_shard[int(shard_of[g])] += span * 8  # seg + gather, i32 each
            groups_patched += 1
        patches.append((f"p{pass_id}", np.concatenate(pos_chunks),
                        np.concatenate(seg_chunks),
                        np.concatenate(gather_chunks)))

    p1_seg, p1_gather = splan.p1_seg, splan.p1_gather
    p2_seg, p2_gather = splan.p2_seg, splan.p2_gather
    for name, pos_np, seg_np, gather_np in patches:
        pos = jnp.asarray(pos_np)
        seg_new = jnp.asarray(seg_np)
        gather_new = jnp.asarray(gather_np)
        if name == "p1":
            p1_seg = p1_seg.at[pos].set(seg_new)
            p1_gather = p1_gather.at[pos].set(gather_new)
        else:
            p2_seg = p2_seg.at[pos].set(seg_new)
            p2_gather = p2_gather.at[pos].set(gather_new)

    block_sizes = splan.block_sizes
    sizes = np.empty(0, np.float32)
    if new_blocks.size:
        sizes = np.diff(index.block_offsets)[new_blocks].astype(np.float32)
        block_sizes = block_sizes.at[jnp.asarray(new_blocks)].set(
            jnp.asarray(sizes))
        per_shard += (new_blocks.size * 4) // splan.ndev  # replicated bcast

    e1, e1_ids, e2, e2_ids = splan.e1, splan.e1_ids, splan.e2, splan.e2_ids
    e1_rows = e2_rows = None
    if splan.has_ell:  # widths already validated before the tile scatters
        from repro.core.engine_jax import (
            _ell_rows_for_new_blocks,
            _ell_rows_for_owners,
        )

        if new_blocks.size:
            e1_rows = _ell_rows_for_new_blocks(index, splan.num_blocks, r1)
            e1 = e1.at[jnp.asarray(new_blocks)].set(jnp.asarray(e1_rows))
            rs1 = splan.e1.shape[0] // splan.ndev
            np.add.at(per_shard, (new_blocks // rs1).astype(np.int64),
                      r1 * 4)
        if owners.size:
            e2_rows = _ell_rows_for_owners(index, owners, r2)
            e2 = e2.at[jnp.asarray(owners)].set(jnp.asarray(e2_rows))
            rs2 = splan.e2.shape[0] // splan.ndev
            np.add.at(per_shard, (owners // rs2).astype(np.int64), r2 * 4)

    if wire is not None:
        wire.append({
            "kind": "patch",
            "num_blocks": int(index.num_blocks),
            "patches": [(name, pos_np, seg_np, gather_np)
                        for name, pos_np, seg_np, gather_np in patches],
            "block_ids": new_blocks,
            "block_sizes": sizes,
            "e1_ids": new_blocks if e1_rows is not None
            else np.empty(0, np.int64),
            "e1_rows": e1_rows,
            "e2_ids": owners if e2_rows is not None
            else np.empty(0, np.int64),
            "e2_rows": e2_rows,
        })

    patch_bytes = int(per_shard.sum())
    _obs.get_registry().counter(
        "repro_patch_bytes_total",
        "bytes of tile-group patches shipped to plan shards").inc(patch_bytes)
    stats.update(
        last_patch_bytes=patch_bytes,
        last_patch_groups=groups_patched,
        last_patch_per_shard=per_shard.tolist(),
        patched_bytes_total=stats.get("patched_bytes_total", 0) + patch_bytes,
    )
    out = dataclasses.replace(
        splan,
        num_blocks=index.num_blocks,
        p1_seg=p1_seg, p1_gather=p1_gather,
        p2_seg=p2_seg, p2_gather=p2_gather,
        block_sizes=block_sizes,
        e1=e1, e1_ids=e1_ids, e2=e2, e2_ids=e2_ids,
        stats=stats,
    )
    if wire is not None:
        from repro.obs.audit import plan_crc

        # post-apply content digest of the plan this message produces —
        # a follower replaying it self-checks (apply_wire_message)
        wire[-1]["plan_crc"] = plan_crc(out)
    return out


# ---------------------------------------------------------------------- #
#  Replication messages (the patch stream on the wire)
# ---------------------------------------------------------------------- #
class WireDivergenceError(RuntimeError):
    """A replayed wire message produced a plan whose content digest does
    not match the leader's ``plan_crc`` stamp (the follower held different
    pre-patch state, or the message was corrupted in transit)."""


def apply_wire_message(splan: ShardedDBPlan, msg: Dict,
                       verify: bool = True) -> ShardedDBPlan:
    """Replay one :func:`patch_sharded_plan` wire message on a follower's
    plan.  The follower must hold the same plan state the leader held
    before the message was produced (apply the stream in order, no gaps);
    positions and row ids in a ``"patch"`` message are absolute, so the
    replay is exactly the leader's device scatters.  A ``"resync"``
    message (leader rebuilt) carries the full index and rebuilds the
    follower the same deterministic way.

    When the message carries the leader's post-apply ``plan_crc`` stamp
    and ``verify`` is on, the follower recomputes its own plan digest and
    raises :class:`WireDivergenceError` on mismatch — silent follower
    drift is converted into an immediate, attributed failure."""
    import jax.numpy as jnp

    if msg["kind"] == "resync":
        from repro.core.engine_jax import plan_from_dbindex

        index = msg["index"]
        cap = splan.block_capacity
        if index.num_blocks > cap:
            cap = 1 << (index.num_blocks - 1).bit_length()
        base = plan_from_dbindex(index, splan.tm, splan.ts,
                                 block_capacity=cap,
                                 headroom=splan.headroom)
        stats = dict(splan.stats)
        stats["version"] = stats.get("version", 0) + 1
        stats["rebuilds"] = stats.get("rebuilds", 0) + 1
        out = build_sharded_plan(base, splan.mesh, splan.axes,
                                 headroom=splan.headroom, stats=stats)
        return _verify_wire_crc(out, msg, verify)

    assert msg["kind"] == "patch", msg["kind"]
    p1_seg, p1_gather = splan.p1_seg, splan.p1_gather
    p2_seg, p2_gather = splan.p2_seg, splan.p2_gather
    for name, pos_np, seg_np, gather_np in msg["patches"]:
        pos = jnp.asarray(pos_np)
        seg_new = jnp.asarray(seg_np)
        gather_new = jnp.asarray(gather_np)
        if name == "p1":
            p1_seg = p1_seg.at[pos].set(seg_new)
            p1_gather = p1_gather.at[pos].set(gather_new)
        else:
            p2_seg = p2_seg.at[pos].set(seg_new)
            p2_gather = p2_gather.at[pos].set(gather_new)
    block_sizes = splan.block_sizes
    if msg["block_ids"].size:
        block_sizes = block_sizes.at[jnp.asarray(msg["block_ids"])].set(
            jnp.asarray(msg["block_sizes"]))
    e1, e2 = splan.e1, splan.e2
    if msg["e1_rows"] is not None and msg["e1_ids"].size:
        e1 = e1.at[jnp.asarray(msg["e1_ids"])].set(
            jnp.asarray(msg["e1_rows"]))
    if msg["e2_rows"] is not None and msg["e2_ids"].size:
        e2 = e2.at[jnp.asarray(msg["e2_ids"])].set(
            jnp.asarray(msg["e2_rows"]))
    stats = dict(splan.stats)
    stats["version"] = stats.get("version", 0) + 1
    out = dataclasses.replace(
        splan,
        num_blocks=int(msg["num_blocks"]),
        p1_seg=p1_seg, p1_gather=p1_gather,
        p2_seg=p2_seg, p2_gather=p2_gather,
        block_sizes=block_sizes,
        e1=e1, e2=e2,
        stats=stats,
    )
    return _verify_wire_crc(out, msg, verify)


def _verify_wire_crc(out: ShardedDBPlan, msg: Dict,
                     verify: bool) -> ShardedDBPlan:
    expect = msg.get("plan_crc")
    if verify and expect is not None:
        from repro.obs.audit import plan_crc

        got = plan_crc(out)
        if got != int(expect):
            _obs.get_registry().counter(
                "repro_wire_divergence_total",
                "wire-replayed plans failing the leader's plan_crc").inc()
            raise WireDivergenceError(
                f"{msg['kind']} replay digest mismatch: "
                f"leader={int(expect):#010x} follower={got:#010x}")
    return out


def encode_wire_message(msg: Dict) -> bytes:
    """Serialize one replication message to bytes (``np.savez``-framed;
    no pickling — index stats ride as JSON)."""
    import io
    import json

    arrays: Dict[str, np.ndarray] = {}
    meta: Dict = {"kind": msg["kind"]}
    if msg.get("plan_crc") is not None:
        meta["plan_crc"] = int(msg["plan_crc"])
    if msg["kind"] == "resync":
        idx = msg["index"]
        meta["n"] = int(idx.n)
        meta["num_blocks"] = int(idx.num_blocks)
        meta["stats"] = {k: v for k, v in idx.stats.items()
                         if isinstance(v, (int, float, bool, str))}
        arrays["block_members"] = np.asarray(idx.block_members)
        arrays["block_offsets"] = np.asarray(idx.block_offsets)
        arrays["link_block"] = np.asarray(idx.link_block)
        arrays["link_owner_offsets"] = np.asarray(idx.link_owner_offsets)
    else:
        meta["num_blocks"] = int(msg["num_blocks"])
        meta["patch_names"] = [name for name, *_ in msg["patches"]]
        for i, (name, pos, seg, gather) in enumerate(msg["patches"]):
            arrays[f"patch{i}_pos"] = pos
            arrays[f"patch{i}_seg"] = seg
            arrays[f"patch{i}_gather"] = gather
        arrays["block_ids"] = msg["block_ids"]
        arrays["block_sizes"] = msg["block_sizes"]
        for key in ("e1", "e2"):
            rows = msg[f"{key}_rows"]
            meta[f"has_{key}"] = rows is not None
            arrays[f"{key}_ids"] = np.asarray(msg[f"{key}_ids"])
            if rows is not None:
                arrays[f"{key}_rows"] = rows
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    payload = buf.getvalue()
    header = json.dumps(meta).encode()
    return (len(header).to_bytes(4, "little") + header + payload)


def decode_wire_message(data: bytes) -> Dict:
    """Inverse of :func:`encode_wire_message`."""
    import io
    import json

    hlen = int.from_bytes(data[:4], "little")
    meta = json.loads(data[4: 4 + hlen].decode())
    arrays = dict(np.load(io.BytesIO(data[4 + hlen:]), allow_pickle=False))
    if meta["kind"] == "resync":
        index = DBIndex(
            n=int(meta["n"]),
            num_blocks=int(meta["num_blocks"]),
            block_members=arrays["block_members"],
            block_offsets=arrays["block_offsets"],
            link_block=arrays["link_block"],
            link_owner_offsets=arrays["link_owner_offsets"],
            stats=dict(meta["stats"]),
        )
        out = {"kind": "resync", "index": index}
        if "plan_crc" in meta:
            out["plan_crc"] = int(meta["plan_crc"])
        return out
    msg: Dict = {
        "kind": "patch",
        "num_blocks": int(meta["num_blocks"]),
        "patches": [
            (name, arrays[f"patch{i}_pos"], arrays[f"patch{i}_seg"],
             arrays[f"patch{i}_gather"])
            for i, name in enumerate(meta["patch_names"])
        ],
        "block_ids": arrays["block_ids"],
        "block_sizes": arrays["block_sizes"],
    }
    for key in ("e1", "e2"):
        msg[f"{key}_ids"] = arrays[f"{key}_ids"]
        msg[f"{key}_rows"] = arrays[f"{key}_rows"] if meta[f"has_{key}"] else None
    if "plan_crc" in meta:
        msg["plan_crc"] = int(meta["plan_crc"])
    return msg


# ---------------------------------------------------------------------- #
#  Sharded streaming state (graph + index + plan shards under updates)
# ---------------------------------------------------------------------- #
class ShardedStreamState:
    """Per-window streaming state with device-resident plan shards.

    Mirrors :class:`repro.core.streaming.StreamingEngine` (``apply`` /
    ``index`` / ``plan`` / ``staleness``) so :class:`repro.core.api.Session`
    machinery drives both interchangeably, but the plan is a
    :class:`ShardedDBPlan` and update propagation is distributed: the
    affected-owner BFS is sharded over the data axis (one seed slice per
    shard) and only the dirty tile groups are shipped to the shard owning
    them.
    """

    def __init__(
        self,
        g: Graph,
        window,
        mesh,
        axis="data",
        *,
        method: str = "emc",
        policy: Optional[StalenessPolicy] = None,
        tm: int = 512,
        ts: int = 512,
        plan_headroom: float = 0.5,
        # below StalenessPolicy.max_garbage_ratio (0.5) on purpose: the
        # in-place sharded compaction is shape-stable (no retrace), so it
        # should fire well before a policy rebuild is due
        compact_garbage: float = 0.25,
        use_device_bfs: Optional[bool] = None,
        capture_wire: bool = False,
        obs=None,
        tracer=None,
    ):
        from repro.core.windows import TopologicalWindow

        if isinstance(window, TopologicalWindow) and method == "emc":
            method = "mc"  # EMC is k-hop only (paper §4.2.2)
        #: replication stream: one message per applied batch when enabled
        #: (``patch_sharded_plan``'s wire format — see ``apply_wire_message``)
        self.wire_log: Optional[list] = [] if capture_wire else None
        self.graph = g
        self.window = window
        self.mesh, self.axes = mesh, _axes_tuple(axis)
        self.method = method
        self.policy = policy or StalenessPolicy()
        self.tm, self.ts = tm, ts
        self.plan_headroom = plan_headroom
        self.compact_garbage = compact_garbage
        self.use_device_bfs = use_device_bfs
        self.index_kind = "dbindex"
        self.batches_applied = 0
        self.reorg_count = 0
        self.batches_since_reorg = 0
        self.obs = obs if obs is not None else _obs.get_registry()
        self.tracer = tracer if tracer is not None else _obs.get_tracer()
        # same families as StreamingEngine so single-host and sharded
        # maintenance land in one place, split by the kind/action labels
        self._m_maint = self.obs.counter(
            "repro_maintenance_total", "index maintenance operations",
            labels=("kind", "action"))
        self._m_t_index = self.obs.histogram(
            "repro_index_update_seconds", "incremental index update latency",
            labels=("kind",))
        self._m_t_plan = self.obs.histogram(
            "repro_plan_patch_seconds", "device plan patch latency",
            labels=("kind",))
        self._build(initial=True)

    def _build(self, initial: bool = False) -> None:
        from repro.core import engine_jax as ej

        self.index = build_dbindex(self.graph, self.window, method=self.method)
        self._base_links = int(self.index.stats.get("num_links", 0))
        self._base_blocks = int(self.index.num_blocks)
        base = ej.plan_from_dbindex(self.index, self.tm, self.ts,
                                    headroom=self.plan_headroom)
        prev = getattr(self, "plan", None)
        self.plan = build_sharded_plan(
            base, self.mesh, self.axes, headroom=self.plan_headroom,
            stats=prev.stats if prev is not None else None,
        )
        if prev is not None:
            # a reorganize re-uploads the whole plan: the patch telemetry
            # must say so, not echo the previous batch's few-KB patch
            self.plan.stats.update(
                last_patch_bytes=self.plan.size_bytes(),
                last_patch_groups=-1,
                last_patch_per_shard=[],
                rebuilds=self.plan.stats.get("rebuilds", 0) + 1,
                version=self.plan.stats.get("version", 0) + 1,
            )
        self.batches_since_reorg = 0
        if not initial:
            self.reorg_count += 1
            if self.wire_log is not None:
                self.wire_log.append({"kind": "resync", "index": self.index})

    # ------------------------------------------------------------------ #
    def _refilter(self, owners: np.ndarray) -> bool:
        """Sharded analogue of :meth:`StreamingEngine._refilter`: phase-1
        merge the flipped owners' re-filtered windows, then ship only the
        changed tile groups to the shards that own them.  Returns True when
        the merge tripped the staleness policy and the state rebuilt."""
        from repro.core.updates import _merge_affected
        from repro.core.windows import expr_windows

        wins = expr_windows(self.graph, self.window, owners)
        self.index = _merge_affected(self.index, owners, wins)
        self.batches_applied += 1
        self.batches_since_reorg += 1
        if self.policy.should_reorganize(
            self.index, self._base_links, self._base_blocks,
            self.batches_since_reorg,
        ):
            self._build()
            return True
        self.plan = patch_sharded_plan(self.plan, self.index, owners,
                                       compact_garbage=self.compact_garbage,
                                       wire=self.wire_log)
        return False

    # ------------------------------------------------------------------ #
    def apply(self, batch: UpdateBatch, graph: Optional[Graph] = None) -> Dict:
        """Apply one batch; the affected-owner BFS runs one seed shard per
        mesh shard, and only changed tile groups ship to the plan shards."""
        from repro.core.streaming import _attr_only_report
        from repro.core.updates import apply_batch

        t0 = time.perf_counter()
        g2 = apply_batch(self.graph, batch) if graph is None else graph
        fast = _attr_only_report(self, batch, g2, t0)
        if fast is not None:
            refiltered = fast.get("refiltered", False)
            fast.update(
                affected_per_shard=[],
                compacted=bool(self.plan.stats.get("last_compaction", False))
                if refiltered else False,
                patch_bytes=int(self.plan.stats.get("last_patch_bytes", 0))
                if refiltered else 0,
                patch_bytes_per_shard=self.plan.stats.get(
                    "last_patch_per_shard", []) if refiltered else [],
                full_plan_bytes=int(self.plan.stats.get("full_bytes", 0)),
                plan_rebuilt=fast["reorganized"],
            )
            return fast
        with self.tracer.span("index.update", cat="update",
                              kind=self.index_kind, size=batch.size,
                              sharded=True):
            owners, per_shard_owners = sharded_affected_owners(
                g2, self.window, batch, self.plan.ndev,
                use_device=self.use_device_bfs,
            )
            idx2, changed = update_dbindex_batch(self.index, g2, self.window,
                                                 batch, owners=owners)
        self.graph, self.index = g2, idx2
        t_index = time.perf_counter() - t0
        self._m_t_index.labels(self.index_kind).observe(t_index)
        self.batches_applied += 1
        self.batches_since_reorg += 1

        reorganized = False
        if idx2.stats.get("last_full_rebuild"):
            self._base_links = int(idx2.stats.get("num_links", 0))
            self._base_blocks = int(idx2.num_blocks)
            self.batches_since_reorg = 0
        t1 = time.perf_counter()
        if self.policy.should_reorganize(
            idx2, self._base_links, self._base_blocks, self.batches_since_reorg
        ):
            with self.tracer.span("plan.patch", cat="update",
                                  kind=self.index_kind, action="reorganize"):
                self._build()
            reorganized = True
        else:
            with self.tracer.span("plan.patch", cat="update",
                                  kind=self.index_kind, action="patch"):
                self.plan = patch_sharded_plan(
                    self.plan, idx2, changed,
                    compact_garbage=self.compact_garbage,
                    wire=self.wire_log)
        t_plan = time.perf_counter() - t1
        self._m_t_plan.labels(self.index_kind).observe(t_plan)
        self._m_maint.labels(
            self.index_kind, "reorganize" if reorganized else "patch").inc()
        # the patcher itself may have rebuilt (updater full rebuild, capacity
        # or ELL-width overflow) — that is a full-plan re-upload too, and
        # consumers asserting patch < full must see it flagged
        plan_rebuilt = self.plan.stats.get("last_patch_groups") == -1
        return {
            "batch_size": batch.size,
            "affected": int(np.asarray(changed).size),
            # the exact owner set the serving-layer cache invalidates
            "affected_owners": np.asarray(changed, np.int32),
            "plan_version": int(self.plan.stats.get("version", 0)),
            "compacted": bool(self.plan.stats.get("last_compaction", False)),
            "affected_per_shard": [int(o.size) for o in per_shard_owners],
            "patch_bytes": int(self.plan.stats.get("last_patch_bytes", 0)),
            "patch_bytes_per_shard": self.plan.stats.get(
                "last_patch_per_shard", []),
            "full_plan_bytes": int(self.plan.stats.get("full_bytes", 0)),
            "t_index_s": t_index,
            "t_plan_s": t_plan,
            "reorganized": reorganized or plan_rebuilt,
            "plan_rebuilt": plan_rebuilt,
        }

    # ------------------------------------------------------------------ #
    def query_multi(self, aggs: Sequence[str], values=None) -> list:
        if values is None:
            values = self.graph.attrs["val"]
        outs = query_sharded_multi(self.plan, values, tuple(aggs))
        return [np.asarray(o) for o in outs]

    def query(self, agg: str = "sum", values=None) -> np.ndarray:
        return self.query_multi((agg,), values)[0]

    @property
    def staleness(self) -> Dict:
        from repro.core.streaming import garbage_block_fraction

        return {
            "link_ratio": int(self.index.stats.get("num_links", 0))
            / max(self._base_links, 1),
            "block_ratio": self.index.num_blocks / max(self._base_blocks, 1),
            "garbage_ratio": garbage_block_fraction(self.index),
        }


# ---------------------------------------------------------------------- #
#  ShardedSession — Session(mesh=...) across the mesh
# ---------------------------------------------------------------------- #
from repro.core.api import Session  # noqa: E402  (api never imports us eagerly)


class ShardedSession(Session):
    """A :class:`~repro.core.api.Session` whose device groups run across a
    mesh: query planning selects sharded capabilities, every distinct window
    gets per-shard device plans, and streamed ``UpdateBatch``es propagate as
    per-shard tile-group patches.  Construct directly or via
    ``Session(g, specs, mesh=mesh)`` — all Session kwargs (policy, headroom,
    method, pins, ``compact_garbage``, ...) keep their meaning; on
    delete-dominated streams the patcher re-packs pass-1 shards in place
    once the garbage-block fraction crosses ``compact_garbage`` (shapes
    stable — no retrace, no rebuild), so streams stay patch-only until a
    :class:`~repro.core.streaming.StalenessPolicy` rebuild is truly due.
    """

    _sharded = True

    def __init__(self, g: Graph, specs, *, mesh, axis="data", **kw):
        assert mesh is not None, "ShardedSession needs a mesh"
        self.axes = _axes_tuple(axis)
        super().__init__(g, specs, mesh=mesh, axis=axis, **kw)

    # ------------------------------------------------------------------ #
    def _make_state(self, window, kind: str, device: bool, sharded: bool):
        if not sharded:  # e.g. explicitly pinned host / iindex groups
            return super()._make_state(window, kind, device, sharded)
        cfg = self._state_cfg
        cg = cfg["compact_garbage"]
        return ShardedStreamState(
            self.graph, window, self.mesh, cfg["axis"],
            method=cfg["method"], policy=cfg["policy"],
            tm=cfg["tm"], ts=cfg["ts"],
            plan_headroom=cfg["plan_headroom"],
            compact_garbage=0.25 if cg is None else cg,
            use_device_bfs=cfg["use_device_bfs"],
            obs=self.obs, tracer=self.tracer,
        )

    def _group_artifacts(self, gi):
        """A (window, kind) state shared between a sharded group and a
        pinned non-sharded device group holds a :class:`ShardedDBPlan`,
        which single-host executors cannot consume — hand those groups the
        index only (their runner builds a host plan per call)."""
        arts = super()._group_artifacts(gi)
        cap = self.registry.capability(self.compiled.groups[gi].engine)
        if not cap.sharded:
            arts = tuple(
                (index, None if isinstance(plan, ShardedDBPlan) else plan)
                for index, plan in arts
            )
        return arts

    # ------------------------------------------------------------------ #
    def _exec_term_many(self, grp, window, index, plan, vb, g, aggs):
        """Serving traffic across the mesh: sharded plans ride the batched
        values axis of the shard-local fn — one launch for the whole
        [B, n] bucket instead of one executable replay per row."""
        if isinstance(plan, ShardedDBPlan):
            outs = query_sharded_many(plan, vb, tuple(aggs))
            return {a: np.asarray(o) for a, o in zip(aggs, outs)}
        return super()._exec_term_many(grp, window, index, plan, vb, g, aggs)
