"""Distribution layer: per-family sharding rules, collective helpers, and
the sharded window-analytics streaming runtime (:mod:`.window_runtime`)."""

from repro.distributed.sharding_rules import (  # noqa: F401
    lm_param_specs,
    lm_batch_specs,
    moe_param_specs,
    gnn_specs,
    recsys_specs,
    opt_state_specs,
)
from repro.distributed.window_runtime import (  # noqa: F401
    ShardedDBPlan,
    ShardedSession,
    ShardedStreamState,
    build_sharded_plan,
    patch_sharded_plan,
    query_sharded_multi,
)
