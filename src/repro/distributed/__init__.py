"""Distribution layer: per-family sharding rules + collective helpers."""

from repro.distributed.sharding_rules import (  # noqa: F401
    lm_param_specs,
    lm_batch_specs,
    moe_param_specs,
    gnn_specs,
    recsys_specs,
    opt_state_specs,
)
