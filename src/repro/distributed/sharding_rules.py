"""PartitionSpec rules per architecture family (DESIGN.md §5).

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  ``dp_axes`` below is ``("data",)`` or ``("pod", "data")``.

LM (dense & MoE), FSDP×TP posture:

* 2-D parameter sharding: the *fsdp* axis (= dp axes) shards the d_model
  (rows) dimension of every matmul weight, the *model* axis shards the
  head/ff (cols) dimension — params and optimizer state are fully sharded
  over the entire mesh (grok-1 f32 master + bf16 moments fit 256 chips).
* activations: batch over dp axes, heads/ff over model.
* vocab sharded over model for embed/unembed (logits psum via GSPMD).

GNN: edges over dp axes (segment partials psum'd), features over model when
wide, node state replicated (full-batch) or batch-sharded (sampled).

RecSys: embedding tables row-sharded over model (mod-hash), batch over dp.

All rules return pytrees of PartitionSpec matching the param pytrees.
"""

from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P


def _fsdp(dp_axes: Tuple[str, ...]):
    return dp_axes if len(dp_axes) > 1 else dp_axes[0]


def lm_param_specs(cfg, dp_axes: Tuple[str, ...] = ("data",), fsdp: bool = True):
    """Spec tree matching transformer.init / moe.init param trees."""
    f = _fsdp(dp_axes) if fsdp else None
    layer = {
        "ln1": P(None),
        "ln2": P(None),
        "wq": P(None, f, "model"),
        "wk": P(None, f, "model"),
        "wv": P(None, f, "model"),
        "wo": P(None, "model", f),
        "w_gate": P(None, f, "model"),
        "w_up": P(None, f, "model"),
        "w_down": P(None, "model", f),
    }
    if getattr(cfg, "qk_norm", False):
        layer["q_norm"] = P(None)
        layer["k_norm"] = P(None)
    specs = {
        "embed": P("model", f),
        "layers": layer,
        "ln_f": P(None),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = P(f, "model")
    return specs


def moe_param_specs(cfg, dp_axes: Tuple[str, ...] = ("data",), fsdp: bool = True,
                    expert_parallel: bool = False):
    f = _fsdp(dp_axes) if fsdp else None
    base = lm_param_specs(cfg, dp_axes, fsdp)
    layer = dict(base["layers"])
    for k in ("w_gate", "w_up", "w_down"):
        layer.pop(k, None)
    if expert_parallel:
        # experts over model axis (requires n_experts_padded % model == 0)
        layer.update(
            router=P(None, f, None),
            we_gate=P(None, "model", f, None),
            we_up=P(None, "model", f, None),
            we_down=P(None, "model", None, f),
        )
    else:
        # TP inside each expert's ffn hidden dim
        layer.update(
            router=P(None, f, None),
            we_gate=P(None, None, f, "model"),
            we_up=P(None, None, f, "model"),
            we_down=P(None, None, "model", f),
        )
    if cfg.n_shared_experts:
        layer.update(
            ws_gate=P(None, f, "model"),
            ws_up=P(None, f, "model"),
            ws_down=P(None, "model", f),
        )
    base["layers"] = layer
    return base


def lm_batch_specs(dp_axes: Tuple[str, ...] = ("data",)):
    d = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return {"tokens": P(d, None), "labels": P(d, None)}


def kv_cache_specs(dp_axes: Tuple[str, ...] = ("data",), seq_axis: str = "model"):
    """KV cache [L, B, Hkv, S, D]: batch over dp, sequence over model
    (flash-decode combines softmax stats over the model axis)."""
    d = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return {"k": P(None, d, None, seq_axis, None), "v": P(None, d, None, seq_axis, None)}


def gnn_specs(dp_axes: Tuple[str, ...] = ("data",)):
    d = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return {
        "edges": P(d),
        "nodes": P(None),  # replicated node state (full-batch)
        "node_batch": P(d),  # sampled-minibatch node sharding
    }


def recsys_specs(dp_axes: Tuple[str, ...] = ("data",)):
    d = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return {
        "emb": P("model", None),  # row-sharded tables
        "w1": P("model"),
        "bias": P(),
        "batch": P(d, None),
    }


def opt_state_specs(param_specs, opt_state):
    """Optimizer-state spec tree: moments shard exactly like their param
    (FSDP of the optimizer state for free); Adafactor row/col factors drop
    the reduced axis from the param spec; scalars replicate."""
    from repro.optim.optimizers import AdafactorState, AdamWState, SGDState

    if isinstance(opt_state, AdamWState):
        return AdamWState(step=P(), mu=param_specs, nu=param_specs)
    if isinstance(opt_state, SGDState):
        return SGDState(step=P(), momentum=param_specs)
    if isinstance(opt_state, AdafactorState):
        def drop(spec, which):
            t = tuple(spec)
            if len(t) < 2:
                return P()
            return P(*(t[:-1] if which == "row" else t[:-2] + t[-1:]))

        row = jax.tree_util.tree_map(lambda s: drop(s, "row"), param_specs,
                                     is_leaf=lambda x: isinstance(x, P))
        col = jax.tree_util.tree_map(lambda s: drop(s, "col"), param_specs,
                                     is_leaf=lambda x: isinstance(x, P))
        full = jax.tree_util.tree_map(lambda s: P(), param_specs,
                                      is_leaf=lambda x: isinstance(x, P))
        return AdafactorState(step=P(), row=row, col=col, full=full)
    raise TypeError(type(opt_state))
