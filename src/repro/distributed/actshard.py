"""Activation sharding constraints (GSPMD hints).

Reshape-heavy spots (attention head folding, MoE dispatch) can break GSPMD
propagation and silently replicate multi-GiB activations.  Models accept an
optional ``acts`` dict of named PartitionSpecs and call :func:`constrain`
at the few places that anchor the layout:

* ``res``    — the residual stream [B, S, D].  The production rule is
  *sequence parallelism*: P(dp, "model", None) — S divides the model axis
  for every assigned shape, unlike head counts (minitron has 24 q heads on
  a 16-wide axis), so this is the universally valid TP anchoring.
* ``logits`` — [B, S_or_1, V]: P(dp, None, "model") (vocab-sharded).
* ``kv``     — cache [L, B, H, S, D]: P(None, dp, None, "model", None).

``constrain(x, acts, name)`` is a no-op when acts is None or the name is
absent — smoke tests and single-device runs never see a mesh requirement.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec as P  # noqa: F401  (re-export)


def constrain(x, acts: Optional[Dict], name: str):
    if acts is None:
        return x
    spec = acts.get(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def lm_train_acts(dp_axes, mesh=None) -> Dict:
    d = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    acts = {
        "res": P(d, "model", None),
        "logits": P(d, None, "model"),  # vocab-sharded; lse psums over model
        "loss_hidden": P(d, None, None),  # gathered over model for the head
        "loss_logits": P(d, None, "model"),  # per-chunk logits, vocab-sharded
    }
    if mesh is not None:
        acts["moe_shard"] = (mesh, tuple(dp_axes), "model")
    return acts


def lm_prefill_acts(dp_axes, mesh=None) -> Dict:
    d = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    acts = {
        "res": P(d, "model", None),
        "logits": P(d, "model"),  # [B, V] last-token logits
    }
    if mesh is not None:
        acts["moe_shard"] = (mesh, tuple(dp_axes), "model")
    return acts


def lm_decode_acts(dp_axes, mesh=None) -> Dict:
    d = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    acts = {
        "res": P(d, None, None),  # [B, 1, D]
        "logits": P(d, "model"),
    }
    if mesh is not None:
        acts["moe_shard"] = (mesh, tuple(dp_axes), "model")
    return acts
