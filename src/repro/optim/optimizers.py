"""Minimal-but-production optimizer stack (optax-style pure transforms).

Distributed-training posture:

* **AdamW with bf16 moments** (`moment_dtype=jnp.bfloat16`) — halves the
  optimizer-state HBM footprint, the difference between fitting and OOMing
  grok-1-314b on a 256-chip pod (DESIGN.md §5).  Moments are upcast for the
  update math, so the trajectory error is bounded by bf16 rounding of the
  *state*, not of the *update*.
* **Adafactor** — sub-linear memory (row/col factors) for the largest archs.
* Global-norm clipping fused into the update (one extra psum under pjit).

All transforms are pure pytree->pytree functions: they shard the same way
params shard, so FSDP sharding of the optimizer state is just "reuse the
param PartitionSpec".
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any  # pytree like params (moment_dtype)
    nu: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw(
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    moment_dtype=jnp.bfloat16,
    clip_norm: Optional[float] = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def update(grads, state, params):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = _global_norm(grads)
        step = state.step + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mhat = m32 / c1
            vhat = v32 / c2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (
                (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype),
                m32.astype(moment_dtype),
                v32.astype(moment_dtype),
            )

        out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), gnorm

    return Optimizer(init=init, update=update)


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    row: Any
    col: Any
    full: Any  # for <2D params


def adafactor(
    lr: Callable | float = 1e-3,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_norm: Optional[float] = 1.0,
) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern) — O(rows+cols)
    state for matrices, the memory floor for 314B-param training."""
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        def rowcol(p):
            if p.ndim >= 2:
                return (
                    jnp.zeros(p.shape[:-1], jnp.float32),
                    jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                    jnp.zeros((1,), jnp.float32),
                )
            return (jnp.zeros((1,), jnp.float32),) * 2 + (jnp.zeros(p.shape, jnp.float32),)

        trip = jax.tree_util.tree_map(rowcol, params)
        pick = lambda i: jax.tree_util.tree_map(
            lambda t: t[i], trip, is_leaf=lambda x: isinstance(x, tuple)
        )
        return AdafactorState(jnp.zeros((), jnp.int32), pick(0), pick(1), pick(2))

    def update(grads, state, params):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = _global_norm(grads)
        step = state.step + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, r, c, f, p):
            g32 = g.astype(jnp.float32)
            if p.ndim >= 2:
                r2 = beta * r + (1 - beta) * jnp.mean(g32 * g32, axis=-1)
                c2 = beta * c + (1 - beta) * jnp.mean(g32 * g32, axis=-2)
                rmean = jnp.mean(r2, axis=-1, keepdims=True)
                v = (r2[..., None] * c2[..., None, :]) / jnp.maximum(rmean[..., None], eps)
                delta = g32 / jnp.maximum(jnp.sqrt(v), eps)
                return ((p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), r2, c2, f)
            f2 = beta * f + (1 - beta) * g32 * g32
            delta = g32 / jnp.maximum(jnp.sqrt(f2), eps)
            return ((p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), r, c, f2)

        out = jax.tree_util.tree_map(upd, grads, state.row, state.col, state.full, params)
        pick = lambda i: jax.tree_util.tree_map(
            lambda o: o[i], out, is_leaf=lambda x: isinstance(x, tuple)
        )
        return pick(0), AdafactorState(step, pick(1), pick(2), pick(3)), gnorm

    return Optimizer(init=init, update=update)


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: Any


def sgd(lr: Callable | float = 1e-2, momentum: float = 0.9,
        clip_norm: Optional[float] = None) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        return SGDState(
            jnp.zeros((), jnp.int32),
            jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        )

    def update(grads, state, params):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = _global_norm(grads)
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m, p):
            m2 = momentum * m + g.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr_t * m2).astype(p.dtype), m2)

        out = jax.tree_util.tree_map(upd, grads, state.momentum, params)
        pick = lambda i: jax.tree_util.tree_map(
            lambda o: o[i], out, is_leaf=lambda x: isinstance(x, tuple)
        )
        return pick(0), SGDState(step, pick(1)), gnorm

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)
