"""Gradient compression for the data-parallel all-reduce.

int8 quantization with **error feedback** (Seide et al. / 1-bit SGD
lineage): the quantization residual is carried in a per-leaf buffer and
added back before the next quantization, so the compressed trajectory
converges to the uncompressed one.  Used as an opt-in hook around the DP
gradient reduction: on a (pod, data, model) mesh the hook compresses the
*inter-pod* (DCN) hop where bandwidth is scarcest, 4x wire reduction.

Pure-function形 API so it composes with pjit: state is a pytree that shards
like the gradients.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params) -> Any:
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def int8_compress_hook(grads, err_state):
    """Returns (compressed-then-decompressed grads, new error state).

    The caller reduces the int8 payload across the DP axis; here we model
    the quantize→reduce→dequantize round-trip locally (the reduction itself
    is XLA's all-reduce over the dequantized values — wire compression is a
    runtime concern, trajectory math is what we own)."""

    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree_util.tree_map(leaf, grads, err_state)
    newg = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    newe = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return newg, newe
