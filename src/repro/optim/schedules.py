"""LR schedules (pure step -> lr functions)."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(peak: float, warmup_steps: int):
    def fn(step):
        s = step.astype(jnp.float32)
        return peak * jnp.minimum(1.0, s / max(warmup_steps, 1))

    return fn


def cosine_schedule(peak: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak * jnp.minimum(1.0, s / max(warmup_steps, 1))
        t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup_steps, warm, peak * cos)

    return fn
