"""Optimizers, schedules, gradient transformations."""

from repro.optim.optimizers import adamw, adafactor, sgd, apply_updates  # noqa: F401
from repro.optim.schedules import cosine_schedule, linear_warmup  # noqa: F401
from repro.optim.grad_compress import int8_compress_hook  # noqa: F401
