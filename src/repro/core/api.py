"""Unified window-analytics API: declarative specs, engine registry, Session.

The paper's GWQ abstraction (Definition 3) is one algebraic object —
``GWQ(G, W, Σ, A)`` — and this module gives it one API surface:

* :class:`QuerySpec` — a declarative value object naming (W, Σ, A).  The
  window may be any :class:`~repro.core.windows.WindowExpr` — the two
  paper leaves (:class:`~repro.core.windows.KHopWindow` /
  :class:`~repro.core.windows.TopologicalWindow`, or shorthand
  ``("khop", 2)`` / ``"topological"``) or a composite expression
  (``Union`` / ``Intersect`` / ``Diff`` / ``Filter`` over direction-aware
  leaves).  Specs canonicalize their window, so algebraically equal
  queries (``Union(A, B)`` vs ``Union(B, A)``) hit one cached plan.

* **Window lowering** — two paths, chosen per (expression, monoid set) by
  the planner (:func:`plan_window_program`): the *generic* path evaluates
  the expression to per-vertex member sets (packed-bitset combinators) and
  feeds the unchanged DBIndex builder/plan pipeline — dense-block sharing,
  tile plans, patching and sharding all apply to any window sets; the
  *algebraic* fast path skips materialization where the algebra allows —
  idempotent monoids evaluate a ``Union`` as ``combine(result(A),
  result(B))`` over the children's existing materializations, and
  sum-monoid channels ride inclusion–exclusion (``Σ(A∪B) = Σ(A) + Σ(B) −
  Σ(A∩B)``) with only the (smaller) intersection materialized.
* :class:`EngineRegistry` — every backend declares an
  :class:`EngineCapability` (window kinds, aggregates, device / sharded /
  incremental flags) and the planner selects by capability; an
  :class:`UnsupportedQueryError` lists what *is* available when nothing
  matches.  This replaces the if/elif engine chain that used to live in
  :mod:`repro.core.query`.
* :func:`compile_queries` — dedups windows across specs, groups by
  (window, attr, engine), and fuses all aggregates sharing a window into
  one multi-channel plan (Cao et al.'s cross-window-function sharing,
  applied to graph windows: k aggregates collapse to one gather feeding k
  stacked monoid segment-reduces).
* :class:`Session` — owns graph + indices + compiled device plans, routes
  :class:`~repro.core.updates.UpdateBatch` streams through the incremental
  maintenance path (compiled artifacts survive updates via plan patching),
  and serves ``run`` / ``run_many`` traffic.
* :class:`SessionView` — an atomic read snapshot pinned at one version.
  Graph, indices and plans are immutable (updates build replacements and
  swap references), so :meth:`Session.snapshot` is one tuple capture and a
  reader holding a view never observes a half-patched plan.  The serving
  layer (:mod:`repro.serve.window_service`) builds its versioned-read /
  ``flip()`` MVCC on exactly this property, and an attached affected-owner
  result cache makes ``run`` / point reads cache-aware.
"""

from __future__ import annotations

import dataclasses
import os
import sys
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs as _obs
from repro.core.aggregates import (
    AGGREGATES,
    ALL_REGISTERED,
    CHANNEL_AGG,
    register_aggregate,  # noqa: F401  (re-export: the open-registry API)
)
from repro.core.graph import Graph
from repro.core.windows import (
    Intersect,
    KHopWindow,
    TopologicalWindow,
    Union,
    WindowExpr,
    canonicalize,
    filter_attrs,
    window_kind_of,
)

#: live view over the open aggregate registry — capabilities declared with
#: it serve aggregates registered *after* the engine was
ALL_AGGREGATES = ALL_REGISTERED


# ---------------------------------------------------------------------- #
#  Declarative specs
# ---------------------------------------------------------------------- #
def as_window(spec):
    """Normalize a window spec — a :class:`WindowExpr` (canonicalized),
    ``"topological"`` or ``("khop", k)`` shorthand."""
    if isinstance(spec, WindowExpr):
        return canonicalize(spec)
    if spec == "topological":
        return TopologicalWindow()
    if isinstance(spec, (tuple, list)) and len(spec) == 2 and spec[0] == "khop":
        return KHopWindow(int(spec[1]))
    raise TypeError(f"not a window spec: {spec!r}")


def window_kind(window) -> str:
    """Capability kind of a window: the two paper leaves keep their names;
    everything else — combinators, filters, direction-variant k-hop leaves
    — is ``"composite"`` and is served by the engines whose capability row
    declares it (the generic materialized lowering or, where the algebra
    allows, the fast path)."""
    return window_kind_of(window)


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """One graph window function (W, Σ, A) plus an optional engine hint.

    ``engine=None`` lets the planner pick by capability; naming an engine
    pins it (and fails loudly if the capability doesn't cover the query).
    """

    window: object
    agg: str = "sum"
    attr: str = "val"
    engine: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "window", as_window(self.window))
        if self.agg not in AGGREGATES:
            raise ValueError(f"unknown aggregate {self.agg!r} "
                             f"(have {sorted(AGGREGATES)})")


class UnsupportedQueryError(ValueError):
    """No registered engine capability covers the requested query."""


# ---------------------------------------------------------------------- #
#  Capability-based engine registry
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class EngineCapability:
    """What one backend can serve.  Selection is purely declarative."""

    name: str
    windows: Tuple[str, ...]  # of {"khop", "topological"}
    aggregates: frozenset
    device: bool = False  # runs on the JAX data plane
    sharded: bool = False  # needs a mesh / shard_map
    incremental: bool = False  # index survives UpdateBatches
    priority: int = 0  # higher wins among matches

    def covers(self, window, aggs: Sequence[str]) -> bool:
        return window_kind(window) in self.windows and set(aggs) <= self.aggregates


def _cap_row(c: "EngineCapability") -> str:
    """One self-explaining capability-table row (window kinds, aggregates,
    and the device/sharded/incremental flags) for planner error messages."""
    return (
        f"{c.name}: windows={c.windows}, aggs={sorted(c.aggregates)}, "
        f"device={c.device}, sharded={c.sharded}, incremental={c.incremental}"
    )


class EngineRegistry:
    """Backends register (capability, runner); the planner selects by need.

    A runner evaluates *all* aggregates of one window in a single call —
    ``runner(g, window, values, aggs, index=None, plan=None, **opts) ->
    {agg: ndarray}`` — so fused multi-channel execution is the interface,
    not an afterthought; host backends simply loop.
    """

    def __init__(self):
        self._caps: Dict[str, EngineCapability] = {}
        self._runners: Dict[str, object] = {}

    def register(self, cap: EngineCapability, runner) -> None:
        self._caps[cap.name] = cap
        self._runners[cap.name] = runner

    def capabilities(self) -> Tuple[EngineCapability, ...]:
        return tuple(self._caps.values())

    def capability(self, name: str) -> EngineCapability:
        if name not in self._caps:
            raise UnsupportedQueryError(
                f"unknown engine {name!r}; registered: {sorted(self._caps)}"
            )
        return self._caps[name]

    def select(
        self,
        window,
        aggs: Sequence[str],
        *,
        engine: Optional[str] = None,
        device: Optional[bool] = None,
        sharded: bool = False,
        incremental: Optional[bool] = None,
    ) -> str:
        """Pick an engine by capability; raise with the full table if none fit."""
        if engine is not None:
            cap = self.capability(engine)
            if not cap.covers(window, aggs):
                raise UnsupportedQueryError(
                    f"engine {engine!r} does not cover "
                    f"({window_kind(window)}, {sorted(set(aggs))}): it serves "
                    f"{_cap_row(cap)}"
                )
            return engine
        matches = [
            c for c in self._caps.values()
            if c.covers(window, aggs)
            and (device is None or c.device == device)
            and c.sharded == sharded
            and (incremental is None or c.incremental == incremental)
        ]
        if not matches:
            table = "; ".join(_cap_row(c) for c in self._caps.values())
            raise UnsupportedQueryError(
                f"no engine serves ({window_kind(window)}, {sorted(set(aggs))}, "
                f"device={device}, sharded={sharded}, "
                f"incremental={incremental}) — registered: {table}"
            )
        return max(matches, key=lambda c: c.priority).name

    def run(self, name: str, g: Graph, window, values, aggs: Sequence[str],
            index=None, plan=None, **opts) -> Dict[str, np.ndarray]:
        cap = self.capability(name)
        if not cap.covers(window, aggs):
            raise UnsupportedQueryError(
                f"engine {name!r} does not cover "
                f"({window_kind(window)}, {sorted(set(aggs))}): it serves "
                f"{_cap_row(cap)}"
            )
        unknown = set(opts) - KNOWN_OPTS
        if unknown:  # typos must fail loudly, not silently use defaults
            raise TypeError(
                f"unknown engine option(s) {sorted(unknown)}; "
                f"known: {sorted(KNOWN_OPTS)}"
            )
        return self._runners[name](g, window, np.asarray(values), tuple(aggs),
                                   index=index, plan=plan, **opts)


# every option any runner understands; EngineRegistry.run rejects the rest
KNOWN_OPTS = frozenset({
    "limit",  # nonindex
    "method", "num_hashes", "cluster_hops", "bfs_batch", "pair_budget",
    "seed",  # build_dbindex
    "iterations", "chunk_size",  # build_eagr
    "tm", "ts", "headroom", "use_pallas", "interpret", "schedule",  # device
    "mesh", "axis",  # sharded
})


def _pick(opts: dict, *names) -> dict:
    return {k: opts[k] for k in names if k in opts}


# ---------------------------------------------------------------------- #
#  Batched fused executors (serving traffic)
# ---------------------------------------------------------------------- #
# jit(vmap(fused query)) per device engine, built lazily so the module
# stays JAX-light.  The scheduler in repro.serve.window_service pads every
# launch to a fixed [bucket, n] shape, so each executor compiles once and
# is reused for every flush (the recompile counter below asserts it).
# _VMANY_ENGINES is the single source of truth for which engines have a
# vmappable fused executor (sharded plans batch via query_sharded_many
# instead — see ShardedSession._exec_group_many).
_VMANY_ENGINES = ("jax", "jax-iindex")
_VMANY: Dict[str, object] = {}


def _get_vmany(engine: str):
    # the vmapped executors jit the CHANNEL cores only; finalizers run
    # eagerly on the [B, n] channel results (same contract as the unbatched
    # wrappers — inside a jit XLA may FMA-contract a registered finalizer
    # and re-round, which would make run_many bitwise-diverge from run)
    if engine not in _VMANY:
        import jax

        from repro.core import engine_jax as ej

        fn = {"jax": ej._query_dbindex_multi_channels,
              "jax-iindex": ej._query_iindex_multi_channels}[engine]
        _VMANY[engine] = jax.jit(
            lambda plan, vb, aggs, interpret: jax.vmap(
                lambda v: fn(plan, v, aggs, use_pallas=False,
                             interpret=interpret))(vb),
            static_argnames=("aggs", "interpret"),
        )
    return _VMANY[engine]


def run_many_cache_size() -> int:
    """Jit cache entries of the batched fused executors — the recompile
    counter behind the serving scheduler's fixed-bucket contract."""
    return sum(f._cache_size() for f in _VMANY.values())


def recompile_count() -> int:
    """Total jit cache entries across every fused executor in the process —
    the ONE recompile number the zero-retrace contract is asserted on.

    Sums the batched serving executors (:func:`run_many_cache_size`), the
    unbatched fused query wrappers (``query_dbindex_multi`` /
    ``query_iindex_multi``) and the sharded runtime's executor cache.
    Modules not imported yet contribute 0 (and are not imported here —
    probing must never pay a jax init)."""
    total = run_many_cache_size()
    ej = sys.modules.get("repro.core.engine_jax")
    if ej is not None:
        total += ej.query_dbindex_multi._cache_size()
        total += ej.query_iindex_multi._cache_size()
    wr = sys.modules.get("repro.distributed.window_runtime")
    if wr is not None:
        total += wr.query_cache_size()
    return total


def record_recompiles(obs=None) -> int:
    """Publish :func:`recompile_count` as the ``repro_recompiles`` gauge
    (in ``obs`` or the process default registry); returns the count."""
    reg = obs if obs is not None else _obs.get_registry()
    n = recompile_count()
    reg.gauge("repro_recompiles",
              "jit cache entries across all fused executors").set(n)
    return n


def _run_nonindex(g, window, values, aggs, index=None, plan=None, **opts):
    from repro.core.nonindex import query_pervertex

    kw = _pick(opts, "limit")
    return {a: query_pervertex(g, window, values, a, **kw) for a in aggs}


def _run_bitset(g, window, values, aggs, index=None, plan=None, **opts):
    from repro.core.nonindex import query_batched_bitset

    return {a: query_batched_bitset(g, window, values, a) for a in aggs}


def _build_dbindex(g, window, opts):
    from repro.core.dbindex import build_dbindex

    kw = _pick(opts, "method", "num_hashes", "cluster_hops", "bfs_batch",
               "pair_budget", "seed")
    if isinstance(window, TopologicalWindow):
        kw.setdefault("method", "mc")
    return build_dbindex(g, window, **kw)


def _run_dbindex(g, window, values, aggs, index=None, plan=None, **opts):
    index = index if index is not None else _build_dbindex(g, window, opts)
    return {a: index.query(values, a) for a in aggs}


def _run_iindex(g, window, values, aggs, index=None, plan=None, **opts):
    from repro.core.iindex import build_iindex

    index = index if index is not None else build_iindex(g)
    return {a: index.query(values, a) for a in aggs}


def _run_eagr(g, window, values, aggs, index=None, plan=None, **opts):
    from repro.core.eagr import build_eagr

    if index is None:
        index = build_eagr(g, window, **_pick(opts, "iterations", "chunk_size"))
    return {a: index.query(values, a) for a in aggs}


def _run_jax_dbindex(g, window, values, aggs, index=None, plan=None, **opts):
    from repro.core import engine_jax as ej

    if plan is None:
        index = index if index is not None else _build_dbindex(g, window, opts)
        plan = ej.plan_from_dbindex(index, **_pick(opts, "tm", "ts", "headroom"))
    outs = ej.query_dbindex_multi(plan, values, tuple(aggs),
                                  **_pick(opts, "use_pallas", "interpret"))
    return {a: np.asarray(o) for a, o in zip(aggs, outs)}


def _run_jax_iindex(g, window, values, aggs, index=None, plan=None, **opts):
    from repro.core import engine_jax as ej
    from repro.core.iindex import build_iindex

    if plan is None:
        index = index if index is not None else build_iindex(g)
        plan = ej.plan_from_iindex(index, **_pick(opts, "tm", "ts"))
    outs = ej.query_iindex_multi(
        plan, values, tuple(aggs),
        **_pick(opts, "schedule", "use_pallas", "interpret"),
    )
    return {a: np.asarray(o) for a, o in zip(aggs, outs)}


def _run_jax_sharded(g, window, values, aggs, index=None, plan=None, **opts):
    """Fused multi-aggregate query across a mesh.  ``plan`` may be a
    device-resident :class:`~repro.distributed.window_runtime.ShardedDBPlan`
    (the streaming Session path — zero per-call layout work) or a host
    :class:`~repro.core.engine_jax.DBIndexPlan` (one-shot: sharded lazily).
    """
    from repro.core import engine_jax as ej
    from repro.distributed import window_runtime as wr

    if isinstance(plan, wr.ShardedDBPlan):
        outs = wr.query_sharded_multi(plan, values, tuple(aggs))
        return {a: np.asarray(o) for a, o in zip(aggs, outs)}
    mesh = opts.get("mesh")
    if mesh is None:
        raise UnsupportedQueryError("engine 'jax-sharded' needs a mesh= opt")
    if plan is None:
        index = index if index is not None else _build_dbindex(g, window, opts)
        plan = ej.plan_from_dbindex(index, **_pick(opts, "tm", "ts"))
    axis = opts.get("axis", "data")
    outs = ej.query_dbindex_sharded_multi(plan, values, tuple(aggs), mesh,
                                          axis=axis)
    return {a: np.asarray(o) for a, o in zip(aggs, outs)}


def _default_registry() -> EngineRegistry:
    r = EngineRegistry()
    both = ("khop", "topological")
    # "composite" marks the engines that consume *materialized* window sets
    # (bitset algebra, DBIndex blocks and the device/sharded plans built
    # from them) — the generic WindowExpr lowering; per-vertex-BFS and
    # structure-specific backends (nonindex, eagr, iindex) stay leaf-only
    any_w = both + ("composite",)
    r.register(EngineCapability("nonindex", both, ALL_AGGREGATES, priority=0),
               _run_nonindex)
    r.register(EngineCapability("bitset", any_w, ALL_AGGREGATES, priority=10),
               _run_bitset)
    r.register(EngineCapability("eagr", both, ALL_AGGREGATES, priority=20),
               _run_eagr)
    r.register(EngineCapability("dbindex", any_w, ALL_AGGREGATES,
                                incremental=True, priority=30), _run_dbindex)
    r.register(EngineCapability("iindex", ("topological",), ALL_AGGREGATES,
                                incremental=True, priority=40), _run_iindex)
    r.register(EngineCapability("jax", any_w, ALL_AGGREGATES, device=True,
                                incremental=True, priority=50), _run_jax_dbindex)
    r.register(EngineCapability("jax-iindex", ("topological",), ALL_AGGREGATES,
                                device=True, incremental=True, priority=60),
               _run_jax_iindex)
    # the stacked-channel sharded executor serves every monoid aggregate
    # (SUM/COUNT/AVG ride one psum, MIN/MAX ride pmin/pmax) — the old
    # SUM-only row predated repro.distributed.window_runtime
    r.register(EngineCapability("jax-sharded", any_w, ALL_AGGREGATES,
                                device=True, sharded=True, incremental=True,
                                priority=70), _run_jax_sharded)
    return r


DEFAULT_REGISTRY = _default_registry()


# ---------------------------------------------------------------------- #
#  Algebraic fast-path planner (per (expr, monoid) lowering choice)
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class WindowProgram:
    """Algebraic evaluation plan for one composite window.

    ``terms`` are the canonical sub-expressions that get materialized
    (index + plan each); the composite's monoid channels are reassembled
    from the terms' channel results: sum-monoid channels as
    ``Σ sum_coefs[t] · term[t]`` (inclusion–exclusion), idempotent channels
    as ``combine(term[t] for t in idem_terms)``.  ``term_aggs`` is the
    closed set of canonical channel aggregates requested from every term
    (one fused multi-channel query per term).
    """

    terms: Tuple[object, ...]
    term_aggs: Tuple[str, ...]
    sum_coefs: Tuple[int, ...]
    idem_terms: Tuple[int, ...]


def _group_channels(aggs: Sequence[str]) -> set:
    chans = set()
    for name in aggs:
        a = AGGREGATES[name]
        chans |= set(zip((m.name for m in a.monoids), a.channel_sources))
    return chans


def plan_window_program(window, aggs: Sequence[str]):
    """Fast-path plan for (window, aggs), or None → generic materialization.

    The choice is per (expression shape, monoid set): a ``Union`` whose
    aggregates are all idempotent (min/max) evaluates as a pointwise
    combine over the children's materializations (any arity); once a
    sum-monoid channel is involved, the union rides pairwise
    inclusion–exclusion (``Σ(A∪B) = Σ(A) + Σ(B) − Σ(A∩B)``) — the
    intersection is the only extra materialization and is never larger
    than either child.  Wider unions with sum channels, and every other
    combinator, take the generic path (still correct — just materialized).
    """
    if not isinstance(window, Union):
        return None
    channels = _group_channels(aggs)
    if any(ch not in CHANNEL_AGG for ch in channels):
        return None  # a channel with no canonical per-term aggregate
    kids = window.exprs
    has_sum = any(m == "sum" for m, _ in channels)
    if has_sum:
        if len(kids) != 2:
            return None  # inclusion–exclusion kept pairwise (2^n terms)
        terms = kids + (canonicalize(Intersect(*kids)),)
        coefs = (1, 1, -1)
    else:
        terms = kids
        coefs = (1,) * len(kids)
    term_aggs = tuple(sorted({CHANNEL_AGG[ch] for ch in channels}))
    return WindowProgram(terms=terms, term_aggs=term_aggs, sum_coefs=coefs,
                         idem_terms=tuple(range(len(kids))))


def _combine_program(prog: WindowProgram, aggs: Sequence[str], term_outs):
    """Reassemble the composite's channels from per-term results and
    finalize.  Pure pointwise arithmetic (works on [n] vectors and [B, n]
    batches alike); exact — hence bit-identical to direct set evaluation —
    on integer-valued attributes, and dtype-preserving on the int paths
    (coefficients are ±1, so no float upcast sneaks in)."""
    outs, chan_cache = {}, {}
    for name in aggs:
        a = AGGREGATES[name]
        chans = []
        for m, src in zip(a.monoids, a.channel_sources):
            key = (m.name, src)
            if key not in chan_cache:
                ca = CHANNEL_AGG[key]
                if m.name == "sum":
                    acc = None
                    for coef, out in zip(prog.sum_coefs, term_outs):
                        v = np.asarray(out[ca])
                        v = v if coef == 1 else v * coef
                        acc = v if acc is None else acc + v
                else:
                    acc = np.asarray(term_outs[prog.idem_terms[0]][ca])
                    for t in prog.idem_terms[1:]:
                        acc = m.np_op(acc, np.asarray(term_outs[t][ca]))
                chan_cache[key] = acc
            chans.append(chan_cache[key])
        outs[name] = a.finalize_np(*chans)
    return outs


# ---------------------------------------------------------------------- #
#  Multi-query compiler
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class PlanGroup:
    """All aggregates that share one (window, attr, engine) — one fused plan."""

    window: object
    attr: str
    engine: str
    aggs: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class CompiledQueries:
    """Output of :func:`compile_queries`: fused groups + spec back-pointers."""

    specs: Tuple[QuerySpec, ...]
    groups: Tuple[PlanGroup, ...]
    spec_slots: Tuple[Tuple[int, int], ...]  # spec i -> (group, agg position)

    def results_for_specs(self, group_results: Sequence[Dict[str, np.ndarray]]):
        return [
            group_results[gi][self.groups[gi].aggs[ai]]
            for gi, ai in self.spec_slots
        ]


def compile_queries(
    specs: Sequence[QuerySpec],
    *,
    registry: EngineRegistry = None,
    device: Optional[bool] = None,
    sharded: bool = False,
) -> CompiledQueries:
    """Plan a batch of queries: dedup windows, select engines by capability,
    fuse aggregates sharing a (window, attr, engine) into one group."""
    registry = registry or DEFAULT_REGISTRY
    specs = tuple(
        s if isinstance(s, QuerySpec) else QuerySpec(*s) for s in specs
    )
    # first pass: resolve each spec's engine (explicit pin or union-capability
    # selection over every spec sharing the window — so sum+min on one window
    # land on an engine that can fuse both)
    union: Dict[Tuple[object, str], set] = {}
    for s in specs:
        if s.engine is None:
            union.setdefault((s.window, s.attr), set()).add(s.agg)
    chosen: Dict[Tuple[object, str], str] = {
        key: registry.select(key[0], sorted(aggs), device=device, sharded=sharded)
        for key, aggs in union.items()
    }
    # second pass: group by (window, attr, engine), dedup aggregates in order
    order: List[Tuple[object, str, str]] = []
    agg_lists: Dict[Tuple[object, str, str], List[str]] = {}
    slots: List[Tuple[int, int]] = []
    for s in specs:
        engine = s.engine or chosen[(s.window, s.attr)]
        if s.engine is not None:  # validate explicit pins eagerly
            registry.select(s.window, (s.agg,), engine=engine)
        key = (s.window, s.attr, engine)
        if key not in agg_lists:
            agg_lists[key] = []
            order.append(key)
        if s.agg not in agg_lists[key]:
            agg_lists[key].append(s.agg)
        slots.append((order.index(key), agg_lists[key].index(s.agg)))
    groups = tuple(
        PlanGroup(window=w, attr=attr, engine=e, aggs=tuple(agg_lists[(w, attr, e)]))
        for (w, attr, e) in order
    )
    return CompiledQueries(specs=specs, groups=groups, spec_slots=tuple(slots))


# ---------------------------------------------------------------------- #
#  Session: graph + indices + compiled plans under streamed updates
# ---------------------------------------------------------------------- #
_DBINDEX_ENGINES = {"dbindex", "jax", "jax-sharded"}
_IINDEX_ENGINES = {"iindex", "jax-iindex"}


def _kind_of(engine: str) -> Optional[str]:
    """Index kind behind an engine name, or None for stateless backends."""
    if engine in _DBINDEX_ENGINES:
        return "dbindex"
    if engine in _IINDEX_ENGINES:
        return "iindex"
    return None


class Session:
    """Stateful serving facade over compiled window queries.

    Builds one index (and, for device engines, one device plan) per distinct
    window — shared by every query group on that window — then keeps all of
    it fresh under :meth:`update` via the incremental maintenance path
    (batched index update + tile-group plan patching + staleness policy), so
    compiled fused plans survive a stream of ``UpdateBatch``es without
    recompilation while shapes stay stable.

    Passing ``mesh=`` constructs a
    :class:`~repro.distributed.window_runtime.ShardedSession` instead:
    query planning selects sharded capabilities, plans live as per-shard
    device shards, and streamed updates ship only changed tile groups to
    the shard owning them.
    """

    #: subclasses flip this to make compile_queries select sharded engines
    _sharded = False

    def __new__(cls, g=None, specs=None, **kw):
        if cls is Session and kw.get("mesh") is not None:
            from repro.distributed.window_runtime import ShardedSession

            return super().__new__(ShardedSession)
        return super().__new__(cls)

    def __init__(
        self,
        g: Graph,
        specs: Sequence[QuerySpec],
        *,
        registry: EngineRegistry = None,
        device: Optional[bool] = None,
        policy=None,
        method: str = "emc",
        use_pallas: bool = True,
        interpret: Optional[bool] = None,
        tm: int = 512,
        ts: int = 512,
        plan_headroom: float = 0.5,
        compact_garbage: Optional[float] = None,
        mesh=None,
        axis="data",
        use_device_bfs: Optional[bool] = None,
        obs=None,
        tracer=None,
    ):
        self.registry = registry or DEFAULT_REGISTRY
        self.obs = obs if obs is not None else _obs.get_registry()
        self.tracer = tracer if tracer is not None else _obs.get_tracer()
        self._m_updates = self.obs.counter(
            "repro_session_updates_total", "UpdateBatches applied")
        self._m_snapshots = self.obs.counter(
            "repro_snapshots_total", "SessionView captures")
        self.compiled = compile_queries(specs, registry=self.registry,
                                        device=device, sharded=self._sharded)
        self.graph = g
        self.mesh = mesh
        self._opts = dict(use_pallas=use_pallas, interpret=interpret,
                          tm=tm, ts=ts, method=method, mesh=mesh, axis=axis)
        self._state_cfg = dict(
            method=method, policy=policy, tm=tm, ts=ts, use_pallas=use_pallas,
            interpret=interpret, plan_headroom=plan_headroom,
            compact_garbage=compact_garbage, mesh=mesh, axis=axis,
            use_device_bfs=use_device_bfs,
        )
        self.updates_applied = 0
        #: monotonically increasing state version: bumped once per
        #: :meth:`update`.  Snapshots pin it; the serving layer's result
        #: cache is keyed by it.
        self.version = 0
        self._result_cache = None
        # per-group lowering programs: composite windows on stateful
        # dbindex-backed engines may decompose algebraically (their *terms*
        # get materialized instead of the composite itself)
        self._programs: Tuple[Optional[WindowProgram], ...] = tuple(
            plan_window_program(grp.window, grp.aggs)
            if (_kind_of(grp.engine) == "dbindex"
                and window_kind(grp.window) == "composite")
            else None
            for grp in self.compiled.groups
        )
        # one stateful engine per (materialized window, index kind) — shared
        # by every group (and every program term) on that key, so the
        # device/sharded flags are the OR over the sharing groups (a host
        # group must not strip the plan a device group compiled).  EAGR
        # indices are rebuilt lazily after updates (no incremental story).
        self._states: Dict[Tuple[object, str], object] = {}
        self._eagr: Dict[object, object] = {}
        self._eagr_dirty = False
        need_device: Dict[Tuple[object, str], bool] = {}
        need_shard: Dict[Tuple[object, str], bool] = {}
        for gi, grp in enumerate(self.compiled.groups):
            kind = _kind_of(grp.engine)
            if kind is None:
                continue
            cap = self.registry.capability(grp.engine)
            for term in self._group_terms(gi):
                key = (term, kind)
                need_device[key] = need_device.get(key, False) or cap.device
                need_shard[key] = need_shard.get(key, False) or cap.sharded
        for (window, kind), dev in need_device.items():
            self._states[(window, kind)] = self._make_state(
                window, kind, dev, need_shard[(window, kind)]
            )

    def _make_state(self, window, kind: str, device: bool, sharded: bool):
        """Build the per-(window, kind) streaming state.  The base Session
        always builds host/single-device engines; :class:`ShardedSession`
        overrides this to place sharded windows on the mesh.

        ``compact_garbage=None`` defers to the engine's own default — the
        single-host compaction re-lays pass 1 (a shape change), so it waits
        as long as a rebuild (0.5); the sharded compaction is in-place and
        shape-stable, so it fires earlier (0.25, below the default
        :class:`StalenessPolicy` ``max_garbage_ratio``)."""
        from repro.core.streaming import StreamingEngine

        cfg = self._state_cfg
        cg = cfg["compact_garbage"]
        return StreamingEngine(
            self.graph, window, index_kind=kind, method=cfg["method"],
            policy=cfg["policy"], device=device, tm=cfg["tm"], ts=cfg["ts"],
            use_pallas=cfg["use_pallas"], interpret=cfg["interpret"],
            plan_headroom=cfg["plan_headroom"],
            compact_garbage=0.5 if cg is None else cg,
            use_device_bfs=cfg["use_device_bfs"],
            obs=self.obs, tracer=self.tracer,
        )

    # ------------------------------------------------------------------ #
    def _group_terms(self, gi: int) -> Tuple[object, ...]:
        """Windows materialized for group ``gi``: the program's terms on
        the algebraic fast path, else the group window itself."""
        prog = self._programs[gi]
        return prog.terms if prog is not None else (
            self.compiled.groups[gi].window,)

    def _group_artifacts(self, gi: int) -> Tuple[Tuple[object, object], ...]:
        """Per-term (index, plan) pairs of group ``gi``."""
        grp = self.compiled.groups[gi]
        kind = _kind_of(grp.engine)
        out = []
        for term in self._group_terms(gi):
            state = self._states.get((term, kind)) if kind else None
            if state is not None:
                out.append((state.index, state.plan))
            elif grp.engine == "eagr":
                if self._eagr_dirty:
                    self._eagr.clear()
                    self._eagr_dirty = False
                if term not in self._eagr:
                    from repro.core.eagr import build_eagr

                    self._eagr[term] = build_eagr(self.graph, term)
                out.append((self._eagr[term], None))
            else:
                out.append((None, None))
        return tuple(out)

    def _values_for(self, grp: PlanGroup, values, graph=None):
        if values is None:
            return (self.graph if graph is None else graph).attrs[grp.attr]
        if isinstance(values, dict):
            return values[grp.attr]
        return values

    # ------------------------------------------------------------------ #
    #  Group executors — shared by Session.run/run_many and SessionView
    # ------------------------------------------------------------------ #
    def _exec_term(self, grp: PlanGroup, window, index, plan, values, g,
                   aggs):
        with self.tracer.span("query.term", cat="query",
                              engine=grp.engine, window=window.name()):
            return self.registry.run(
                grp.engine, g, window, values, aggs,
                index=index, plan=plan, **self._opts,
            )

    def _exec_term_many(self, grp: PlanGroup, window, index, plan, vb, g,
                        aggs):
        """One [B, n] batch through one materialized window.

        Device plans run the jitted vmapped fused executor (XLA lowering —
        batching a Pallas kernel is not supported on every backend, and the
        fused XLA path vmaps cleanly); host engines loop the batch.
        """
        with self.tracer.span("query.term", cat="query", engine=grp.engine,
                              window=window.name(), rows=len(vb)):
            if plan is not None and grp.engine in _VMANY_ENGINES:
                import jax.numpy as jnp

                from repro.core.aggregates import pack_channels

                aggs = tuple(aggs)
                chans = _get_vmany(grp.engine)(
                    plan, jnp.asarray(vb, jnp.float32), aggs,
                    self._opts["interpret"],
                )
                pack = pack_channels(aggs)
                return {
                    a: np.asarray(pack.finalize(i, chans, xp=jnp))
                    for i, a in enumerate(aggs)
                }
            rows = [
                self.registry.run(grp.engine, g, window, v, aggs,
                                  index=index, plan=plan, **self._opts)
                for v in vb
            ]
            return {a: np.stack([r[a] for r in rows]) for a in aggs}

    def _exec_group(self, gi: int, arts, values, graph=None):
        grp = self.compiled.groups[gi]
        g = self.graph if graph is None else graph
        vals = self._values_for(grp, values, graph=g)
        prog = self._programs[gi]
        if prog is None:
            index, plan = arts[0]
            return self._exec_term(grp, grp.window, index, plan, vals, g,
                                   grp.aggs)
        term_outs = [
            self._exec_term(grp, term, index, plan, vals, g, prog.term_aggs)
            for term, (index, plan) in zip(prog.terms, arts)
        ]
        return _combine_program(prog, grp.aggs, term_outs)

    def _exec_group_many(self, gi: int, arts, vb, graph=None):
        grp = self.compiled.groups[gi]
        g = self.graph if graph is None else graph
        prog = self._programs[gi]
        if prog is None:
            index, plan = arts[0]
            return self._exec_term_many(grp, grp.window, index, plan, vb, g,
                                        grp.aggs)
        term_outs = [
            self._exec_term_many(grp, term, index, plan, vb, g,
                                 prog.term_aggs)
            for term, (index, plan) in zip(prog.terms, arts)
        ]
        return _combine_program(prog, grp.aggs, term_outs)

    # ------------------------------------------------------------------ #
    #  Versioned snapshot reads + result cache hooks
    # ------------------------------------------------------------------ #
    def snapshot(self) -> "SessionView":
        """Pin the current version for reads.

        Graph, indices and plans are immutable — :meth:`update` builds
        replacements and swaps references — so capturing them here is an
        atomic point-in-time view: the session can patch version v+1 while
        the view keeps answering at v, and no reader ever sees a
        half-patched plan.
        """
        self._m_snapshots.inc()
        return SessionView(
            session=self,
            graph=self.graph,
            version=self.version,
            artifacts=tuple(self._group_artifacts(gi)
                            for gi in range(len(self.compiled.groups))),
        )

    def attach_cache(self, cache) -> None:
        """Attach an affected-owner result cache (duck-typed; see
        :class:`repro.serve.window_service.AffectedOwnerCache`): ``run``
        consults it for current-attribute reads, and every :meth:`update`
        feeds it the per-group affected-owner sets so it invalidates only
        the vertices whose windows actually changed.

        One session serves one cache: silently replacing an attached cache
        would freeze the old one behind the head (its reads version-
        mismatch forever), so a second distinct cache raises — front one
        Session with one caching service (or ``use_cache=False``)."""
        if self._result_cache is not None and self._result_cache is not cache:
            raise RuntimeError(
                "a result cache is already attached to this Session; "
                "detach it (session._result_cache = None) or construct the "
                "second WindowService with use_cache=False"
            )
        self._result_cache = cache
        cache.bind(self)

    def group_state_keys(self, gi: int) -> Tuple[str, ...]:
        """Report keys of the stateful engines behind group ``gi`` (the
        keys of :meth:`update` reports / :attr:`staleness`) — one per
        materialized term on the algebraic fast path, empty for groups
        with no incremental state (their cached results cannot be bounded
        by an affected set and must be dropped wholesale on update)."""
        grp = self.compiled.groups[gi]
        kind = _kind_of(grp.engine)
        if kind is None:
            return ()
        return tuple(
            f"{term.name()}/{kind}" for term in self._group_terms(gi)
            if (term, kind) in self._states
        )

    # ------------------------------------------------------------------ #
    def run(self, values=None) -> List[np.ndarray]:
        """Evaluate every compiled spec; returns results in spec order.

        ``values`` overrides the graph attribute(s): an array (applied to
        every group) or a dict keyed by attr name.  With an attached result
        cache and ``values=None``, group vectors come from / land in the
        cache (see :meth:`attach_cache`).
        """
        return self.snapshot().run(values)

    def run_many(self, values_batch) -> List[np.ndarray]:
        """Serving-style traffic: evaluate all specs for a [B, n] batch of
        attribute vectors in one vmapped launch per device group."""
        return self.snapshot().run_many(values_batch)

    # ------------------------------------------------------------------ #
    #  EXPLAIN / ANALYZE (repro.obs.explain / repro.obs.profile)
    # ------------------------------------------------------------------ #
    def explain(self, spec=None):
        """EXPLAIN: the compiled plan as a structured
        :class:`~repro.obs.explain.PlanReport` — engine resolution with
        rejected candidates, per-(expr, monoid set) lowering choice, plan
        anatomy and exact per-array device footprint — without executing
        anything.  ``spec`` optionally narrows to one group (an index, a
        :class:`QuerySpec`, or a window spec)."""
        from repro.obs.explain import explain_session

        return explain_session(self, spec)

    def analyze(self, spec=None, values=None):
        """ANALYZE: execute the selected groups once under a
        phase-profiled scope and return an
        :class:`~repro.obs.profile.AnalyzeReport` attributing wall time
        to named phases (gather, pass-1/pass-2 reduce, inherit, finalize,
        host combine).  Runs eagerly outside the tracked jitted
        executors, so it never perturbs the zero-recompile counters."""
        from repro.obs.profile import analyze_session

        return analyze_session(self, spec, values=values)

    def digest(self, include_results: bool = False) -> Dict:
        """Per-version content digest (crc32 over graph + plan arrays,
        optionally the result vectors) — the leader/follower self-check
        channel; see :func:`repro.obs.audit.session_digest`."""
        from repro.obs.audit import session_digest

        return session_digest(self, include_results=include_results)

    # ------------------------------------------------------------------ #
    def update(self, batch) -> Dict:
        """Stream one UpdateBatch through every stateful index + plan.

        The graph edit is applied once and shared by every engine (their
        index maintenance is per-window, the graph is not).  Bumps
        :attr:`version`; each report carries the new version and the
        engine's ``affected_owners`` array, and an attached result cache is
        invalidated for exactly those owners.

        Attribute-value edits (``batch.attr_edits``) skip index and plan
        maintenance entirely — both indices are structure-only — and
        invalidate the result cache through the DBIndex *reverse link map*:
        exactly the owners whose windows contain an edited vertex, instead
        of flushing whole result vectors.  The exception is a
        :class:`~repro.core.windows.Filter` predicate attribute, which
        changes window *membership*: the touched states rebuild (their
        streaming engines detect it) and invalidate wholesale."""
        from repro.core.updates import apply_batch, containing_owners

        with self.tracer.span("session.update", cat="update",
                              size=batch.size, version=self.version + 1):
            return self._update_inner(batch)

    def _update_inner(self, batch) -> Dict:
        from repro.core.updates import apply_batch, containing_owners

        g2 = apply_batch(self.graph, batch)
        reports = {}
        for (window, kind), eng in self._states.items():
            key = f"{window.name()}/{kind}"
            with self.tracer.span("maintain", cat="update", state=key):
                reports[key] = eng.apply(batch, graph=g2)
        self.graph = g2
        self._eagr_dirty = (
            bool(self._eagr) and batch.size > 0) or self._eagr_dirty
        self.updates_applied += 1
        self.version += 1
        self._m_updates.inc()
        for rep in reports.values():
            rep["version"] = self.version
        if self._result_cache is not None:
            with self.tracer.span("cache.invalidate", cat="update"):
                edited: Dict[str, list] = {}
                for e in batch.attr_edits:
                    edited.setdefault(e.name, []).append(e.vertices)
                owner_map = {}
                for gi, grp in enumerate(self.compiled.groups):
                    keys = self.group_state_keys(gi)
                    group_attr_touched = grp.attr in edited
                    if not keys:
                        # no incremental state to bound the blast radius:
                        # drop on any change that could affect the group,
                        # keep on a provably-unrelated attr-only batch
                        unrelated = (
                            batch.size == 0 and not group_attr_touched
                            and not (set(edited)
                                     & set(filter_attrs(grp.window))))
                        owner_map[gi] = (
                            np.empty(0, np.int32) if unrelated else None)
                        continue
                    parts = [reports[k]["affected_owners"] for k in keys]
                    if group_attr_touched:
                        verts = np.unique(np.concatenate(edited[grp.attr]))
                        kind = _kind_of(grp.engine)
                        for term in self._group_terms(gi):
                            state = self._states.get((term, kind))
                            if state is not None:
                                parts.append(containing_owners(
                                    state.index, g2, term, verts))
                    owner_map[gi] = np.unique(np.concatenate(parts)).astype(
                        np.int32) if parts else np.empty(0, np.int32)
                self._result_cache.on_update(self.version, owner_map)
        return reports

    # ------------------------------------------------------------------ #
    def replay(self, batches) -> int:
        """Replay an ordered batch stream through :meth:`update`.

        ``batches`` yields :class:`~repro.core.updates.UpdateBatch`es or
        ``(version, batch)`` pairs (the WAL record shape — versions are
        informational here; :attr:`version` advances once per batch either
        way, so a replay of the full log reproduces the live session's
        version numbering).  Returns the number of batches applied.  The
        zero-recompile contract holds across a replay exactly as it does
        across the live stream: same batches, same shapes, same plans.
        """
        applied = 0
        for item in batches:
            batch = item[1] if isinstance(item, tuple) else item
            self.update(batch)
            applied += 1
        return applied

    def save_checkpoint(self, directory) -> Tuple[int, str]:
        """Write a snapshot checkpoint of this session's graph + digest to
        ``directory`` (:mod:`repro.serve.checkpoint`); returns
        ``(version, path)``.  Pair with ``restore_from_wal(...,
        checkpoint=directory)`` for bounded-tail recovery."""
        from repro.serve.checkpoint import save_checkpoint

        return save_checkpoint(self, directory)

    @classmethod
    def from_checkpoint(cls, path, specs, **kw) -> "Session":
        """Rebuild a session from one checkpoint file (no WAL tail).

        The checkpoint's section CRCs and stamped ``graph_crc`` are
        verified on load; the restored session resumes version numbering
        at the checkpoint version.  Bit-identity holds because every
        engine state is a deterministic function of the graph — but the
        freshly built *plan bytes* may legitimately differ from the
        writer's incrementally patched ones, so follower digest checks
        against this session must skip the plan component
        (``check_plan_digest=False``)."""
        from repro.serve.checkpoint import load_checkpoint

        version, graph, _digest = load_checkpoint(path)
        session = cls(graph, specs, **kw)
        session.version = int(version)
        return session

    @classmethod
    def restore_from_wal(cls, g: Graph, specs, wal, *,
                         upto_version: Optional[int] = None,
                         checkpoint=None, **kw):
        """Crash recovery: rebuild a session by replaying a write-ahead log.

        ``g`` and ``specs`` must be the *base* graph and compiled specs the
        crashed session started from (the WAL records every batch applied
        since); ``wal`` is a log file path, a WAL segment directory, an
        open :class:`~repro.serve.wal.WriteAheadLog` /
        :class:`~repro.serve.wal.SegmentedWriteAheadLog`, or any iterable
        of ``(version, batch)`` pairs.  ``upto_version`` stops the replay
        early (point-in-time recovery).

        ``checkpoint`` names a checkpoint directory (or a single
        checkpoint file): recovery then starts from the newest usable
        checkpoint at or below ``upto_version`` and replays only the
        bounded WAL *tail* past it, instead of the whole log — ``g`` is
        ignored in that case (the checkpoint carries the graph).  When no
        usable checkpoint exists, recovery silently falls back to the
        full replay.  All other kwargs are forwarded to the constructor —
        they must match the crashed session's for bit-identical results.
        """
        session = None
        after_version = 0
        if checkpoint is not None:
            from repro.serve.checkpoint import latest_checkpoint

            ckpt_path = os.fspath(checkpoint)
            if os.path.isdir(ckpt_path):
                found = latest_checkpoint(ckpt_path,
                                          upto_version=upto_version)
                ckpt_path = found[1] if found else None
            if ckpt_path is not None:
                session = cls.from_checkpoint(ckpt_path, specs, **kw)
                after_version = session.version
        if hasattr(wal, "replay"):
            records = list(wal.replay())
        elif isinstance(wal, (str, os.PathLike)) and os.path.isdir(wal):
            from repro.serve.wal import read_segmented_records

            records = read_segmented_records(wal, after_version)
        elif isinstance(wal, (str, os.PathLike)):
            from repro.serve.wal import read_wal_records

            records = read_wal_records(wal)[0]
        else:
            records = list(wal)
        if session is None:
            session = cls(g, specs, **kw)
        for item in records:
            version, batch = item if isinstance(item, tuple) else (None, item)
            if version is not None and version <= after_version:
                continue  # below the checkpoint: already folded in
            if upto_version is not None and version is not None \
                    and version > upto_version:
                break
            session.update(batch)
        return session

    @property
    def staleness(self) -> Dict[str, Dict]:
        """Per-state sharing-loss telemetry (same keys as :meth:`update`
        reports) plus each engine's reorganize count."""
        return {
            f"{window.name()}/{kind}": {**eng.staleness,
                                        "reorg_count": eng.reorg_count}
            for (window, kind), eng in self._states.items()
        }


# ---------------------------------------------------------------------- #
#  SessionView: atomic, version-pinned read snapshot
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SessionView:
    """A point-in-time read view of a :class:`Session` pinned at one version.

    Holds the graph and every group's (index, plan) by reference; because
    all of them are immutable, reads through the view are snapshot-isolated:
    ``Session.update`` replaces the session's references for version v+1
    while this view keeps serving version v.  The serving layer
    (:class:`repro.serve.window_service.WindowService`) keeps one "active"
    view for readers and republishes on ``flip()``.

    Cache interplay: current-attribute reads (``values=None``) consult the
    session's attached result cache.  Cache reads and writes are gated on
    the view's version matching the cache's — a view pinned behind the
    write head simply bypasses the cache rather than polluting it.
    """

    session: Session
    graph: Graph
    version: int
    #: per group: per materialized term, an (index, plan) pair — generic
    #: groups hold one term, algebraic fast-path groups one per program term
    artifacts: Tuple[Tuple[Tuple[object, object], ...], ...]

    # ------------------------------------------------------------------ #
    def run_group(self, gi: int, values=None) -> Dict[str, np.ndarray]:
        """All aggregates of plan group ``gi`` (one fused launch per
        materialized term on device engines), cache-aware for
        current-attribute reads."""
        cache = self.session._result_cache
        if values is None and cache is not None:
            hit = cache.get_group(gi, self.version)
            if hit is not None:
                return hit
        with self.session.tracer.span("query.group", cat="query", group=gi,
                                      version=self.version):
            out = self.session._exec_group(gi, self.artifacts[gi], values,
                                           graph=self.graph)
        if values is None and cache is not None:
            cache.put_group(gi, self.version, out)
        return out

    def run_group_many(self, gi: int, values_batch) -> Dict[str, np.ndarray]:
        """[B, n] batch through plan group ``gi`` — one vmapped launch per
        materialized term on device engines (the scheduler's coalesced
        flush path)."""
        with self.session.tracer.span("query.group", cat="query", group=gi,
                                      version=self.version, batched=True):
            return self.session._exec_group_many(gi, self.artifacts[gi],
                                                 values_batch,
                                                 graph=self.graph)

    # ------------------------------------------------------------------ #
    def run(self, values=None) -> List[np.ndarray]:
        groups = range(len(self.session.compiled.groups))
        return self.session.compiled.results_for_specs(
            [self.run_group(gi, values) for gi in groups]
        )

    def run_many(self, values_batch) -> List[np.ndarray]:
        vb = np.asarray(values_batch)
        assert vb.ndim == 2, "values_batch must be [B, n]"
        groups = range(len(self.session.compiled.groups))
        return self.session.compiled.results_for_specs(
            [self.run_group_many(gi, vb) for gi in groups]
        )
