"""Device (JAX/TPU) query data plane for DBIndex and I-Index.

The host-built indices become static *plans* of device arrays:

* DBIndex: two chained tile plans — members→blocks, then links→owners —
  each one fused gather + Pallas segment-sum (DESIGN.md §2).
* I-Index: one tile plan for the window-difference partials plus the PID
  forest; the inheritance scan is either level-scheduled (``depth`` gathers)
  or pointer-doubled (``log2(depth)`` gathers, the §Perf variant).

``query_dbindex_multi`` / ``query_iindex_multi`` are the fused
multi-aggregate executors behind :mod:`repro.core.api`: one gather per
pass feeds every monoid channel (sum channels stack into a matrix reduce;
min/max ride dense ELL layouts or per-monoid inheritance), so k aggregates
over one window cost roughly one query instead of k.

``query_dbindex_sharded`` distributes the query under ``shard_map``:
pass 1 is sharded over *blocks*, the (small) block-partial vector ``T`` is
all-gathered over the data axis, and pass 2 is sharded over *owners* —
the collective footprint is ``|T|`` floats, independent of window sizes,
which is what makes the paper's sharing structure attractive on a pod.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dbindex import DBIndex
from repro.core.iindex import IIndex
from repro.kernels.segment_reduce.ops import (
    TilePlan,
    build_tile_plan,
    patch_tile_plan,
    segment_sum,
    segment_sum_gathered,
)


# ---------------------------------------------------------------------- #
#  DBIndex plan
# ---------------------------------------------------------------------- #
_ELL_SENTINEL = np.int32(np.iinfo(np.int32).max)  # jnp.take clips -> last row


@dataclasses.dataclass(frozen=True)
class DBIndexPlan:
    """Device plan.  ``block_capacity >= num_blocks`` pads the block-partial
    vector ``T`` so that streamed updates appending secondary blocks keep
    static shapes (capacity grows by powers of two → O(log) recompiles over
    a stream instead of one per batch).

    ``num_blocks`` is a pytree *child* (not aux data): it changes on every
    streamed batch, and jitted queries must not retrace for it — device code
    sizes everything by ``block_capacity`` instead.

    ``p1_ell`` / ``p2_ell`` are padded per-segment row layouts (ELL style)
    for the idempotent monoids: blocks and owner link lists have tiny
    bounded fan-in, so min/max evaluate as one dense gather + axis reduce
    instead of an XLA scatter.  min/max are order-insensitive, so the
    formulation is bit-exact against any other evaluation order.  Pad slots
    hold ``_ELL_SENTINEL``; ``jnp.take`` clips it to the last row of the
    value vector, which the query extends with the monoid identity."""

    n: int
    num_blocks: int
    block_capacity: int
    pass1: TilePlan  # members -> block partials
    pass2: TilePlan  # block partials -> owner windows
    block_sizes: jnp.ndarray  # f32 [block_capacity] (for count/avg)
    link_counts: jnp.ndarray  # f32 [n]
    p1_ell: Optional[jnp.ndarray] = None  # i32 [block_capacity, R1] member ids
    p2_ell: Optional[jnp.ndarray] = None  # i32 [n, R2] block ids

    def tree_flatten(self):
        return (
            (self.num_blocks, self.pass1, self.pass2, self.block_sizes,
             self.link_counts, self.p1_ell, self.p2_ell),
            (self.n, self.block_capacity),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        nb, p1, p2, bs, lc, e1, e2 = children
        return cls(aux[0], nb, aux[1], p1, p2, bs, lc, e1, e2)

    def array_nbytes(self) -> dict:
        """Exact per-array device bytes, keyed ``pass1.<name>`` /
        ``pass2.<name>`` / top-level array name.  The EXPLAIN footprint
        accounting (and ROADMAP direction 2's spill planning) reads this."""
        out = {}
        for prefix, tp in (("pass1", self.pass1), ("pass2", self.pass2)):
            for k, v in tp.array_nbytes().items():
                out[f"{prefix}.{k}"] = v
        out["block_sizes"] = int(self.block_sizes.nbytes)
        out["link_counts"] = int(self.link_counts.nbytes)
        if self.p1_ell is not None:
            out["p1_ell"] = int(self.p1_ell.nbytes)
        if self.p2_ell is not None:
            out["p2_ell"] = int(self.p2_ell.nbytes)
        return out

    def plan_nbytes(self) -> int:
        """Total device bytes held by this plan (sum of per-array sizes)."""
        return sum(self.array_nbytes().values())


jax.tree_util.register_pytree_node(
    DBIndexPlan, DBIndexPlan.tree_flatten, DBIndexPlan.tree_unflatten
)


def _block_sizes_padded(index: DBIndex, capacity: int) -> np.ndarray:
    sizes = np.zeros(capacity, np.float32)
    sizes[: index.num_blocks] = np.diff(index.block_offsets)
    return sizes


def _pow2(x: int) -> int:
    return 1 << (max(int(x), 1) - 1).bit_length()


def _ell_rows(offsets: np.ndarray, items: np.ndarray, num_rows: int,
              width: int) -> np.ndarray:
    """Padded per-segment item matrix [num_rows, width], sentinel-padded."""
    out = np.full((num_rows, width), _ELL_SENTINEL, np.int32)
    sizes = np.diff(offsets).astype(np.int64)
    row = np.repeat(np.arange(sizes.size, dtype=np.int64), sizes)
    pos = np.arange(items.size) - np.repeat(offsets[:-1], sizes)
    out[row, pos] = items
    return out


def _ell_from_index(index: DBIndex, cap: int):
    """(p1_ell, p2_ell) for the min/max fast path, or (None, None) when a
    degenerate fan-in distribution would blow the padded layout up (the
    scatter fallback stays available — min/max are exact either way)."""
    max_block = int(np.diff(index.block_offsets).max()) if index.num_blocks else 1
    max_links = int(np.diff(index.link_owner_offsets).max()) if index.n else 1
    r1, r2 = _pow2(max_block), _pow2(max_links)
    # the dense reduce beats the XLA scatter until padding inflates the row
    # count by roughly an order of magnitude (scatter ~50-100ns/row vs ~1-2
    # ns/element dense); skewed fan-in distributions (one huge block, one
    # hub owner linking thousands of blocks) fall back to the scatter path
    if (cap * r1 > max(16 * index.block_members.size, 1 << 16)
            or index.n * r2 > max(16 * index.link_block.size, 1 << 16)):
        return None, None
    p1 = _ell_rows(index.block_offsets, index.block_members, cap, r1)
    p2 = _ell_rows(index.link_owner_offsets, index.link_block, index.n, r2)
    return jnp.asarray(p1), jnp.asarray(p2)


def plan_from_dbindex(
    index: DBIndex, tm: int = 512, ts: int = 512,
    block_capacity: Optional[int] = None, headroom: float = 0.0,
) -> DBIndexPlan:
    cap = max(int(block_capacity or 0), index.num_blocks, 1)
    floors = None
    if headroom > 0:
        # pre-pad the block id space to the next power of two past the
        # headroom so streamed secondary-block appends don't change the
        # capacity (and hence the static shapes) on the first few batches
        cap = _pow2(int(cap * (1 + headroom)))
        # appended secondary blocks take consecutive ids just past
        # num_blocks, so the growth lands in a handful of specific tile
        # groups — floor those at the expected rows of a full group of
        # average-sized blocks instead of spreading slack uniformly
        n_groups = max(1, -(-cap // ts))
        avg_block = index.block_members.size / max(index.num_blocks, 1)
        boost = -(-int(ts * avg_block * (1 + headroom)) // tm)
        floors = np.ones(n_groups, np.int64)
        g0 = index.num_blocks // ts
        floors[g0: g0 + 4] = max(boost, 1)
    member_block = np.asarray(index.member_block_ids, np.int64)
    pass1 = build_tile_plan(index.block_members, member_block, cap, tm, ts,
                            headroom=headroom, group_min_tiles=floors)
    owner_ids = np.asarray(index.link_owner_ids, np.int64)
    pass2 = build_tile_plan(index.link_block, owner_ids, index.n, tm, ts,
                            headroom=headroom)
    links = np.diff(index.link_owner_offsets).astype(np.float32)
    p1_ell, p2_ell = _ell_from_index(index, cap)
    return DBIndexPlan(
        n=index.n,
        num_blocks=index.num_blocks,
        block_capacity=cap,
        pass1=pass1,
        pass2=pass2,
        block_sizes=jnp.asarray(_block_sizes_padded(index, cap)),
        link_counts=jnp.asarray(links),
        p1_ell=p1_ell,
        p2_ell=p2_ell,
    )


def patch_plan_dbindex(
    plan: DBIndexPlan, index: DBIndex, changed_owners: np.ndarray,
    compact_garbage: float = 0.5, headroom: float = 0.0,
) -> DBIndexPlan:
    """Incremental plan maintenance after ``update_dbindex_batch``.

    The merged index keeps the primary block prefix intact and appends
    secondary blocks, so pass 1 only re-lays-out the tile groups holding
    appended block ids; pass 2 re-lays-out the groups containing
    ``changed_owners`` (the batch's affected owner set).  Everything else
    is spliced from the live plan.

    Delete-heavy streams accumulate *garbage blocks* — blocks no owner
    links to any more, whose member rows still occupy pass-1 tiles.  When
    the garbage fraction crosses ``compact_garbage``, pass 1 is re-laid-out
    without the garbage blocks' member rows (block ids are untouched, so
    pass 2 and the jitted query are unaffected beyond the shape change).

    When the updater fell back to a full rebuild (``last_full_rebuild``
    stat), the appended-prefix invariant does not hold and splicing would
    silently reuse stale tiles — build a fresh plan instead.
    """
    cap = plan.block_capacity
    if index.num_blocks > cap:
        cap = _pow2(index.num_blocks)
    if index.stats.get("last_full_rebuild"):
        return plan_from_dbindex(index, plan.pass1.tm, plan.pass1.ts,
                                 block_capacity=cap, headroom=headroom)
    member_block = np.asarray(index.member_block_ids, np.int64)
    linked = index.linked_blocks_mask()
    # require actual garbage, not just fraction >= threshold: an empty or
    # garbage-free index with compact_garbage == 0.0 would otherwise take
    # the full pass-1 re-layout every batch (a spurious compaction that
    # drops nothing — the delete-everything / zero-block degenerate cases)
    has_garbage = index.num_blocks > 0 and bool(np.any(~linked))
    if has_garbage and index.garbage_block_fraction(linked) >= compact_garbage:
        keep = linked[member_block]
        pass1 = build_tile_plan(
            index.block_members[keep], member_block[keep], cap,
            plan.pass1.tm, plan.pass1.ts, headroom=headroom,
        )
    else:
        new_blocks = np.arange(plan.num_blocks, index.num_blocks, dtype=np.int64)
        pass1 = patch_tile_plan(
            plan.pass1,
            index.block_members,
            member_block,
            cap,
            new_blocks,
        )
    pass2 = patch_tile_plan(
        plan.pass2,
        index.link_block,
        np.asarray(index.link_owner_ids, np.int64),
        index.n,
        np.asarray(changed_owners, np.int64),
    )
    links = np.diff(index.link_owner_offsets).astype(np.float32)
    p1_ell, p2_ell = _patch_ell(plan, index, cap, changed_owners)
    return DBIndexPlan(
        n=index.n,
        num_blocks=index.num_blocks,
        block_capacity=cap,
        pass1=pass1,
        pass2=pass2,
        block_sizes=jnp.asarray(_block_sizes_padded(index, cap)),
        link_counts=jnp.asarray(links),
        p1_ell=p1_ell,
        p2_ell=p2_ell,
    )


def _ell_rows_for_new_blocks(index: DBIndex, old_num_blocks: int,
                             width: int) -> np.ndarray:
    """Padded ELL rows for the blocks appended past ``old_num_blocks``
    (relies on the appended-prefix invariant of phase-1 merges).  Shared by
    the single-host and sharded ELL patchers."""
    off = index.block_offsets[old_num_blocks:]
    return _ell_rows(off - off[0], index.block_members[off[0]:],
                     off.size - 1, width)


def _ell_rows_for_owners(index: DBIndex, owners: np.ndarray,
                         width: int) -> np.ndarray:
    """Padded ELL rows of the given owners' link lists (vectorized
    multi-slice gather).  Shared by the single-host and sharded patchers."""
    counts = np.diff(index.link_owner_offsets)[owners]
    starts = index.link_owner_offsets[owners]
    off = np.zeros(owners.size + 1, np.int64)
    np.cumsum(counts, out=off[1:])
    items = index.link_block[
        np.repeat(starts, counts)
        + (np.arange(off[-1]) - np.repeat(off[:-1], counts))
    ]
    return _ell_rows(off, items, owners.size, width)


def _patch_ell(plan: DBIndexPlan, index: DBIndex, cap: int,
               changed_owners: np.ndarray):
    """Incremental maintenance of the min/max ELL layouts: scatter-set only
    the appended blocks' rows and the changed owners' rows; rebuild (a
    recompile-sized event, like capacity growth) only when a row no longer
    fits its padded width."""
    if plan.p1_ell is None:
        return None, None
    block_sizes = np.diff(index.block_offsets)
    new_sizes = block_sizes[plan.num_blocks:]
    link_sizes = np.diff(index.link_owner_offsets)
    owners = np.asarray(changed_owners, np.int64)
    r1, r2 = plan.p1_ell.shape[1], plan.p2_ell.shape[1]
    if (cap != plan.block_capacity
            or (new_sizes.size and int(new_sizes.max()) > r1)
            or (owners.size and int(link_sizes[owners].max()) > r2)):
        return _ell_from_index(index, cap)
    p1_ell = plan.p1_ell
    if new_sizes.size:
        rows = _ell_rows_for_new_blocks(index, plan.num_blocks, r1)
        ids = jnp.asarray(np.arange(plan.num_blocks, index.num_blocks))
        p1_ell = p1_ell.at[ids].set(jnp.asarray(rows))
    p2_ell = plan.p2_ell
    if owners.size:
        rows = _ell_rows_for_owners(index, owners, r2)
        p2_ell = p2_ell.at[jnp.asarray(owners)].set(jnp.asarray(rows))
    return p1_ell, p2_ell


@functools.partial(jax.jit, static_argnames=("agg", "use_pallas", "interpret"))
def query_dbindex(plan: DBIndexPlan, values, agg: str = "sum",
                  use_pallas: bool = True, interpret: Optional[bool] = None):
    """values: [n] (or [n, D]) vertex attribute -> [n(, D)] window aggregates."""
    values = jnp.asarray(values, jnp.float32)
    if agg in ("sum", "count", "avg"):
        chans = []
        if agg in ("sum", "avg"):
            t = segment_sum(plan.pass1, values, use_pallas=use_pallas, interpret=interpret)
            chans.append(segment_sum(plan.pass2, t, use_pallas=use_pallas, interpret=interpret))
        if agg in ("count", "avg"):
            cnt = segment_sum(plan.pass2, plan.block_sizes, use_pallas=use_pallas,
                              interpret=interpret)
            chans.append(cnt)
        if agg == "sum":
            return chans[0]
        if agg == "count":
            return chans[0]
        return chans[0] / jnp.maximum(chans[1], 1e-30)
    if agg in ("min", "max"):
        t = _minmax_pass1(plan, values, agg)
        return _minmax_pass2(plan, t, agg)
    raise ValueError(agg)


def _ell_reduce(ell, vec, op: str):
    """Dense padded reduce: one gather + axis reduce, no scatter.  The
    sentinel pad index clips to the appended identity row of ``vec``.
    ``vec`` may be [S] or [S, C] (stacked channels of one monoid)."""
    ident = {"min": jnp.inf, "max": -jnp.inf, "sum": 0.0}[op]
    pad = jnp.full((1,) + vec.shape[1:], ident, vec.dtype)
    ext = jnp.concatenate([vec, pad])
    rows = jnp.take(ext, ell, axis=0, mode="clip")  # sentinel -> identity row
    red = {"min": jnp.min, "max": jnp.max, "sum": jnp.sum}[op]
    return red(rows, axis=1)


def _minmax_pass1(plan: DBIndexPlan, values, op: str, gathered=None):
    """Block partials for an idempotent monoid: ELL fast path when the plan
    carries one, else the masked XLA segment lowering over the tile layout
    (sized by block_capacity — static under streamed updates)."""
    if plan.p1_ell is not None:
        return _ell_reduce(plan.p1_ell, values, op)
    if gathered is None:
        gathered = jnp.take(values, plan.pass1.gather_padded)
    return _segment_minmax_gathered(plan.pass1, gathered,
                                    plan.block_capacity, op)


def _minmax_pass2(plan: DBIndexPlan, t, op: str):
    if plan.p2_ell is not None:
        return _ell_reduce(plan.p2_ell, t, op)
    gathered = jnp.take(t, plan.pass2.gather_padded)
    return _segment_minmax_gathered(plan.pass2, gathered, plan.n, op)


def _segment_minmax_gathered(plan, gathered, num_segments: int, op: str):
    """Masked XLA segment min/max over pre-gathered rows in plan layout."""
    sid = plan.seg_tiles.reshape(-1)
    valid = sid >= 0
    fill = jnp.inf if op == "min" else -jnp.inf
    seg_op = jax.ops.segment_min if op == "min" else jax.ops.segment_max
    if gathered.ndim == 1:
        masked = jnp.where(valid, gathered, fill)
    else:
        masked = jnp.where(valid[:, None], gathered, fill)
    out = seg_op(masked, jnp.where(valid, sid, num_segments),
                 num_segments=num_segments + 1)
    return out[:num_segments]


@functools.partial(jax.jit, static_argnames=("aggs", "use_pallas", "interpret"))
def _query_dbindex_multi_channels(plan: DBIndexPlan, values, aggs: tuple,
                                  use_pallas: bool = True,
                                  interpret: Optional[bool] = None):
    """Jitted channel core of :func:`query_dbindex_multi`: returns the
    deduped monoid channel results (finalizers run eagerly in the wrapper —
    XLA fusion may contract a finalizer's multiply-add into an FMA, which
    re-rounds; keeping the pure finalize outside the jit keeps registered
    aggregates bit-identical to their NumPy evaluation)."""
    from repro.core.aggregates import pack_channels

    pack = pack_channels(aggs)
    values = jnp.asarray(values, jnp.float32)
    sum_cols = pack.channels_of("sum")
    minmax_cols = [
        (ci, m, s) for ci, (m, s) in enumerate(pack.channels) if m != "sum"
    ]

    # ---- pass 1: one shared gather of the attribute vector -------------- #
    # registered derived aggregates add "square" channels; they reuse the
    # same gather (take(v², idx) == take(v, idx)² elementwise)
    need_g1 = any(
        pack.channels[ci][1] in ("value", "square") for ci in sum_cols
    ) or (plan.p1_ell is None and minmax_cols)
    g1 = jnp.take(values, plan.pass1.gather_padded) if need_g1 else None
    t_cols = {}
    for ci in sum_cols:
        src = pack.channels[ci][1]
        if src == "ones":
            # block cardinalities are host-exact plan metadata: the count
            # channel skips pass 1 entirely (same as the per-agg path)
            t_cols[ci] = plan.block_sizes
        else:
            t_cols[ci] = segment_sum_gathered(
                plan.pass1, g1 if src == "value" else g1 * g1,
                use_pallas=use_pallas, interpret=interpret)
    for ci, mname, src in minmax_cols:
        vsrc = values if src == "value" else values * values
        gsrc = g1 if (g1 is None or src == "value") else g1 * g1
        t_cols[ci] = _minmax_pass1(plan, vsrc, mname, gathered=gsrc)

    # ---- pass 2: one gather of the stacked sum-channel matrix; min/max
    # ride the dense ELL layout (idempotent monoids, order-insensitive) --- #
    outs = {}
    if sum_cols:
        t_mat = jnp.stack([t_cols[ci] for ci in sum_cols], axis=1)
        g2 = jnp.take(t_mat, plan.pass2.gather_padded, axis=0)  # [Lpad, C]
        reduced = segment_sum_gathered(
            plan.pass2, g2, use_pallas=use_pallas, interpret=interpret,
        )
        if reduced.ndim == 1:
            reduced = reduced[:, None]
        for j, ci in enumerate(sum_cols):
            outs[ci] = reduced[:, j]
    for ci, mname, _ in minmax_cols:
        outs[ci] = _minmax_pass2(plan, t_cols[ci], mname)
    return tuple(outs[ci] for ci in range(len(pack.channels)))


def query_dbindex_multi(plan: DBIndexPlan, values, aggs: tuple,
                        use_pallas: bool = True,
                        interpret: Optional[bool] = None):
    """Fused multi-aggregate DBIndex query: one gather per pass feeds every
    monoid channel (the Cao et al. multi-window-function sharing, applied to
    graph windows).

    ``aggs`` is a static tuple of aggregate names sharing one window; the
    channels are deduped (``sum``/``avg`` share the value channel, ``count``/
    ``avg`` the cardinality channel, registered derived aggregates ride
    extra ``square`` channels), pass 1 runs once over the deduped value
    channels, and pass 2 gathers one stacked ``[block_capacity, C]`` matrix
    feeding k per-monoid segment reduces.  Returns one array per aggregate,
    in ``aggs`` order, bit-identical to the per-aggregate ``query_dbindex``
    results.
    """
    from repro.core.aggregates import pack_channels

    aggs = tuple(aggs)
    chans = _query_dbindex_multi_channels(plan, values, aggs,
                                          use_pallas=use_pallas,
                                          interpret=interpret)
    pack = pack_channels(aggs)
    return tuple(pack.finalize(i, chans, xp=jnp) for i in range(len(aggs)))


# the recompile counter the streaming/serving tests assert on lives on the
# jitted channel core (the wrapper itself is plain Python)
query_dbindex_multi._cache_size = _query_dbindex_multi_channels._cache_size


def query_dbindex_sharded_multi(plan: DBIndexPlan, values, aggs: tuple,
                                mesh, axis="data"):
    """Fused multi-aggregate distributed query (stacked-channel matrix form).

    Tile rows are sharded over ``axis`` at whole-tile-group granularity
    (:mod:`repro.distributed.window_runtime`), so every segment's partial is
    produced by exactly one shard: the stacked SUM/COUNT/AVG channels ride
    one ``psum`` per pass, MIN/MAX ride ``pmin``/``pmax`` over sharded ELL
    layouts, and every aggregate is **bit-identical** to the single-host
    fused ``query_dbindex_multi`` answers (non-owning shards only ever
    contribute exact monoid identities).  Collective footprint: ``|T|·C +
    |n|·C`` floats per query, independent of window sizes.

    One-shot convenience — lays the plan out per call.  Streaming callers
    hold a :class:`~repro.distributed.window_runtime.ShardedDBPlan` (via
    ``Session(mesh=...)``) so the layout uploads once and streamed updates
    ship only changed tile groups.
    """
    from repro.distributed.window_runtime import (
        build_sharded_plan,
        query_sharded_multi,
    )

    splan = build_sharded_plan(plan, mesh, axis)
    return query_sharded_multi(splan, values, tuple(aggs))


def query_dbindex_sharded(plan: DBIndexPlan, values, mesh, axis="data"):
    """Single-aggregate (SUM) wrapper over the stacked-channel sharded
    query, kept for compatibility with the pre-multi-channel API."""
    return query_dbindex_sharded_multi(plan, values, ("sum",), mesh, axis)[0][: plan.n]


# ---------------------------------------------------------------------- #
#  I-Index plan
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class IIndexPlan:
    n: int
    max_level: int
    wd_plan: TilePlan  # wd members -> per-vertex difference partials
    pid: jnp.ndarray  # int32 [n], -1 roots
    level: jnp.ndarray  # int32 [n]

    def tree_flatten(self):
        return ((self.wd_plan, self.pid, self.level), (self.n, self.max_level))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux[0], aux[1], *children)

    def array_nbytes(self) -> dict:
        """Exact per-array device bytes (see :meth:`DBIndexPlan.array_nbytes`)."""
        out = {f"wd_plan.{k}": v for k, v in self.wd_plan.array_nbytes().items()}
        out["pid"] = int(self.pid.nbytes)
        out["level"] = int(self.level.nbytes)
        return out

    def plan_nbytes(self) -> int:
        """Total device bytes held by this plan."""
        return sum(self.array_nbytes().values())


jax.tree_util.register_pytree_node(
    IIndexPlan, IIndexPlan.tree_flatten, IIndexPlan.tree_unflatten
)


def plan_from_iindex(index: IIndex, tm: int = 512, ts: int = 512) -> IIndexPlan:
    sizes = np.diff(index.wd_offsets)
    owner = np.repeat(np.arange(index.n, dtype=np.int64), sizes)
    wd_plan = build_tile_plan(index.wd_members, owner, index.n, tm, ts)
    return IIndexPlan(
        n=index.n,
        max_level=int(index.level.max()) if index.n else 0,
        wd_plan=wd_plan,
        pid=jnp.asarray(index.pid),
        level=jnp.asarray(index.level),
    )


def patch_plan_iindex(
    plan: IIndexPlan, index: IIndex, changed_owners: np.ndarray
) -> IIndexPlan:
    """Incremental plan maintenance after ``update_iindex_batch``: only the
    WD tile groups holding cone vertices are re-laid-out; the PID forest and
    levels are small [n] arrays and are simply re-uploaded."""
    sizes = np.diff(index.wd_offsets)
    owner = np.repeat(np.arange(index.n, dtype=np.int64), sizes)
    wd_plan = patch_tile_plan(
        plan.wd_plan,
        index.wd_members,
        owner,
        index.n,
        np.asarray(changed_owners, np.int64),
    )
    return IIndexPlan(
        n=index.n,
        max_level=int(index.level.max()) if index.n else 0,
        wd_plan=wd_plan,
        pid=jnp.asarray(index.pid),
        level=jnp.asarray(index.level),
    )


@functools.partial(jax.jit, static_argnames=("schedule", "use_pallas", "interpret"))
def query_iindex(plan: IIndexPlan, values, schedule: str = "level",
                 use_pallas: bool = True, interpret: Optional[bool] = None):
    """Topological window SUM via inheritance (paper Algorithm 5 on device).

    schedule="level":   depth sequential steps, each one masked gather.
    schedule="doubling": pointer doubling, ceil(log2(depth+1)) gathers —
    the beyond-paper parallelization (§Perf).
    """
    values = jnp.asarray(values, jnp.float32)
    wdp = segment_sum(plan.wd_plan, values, use_pallas=use_pallas, interpret=interpret)
    return _inherit_scan(wdp, plan.pid, plan.level, plan.max_level, plan.n,
                         "sum", schedule)


_COMBINE = {"sum": (jnp.add, 0.0), "min": (jnp.minimum, jnp.inf),
            "max": (jnp.maximum, -jnp.inf)}


def _inherit_scan(wdp, pid, level, max_level: int, n: int, monoid: str,
                  schedule: str):
    """Per-monoid inheritance along the PID forest (Algorithm 5 generalized).

    ``wdp`` holds the window-difference partials, [n] or [n, C] (stacked
    channels of the same monoid).  Works for any commutative monoid — the
    level schedule combines each vertex with its parent's *finished*
    aggregate, the doubling schedule is an exact pointer-chain prefix
    combine — which is what lifts the device I-Index path beyond SUM.
    """
    combine, ident = _COMBINE[monoid]
    mat = wdp.ndim == 2
    if schedule == "level":
        def body(i, ans):
            parent = jnp.take(ans, jnp.clip(pid, 0, n - 1), axis=0)
            mask = pid >= 0
            parent = jnp.where(mask[:, None] if mat else mask, parent, ident)
            cond = level == i
            return jnp.where(cond[:, None] if mat else cond,
                             combine(wdp, parent), ans)

        return jax.lax.fori_loop(1, max_level + 1, body, wdp)
    if schedule == "doubling":
        rounds = max(1, int(np.ceil(np.log2(max_level + 1)))) if max_level else 0

        def body(_, carry):
            val, ptr = carry
            pv = jnp.take(val, jnp.clip(ptr, 0, n - 1), axis=0)
            mask = ptr >= 0
            pv = jnp.where(mask[:, None] if mat else mask, pv, ident)
            val = combine(val, pv)
            pp = jnp.take(ptr, jnp.clip(ptr, 0, n - 1))
            ptr = jnp.where(mask, pp, -1)
            return val, ptr

        val, _ = jax.lax.fori_loop(0, rounds, body, (wdp, pid))
        return val
    raise ValueError(schedule)


@functools.partial(jax.jit,
                   static_argnames=("aggs", "schedule", "use_pallas", "interpret"))
def _query_iindex_multi_channels(plan: IIndexPlan, values, aggs: tuple,
                                 schedule: str = "level",
                                 use_pallas: bool = True,
                                 interpret: Optional[bool] = None):
    """Jitted channel core of :func:`query_iindex_multi` (finalizers run
    eagerly in the wrapper — see ``_query_dbindex_multi_channels``)."""
    from repro.core.aggregates import pack_channels

    pack = pack_channels(aggs)
    values = jnp.asarray(values, jnp.float32)
    n = plan.n
    ones = jnp.ones(n, jnp.float32)
    srcs = {"value": values, "ones": ones, "square": values * values}
    cols = jnp.stack([srcs[src] for _, src in pack.channels], axis=1)  # [n, C]
    g = jnp.take(cols, plan.wd_plan.gather_padded, axis=0)  # one gather
    chans = [None] * len(pack.channels)
    sum_cols = pack.channels_of("sum")
    if sum_cols:
        wdp = segment_sum_gathered(plan.wd_plan, g[:, list(sum_cols)],
                                   use_pallas=use_pallas, interpret=interpret)
        if wdp.ndim == 1:
            wdp = wdp[:, None]
        done = _inherit_scan(wdp, plan.pid, plan.level, plan.max_level, n,
                             "sum", schedule)
        for j, ci in enumerate(sum_cols):
            chans[ci] = done[:, j]
    for mname in ("min", "max"):
        for ci in pack.channels_of(mname):
            wdp = _segment_minmax_gathered(plan.wd_plan, g[:, ci], n, mname)
            chans[ci] = _inherit_scan(wdp, plan.pid, plan.level,
                                      plan.max_level, n, mname, schedule)
    return tuple(chans)


def query_iindex_multi(plan: IIndexPlan, values, aggs: tuple,
                       schedule: str = "level", use_pallas: bool = True,
                       interpret: Optional[bool] = None):
    """Fused multi-aggregate topological query via inheritance.

    One gather of the stacked channel matrix feeds every monoid's
    window-difference reduce; the inheritance scan then runs once per
    monoid (sum channels stacked into a single scan).  min/max ride the
    per-monoid level inheritance — containment (Theorem 5.1) makes the
    parent's finished aggregate a valid partial for *any* monoid, not just
    SUM.  Returns one array per aggregate, in ``aggs`` order.
    """
    from repro.core.aggregates import pack_channels

    aggs = tuple(aggs)
    chans = _query_iindex_multi_channels(plan, values, aggs,
                                         schedule=schedule,
                                         use_pallas=use_pallas,
                                         interpret=interpret)
    pack = pack_channels(aggs)
    return tuple(pack.finalize(i, chans, xp=jnp) for i in range(len(aggs)))


query_iindex_multi._cache_size = _query_iindex_multi_channels._cache_size
