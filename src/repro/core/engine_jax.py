"""Device (JAX/TPU) query data plane for DBIndex and I-Index.

The host-built indices become static *plans* of device arrays:

* DBIndex: two chained tile plans — members→blocks, then links→owners —
  each one fused gather + Pallas segment-sum (DESIGN.md §2).
* I-Index: one tile plan for the window-difference partials plus the PID
  forest; the inheritance scan is either level-scheduled (``depth`` gathers)
  or pointer-doubled (``log2(depth)`` gathers, the §Perf variant).

``query_dbindex_sharded`` distributes the query under ``shard_map``:
pass 1 is sharded over *blocks*, the (small) block-partial vector ``T`` is
all-gathered over the data axis, and pass 2 is sharded over *owners* —
the collective footprint is ``|T|`` floats, independent of window sizes,
which is what makes the paper's sharing structure attractive on a pod.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dbindex import DBIndex
from repro.core.iindex import IIndex
from repro.kernels.segment_reduce.ops import (
    TilePlan,
    build_tile_plan,
    patch_tile_plan,
    segment_sum,
)


# ---------------------------------------------------------------------- #
#  DBIndex plan
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class DBIndexPlan:
    """Device plan.  ``block_capacity >= num_blocks`` pads the block-partial
    vector ``T`` so that streamed updates appending secondary blocks keep
    static shapes (capacity grows by powers of two → O(log) recompiles over
    a stream instead of one per batch)."""

    n: int
    num_blocks: int
    block_capacity: int
    pass1: TilePlan  # members -> block partials
    pass2: TilePlan  # block partials -> owner windows
    block_sizes: jnp.ndarray  # f32 [block_capacity] (for count/avg)
    link_counts: jnp.ndarray  # f32 [n]

    def tree_flatten(self):
        return (
            (self.pass1, self.pass2, self.block_sizes, self.link_counts),
            (self.n, self.num_blocks, self.block_capacity),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        p1, p2, bs, lc = children
        return cls(aux[0], aux[1], aux[2], p1, p2, bs, lc)


jax.tree_util.register_pytree_node(
    DBIndexPlan, DBIndexPlan.tree_flatten, DBIndexPlan.tree_unflatten
)


def _block_sizes_padded(index: DBIndex, capacity: int) -> np.ndarray:
    sizes = np.zeros(capacity, np.float32)
    sizes[: index.num_blocks] = np.diff(index.block_offsets)
    return sizes


def plan_from_dbindex(
    index: DBIndex, tm: int = 512, ts: int = 512,
    block_capacity: Optional[int] = None,
) -> DBIndexPlan:
    cap = max(int(block_capacity or 0), index.num_blocks, 1)
    member_block = np.asarray(index.member_block_ids, np.int64)
    pass1 = build_tile_plan(index.block_members, member_block, cap, tm, ts)
    owner_ids = np.asarray(index.link_owner_ids, np.int64)
    pass2 = build_tile_plan(index.link_block, owner_ids, index.n, tm, ts)
    links = np.diff(index.link_owner_offsets).astype(np.float32)
    return DBIndexPlan(
        n=index.n,
        num_blocks=index.num_blocks,
        block_capacity=cap,
        pass1=pass1,
        pass2=pass2,
        block_sizes=jnp.asarray(_block_sizes_padded(index, cap)),
        link_counts=jnp.asarray(links),
    )


def patch_plan_dbindex(
    plan: DBIndexPlan, index: DBIndex, changed_owners: np.ndarray
) -> DBIndexPlan:
    """Incremental plan maintenance after ``update_dbindex_batch``.

    The merged index keeps the primary block prefix intact and appends
    secondary blocks, so pass 1 only re-lays-out the tile groups holding
    appended block ids; pass 2 re-lays-out the groups containing
    ``changed_owners`` (the batch's affected owner set).  Everything else
    is spliced from the live plan.

    When the updater fell back to a full rebuild (``last_full_rebuild``
    stat), the appended-prefix invariant does not hold and splicing would
    silently reuse stale tiles — build a fresh plan instead.
    """
    cap = plan.block_capacity
    if index.num_blocks > cap:
        cap = 1 << (index.num_blocks - 1).bit_length()
    if index.stats.get("last_full_rebuild"):
        return plan_from_dbindex(index, plan.pass1.tm, plan.pass1.ts,
                                 block_capacity=cap)
    new_blocks = np.arange(plan.num_blocks, index.num_blocks, dtype=np.int64)
    pass1 = patch_tile_plan(
        plan.pass1,
        index.block_members,
        np.asarray(index.member_block_ids, np.int64),
        cap,
        new_blocks,
    )
    pass2 = patch_tile_plan(
        plan.pass2,
        index.link_block,
        np.asarray(index.link_owner_ids, np.int64),
        index.n,
        np.asarray(changed_owners, np.int64),
    )
    links = np.diff(index.link_owner_offsets).astype(np.float32)
    return DBIndexPlan(
        n=index.n,
        num_blocks=index.num_blocks,
        block_capacity=cap,
        pass1=pass1,
        pass2=pass2,
        block_sizes=jnp.asarray(_block_sizes_padded(index, cap)),
        link_counts=jnp.asarray(links),
    )


@functools.partial(jax.jit, static_argnames=("agg", "use_pallas", "interpret"))
def query_dbindex(plan: DBIndexPlan, values, agg: str = "sum",
                  use_pallas: bool = True, interpret: Optional[bool] = None):
    """values: [n] (or [n, D]) vertex attribute -> [n(, D)] window aggregates."""
    values = jnp.asarray(values, jnp.float32)
    if agg in ("sum", "count", "avg"):
        chans = []
        if agg in ("sum", "avg"):
            t = segment_sum(plan.pass1, values, use_pallas=use_pallas, interpret=interpret)
            chans.append(segment_sum(plan.pass2, t, use_pallas=use_pallas, interpret=interpret))
        if agg in ("count", "avg"):
            cnt = segment_sum(plan.pass2, plan.block_sizes, use_pallas=use_pallas,
                              interpret=interpret)
            chans.append(cnt)
        if agg == "sum":
            return chans[0]
        if agg == "count":
            return chans[0]
        return chans[0] / jnp.maximum(chans[1], 1e-30)
    if agg in ("min", "max"):
        from repro.kernels.segment_reduce.ref import segment_reduce_ref

        sid1 = plan.pass1.seg_tiles.reshape(-1)
        t = segment_reduce_ref(values, plan.pass1.gather_padded, sid1,
                               plan.num_blocks, op=agg)
        sid2 = plan.pass2.seg_tiles.reshape(-1)
        return segment_reduce_ref(t, plan.pass2.gather_padded, sid2, plan.n, op=agg)
    raise ValueError(agg)


def query_dbindex_sharded(plan: DBIndexPlan, values, mesh, axis="data"):
    """Distributed two-stage query under shard_map.

    Link/member rows are sharded over `axis` (row order is arbitrary for
    correctness — partial segment sums are combined with one ``psum`` per
    stage, so a segment straddling shards is handled for free).  Collective
    footprint: |T| + |n| floats per step, independent of window sizes —
    the paper's sharing structure keeps the wire format tiny.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    nb_pad = plan.pass1.num_out_tiles * plan.pass1.ts
    n_pad = plan.pass2.num_out_tiles * plan.pass2.ts
    axes = (axis,) if isinstance(axis, str) else tuple(axis)

    def local(p1_gather, p1_seg, p2_gather, p2_seg, vals):
        ok1 = p1_seg >= 0
        t_partial = jax.ops.segment_sum(
            jnp.where(ok1, jnp.take(vals, p1_gather), 0.0),
            jnp.where(ok1, p1_seg, nb_pad),
            num_segments=nb_pad + 1,
        )[:nb_pad]
        t_full = jax.lax.psum(t_partial, axes)
        ok2 = p2_seg >= 0
        out_partial = jax.ops.segment_sum(
            jnp.where(ok2, jnp.take(t_full, p2_gather), 0.0),
            jnp.where(ok2, p2_seg, n_pad),
            num_segments=n_pad + 1,
        )[:n_pad]
        return jax.lax.psum(out_partial, axes)

    p1g, p1s = plan.pass1.gather_padded, plan.pass1.seg_tiles.reshape(-1)
    p2g, p2s = plan.pass2.gather_padded, plan.pass2.seg_tiles.reshape(-1)
    ndev = int(np.prod([mesh.shape[a] for a in axes]))

    def pad_rows(x):  # equal row shards
        pad = (-x.shape[0]) % ndev
        return jnp.pad(x, (0, pad), constant_values=-1 if x.dtype == jnp.int32 else 0)

    p1s, p2s = pad_rows(p1s), pad_rows(p2s)
    p1g = jnp.pad(p1g, (0, p1s.shape[0] - p1g.shape[0]))
    p2g = jnp.pad(p2g, (0, p2s.shape[0] - p2g.shape[0]))
    values = jnp.asarray(values, jnp.float32)

    spec = P(axes)
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(spec, spec, spec, spec, P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(p1g, p1s, p2g, p2s, values)[: plan.n]


# ---------------------------------------------------------------------- #
#  I-Index plan
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class IIndexPlan:
    n: int
    max_level: int
    wd_plan: TilePlan  # wd members -> per-vertex difference partials
    pid: jnp.ndarray  # int32 [n], -1 roots
    level: jnp.ndarray  # int32 [n]

    def tree_flatten(self):
        return ((self.wd_plan, self.pid, self.level), (self.n, self.max_level))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux[0], aux[1], *children)


jax.tree_util.register_pytree_node(
    IIndexPlan, IIndexPlan.tree_flatten, IIndexPlan.tree_unflatten
)


def plan_from_iindex(index: IIndex, tm: int = 512, ts: int = 512) -> IIndexPlan:
    sizes = np.diff(index.wd_offsets)
    owner = np.repeat(np.arange(index.n, dtype=np.int64), sizes)
    wd_plan = build_tile_plan(index.wd_members, owner, index.n, tm, ts)
    return IIndexPlan(
        n=index.n,
        max_level=int(index.level.max()) if index.n else 0,
        wd_plan=wd_plan,
        pid=jnp.asarray(index.pid),
        level=jnp.asarray(index.level),
    )


def patch_plan_iindex(
    plan: IIndexPlan, index: IIndex, changed_owners: np.ndarray
) -> IIndexPlan:
    """Incremental plan maintenance after ``update_iindex_batch``: only the
    WD tile groups holding cone vertices are re-laid-out; the PID forest and
    levels are small [n] arrays and are simply re-uploaded."""
    sizes = np.diff(index.wd_offsets)
    owner = np.repeat(np.arange(index.n, dtype=np.int64), sizes)
    wd_plan = patch_tile_plan(
        plan.wd_plan,
        index.wd_members,
        owner,
        index.n,
        np.asarray(changed_owners, np.int64),
    )
    return IIndexPlan(
        n=index.n,
        max_level=int(index.level.max()) if index.n else 0,
        wd_plan=wd_plan,
        pid=jnp.asarray(index.pid),
        level=jnp.asarray(index.level),
    )


@functools.partial(jax.jit, static_argnames=("schedule", "use_pallas", "interpret"))
def query_iindex(plan: IIndexPlan, values, schedule: str = "level",
                 use_pallas: bool = True, interpret: Optional[bool] = None):
    """Topological window SUM via inheritance (paper Algorithm 5 on device).

    schedule="level":   depth sequential steps, each one masked gather.
    schedule="doubling": pointer doubling, ceil(log2(depth+1)) gathers —
    the beyond-paper parallelization (§Perf).
    """
    values = jnp.asarray(values, jnp.float32)
    wdp = segment_sum(plan.wd_plan, values, use_pallas=use_pallas, interpret=interpret)
    pid = plan.pid
    if schedule == "level":
        def body(i, ans):
            parent = jnp.take(ans, jnp.clip(pid, 0, plan.n - 1))
            parent = jnp.where(pid >= 0, parent, 0.0)
            return jnp.where(plan.level == i, wdp + parent, ans)

        return jax.lax.fori_loop(1, plan.max_level + 1, body, wdp)
    if schedule == "doubling":
        rounds = max(1, int(np.ceil(np.log2(plan.max_level + 1)))) if plan.max_level else 0

        def body(_, carry):
            val, ptr = carry
            pv = jnp.take(val, jnp.clip(ptr, 0, plan.n - 1))
            val = val + jnp.where(ptr >= 0, pv, 0.0)
            pp = jnp.take(ptr, jnp.clip(ptr, 0, plan.n - 1))
            ptr = jnp.where(ptr >= 0, pp, -1)
            return val, ptr

        val, _ = jax.lax.fori_loop(0, rounds, body, (wdp, pid))
        return val
    raise ValueError(schedule)
