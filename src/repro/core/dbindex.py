"""Dense Block Index (paper §4).

Construction follows the paper's two-step heuristic:

1. **Cluster** vertices (window *owners*) by MinHash signature of their
   windows — MC uses the full k-hop signature, EMC a cheaper k'-hop estimate
   (§4.2.2).  Signatures are computed by segment-min message passing without
   any window materialization (:mod:`repro.core.minhash`).
2. **Partition into blocks**: per cluster, partition the window *members*
   into equivalence classes — two members are equivalent iff they appear in
   exactly the same set of the cluster's windows (paper's node equivalence).
   Each class is a block; a block is *dense* if it has >= 2 members and
   >= 2 owners.  Links ``block -> owner`` record the exact disjoint cover of
   every window.

Implementation notes (vectorized; DESIGN.md §2):

* Windows are materialized **per owner-batch** as packed bitsets (one
  multi-source BFS per ~4096 owners, whole clusters packed per batch), never
  all at once — this is the paper's memory argument against EAGR, kept.
* The equivalence partition is one ``lexsort`` over (cluster, member, owner)
  pairs + ``reduceat`` owner-set hashing (128-bit order-independent), one
  ``np.unique`` for block ids — no Python loop over members.
* Oversized clusters are sub-chunked to a pair budget (the paper's recursive
  re-partition of clusters that don't fit in memory).
* With an exact owner-set partition the paper's ``RefineCluster`` recursion
  reaches its fixed point in one pass (owner-set equality is the finest
  useful refinement), so output semantics match at lower cost.

The built index is a bipartite blocks↔owners structure (paper Fig. 3) stored
as flat sorted arrays ready for the device data plane:

* pass 1: ``T[b]   = Σ attr[block_members[b]]``   (segment-reduce by block)
* pass 2: ``ans[v] = Σ T[link_block under owner v]`` (segment-reduce by owner)
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import minhash as mh
from repro.core.aggregates import AGGREGATES, Aggregate
from repro.core.graph import Graph
from repro.core.windows import (
    KHopWindow,
    TopologicalWindow,
    WindowExpr,
    expr_reach_bitsets,
    khop_reach_bitsets,
)

Array = np.ndarray

_C1 = np.uint64(0x517CC1B727220A95)
_C2 = np.uint64(0x2545F4914F6CDD1D)
_C3 = np.uint64(0x27D4EB2F165667C5)


@dataclasses.dataclass(frozen=True)
class DBIndex:
    """Bipartite block index (static arrays; ids int32)."""

    n: int
    num_blocks: int
    block_members: Array  # int32 [M] member vertex ids, grouped by block
    block_offsets: Array  # int64 [num_blocks+1]
    link_block: Array  # int32 [L] block ids, grouped by owner
    link_owner_offsets: Array  # int64 [n+1] CSR over owners
    stats: Dict = dataclasses.field(default_factory=dict, repr=False)

    # ---------------------------------------------------------------- #
    # the expanded id vectors are memoized on the (immutable) index —
    # plan building/patching and the attr-edit reverse lookup all consume
    # them, and re-materializing O(M)/O(L) arrays per call is pure waste
    @property
    def member_block_ids(self) -> Array:
        cached = getattr(self, "_member_block_ids", None)
        if cached is None:
            sizes = np.diff(self.block_offsets)
            cached = np.repeat(np.arange(self.num_blocks, dtype=np.int32), sizes)
            object.__setattr__(self, "_member_block_ids", cached)
        return cached

    @property
    def link_owner_ids(self) -> Array:
        cached = getattr(self, "_link_owner_ids", None)
        if cached is None:
            sizes = np.diff(self.link_owner_offsets)
            cached = np.repeat(np.arange(self.n, dtype=np.int32), sizes)
            object.__setattr__(self, "_link_owner_ids", cached)
        return cached

    def block(self, b: int) -> Array:
        return self.block_members[self.block_offsets[b] : self.block_offsets[b + 1]]

    def owner_blocks(self, v: int) -> Array:
        return self.link_block[self.link_owner_offsets[v] : self.link_owner_offsets[v + 1]]

    def window_of(self, v: int) -> Array:
        """Reconstruct W(v) from the cover — used by invariant tests."""
        parts = [self.block(b) for b in self.owner_blocks(v)]
        return np.sort(np.concatenate(parts)) if parts else np.empty(0, np.int32)

    def size_bytes(self) -> int:
        return int(
            self.block_members.nbytes
            + self.block_offsets.nbytes
            + self.link_block.nbytes
            + self.link_owner_offsets.nbytes
        )

    def linked_blocks_mask(self) -> Array:
        """Bool [num_blocks]: which blocks at least one owner links to."""
        linked = np.zeros(self.num_blocks, dtype=bool)
        linked[self.link_block] = True
        return linked

    def garbage_block_fraction(self, linked: Optional[Array] = None) -> float:
        """Fraction of blocks no owner links to (zero-link = garbage).

        Delete-dominated streams shrink windows: phase-1 merges drop the
        affected owners' links and append smaller secondary blocks, so old
        blocks lose their last link without the links/blocks *growth*
        ratios ever tripping — this is the direct staleness signal for
        them, shared by :class:`repro.core.streaming.StalenessPolicy` and
        the pass-1 compaction in
        :func:`repro.core.engine_jax.patch_plan_dbindex` (which passes its
        already-computed ``linked`` mask to avoid a second scan).
        """
        if self.num_blocks == 0:
            return 0.0
        if linked is None:
            linked = self.linked_blocks_mask()
        return 1.0 - int(np.count_nonzero(linked)) / self.num_blocks

    # ----------------------- reverse link map ------------------------ #
    def owners_of_members(self, vertices: Array) -> Array:
        """Owners whose windows contain any of the given vertices.

        The bipartite structure already encodes the reverse mapping: a
        vertex sits in some blocks (member lists), and the owners linking
        any of those blocks are exactly the windows containing it.  This is
        the attribute-update invalidation set — an attr edit changes only
        the cached aggregates of these owners (membership is untouched).
        """
        vertices = np.asarray(vertices, np.int64)
        if vertices.size == 0 or self.block_members.size == 0:
            return np.empty(0, np.int32)
        hit = np.zeros(self.n + 1, dtype=bool)
        hit[np.clip(vertices, 0, self.n)] = True
        blocks = np.unique(self.member_block_ids[hit[self.block_members]])
        if blocks.size == 0:
            return np.empty(0, np.int32)
        bmask = np.zeros(self.num_blocks, dtype=bool)
        bmask[blocks] = True
        return np.unique(self.link_owner_ids[bmask[self.link_block]]).astype(
            np.int32)

    # ------------------------- query (NumPy) ------------------------- #
    def query(self, values: Array, agg: str = "sum") -> Array:
        """Two-stage shared aggregation (paper §4.1), NumPy executor.

        Dtype-safe: integer attributes ride int64 channels end to end with
        per-dtype monoid identities — the serving layer's bitwise oracle
        depends on the int path never silently upcasting to float (only a
        finalizer may change the dtype).
        """
        a: Aggregate = AGGREGATES[agg]
        chans = a.prepare(np.asarray(values))
        outs = []
        for monoid, chan in zip(a.monoids, chans):
            ident = monoid.identity_for(chan.dtype)
            # pass 1: per-block partials
            t = np.full(self.num_blocks, ident, dtype=chan.dtype)
            if self.block_members.size:
                gathered = chan[self.block_members]
                starts = self.block_offsets[:-1]
                nonempty = np.diff(self.block_offsets) > 0
                red = monoid.np_op.reduceat(gathered, np.minimum(starts, gathered.size - 1))
                t = np.where(nonempty, red, ident)
            # pass 2: combine partials per owner
            ans = np.full(self.n, ident, dtype=chan.dtype)
            if self.link_block.size:
                g2 = t[self.link_block]
                starts2 = self.link_owner_offsets[:-1]
                nonempty2 = np.diff(self.link_owner_offsets) > 0
                red2 = monoid.np_op.reduceat(g2, np.minimum(starts2, g2.size - 1))
                ans = np.where(nonempty2, red2, ident)
            assert ans.dtype == chan.dtype, (
                f"monoid channel upcast: {chan.dtype} -> {ans.dtype}")
            outs.append(ans)
        return a.finalize_np(*outs)


# -------------------------------------------------------------------- #
#  Vectorized equivalence partition
# -------------------------------------------------------------------- #
class _Builder:
    """Accumulates blocks/links across owner batches with global dedup."""

    def __init__(self, n: int):
        self.n = n
        self.registry: Dict[Tuple[int, int, int], int] = {}
        self.block_chunks: List[Array] = []
        self.block_size_chunks: List[Array] = []
        self.link_block_chunks: List[Array] = []
        self.link_owner_chunks: List[Array] = []
        self.num_blocks = 0
        self.num_dense = 0

    def add_pairs(self, member: Array, owner: Array, cluster: Array) -> None:
        """Partition (cluster, member, owner) incidence pairs into blocks.

        member/owner are global vertex ids; cluster scopes the equivalence.
        """
        if member.size == 0:
            return
        member = member.astype(np.int64, copy=False)
        owner = owner.astype(np.int64, copy=False)
        cluster = cluster.astype(np.int64, copy=False)
        # owner order within a (cluster, member) segment is irrelevant (the
        # owner-set hash is order-independent), so one combined-key argsort
        # replaces a 3-key lexsort.
        combined = cluster * np.int64(self.n + 1) + member
        order = np.argsort(combined, kind="stable")
        m = member[order]
        o = owner[order]
        c = cluster[order]
        comb = combined[order]
        new_seg = np.empty(m.size, dtype=bool)
        new_seg[0] = True
        np.not_equal(np.diff(comb), 0, out=new_seg[1:])
        seg_starts = np.flatnonzero(new_seg)
        seg_len = np.diff(np.append(seg_starts, m.size))
        # 128-bit order-independent owner-set hash per (cluster, member) seg
        oh_a = mh._splitmix64(o.astype(np.uint64) * _C1)
        oh_b = mh._splitmix64(o.astype(np.uint64) ^ _C2)
        ha = np.add.reduceat(oh_a, seg_starts)
        hb = np.add.reduceat(oh_b, seg_starts)
        seg_member = m[seg_starts]
        seg_cluster = c[seg_starts]
        # block key: mix of (cluster, owner-set hash pair, size) -> uint64
        key = mh._splitmix64(
            ha
            ^ mh._splitmix64(hb ^ mh._splitmix64(seg_cluster.astype(np.uint64) * _C3))
            ^ (seg_len.astype(np.uint64) * _C2)
        )
        _, inv = np.unique(key, return_inverse=True)
        order2 = np.argsort(inv, kind="stable")
        inv_sorted = inv[order2]
        bstarts = np.flatnonzero(np.diff(inv_sorted, prepend=-1))
        bsizes = np.diff(np.append(bstarts, inv_sorted.size))
        blk_members = seg_member[order2]  # ascending within each block
        # content hash for global dedup
        mh_mix = mh._splitmix64(blk_members.astype(np.uint64) * _C3)
        chash = np.add.reduceat(mh_mix, bstarts)
        first = blk_members[bstarts]
        # owner lists come from each block's representative segment
        rep_seg = order2[bstarts]
        rep_start = seg_starts[rep_seg]
        rep_len = seg_len[rep_seg]
        # dense blocks: >=2 members and >=2 owners
        self.num_dense += int(np.count_nonzero((bsizes >= 2) & (rep_len >= 2)))
        # global ids with dedup
        nb = bstarts.size
        gids = np.empty(nb, dtype=np.int64)
        reg = self.registry
        new_mask = np.zeros(nb, dtype=bool)
        for i in range(nb):
            k = (int(chash[i]), int(bsizes[i]), int(first[i]))
            gid = reg.get(k)
            if gid is None:
                gid = self.num_blocks
                reg[k] = gid
                self.num_blocks += 1
                new_mask[i] = True
            gids[i] = gid
        # store only new blocks' member lists
        if new_mask.any():
            keep_members = np.repeat(new_mask, bsizes)
            self.block_chunks.append(blk_members[keep_members].astype(np.int32))
            self.block_size_chunks.append(bsizes[new_mask])
            # gids of new blocks are consecutive by construction order
        # links: block gid -> owners of representative segment
        total_links = int(rep_len.sum())
        idx = np.repeat(rep_start, rep_len) + (
            np.arange(total_links) - np.repeat(np.cumsum(rep_len) - rep_len, rep_len)
        )
        self.link_owner_chunks.append(o[idx].astype(np.int32))
        self.link_block_chunks.append(np.repeat(gids, rep_len).astype(np.int32))

    def finish(self, stats: Dict) -> DBIndex:
        n = self.n
        if self.num_blocks:
            block_members = np.concatenate(self.block_chunks)
            sizes = np.concatenate(self.block_size_chunks)
            block_offsets = np.zeros(self.num_blocks + 1, dtype=np.int64)
            np.cumsum(sizes, out=block_offsets[1:])
            lb = np.concatenate(self.link_block_chunks)
            lo_ = np.concatenate(self.link_owner_chunks)
            lorder = np.lexsort((lb, lo_))
            lb, lo_ = lb[lorder], lo_[lorder]
            link_owner_offsets = np.zeros(n + 1, dtype=np.int64)
            np.cumsum(np.bincount(lo_, minlength=n), out=link_owner_offsets[1:])
        else:
            block_members = np.empty(0, np.int32)
            block_offsets = np.zeros(1, np.int64)
            lb = np.empty(0, np.int32)
            link_owner_offsets = np.zeros(n + 1, np.int64)
        stats.update(
            num_blocks=self.num_blocks,
            num_dense_blocks=self.num_dense,
            num_links=int(lb.size),
            num_members=int(block_members.size),
        )
        return DBIndex(
            n=n,
            num_blocks=self.num_blocks,
            block_members=block_members,
            block_offsets=block_offsets,
            link_block=lb,
            link_owner_offsets=link_owner_offsets,
            stats=stats,
        )


def _blocks_from_windows(
    builder: _Builder, owners: Array, windows: List[Array], cluster_ids: Optional[Array] = None
) -> None:
    """Compatibility shim (used by incremental updates): explicit windows."""
    lens = np.array([w.size for w in windows], dtype=np.int64)
    if lens.sum() == 0:
        return
    member = np.concatenate(windows)
    owner = np.repeat(np.asarray(owners, np.int64), lens)
    if cluster_ids is None:
        cl = np.zeros(member.size, dtype=np.int64)
    else:
        cl = np.repeat(np.asarray(cluster_ids, np.int64), lens)
    builder.add_pairs(member.astype(np.int64), owner, cl)


# -------------------------------------------------------------------- #
#  Construction driver
# -------------------------------------------------------------------- #
def _pairs_from_packed(mat: Array) -> Tuple[Array, Array]:
    """(row, col) indices of set bits in a packed uint64 matrix [R, W].

    Sparse-aware: only nonzero words are expanded (64x less scan than a full
    unpackbits at low densities).  Column index = word*64 + bit.
    """
    rows, wcols = np.nonzero(mat)
    if rows.size == 0:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    vals = np.ascontiguousarray(mat[rows, wcols])
    bits = np.unpackbits(vals.view(np.uint8).reshape(-1, 8), axis=1, bitorder="little")
    nz_r, nz_b = np.nonzero(bits)
    return rows[nz_r].astype(np.int64), (wcols[nz_r] * 64 + nz_b).astype(np.int64)


def _paper_signatures_khop(
    g: Graph, k: int, num_hashes: int, bfs_batch: int, seed: int
) -> Array:
    """MinHash by explicit window materialization (paper's MC first pass)."""
    h = mh.vertex_hashes(g.n, num_hashes, seed)
    sig = np.full((g.n, num_hashes), np.iinfo(np.uint64).max, dtype=np.uint64)
    all_src = np.arange(g.n, dtype=np.int32)
    for lo in range(0, g.n, bfs_batch):
        batch = all_src[lo : lo + bfs_batch]
        reach = khop_reach_bitsets(g, k, batch)
        member, owner_local = _pairs_from_packed(reach)
        order = np.argsort(owner_local, kind="stable")
        m_s, o_s = member[order], owner_local[order]
        starts = np.flatnonzero(np.diff(o_s, prepend=-1))
        owners = batch[o_s[starts]]
        red = np.minimum.reduceat(h[m_s], starts, axis=0)
        sig[owners] = red
    return sig


def _expr_signatures(g: Graph, expr, num_hashes: int, bfs_batch: int,
                     seed: int) -> Array:
    """MinHash signatures of composite-expression windows, by batched
    materialization (the only generic option: a combinator's member set is
    not reachable by message passing alone).  Same pattern as the paper's
    MC first pass, with the window materializer swapped for the expression
    evaluator — everything downstream (clustering, equivalence partition,
    blocks) is unchanged, which is the point: DBIndex is window-agnostic."""
    h = mh.vertex_hashes(g.n, num_hashes, seed)
    sig = np.full((g.n, num_hashes), np.iinfo(np.uint64).max, dtype=np.uint64)
    all_src = np.arange(g.n, dtype=np.int32)
    for lo in range(0, g.n, bfs_batch):
        batch = all_src[lo : lo + bfs_batch]
        reach = expr_reach_bitsets(g, expr, batch)
        member, owner_local = _pairs_from_packed(reach)
        if member.size == 0:
            continue
        order = np.argsort(owner_local, kind="stable")
        m_s, o_s = member[order], owner_local[order]
        starts = np.flatnonzero(np.diff(o_s, prepend=-1))
        owners = batch[o_s[starts]]
        red = np.minimum.reduceat(h[m_s], starts, axis=0)
        sig[owners] = red
    return sig


def _topo_ancestor_bitsets(g: Graph) -> Array:
    """Packed ancestor matrix [n, ceil(n/64)] (row v = W_t(v))."""
    order = g.topological_order()
    words = (g.n + 63) // 64
    anc = np.zeros((g.n, words), dtype=np.uint64)
    ids = np.arange(g.n, dtype=np.int64)
    anc[ids, ids // 64] |= np.uint64(1) << (ids % 64).astype(np.uint64)
    for v in order:
        ch = g.out_neighbors(v)
        if ch.size:
            anc[ch] |= anc[v]
    return anc


def build_dbindex(
    g: Graph,
    window,
    method: str = "mc",
    num_hashes: int = 2,
    cluster_hops: Optional[int] = None,
    bfs_batch: int = 4096,
    pair_budget: int = 8_000_000,
    seed: int = 0,
) -> DBIndex:
    """Build a DBIndex.

    method: "mc" (cluster on full window signatures) or "emc" (cluster on
    `cluster_hops`-hop signatures; default 1) — EMC only defined for k-hop
    windows (§4.2.2).

    Composite :class:`~repro.core.windows.WindowExpr` windows (combinators,
    direction-variant k-hop leaves) take the generic path: signatures by
    batched expression materialization, then the *same* clustering /
    equivalence-partition / block pipeline — dense-block sharing works for
    any window sets (the paper's own observation), so the device plans,
    patching and sharding downstream apply unchanged.
    """
    t0 = time.perf_counter()
    is_khop = isinstance(window, KHopWindow)
    is_expr = isinstance(window, WindowExpr) and not isinstance(
        window, (KHopWindow, TopologicalWindow))
    if is_expr:
        method = "expr"
        sig = _expr_signatures(g, window, num_hashes, bfs_batch, seed)
    elif is_khop:
        if method == "mc_paper":
            # Paper Algorithm 1 lines 2-5 verbatim: materialize each window
            # (first of two BFS passes) and hash its member list.  Kept for
            # the Fig-7 reproduction; `mc` below is our message-passing
            # signature that removes this pass entirely (EXPERIMENTS §Perf).
            sig = _paper_signatures_khop(g, window.k, num_hashes, bfs_batch, seed)
        elif method == "mc":
            sig = mh.minhash_signatures_khop(g, window.k, num_hashes, seed)
        elif method == "emc":
            sig_hops = cluster_hops or 1
            assert sig_hops <= window.k
            sig = mh.minhash_signatures_khop(g, sig_hops, num_hashes, seed)
        else:
            raise ValueError(method)
    elif isinstance(window, TopologicalWindow):
        if method == "emc":
            raise ValueError("EMC is defined for k-hop windows only (paper §4.2.2)")
        sig = mh.minhash_signatures_topo(g, num_hashes, seed)
    else:
        raise TypeError(window)
    cluster_ids = mh.cluster_by_signature(sig)
    t_hash = time.perf_counter() - t0

    # owners in cluster-contiguous order
    order = np.argsort(cluster_ids, kind="stable").astype(np.int32)
    cl_sorted = cluster_ids[order]

    builder = _Builder(g.n)
    t1 = time.perf_counter()
    # expression windows share the k-hop orientation ([member, owner] packed
    # matrix per source batch), so they ride the same pair-extraction path
    packed_cols = is_khop or is_expr
    anc = _topo_ancestor_bitsets(g) if not packed_cols else None

    for blo in range(0, g.n, bfs_batch):
        sources = order[blo : blo + bfs_batch]
        src_clusters = cl_sorted[blo : blo + bfs_batch].astype(np.int64)
        if packed_cols:
            reach = (
                khop_reach_bitsets(g, window.k, sources) if is_khop
                else expr_reach_bitsets(g, window, sources)
            )  # [n, words]
        # extract (owner_local, member) pairs in column chunks; split the
        # partition scope at the pair budget (prefer cluster boundaries)
        pend_member: List[Array] = []
        pend_owner: List[Array] = []
        pend_cluster: List[Array] = []
        pend_count = 0

        def flush():
            nonlocal pend_count
            if pend_count:
                builder.add_pairs(
                    np.concatenate(pend_member),
                    np.concatenate(pend_owner),
                    np.concatenate(pend_cluster),
                )
            pend_member.clear()
            pend_owner.clear()
            pend_cluster.clear()
            pend_count = 0

        col_chunk = 1024
        for clo in range(0, sources.size, col_chunk):
            chi = min(clo + col_chunk, sources.size)
            if packed_cols:
                sub = reach[:, clo // 64 : (chi + 63) // 64]
                member, owner_local = _pairs_from_packed(sub)
            else:
                rows = anc[sources[clo:chi].astype(np.int64)]
                owner_local, member = _pairs_from_packed(rows)
                keep = member < g.n
                member, owner_local = member[keep], owner_local[keep]
            owner_local = owner_local + clo
            pend_member.append(member.astype(np.int64))
            pend_owner.append(sources[owner_local].astype(np.int64))
            pend_cluster.append(src_clusters[owner_local])
            pend_count += member.size
            if pend_count >= pair_budget:
                flush()
        flush()
    t_blocks = time.perf_counter() - t1

    stats = {
        "method": method,
        "t_hash_s": t_hash,
        "t_blocks_s": t_blocks,
        "t_total_s": time.perf_counter() - t0,
        "num_clusters": int(cluster_ids.max()) + 1 if g.n else 0,
    }
    return builder.finish(stats)
