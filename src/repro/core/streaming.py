"""Streaming dynamic-update engine (paper §4.3 Phase 1 + Phase 2 policy).

Ties the batched maintenance path into one stateful object:

    engine = StreamingEngine(g, KHopWindow(2))
    for batch in stream:                # UpdateBatch per tick
        engine.apply(batch)             # graph + index + device plan, all
        ans = engine.query("sum")       #   maintained incrementally

Each ``apply`` is: vectorized graph edit → batched index maintenance (one
multi-source BFS for the whole batch) → incremental device-plan patch
(only the tile groups whose blocks / owner links / WD segments changed).

Phase 2 (reorganization) is driven by :class:`StalenessPolicy`: the merged
index after phase-1 updates is exact but *less shared* — links and garbage
blocks accumulate.  When sharing loss crosses the configured ratio, the
engine rebuilds from scratch and re-baselines.  The I-Index maintenance is
a localized exact rebuild (no sharing loss), so the policy only arms for
DBIndex engines.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro import obs as _obs
from repro.core.dbindex import DBIndex, build_dbindex
from repro.core.graph import Graph
from repro.core.iindex import IIndex, build_iindex
from repro.core.updates import (
    UpdateBatch,
    apply_batch,
    update_dbindex_batch,
    update_iindex_batch,
)
from repro.core.windows import KHopWindow, TopologicalWindow, filter_attrs


def garbage_block_fraction(index) -> float:
    """Zero-link block fraction (see :meth:`DBIndex.garbage_block_fraction`);
    tolerates duck-typed policy test doubles that only carry
    ``num_blocks``/``link_block``/``stats`` (unbound calls keep the metric
    definition in one place)."""
    if getattr(index, "link_block", None) is None:
        return 0.0
    # zero-block guard here as well as in the method: a duck-typed index
    # reaching the unbound call must not divide by num_blocks == 0 (a graph
    # whose edges — or whose filtered windows — were all deleted)
    if not getattr(index, "num_blocks", 0):
        return 0.0
    return DBIndex.garbage_block_fraction(index, DBIndex.linked_blocks_mask(index))


@dataclasses.dataclass(frozen=True)
class StalenessPolicy:
    """Reorganize when phase-1 sharing loss exceeds a threshold.

    ``max_link_ratio``: rebuild when ``num_links`` exceeds this multiple of
    the last full build's link count (links are the pass-2 work and the
    paper's sharing metric).  ``max_block_ratio``: same for block count
    (appended secondary + garbage blocks).  ``max_garbage_ratio``: rebuild
    when the zero-link (garbage) block fraction crosses this — the signal
    for delete-dominated streams, which *shrink* links and so never trip
    the growth ratios.  ``min_batches`` delays the first check so bursts
    amortize.
    """

    max_link_ratio: float = 1.5
    max_block_ratio: float = 2.0
    max_garbage_ratio: float = 0.5
    min_batches: int = 1

    def should_reorganize(
        self, index: DBIndex, base_links: int, base_blocks: int, batches_since: int
    ) -> bool:
        if batches_since < self.min_batches:
            return False
        if not index.num_blocks:
            # an empty index (every edge — or every filtered window —
            # deleted) has nothing to reorganize; without this guard the
            # block-ratio test against a max(base, 1) baseline can trip
            # forever on a drained graph, rebuilding an empty index each tick
            return False
        links = int(index.stats.get("num_links", 0))
        return (
            links > self.max_link_ratio * max(base_links, 1)
            or index.num_blocks > self.max_block_ratio * max(base_blocks, 1)
            or garbage_block_fraction(index) > self.max_garbage_ratio
        )


def _flipped_vertices(g_old: Graph, g_new: Graph, batch: UpdateBatch,
                      touched) -> np.ndarray:
    """Edited vertices whose *truthiness* changed for any touched
    predicate attribute.  Edits that keep truthiness (e.g. ``1 → 2``) do
    not move window membership — ``Filter`` tests ``pred != 0`` — so they
    need no index maintenance at all."""
    flipped = []
    for name in touched:
        verts = np.unique(np.concatenate(
            [e.vertices for e in batch.attr_edits if e.name == name]
        ))
        old = np.asarray(g_old.attrs[name])[verts] != 0
        new = np.asarray(g_new.attrs[name])[verts] != 0
        flipped.append(verts[old != new])
    if not flipped:
        return np.empty(0, np.int64)
    return np.unique(np.concatenate(flipped)).astype(np.int64)


def _filter_flip_owners(index, g_new: Graph, window,
                        flipped: np.ndarray) -> np.ndarray:
    """Exact affected-owner set of a predicate truthiness flip.

    Combinators are pointwise per-owner set operations (k-hop/topological
    expansion exists only at the leaves, *below* every Filter), so a flip
    at ``u`` can only change ``u``'s own membership in any ``W(v)``.  The
    owners whose windows change are therefore exactly covered by

        {v : u ∈ W_old(v)}  ∪  {v : u ∈ W_new(v)}    for flipped u

    The old side is the DBIndex reverse link map
    (:meth:`~repro.core.dbindex.DBIndex.owners_of_members` — the flipped
    members' blocks' owners).  The new side only matters for *gained*
    members (falsy → truthy) or a :class:`~repro.core.windows.Diff`
    subtrahend (where a loss below adds members above); every window
    expression is otherwise monotone in its predicates, so a loss-only
    flip satisfies ``W_new(v) ⊆ W_old(v)`` and the reverse map alone is
    exact.  The new side, when needed, is one reverse-direction bitset
    sweep on the updated graph
    (:func:`~repro.core.windows.expr_containing_owners`).
    """
    from repro.core.windows import expr_containing_owners, has_diff

    owners = np.asarray(index.owners_of_members(flipped), np.int64)
    gains = np.any(np.asarray(
        [g_new.attrs[a][flipped] != 0 for a in filter_attrs(window)]
    )) if flipped.size else False
    if gains or has_diff(window):
        new_side = expr_containing_owners(g_new, window, flipped)
        owners = np.union1d(owners, np.asarray(new_side, np.int64))
    return owners.astype(np.int32)


def _attr_only_report(engine, batch, g2: Graph, t0: float) -> Optional[Dict]:
    """Shared attr-edit handling for the streaming engines (single-host and
    sharded).  Returns None when normal structural maintenance should run.

    A pure attribute-value batch (``size == 0``) skips index/plan
    maintenance entirely — both indices are structure-only, so swapping in
    the attr-updated graph is the whole update.  The exception is a batch
    editing a :class:`Filter` predicate attribute: membership may change
    for the flipped vertices, so the engine re-filters exactly the owners
    whose windows can change (``engine._refilter``), falling back to a
    full rebuild only when the flip reaches more than half the owners or
    the batch also carries structural edits.
    """
    touched = set(batch.edited_attrs()) & set(filter_attrs(engine.window))
    if batch.size > 0 and not touched:
        return None
    refiltered = False
    reorganized = False
    changed = np.empty(0, np.int32)
    if touched and batch.size > 0:
        # mixed structural + predicate batch: membership moves for both
        # reasons at once — rebuild outright rather than composing bounds
        engine.graph = g2
        engine._build()
        changed = np.arange(g2.n, dtype=np.int32)
        reorganized = True
    elif touched:
        flipped = _flipped_vertices(engine.graph, g2, batch, touched)
        refilter = getattr(engine, "_refilter", None)
        if flipped.size == 0:
            engine.graph = g2  # truthiness unchanged: structure unchanged
        else:
            owners = _filter_flip_owners(engine.index, g2, engine.window,
                                         flipped)
            engine.graph = g2
            if refilter is None or owners.size > g2.n // 2:
                engine._build()
                changed = np.arange(g2.n, dtype=np.int32)
                reorganized = True
            else:
                reorganized = refilter(owners)
                changed = (np.arange(g2.n, dtype=np.int32) if reorganized
                           else owners)
                refiltered = not reorganized
    else:
        engine.graph = g2
    plan_version = getattr(engine, "plan_version", None)
    if plan_version is None:
        plan_version = int(engine.plan.stats.get("version", 0))
    m = getattr(engine, "_m_maint", None)
    if m is not None:  # duck-typed engines without obs instruments skip
        action = ("reorganize" if reorganized
                  else "refilter" if refiltered else "attr_only")
        m.labels(engine.index_kind, action).inc()
    return {
        "batch_size": batch.size,
        "attr_edits": int(batch.attr_size),
        "affected": int(changed.size),
        "affected_owners": changed,
        "plan_version": int(plan_version),
        "t_index_s": time.perf_counter() - t0,
        "t_plan_s": 0.0,
        "reorganized": reorganized,
        "refiltered": refiltered,
    }


class StreamingEngine:
    """Stateful graph + index + device plan under a stream of UpdateBatches.

    ``index_kind``: "dbindex" (k-hop or topological windows) or "iindex"
    (topological only).  ``device=False`` keeps everything host-side
    (NumPy query executor) — useful for oracles and JAX-free paths.
    """

    def __init__(
        self,
        g: Graph,
        window,
        *,
        index_kind: str = "dbindex",
        method: str = "emc",
        policy: Optional[StalenessPolicy] = None,
        device: bool = True,
        tm: int = 512,
        ts: int = 512,
        use_pallas: bool = True,
        interpret: Optional[bool] = None,
        plan_headroom: float = 0.0,
        compact_garbage: float = 0.5,
        use_device_bfs: Optional[bool] = None,
        obs=None,
        tracer=None,
    ):
        assert index_kind in ("dbindex", "iindex")
        self.obs = obs if obs is not None else _obs.get_registry()
        self.tracer = tracer if tracer is not None else _obs.get_tracer()
        self._m_maint = self.obs.counter(
            "repro_maintenance_total",
            "maintenance outcomes per applied batch",
            labels=("kind", "action"))
        self._m_t_index = self.obs.histogram(
            "repro_index_update_seconds", "batched index maintenance time",
            labels=("kind",))
        self._m_t_plan = self.obs.histogram(
            "repro_plan_patch_seconds", "device plan patch/rebuild time",
            labels=("kind",))
        if index_kind == "iindex":
            assert isinstance(window, TopologicalWindow), "I-Index is topological-only"
        if isinstance(window, TopologicalWindow) and method == "emc":
            method = "mc"  # EMC is k-hop only (paper §4.2.2)
        self.graph = g
        self.window = window
        self.index_kind = index_kind
        self.method = method
        self.policy = policy or StalenessPolicy()
        self.device = device
        self.tm, self.ts = tm, ts
        self.use_pallas, self.interpret = use_pallas, interpret
        self.plan_headroom = plan_headroom
        self.compact_garbage = compact_garbage
        # pins the affected-owner BFS routing (None = size-based auto
        # between host NumPy and the bitset_expand Pallas kernel)
        self.use_device_bfs = use_device_bfs
        self.batches_applied = 0
        self.edits_applied = 0
        self.reorg_count = 0
        self.batches_since_reorg = 0
        #: monotonically increasing plan version: every patch or rebuild of
        #: the device plan bumps it, so a reader can tell whether the plan
        #: object it pinned is still the engine's newest one
        self.plan_version = 0
        self._build(initial=True)

    # ------------------------------------------------------------------ #
    def _build(self, initial: bool = False) -> None:
        if self.index_kind == "dbindex":
            self.index: object = build_dbindex(self.graph, self.window, method=self.method)
            self._base_links = int(self.index.stats.get("num_links", 0))
            self._base_blocks = int(self.index.num_blocks)
        else:
            self.index = build_iindex(self.graph)
            self._base_links = self._base_blocks = 0
        self.plan = None
        if self.device:
            from repro.core import engine_jax as ej

            if self.index_kind == "dbindex":
                self.plan = ej.plan_from_dbindex(self.index, self.tm, self.ts,
                                                 headroom=self.plan_headroom)
            else:
                self.plan = ej.plan_from_iindex(self.index, self.tm, self.ts)
        self.batches_since_reorg = 0
        if not initial:
            self.reorg_count += 1
            self.plan_version += 1

    # ------------------------------------------------------------------ #
    def _refilter(self, owners: np.ndarray) -> bool:
        """Re-evaluate exactly ``owners``'s windows after a predicate
        truthiness flip and phase-1-merge them into the index (the flip
        analogue of a structural batch: drop the owners' links, append
        secondary blocks over their re-filtered windows, patch only the
        touched tile groups).  Returns True when the merge tripped the
        staleness policy and the engine reorganized instead."""
        from repro.core.updates import _merge_affected
        from repro.core.windows import expr_windows

        wins = expr_windows(self.graph, self.window, owners)
        self.index = _merge_affected(self.index, owners, wins)
        self.batches_applied += 1
        self.batches_since_reorg += 1
        if self.policy.should_reorganize(
            self.index, self._base_links, self._base_blocks,
            self.batches_since_reorg,
        ):
            self._build()
            return True
        if self.device:
            from repro.core import engine_jax as ej

            self.plan = ej.patch_plan_dbindex(
                self.plan, self.index, owners,
                compact_garbage=self.compact_garbage,
                headroom=self.plan_headroom,
            )
        self.plan_version += 1
        return False

    # ------------------------------------------------------------------ #
    def apply(self, batch: UpdateBatch, graph: Optional[Graph] = None) -> Dict:
        """Apply one batch; returns a timing/size report.

        ``graph`` optionally supplies the already-updated graph (``batch``
        applied to the current one) so a caller driving several engines —
        e.g. a :class:`repro.core.api.Session` with states on multiple
        windows — pays for ``apply_batch`` once, not once per engine.
        """
        t0 = time.perf_counter()
        g2 = apply_batch(self.graph, batch) if graph is None else graph
        fast = _attr_only_report(self, batch, g2, t0)
        if fast is not None:
            return fast
        with self.tracer.span("index.update", cat="update",
                              kind=self.index_kind, size=batch.size):
            if self.index_kind == "dbindex":
                idx2, changed = update_dbindex_batch(
                    self.index, g2, self.window, batch,
                    use_device=self.use_device_bfs)
            else:
                idx2, changed = update_iindex_batch(self.index, g2, batch)
        self.graph, self.index = g2, idx2
        t_index = time.perf_counter() - t0
        self._m_t_index.labels(self.index_kind).observe(t_index)
        self.batches_applied += 1
        self.batches_since_reorg += 1
        self.edits_applied += batch.size

        reorganized = False
        if self.index_kind == "dbindex" and idx2.stats.get("last_full_rebuild"):
            # the updater rebuilt outright (affected set > n/2): the index is
            # as fresh as a phase-2 pass, so re-baseline the staleness policy
            self._base_links = int(idx2.stats.get("num_links", 0))
            self._base_blocks = int(idx2.num_blocks)
            self.batches_since_reorg = 0
        t1 = time.perf_counter()
        if self.index_kind == "dbindex" and self.policy.should_reorganize(
            idx2, self._base_links, self._base_blocks, self.batches_since_reorg
        ):
            with self.tracer.span("plan.patch", cat="update",
                                  kind=self.index_kind, action="reorganize"):
                self._build()
            reorganized = True
        elif self.device:
            from repro.core import engine_jax as ej

            with self.tracer.span("plan.patch", cat="update",
                                  kind=self.index_kind, action="patch"):
                if self.index_kind == "dbindex":
                    self.plan = ej.patch_plan_dbindex(
                        self.plan, idx2, changed,
                        compact_garbage=self.compact_garbage,
                        headroom=self.plan_headroom,
                    )
                else:
                    self.plan = ej.patch_plan_iindex(self.plan, idx2, changed)
            self.plan_version += 1
        else:
            self.plan_version += 1  # host "plan" is the index itself
        t_plan = time.perf_counter() - t1
        self._m_t_plan.labels(self.index_kind).observe(t_plan)
        self._m_maint.labels(
            self.index_kind, "reorganize" if reorganized else "patch").inc()
        return {
            "batch_size": batch.size,
            "affected": int(np.asarray(changed).size),
            # the exact owner set whose windows were recomputed — the
            # serving layer's cache invalidates precisely these vertices
            "affected_owners": np.asarray(changed, np.int32),
            "plan_version": self.plan_version,
            "t_index_s": t_index,
            "t_plan_s": t_plan,
            "reorganized": reorganized,
            # device footprint after this batch: constant between reorgs
            # (headroom absorbs appends shape-stably) — EXPLAIN's stability
            # tests and the out-of-core accounting both key off this
            "plan_bytes": (int(self.plan.plan_nbytes())
                           if self.plan is not None
                           and hasattr(self.plan, "plan_nbytes") else 0),
        }

    # ------------------------------------------------------------------ #
    def query(self, agg: str = "sum", values=None, **kw) -> np.ndarray:
        """One aggregate.  The device I-Index path routes min/max/count/avg
        through the capability registry's multi-channel executor (per-monoid
        level inheritance) instead of the old SUM-only assert; anything the
        registry can't serve raises :class:`UnsupportedQueryError`."""
        if values is None:
            values = self.graph.attrs["val"]
        if not self.device:
            return self.index.query(np.asarray(values), agg)
        from repro.core import engine_jax as ej

        if self.index_kind == "dbindex":
            out = ej.query_dbindex(
                self.plan, values, agg,
                use_pallas=self.use_pallas, interpret=self.interpret, **kw,
            )
            return np.asarray(out)
        if agg == "sum":
            out = ej.query_iindex(
                self.plan, values,
                use_pallas=self.use_pallas, interpret=self.interpret, **kw,
            )
            return np.asarray(out)
        return self.query_multi((agg,), values, **kw)[0]

    def query_multi(self, aggs, values=None, **kw) -> list:
        """All ``aggs`` over the engine's window as one fused multi-channel
        plan (one gather feeding stacked per-monoid segment reduces)."""
        from repro.core.api import DEFAULT_REGISTRY

        if values is None:
            values = self.graph.attrs["val"]
        engine = (
            ("jax" if self.index_kind == "dbindex" else "jax-iindex")
            if self.device
            else ("dbindex" if self.index_kind == "dbindex" else "iindex")
        )
        out = DEFAULT_REGISTRY.run(
            engine, self.graph, self.window, values, tuple(aggs),
            index=self.index, plan=self.plan,
            use_pallas=self.use_pallas, interpret=self.interpret, **kw,
        )
        return [np.asarray(out[a]) for a in aggs]

    # ------------------------------------------------------------------ #
    @property
    def staleness(self) -> Dict:
        """Sharing-loss telemetry for the phase-2 policy."""
        if self.index_kind != "dbindex":
            return {"link_ratio": 1.0, "block_ratio": 1.0, "garbage_ratio": 0.0}
        return {
            "link_ratio": int(self.index.stats.get("num_links", 0))
            / max(self._base_links, 1),
            "block_ratio": self.index.num_blocks / max(self._base_blocks, 1),
            "garbage_ratio": garbage_block_fraction(self.index),
        }
