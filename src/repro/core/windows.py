"""Window specifications, the window expression algebra, and host evaluation.

Implements the paper's two window instantiations (Definitions 1 and 2):

* :class:`KHopWindow` — ``W_kh(v)`` = vertices reachable from ``v`` within
  ``k`` hops (follows out-edges on directed graphs, all edges on undirected
  graphs).  Includes ``v`` itself, matching the paper's running examples
  (``W(B) = {A, B, D, F}`` contains ``B``).
* :class:`TopologicalWindow` — ``W_t(v)`` = ``{v}`` plus all ancestors of
  ``v`` in a DAG (the paper's example ``W_t(E) = {A,B,C,D,E}`` includes
  ``E``).

The paper notes DBIndex is agnostic to *how* per-vertex windows are defined
— dense-block sharing works for any window sets — so the two instantiations
are merely the **leaves** of an open :class:`WindowExpr` algebra:

* leaves :class:`KHop` (direction-aware k-hop ball) and :class:`Topo`;
* combinators :class:`Union`, :class:`Intersect`, :class:`Diff` (per-vertex
  set operations on the member sets);
* :class:`Filter` — mask window members by a boolean vertex attribute.

All expressions are hashable value objects; :func:`canonicalize` flattens
nested combinators, sorts commutative children, dedups, and applies
containment rewrites (``KHop(1) ⊆ KHop(2)`` so their union IS ``KHop(2)``
— reuse the larger materialization).  Evaluation rides the same packed
bitset machinery the leaves use: a combinator is one vectorized bitwise
op over the children's reachability matrices (:func:`expr_reach_bitsets`),
so the *existing* DBIndex builder/plan pipeline consumes composite windows
unchanged.

Host computation uses *batched multi-source bitset BFS*: reachability bits
for a batch of B source vertices are packed into ``uint64`` words and the
k-hop expansion is one vectorized scatter-OR per hop (``R[dst] |= R[src]``
grouped with ``np.bitwise_or.reduceat``).  This is the NumPy mirror of the
TPU `bitset_expand` Pallas kernel and is what lets index construction avoid
materializing all windows at once (the paper's central memory argument
against EAGR).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import Graph

Array = np.ndarray


# ---------------------------------------------------------------------- #
#  Window expression algebra
# ---------------------------------------------------------------------- #
class WindowExpr:
    """Base class of all window expressions (leaves and combinators).

    Subclasses are frozen dataclasses — hashable value objects usable as
    dict keys (plan groups, session states).  ``_key()`` returns a nested
    tuple that totally orders expressions for canonical child sorting.
    """

    def name(self) -> str:
        raise NotImplementedError

    def _key(self) -> tuple:
        raise NotImplementedError


# ---------------------------------------------------------------------- #
#  Window specs (canonical leaves)
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class KHopWindow(WindowExpr):
    """k-hop window (Definition 1)."""

    k: int

    def __post_init__(self):
        assert self.k >= 1

    def name(self) -> str:
        return f"khop[{self.k}]"

    def _key(self) -> tuple:
        return ("khop", self.k, "out")

    def windows(self, g: Graph, sources: Optional[Array] = None) -> List[Array]:
        return khop_windows(g, self.k, sources)

    def batches(self, g: Graph, batch: int = 4096) -> Iterator[Tuple[Array, List[Array]]]:
        return khop_window_batches(g, self.k, batch)


@dataclasses.dataclass(frozen=True)
class TopologicalWindow(WindowExpr):
    """Topological window (Definition 2) — ancestors in a DAG, plus self."""

    def name(self) -> str:
        return "topological"

    def _key(self) -> tuple:
        return ("topological",)

    def windows(self, g: Graph, sources: Optional[Array] = None) -> List[Array]:
        return topological_windows(g, sources)


@dataclasses.dataclass(frozen=True)
class KHop(WindowExpr):
    """Direction-aware k-hop leaf.

    ``direction="out"`` is Definition 1 (canonicalizes to
    :class:`KHopWindow`); ``"in"`` follows reverse edges (the k-hop
    *audience* of a vertex); ``"both"`` ignores orientation.  On undirected
    graphs all three coincide (the CSR caches are symmetrized), but
    canonicalization is graph-independent so only ``"out"`` is rewritten.
    """

    k: int
    direction: str = "out"

    def __post_init__(self):
        assert self.k >= 1
        assert self.direction in ("out", "in", "both"), self.direction

    def name(self) -> str:
        return f"khop[{self.k},{self.direction}]"

    def _key(self) -> tuple:
        return ("khop", self.k, self.direction)


@dataclasses.dataclass(frozen=True)
class Topo(WindowExpr):
    """Spelling alias of :class:`TopologicalWindow` (canonicalizes to it)."""

    def name(self) -> str:
        return "topological"

    def _key(self) -> tuple:
        return ("topological",)


@dataclasses.dataclass(frozen=True, init=False)
class Union(WindowExpr):
    """W(v) = union of the children's windows of ``v`` (commutative)."""

    exprs: Tuple[WindowExpr, ...]

    def __init__(self, *exprs):
        assert exprs, "Union needs at least one child window"
        object.__setattr__(self, "exprs", tuple(exprs))

    def name(self) -> str:
        return "union(" + ",".join(e.name() for e in self.exprs) + ")"

    def _key(self) -> tuple:
        return ("union",) + tuple(e._key() for e in self.exprs)


@dataclasses.dataclass(frozen=True, init=False)
class Intersect(WindowExpr):
    """W(v) = intersection of the children's windows of ``v`` (commutative)."""

    exprs: Tuple[WindowExpr, ...]

    def __init__(self, *exprs):
        assert exprs, "Intersect needs at least one child window"
        object.__setattr__(self, "exprs", tuple(exprs))

    def name(self) -> str:
        return "intersect(" + ",".join(e.name() for e in self.exprs) + ")"

    def _key(self) -> tuple:
        return ("intersect",) + tuple(e._key() for e in self.exprs)


@dataclasses.dataclass(frozen=True)
class Diff(WindowExpr):
    """W(v) = a's window of ``v`` minus b's window of ``v``."""

    a: WindowExpr
    b: WindowExpr

    def name(self) -> str:
        return f"diff({self.a.name()},{self.b.name()})"

    def _key(self) -> tuple:
        return ("diff", self.a._key(), self.b._key())


@dataclasses.dataclass(frozen=True)
class Filter(WindowExpr):
    """W(v) = members u of the child's window with ``attrs[pred][u]`` truthy.

    The predicate is a *vertex attribute name*: membership depends on
    attribute values, so attribute edits to ``predicate_attr`` are
    structural for the windows (the maintenance path rebuilds the affected
    state — see ``Session.update``).
    """

    expr: WindowExpr
    predicate_attr: str

    def name(self) -> str:
        return f"filter({self.expr.name()},{self.predicate_attr})"

    def _key(self) -> tuple:
        return ("filter", self.expr._key(), self.predicate_attr)


def is_leaf(expr) -> bool:
    """True for the materialization primitives (no child expressions)."""
    return isinstance(expr, (KHopWindow, TopologicalWindow, KHop, Topo))


def window_kind_of(window) -> str:
    """Capability kind: "khop" / "topological" for the paper leaves,
    "composite" for combinators and direction-variant k-hop leaves."""
    if isinstance(window, KHopWindow):
        return "khop"
    if isinstance(window, (TopologicalWindow, Topo)):
        return "topological"
    if isinstance(window, KHop):
        return "khop" if window.direction == "out" else "composite"
    if isinstance(window, WindowExpr):
        return "composite"
    raise TypeError(window)


def contains(a, b) -> bool:
    """Provable ``b ⊆ a`` (conservative: False means "unknown").

    Drives the canonicalization containment rewrites: a union drops every
    child some sibling provably contains (reuse the larger materialization),
    an intersection drops every child that provably contains a sibling.
    """
    if a == b:
        return True
    ka, kb = a._key(), b._key()
    if ka[0] == kb[0] == "khop" and ka[2] == kb[2]:
        return kb[1] <= ka[1]
    if isinstance(a, Union) and any(contains(c, b) for c in a.exprs):
        return True
    if isinstance(b, Intersect) and any(contains(a, c) for c in b.exprs):
        return True
    if isinstance(b, Filter) and contains(a, b.expr):
        return True
    return False


def canonicalize(expr):
    """Canonical form: flatten, sort + dedup commutative children, rewrite
    containment, normalize leaf spellings.  Equal queries — e.g.
    ``Union(A, B)`` and ``Union(B, A)`` — canonicalize to one value object
    and therefore hit one cached plan."""
    if isinstance(expr, (KHopWindow, TopologicalWindow)):
        return expr
    if isinstance(expr, KHop):
        return KHopWindow(expr.k) if expr.direction == "out" else expr
    if isinstance(expr, Topo):
        return TopologicalWindow()
    if isinstance(expr, (Union, Intersect)):
        cls = type(expr)
        flat: List[WindowExpr] = []
        for c in expr.exprs:
            c = canonicalize(c)
            flat.extend(c.exprs if isinstance(c, cls) else [c])
        flat = sorted(set(flat), key=lambda e: e._key())
        kept = _drop_contained(flat, larger_wins=cls is Union)
        if len(kept) == 1:
            return kept[0]
        return cls(*kept)
    if isinstance(expr, Diff):
        return Diff(canonicalize(expr.a), canonicalize(expr.b))
    if isinstance(expr, Filter):
        child = canonicalize(expr.expr)
        if isinstance(child, Filter) and child.predicate_attr == expr.predicate_attr:
            return child
        return Filter(child, expr.predicate_attr)
    raise TypeError(f"not a window expression: {expr!r}")


def _drop_contained(exprs: Sequence[WindowExpr], larger_wins: bool) -> List[WindowExpr]:
    """Containment filter for deduped commutative children: a union keeps
    the larger of a provably nested pair, an intersection the smaller."""
    out: List[WindowExpr] = []
    for c in exprs:
        if larger_wins:
            redundant = any(o != c and contains(o, c) for o in exprs)
        else:
            redundant = any(o != c and contains(c, o) for o in exprs)
        if not redundant:
            out.append(c)
    return out


def expr_leaves(expr) -> List[WindowExpr]:
    """All leaf windows of an expression, in evaluation order."""
    if is_leaf(expr):
        return [expr]
    if isinstance(expr, (Union, Intersect)):
        return [l for c in expr.exprs for l in expr_leaves(c)]
    if isinstance(expr, Diff):
        return expr_leaves(expr.a) + expr_leaves(expr.b)
    if isinstance(expr, Filter):
        return expr_leaves(expr.expr)
    raise TypeError(expr)


def filter_attrs(expr) -> frozenset:
    """Attribute names any :class:`Filter` in the expression predicates on
    (edits to them change window *membership*, not just values)."""
    if is_leaf(expr):
        return frozenset()
    if isinstance(expr, Filter):
        return frozenset({expr.predicate_attr}) | filter_attrs(expr.expr)
    if isinstance(expr, (Union, Intersect)):
        out = frozenset()
        for c in expr.exprs:
            out |= filter_attrs(c)
        return out
    if isinstance(expr, Diff):
        return filter_attrs(expr.a) | filter_attrs(expr.b)
    raise TypeError(expr)


WindowSpec = object  # typing alias; any WindowExpr


# ---------------------------------------------------------------------- #
#  Batched bitset BFS
# ---------------------------------------------------------------------- #
def _scatter_or_rows(
    reach: Array, src_sorted: Array, dst_sorted: Array, group_starts: Array, dst_unique: Array
) -> Array:
    """new[dst] |= OR-reduce of reach[src] grouped by dst.  reach: [n, W] u64."""
    if src_sorted.size == 0:
        return reach
    gathered = reach[src_sorted]  # [E, W]
    reduced = np.bitwise_or.reduceat(gathered, group_starts, axis=0)
    out = reach.copy()
    out[dst_unique] |= reduced
    return out


def _sorted_edges_by_dst(g: Graph) -> Tuple[Array, Array, Array, Array]:
    """Symmetrized-if-undirected edges sorted by dst + reduceat group info."""
    if g.directed:
        src, dst = g.src, g.dst
    else:
        src = np.concatenate([g.src, g.dst])
        dst = np.concatenate([g.dst, g.src])
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    dst_unique, group_starts = np.unique(dst, return_index=True)
    return src, dst, group_starts, dst_unique


def khop_reach_bitsets(g: Graph, k: int, sources: Array) -> Array:
    """Packed reachability: bit j of word row u says source[j] reaches u in <=k hops.

    Returns uint64 array of shape [n, ceil(B/64)].
    """
    sources = np.asarray(sources, np.int64)
    b = sources.size
    words = (b + 63) // 64
    reach = np.zeros((g.n, words), dtype=np.uint64)
    cols = np.arange(b)
    reach[sources, cols // 64] |= np.uint64(1) << (cols % 64).astype(np.uint64)
    src, dst, group_starts, dst_unique = _sorted_edges_by_dst(g)
    for _ in range(k):
        new = _scatter_or_rows(reach, src, dst, group_starts, dst_unique)
        if np.array_equal(new, reach):  # converged early (small diameter)
            break
        reach = new
    return reach


def _bitsets_to_windows(reach: Array, sources: Array) -> List[Array]:
    """Column j of the packed matrix -> sorted member array for source j."""
    n, _ = reach.shape
    b = sources.size
    out: List[Array] = []
    # unpack per 64-column block to bound memory
    for w in range((b + 63) // 64):
        lo, hi = w * 64, min((w + 1) * 64, b)
        block = reach[:, w]  # [n] uint64
        for j in range(lo, hi):
            bit = np.uint64(1) << np.uint64(j - lo)
            members = np.flatnonzero((block & bit) != 0).astype(np.int32)
            out.append(members)
    return out


def khop_windows(g: Graph, k: int, sources: Optional[Array] = None) -> List[Array]:
    """Materialize W_kh for the given sources (default: all vertices)."""
    if sources is None:
        sources = np.arange(g.n, dtype=np.int32)
    sources = np.asarray(sources, np.int32)
    out: List[Array] = []
    for lo in range(0, sources.size, 4096):
        batch = sources[lo : lo + 4096]
        reach = khop_reach_bitsets(g, k, batch)
        out.extend(_bitsets_to_windows(reach, batch))
    return out


def khop_window_batches(
    g: Graph, k: int, batch: int = 4096
) -> Iterator[Tuple[Array, List[Array]]]:
    """Stream (source_batch, windows) without holding all windows in memory."""
    sources = np.arange(g.n, dtype=np.int32)
    for lo in range(0, g.n, batch):
        chunk = sources[lo : lo + batch]
        reach = khop_reach_bitsets(g, k, chunk)
        yield chunk, _bitsets_to_windows(reach, chunk)


def khop_window_single(g: Graph, k: int, v: int) -> Array:
    """Per-vertex frontier BFS — the paper's Non-Indexed primitive."""
    seen = np.zeros(g.n, dtype=bool)
    seen[v] = True
    frontier = np.array([v], dtype=np.int32)
    for _ in range(k):
        if frontier.size == 0:
            break
        starts = g.out_indptr[frontier]
        lens = g.out_indptr[frontier + 1] - starts
        total = int(lens.sum())
        if total == 0:
            break
        idx = np.repeat(starts, lens) + (
            np.arange(total) - np.repeat(np.cumsum(lens) - lens, lens)
        )
        nbr = g.out_indices[idx]
        nbr = nbr[~seen[nbr]]
        nbr = np.unique(nbr)
        seen[nbr] = True
        frontier = nbr.astype(np.int32)
    return np.flatnonzero(seen).astype(np.int32)


# ---------------------------------------------------------------------- #
#  Topological windows (ancestor sets)
# ---------------------------------------------------------------------- #
def topological_windows(g: Graph, sources: Optional[Array] = None) -> List[Array]:
    """W_t(v) = {v} ∪ ancestors(v) for every v (or the given sources).

    One topological sweep propagating packed ancestor bitsets down out-edges.
    Memory is bounded by freeing a vertex's bitset once all children consumed
    it (the paper's Algorithm 4 memory discipline); here we keep the simple
    dense [n, n/64] variant for n up to ~60k and a chunked variant above.
    """
    order = g.topological_order()
    words = (g.n + 63) // 64
    # chunk over *bit columns* (ancestor id space) to bound memory at ~512MB
    max_cols_words = max(1, (512 * 2**20) // max(1, 8 * g.n))
    anc = None
    pieces: List[Array] = []
    for wlo in range(0, words, max_cols_words):
        whi = min(words, wlo + max_cols_words)
        anc = np.zeros((g.n, whi - wlo), dtype=np.uint64)
        ids = np.arange(g.n, dtype=np.int64)
        in_range = (ids >= wlo * 64) & (ids < whi * 64)
        rel = ids[in_range] - wlo * 64
        anc[ids[in_range], rel // 64] |= np.uint64(1) << (rel % 64).astype(np.uint64)
        for v in order:
            ch = g.out_neighbors(v)
            if ch.size:
                anc[ch] |= anc[v]
        pieces.append(anc)
    full = np.concatenate(pieces, axis=1) if len(pieces) > 1 else pieces[0]
    if sources is None:
        sources = np.arange(g.n, dtype=np.int32)
    out: List[Array] = []
    for v in np.asarray(sources, np.int64):
        row = full[v]
        members = np.flatnonzero(
            np.unpackbits(row.view(np.uint8), bitorder="little")[: g.n]
        ).astype(np.int32)
        out.append(members)
    return out


def descendants_multi(g: Graph, seeds: Array) -> Array:
    """Seeds plus everything reachable from any seed (directed, forward).

    One vectorized multi-source BFS (frontier gathers via
    ``Graph._frontier_out``) — this is the batched replacement for calling
    :func:`repro.core.updates.descendants` once per edge.
    """
    seen = np.zeros(g.n, dtype=bool)
    seeds = np.unique(np.asarray(seeds, np.int64))
    seen[seeds] = True
    frontier = seeds.astype(np.int32)
    while frontier.size:
        nbr = g._frontier_out(frontier)
        if nbr.size == 0:
            break
        nbr = np.unique(nbr[~seen[nbr]])
        seen[nbr] = True
        frontier = nbr.astype(np.int32)
    return np.flatnonzero(seen).astype(np.int32)


def topological_window_single(g: Graph, v: int) -> Array:
    """Reverse BFS from v over in-edges (brute-force oracle)."""
    seen = np.zeros(g.n, dtype=bool)
    seen[v] = True
    frontier = [int(v)]
    while frontier:
        u = frontier.pop()
        for p in g.in_neighbors(u):
            if not seen[p]:
                seen[p] = True
                frontier.append(int(p))
    return np.flatnonzero(seen).astype(np.int32)


# ---------------------------------------------------------------------- #
#  Expression evaluation (packed bitsets — the generic lowering path)
# ---------------------------------------------------------------------- #
def graph_view(g: Graph, direction: str) -> Graph:
    """Directed graph reinterpreted for a leaf's traversal direction.

    ``"out"`` is the graph itself; ``"in"`` swaps edge orientation;
    ``"both"`` drops orientation.  Undirected graphs are returned as-is
    (their CSR caches are already symmetrized).  Views are memoized on the
    graph object (graphs are immutable — updates build new ones): callers
    sit in hot loops (per-vertex oracle BFS, per-chunk expression
    materialization, per-batch affected-owner maintenance) and must not
    pay the O(E log E) CSR rebuild on every call."""
    if not g.directed or direction == "out":
        return g
    if direction == "in":
        return g.reverse_view()  # O(1): swaps the existing CSR caches
    memo = getattr(g, "_dir_views", None)
    if memo is None:
        memo = {}
        object.__setattr__(g, "_dir_views", memo)
    if direction not in memo:
        # "both" genuinely needs the symmetrized CSR built once per graph
        memo[direction] = Graph(n=g.n, src=g.src, dst=g.dst, directed=False)
    return memo[direction]


def expr_reach_bitsets(g: Graph, expr, sources: Array) -> Array:
    """Packed membership matrix of a window expression: bit ``j`` of word
    row ``u`` says ``u ∈ W_expr(sources[j])``.  Combinators are single
    vectorized bitwise ops over the children's matrices — the same
    ``[n, ceil(B/64)]`` layout the leaf BFS produces, so the DBIndex
    builder's pair-extraction path consumes composite windows unchanged."""
    sources = np.asarray(sources, np.int32)
    if isinstance(expr, KHopWindow):
        return khop_reach_bitsets(g, expr.k, sources)
    if isinstance(expr, KHop):
        return khop_reach_bitsets(graph_view(g, expr.direction), expr.k, sources)
    if isinstance(expr, (TopologicalWindow, Topo)):
        # u ∈ W_t(v) iff u reaches v: one reverse multi-source BFS, run to
        # convergence (khop_reach_bitsets breaks on a fixed point)
        return khop_reach_bitsets(graph_view(g, "in"), max(g.n, 1), sources)
    if isinstance(expr, Union):
        out = expr_reach_bitsets(g, expr.exprs[0], sources)
        for c in expr.exprs[1:]:
            out = out | expr_reach_bitsets(g, c, sources)
        return out
    if isinstance(expr, Intersect):
        out = expr_reach_bitsets(g, expr.exprs[0], sources)
        for c in expr.exprs[1:]:
            out = out & expr_reach_bitsets(g, c, sources)
        return out
    if isinstance(expr, Diff):
        return expr_reach_bitsets(g, expr.a, sources) & ~expr_reach_bitsets(
            g, expr.b, sources)
    if isinstance(expr, Filter):
        out = expr_reach_bitsets(g, expr.expr, sources).copy()
        pred = np.asarray(g.attrs[expr.predicate_attr])
        out[pred == 0] = 0  # member rows failing the predicate drop out
        return out
    raise TypeError(f"not a window expression: {expr!r}")


def expr_windows(g: Graph, expr, sources: Optional[Array] = None,
                 batch: int = 4096) -> List[Array]:
    """Materialize W_expr for the given sources (default: all vertices)."""
    if sources is None:
        sources = np.arange(g.n, dtype=np.int32)
    sources = np.asarray(sources, np.int32)
    out: List[Array] = []
    for lo in range(0, sources.size, batch):
        chunk = sources[lo : lo + batch]
        reach = expr_reach_bitsets(g, expr, chunk)
        out.extend(_bitsets_to_windows(reach, chunk))
    return out


def expr_window_single(g: Graph, expr, v: int) -> Array:
    """Per-vertex set evaluation — the brute-force oracle path, kept
    independent of the bitset machinery (frontier BFS per leaf + NumPy set
    ops per combinator)."""
    if isinstance(expr, KHopWindow):
        return khop_window_single(g, expr.k, v)
    if isinstance(expr, KHop):
        return khop_window_single(graph_view(g, expr.direction), expr.k, v)
    if isinstance(expr, (TopologicalWindow, Topo)):
        return topological_window_single(g, v)
    if isinstance(expr, Union):
        out = expr_window_single(g, expr.exprs[0], v)
        for c in expr.exprs[1:]:
            out = np.union1d(out, expr_window_single(g, c, v))
        return out.astype(np.int32)
    if isinstance(expr, Intersect):
        out = expr_window_single(g, expr.exprs[0], v)
        for c in expr.exprs[1:]:
            out = np.intersect1d(out, expr_window_single(g, c, v))
        return out.astype(np.int32)
    if isinstance(expr, Diff):
        return np.setdiff1d(
            expr_window_single(g, expr.a, v), expr_window_single(g, expr.b, v)
        ).astype(np.int32)
    if isinstance(expr, Filter):
        members = expr_window_single(g, expr.expr, v)
        pred = np.asarray(g.attrs[expr.predicate_attr])
        return members[pred[members] != 0].astype(np.int32)
    raise TypeError(f"not a window expression: {expr!r}")


# ---------------------------------------------------------------------- #
#  Reverse membership (containing-owner) evaluation
# ---------------------------------------------------------------------- #
def _flip_direction(direction: str) -> str:
    return {"out": "in", "in": "out", "both": "both"}[direction]


def expr_containing_bitsets(
    g: Graph, expr, sources: Array,
    uncertain_attrs: frozenset = frozenset(), upper: bool = True,
) -> Array:
    """Packed *reverse* membership matrix: bit ``j`` of word row ``v`` says
    ``sources[j] ∈ W_expr(v)`` — the transpose question of
    :func:`expr_reach_bitsets`, answered without materializing any window.
    Leaves run the same multi-source bitset BFS with the traversal
    direction flipped (``u ∈ W_khop(v)`` iff ``u`` reaches ``v`` in the
    reversed view; ``u ∈ W_topo(v)`` iff ``u`` reaches ``v`` forward);
    combinators stay pointwise; a :class:`Filter` masks bit *columns*
    (the sources failing its predicate) instead of member rows.

    ``uncertain_attrs`` computes an *envelope* instead of the exact
    matrix: a Filter predicating on an uncertain attribute is treated as
    free to admit (``upper=True``) or reject (``upper=False``) every
    source.  ``Diff`` swaps the envelope side for its subtrahend, so the
    upper matrix is a sound superset of membership under ANY truth
    assignment of the uncertain predicates at the sources — which is what
    bounds the affected-owner set of a predicate-attribute edit (the
    sources being exactly the vertices whose truthiness flipped).
    """
    sources = np.asarray(sources, np.int32)
    if isinstance(expr, KHopWindow):
        return khop_reach_bitsets(graph_view(g, "in"), expr.k, sources)
    if isinstance(expr, KHop):
        view = graph_view(g, _flip_direction(expr.direction))
        return khop_reach_bitsets(view, expr.k, sources)
    if isinstance(expr, (TopologicalWindow, Topo)):
        # u ∈ W_t(v) iff u reaches v: forward BFS, run to convergence
        return khop_reach_bitsets(g, max(g.n, 1), sources)
    if isinstance(expr, Union):
        out = expr_containing_bitsets(g, expr.exprs[0], sources,
                                      uncertain_attrs, upper)
        for c in expr.exprs[1:]:
            out = out | expr_containing_bitsets(g, c, sources,
                                                uncertain_attrs, upper)
        return out
    if isinstance(expr, Intersect):
        out = expr_containing_bitsets(g, expr.exprs[0], sources,
                                      uncertain_attrs, upper)
        for c in expr.exprs[1:]:
            out = out & expr_containing_bitsets(g, c, sources,
                                                uncertain_attrs, upper)
        return out
    if isinstance(expr, Diff):
        # the subtrahend flips envelope side: possibly-in(a \ b) needs
        # definitely-in(b), and vice versa
        return expr_containing_bitsets(
            g, expr.a, sources, uncertain_attrs, upper
        ) & ~expr_containing_bitsets(
            g, expr.b, sources, uncertain_attrs, not upper)
    if isinstance(expr, Filter):
        child = expr_containing_bitsets(g, expr.expr, sources,
                                        uncertain_attrs, upper)
        if expr.predicate_attr in uncertain_attrs:
            if upper:
                return child  # predicate may admit every source
            return np.zeros_like(child)  # ... or reject every source
        pred = np.asarray(g.attrs[expr.predicate_attr])
        cols = np.flatnonzero(pred[sources.astype(np.int64)] != 0)
        mask = np.zeros((sources.size + 63) // 64, dtype=np.uint64)
        np.bitwise_or.at(  # duplicate word slots: plain |= keeps one bit
            mask, cols // 64, np.uint64(1) << (cols % 64).astype(np.uint64))
        return child & mask  # broadcasts over rows
    raise TypeError(f"not a window expression: {expr!r}")


def expr_containing_owners(
    g: Graph, expr, vertices: Array,
    uncertain_attrs: frozenset = frozenset(), batch: int = 4096,
) -> Array:
    """Owners ``v`` with ``W_expr(v) ∩ vertices ≠ ∅`` (with
    ``uncertain_attrs``: owners that could contain one under *some* truth
    assignment of those predicates at the vertices) — the index-free
    reverse window map.  Chunked like :func:`expr_windows`."""
    vertices = np.asarray(vertices, np.int64)
    if vertices.size == 0:
        return np.empty(0, np.int32)
    hit = np.zeros(g.n, dtype=bool)
    for lo in range(0, vertices.size, batch):
        m = expr_containing_bitsets(g, expr, vertices[lo: lo + batch],
                                    uncertain_attrs, upper=True)
        hit |= (m != 0).any(axis=1)
    return np.flatnonzero(hit).astype(np.int32)


def has_diff(expr) -> bool:
    """True when the expression contains a :class:`Diff` node (predicate
    flips can then *add* members through the subtrahend, so a pure-loss
    edit is not guaranteed to only shrink windows)."""
    if is_leaf(expr):
        return False
    if isinstance(expr, Diff):
        return True
    if isinstance(expr, (Union, Intersect)):
        return any(has_diff(c) for c in expr.exprs)
    if isinstance(expr, Filter):
        return has_diff(expr.expr)
    raise TypeError(expr)
