"""Window specifications and host-side window computation.

Implements the paper's two window instantiations (Definitions 1 and 2):

* :class:`KHopWindow` — ``W_kh(v)`` = vertices reachable from ``v`` within
  ``k`` hops (follows out-edges on directed graphs, all edges on undirected
  graphs).  Includes ``v`` itself, matching the paper's running examples
  (``W(B) = {A, B, D, F}`` contains ``B``).
* :class:`TopologicalWindow` — ``W_t(v)`` = ``{v}`` plus all ancestors of
  ``v`` in a DAG (the paper's example ``W_t(E) = {A,B,C,D,E}`` includes
  ``E``).

Host computation uses *batched multi-source bitset BFS*: reachability bits
for a batch of B source vertices are packed into ``uint64`` words and the
k-hop expansion is one vectorized scatter-OR per hop (``R[dst] |= R[src]``
grouped with ``np.bitwise_or.reduceat``).  This is the NumPy mirror of the
TPU `bitset_expand` Pallas kernel and is what lets index construction avoid
materializing all windows at once (the paper's central memory argument
against EAGR).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import Graph

Array = np.ndarray


# ---------------------------------------------------------------------- #
#  Window specs
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class KHopWindow:
    """k-hop window (Definition 1)."""

    k: int

    def __post_init__(self):
        assert self.k >= 1

    def name(self) -> str:
        return f"khop[{self.k}]"

    def windows(self, g: Graph, sources: Optional[Array] = None) -> List[Array]:
        return khop_windows(g, self.k, sources)

    def batches(self, g: Graph, batch: int = 4096) -> Iterator[Tuple[Array, List[Array]]]:
        return khop_window_batches(g, self.k, batch)


@dataclasses.dataclass(frozen=True)
class TopologicalWindow:
    """Topological window (Definition 2) — ancestors in a DAG, plus self."""

    def name(self) -> str:
        return "topological"

    def windows(self, g: Graph, sources: Optional[Array] = None) -> List[Array]:
        return topological_windows(g, sources)


WindowSpec = object  # typing alias; either of the dataclasses above


# ---------------------------------------------------------------------- #
#  Batched bitset BFS
# ---------------------------------------------------------------------- #
def _scatter_or_rows(
    reach: Array, src_sorted: Array, dst_sorted: Array, group_starts: Array, dst_unique: Array
) -> Array:
    """new[dst] |= OR-reduce of reach[src] grouped by dst.  reach: [n, W] u64."""
    if src_sorted.size == 0:
        return reach
    gathered = reach[src_sorted]  # [E, W]
    reduced = np.bitwise_or.reduceat(gathered, group_starts, axis=0)
    out = reach.copy()
    out[dst_unique] |= reduced
    return out


def _sorted_edges_by_dst(g: Graph) -> Tuple[Array, Array, Array, Array]:
    """Symmetrized-if-undirected edges sorted by dst + reduceat group info."""
    if g.directed:
        src, dst = g.src, g.dst
    else:
        src = np.concatenate([g.src, g.dst])
        dst = np.concatenate([g.dst, g.src])
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    dst_unique, group_starts = np.unique(dst, return_index=True)
    return src, dst, group_starts, dst_unique


def khop_reach_bitsets(g: Graph, k: int, sources: Array) -> Array:
    """Packed reachability: bit j of word row u says source[j] reaches u in <=k hops.

    Returns uint64 array of shape [n, ceil(B/64)].
    """
    sources = np.asarray(sources, np.int64)
    b = sources.size
    words = (b + 63) // 64
    reach = np.zeros((g.n, words), dtype=np.uint64)
    cols = np.arange(b)
    reach[sources, cols // 64] |= np.uint64(1) << (cols % 64).astype(np.uint64)
    src, dst, group_starts, dst_unique = _sorted_edges_by_dst(g)
    for _ in range(k):
        new = _scatter_or_rows(reach, src, dst, group_starts, dst_unique)
        if np.array_equal(new, reach):  # converged early (small diameter)
            break
        reach = new
    return reach


def _bitsets_to_windows(reach: Array, sources: Array) -> List[Array]:
    """Column j of the packed matrix -> sorted member array for source j."""
    n, _ = reach.shape
    b = sources.size
    out: List[Array] = []
    # unpack per 64-column block to bound memory
    for w in range((b + 63) // 64):
        lo, hi = w * 64, min((w + 1) * 64, b)
        block = reach[:, w]  # [n] uint64
        for j in range(lo, hi):
            bit = np.uint64(1) << np.uint64(j - lo)
            members = np.flatnonzero((block & bit) != 0).astype(np.int32)
            out.append(members)
    return out


def khop_windows(g: Graph, k: int, sources: Optional[Array] = None) -> List[Array]:
    """Materialize W_kh for the given sources (default: all vertices)."""
    if sources is None:
        sources = np.arange(g.n, dtype=np.int32)
    sources = np.asarray(sources, np.int32)
    out: List[Array] = []
    for lo in range(0, sources.size, 4096):
        batch = sources[lo : lo + 4096]
        reach = khop_reach_bitsets(g, k, batch)
        out.extend(_bitsets_to_windows(reach, batch))
    return out


def khop_window_batches(
    g: Graph, k: int, batch: int = 4096
) -> Iterator[Tuple[Array, List[Array]]]:
    """Stream (source_batch, windows) without holding all windows in memory."""
    sources = np.arange(g.n, dtype=np.int32)
    for lo in range(0, g.n, batch):
        chunk = sources[lo : lo + batch]
        reach = khop_reach_bitsets(g, k, chunk)
        yield chunk, _bitsets_to_windows(reach, chunk)


def khop_window_single(g: Graph, k: int, v: int) -> Array:
    """Per-vertex frontier BFS — the paper's Non-Indexed primitive."""
    seen = np.zeros(g.n, dtype=bool)
    seen[v] = True
    frontier = np.array([v], dtype=np.int32)
    for _ in range(k):
        if frontier.size == 0:
            break
        starts = g.out_indptr[frontier]
        lens = g.out_indptr[frontier + 1] - starts
        total = int(lens.sum())
        if total == 0:
            break
        idx = np.repeat(starts, lens) + (
            np.arange(total) - np.repeat(np.cumsum(lens) - lens, lens)
        )
        nbr = g.out_indices[idx]
        nbr = nbr[~seen[nbr]]
        nbr = np.unique(nbr)
        seen[nbr] = True
        frontier = nbr.astype(np.int32)
    return np.flatnonzero(seen).astype(np.int32)


# ---------------------------------------------------------------------- #
#  Topological windows (ancestor sets)
# ---------------------------------------------------------------------- #
def topological_windows(g: Graph, sources: Optional[Array] = None) -> List[Array]:
    """W_t(v) = {v} ∪ ancestors(v) for every v (or the given sources).

    One topological sweep propagating packed ancestor bitsets down out-edges.
    Memory is bounded by freeing a vertex's bitset once all children consumed
    it (the paper's Algorithm 4 memory discipline); here we keep the simple
    dense [n, n/64] variant for n up to ~60k and a chunked variant above.
    """
    order = g.topological_order()
    words = (g.n + 63) // 64
    # chunk over *bit columns* (ancestor id space) to bound memory at ~512MB
    max_cols_words = max(1, (512 * 2**20) // max(1, 8 * g.n))
    anc = None
    pieces: List[Array] = []
    for wlo in range(0, words, max_cols_words):
        whi = min(words, wlo + max_cols_words)
        anc = np.zeros((g.n, whi - wlo), dtype=np.uint64)
        ids = np.arange(g.n, dtype=np.int64)
        in_range = (ids >= wlo * 64) & (ids < whi * 64)
        rel = ids[in_range] - wlo * 64
        anc[ids[in_range], rel // 64] |= np.uint64(1) << (rel % 64).astype(np.uint64)
        for v in order:
            ch = g.out_neighbors(v)
            if ch.size:
                anc[ch] |= anc[v]
        pieces.append(anc)
    full = np.concatenate(pieces, axis=1) if len(pieces) > 1 else pieces[0]
    if sources is None:
        sources = np.arange(g.n, dtype=np.int32)
    out: List[Array] = []
    for v in np.asarray(sources, np.int64):
        row = full[v]
        members = np.flatnonzero(
            np.unpackbits(row.view(np.uint8), bitorder="little")[: g.n]
        ).astype(np.int32)
        out.append(members)
    return out


def descendants_multi(g: Graph, seeds: Array) -> Array:
    """Seeds plus everything reachable from any seed (directed, forward).

    One vectorized multi-source BFS (frontier gathers via
    ``Graph._frontier_out``) — this is the batched replacement for calling
    :func:`repro.core.updates.descendants` once per edge.
    """
    seen = np.zeros(g.n, dtype=bool)
    seeds = np.unique(np.asarray(seeds, np.int64))
    seen[seeds] = True
    frontier = seeds.astype(np.int32)
    while frontier.size:
        nbr = g._frontier_out(frontier)
        if nbr.size == 0:
            break
        nbr = np.unique(nbr[~seen[nbr]])
        seen[nbr] = True
        frontier = nbr.astype(np.int32)
    return np.flatnonzero(seen).astype(np.int32)


def topological_window_single(g: Graph, v: int) -> Array:
    """Reverse BFS from v over in-edges (brute-force oracle)."""
    seen = np.zeros(g.n, dtype=bool)
    seen[v] = True
    frontier = [int(v)]
    while frontier:
        u = frontier.pop()
        for p in g.in_neighbors(u):
            if not seen[p]:
                seen[p] = True
                frontier.append(int(p))
    return np.flatnonzero(seen).astype(np.int32)
