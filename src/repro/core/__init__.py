"""Core of the paper: graph window queries, DBIndex, I-Index, baselines."""

from repro.core.aggregates import AGGREGATES  # noqa: F401
from repro.core.api import (  # noqa: F401
    DEFAULT_REGISTRY,
    EngineCapability,
    EngineRegistry,
    QuerySpec,
    Session,
    UnsupportedQueryError,
    compile_queries,
)
from repro.core.graph import DeviceGraph, Graph  # noqa: F401
from repro.core.windows import KHopWindow, TopologicalWindow  # noqa: F401
