"""Core of the paper: graph window queries, DBIndex, I-Index, baselines."""

from repro.core.aggregates import AGGREGATES, register_aggregate  # noqa: F401
from repro.core.api import (  # noqa: F401
    DEFAULT_REGISTRY,
    EngineCapability,
    EngineRegistry,
    QuerySpec,
    Session,
    UnsupportedQueryError,
    compile_queries,
)
from repro.core.graph import DeviceGraph, Graph  # noqa: F401
from repro.core.windows import (  # noqa: F401
    Diff,
    Filter,
    Intersect,
    KHop,
    KHopWindow,
    Topo,
    TopologicalWindow,
    Union,
    WindowExpr,
    canonicalize,
)
