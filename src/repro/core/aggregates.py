"""Distributive / algebraic aggregate functions (paper §3).

Each distributive aggregate is a commutative monoid ``(op, identity)`` — that
is exactly what both the DBIndex two-stage evaluation and the I-Index
inheritance evaluation require (partial aggregates must compose).  Algebraic
aggregates (``avg``) are expressed as a tuple of distributive parts plus a
finalizer, per the classic Gray et al. decomposition the paper leans on.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Monoid:
    name: str
    np_op: Callable  # ufunc with .reduceat / .at
    identity: float

    def jnp_segment(self):
        import jax.ops as jops

        return {
            "add": jops.segment_sum,
            "minimum": jops.segment_min,
            "maximum": jops.segment_max,
        }[self.np_op.__name__]


SUM = Monoid("sum", np.add, 0.0)
MIN = Monoid("min", np.minimum, np.inf)
MAX = Monoid("max", np.maximum, -np.inf)


@dataclasses.dataclass(frozen=True)
class Aggregate:
    """An aggregate = one or two monoid channels + a finalizer."""

    name: str
    monoids: Tuple[Monoid, ...]
    # channel value extractor: attr -> per-channel input values
    prepare: Callable[[np.ndarray], Tuple[np.ndarray, ...]]
    finalize: Optional[Callable] = None  # (channel_results...) -> result

    def finalize_np(self, *chans):
        return self.finalize(*chans) if self.finalize else chans[0]


def _ones_like(a):
    return np.ones(a.shape[0], dtype=np.float64)


AGGREGATES = {
    "sum": Aggregate("sum", (SUM,), lambda a: (a.astype(np.float64),)),
    "count": Aggregate("count", (SUM,), lambda a: (_ones_like(a),)),
    "min": Aggregate("min", (MIN,), lambda a: (a.astype(np.float64),)),
    "max": Aggregate("max", (MAX,), lambda a: (a.astype(np.float64),)),
    "avg": Aggregate(
        "avg",
        (SUM, SUM),
        lambda a: (a.astype(np.float64), _ones_like(a)),
        finalize=lambda s, c: s / np.maximum(c, 1e-30),
    ),
}
