"""Distributive / algebraic aggregate functions (paper §3).

Each distributive aggregate is a commutative monoid ``(op, identity)`` — that
is exactly what both the DBIndex two-stage evaluation and the I-Index
inheritance evaluation require (partial aggregates must compose).  Algebraic
aggregates (``avg``) are expressed as a tuple of distributive parts plus a
finalizer, per the classic Gray et al. decomposition the paper leans on.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Monoid:
    name: str
    np_op: Callable  # ufunc with .reduceat / .at
    identity: float

    def jnp_segment(self):
        import jax.ops as jops

        return {
            "add": jops.segment_sum,
            "minimum": jops.segment_min,
            "maximum": jops.segment_max,
        }[self.np_op.__name__]


SUM = Monoid("sum", np.add, 0.0)
MIN = Monoid("min", np.minimum, np.inf)
MAX = Monoid("max", np.maximum, -np.inf)

MONOIDS = {"sum": SUM, "min": MIN, "max": MAX}


@dataclasses.dataclass(frozen=True)
class Aggregate:
    """An aggregate = one or two monoid channels + a finalizer.

    ``channel_sources`` names what feeds each monoid channel — ``"value"``
    (the attribute vector itself) or ``"ones"`` (an all-ones vector, i.e.
    cardinality).  The source labels are what lets a multi-aggregate plan
    dedup channels: ``sum`` and ``avg`` share the (sum, value) channel,
    ``count`` and ``avg`` share (sum, ones).
    """

    name: str
    monoids: Tuple[Monoid, ...]
    # channel value extractor: attr -> per-channel input values
    prepare: Callable[[np.ndarray], Tuple[np.ndarray, ...]]
    finalize: Optional[Callable] = None  # (channel_results...) -> result
    channel_sources: Tuple[str, ...] = ("value",)

    def finalize_np(self, *chans):
        return self.finalize(*chans) if self.finalize else chans[0]


def _ones_like(a):
    return np.ones(a.shape[0], dtype=np.float64)


AGGREGATES = {
    "sum": Aggregate("sum", (SUM,), lambda a: (a.astype(np.float64),)),
    "count": Aggregate("count", (SUM,), lambda a: (_ones_like(a),),
                       channel_sources=("ones",)),
    "min": Aggregate("min", (MIN,), lambda a: (a.astype(np.float64),)),
    "max": Aggregate("max", (MAX,), lambda a: (a.astype(np.float64),)),
    "avg": Aggregate(
        "avg",
        (SUM, SUM),
        lambda a: (a.astype(np.float64), _ones_like(a)),
        finalize=lambda s, c: s / np.maximum(c, 1e-30),
        channel_sources=("value", "ones"),
    ),
}


# -------------------------------------------------------------------- #
#  Multi-aggregate channel packing (fused query plans)
# -------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ChannelPack:
    """Deduped monoid channels for a set of aggregates over one window.

    ``channels[i]`` is ``(monoid_name, source)``; each distinct pair appears
    once no matter how many aggregates reference it, so k aggregates over
    the same window collapse to ``len(channels) <= k + 1`` segment reduces
    sharing a single gather.  ``agg_channels[j]`` maps aggregate j back to
    its channel indices for finalization.
    """

    aggs: Tuple[str, ...]
    channels: Tuple[Tuple[str, str], ...]
    agg_channels: Tuple[Tuple[int, ...], ...]

    def monoid(self, i: int) -> Monoid:
        return MONOIDS[self.channels[i][0]]

    def channels_of(self, monoid_name: str, source: str = None):
        """Channel indices with the given monoid (and source, if given)."""
        return tuple(
            i for i, (m, s) in enumerate(self.channels)
            if m == monoid_name and (source is None or s == source)
        )

    def prepare_np(self, values: np.ndarray) -> Tuple[np.ndarray, ...]:
        values = np.asarray(values)
        ones = _ones_like(values)
        return tuple(
            values.astype(np.float64) if src == "value" else ones
            for _, src in self.channels
        )

    def finalize(self, agg_i: int, chans: Sequence, maximum=np.maximum):
        """Finalize aggregate ``agg_i`` from the reduced channel results.

        ``maximum`` is ``np.maximum`` or ``jnp.maximum`` so the same ratio
        finalizer (the Gray et al. algebraic decomposition — only ``avg``
        here) serves both the host and device executors bit-identically.
        """
        picked = [chans[j] for j in self.agg_channels[agg_i]]
        if len(picked) == 1:
            return picked[0]
        return picked[0] / maximum(picked[1], 1e-30)


def pack_channels(aggs: Sequence[str]) -> ChannelPack:
    """Collapse a list of aggregates into deduped monoid channels."""
    channels: list = []
    seen = {}
    agg_channels = []
    for name in aggs:
        a = AGGREGATES[name]
        idxs = []
        for m, src in zip(a.monoids, a.channel_sources):
            key = (m.name, src)
            if key not in seen:
                seen[key] = len(channels)
                channels.append(key)
            idxs.append(seen[key])
        agg_channels.append(tuple(idxs))
    return ChannelPack(tuple(aggs), tuple(channels), tuple(agg_channels))
