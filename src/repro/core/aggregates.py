"""Open aggregate registry: distributive / algebraic aggregates (paper §3).

Each distributive aggregate is a commutative monoid ``(op, identity)`` — that
is exactly what both the DBIndex two-stage evaluation and the I-Index
inheritance evaluation require (partial aggregates must compose).  Algebraic
aggregates (``avg``, ``var``, ...) are expressed as a tuple of distributive
*channels* plus a pure finalizer, per the classic Gray et al. decomposition
the paper leans on.

The registry is **open**: :func:`register_aggregate` adds a new aggregate as
a set of monoid channels over the three channel *sources* — ``"value"`` (the
attribute vector), ``"ones"`` (cardinality), ``"square"`` (the squared
attribute) — plus a pure ``finalize(xp, *chans)`` where ``xp`` is ``numpy``
or ``jax.numpy``.  Because every engine executes aggregates through the
shared channel machinery (:class:`ChannelPack`), a registered aggregate
immediately compiles to extra fused channels on the device executors, the
sharded runtime and the serving layer — no core file edits.

Dtype discipline: monoid channels preserve the integer/float class of the
input attribute.  Integer attributes ride int64 channels with per-dtype
identities (``iinfo.min``/``max`` for idempotent monoids) so the host paths
the serving layer's bitwise oracle relies on never silently upcast to
float; only a finalizer (a division, a sqrt) may change the dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

CHANNEL_SOURCES = ("value", "ones", "square")


@dataclasses.dataclass(frozen=True)
class Monoid:
    name: str
    np_op: Callable  # ufunc with .reduceat / .at
    identity: float  # float-channel identity (kept for compatibility)

    def identity_for(self, dtype):
        """Dtype-safe identity: integer channels use the dtype's own
        extrema instead of ``±inf`` (which would force a float upcast)."""
        dtype = np.dtype(dtype)
        if np.issubdtype(dtype, np.integer):
            if self.name == "sum":
                return dtype.type(0)
            info = np.iinfo(dtype)
            return dtype.type(info.max if self.name == "min" else info.min)
        return dtype.type(self.identity)

    def jnp_segment(self):
        import jax.ops as jops

        return {
            "add": jops.segment_sum,
            "minimum": jops.segment_min,
            "maximum": jops.segment_max,
        }[self.np_op.__name__]


SUM = Monoid("sum", np.add, 0.0)
MIN = Monoid("min", np.minimum, np.inf)
MAX = Monoid("max", np.maximum, -np.inf)

MONOIDS = {"sum": SUM, "min": MIN, "max": MAX}


def promote_channel_dtype(values: np.ndarray) -> np.dtype:
    """Channel accumulator dtype for an attribute vector: integer (and bool)
    attributes stay integer (int64 — no silent float upcast on the paths
    the service's bitwise oracle rides), floats widen to float64."""
    dt = np.asarray(values).dtype
    if np.issubdtype(dt, np.integer) or dt == np.bool_:
        return np.dtype(np.int64)
    return np.dtype(np.float64)


@dataclasses.dataclass(frozen=True)
class Aggregate:
    """An aggregate = monoid channels over named sources + a pure finalizer.

    ``channel_sources`` names what feeds each monoid channel — ``"value"``
    (the attribute vector itself), ``"ones"`` (an all-ones vector, i.e.
    cardinality) or ``"square"`` (the squared attribute).  The source labels
    are what lets a multi-aggregate plan dedup channels: ``sum`` and ``avg``
    share the (sum, value) channel, ``count`` and ``avg`` share (sum, ones),
    ``var`` and ``l2`` share (sum, square).

    ``finalize(xp, *chans)`` must be pure array code written against the
    ``xp`` namespace (``numpy`` on host, ``jax.numpy`` inside jitted fused
    executors) so one definition serves both bit-identically.
    """

    name: str
    monoids: Tuple[Monoid, ...]
    channel_sources: Tuple[str, ...] = ("value",)
    finalize: Optional[Callable] = None  # (xp, *channel_results) -> result

    def __post_init__(self):
        assert len(self.monoids) == len(self.channel_sources)
        for src in self.channel_sources:
            assert src in CHANNEL_SOURCES, src

    def prepare(self, values: np.ndarray) -> Tuple[np.ndarray, ...]:
        """Per-channel input vectors, dtype-preserving (see module doc)."""
        values = np.asarray(values)
        dt = promote_channel_dtype(values)
        v = values.astype(dt)
        return tuple(_channel_input(v, src) for src in self.channel_sources)

    def finalize_np(self, *chans):
        return self.finalize_xp(np, *chans)

    def finalize_xp(self, xp, *chans):
        return self.finalize(xp, *chans) if self.finalize else chans[0]


def _channel_input(v: np.ndarray, src: str) -> np.ndarray:
    if src == "ones":
        return np.ones(v.shape[0], dtype=v.dtype)
    if src == "square":
        return v * v
    return v


AGGREGATES: Dict[str, Aggregate] = {}


def register_aggregate(
    name: str,
    monoids: Sequence,
    sources: Sequence[str] = ("value",),
    finalize: Optional[Callable] = None,
    overwrite: bool = False,
) -> Aggregate:
    """Register an aggregate with the open registry.

    ``monoids`` is a sequence of monoid names (``"sum"``/``"min"``/``"max"``)
    or :class:`Monoid` objects; ``sources`` the matching channel sources;
    ``finalize`` an optional pure ``(xp, *chans) -> result``.  The aggregate
    is immediately servable by every engine capability declaring the dynamic
    aggregate set, and its channels fuse with other aggregates sharing a
    window (dedup by ``(monoid, source)``).
    """
    if name in AGGREGATES and not overwrite:
        raise ValueError(f"aggregate {name!r} is already registered "
                         f"(pass overwrite=True to replace it)")
    ms = tuple(m if isinstance(m, Monoid) else MONOIDS[m] for m in monoids)
    if len(ms) != len(tuple(sources)):
        raise ValueError("monoids and sources must have equal length")
    for src in sources:
        if src not in CHANNEL_SOURCES:
            raise ValueError(f"unknown channel source {src!r} "
                             f"(have {CHANNEL_SOURCES})")
    agg = Aggregate(name=name, monoids=ms, channel_sources=tuple(sources),
                    finalize=finalize)
    AGGREGATES[name] = agg
    return agg


class RegisteredAggregates:
    """Live view over the registry for engine capability declarations:
    membership / subset checks consult :data:`AGGREGATES` at query time, so
    a capability declared with ``ALL_REGISTERED`` serves aggregates
    registered *after* the engine was."""

    def __contains__(self, name) -> bool:
        return name in AGGREGATES

    def __iter__(self):
        return iter(AGGREGATES)

    def __len__(self) -> int:
        return len(AGGREGATES)

    def __ge__(self, other) -> bool:  # set(aggs) <= ALL_REGISTERED
        return all(a in AGGREGATES for a in other)

    def issuperset(self, other) -> bool:
        return self.__ge__(other)

    def __hash__(self):  # capabilities are frozen dataclasses
        return hash(type(self))

    def __eq__(self, other):
        return isinstance(other, RegisteredAggregates)


ALL_REGISTERED = RegisteredAggregates()


# -------------------------- built-in aggregates ------------------------ #
register_aggregate("sum", ("sum",), ("value",))
register_aggregate("count", ("sum",), ("ones",))
register_aggregate("min", ("min",), ("value",))
register_aggregate("max", ("max",), ("value",))
register_aggregate(
    "avg", ("sum", "sum"), ("value", "ones"),
    finalize=lambda xp, s, c: s / xp.maximum(c, 1e-30),
)
# derived aggregates compile to extra fused channels with pure finalizers —
# the registration API at work (no engine edits):
register_aggregate("sum_sq", ("sum",), ("square",))
register_aggregate(
    "mean_sq", ("sum", "sum"), ("square", "ones"),
    finalize=lambda xp, s2, c: s2 / xp.maximum(c, 1e-30),
)
register_aggregate(
    "var", ("sum", "sum", "sum"), ("square", "value", "ones"),
    finalize=lambda xp, s2, s, c: s2 / xp.maximum(c, 1e-30)
    - (s / xp.maximum(c, 1e-30)) * (s / xp.maximum(c, 1e-30)),
)
register_aggregate(
    "l2", ("sum",), ("square",), finalize=lambda xp, s2: xp.sqrt(s2),
)


# -------------------------------------------------------------------- #
#  Multi-aggregate channel packing (fused query plans)
# -------------------------------------------------------------------- #
#: canonical aggregate name per (monoid, source) channel — what the
#: algebraic fast paths request from materialized terms to reassemble a
#: composite window's channels (inclusion–exclusion / idempotent combine)
CHANNEL_AGG = {
    ("sum", "value"): "sum",
    ("sum", "ones"): "count",
    ("sum", "square"): "sum_sq",
    ("min", "value"): "min",
    ("max", "value"): "max",
}


@dataclasses.dataclass(frozen=True)
class ChannelPack:
    """Deduped monoid channels for a set of aggregates over one window.

    ``channels[i]`` is ``(monoid_name, source)``; each distinct pair appears
    once no matter how many aggregates reference it, so k aggregates over
    the same window collapse to a handful of segment reduces sharing a
    single gather.  ``agg_channels[j]`` maps aggregate j back to its channel
    indices for finalization.
    """

    aggs: Tuple[str, ...]
    channels: Tuple[Tuple[str, str], ...]
    agg_channels: Tuple[Tuple[int, ...], ...]

    def monoid(self, i: int) -> Monoid:
        return MONOIDS[self.channels[i][0]]

    def channels_of(self, monoid_name: str, source: str = None):
        """Channel indices with the given monoid (and source, if given)."""
        return tuple(
            i for i, (m, s) in enumerate(self.channels)
            if m == monoid_name and (source is None or s == source)
        )

    def prepare_np(self, values: np.ndarray) -> Tuple[np.ndarray, ...]:
        values = np.asarray(values)
        v = values.astype(promote_channel_dtype(values))
        return tuple(_channel_input(v, src) for _, src in self.channels)

    def finalize(self, agg_i: int, chans: Sequence, xp=np):
        """Finalize aggregate ``agg_i`` from the reduced channel results.

        ``xp`` is ``numpy`` or ``jax.numpy`` so the registered pure
        finalizer (the Gray et al. algebraic decomposition) serves both the
        host and device executors bit-identically.
        """
        picked = [chans[j] for j in self.agg_channels[agg_i]]
        return AGGREGATES[self.aggs[agg_i]].finalize_xp(xp, *picked)


def pack_channels(aggs: Sequence[str]) -> ChannelPack:
    """Collapse a list of aggregates into deduped monoid channels."""
    channels: list = []
    seen = {}
    agg_channels = []
    for name in aggs:
        a = AGGREGATES[name]
        idxs = []
        for m, src in zip(a.monoids, a.channel_sources):
            key = (m.name, src)
            if key not in seen:
                seen[key] = len(channels)
                channels.append(key)
            idxs.append(seen[key])
        agg_channels.append(tuple(idxs))
    return ChannelPack(tuple(aggs), tuple(channels), tuple(agg_channels))
