"""EAGR baseline (Mondal & Deshpande, SIGMOD'14) — paper §2 / §6.2.

Faithful-in-structure reimplementation of the comparison system:

* the *overlay* is a bipartite mapping ``owner -> item list`` where items are
  vertex ids or virtual-node ids; initially ``overlay[v] = W(v)`` for every
  vertex (all windows materialized in memory — the paper's central criticism
  of EAGR's memory profile, which we reproduce deliberately);
* each iteration (i) sorts owners by their item lists lexicographically,
  (ii) splits them into equal-sized chunks, (iii) builds an FP-tree per chunk
  and mines frequent itemsets (bi-cliques of the bipartite overlay),
  (iv) materializes the best bi-cliques as virtual nodes and rewrites the
  owner lists through them;
* query evaluation resolves virtual nodes bottom-up (they form a DAG), then
  combines per owner.

The FP-growth miner is bounded (top patterns by saved-edge benefit) exactly
because EAGR's own iterations are bounded (10 in the paper's experiments).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.aggregates import AGGREGATES
from repro.core.graph import Graph
from repro.core.windows import KHopWindow, TopologicalWindow, khop_windows, topological_windows

Array = np.ndarray


# ------------------------------ FP-tree ------------------------------ #
class _FPNode:
    __slots__ = ("item", "count", "parent", "children")

    def __init__(self, item: int, parent: Optional["_FPNode"]):
        self.item = item
        self.count = 0
        self.parent = parent
        self.children: Dict[int, "_FPNode"] = {}


def _mine_chunk(itemsets: List[Array], min_support: int = 2,
                max_patterns: int = 64) -> List[Tuple[np.ndarray, List[int]]]:
    """Mine (itemset, supporting-owner-indices) bicliques from a chunk.

    Single-level FP-tree walk: insert transactions in frequency order, then
    read off maximal root-paths with count >= min_support.  Bounded, greedy,
    benefit-ordered — mirrors EAGR's VNM heuristic without unbounded
    recursion.
    """
    # item frequencies
    freq: Dict[int, int] = {}
    for t in itemsets:
        for it in t.tolist():
            freq[it] = freq.get(it, 0) + 1
    keep = {it for it, c in freq.items() if c >= min_support}
    if not keep:
        return []
    root = _FPNode(-1, None)
    owner_paths: List[Optional[_FPNode]] = []
    for t in itemsets:
        items = [it for it in t.tolist() if it in keep]
        items.sort(key=lambda it: (-freq[it], it))
        node = root
        for it in items:
            nxt = node.children.get(it)
            if nxt is None:
                nxt = _FPNode(it, node)
                node.children[it] = nxt
            nxt.count += 1
            node = nxt
        owner_paths.append(node if node is not root else None)
    # collect candidate paths: walk tree, emit (path_items, count) for nodes
    # with count >= min_support and depth >= 2
    cands: List[Tuple[int, _FPNode, int]] = []  # (benefit, node, depth)
    stack: List[Tuple[_FPNode, int]] = [(c, 1) for c in root.children.values()]
    while stack:
        node, depth = stack.pop()
        if node.count >= min_support and depth >= 2:
            benefit = node.count * depth - (node.count + depth)
            if benefit > 0:
                cands.append((benefit, node, depth))
        for ch in node.children.values():
            stack.append((ch, depth + 1))
    cands.sort(key=lambda x: -x[0])
    out: List[Tuple[np.ndarray, List[int]]] = []
    used_nodes: set = set()
    for benefit, node, depth in cands[: max_patterns * 4]:
        if len(out) >= max_patterns:
            break
        # path to root
        path = []
        cur: Optional[_FPNode] = node
        ok = True
        while cur is not None and cur.item != -1:
            if id(cur) in used_nodes:
                ok = False  # ancestor/descendant already consumed
                break
            path.append(cur.item)
            cur = cur.parent
        if not ok:
            continue
        # supporting owners: owners whose path passes through `node`
        supp = []
        for oi, leaf in enumerate(owner_paths):
            cur = leaf
            while cur is not None and cur.item != -1:
                if cur is node:
                    supp.append(oi)
                    break
                cur = cur.parent
        if len(supp) >= min_support:
            cur = node
            while cur is not None and cur.item != -1:
                used_nodes.add(id(cur))
                cur = cur.parent
            out.append((np.array(sorted(path), dtype=np.int64), supp))
    return out


# ------------------------------ overlay ------------------------------ #
@dataclasses.dataclass
class EAGRIndex:
    n: int
    overlay: List[Array]  # owner -> item list (items >= n are virtual)
    virtual_members: List[Array]  # virtual id - n -> member items
    stats: Dict = dataclasses.field(default_factory=dict)

    def size_bytes(self) -> int:
        s = sum(o.nbytes for o in self.overlay)
        s += sum(v.nbytes for v in self.virtual_members)
        return int(s)

    def query(self, values: Array, agg: str = "sum") -> Array:
        a = AGGREGATES[agg]
        chans = a.prepare(np.asarray(values))
        outs = []
        for monoid, chan in zip(a.monoids, chans):
            ident = monoid.identity_for(chan.dtype)  # dtype-safe (no upcast)
            vvals = np.full(len(self.virtual_members), ident, dtype=chan.dtype)
            # virtual nodes were appended in creation order: later virtuals
            # may reference earlier ones only -> evaluate in order
            for i, members in enumerate(self.virtual_members):
                base = members[members < self.n]
                virt = members[members >= self.n] - self.n
                acc = ident
                if base.size:
                    acc = monoid.np_op(acc, monoid.np_op.reduce(chan[base]))
                if virt.size:
                    acc = monoid.np_op(acc, monoid.np_op.reduce(vvals[virt]))
                vvals[i] = acc
            ans = np.full(self.n, ident, dtype=chan.dtype)
            for v in range(self.n):
                items = self.overlay[v]
                base = items[items < self.n]
                virt = items[items >= self.n] - self.n
                acc = ident
                if base.size:
                    acc = monoid.np_op(acc, monoid.np_op.reduce(chan[base]))
                if virt.size:
                    acc = monoid.np_op(acc, monoid.np_op.reduce(vvals[virt]))
                ans[v] = acc
            outs.append(ans)
        return a.finalize_np(*outs)


def build_eagr(
    g: Graph,
    window,
    iterations: int = 10,
    chunk_size: int = 256,
    memory_limit_bytes: Optional[int] = None,
) -> EAGRIndex:
    """Build the EAGR overlay.  Raises MemoryError if materializing all
    windows exceeds `memory_limit_bytes` (reproducing the paper's OOM runs).
    """
    t0 = time.perf_counter()
    if isinstance(window, KHopWindow):
        wins = khop_windows(g, window.k)
    elif isinstance(window, TopologicalWindow):
        wins = topological_windows(g)
    else:
        raise TypeError(window)
    footprint = sum(w.nbytes for w in wins)
    if memory_limit_bytes is not None and footprint > memory_limit_bytes:
        raise MemoryError(
            f"EAGR vertex-window mapping is {footprint/2**20:.1f} MiB "
            f"> limit {memory_limit_bytes/2**20:.1f} MiB"
        )
    overlay: List[Array] = [w.astype(np.int64) for w in wins]
    virtual_members: List[Array] = []
    n = g.n
    t_mine = 0.0
    for _ in range(iterations):
        order = sorted(range(n), key=lambda v: overlay[v].tolist())
        changed = False
        t1 = time.perf_counter()
        for clo in range(0, n, chunk_size):
            chunk_owner_ids = order[clo : clo + chunk_size]
            chunk_sets = [overlay[v] for v in chunk_owner_ids]
            for itemset, supp in _mine_chunk(chunk_sets):
                vid = n + len(virtual_members)
                virtual_members.append(itemset)
                iset = set(itemset.tolist())
                for oi in supp:
                    v = chunk_owner_ids[oi]
                    rest = np.array(
                        [it for it in overlay[v].tolist() if it not in iset],
                        dtype=np.int64,
                    )
                    overlay[v] = np.sort(np.append(rest, vid))
                changed = True
        t_mine += time.perf_counter() - t1
        if not changed:
            break
    return EAGRIndex(
        n=n,
        overlay=overlay,
        virtual_members=virtual_members,
        stats={
            "t_total_s": time.perf_counter() - t0,
            "t_mine_s": t_mine,
            "num_virtual": len(virtual_members),
            "window_footprint_bytes": footprint,
        },
    )
