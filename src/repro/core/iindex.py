"""Inheritance Index (paper §5) for topological windows on DAGs.

Exploits the containment theorem (5.1): ``W_t(parent) ⊂ W_t(child)``.  Each
vertex stores

* ``PID(v)`` — the *closest* parent = parent with the largest window
  cardinality (ties broken arbitrarily; paper Algorithm 4 lines 7-12),
* ``WD(v)`` — the window difference ``W_t(v) \\ W_t(PID(v))`` (always
  contains ``v`` itself; equals ``{v} ∪ ancestors`` for sources).

Query (Algorithm 5): one sweep in topological order,
``Σ(W_t(v)) = Σ( Σ(W_t(PID(v))), Σ(WD(v)) )``.

TPU adaptation (DESIGN.md §2): the sequential scan is *level-scheduled* —
``level(v) = 1 + level(PID(v))`` along the PID forest, every level is one
fused gather+segment-reduce + one gather of the parents' finished aggregates,
preserving the paper's inheritance reuse while exposing data parallelism.
The difference aggregates ``Σ(WD(v))`` for *all* vertices are a single
segment-reduce (they don't depend on the scan), so the device plan is:

    wd_partial = segment_reduce(values[wd_members], wd_owner)      # once
    for level in 1..depth:  agg[v] = op(agg[PID(v)], wd_partial[v])

An optional *pointer-doubling* schedule (O(log depth) gathers) is provided
for deep chains — used by the §Perf hillclimb.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import numpy as np

from repro.core.aggregates import AGGREGATES
from repro.core.graph import Graph

Array = np.ndarray


@dataclasses.dataclass(frozen=True)
class IIndex:
    n: int
    pid: Array  # int32 [n]; -1 for sources of the PID forest
    wd_members: Array  # int32 [D] concatenated window differences
    wd_offsets: Array  # int64 [n+1]
    level: Array  # int32 [n]: depth along the PID forest (0 for roots)
    topo_order: Array  # int32 [n]
    stats: Dict = dataclasses.field(default_factory=dict, repr=False)

    def wd(self, v: int) -> Array:
        return self.wd_members[self.wd_offsets[v] : self.wd_offsets[v + 1]]

    def window_of(self, v: int) -> Array:
        """Reconstruct W_t(v) by walking the PID chain (invariant tests)."""
        parts = []
        u = int(v)
        while u != -1:
            parts.append(self.wd(u))
            u = int(self.pid[u])
        return np.sort(np.concatenate(parts)) if parts else np.empty(0, np.int32)

    def size_bytes(self) -> int:
        return int(self.pid.nbytes + self.wd_members.nbytes + self.wd_offsets.nbytes)

    # ------------------------- query (NumPy) ------------------------- #
    def query(self, values: Array, agg: str = "sum") -> Array:
        a = AGGREGATES[agg]
        chans = a.prepare(np.asarray(values))
        outs = []
        for monoid, chan in zip(a.monoids, chans):
            ident = monoid.identity_for(chan.dtype)  # dtype-safe (no upcast)
            # Σ(WD(v)) for all v in one reduceat
            wdp = np.full(self.n, ident, dtype=chan.dtype)
            if self.wd_members.size:
                starts = self.wd_offsets[:-1]
                nonempty = np.diff(self.wd_offsets) > 0
                red = monoid.np_op.reduceat(
                    chan[self.wd_members], np.minimum(starts, self.wd_members.size - 1)
                )
                wdp = np.where(nonempty, red, ident)
            ans = wdp.copy()
            for v in self.topo_order:  # inherit parent's finished aggregate
                p = self.pid[v]
                if p != -1:
                    ans[v] = monoid.np_op(ans[v], ans[p])
            outs.append(ans)
        return a.finalize_np(*outs)


def build_iindex(g: Graph, max_live_bytes: int = 2 * 2**30) -> IIndex:
    """Paper Algorithm 4 with bitset windows + liveness-based freeing.

    A vertex's ancestor bitset is dropped as soon as its last child has
    consumed it (the paper's "release memory" step), so peak memory tracks
    the widest live antichain rather than |V| windows.
    """
    t0 = time.perf_counter()
    order = g.topological_order()
    words = (g.n + 63) // 64
    live: Dict[int, Array] = {}
    remaining_children = np.diff(g.out_indptr).astype(np.int64).copy()
    pid = np.full(g.n, -1, dtype=np.int32)
    card = np.zeros(g.n, dtype=np.int64)
    wd_lists: List[Array] = [None] * g.n  # type: ignore

    for v in order:
        v = int(v)
        parents = g.in_neighbors(v)
        # closest parent = parent with max |W_t(parent)|
        best, best_c = -1, -1
        for p in parents:
            if card[p] > best_c:
                best_c, best = int(card[p]), int(p)
        own = np.zeros(words, dtype=np.uint64)
        own[v // 64] |= np.uint64(1) << np.uint64(v % 64)
        for p in parents:
            own |= live[int(p)]
        if best != -1:
            diff = own & ~live[best]
        else:
            diff = own
        wd_lists[v] = np.flatnonzero(
            np.unpackbits(diff.view(np.uint8), bitorder="little")[: g.n]
        ).astype(np.int32)
        pid[v] = best
        card[v] = int(
            np.unpackbits(own.view(np.uint8), bitorder="little")[: g.n].sum()
        )
        live[v] = own
        for p in parents:
            p = int(p)
            remaining_children[p] -= 1
            if remaining_children[p] == 0:
                del live[p]
        if remaining_children[v] == 0:
            # leaf: nobody will consume it
            del live[v]

    sizes = np.array([w.size for w in wd_lists], dtype=np.int64)
    wd_offsets = np.zeros(g.n + 1, dtype=np.int64)
    np.cumsum(sizes, out=wd_offsets[1:])
    wd_members = (
        np.concatenate(wd_lists) if g.n else np.empty(0, np.int32)
    ).astype(np.int32)

    # level along PID forest
    level = np.zeros(g.n, dtype=np.int32)
    for v in order:
        p = pid[v]
        if p != -1:
            level[v] = level[p] + 1

    stats = {
        "t_total_s": time.perf_counter() - t0,
        "num_wd_entries": int(wd_members.size),
        "max_level": int(level.max()) if g.n else 0,
        "avg_wd": float(sizes.mean()) if g.n else 0.0,
    }
    return IIndex(
        n=g.n,
        pid=pid,
        wd_members=wd_members,
        wd_offsets=wd_offsets,
        level=level,
        topo_order=order,
        stats=stats,
    )
