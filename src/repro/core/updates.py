"""Dynamic-graph updates (paper §4.3 and §5.3).

Attribute updates never touch either index (both are structure-only).

Structural updates:

* **DBIndex** — two-phase maintenance (§4.3).  Phase 1 (here): identify the
  owner set ``S`` whose windows changed, drop their links from the primary
  index, build a *secondary* DBIndex over their new windows, and merge.  The
  merged index is exactly correct but possibly less shared than a fresh
  build.  Phase 2: :func:`reorganize` = full rebuild (run periodically).
* **I-Index** — localized rebuild of the affected descendant cone (§5.3's
  four cases collapse to: every vertex whose ancestor set may change is a
  descendant of the edge head ``t``; we recompute PID/WD for exactly that
  cone, reusing untouched entries).  The paper defers efficient update
  algorithms to future work; this is the correct localized variant.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Set, Tuple

import numpy as np

from repro.core.dbindex import DBIndex, _Builder, _blocks_from_windows, build_dbindex
from repro.core.graph import Graph
from repro.core.iindex import IIndex, build_iindex
from repro.core.windows import (
    KHopWindow,
    TopologicalWindow,
    khop_reach_bitsets,
    khop_windows,
)

Array = np.ndarray


# --------------------------- graph edits ------------------------------ #
def insert_edge(g: Graph, s: int, t: int) -> Graph:
    return g.with_edges(np.append(g.src, np.int32(s)), np.append(g.dst, np.int32(t)))


def delete_edge(g: Graph, s: int, t: int) -> Graph:
    hit = np.flatnonzero((g.src == s) & (g.dst == t))
    if not g.directed and hit.size == 0:
        hit = np.flatnonzero((g.src == t) & (g.dst == s))
    if hit.size == 0:
        raise KeyError(f"edge ({s},{t}) not present")
    keep = np.ones(g.n_edges, dtype=bool)
    keep[hit[0]] = False
    return g.with_edges(g.src[keep], g.dst[keep])


# ------------------------ affected-owner sets ------------------------- #
def affected_owners_khop(g_new: Graph, k: int, s: int, t: int) -> Array:
    """Owners whose k-hop window may change after touching edge (s,t):
    vertices that reach `s` within k-1 hops (plus s itself), on either
    endpoint for undirected graphs."""
    rg = Graph(
        n=g_new.n, src=g_new.dst, dst=g_new.src, directed=True
    ) if g_new.directed else g_new
    ends = [s] if g_new.directed else [s, t]
    out: Set[int] = set()
    for e in ends:
        reach = khop_reach_bitsets(rg, max(k - 1, 0), np.array([e], np.int32))
        hit = np.flatnonzero(
            np.unpackbits(reach.view(np.uint8), axis=1, bitorder="little")[:, 0]
        )
        out.update(int(x) for x in hit)
        out.add(int(e))
    return np.array(sorted(out), dtype=np.int32)


def descendants(g: Graph, t: int) -> Array:
    """t plus all vertices reachable from t (directed)."""
    seen = np.zeros(g.n, dtype=bool)
    seen[t] = True
    stack = [int(t)]
    while stack:
        u = stack.pop()
        for w in g.out_neighbors(u):
            if not seen[w]:
                seen[w] = True
                stack.append(int(w))
    return np.flatnonzero(seen).astype(np.int32)


# ------------------------- DBIndex maintenance ------------------------ #
def update_dbindex(
    index: DBIndex, g_new: Graph, window, s: int, t: int
) -> DBIndex:
    """Incremental phase-1 maintenance after inserting/deleting edge (s,t)."""
    if isinstance(window, KHopWindow):
        owners = affected_owners_khop(g_new, window.k, s, t)
        wins = khop_windows(g_new, window.k, owners)
    elif isinstance(window, TopologicalWindow):
        owners = descendants(g_new, t)
        # windows of affected owners on the new graph
        from repro.core.windows import topological_window_single

        wins = [topological_window_single(g_new, int(v)) for v in owners]
    else:
        raise TypeError(window)

    # drop links of affected owners from the primary
    affected = np.zeros(index.n, dtype=bool)
    affected[owners] = True
    owner_ids = index.link_owner_ids
    keep = ~affected[owner_ids]
    kept_block = index.link_block[keep]
    kept_owner = owner_ids[keep]

    # secondary index: blocks over the new windows of affected owners
    b = _Builder(index.n)
    _blocks_from_windows(b, owners, wins)
    sec = b.finish({})

    # merge: secondary block ids offset by primary count
    nb0 = index.num_blocks
    sizes0 = np.diff(index.block_offsets)
    new_sizes = np.diff(sec.block_offsets)
    block_members = np.concatenate([index.block_members, sec.block_members])
    block_offsets = np.zeros(nb0 + sec.num_blocks + 1, dtype=np.int64)
    np.cumsum(np.concatenate([sizes0, new_sizes]), out=block_offsets[1:])
    lb_new = (sec.link_block + nb0).astype(np.int32)
    lo_new = sec.link_owner_ids.astype(np.int32)
    lb = np.concatenate([kept_block, lb_new])
    lo_ = np.concatenate([kept_owner, lo_new])
    order = np.lexsort((lb, lo_))
    lb, lo_ = lb[order], lo_[order]
    link_owner_offsets = np.zeros(index.n + 1, dtype=np.int64)
    np.cumsum(np.bincount(lo_, minlength=index.n), out=link_owner_offsets[1:])
    stats = dict(index.stats)
    stats["incremental_updates"] = stats.get("incremental_updates", 0) + 1
    stats["last_affected_owners"] = int(owners.size)
    return DBIndex(
        n=index.n,
        num_blocks=nb0 + sec.num_blocks,
        block_members=block_members,
        block_offsets=block_offsets,
        link_block=lb,
        link_owner_offsets=link_owner_offsets,
        stats=stats,
    )


def reorganize(g: Graph, window, method: str = "emc", **kw) -> DBIndex:
    """Phase-2 periodic reorganization = fresh build (paper §4.3)."""
    if isinstance(window, TopologicalWindow):
        method = "mc"
    return build_dbindex(g, window, method=method, **kw)


# ------------------------- I-Index maintenance ------------------------ #
def update_iindex(index: IIndex, g_new: Graph, s: int, t: int) -> IIndex:
    """Localized rebuild of the descendant cone of t on the new graph."""
    cone = descendants(g_new, t)
    if cone.size > index.n // 2:
        return build_iindex(g_new)  # cheaper to rebuild outright
    from repro.core.windows import topological_window_single

    pid = index.pid.copy()
    level = index.level.copy()
    wd_lists = [index.wd(v) for v in range(index.n)]
    # recompute in topological order restricted to the cone
    order = g_new.topological_order()
    in_cone = np.zeros(index.n, dtype=bool)
    in_cone[cone] = True
    win_cache: dict = {}

    def win(v: int) -> Array:
        if v not in win_cache:
            win_cache[v] = topological_window_single(g_new, v)
        return win_cache[v]

    for v in order:
        v = int(v)
        if not in_cone[v]:
            continue
        parents = g_new.in_neighbors(v)
        best, best_c = -1, -1
        for p in parents:
            c = win(int(p)).size
            if c > best_c:
                best_c, best = c, int(p)
        wv = win(v)
        if best != -1:
            wd = np.setdiff1d(wv, win(best), assume_unique=True)
        else:
            wd = wv
        pid[v] = best
        wd_lists[v] = wd.astype(np.int32)
        level[v] = 0 if best == -1 else level[best] + 1

    sizes = np.array([w.size for w in wd_lists], dtype=np.int64)
    wd_offsets = np.zeros(index.n + 1, dtype=np.int64)
    np.cumsum(sizes, out=wd_offsets[1:])
    stats = dict(index.stats)
    stats["incremental_updates"] = stats.get("incremental_updates", 0) + 1
    return IIndex(
        n=index.n,
        pid=pid,
        wd_members=np.concatenate(wd_lists).astype(np.int32) if index.n else np.empty(0, np.int32),
        wd_offsets=wd_offsets,
        level=level,
        topo_order=order,
        stats=stats,
    )
