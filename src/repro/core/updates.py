"""Dynamic-graph updates (paper §4.3 and §5.3), single-edge and streaming.

Attribute updates never touch either index (both are structure-only).

Structural updates come in two granularities:

* **Single edge** — :func:`insert_edge` / :func:`delete_edge` plus
  :func:`update_dbindex` / :func:`update_iindex`, kept as thin wrappers over
  the batched path below.
* **Batched streams** — :class:`UpdateBatch` (vectorized edge insert/delete
  sets, optionally timestamped) applied atomically with :func:`apply_batch`.
  :func:`update_dbindex_batch` / :func:`update_iindex_batch` compute the
  affected owner set / descendant cone for the *whole batch* with one
  multi-source bitset BFS instead of one traversal per edge, so maintenance
  cost is proportional to the touched region, not to the batch size times
  the graph.

DBIndex maintenance is the paper's two-phase scheme (§4.3): Phase 1 drops
the affected owners' links from the primary index, builds a *secondary*
index over their new windows, and merges — exactly correct but possibly
less shared than a fresh build.  Phase 2 (:func:`reorganize`) is the
periodic full rebuild; :mod:`repro.core.streaming` decides *when* via a
sharing-loss staleness policy.

I-Index maintenance localizes §5.3's four cases to the descendant cone of
the touched edge heads: every vertex whose ancestor set may change is a
descendant of some head ``t``, so PID/WD/level are recomputed for exactly
that cone.  Cone windows are rebuilt by a cone-restricted topological
sweep whose out-of-cone parents are seeded from the *old* index's windows
(unchanged by definition of the cone) — maintenance never traverses the
graph outside the cone, and is depth-independent.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dbindex import DBIndex, _Builder, _blocks_from_windows, build_dbindex
from repro.core.graph import Graph
from repro.core.iindex import IIndex, build_iindex
from repro.core.windows import (
    KHop,
    KHopWindow,
    Topo,
    TopologicalWindow,
    WindowExpr,
    descendants_multi,
    expr_leaves,
    expr_windows,
    graph_view,
    khop_reach_bitsets,
    khop_windows,
)

Array = np.ndarray


# ---------------------------------------------------------------------- #
#  Update batches
# ---------------------------------------------------------------------- #
OP_INSERT = np.int8(1)
OP_DELETE = np.int8(-1)


@dataclasses.dataclass(frozen=True)
class AttrEdit:
    """One vectorized attribute-value edit: ``attrs[name][vertices] = values``.

    Attribute edits never touch window *membership* (both indices are
    structure-only) — except for :class:`~repro.core.windows.Filter`
    predicates, which the Session maintenance path detects and rebuilds.
    What they do invalidate is cached *results*: exactly the owners whose
    windows contain an edited vertex (the DBIndex reverse link map).
    """

    name: str
    vertices: Array  # int64 [K]
    values: Array  # [K], cast to the attribute's dtype on apply

    def __post_init__(self):
        object.__setattr__(self, "vertices",
                           np.asarray(self.vertices, np.int64))
        object.__setattr__(self, "values", np.asarray(self.values))
        assert self.vertices.shape == self.values.shape


@dataclasses.dataclass(frozen=True)
class UpdateBatch:
    """A vectorized set of edge insertions/deletions, applied atomically.

    ``op[i]`` is +1 (insert) or -1 (delete).  ``ts`` is an optional
    per-edit timestamp used by stream replay (not by maintenance).
    Semantics of :func:`apply_batch`: deletions are resolved against the
    *pre-batch* edge list first, then insertions are appended, then
    ``attr_edits`` (vectorized attribute-value assignments) land on the
    new graph.  ``size`` counts structural edits only — an attr-only batch
    (``size == 0``) skips index/plan maintenance entirely.
    """

    src: Array  # int32 [B]
    dst: Array  # int32 [B]
    op: Array  # int8  [B]
    ts: Optional[Array] = None  # float64 [B] or None
    attr_edits: Tuple[AttrEdit, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "src", np.asarray(self.src, np.int32))
        object.__setattr__(self, "dst", np.asarray(self.dst, np.int32))
        object.__setattr__(self, "op", np.asarray(self.op, np.int8))
        assert self.src.shape == self.dst.shape == self.op.shape
        if self.ts is not None:
            object.__setattr__(self, "ts", np.asarray(self.ts, np.float64))
            assert self.ts.shape == self.src.shape
        object.__setattr__(self, "attr_edits", tuple(self.attr_edits))

    @property
    def size(self) -> int:
        return int(self.src.size)

    @property
    def attr_size(self) -> int:
        return int(sum(e.vertices.size for e in self.attr_edits))

    def edited_attrs(self) -> Tuple[str, ...]:
        return tuple(dict.fromkeys(e.name for e in self.attr_edits))

    @staticmethod
    def inserts(src: Sequence[int], dst: Sequence[int], ts=None) -> "UpdateBatch":
        src = np.asarray(src, np.int32)
        return UpdateBatch(src, np.asarray(dst, np.int32),
                           np.full(src.size, OP_INSERT), ts)

    @staticmethod
    def deletes(src: Sequence[int], dst: Sequence[int], ts=None) -> "UpdateBatch":
        src = np.asarray(src, np.int32)
        return UpdateBatch(src, np.asarray(dst, np.int32),
                           np.full(src.size, OP_DELETE), ts)

    @staticmethod
    def attr_set(name: str, vertices: Sequence[int], values) -> "UpdateBatch":
        """An attribute-only batch: no structural edits, one value edit."""
        empty = np.empty(0, np.int32)
        return UpdateBatch(empty, empty, np.empty(0, np.int8),
                           attr_edits=(AttrEdit(name, vertices, values),))

    def to_bytes(self) -> bytes:
        """Deterministic byte encoding (WAL record / replication payload)."""
        return encode_update_batch(self)

    @staticmethod
    def from_bytes(data: bytes) -> "UpdateBatch":
        return decode_update_batch(data)

    @staticmethod
    def concat(batches: Sequence["UpdateBatch"]) -> "UpdateBatch":
        ts = None
        if batches and all(b.ts is not None for b in batches):
            ts = np.concatenate([b.ts for b in batches])
        return UpdateBatch(
            np.concatenate([b.src for b in batches]) if batches else np.empty(0, np.int32),
            np.concatenate([b.dst for b in batches]) if batches else np.empty(0, np.int32),
            np.concatenate([b.op for b in batches]) if batches else np.empty(0, np.int8),
            ts,
            tuple(e for b in batches for e in b.attr_edits),
        )


def apply_batch(g: Graph, batch: UpdateBatch) -> Graph:
    """Apply a whole batch in O(E + B log B): vectorized key-matched
    deletions (first occurrence per requested multiplicity) + appended
    insertions + attribute-value edits.  Raises KeyError if a deletion has
    no matching edge."""
    g = _apply_structural(g, batch)
    for e in batch.attr_edits:
        arr = np.array(g.attrs[e.name])  # copy: graphs are immutable
        arr[e.vertices] = e.values.astype(arr.dtype)
        g = g.with_attr(e.name, arr)
    return g


def _apply_structural(g: Graph, batch: UpdateBatch) -> Graph:
    if batch.size == 0:
        return g
    ins = batch.op > 0
    dels = batch.op < 0
    new_src, new_dst = g.src, g.dst
    if dels.any():
        del_keys = g.edge_keys(batch.src[dels], batch.dst[dels])
        uk, req = np.unique(del_keys, return_counts=True)
        keys = g.edge_keys()
        order = np.argsort(keys, kind="stable")
        sk = keys[order]
        lo = np.searchsorted(sk, uk, "left")
        hi = np.searchsorted(sk, uk, "right")
        avail = hi - lo
        if (avail < req).any():
            missing = uk[avail < req]
            raise KeyError(
                f"{missing.size} deleted edge(s) not present "
                f"(first key {int(missing[0])})"
            )
        # occurrence rank of every edge within its key group
        grp_starts = np.flatnonzero(np.diff(sk, prepend=np.int64(-1)) != 0)
        grp_len = np.diff(np.append(grp_starts, sk.size))
        rank = np.empty(g.n_edges, np.int64)
        rank[order] = np.arange(g.n_edges) - np.repeat(grp_starts, grp_len)
        pos = np.searchsorted(uk, keys)
        pos_c = np.clip(pos, 0, uk.size - 1)
        matched = (pos < uk.size) & (uk[pos_c] == keys)
        remove = matched & (rank < req[pos_c])
        keep = ~remove
        new_src, new_dst = new_src[keep], new_dst[keep]
    if ins.any():
        new_src = np.append(new_src, batch.src[ins])
        new_dst = np.append(new_dst, batch.dst[ins])
    return g.with_edges(new_src, new_dst)


# --------------------------- graph edits ------------------------------ #
def insert_edge(g: Graph, s: int, t: int) -> Graph:
    return g.with_edges(np.append(g.src, np.int32(s)), np.append(g.dst, np.int32(t)))


def delete_edge(g: Graph, s: int, t: int) -> Graph:
    hit = np.flatnonzero((g.src == s) & (g.dst == t))
    if not g.directed and hit.size == 0:
        hit = np.flatnonzero((g.src == t) & (g.dst == s))
    if hit.size == 0:
        raise KeyError(f"edge ({s},{t}) not present")
    keep = np.ones(g.n_edges, dtype=bool)
    keep[hit[0]] = False
    return g.with_edges(g.src[keep], g.dst[keep])


# ------------------------ affected-owner sets ------------------------- #
# Above this many seed endpoints the multi-source BFS routes through the
# ``bitset_expand`` Pallas kernel (one device hop expands 4096 sources at
# once); below it the NumPy scatter-OR wins because the per-call tile-plan
# build dominates.  Tests force either path via ``use_device``.
DEVICE_BFS_MIN_SEEDS = 4096


def _device_khop_reach_any(g_rev: Graph, k: int, seeds: Array) -> Array:
    """Device mirror of the reverse multi-source BFS: one ``bitset_expand``
    tile plan over the reverse edges, then k expansion hops per 4096-seed
    chunk.  Returns the bool [n] mask of vertices reaching any seed."""
    from repro.kernels.bitset_expand.ops import build_expand_plan, khop_reach

    if g_rev.directed:
        src, dst = g_rev.src, g_rev.dst
    else:  # symmetrize, like the host bitset BFS
        src = np.concatenate([g_rev.src, g_rev.dst])
        dst = np.concatenate([g_rev.dst, g_rev.src])
    order = np.argsort(dst, kind="stable")
    plan = build_expand_plan(src[order], dst[order], g_rev.n)
    mask = np.zeros(g_rev.n, dtype=bool)
    for lo in range(0, seeds.size, 4096):
        chunk = seeds[lo : lo + 4096]
        reach = np.asarray(khop_reach(plan, g_rev.n, chunk, k))
        mask |= (reach != 0).any(axis=1)
    return mask


def affected_owners_khop_multi(
    g_new: Graph, k: int, seeds: Array, use_device: Optional[bool] = None
) -> Array:
    """Owners whose k-hop window may change after a batch touching edges
    with the given seed endpoints: every vertex that reaches *any* seed
    within k-1 hops (plus the seeds).  One multi-source reverse bitset BFS
    for the whole batch — on host NumPy for small batches, through the
    ``bitset_expand`` Pallas kernel above :data:`DEVICE_BFS_MIN_SEEDS`
    (``use_device`` pins either path)."""
    seeds = np.unique(np.asarray(seeds, np.int64))
    if seeds.size == 0:
        return np.empty(0, np.int32)
    rg = g_new.reverse_view()  # O(1) CSR-cache swap (self when undirected)
    if use_device is None:  # auto-routing: device pays off past the
        # threshold, and only when there is at least one hop to expand
        use_device = seeds.size >= DEVICE_BFS_MIN_SEEDS and k > 1
    if use_device:  # an explicit pin is honored even for k == 1
        mask = _device_khop_reach_any(rg, max(k - 1, 0), seeds)
        mask[seeds] = True
        return np.flatnonzero(mask).astype(np.int32)
    out = [seeds]
    for lo in range(0, seeds.size, 4096):
        chunk = seeds[lo : lo + 4096].astype(np.int32)
        reach = khop_reach_bitsets(rg, max(k - 1, 0), chunk)
        out.append(np.flatnonzero((reach != 0).any(axis=1)))
    return np.unique(np.concatenate(out)).astype(np.int32)


def sharded_affected_owners(
    g_new: Graph, window, batch: UpdateBatch, num_shards: int,
    use_device: Optional[bool] = None,
) -> Tuple[Array, List[Array]]:
    """Distributed affected-set computation for one batch: the seed
    endpoints are sliced over ``num_shards`` (the data axis), each shard
    traverses only its slice's reverse balls / descendant cones, and the
    union is exactly the single-host affected set (BFS distributes over
    seed unions).  Returns ``(owners_union, per_shard_owners)`` — the
    per-shard sets are what each shard's dirty tile groups derive from.
    """
    if isinstance(window, KHopWindow):
        seeds = np.unique(_khop_seeds(g_new, batch))
        slices = np.array_split(seeds, max(num_shards, 1))
        per_shard = [
            affected_owners_khop_multi(g_new, window.k, s, use_device=use_device)
            if s.size else np.empty(0, np.int32)
            for s in slices
        ]
    elif isinstance(window, TopologicalWindow):
        seeds = np.unique(batch.dst.astype(np.int64))
        slices = np.array_split(seeds, max(num_shards, 1))
        per_shard = [
            descendants_multi(g_new, s) if s.size else np.empty(0, np.int32)
            for s in slices
        ]
    elif isinstance(window, WindowExpr):
        # composite windows: affected sets distribute over *batch* unions
        # (each leaf's set does), so slice the batch's edits over the data
        # axis — the per-shard union is exactly the single-host set
        idx_slices = np.array_split(np.arange(batch.size), max(num_shards, 1))
        per_shard = [
            affected_owners(
                g_new, window,
                UpdateBatch(batch.src[s], batch.dst[s], batch.op[s]),
                use_device=use_device,
            ) if s.size else np.empty(0, np.int32)
            for s in idx_slices
        ]
    else:
        raise TypeError(window)
    owners = (
        np.unique(np.concatenate(per_shard)).astype(np.int32)
        if per_shard else np.empty(0, np.int32)
    )
    return owners, per_shard


def _leaf_affected(g_new: Graph, leaf, batch: UpdateBatch,
                   use_device: Optional[bool]) -> Array:
    """Affected owners of one *leaf* window for a structural batch."""
    if isinstance(leaf, KHopWindow):
        return affected_owners_khop_multi(
            g_new, leaf.k, _khop_seeds(g_new, batch), use_device=use_device
        )
    if isinstance(leaf, KHop):
        view = graph_view(g_new, leaf.direction)
        if leaf.direction == "in" and g_new.directed:
            # W_in(v) = {u : u →≤k v}: an edit on (s, t) reaches v's window
            # only through t, so the affected set is the forward (k-1)-ball
            # of the heads — which IS the reverse ball in the flipped view
            seeds = batch.dst.astype(np.int64)
        else:
            seeds = _khop_seeds(view, batch)
        return affected_owners_khop_multi(view, leaf.k, seeds,
                                          use_device=use_device)
    if isinstance(leaf, (TopologicalWindow, Topo)):
        return descendants_multi(g_new, batch.dst.astype(np.int64))
    raise TypeError(leaf)


def affected_owners(
    g_new: Graph, window, batch: UpdateBatch,
    use_device: Optional[bool] = None,
) -> Array:
    """Affected-owner set of one batch for any window expression — the
    exact set whose windows the batched maintenance recomputes, and
    therefore the exact invalidation set for any cached per-vertex results
    (everything outside it provably keeps its window, so a serving-layer
    cache entry for it stays valid across the batch).

    K-hop windows: every vertex reaching a touched endpoint within k-1
    hops (plus the endpoints); topological windows: the descendant cone of
    the touched edge heads.  Composite windows inherit the property from
    their leaves: set operations are pointwise on per-vertex member sets,
    so a composite window of ``v`` can only change if some leaf window of
    ``v`` changed — the union of the leaves' affected sets is a sound (and
    leaf-exact) invalidation set.  ``use_device`` pins the k-hop BFS
    routing.
    """
    if isinstance(window, KHopWindow):
        return _leaf_affected(g_new, window, batch, use_device)
    if isinstance(window, TopologicalWindow):
        return _leaf_affected(g_new, window, batch, use_device)
    if isinstance(window, WindowExpr):
        leaves = {l for l in expr_leaves(window)}
        sets = [_leaf_affected(g_new, l, batch, use_device) for l in leaves]
        return (np.unique(np.concatenate(sets)).astype(np.int32)
                if sets else np.empty(0, np.int32))
    raise TypeError(window)


def affected_owners_khop(g_new: Graph, k: int, s: int, t: int) -> Array:
    """Single-edge wrapper (kept for compatibility)."""
    seeds = [s] if g_new.directed else [s, t]
    return affected_owners_khop_multi(g_new, k, np.asarray(seeds, np.int64))


def descendants(g: Graph, t: int) -> Array:
    """t plus all vertices reachable from t (directed)."""
    return descendants_multi(g, np.array([t], np.int64))


def containing_owners(index, g: Graph, window, vertices: Array) -> Array:
    """Owners whose windows *contain* any of the given vertices — the
    attribute-update invalidation set (an attr edit changes the cached
    aggregate of exactly the windows the edited vertex sits in; window
    membership itself is untouched).

    For a DBIndex the bipartite link structure already encodes the reverse
    mapping (:meth:`~repro.core.dbindex.DBIndex.owners_of_members`); for an
    I-Index, ``u ∈ W_t(v)`` iff ``v`` is a descendant of ``u``, so the set
    is one forward multi-source BFS.
    """
    vertices = np.asarray(vertices, np.int64)
    if vertices.size == 0:
        return np.empty(0, np.int32)
    if isinstance(index, DBIndex):
        return index.owners_of_members(vertices)
    if isinstance(index, IIndex):
        return descendants_multi(g, vertices)
    raise TypeError(f"no reverse window map for {type(index).__name__}")


def _khop_seeds(g: Graph, batch: UpdateBatch) -> Array:
    """Endpoints whose reverse (k-1)-hop balls cover all affected owners:
    edge tails for directed graphs, both endpoints for undirected."""
    if g.directed:
        return batch.src.astype(np.int64)
    return np.concatenate([batch.src, batch.dst]).astype(np.int64)


# ---------------------- localized cone windows ------------------------ #
def _pack_members(members: Array, words: int) -> Array:
    b = np.zeros(words, dtype=np.uint64)
    m = np.asarray(members, np.int64)
    np.bitwise_or.at(b, m // 64, np.uint64(1) << (m % 64).astype(np.uint64))
    return b


def _unpack_bits(b: Array, n: int) -> Array:
    return np.flatnonzero(
        np.unpackbits(b.view(np.uint8), bitorder="little")[:n]
    ).astype(np.int32)


def _cone_windows_from_old(g_new: Graph, cone: Array, old_window_of, order: Array):
    """New topological windows for a descendant cone, touching nothing
    outside it.

    Any vertex whose window changed is *in* the cone, so an out-of-cone
    parent's window is unchanged — seed it from the existing index
    (``old_window_of``) instead of re-traversing the graph.  One sweep of
    the cone in topological order (``order``, computed once by the caller)
    then rebuilds each member's window as ``{v} ∪ parents' windows`` with
    packed-bitset unions (Algorithm 4 restricted to the cone).  Returns
    ``(wins, card)`` dicts over cone ∪ parents(cone): packed window
    bitsets and their cardinalities.
    """
    n = g_new.n
    words = (n + 63) // 64
    in_cone = np.zeros(n, dtype=bool)
    in_cone[cone] = True
    wins: dict = {}
    card: dict = {}
    for v in order:
        v = int(v)
        if not in_cone[v]:
            continue
        own = np.zeros(words, dtype=np.uint64)
        own[v // 64] |= np.uint64(1) << np.uint64(v % 64)
        for p in g_new.in_neighbors(v):
            p = int(p)
            if p not in wins:  # out-of-cone parent: old window still exact
                w = np.asarray(old_window_of(p), np.int64)
                wins[p] = _pack_members(w, words)
                card[p] = int(w.size)
            own |= wins[p]
        wins[v] = own
        card[v] = int(
            np.unpackbits(own.view(np.uint8), bitorder="little")[:n].sum()
        )
    return wins, card


# ------------------------- DBIndex maintenance ------------------------ #
def _merge_affected(index: DBIndex, owners: Array, wins: List[Array]) -> DBIndex:
    """Phase-1 merge: drop affected owners' links, append a secondary index
    over their new windows (paper §4.3)."""
    affected = np.zeros(index.n, dtype=bool)
    affected[owners] = True
    owner_ids = index.link_owner_ids
    keep = ~affected[owner_ids]
    kept_block = index.link_block[keep]
    kept_owner = owner_ids[keep]

    # secondary index: blocks over the new windows of affected owners
    b = _Builder(index.n)
    _blocks_from_windows(b, owners, wins)
    sec = b.finish({})

    # merge: secondary block ids offset by primary count
    nb0 = index.num_blocks
    sizes0 = np.diff(index.block_offsets)
    new_sizes = np.diff(sec.block_offsets)
    block_members = np.concatenate([index.block_members, sec.block_members])
    block_offsets = np.zeros(nb0 + sec.num_blocks + 1, dtype=np.int64)
    np.cumsum(np.concatenate([sizes0, new_sizes]), out=block_offsets[1:])
    lb_new = (sec.link_block + nb0).astype(np.int32)
    lo_new = sec.link_owner_ids.astype(np.int32)
    lb = np.concatenate([kept_block, lb_new])
    lo_ = np.concatenate([kept_owner, lo_new])
    order = np.lexsort((lb, lo_))
    lb, lo_ = lb[order], lo_[order]
    link_owner_offsets = np.zeros(index.n + 1, dtype=np.int64)
    np.cumsum(np.bincount(lo_, minlength=index.n), out=link_owner_offsets[1:])
    stats = dict(index.stats)
    stats["incremental_updates"] = stats.get("incremental_updates", 0) + 1
    stats["last_full_rebuild"] = False
    stats["last_affected_owners"] = int(owners.size)
    stats["last_secondary_blocks"] = int(sec.num_blocks)
    stats["num_blocks"] = nb0 + sec.num_blocks
    stats["num_links"] = int(lb.size)
    stats["num_members"] = int(block_members.size)
    return DBIndex(
        n=index.n,
        num_blocks=nb0 + sec.num_blocks,
        block_members=block_members,
        block_offsets=block_offsets,
        link_block=lb,
        link_owner_offsets=link_owner_offsets,
        stats=stats,
    )


def update_dbindex_batch(
    index: DBIndex, g_new: Graph, window, batch: UpdateBatch,
    owners: Optional[Array] = None, use_device: Optional[bool] = None,
) -> Tuple[DBIndex, Array]:
    """Incremental phase-1 maintenance for a whole batch.

    Returns ``(new_index, affected_owners)``; the owner array is what the
    device-plan patchers need to splice only the changed tiles.  The
    primary prefix of the block arrays is unchanged by construction — new
    (secondary) blocks are strictly appended.  Exception: when the batch
    touches more than half the owners, an incremental merge would cost
    (and leak sharing) more than phase 2, so the index is rebuilt outright;
    the result carries ``stats["last_full_rebuild"] = True`` because the
    appended-prefix invariant does NOT hold then and plan patchers must
    rebuild rather than splice (``patch_plan_dbindex`` checks the flag).

    ``owners`` optionally supplies a precomputed affected-owner set (e.g.
    from :func:`sharded_affected_owners`, where each shard traversed only
    its seed slice) so the BFS is not repeated here.  ``use_device`` pins
    the k-hop BFS routing (host NumPy vs the ``bitset_expand`` kernel);
    ignored when ``owners`` is given.
    """
    if batch.size == 0:
        return index, np.empty(0, np.int32)

    def rebuild():
        idx = reorganize(g_new, window)
        idx.stats["last_full_rebuild"] = True
        return idx, np.arange(index.n, dtype=np.int32)

    if owners is None:
        owners = affected_owners(g_new, window, batch, use_device=use_device)
    if owners.size > index.n // 2:
        return rebuild()
    if isinstance(window, KHopWindow):
        wins = khop_windows(g_new, window.k, owners)
    elif isinstance(window, TopologicalWindow):
        # localized: out-of-cone parents' windows come from the old index's
        # exact cover, so nothing outside the cone is traversed
        order = g_new.topological_order()
        packed, _ = _cone_windows_from_old(g_new, owners, index.window_of, order)
        wins = [_unpack_bits(packed[int(v)], index.n) for v in owners]
    elif isinstance(window, WindowExpr):
        # composite windows: re-evaluate the expression for the affected
        # owners only (batched bitset evaluation); the phase-1 merge and
        # everything downstream is window-agnostic
        wins = expr_windows(g_new, window, owners)
    else:
        raise TypeError(window)
    return _merge_affected(index, owners, wins), owners


def update_dbindex(index: DBIndex, g_new: Graph, window, s: int, t: int) -> DBIndex:
    """Single-edge wrapper over the batched path (op is irrelevant to the
    affected-owner computation, which only needs the touched endpoints)."""
    new_index, _ = update_dbindex_batch(
        index, g_new, window, UpdateBatch.inserts([s], [t])
    )
    return new_index


def reorganize(g: Graph, window, method: str = "emc", **kw) -> DBIndex:
    """Phase-2 periodic reorganization = fresh build (paper §4.3)."""
    if isinstance(window, TopologicalWindow):
        method = "mc"
    return build_dbindex(g, window, method=method, **kw)


# ------------------------- I-Index maintenance ------------------------ #
def update_iindex_batch(
    index: IIndex, g_new: Graph, batch: UpdateBatch
) -> Tuple[IIndex, Array]:
    """Localized rebuild of the union of descendant cones of all touched
    edge heads.  Returns ``(new_index, cone)``.

    Windows of the cone are rebuilt by one cone-restricted topological
    sweep seeded from the *old* index's windows for out-of-cone parents
    (their windows are unchanged by definition of the cone), so the update
    never traverses the graph outside the cone; PID/WD/level are then
    recomputed for the cone alone, and the flat WD arrays are spliced
    vectorized (no per-vertex Python rebuild of untouched entries).
    """
    if batch.size == 0:
        return index, np.empty(0, np.int32)
    cone = descendants_multi(g_new, batch.dst.astype(np.int64))
    if cone.size > index.n // 2:  # cheaper to rebuild outright
        return build_iindex(g_new), np.arange(index.n, dtype=np.int32)

    n = index.n
    in_cone = np.zeros(n, dtype=bool)
    in_cone[cone] = True
    order = g_new.topological_order()  # one Kahn pass, shared with the sweep
    wins, card = _cone_windows_from_old(g_new, cone, index.window_of, order)

    pid = index.pid.copy()
    level = index.level.copy()
    wd_new: List[Array] = []
    cone_order: List[int] = []
    for v in order:
        v = int(v)
        if not in_cone[v]:
            continue
        parents = g_new.in_neighbors(v)
        best, best_c = -1, -1
        for p in parents:
            c = card[int(p)]
            if c > best_c:
                best_c, best = c, int(p)
        if best != -1:
            wd = _unpack_bits(wins[v] & ~wins[best], n)
        else:
            wd = _unpack_bits(wins[v], n)
        pid[v] = best
        level[v] = 0 if best == -1 else level[best] + 1
        wd_new.append(wd)
        cone_order.append(v)

    # vectorized splice: keep untouched owners' WD rows, replace the cone's
    old_sizes = np.diff(index.wd_offsets)
    owner_old = np.repeat(np.arange(n, dtype=np.int64), old_sizes)
    keep = ~in_cone[owner_old]
    new_sizes = np.array([w.size for w in wd_new], dtype=np.int64)
    all_owner = np.concatenate(
        [owner_old[keep], np.repeat(np.asarray(cone_order, np.int64), new_sizes)]
    )
    all_members = np.concatenate(
        [index.wd_members[keep]] + ([np.concatenate(wd_new)] if wd_new else [])
    ) if all_owner.size else np.empty(0, np.int32)
    order2 = np.argsort(all_owner, kind="stable")
    wd_members = all_members[order2].astype(np.int32)
    wd_offsets = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(all_owner, minlength=n), out=wd_offsets[1:])

    stats = dict(index.stats)
    stats["incremental_updates"] = stats.get("incremental_updates", 0) + 1
    stats["last_cone_size"] = int(cone.size)
    stats["num_wd_entries"] = int(wd_members.size)
    return (
        IIndex(
            n=n,
            pid=pid,
            wd_members=wd_members,
            wd_offsets=wd_offsets,
            level=level,
            topo_order=order,
            stats=stats,
        ),
        cone,
    )


def update_iindex(index: IIndex, g_new: Graph, s: int, t: int) -> IIndex:
    """Single-edge wrapper over the batched path."""
    new_index, _ = update_iindex_batch(index, g_new, UpdateBatch.inserts([s], [t]))
    return new_index


# ------------------------- serialization (WAL) ------------------------ #
# One UpdateBatch <-> bytes, for the write-ahead log and the replication
# stream.  Layout (all little-endian, arrays raw C-order):
#
#   magic "UB1\0" | flags u8 | n_attr_edits u16 | n_structural u64
#   src i32[m] | dst i32[m] | op i8[m] | [ts f64[m] if flags & 1]
#   per attr edit:
#     name_len u16 | dtype_len u8 | k u64 | name utf-8 | dtype np-str
#     vertices i64[k] | values dtype[k]
#
# The encoding is deterministic (same batch -> same bytes), so WAL records
# can be checksummed and replicas can be diffed byte-for-byte.
_CODEC_MAGIC = b"UB1\x00"
_CODEC_HDR = "<BHQ"
_CODEC_EDIT_HDR = "<HBQ"


def encode_update_batch(batch: UpdateBatch) -> bytes:
    import struct

    flags = 1 if batch.ts is not None else 0
    out = [
        _CODEC_MAGIC,
        struct.pack(_CODEC_HDR, flags, len(batch.attr_edits), batch.size),
        np.ascontiguousarray(batch.src, np.int32).tobytes(),
        np.ascontiguousarray(batch.dst, np.int32).tobytes(),
        np.ascontiguousarray(batch.op, np.int8).tobytes(),
    ]
    if batch.ts is not None:
        out.append(np.ascontiguousarray(batch.ts, np.float64).tobytes())
    for e in batch.attr_edits:
        name = e.name.encode("utf-8")
        dt = np.dtype(e.values.dtype).str.encode("ascii")  # e.g. b"<f4"
        out.append(struct.pack(_CODEC_EDIT_HDR, len(name), len(dt),
                               e.vertices.size))
        out.append(name)
        out.append(dt)
        out.append(np.ascontiguousarray(e.vertices, np.int64).tobytes())
        out.append(np.ascontiguousarray(e.values).tobytes())
    return b"".join(out)


def decode_update_batch(data: bytes) -> UpdateBatch:
    import struct

    mv = memoryview(data)
    if bytes(mv[:4]) != _CODEC_MAGIC:
        raise ValueError("not an UpdateBatch record (bad magic)")
    off = 4
    flags, n_edits, m = struct.unpack_from(_CODEC_HDR, mv, off)
    off += struct.calcsize(_CODEC_HDR)

    def take(dtype, count):
        nonlocal off
        dt = np.dtype(dtype)
        end = off + dt.itemsize * count
        if end > len(data):
            raise ValueError("truncated UpdateBatch record")
        arr = np.frombuffer(mv, dtype=dt, count=count, offset=off).copy()
        off = end
        return arr

    src = take(np.int32, m)
    dst = take(np.int32, m)
    op = take(np.int8, m)
    ts = take(np.float64, m) if flags & 1 else None
    edits = []
    for _ in range(n_edits):
        name_len, dt_len, k = struct.unpack_from(_CODEC_EDIT_HDR, mv, off)
        off += struct.calcsize(_CODEC_EDIT_HDR)
        name = bytes(mv[off: off + name_len]).decode("utf-8")
        off += name_len
        dt = np.dtype(bytes(mv[off: off + dt_len]).decode("ascii"))
        off += dt_len
        verts = take(np.int64, k)
        vals = take(dt, k)
        edits.append(AttrEdit(name, verts, vals))
    if off != len(data):
        raise ValueError(f"{len(data) - off} trailing byte(s) after record")
    return UpdateBatch(src, dst, op, ts, tuple(edits))
