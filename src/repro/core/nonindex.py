"""Non-indexed baseline (paper §4 intro, §6.1).

Computes ``W(v)`` and its aggregate independently for every vertex — a
k-bounded BFS per vertex for k-hop windows, a reverse reachability sweep for
topological windows.  Two variants:

* :func:`query_pervertex` — the paper's literal baseline (per-vertex BFS),
  intentionally unshared; used for the four-orders-of-magnitude comparison.
* :func:`query_batched_bitset` — our vectorized lower bound for a fair "best
  non-index" comparison (batched bitset BFS + masked aggregation).  Serves
  composite :class:`~repro.core.windows.WindowExpr` windows too: a
  combinator is one bitwise op over the packed reachability matrices.

Both are dtype-safe: integer attributes ride integer monoid channels with
per-dtype identities (no silent float upcast; finalizers may change dtype).
"""

from __future__ import annotations

import numpy as np

from repro.core.aggregates import AGGREGATES
from repro.core.graph import Graph
from repro.core.windows import (
    KHopWindow,
    TopologicalWindow,
    expr_reach_bitsets,
    khop_window_single,
    topological_window_single,
    topological_windows,
)

Array = np.ndarray


def query_pervertex(g: Graph, window, values: Array, agg: str = "sum",
                    limit: int | None = None) -> Array:
    """Aggregate per window with zero sharing.  `limit` caps the number of
    vertices processed (for benchmark extrapolation, paper-style)."""
    a = AGGREGATES[agg]
    chans = a.prepare(np.asarray(values))
    n = g.n if limit is None else min(g.n, limit)
    idents = [m.identity_for(c.dtype) for m, c in zip(a.monoids, chans)]
    outs = [np.full(g.n, i, dtype=c.dtype) for i, c in zip(idents, chans)]
    for v in range(n):
        if isinstance(window, KHopWindow):
            w = khop_window_single(g, window.k, v)
        elif isinstance(window, TopologicalWindow):
            w = topological_window_single(g, v)
        else:
            raise TypeError(window)
        for o, m, c, i in zip(outs, a.monoids, chans, idents):
            o[v] = m.np_op.reduce(c[w]) if w.size else i
    return a.finalize_np(*outs)


def query_batched_bitset(g: Graph, window, values: Array, agg: str = "sum") -> Array:
    """Vectorized non-index evaluation via packed reachability bitsets.

    Any window expression is served: leaves are batched bitset BFS runs and
    combinators are single vectorized bitwise ops on the packed matrices
    (:func:`~repro.core.windows.expr_reach_bitsets`), so this doubles as the
    fast independent evaluation path for composite windows.
    """
    a = AGGREGATES[agg]
    chans = a.prepare(np.asarray(values))
    idents = [m.identity_for(c.dtype) for m, c in zip(a.monoids, chans)]
    outs = [np.full(g.n, i, dtype=c.dtype) for i, c in zip(idents, chans)]
    if isinstance(window, TopologicalWindow):
        wins = topological_windows(g)
        for v, w in enumerate(wins):
            for o, m, c, i in zip(outs, a.monoids, chans, idents):
                o[v] = m.np_op.reduce(c[w]) if w.size else i
        return a.finalize_np(*outs)
    batch = 2048
    for lo in range(0, g.n, batch):
        srcs = np.arange(lo, min(lo + batch, g.n), dtype=np.int32)
        reach = expr_reach_bitsets(g, window, srcs)  # [n, words]
        bits = np.unpackbits(
            reach.view(np.uint8), axis=1, bitorder="little"
        )[:, : srcs.size].astype(bool)  # [n, B] member x source
        for o, m, c, i in zip(outs, a.monoids, chans, idents):
            vals = np.where(bits, c[:, None], i)
            o[srcs] = m.np_op.reduce(vals, axis=0)
    return a.finalize_np(*outs)
