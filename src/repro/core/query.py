"""Graph Window Query facade (paper Definition 3).

``GWQ(G, W, Σ, A)`` evaluated through any engine:

* ``nonindex``   — per-vertex BFS (paper baseline)
* ``bitset``     — vectorized non-index (batched bitset BFS)
* ``dbindex``    — Dense Block Index (builds one if not supplied)
* ``iindex``     — Inheritance Index (topological windows on DAGs)
* ``eagr``       — EAGR overlay baseline
* ``jax``        — device data plane (two-stage segment-reduce; sharded
                   variant lives in :mod:`repro.core.engine_jax`)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.aggregates import AGGREGATES
from repro.core.graph import Graph
from repro.core.windows import KHopWindow, TopologicalWindow


@dataclasses.dataclass(frozen=True)
class GraphWindowQuery:
    """A single graph window function (G, W, Σ, A)."""

    window: object  # KHopWindow | TopologicalWindow
    agg: str = "sum"
    attr: str = "val"

    def __post_init__(self):
        assert self.agg in AGGREGATES, f"unknown aggregate {self.agg}"

    def run(
        self,
        g: Graph,
        engine: str = "dbindex",
        index: Optional[object] = None,
        **kw,
    ) -> np.ndarray:
        values = g.attrs[self.attr]
        if engine == "nonindex":
            from repro.core.nonindex import query_pervertex

            return query_pervertex(g, self.window, values, self.agg, **kw)
        if engine == "bitset":
            from repro.core.nonindex import query_batched_bitset

            return query_batched_bitset(g, self.window, values, self.agg)
        if engine == "dbindex":
            if index is None:
                from repro.core.dbindex import build_dbindex

                index = build_dbindex(g, self.window, **kw)
            return index.query(values, self.agg)
        if engine == "iindex":
            assert isinstance(self.window, TopologicalWindow)
            if index is None:
                from repro.core.iindex import build_iindex

                index = build_iindex(g)
            return index.query(values, self.agg)
        if engine == "eagr":
            if index is None:
                from repro.core.eagr import build_eagr

                index = build_eagr(g, self.window, **kw)
            return index.query(values, self.agg)
        if engine == "jax":
            from repro.core import engine_jax

            if index is None:
                from repro.core.dbindex import build_dbindex

                index = build_dbindex(g, self.window, **kw)
            plan = engine_jax.plan_from_dbindex(index)
            return np.asarray(engine_jax.query_dbindex(plan, values, self.agg))
        raise ValueError(f"unknown engine {engine!r}")


def brute_force(g: Graph, window, values: np.ndarray, agg: str = "sum") -> np.ndarray:
    """Reference oracle used by property tests — independent code path."""
    from repro.core.windows import khop_window_single, topological_window_single

    a = AGGREGATES[agg]
    chans = a.prepare(np.asarray(values))
    outs = [np.full(g.n, m.identity) for m in a.monoids]
    for v in range(g.n):
        if isinstance(window, KHopWindow):
            w = khop_window_single(g, window.k, v)
        else:
            w = topological_window_single(g, v)
        for o, m, c in zip(outs, a.monoids, chans):
            o[v] = m.np_op.reduce(c[w]) if w.size else m.identity
    return a.finalize_np(*outs)
