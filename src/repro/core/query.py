"""Graph Window Query facade (paper Definition 3) — thin legacy shim.

The engine dispatch now lives in :mod:`repro.core.api`: backends register
:class:`~repro.core.api.EngineCapability` objects with the
:data:`~repro.core.api.DEFAULT_REGISTRY`, and selection is by declared
capability rather than an if/elif chain.  ``GraphWindowQuery.run`` is kept
as a one-query convenience over that registry; new code should use
:class:`repro.core.api.QuerySpec` + :class:`repro.core.api.Session` (which
fuse multi-aggregate queries and survive update streams).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.aggregates import AGGREGATES
from repro.core.graph import Graph
from repro.core.windows import KHopWindow, TopologicalWindow


@dataclasses.dataclass(frozen=True)
class GraphWindowQuery:
    """A single graph window function (G, W, Σ, A)."""

    window: object  # KHopWindow | TopologicalWindow
    agg: str = "sum"
    attr: str = "val"

    def __post_init__(self):
        assert self.agg in AGGREGATES, f"unknown aggregate {self.agg}"

    def run(
        self,
        g: Graph,
        engine: str = "dbindex",
        index: Optional[object] = None,
        **kw,
    ) -> np.ndarray:
        from repro.core.api import DEFAULT_REGISTRY

        out = DEFAULT_REGISTRY.run(
            engine, g, self.window, g.attrs[self.attr], (self.agg,),
            index=index, **kw,
        )
        return np.asarray(out[self.agg])


def brute_force(g: Graph, window, values: np.ndarray, agg: str = "sum",
                dtype=None) -> np.ndarray:
    """Reference oracle used by property tests — independent code path.

    Per-vertex *set evaluation*: one frontier BFS per leaf, NumPy set ops
    per combinator (:func:`~repro.core.windows.expr_window_single`), then a
    direct monoid reduce over the member set — no bitsets, no blocks, no
    sharing.  ``dtype`` pins the channel dtype (e.g. ``np.float32`` to
    differentially match a device engine bit-for-bit on integer-valued
    attributes: every partial is an exact integer, so evaluation order is
    irrelevant and the finalizer is the only rounding step on both sides).
    """
    from repro.core.windows import (
        expr_window_single,
        khop_window_single,
        topological_window_single,
    )

    a = AGGREGATES[agg]
    chans = a.prepare(np.asarray(values))
    if dtype is not None:
        chans = tuple(c.astype(dtype) for c in chans)
    idents = [m.identity_for(c.dtype) for m, c in zip(a.monoids, chans)]
    outs = [np.full(g.n, i, dtype=c.dtype) for i, c in zip(idents, chans)]
    for v in range(g.n):
        if isinstance(window, KHopWindow):
            w = khop_window_single(g, window.k, v)
        elif isinstance(window, TopologicalWindow):
            w = topological_window_single(g, v)
        else:
            w = expr_window_single(g, window, v)
        for o, m, c, i in zip(outs, a.monoids, chans, idents):
            o[v] = m.np_op.reduce(c[w]) if w.size else i
    return a.finalize_np(*outs)
