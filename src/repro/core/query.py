"""Graph Window Query facade (paper Definition 3) — thin legacy shim.

The engine dispatch now lives in :mod:`repro.core.api`: backends register
:class:`~repro.core.api.EngineCapability` objects with the
:data:`~repro.core.api.DEFAULT_REGISTRY`, and selection is by declared
capability rather than an if/elif chain.  ``GraphWindowQuery.run`` is kept
as a one-query convenience over that registry; new code should use
:class:`repro.core.api.QuerySpec` + :class:`repro.core.api.Session` (which
fuse multi-aggregate queries and survive update streams).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.aggregates import AGGREGATES
from repro.core.graph import Graph
from repro.core.windows import KHopWindow, TopologicalWindow


@dataclasses.dataclass(frozen=True)
class GraphWindowQuery:
    """A single graph window function (G, W, Σ, A)."""

    window: object  # KHopWindow | TopologicalWindow
    agg: str = "sum"
    attr: str = "val"

    def __post_init__(self):
        assert self.agg in AGGREGATES, f"unknown aggregate {self.agg}"

    def run(
        self,
        g: Graph,
        engine: str = "dbindex",
        index: Optional[object] = None,
        **kw,
    ) -> np.ndarray:
        from repro.core.api import DEFAULT_REGISTRY

        out = DEFAULT_REGISTRY.run(
            engine, g, self.window, g.attrs[self.attr], (self.agg,),
            index=index, **kw,
        )
        return np.asarray(out[self.agg])


def brute_force(g: Graph, window, values: np.ndarray, agg: str = "sum") -> np.ndarray:
    """Reference oracle used by property tests — independent code path."""
    from repro.core.windows import khop_window_single, topological_window_single

    a = AGGREGATES[agg]
    chans = a.prepare(np.asarray(values))
    outs = [np.full(g.n, m.identity) for m in a.monoids]
    for v in range(g.n):
        if isinstance(window, KHopWindow):
            w = khop_window_single(g, window.k, v)
        else:
            w = topological_window_single(g, v)
        for o, m, c in zip(outs, a.monoids, chans):
            o[v] = m.np_op.reduce(c[w]) if w.size else m.identity
    return a.finalize_np(*outs)
