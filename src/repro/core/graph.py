"""Graph containers.

Two tiers, mirroring DESIGN.md:

* :class:`Graph` — host-side (NumPy) container used by the index *builders*
  (the control plane).  Stores edges as COO plus cached CSR adjacency in both
  directions, vertex attributes, and DAG metadata when acyclic.
* :class:`DeviceGraph` — static-shape JAX arrays for the query *data plane*:
  COO sorted by destination (the layout the fused gather+segment-reduce
  kernel consumes) plus CSR offsets.

All vertex ids are int32.  Graphs are immutable; structural updates produce
new `Graph` objects via :mod:`repro.core.updates`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

Array = np.ndarray


def _build_csr(n: int, src: Array, dst: Array) -> Tuple[Array, Array]:
    """CSR over (src -> dst): returns (indptr [n+1], indices sorted by src)."""
    order = np.argsort(src, kind="stable")
    indices = dst[order].astype(np.int32)
    counts = np.bincount(src, minlength=n).astype(np.int64)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, indices


@dataclasses.dataclass(frozen=True)
class Graph:
    """Immutable host-side graph.

    For undirected graphs, ``src``/``dst`` store each edge once; the
    symmetrized adjacency is materialized in the CSR caches.
    """

    n: int
    src: Array  # int32 [E]
    dst: Array  # int32 [E]
    directed: bool = True
    attrs: Dict[str, Array] = dataclasses.field(default_factory=dict)

    # caches (filled in __post_init__)
    out_indptr: Array = dataclasses.field(default=None, repr=False)
    out_indices: Array = dataclasses.field(default=None, repr=False)
    in_indptr: Array = dataclasses.field(default=None, repr=False)
    in_indices: Array = dataclasses.field(default=None, repr=False)

    def __post_init__(self):
        src = np.asarray(self.src, dtype=np.int32)
        dst = np.asarray(self.dst, dtype=np.int32)
        object.__setattr__(self, "src", src)
        object.__setattr__(self, "dst", dst)
        if src.size:
            assert src.min() >= 0 and src.max() < self.n, "src out of range"
            assert dst.min() >= 0 and dst.max() < self.n, "dst out of range"
        if self.directed:
            o_ptr, o_idx = _build_csr(self.n, src, dst)
            i_ptr, i_idx = _build_csr(self.n, dst, src)
        else:
            both_src = np.concatenate([src, dst])
            both_dst = np.concatenate([dst, src])
            o_ptr, o_idx = _build_csr(self.n, both_src, both_dst)
            i_ptr, i_idx = o_ptr, o_idx
        object.__setattr__(self, "out_indptr", o_ptr)
        object.__setattr__(self, "out_indices", o_idx)
        object.__setattr__(self, "in_indptr", i_ptr)
        object.__setattr__(self, "in_indices", i_idx)

    # ------------------------------------------------------------------ #
    @property
    def n_edges(self) -> int:
        return int(self.src.size)

    def out_neighbors(self, v: int) -> Array:
        return self.out_indices[self.out_indptr[v] : self.out_indptr[v + 1]]

    def in_neighbors(self, v: int) -> Array:
        return self.in_indices[self.in_indptr[v] : self.in_indptr[v + 1]]

    def degree_out(self) -> Array:
        return np.diff(self.out_indptr)

    def with_attr(self, name: str, values: Array) -> "Graph":
        values = np.asarray(values)
        assert values.shape[0] == self.n
        attrs = dict(self.attrs)
        attrs[name] = values
        return dataclasses.replace(self, attrs=attrs)

    def with_edges(self, src: Array, dst: Array) -> "Graph":
        """New graph, same vertices/attrs, different edge set."""
        return Graph(
            n=self.n,
            src=np.asarray(src, np.int32),
            dst=np.asarray(dst, np.int32),
            directed=self.directed,
            attrs=dict(self.attrs),
        )

    def reverse_view(self) -> "Graph":
        """Edge-flipped graph sharing this graph's CSR caches, O(1).

        The reverse adjacency already exists (``in_indptr``/``in_indices``),
        so the flipped view just swaps the cached arrays instead of paying
        ``__post_init__``'s edge sort + CSR builds — reverse traversals
        (topological oracles, affected-owner BFS, ``KHop(k, "in")`` leaves)
        sit in per-batch maintenance hot paths."""
        if not self.directed:
            return self
        rv = object.__new__(Graph)
        object.__setattr__(rv, "n", self.n)
        object.__setattr__(rv, "src", self.dst)
        object.__setattr__(rv, "dst", self.src)
        object.__setattr__(rv, "directed", True)
        object.__setattr__(rv, "attrs", self.attrs)
        object.__setattr__(rv, "out_indptr", self.in_indptr)
        object.__setattr__(rv, "out_indices", self.in_indices)
        object.__setattr__(rv, "in_indptr", self.out_indptr)
        object.__setattr__(rv, "in_indices", self.out_indices)
        return rv

    # --------------------------- edge keys ---------------------------- #
    def edge_keys(self, src: Optional[Array] = None, dst: Optional[Array] = None) -> Array:
        """Canonical int64 key per edge (orientation-insensitive when
        undirected).  Defaults to the graph's own edge list — the batch
        update machinery uses these for vectorized membership/deletion."""
        src = self.src if src is None else np.asarray(src, np.int64)
        dst = self.dst if dst is None else np.asarray(dst, np.int64)
        s = src.astype(np.int64)
        d = dst.astype(np.int64)
        if not self.directed:
            s, d = np.minimum(s, d), np.maximum(s, d)
        return s * np.int64(self.n) + d

    def contains_edges(self, src: Array, dst: Array) -> Array:
        """Boolean mask: is each (src[i], dst[i]) present in the edge list?"""
        return np.isin(self.edge_keys(src, dst), self.edge_keys())

    # ------------------------------ DAG ------------------------------- #
    def topological_order(self) -> Array:
        """Kahn's algorithm. Raises ValueError on cycles. Directed only."""
        if not self.directed:
            raise ValueError("topological order requires a directed graph")
        indeg = np.bincount(self.dst, minlength=self.n).astype(np.int64)
        order = np.empty(self.n, dtype=np.int32)
        frontier = np.flatnonzero(indeg == 0).astype(np.int32)
        pos = 0
        indeg = indeg.copy()
        while frontier.size:
            order[pos : pos + frontier.size] = frontier
            pos += frontier.size
            # decrement indegree of all out-neighbors of the frontier
            nbr = np.concatenate(
                [self.out_indices[self.out_indptr[v] : self.out_indptr[v + 1]] for v in frontier]
            ) if frontier.size < 4096 else self._frontier_out(frontier)
            if nbr.size == 0:
                frontier = np.empty(0, np.int32)
                continue
            dec = np.bincount(nbr, minlength=self.n)
            indeg -= dec
            frontier = np.flatnonzero((indeg == 0) & (dec > 0)).astype(np.int32)
        if pos != self.n:
            raise ValueError("graph has a cycle; not a DAG")
        return order

    def _frontier_out(self, frontier: Array) -> Array:
        starts = self.out_indptr[frontier]
        stops = self.out_indptr[frontier + 1]
        lens = stops - starts
        total = int(lens.sum())
        if total == 0:
            return np.empty(0, np.int32)
        out = np.empty(total, np.int32)
        # vectorized multi-slice copy via repeat/cumsum trick
        idx = np.repeat(starts, lens) + (
            np.arange(total) - np.repeat(np.cumsum(lens) - lens, lens)
        )
        out[:] = self.out_indices[idx]
        return out

    def dag_levels(self) -> Array:
        """level[v] = longest path length from any source to v (0-based)."""
        order = self.topological_order()
        level = np.zeros(self.n, dtype=np.int32)
        for v in order:
            nbr = self.out_neighbors(v)
            if nbr.size:
                np.maximum.at(level, nbr, level[v] + 1)
        return level

    def is_dag(self) -> bool:
        try:
            self.topological_order()
            return True
        except ValueError:
            return False


# ---------------------------------------------------------------------- #
#  Device-side representation
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class DeviceGraph:
    """Static-shape JAX arrays for the query data plane.

    ``edge_src``/``edge_dst`` are sorted by ``edge_dst`` so that segment
    reductions into the destination vertex see contiguous segment ids.  For
    undirected graphs the edge list is pre-symmetrized.  Padding edges (if
    any) point at vertex id ``n`` (one-past-the-end sink row).
    """

    n: int
    n_edges: int  # valid edges (pre-padding)
    edge_src: "jax.Array"  # int32 [E_pad]
    edge_dst: "jax.Array"  # int32 [E_pad], sorted ascending
    attrs: Dict[str, "jax.Array"] = dataclasses.field(default_factory=dict)

    @staticmethod
    def from_graph(g: Graph, pad_to: Optional[int] = None) -> "DeviceGraph":
        import jax.numpy as jnp

        if g.directed:
            src, dst = g.src, g.dst
        else:
            src = np.concatenate([g.src, g.dst])
            dst = np.concatenate([g.dst, g.src])
        order = np.argsort(dst, kind="stable")
        src, dst = src[order], dst[order]
        e = src.size
        pad_to = pad_to or e
        assert pad_to >= e
        if pad_to > e:
            src = np.pad(src, (0, pad_to - e), constant_values=g.n)
            dst = np.pad(dst, (0, pad_to - e), constant_values=g.n)
        attrs = {k: jnp.asarray(v) for k, v in g.attrs.items()}
        return DeviceGraph(
            n=g.n,
            n_edges=e,
            edge_src=jnp.asarray(src, jnp.int32),
            edge_dst=jnp.asarray(dst, jnp.int32),
            attrs=attrs,
        )
