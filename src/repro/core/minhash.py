"""MinHash signatures for window clustering (paper §4.2.1 / §4.2.2).

The paper computes, for each vertex ``v``, ``m`` min-hashes of the member set
``W(v)`` and clusters vertices with identical signatures (Jaccard-similar
windows collide with probability ``J(u,v)^m``).

Key implementation insight (our TPU adaptation, also a big host-side win):
the min-hash of a k-hop window satisfies the recurrence

    sig_{r+1}(v) = min( h(v), min_{u in N_out(v)} sig_r(u) )

because ``W_{r+1}(v) = {v} ∪ ⋃_{u∈N_out(v)} W_r(u)``.  So signatures are
computed by ``k`` rounds of *segment-min message passing* — never
materializing any window — which is the same fused gather+segment-reduce
primitive the query data plane uses (``repro/kernels/segment_reduce``).
This strengthens the paper's "compute windows on the fly" memory argument:
clustering needs **no** window materialization at all.

For topological windows one sweep in topological order is exact:
``sig(v) = min(h(v), min_{p in parents(v)} sig(p))``.

EMC (§4.2.2) = run only ``k' < k`` rounds (default 1) and cluster on the
estimated signatures; justified by the paper's Theorem 4.1 corollary that
Jaccard similarity is non-decreasing in hop count.
"""

from __future__ import annotations

import numpy as np

from repro.core.graph import Graph

# Odd multipliers for multiply-shift hashing (splitmix64-derived constants).
_MIX = np.uint64(0x9E3779B97F4A7C15)
_MIX2 = np.uint64(0xBF58476D1CE4E5B9)
_MIX3 = np.uint64(0x94D049BB133111EB)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + _MIX).astype(np.uint64)
    x = ((x ^ (x >> np.uint64(30))) * _MIX2).astype(np.uint64)
    x = ((x ^ (x >> np.uint64(27))) * _MIX3).astype(np.uint64)
    return (x ^ (x >> np.uint64(31))).astype(np.uint64)


def vertex_hashes(n: int, num_hashes: int, seed: int = 0) -> np.ndarray:
    """h_i(v) for all v: [n, m] uint64, each column an independent hash."""
    ids = np.arange(n, dtype=np.uint64)[:, None]
    salts = _splitmix64(np.arange(num_hashes, dtype=np.uint64) + np.uint64(seed * 1315423911))
    return _splitmix64(ids * np.uint64(0x100000001B3) ^ salts[None, :])


def minhash_signatures_khop(
    g: Graph, hops: int, num_hashes: int = 4, seed: int = 0
) -> np.ndarray:
    """[n, m] uint64 min-hash signatures of the `hops`-hop windows."""
    sig = vertex_hashes(g.n, num_hashes, seed)
    if g.directed:
        src, dst = g.src, g.dst
    else:
        src = np.concatenate([g.src, g.dst])
        dst = np.concatenate([g.dst, g.src])
    # message passing: sig[src] receives min of sig[dst]?  The recurrence
    # pulls from OUT-neighbors: sig'(v) = min(sig(v), min_{(v,u)} sig(u)).
    # Group edges by the *source* so reduceat reduces over out-neighbors.
    order = np.argsort(src, kind="stable")
    s_sorted, d_sorted = src[order], dst[order]
    s_unique, group_starts = np.unique(s_sorted, return_index=True)
    for _ in range(hops):
        gathered = sig[d_sorted]  # [E, m]
        reduced = np.minimum.reduceat(gathered, group_starts, axis=0)
        new = sig.copy()
        new[s_unique] = np.minimum(new[s_unique], reduced)
        if np.array_equal(new, sig):
            break
        sig = new
    return sig


def minhash_signatures_topo(g: Graph, num_hashes: int = 4, seed: int = 0) -> np.ndarray:
    """Exact min-hash of ancestor windows via one topological sweep."""
    sig = vertex_hashes(g.n, num_hashes, seed)
    for v in g.topological_order():
        ch = g.out_neighbors(v)
        if ch.size:
            sig[ch] = np.minimum(sig[ch], sig[v][None, :])
    return sig


def cluster_by_signature(sig: np.ndarray) -> np.ndarray:
    """Group rows with identical signatures: returns cluster_id [n] int32,
    ids dense in [0, n_clusters)."""
    _, inverse = np.unique(sig, axis=0, return_inverse=True)
    return inverse.astype(np.int32)
