"""Pallas TPU kernel: causal GQA flash attention (prefill hot spot).

Standard streaming-softmax tiling: grid (batch*kv_head, q_group, q_block,
kv_block) with the kv_block dimension innermost/sequential; running
(max, sum, acc) live in VMEM scratch and are rescaled per kv tile.  Causal
tiles beyond the diagonal are skipped via ``pl.when`` (they still appear in
the grid, but do no work — Mosaic elides the DMA for untouched blocks).

Block sizes default to (BQ=512, BK=512) with D = head_dim on lanes; VMEM
per step ~ q 512·128·4 + k/v 2·512·128·4 + scores 512·512·4 ≈ 2.3 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, bq: int, bk: int, causal: bool, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _reset():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _work():
        q = q_ref[0, 0]  # [BQ, D]
        k = k_ref[0, 0]  # [BK, D]
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [BQ, BK]
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_ref[...]  # [BQ, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # [BQ, BK]
        alpha = jnp.exp(m_prev - m_new)  # [BQ, 1]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret")
)
def flash_attention(
    q, k, v, *, causal: bool = True,
    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK, interpret: bool = False,
):
    """q: [B, Hq, S, D]; k, v: [B, Hkv, S, D] -> [B, Hq, S, D].

    GQA folding: q heads are grouped so each kv head serves Hq/Hkv query
    groups; grid axis 1 walks the groups (k/v index map ignores it).
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)
    qr = q.reshape(b * hkv, group, s, d)
    kr = k.reshape(b * hkv, 1, s, d)
    vr = v.reshape(b * hkv, 1, s, d)
    grid = (b * hkv, group, s // bq, s // bk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda h, g, qi, ki: (h, g, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda h, g, qi, ki: (h, 0, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda h, g, qi, ki: (h, 0, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda h, g, qi, ki: (h, g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, group, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(
                pltpu.PARALLEL, pltpu.PARALLEL, pltpu.PARALLEL, pltpu.ARBITRARY,
            )
        ),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, s, d)
