"""Jit'd attention entry points with backend-aware dispatch.

``attention(...)`` picks the Pallas flash kernel on TPU (or in interpret
mode for tests) and the jnp oracle otherwise.  The model code calls only
this wrapper, so the dry-run lowers the Pallas kernel while CPU smoke tests
ride the oracle at tiny shapes.
"""

from __future__ import annotations

import jax

from repro.kernels.compat import default_interpret
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import decode_ref, mha_ref


def attention(q, k, v, *, causal: bool = True, local_window=None,
              use_pallas: bool | None = None, interpret: bool | None = None,
              bq: int = 512, bk: int = 512):
    """q: [B, Hq, S, D]; k/v: [B, Hkv, S, D] -> [B, Hq, S, D]."""
    s = q.shape[2]
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu" and s % bq == 0 and local_window is None
    if not use_pallas:
        return mha_ref(q, k, v, causal=causal, local_window=local_window)
    if interpret is None:
        interpret = default_interpret()
    return flash_attention(q, k, v, causal=causal, bq=bq, bk=bk, interpret=interpret)


def decode_attention(q, k_cache, v_cache, length):
    """Single-token decode over a KV cache (XLA path; the sharded
    flash-decode lives in repro.serve.engine via shard_map)."""
    return decode_ref(q, k_cache, v_cache, length)
