"""Oracle: causal GQA attention (pure jnp, materializes the score matrix)."""

from __future__ import annotations

import jax.numpy as jnp


def mha_ref(q, k, v, causal: bool = True, local_window: int | None = None):
    """q: [B, Hq, S, D]; k, v: [B, Hkv, S, D]; Hq % Hkv == 0 (GQA).

    Returns [B, Hq, S, D].  `local_window` masks keys further than W back.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(d).astype(q.dtype)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask &= ki <= qi
    if local_window is not None:
        mask &= ki > qi - local_window
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1) if False else _softmax(scores)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def _softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def decode_ref(q, k_cache, v_cache, length, window: int | None = None):
    """One decode step.  q: [B, Hq, D]; caches: [B, Hkv, S, D]; length: int
    or [B] valid cache entries.  Returns [B, Hq, D].

    GQA via grouped einsum — never `repeat`s the cache to Hq heads (a
    6x cache blow-up on grok-1; -13 GiB/device measured, EXPERIMENTS
    §Perf).  `window` masks keys older than `length - window` (sliding
    window decode for the long_500k bonus rows).
    """
    b, hq, d = q.shape
    hkv = k_cache.shape[1]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, d)
    scores = jnp.einsum(
        "bhgd,bhsd->bhgs", qg, k_cache, preferred_element_type=jnp.float32
    ) / jnp.sqrt(d).astype(jnp.float32)
    s = k_cache.shape[2]
    length = jnp.asarray(length).reshape(-1, 1)
    pos = jnp.arange(s)[None, :]
    valid = pos < length
    if window is not None:
        valid &= pos >= length - window
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    p = _softmax(scores).astype(v_cache.dtype)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v_cache)
    return out.reshape(b, hq, d)


import jax  # noqa: E402  (kept at bottom to avoid unused warning churn)
