"""Pallas TPU kernels for the perf-critical data planes.

* ``segment_reduce`` — fused gather + tiled segment-sum (MXU one-hot
  matmul).  The paper's entire query data plane (DBIndex pass 1/2, I-Index
  window differences) plus GNN message passing and recsys EmbeddingBag.
* ``bitset_expand``  — packed-uint32 BFS hop (segmented OR scan + 16-bit
  split boundary extraction).  The paper's window computation.
* ``fm_interaction`` — FM sum-square second-order term (memory-bound fuse).
* ``flash_attention``— causal GQA streaming-softmax attention (LM prefill).

Every kernel ships ``ops.py`` (jit'd wrapper, backend dispatch) and
``ref.py`` (oracle used by the allclose sweeps in tests/).
"""
