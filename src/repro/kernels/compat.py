"""Pallas API drift shims shared by all kernels.

jax >= 0.5 renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
the toolchain image pins 0.4.x.  Keep every version-compatibility alias —
and every other per-kernel copy-pasted default, like the off-TPU interpret
fallback — here so a toolchain upgrade is a one-file change (ROADMAP open
item).
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def default_interpret() -> bool:
    """Pallas kernels run compiled on TPU and in interpret mode everywhere
    else (CPU CI, tests) — the shared ``interpret=None`` resolution for
    every kernel's ops wrapper."""
    import jax

    return jax.default_backend() != "tpu"
