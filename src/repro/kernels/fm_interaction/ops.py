"""Jit'd wrapper for the FM interaction kernel with CPU fallback."""

from __future__ import annotations

from repro.kernels.compat import default_interpret
from repro.kernels.fm_interaction.fm_interaction import fm_interaction
from repro.kernels.fm_interaction.ref import fm_interaction_ref


def fm_second_order(emb, use_pallas: bool = True, interpret=None):
    """emb: [B, F, K] -> [B].  Pallas on TPU / interpret; jnp oracle else."""
    if not use_pallas:
        return fm_interaction_ref(emb)
    if interpret is None:
        interpret = default_interpret()
    return fm_interaction(emb, interpret=interpret)
