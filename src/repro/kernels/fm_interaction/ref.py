"""Oracle for the FM second-order interaction (Rendle, ICDM'10).

``y[b] = 0.5 * sum_k ( (sum_f v[b,f,k])^2 - sum_f v[b,f,k]^2 )``

— the O(n*k) sum-square factorization of the pairwise dot interactions.
"""

from __future__ import annotations

import jax.numpy as jnp


def fm_interaction_ref(emb):
    """emb: [B, F, K] field embeddings (already weighted by feature value).
    Returns [B] second-order interaction."""
    s = jnp.sum(emb, axis=1)  # [B, K]
    ss = jnp.sum(emb * emb, axis=1)  # [B, K]
    return 0.5 * jnp.sum(s * s - ss, axis=-1)
