"""Pallas TPU kernel: fused FM second-order interaction.

Tiles the batch (rows) and keeps the full [F, K] field block per example in
VMEM; computes the sum-square factorization in one pass so the [B, F, K]
embedding tensor is read exactly once from HBM (the op is purely
memory-bound: 3 flops/float).  Lane layout: K padded to 128; F on sublanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

DEFAULT_TB = 256


def _fm_kernel(emb_ref, out_ref):
    emb = emb_ref[...]  # [TB, F, Kp]
    s = jnp.sum(emb, axis=1)  # [TB, Kp]
    ss = jnp.sum(emb * emb, axis=1)
    out_ref[...] = 0.5 * jnp.sum(s * s - ss, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def fm_interaction(emb, tb: int = DEFAULT_TB, interpret: bool = False):
    """emb: [B, F, K] f32 -> [B] f32.  B padded to a TB multiple."""
    b, f, k = emb.shape
    kp = (-k) % 128
    bp = (-b) % tb
    if kp or bp:
        emb = jnp.pad(emb, ((0, bp), (0, 0), (0, kp)))
    bb = emb.shape[0]
    out = pl.pallas_call(
        _fm_kernel,
        grid=(bb // tb,),
        in_specs=[pl.BlockSpec((tb, f, emb.shape[2]), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bb, 1), jnp.float32),
        compiler_params=_CompilerParams(dimension_semantics=(pltpu.PARALLEL,)),
        interpret=interpret,
    )(emb.astype(jnp.float32))
    return out[:b, 0]
