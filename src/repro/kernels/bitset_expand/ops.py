"""Jit'd wrapper: k-hop reachability sweep on device.

Reuses the segment-reduce tile plan (segments = destination vertices).  One
call = one BFS hop for up to ``32 * W`` sources (W uint32 lane words, default
128 -> 4096 sources), the on-device mirror of
:func:`repro.core.windows.khop_reach_bitsets`.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitset_expand.bitset_expand import (
    DEFAULT_TM,
    DEFAULT_TS,
    bitset_expand_tiled,
)
from repro.kernels.compat import default_interpret as _default_interpret
from repro.kernels.segment_reduce.ops import TilePlan, build_tile_plan


def build_expand_plan(edge_src: np.ndarray, edge_dst: np.ndarray, n: int,
                      tm: int = DEFAULT_TM, ts: int = DEFAULT_TS) -> TilePlan:
    """Edges must be sorted by dst (DeviceGraph layout)."""
    return build_tile_plan(edge_src, edge_dst, n, tm=tm, ts=ts)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitset_expand(plan: TilePlan, reach: jnp.ndarray,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """One expansion hop: returns new reach [n_pad, W] (same shape as input,
    padded to num_out_tiles*TS rows)."""
    interpret = _default_interpret() if interpret is None else interpret
    n_pad = plan.num_out_tiles * plan.ts
    if reach.shape[0] != n_pad:
        reach = jnp.pad(reach, ((0, n_pad - reach.shape[0]), (0, 0)))
    gathered = jnp.take(reach, plan.gather_padded, axis=0)
    return bitset_expand_tiled(
        gathered,
        reach,
        plan.seg_tiles,
        plan.m2out,
        plan.first_visit,
        num_out_tiles=plan.num_out_tiles,
        tm=plan.tm,
        ts=plan.ts,
        interpret=interpret,
    )


def khop_reach(plan: TilePlan, n: int, sources: np.ndarray, k: int,
               lanes: int = 128, interpret: Optional[bool] = None) -> jnp.ndarray:
    """Full k-hop sweep for <= 32*lanes sources; returns [n, lanes] uint32."""
    sources = np.asarray(sources)
    assert sources.size <= 32 * lanes
    reach0 = np.zeros((n, lanes), dtype=np.uint32)
    cols = np.arange(sources.size)
    reach0[sources, cols // 32] |= np.uint32(1) << (cols % 32).astype(np.uint32)
    r = jnp.asarray(reach0)
    for _ in range(k):
        r = bitset_expand(plan, r, interpret=interpret)[: n]
    return r[:n]
