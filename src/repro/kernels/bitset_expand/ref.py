"""Oracle for packed-bitset frontier expansion (k-hop BFS step).

``out[s] = OR over { reach[src[i]] : dst[i] == s }  |  reach[s]``

The NumPy oracle mirrors :func:`repro.core.windows.khop_reach_bitsets` one
hop at a time (uint32 words here, uint64 on the host path).
"""

from __future__ import annotations

import numpy as np


def bitset_expand_ref(reach, edge_src, edge_dst, n):
    """reach: [n, W] uint32; edges sorted by dst; returns new reach."""
    reach = np.asarray(reach)
    src = np.asarray(edge_src)
    dst = np.asarray(edge_dst)
    out = reach.copy()
    valid = (dst >= 0) & (dst < n)
    src, dst = src[valid], dst[valid]
    if src.size:
        order = np.argsort(dst, kind="stable")
        src, dst = src[order], dst[order]
        starts = np.flatnonzero(np.diff(dst, prepend=-1))
        red = np.bitwise_or.reduceat(reach[src], starts, axis=0)
        uniq = dst[starts]
        out[uniq] |= red
    return out


def khop_reach_ref(reach0, edge_src, edge_dst, n, k):
    r = np.asarray(reach0).copy()
    for _ in range(k):
        r = bitset_expand_ref(r, edge_src, edge_dst, n)
    return r
