"""Pallas TPU kernel: one k-hop BFS expansion step over packed bitsets.

TPU adaptation of the paper's window-computation primitive (DESIGN.md §2):
multi-source reachability is a scatter-OR of ``uint32``-packed source rows
into destination rows over a dst-sorted edge list — i.e. a segment-OR with
the same tile-aligned plan as the segment-sum kernel.

OR is not a matmul monoid, so the kernel uses the two-step TPU idiom:

1. **Segmented Hillis–Steele OR-scan** over the row tile (log2(TM) vector
   steps on the VPU; rows of different segments masked out of each shift),
   after which the *last* row of every segment holds the tile-local OR.
2. **Boundary extraction via 16-bit split one-hot matmul**: each output row
   receives exactly one boundary contribution per tile, so splitting words
   into exact-in-f32 16-bit halves makes the MXU scatter the boundary rows
   (sum of one term == the value), recombined as ``lo | hi << 16``.

Cross-tile continuation of a segment is handled by OR-idempotent revisit
accumulation on the resident output block (same consecutive-revisit
guarantee as segment_sum).  Lane count W = 128 uint32 words = 4096 BFS
sources per sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

DEFAULT_TM = 256
DEFAULT_TS = 256


def _expand_kernel(m2out_ref, first_ref, seg_ref, rows_ref, base_ref, out_ref, *, ts: int):
    mi = pl.program_id(0)
    out_tile = m2out_ref[mi]
    seg = seg_ref[0, :]  # [TM] int32, -1 padding
    vals = rows_ref[...].astype(jnp.uint32)  # [TM, W] gathered reach[src]
    tm = seg.shape[0]
    vals = jnp.where((seg >= 0)[:, None], vals, jnp.uint32(0))
    # segmented inclusive OR-scan down the rows
    shift = 1
    while shift < tm:
        rolled = pltpu.roll(vals, shift, 0)
        seg_rolled = pltpu.roll(seg, shift, 0)
        row = jax.lax.broadcasted_iota(jnp.int32, (tm,), 0)
        same = (row >= shift) & (seg_rolled == seg)
        vals = vals | jnp.where(same[:, None], rolled, jnp.uint32(0))
        shift *= 2
    # boundary = last row of each segment within the tile
    nxt = pltpu.roll(seg, tm - 1, 0)  # nxt[i] = seg[i+1 mod tm]
    row = jax.lax.broadcasted_iota(jnp.int32, (tm,), 0)
    boundary = (seg >= 0) & ((nxt != seg) | (row == tm - 1))
    rel = jnp.where(boundary, seg - out_tile * ts, 0)
    ok = boundary & (rel >= 0) & (rel < ts)
    iota = jax.lax.broadcasted_iota(jnp.int32, (tm, ts), 1)
    oh = jnp.where(ok[:, None], (iota == rel[:, None]).astype(jnp.float32), 0.0)
    lo = (vals & jnp.uint32(0xFFFF)).astype(jnp.float32)
    hi = (vals >> jnp.uint32(16)).astype(jnp.float32)
    plo = jax.lax.dot_general(oh, lo, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    phi = jax.lax.dot_general(oh, hi, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    partial = plo.astype(jnp.uint32) | (phi.astype(jnp.uint32) << jnp.uint32(16))

    @pl.when(first_ref[mi] == 1)
    def _init():
        out_ref[...] = partial | base_ref[...]

    @pl.when(first_ref[mi] == 0)
    def _acc():
        out_ref[...] = out_ref[...] | partial


@functools.partial(jax.jit, static_argnames=("num_out_tiles", "tm", "ts", "interpret"))
def bitset_expand_tiled(
    gathered_rows,  # [Mpad, W] uint32 = reach[edge_src] tile-aligned
    base,  # [num_out_tiles*TS, W] uint32 = current reach (self OR)
    seg_ids,  # [nm, TM] int32 (-1 padding)
    m2out,
    first_visit,
    *,
    num_out_tiles: int,
    tm: int = DEFAULT_TM,
    ts: int = DEFAULT_TS,
    interpret: bool = False,
):
    num_m_tiles = seg_ids.shape[0]
    w = gathered_rows.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_m_tiles,),
        in_specs=[
            pl.BlockSpec((1, tm), lambda mi, m2out, first: (mi, 0)),
            pl.BlockSpec((tm, w), lambda mi, m2out, first: (mi, 0)),
            pl.BlockSpec((ts, w), lambda mi, m2out, first: (m2out[mi], 0)),
        ],
        out_specs=pl.BlockSpec((ts, w), lambda mi, m2out, first: (m2out[mi], 0)),
    )
    return pl.pallas_call(
        functools.partial(_expand_kernel, ts=ts),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_out_tiles * ts, w), jnp.uint32),
        compiler_params=_CompilerParams(dimension_semantics=(pltpu.ARBITRARY,)),
        interpret=interpret,
    )(m2out, first_visit, seg_ids, gathered_rows, base)
