"""Pallas TPU kernel: tiled segment-sum over sorted, tile-aligned segments.

TPU-native rethink of the paper's shared-aggregation data plane (DESIGN.md
§2).  The host plan (:func:`repro.kernels.segment_reduce.ops.build_tile_plan`)
renumbers segments and pads rows so that

* rows are grouped by segment, segments by output tile of ``TS`` ids,
* every input tile of ``TM`` rows touches exactly **one** output tile,
* all tiles visiting one output tile are consecutive in the grid.

Inside the kernel, the per-tile reduction becomes a one-hot matmul on the
MXU: ``partial[TS, D] = one_hot(seg - ts0)^T @ vals`` — the scatter that a
GPU implementation would do with atomics is a systolic matrix product here.
Revisit accumulation relies on Pallas TPU semantics: an output block whose
index_map repeats across *consecutive* grid steps stays resident in VMEM, so
``out += partial`` accumulates without ever round-tripping HBM.

VMEM budget per grid step (defaults ``TM=512, TS=512, D<=256`` f32):
vals 512·256·4 = 512 KiB, one-hot 512·512·4 = 1 MiB, out 512 KiB — well
under the ~16 MiB/core budget, MXU-aligned (multiples of 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

DEFAULT_TM = 512  # rows per input tile
DEFAULT_TS = 512  # segment ids per output tile


def _seg_sum_kernel(m2out_ref, first_ref, seg_ref, vals_ref, out_ref, *, ts: int):
    mi = pl.program_id(0)
    out_tile = m2out_ref[mi]
    seg = seg_ref[0, :]  # [TM] int32 (padding rows carry -1)
    vals = vals_ref[...]  # [TM, D]
    tm = seg.shape[0]
    rel = seg - out_tile * ts
    valid = (rel >= 0) & (rel < ts)
    rel = jnp.where(valid, rel, 0)
    # one-hot [TM, TS] on the fly; padding rows masked out
    iota = jax.lax.broadcasted_iota(jnp.int32, (tm, ts), 1)
    oh = jnp.where(valid[:, None], (iota == rel[:, None]).astype(vals.dtype), 0)
    partial = jax.lax.dot_general(
        oh,
        vals,
        (((0,), (0,)), ((), ())),  # contract over TM: [TS, D]
        preferred_element_type=jnp.float32,
    )

    @pl.when(first_ref[mi] == 1)
    def _init():
        out_ref[...] = partial.astype(out_ref.dtype)

    @pl.when(first_ref[mi] == 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("num_out_tiles", "tm", "ts", "interpret")
)
def segment_sum_tiled(
    vals,  # [M_pad, D] pre-gathered rows, grouped by segment
    seg_ids,  # [num_m_tiles, TM] int32, -1 on padding rows
    m2out,  # [num_m_tiles] int32: output tile per input tile (non-decreasing)
    first_visit,  # [num_m_tiles] int32 {0,1}
    *,
    num_out_tiles: int,
    tm: int = DEFAULT_TM,
    ts: int = DEFAULT_TS,
    interpret: bool = False,
):
    """Returns [num_out_tiles * TS, D] f32 segment sums."""
    num_m_tiles = seg_ids.shape[0]
    d = vals.shape[1]
    assert vals.shape[0] == num_m_tiles * tm, (vals.shape, num_m_tiles, tm)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # m2out, first_visit
        grid=(num_m_tiles,),
        in_specs=[
            pl.BlockSpec((1, tm), lambda mi, m2out, first: (mi, 0)),
            pl.BlockSpec((tm, d), lambda mi, m2out, first: (mi, 0)),
        ],
        out_specs=pl.BlockSpec((ts, d), lambda mi, m2out, first: (m2out[mi], 0)),
    )
    return pl.pallas_call(
        functools.partial(_seg_sum_kernel, ts=ts),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_out_tiles * ts, d), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.ARBITRARY,)
        ),
        interpret=interpret,
    )(m2out, first_visit, seg_ids, vals)
