"""Jit'd wrapper + host tile-plan builder for the segment-reduce kernel.

``build_tile_plan`` is run once at *index build time* (host, NumPy): it
renumbers nothing (ids are already dense) but groups rows by output tile and
pads so the Pallas kernel sees a tile-aligned layout.  The returned plan is
a pytree of device arrays with static shapes — exactly what pjit wants.

``segment_sum(plan, values)`` = gather + Pallas tiled segment sum.
``segment_reduce(...)`` adds the min/max fallbacks (XLA segment ops): the
paper's experiments use SUM exclusively (§6 "the window query is conducted
by using SUM()"), so the MXU path optimizes sum/count/avg and min/max ride
the well-tuned XLA lowering.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.compat import default_interpret as _default_interpret
from repro.kernels.segment_reduce.segment_reduce import (
    DEFAULT_TM,
    DEFAULT_TS,
    segment_sum_tiled,
)


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Static-shape device plan for one sorted segment reduction."""

    gather_padded: jnp.ndarray  # int32 [Mpad] index into values rows (0 on pad)
    seg_tiles: jnp.ndarray  # int32 [nm, TM]; -1 on padding rows
    m2out: jnp.ndarray  # int32 [nm]
    first_visit: jnp.ndarray  # int32 [nm]
    num_segments: int
    num_out_tiles: int
    tm: int
    ts: int

    def tree_flatten(self):
        return (
            (self.gather_padded, self.seg_tiles, self.m2out, self.first_visit),
            (self.num_segments, self.num_out_tiles, self.tm, self.ts),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def array_nbytes(self) -> "dict":
        """Per-array device bytes held by this plan (exact ``.nbytes``)."""
        return {
            "gather_padded": int(self.gather_padded.nbytes),
            "seg_tiles": int(self.seg_tiles.nbytes),
            "m2out": int(self.m2out.nbytes),
            "first_visit": int(self.first_visit.nbytes),
        }

    def plan_nbytes(self) -> int:
        return sum(self.array_nbytes().values())


jax.tree_util.register_pytree_node(
    TilePlan, TilePlan.tree_flatten, TilePlan.tree_unflatten
)


def build_tile_plan(
    gather_idx: np.ndarray,
    segment_ids: np.ndarray,
    num_segments: int,
    tm: int = DEFAULT_TM,
    ts: int = DEFAULT_TS,
    headroom: float = 0.0,
    group_min_tiles: "Optional[np.ndarray]" = None,
) -> TilePlan:
    """Host-side plan: rows (sorted by segment id) -> tile-aligned layout.

    ``headroom`` > 0 over-allocates every tile group by an even share of
    ``total_rows * headroom`` extra row capacity.  Streamed updates append
    rows into a few hot groups (e.g. secondary blocks land in the capacity
    tail); the spread keeps :func:`patch_tile_plan` shape-stable — hence
    recompile-free — until the cumulative growth exceeds the slack.
    ``group_min_tiles`` optionally floors individual groups' tile counts —
    the caller's way to concentrate slack where appends will land.
    """
    gather_idx = np.asarray(gather_idx, np.int32)
    segment_ids = np.asarray(segment_ids, np.int64)
    assert gather_idx.shape == segment_ids.shape
    if segment_ids.size:
        assert (np.diff(segment_ids) >= 0).all(), "segment_ids must be sorted"
    sizes = np.bincount(segment_ids, minlength=num_segments).astype(np.int64)
    n_out_tiles = max(1, -(-num_segments // ts))
    group_rows = np.add.reduceat(sizes, np.arange(0, num_segments, ts)) if num_segments else np.zeros(1, np.int64)
    if group_rows.size < n_out_tiles:
        group_rows = np.pad(group_rows, (0, n_out_tiles - group_rows.size))
    # >=1 input tile per output tile so every output block gets initialized
    tiles_per_group = np.maximum(1, -(-group_rows // tm))
    if headroom > 0:
        extra = max(1, -(-int(group_rows.sum() * headroom) // (n_out_tiles * tm)))
        tiles_per_group = tiles_per_group + extra
    if group_min_tiles is not None:
        tiles_per_group = np.maximum(
            tiles_per_group, group_min_tiles[:n_out_tiles].astype(np.int64)
        )
    padded_rows = tiles_per_group * tm
    total_pad = int(padded_rows.sum())
    nm = int(tiles_per_group.sum())
    # scatter original rows into the padded layout
    src_group_start = np.zeros(n_out_tiles + 1, np.int64)
    np.cumsum(group_rows, out=src_group_start[1:])
    dst_group_start = np.zeros(n_out_tiles + 1, np.int64)
    np.cumsum(padded_rows, out=dst_group_start[1:])
    row_map = np.full(total_pad, -1, dtype=np.int64)
    if segment_ids.size:
        within = np.arange(segment_ids.size) - np.repeat(
            src_group_start[:-1], group_rows
        )
        dst = np.repeat(dst_group_start[:-1], group_rows) + within
        row_map[dst] = np.arange(segment_ids.size)
    seg_padded = np.full(total_pad, -1, dtype=np.int32)
    valid = row_map >= 0
    seg_padded[valid] = segment_ids[row_map[valid]]
    gather_padded = np.zeros(total_pad, dtype=np.int32)
    gather_padded[valid] = gather_idx[row_map[valid]]
    m2out = np.repeat(np.arange(n_out_tiles, dtype=np.int32), tiles_per_group)
    first_visit = np.empty(nm, dtype=np.int32)
    first_visit[0] = 1
    first_visit[1:] = (np.diff(m2out) != 0).astype(np.int32)
    return TilePlan(
        gather_padded=jnp.asarray(gather_padded),
        seg_tiles=jnp.asarray(seg_padded.reshape(nm, tm)),
        m2out=jnp.asarray(m2out),
        first_visit=jnp.asarray(first_visit),
        num_segments=int(num_segments),
        num_out_tiles=n_out_tiles,
        tm=tm,
        ts=ts,
    )


def patch_tile_plan(
    plan: TilePlan,
    gather_idx: np.ndarray,
    segment_ids: np.ndarray,
    num_segments: int,
    changed_segments: np.ndarray,
) -> TilePlan:
    """Incrementally rebuild a tile plan after a sparse segment change.

    ``gather_idx``/``segment_ids`` are the FULL new row arrays (sorted by
    segment id, same contract as :func:`build_tile_plan`); the caller
    guarantees that every segment whose row set changed is listed in
    ``changed_segments``.  Only output-tile groups containing a changed
    segment are re-laid-out; untouched groups reuse their existing padded
    rows verbatim.  A changed group keeps its old tile capacity when the
    new rows still fit (extra tiles are all-padding rows the kernel masks),
    so steady-state streams produce plans with *identical static shapes* —
    no XLA recompilation of the jitted query.  ``num_segments`` may grow
    (e.g. appended secondary blocks); new groups are appended at the end.
    """
    gather_idx = np.asarray(gather_idx, np.int32)
    segment_ids = np.asarray(segment_ids, np.int64)
    assert gather_idx.shape == segment_ids.shape
    if segment_ids.size:
        assert (np.diff(segment_ids) >= 0).all(), "segment_ids must be sorted"
    tm, ts = plan.tm, plan.ts
    n_out_old = plan.num_out_tiles
    n_out_new = max(1, -(-num_segments // ts))
    if n_out_new < n_out_old:  # shrinking segment space: no reuse story
        return build_tile_plan(gather_idx, segment_ids, num_segments, tm, ts)

    old_m2out = np.asarray(plan.m2out)
    old_tiles = np.bincount(old_m2out, minlength=n_out_old).astype(np.int64)
    old_starts = np.zeros(n_out_old + 1, np.int64)
    np.cumsum(old_tiles * tm, out=old_starts[1:])

    changed_mask = np.zeros(n_out_new, dtype=bool)
    cs = np.asarray(changed_segments, np.int64)
    changed_mask[np.unique(cs[cs < num_segments]) // ts] = True
    changed_mask[n_out_old:] = True  # appended groups are always new

    # per-group row ranges in the new arrays
    bounds = np.searchsorted(
        segment_ids, np.arange(n_out_new + 1, dtype=np.int64) * ts
    )
    rows_per_group = np.diff(bounds)
    tiles_needed = np.maximum(1, -(-rows_per_group // tm))
    old_tiles_ext = np.zeros(n_out_new, np.int64)
    old_tiles_ext[:n_out_old] = old_tiles
    tiles_new = np.where(
        changed_mask, np.maximum(tiles_needed, old_tiles_ext), old_tiles_ext
    )
    new_starts = np.zeros(n_out_new + 1, np.int64)
    np.cumsum(tiles_new * tm, out=new_starts[1:])
    total_pad = int(new_starts[-1])
    nm = int(tiles_new.sum())

    if n_out_new == n_out_old and np.array_equal(tiles_new, old_tiles):
        # Shape-stable steady state: scatter only the changed tile groups
        # into the live device arrays (`jax.Array.at[...].set`) instead of
        # round-tripping the whole plan through host memory and re-uploading
        # it.  Everything static (m2out, first_visit, shapes) is reused, so
        # jitted consumers never retrace.
        pos_chunks, seg_chunks, gather_chunks = [], [], []
        for g in np.flatnonzero(changed_mask):
            lo, span = int(new_starts[g]), int(tiles_new[g]) * tm
            r0, r1 = int(bounds[g]), int(bounds[g + 1])
            seg_rows = np.full(span, -1, dtype=np.int32)
            gather_rows = np.zeros(span, dtype=np.int32)
            seg_rows[: r1 - r0] = segment_ids[r0:r1]
            gather_rows[: r1 - r0] = gather_idx[r0:r1]
            pos_chunks.append(np.arange(lo, lo + span, dtype=np.int64))
            seg_chunks.append(seg_rows)
            gather_chunks.append(gather_rows)
        seg_flat = plan.seg_tiles.reshape(-1)
        gather_flat = plan.gather_padded
        if pos_chunks:
            pos = jnp.asarray(np.concatenate(pos_chunks))
            seg_flat = seg_flat.at[pos].set(jnp.asarray(np.concatenate(seg_chunks)))
            gather_flat = gather_flat.at[pos].set(
                jnp.asarray(np.concatenate(gather_chunks))
            )
        return TilePlan(
            gather_padded=gather_flat,
            seg_tiles=seg_flat.reshape(nm, tm),
            m2out=plan.m2out,
            first_visit=plan.first_visit,
            num_segments=int(num_segments),
            num_out_tiles=n_out_new,
            tm=tm,
            ts=ts,
        )

    old_seg = np.asarray(plan.seg_tiles).reshape(-1)
    old_gather = np.asarray(plan.gather_padded)
    seg_padded = np.full(total_pad, -1, dtype=np.int32)
    gather_padded = np.zeros(total_pad, dtype=np.int32)
    for g in range(n_out_new):
        lo = int(new_starts[g])
        if changed_mask[g]:
            r0, r1 = int(bounds[g]), int(bounds[g + 1])
            seg_padded[lo : lo + (r1 - r0)] = segment_ids[r0:r1]
            gather_padded[lo : lo + (r1 - r0)] = gather_idx[r0:r1]
        else:
            o0 = int(old_starts[g])
            span = int(old_tiles[g]) * tm
            seg_padded[lo : lo + span] = old_seg[o0 : o0 + span]
            gather_padded[lo : lo + span] = old_gather[o0 : o0 + span]
    m2out = np.repeat(np.arange(n_out_new, dtype=np.int32), tiles_new)
    first_visit = np.empty(nm, dtype=np.int32)
    first_visit[0] = 1
    first_visit[1:] = (np.diff(m2out) != 0).astype(np.int32)
    return TilePlan(
        gather_padded=jnp.asarray(gather_padded),
        seg_tiles=jnp.asarray(seg_padded.reshape(nm, tm)),
        m2out=jnp.asarray(m2out),
        first_visit=jnp.asarray(first_visit),
        num_segments=int(num_segments),
        num_out_tiles=n_out_new,
        tm=tm,
        ts=ts,
    )


def segment_sum_gathered(
    plan: TilePlan,
    gathered: jnp.ndarray,
    interpret: Optional[bool] = None,
    use_pallas: bool = True,
):
    """Tiled segment sum over pre-gathered rows ([Mpad] or [Mpad, D]).

    Traceable (no jit of its own): fused multi-channel queries call this
    after a single shared ``jnp.take`` so k aggregates pay for one gather.
    """
    interpret = _default_interpret() if interpret is None else interpret
    squeeze = gathered.ndim == 1
    v = gathered[:, None] if squeeze else gathered
    d = v.shape[1]
    if use_pallas:
        # the MXU kernel wants 128-lane tiles; the XLA fallback does not —
        # padding there would do 128/d times the useful work
        pad_d = (-d) % 128
        if pad_d:
            v = jnp.pad(v, ((0, 0), (0, pad_d)))
    gathered = v
    if use_pallas:
        out = segment_sum_tiled(
            gathered.astype(jnp.float32),
            plan.seg_tiles,
            plan.m2out,
            plan.first_visit,
            num_out_tiles=plan.num_out_tiles,
            tm=plan.tm,
            ts=plan.ts,
            interpret=interpret,
        )
    else:  # XLA fallback (same tile-aligned inputs)
        sid = plan.seg_tiles.reshape(-1)
        ok = sid >= 0
        out = jax.ops.segment_sum(
            jnp.where(ok[:, None], gathered, 0).astype(jnp.float32),
            jnp.where(ok, sid, plan.num_out_tiles * plan.ts),
            num_segments=plan.num_out_tiles * plan.ts + 1,
        )[:-1]
    out = out[: plan.num_segments, :d]
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("interpret", "use_pallas"))
def segment_sum(
    plan: TilePlan,
    values: jnp.ndarray,
    interpret: Optional[bool] = None,
    use_pallas: bool = True,
):
    """Fused gather + tiled segment sum.  values: [N] or [N, D] -> [S(, D)]."""
    gathered = jnp.take(values, plan.gather_padded, axis=0)
    return segment_sum_gathered(plan, gathered, interpret, use_pallas)


def segment_reduce(
    values, gather_idx, segment_ids, num_segments, op="add",
    plan: Optional[TilePlan] = None, interpret: Optional[bool] = None,
    use_pallas: bool = True,
):
    """General entry point.  SUM goes through the Pallas MXU path (plan
    required or built eagerly); min/max use the XLA segment lowering."""
    if op == "add":
        if plan is None:
            plan = build_tile_plan(
                np.asarray(gather_idx), np.asarray(segment_ids), num_segments
            )
        return segment_sum(plan, values, interpret=interpret, use_pallas=use_pallas)
    from repro.kernels.segment_reduce.ref import segment_reduce_ref

    return segment_reduce_ref(values, gather_idx, segment_ids, num_segments, op)
