"""Pure-jnp oracle for the fused gather + segment-reduce primitive.

This is the paper's entire query data plane as one op (DESIGN.md §2):
``out[s] = op-reduce over { values[gather_idx[i]] : segment_ids[i] == s }``.
"""

from __future__ import annotations

import jax.numpy as jnp
import jax.ops


def segment_reduce_ref(values, gather_idx, segment_ids, num_segments, op="add"):
    """values: [N, D] (or [N]); gather_idx, segment_ids: [M] int32.

    Rows with segment_ids < 0 are dropped (padding).  Returns [S, D].
    """
    squeeze = values.ndim == 1
    if squeeze:
        values = values[:, None]
    gathered = values[jnp.clip(gather_idx, 0, values.shape[0] - 1)]
    valid = segment_ids >= 0
    sid = jnp.where(valid, segment_ids, num_segments)  # sink row
    if op == "add":
        gathered = jnp.where(valid[:, None], gathered, 0)
        out = jax.ops.segment_sum(gathered, sid, num_segments=num_segments + 1)
    elif op == "min":
        big = jnp.array(jnp.inf, values.dtype) if jnp.issubdtype(values.dtype, jnp.floating) else jnp.iinfo(values.dtype).max
        gathered = jnp.where(valid[:, None], gathered, big)
        out = jax.ops.segment_min(gathered, sid, num_segments=num_segments + 1)
    elif op == "max":
        small = jnp.array(-jnp.inf, values.dtype) if jnp.issubdtype(values.dtype, jnp.floating) else jnp.iinfo(values.dtype).min
        gathered = jnp.where(valid[:, None], gathered, small)
        out = jax.ops.segment_max(gathered, sid, num_segments=num_segments + 1)
    elif op == "or":
        gathered = jnp.where(valid[:, None], gathered, 0)
        out = jax.ops.segment_max(gathered, sid, num_segments=num_segments + 1)
        raise NotImplementedError("use bitset_expand ref for packed-or")
    else:
        raise ValueError(op)
    out = out[:num_segments]
    return out[:, 0] if squeeze else out
