"""Attention dispatch: Pallas flash kernel on TPU, pure-jnp flash elsewhere.

``flash_jnp`` is the *algorithmic twin* of the Pallas kernel — a two-level
``lax.scan`` (query chunks × kv chunks) carrying streaming-softmax stats —
so the dry-run lowering on the host platform has the same O(S·chunk) memory
profile the TPU kernel has, and ``compiled.memory_analysis()`` stays honest
for 32k prefill.  ``local_window`` gives sliding-window attention (the
sub-quadratic variant used for the bonus long_500k rows).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG = -1e30


def flash_jnp(q, k, v, *, causal: bool = True, q_chunk: int = 512,
              kv_chunk: int = 512, local_window: Optional[int] = None):
    """q: [B, Hq, S, D]; k/v: [B, Hkv, S, D] -> [B, Hq, S, D] (f32 acc)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = d ** -0.5
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s)
    nq, nk = s // q_chunk, s // kv_chunk
    qr = q.reshape(b, hkv, group, nq, q_chunk, d)
    kr = k.reshape(b, hkv, nk, kv_chunk, d)
    vr = v.reshape(b, hkv, nk, kv_chunk, d)

    def q_step(_, qi):
        qblk = jax.lax.dynamic_index_in_dim(qr, qi, axis=3, keepdims=False)
        # qblk: [B, Hkv, G, qc, D]
        m0 = jnp.full(qblk.shape[:-1], NEG, jnp.float32)
        l0 = jnp.zeros(qblk.shape[:-1], jnp.float32)
        a0 = jnp.zeros(qblk.shape, jnp.float32)

        @jax.checkpoint  # flash backward: recompute p per chunk, store carries only
        def kv_step(carry, ki):
            m, l, acc = carry
            kblk = jax.lax.dynamic_index_in_dim(kr, ki, axis=2, keepdims=False)
            vblk = jax.lax.dynamic_index_in_dim(vr, ki, axis=2, keepdims=False)
            sc = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qblk, kblk,
                preferred_element_type=jnp.float32,
            ) * scale
            rows = qi * q_chunk + jnp.arange(q_chunk)[:, None]
            cols = ki * kv_chunk + jnp.arange(kv_chunk)[None, :]
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= cols <= rows
            if local_window is not None:
                mask &= cols > rows - local_window
            sc = jnp.where(mask, sc, NEG)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vblk.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))
    # outs: [nq, B, Hkv, G, qc, D] -> [B, Hq, S, D]
    out = jnp.moveaxis(outs, 0, 3).reshape(b, hkv, group, s, d)
    return out.reshape(b, hq, s, d)


def attention(q, k, v, *, causal: bool = True, local_window: Optional[int] = None,
              backend: Optional[str] = None, q_chunk: int = 512, kv_chunk: int = 512):
    """Unified entry: backend in {None (auto), 'pallas', 'flash_jnp', 'naive'}."""
    if backend is None:
        backend = "pallas" if (
            jax.default_backend() == "tpu" and local_window is None
            and q.shape[2] % 512 == 0
        ) else ("flash_jnp" if q.shape[2] > 1024 else "naive")
    if backend == "pallas":
        from repro.kernels.flash_attention.flash_attention import flash_attention

        return flash_attention(q, k, v, causal=causal)
    if backend == "flash_jnp":
        return flash_jnp(q, k, v, causal=causal, local_window=local_window,
                         q_chunk=q_chunk, kv_chunk=kv_chunk)
    from repro.kernels.flash_attention.ref import mha_ref

    return mha_ref(q, k, v, causal=causal, local_window=local_window)
