"""Mixture-of-Experts transformer (grok-1-314b, qwen2-moe-a2.7b).

Router: softmax top-k with capacity-bounded sort-based dispatch — no
[T, E, C] one-hot tensors (32k-seq prefill would not survive them).  Tokens
are argsorted by expert id, truncated to per-expert capacity, processed as
a dense [E, C, d] einsum against stacked expert weights, and combined with
router weights.  Static shapes throughout (pjit-safe).

Sharding posture (DESIGN.md §5): tokens DP over (pod, data); expert FFN
hidden dim TP over "model"; optionally (qwen2-moe hillclimb) experts padded
to a multiple of the mesh axis for true expert parallelism.

qwen2-moe extras: 4 shared experts (one fused always-on SwiGLU of width
4*1408) + routed top-4 over 60 experts, per the public config.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.attention import attention


@dataclasses.dataclass(frozen=True)
class MoEConfig(T.TransformerConfig):
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    n_shared_experts: int = 0
    d_ff_shared: int = 0  # width of the fused shared-expert SwiGLU
    router_aux_coef: float = 0.01
    pad_experts_to: Optional[int] = None  # EP knob: pad experts for sharding
    # Dispatch is vmapped over token groups sharded across the whole mesh:
    # each group sorts/capacities its own tokens (per-device capacity, the
    # production EP semantics) so no global argsort / token gather appears.
    dispatch_groups: int = 512

    @property
    def n_experts_padded(self) -> int:
        return self.pad_experts_to or self.n_experts

    def n_params(self) -> int:
        d, f, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        moe = 3 * d * f * self.n_experts + d * self.n_experts
        shared = 3 * d * self.d_ff_shared if self.n_shared_experts else 0
        per_layer = attn + moe + shared + 2 * d
        emb = v * d * (1 if self.tie_embeddings else 2)
        return l * per_layer + emb + d

    def n_active_params(self) -> int:
        d, f, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        moe = 3 * d * f * self.top_k + d * self.n_experts
        shared = 3 * d * self.d_ff_shared if self.n_shared_experts else 0
        per_layer = attn + moe + shared + 2 * d
        emb = v * d * (1 if self.tie_embeddings else 2)
        return l * per_layer + emb + d


def layer_init(key, cfg: MoEConfig):
    ks = jax.random.split(key, 12)
    d, hd = cfg.d_model, cfg.head_dim
    ep = cfg.n_experts_padded
    p = {
        "ln1": L.rmsnorm_init(d, cfg.pdtype),
        "ln2": L.rmsnorm_init(d, cfg.pdtype),
        "wq": L.dense_init(ks[0], d, cfg.n_heads * hd, cfg.pdtype),
        "wk": L.dense_init(ks[1], d, cfg.n_kv_heads * hd, cfg.pdtype),
        "wv": L.dense_init(ks[2], d, cfg.n_kv_heads * hd, cfg.pdtype),
        "wo": L.dense_init(ks[3], cfg.n_heads * hd, d, cfg.pdtype),
        "router": L.dense_init(ks[4], d, ep, cfg.pdtype, scale=0.02),
        "we_gate": jax.random.normal(ks[5], (ep, d, cfg.d_ff), jnp.float32).astype(cfg.pdtype) * (d ** -0.5),
        "we_up": jax.random.normal(ks[6], (ep, d, cfg.d_ff), jnp.float32).astype(cfg.pdtype) * (d ** -0.5),
        "we_down": jax.random.normal(ks[7], (ep, cfg.d_ff, d), jnp.float32).astype(cfg.pdtype) * (cfg.d_ff ** -0.5),
    }
    if cfg.n_shared_experts:
        p["ws_gate"] = L.dense_init(ks[8], d, cfg.d_ff_shared, cfg.pdtype)
        p["ws_up"] = L.dense_init(ks[9], d, cfg.d_ff_shared, cfg.pdtype)
        p["ws_down"] = L.dense_init(ks[10], cfg.d_ff_shared, d, cfg.pdtype)
    return p


def init(key, cfg: MoEConfig):
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    stacked = jax.vmap(lambda k: layer_init(k, cfg))(
        jax.random.split(k_layers, cfg.n_layers)
    )
    params = {
        "embed": L.embed_init(k_emb, cfg.vocab, cfg.d_model, cfg.pdtype),
        "layers": stacked,
        "ln_f": L.rmsnorm_init(cfg.d_model, cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(k_out, cfg.d_model, cfg.vocab, cfg.pdtype)
    return params


def _dispatch_group(xt, router, we_gate, we_up, we_down, shared_w, cfg: MoEConfig,
                    partial_tp: bool = False):
    """Dispatch one token group [T_loc, d] -> ([T_loc, d], aux scalar).

    With ``partial_tp=True`` the expert ffn weights are local ff-dim shards
    and the returned output is a *partial* sum (caller psums over the TP
    axis) — the shard_map path.
    """
    t, d = xt.shape
    ep = cfg.n_experts_padded
    logits = (xt @ router.astype(cfg.cdtype)).astype(jnp.float32)
    if ep != cfg.n_experts:  # padded experts never routed
        pad_mask = jnp.arange(ep) < cfg.n_experts
        logits = jnp.where(pad_mask[None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)  # [T, K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )
    # load-balance auxiliary loss (Switch style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(gate_idx[:, 0], ep, dtype=jnp.float32), axis=0
    )
    aux = cfg.router_aux_coef * ep * jnp.sum(me * ce)

    # sort-based capacity dispatch (local to the group)
    cap = int(cfg.capacity_factor * t * cfg.top_k / cfg.n_experts) + 1
    flat_expert = gate_idx.reshape(-1)  # [T*K]
    flat_token = jnp.repeat(jnp.arange(t), cfg.top_k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    seg_pos = _segment_positions(se)  # position within each expert's run
    keep = seg_pos < cap
    slot = se * cap + seg_pos  # [T*K] in [0, EP*cap)
    slot = jnp.where(keep, slot, ep * cap)  # overflow -> dropped sink
    # scatter tokens into [EP*cap, d]
    buf = jnp.zeros((ep * cap + 1, d), cfg.cdtype)
    buf = buf.at[slot].set(jnp.take(xt, st, axis=0))
    buf = buf[:-1].reshape(ep, cap, d)
    # expert computation (ff dim possibly a local TP shard)
    h = jnp.einsum("ecd,edf->ecf", buf, we_gate.astype(cfg.cdtype))
    u = jnp.einsum("ecd,edf->ecf", buf, we_up.astype(cfg.cdtype))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, we_down.astype(cfg.cdtype))
    y = y.reshape(ep * cap, d)
    # combine back
    contrib = jnp.take(y, jnp.minimum(slot, ep * cap - 1), axis=0)
    contrib = jnp.where(keep[:, None], contrib, 0) * sg[:, None].astype(cfg.cdtype)
    out = jnp.zeros((t, d), cfg.cdtype).at[st].add(contrib)
    if shared_w is not None:
        ws_gate, ws_up, ws_down = shared_w
        out = out + L.swiglu(
            xt,
            ws_gate.astype(cfg.cdtype),
            ws_up.astype(cfg.cdtype),
            ws_down.astype(cfg.cdtype),
        )
    return out, aux


def moe_ffn(lp, x, cfg: MoEConfig, acts=None):
    """x: [B, S, d] -> ([B, S, d], aux_loss scalar).

    Tokens are regrouped [G, T/G, d]; the dispatch is group-local (per-group
    capacity — the production EP semantics), so argsort/top-k/scatter never
    cross a shard.

    Distribution: GSPMD handles the vmapped gather poorly ("involuntary
    full rematerialization", 32 GiB replicated buffers measured on
    qwen2-moe train_4k), so when the acts dict carries a ``moe_shard``
    entry the dispatch runs under **shard_map**: token groups sharded over
    the dp axes, expert ffn hidden dim a local TP shard over "model", one
    psum combining the down-projection partials (textbook Megatron-style
    TP with manual collective control; EXPERIMENTS §Perf).
    """
    from repro.distributed.actshard import constrain

    b, s, d = x.shape
    t = b * s
    g = min(cfg.dispatch_groups, t)
    while t % g:
        g -= 1
    xt = x.reshape(g, t // g, d)
    shared = (
        (lp["ws_gate"], lp["ws_up"], lp["ws_down"]) if cfg.n_shared_experts else None
    )
    moe_shard = acts.get("moe_shard") if acts else None
    if moe_shard is None:  # single-device / smoke path
        out, aux = jax.vmap(
            lambda xg: _dispatch_group(
                xg, lp["router"], lp["we_gate"], lp["we_up"], lp["we_down"],
                shared, cfg,
            )
        )(xt)
        return out.reshape(b, s, d), jnp.mean(aux)

    mesh, token_axes, tp = moe_shard
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def body(xt_l, router, wg, wu, wd, *shared_l):
        sh = shared_l if shared_l else None

        # scan (not vmap) over the device's local groups: the dispatch
        # scatter/gather working set stays one group wide, and the remat'd
        # backward recomputes per group instead of materializing every
        # group's buffers at once (-20 GiB measured; EXPERIMENTS §Perf).
        @jax.checkpoint
        def step(aux_acc, xg):
            out_g, aux_g = _dispatch_group(
                xg, router, wg, wu, wd, sh, cfg, partial_tp=True
            )
            return aux_acc + aux_g, out_g

        aux_sum, out_l = jax.lax.scan(step, jnp.zeros((), jnp.float32), xt_l)
        out_l = jax.lax.psum(out_l, tp)  # combine ff-shard partials
        aux = jax.lax.pmean(aux_sum / xt_l.shape[0], token_axes)
        return out_l, aux

    shared_args = tuple(shared) if shared is not None else ()
    shared_specs = tuple(
        [P(None, tp), P(None, tp), P(tp, None)]
    ) if shared is not None else ()
    out, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(token_axes, None, None),
            P(None, None),  # router replicated
            P(None, None, tp),  # we_gate [E, d, ff/tp]
            P(None, None, tp),
            P(None, tp, None),  # we_down [E, ff/tp, d]
            *shared_specs,
        ),
        out_specs=(P(token_axes, None, None), P()),
        check_rep=False,
    )(xt, lp["router"], lp["we_gate"], lp["we_up"], lp["we_down"], *shared_args)
    return out.reshape(b, s, d), aux


def _segment_positions(sorted_ids):
    """Position of each element within its run of equal ids (sorted input)."""
    n = sorted_ids.shape[0]
    idx = jnp.arange(n)
    is_start = jnp.concatenate([jnp.ones(1, bool), sorted_ids[1:] != sorted_ids[:-1]])
    start_idx = jnp.where(is_start, idx, 0)
    run_start = jax.lax.associative_scan(jnp.maximum, start_idx)
    return idx - run_start


def layer_fwd(lp, x, cfg: MoEConfig, cos, sin, positions=None, attn_backend=None,
              acts=None):
    b, s, _ = x.shape
    q, k, v, _ = T._qkv(lp, x, cfg, positions, cos, sin)
    o = attention(q, k, v, causal=True, local_window=cfg.local_window,
                  backend=attn_backend, q_chunk=cfg.attn_q_chunk,
                  kv_chunk=cfg.attn_kv_chunk)
    o = o.swapaxes(1, 2).reshape(b, s, cfg.n_heads * cfg.head_dim)
    x = x + o @ lp["wo"].astype(cfg.cdtype)
    xn = L.rmsnorm(x, lp["ln2"])
    y, aux = moe_ffn(lp, xn, cfg, acts=acts)
    return x + y, aux


def forward(params, tokens, cfg: MoEConfig, attn_backend=None, acts=None):
    from repro.distributed.actshard import constrain

    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    x = constrain(x, acts, "res")
    s = tokens.shape[1]
    cos, sin = L.rope_freqs(cfg.head_dim, s, cfg.rope_theta)

    def body(carry, lp):
        x, aux = carry
        x, a = layer_fwd(lp, x, cfg, cos, sin, attn_backend=attn_backend, acts=acts)
        return (constrain(x, acts, "res"), aux + a), None

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
    x = L.rmsnorm(x, params["ln_f"])
    unemb = params.get("unembed", None)
    w = unemb if unemb is not None else params["embed"].T
    logits = (x @ w.astype(cfg.cdtype)).astype(jnp.float32)
    return constrain(logits, acts, "logits"), aux


def forward_hidden(params, tokens, cfg: MoEConfig, attn_backend=None, acts=None):
    from repro.distributed.actshard import constrain

    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    x = constrain(x, acts, "res")
    s = tokens.shape[1]
    cos, sin = L.rope_freqs(cfg.head_dim, s, cfg.rope_theta)

    def body(carry, lp):
        x, aux = carry
        x, a = layer_fwd(lp, x, cfg, cos, sin, attn_backend=attn_backend, acts=acts)
        return (constrain(x, acts, "res"), aux + a), None

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
    return L.rmsnorm(x, params["ln_f"]), aux


def loss_fn(params, batch, cfg: MoEConfig, acts=None):
    x, aux = forward_hidden(params, batch["tokens"], cfg, acts=acts)
    unemb = params.get("unembed", None)
    w = unemb if unemb is not None else params["embed"].T
    return L.lm_loss_fused(
        x[:, :-1], w, batch["labels"][:, 1:], cfg.z_loss, acts=acts
    ) + aux


# --------------------------- serving ----------------------------------- #
def prefill(params, tokens, cfg: MoEConfig, acts=None):
    from repro.distributed.actshard import constrain

    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    x = constrain(x, acts, "res")
    b, s = tokens.shape
    cos, sin = L.rope_freqs(cfg.head_dim, s, cfg.rope_theta)

    def body(x, lp):
        q, k, v, _ = T._qkv(lp, x, cfg, None, cos, sin)
        o = attention(q, k, v, causal=True, local_window=cfg.local_window,
                      q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk)
        o = o.swapaxes(1, 2).reshape(b, s, cfg.n_heads * cfg.head_dim)
        x = x + o @ lp["wo"].astype(cfg.cdtype)
        xn = L.rmsnorm(x, lp["ln2"])
        y, _ = moe_ffn(lp, xn, cfg, acts=acts)
        return constrain(x + y, acts, "res"), (k, v)

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, (ks, vs) = jax.lax.scan(body_fn, x, params["layers"])
    x = L.rmsnorm(x, params["ln_f"])
    unemb = params.get("unembed", None)
    w = unemb if unemb is not None else params["embed"].T
    logits = (x[:, -1] @ w.astype(cfg.cdtype)).astype(jnp.float32)
    return {"k": ks, "v": vs}, constrain(logits, acts, "logits")


def decode_step(params, token, kv, pos, cfg: MoEConfig, acts=None):
    from repro.distributed.actshard import constrain
    from repro.kernels.flash_attention.ref import decode_ref

    b = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.cdtype)[:, None, :]
    x = constrain(x, acts, "res")
    smax = kv["k"].shape[3]
    cos, sin = L.rope_freqs(cfg.head_dim, smax, cfg.rope_theta)
    positions = jnp.full((1,), pos, jnp.int32)

    def body(x, inp):
        lp, kc, vc = inp
        q, k, v, _ = T._qkv(lp, x, cfg, positions, cos, sin)
        kc = T.cache_update_add(kc, k[:, :, 0], pos)
        vc = T.cache_update_add(vc, v[:, :, 0], pos)
        o = decode_ref(q[:, :, 0], kc, vc, pos + 1, window=cfg.local_window)
        o = o.reshape(b, 1, cfg.n_heads * cfg.head_dim)
        x = x + o @ lp["wo"].astype(cfg.cdtype)
        xn = L.rmsnorm(x, lp["ln2"])
        y, _ = moe_ffn(lp, xn, cfg, acts=acts)
        return constrain(x + y, acts, "res"), (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], kv["k"], kv["v"]))
    x = L.rmsnorm(x, params["ln_f"])
    unemb = params.get("unembed", None)
    w = unemb if unemb is not None else params["embed"].T
    logits = (x[:, 0] @ w.astype(cfg.cdtype)).astype(jnp.float32)
    return constrain(logits, acts, "logits"), {"k": ks, "v": vs}
