"""Model zoo: dense/MoE LMs, GNNs, recsys FM — functional param-pytree style."""
