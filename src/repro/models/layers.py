"""Shared layers (functional, param-pytree style — no framework dep).

Conventions:
* params are nested dicts of jnp arrays; init fns take an `jax.random` key;
* every init is `jax.eval_shape`-safe (no data-dependent shapes), which is
  what lets the dry-run build 314B-param shape trees without allocating;
* compute dtype is bf16 by default with f32 params (mixed precision policy
  lives in the model configs).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: Optional[float] = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in)).item() if False else (d_in ** -0.5)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def rmsnorm_init(d: int, dtype=jnp.float32):
    return jnp.ones((d,), dtype)


def rmsnorm(x, gamma, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    normed = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (normed * gamma.astype(jnp.float32)).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def rope_freqs(head_dim: int, max_seq: int, theta: float = 10000.0):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(max_seq, dtype=jnp.float32)
    ang = jnp.outer(t, inv)  # [S, D/2]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, positions=None):
    """x: [..., S, D]; cos/sin: [S_max, D/2] (gathered at `positions` if given)."""
    if positions is not None:
        cos = jnp.take(cos, positions, axis=0)
        sin = jnp.take(sin, positions, axis=0)
    x1, x2 = jnp.split(x, 2, axis=-1)
    shape = (1,) * (x.ndim - 2) + cos.shape
    cos = cos.reshape(shape).astype(x.dtype)
    sin = sin.reshape(shape).astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def mlp_init(key, dims, dtype=jnp.float32):
    """Simple MLP: list of (w, b) for dims [d0, d1, ..., dn]."""
    keys = jax.random.split(key, len(dims) - 1)
    return [
        {
            "w": dense_init(k, a, b, dtype),
            "b": jnp.zeros((b,), dtype),
        }
        for k, a, b in zip(keys, dims[:-1], dims[1:])
    ]


def mlp_apply(params, x, act=jax.nn.relu, final_act: bool = False):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


def lm_loss_fused(x, w, labels, z_loss: float = 0.0, chunk: int = 512, acts=None):
    """Fused unembed + cross entropy, chunked over the sequence axis.

    Never materializes the full [B, S, V] logits: each chunk's logits are
    produced, reduced to nll, and (via jax.checkpoint) recomputed in the
    backward — the standard memory fix for 256k-vocab training heads
    (-7 GiB/device measured on qwen2-moe train_4k, EXPERIMENTS §Perf).

    x: [B, S, D] final hidden states; w: [D, V]; labels: [B, S].
    """
    from repro.distributed.actshard import constrain

    b, s, d = x.shape
    x = constrain(x, acts, "loss_hidden")
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    nchunks = s // chunk
    xc = x.reshape(b, nchunks, chunk, d)
    lc = labels.reshape(b, nchunks, chunk)

    @jax.checkpoint
    def body(carry, inp):
        xi, li = inp  # [B, chunk, D], [B, chunk]
        logits = (xi @ w.astype(xi.dtype)).astype(jnp.float32)
        logits = constrain(logits, acts, "loss_logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        ll = jnp.sum(jnp.where(iota == li[..., None], logits, 0.0), axis=-1)
        nll = lse - ll
        if z_loss:
            nll = nll + z_loss * jnp.square(lse)
        return carry + jnp.sum(nll), None

    total, _ = jax.lax.scan(
        body, jnp.zeros((), jnp.float32),
        (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(lc, 1, 0)),
    )
    return total / (b * s)


def cross_entropy(logits, labels, z_loss: float = 0.0):
    """logits: [..., V] f32; labels int32.  Mean NLL (+ optional z-loss).

    The label pick uses a masked sum (select over iota) rather than
    take_along_axis: on vocab-sharded logits the gather would force an
    all-gather of the full [B, S, V] tensor, while the masked sum stays
    elementwise + psum (GSPMD-friendly; measured in EXPERIMENTS §Perf).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    picked = jnp.where(vocab_iota == labels[..., None], logits, 0.0)
    ll = jnp.sum(picked, axis=-1)
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    return jnp.mean(nll)
