"""Dense decoder-only transformer: GQA + RoPE + SwiGLU (+ optional qk-norm).

Covers minitron-4b/8b and qwen3-0.6b exactly (their public configs) and is
the backbone the MoE models extend.  Layer params are *stacked* [L, ...] and
the forward pass is a ``lax.scan`` over layers — compile time and HLO size
stay flat in depth, and remat policy wraps the scan body.

Functional API:
    params = init(key, cfg)                  (eval_shape-safe)
    logits = forward(params, tokens, cfg)     [B, S, V]
    loss   = loss_fn(params, batch, cfg)
    kv, logits = prefill(params, tokens, cfg)
    logits, kv = decode_step(params, token, kv, pos, cfg)
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import attention


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    qk_norm: bool = False
    rope_theta: float = 10000.0
    max_seq: int = 32768 * 16 + 4096
    tie_embeddings: bool = False
    local_window: Optional[int] = None  # sliding-window attention (bonus)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    z_loss: float = 1e-4
    # flash-attention chunking: bwd saves the (m,l,acc) carry per kv chunk,
    # so nk scales the per-layer bwd footprint; large-d models use bigger
    # kv chunks (grok-1: 2048 -> 4x fewer saved carries; §Perf)
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 512

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def n_params(self) -> int:
        d, f, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d
        emb = v * d * (1 if self.tie_embeddings else 2)
        return l * per_layer + emb + d


def layer_init(key, cfg: TransformerConfig):
    ks = jax.random.split(key, 8)
    d, hd = cfg.d_model, cfg.head_dim
    p = {
        "ln1": L.rmsnorm_init(d, cfg.pdtype),
        "ln2": L.rmsnorm_init(d, cfg.pdtype),
        "wq": L.dense_init(ks[0], d, cfg.n_heads * hd, cfg.pdtype),
        "wk": L.dense_init(ks[1], d, cfg.n_kv_heads * hd, cfg.pdtype),
        "wv": L.dense_init(ks[2], d, cfg.n_kv_heads * hd, cfg.pdtype),
        "wo": L.dense_init(ks[3], cfg.n_heads * hd, d, cfg.pdtype),
        "w_gate": L.dense_init(ks[4], d, cfg.d_ff, cfg.pdtype),
        "w_up": L.dense_init(ks[5], d, cfg.d_ff, cfg.pdtype),
        "w_down": L.dense_init(ks[6], cfg.d_ff, d, cfg.pdtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.rmsnorm_init(hd, cfg.pdtype)
        p["k_norm"] = L.rmsnorm_init(hd, cfg.pdtype)
    return p


def init(key, cfg: TransformerConfig):
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    stacked = jax.vmap(lambda k: layer_init(k, cfg))(
        jax.random.split(k_layers, cfg.n_layers)
    )
    params = {
        "embed": L.embed_init(k_emb, cfg.vocab, cfg.d_model, cfg.pdtype),
        "layers": stacked,
        "ln_f": L.rmsnorm_init(cfg.d_model, cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(k_out, cfg.d_model, cfg.vocab, cfg.pdtype)
    return params


def _qkv(lp, x, cfg: TransformerConfig, positions, cos, sin):
    b, s, d = x.shape
    hd = cfg.head_dim
    xn = L.rmsnorm(x, lp["ln1"])
    q = (xn @ lp["wq"].astype(cfg.cdtype)).reshape(b, s, cfg.n_heads, hd)
    k = (xn @ lp["wk"].astype(cfg.cdtype)).reshape(b, s, cfg.n_kv_heads, hd)
    v = (xn @ lp["wv"].astype(cfg.cdtype)).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = L.rmsnorm(q, lp["q_norm"])
        k = L.rmsnorm(k, lp["k_norm"])
    q = L.apply_rope(q.swapaxes(1, 2), cos, sin, positions)  # [B, H, S, D]
    k = L.apply_rope(k.swapaxes(1, 2), cos, sin, positions)
    return q, k, v.swapaxes(1, 2), xn


def layer_fwd(lp, x, cfg: TransformerConfig, cos, sin, positions=None,
              attn_backend: Optional[str] = None):
    q, k, v, _ = _qkv(lp, x, cfg, positions, cos, sin)
    o = attention(q, k, v, causal=True, local_window=cfg.local_window,
                  backend=attn_backend, q_chunk=cfg.attn_q_chunk,
                  kv_chunk=cfg.attn_kv_chunk)
    b, s = x.shape[:2]
    o = o.swapaxes(1, 2).reshape(b, s, cfg.n_heads * cfg.head_dim)
    x = x + o @ lp["wo"].astype(cfg.cdtype)
    xn = L.rmsnorm(x, lp["ln2"])
    x = x + L.swiglu(
        xn,
        lp["w_gate"].astype(cfg.cdtype),
        lp["w_up"].astype(cfg.cdtype),
        lp["w_down"].astype(cfg.cdtype),
    )
    return x


def forward(params, tokens, cfg: TransformerConfig, layer_fn=layer_fwd,
            attn_backend: Optional[str] = None, acts=None):
    """tokens: int32 [B, S] -> logits f32 [B, S, V]."""
    from repro.distributed.actshard import constrain

    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    x = constrain(x, acts, "res")
    s = tokens.shape[1]
    cos, sin = L.rope_freqs(cfg.head_dim, s, cfg.rope_theta)

    def body(x, lp):
        return constrain(
            layer_fn(lp, x, cfg, cos, sin, attn_backend=attn_backend), acts, "res"
        ), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(x, params["ln_f"])
    unemb = params.get("unembed", None)
    w = unemb if unemb is not None else params["embed"].T
    logits = (x @ w.astype(cfg.cdtype)).astype(jnp.float32)
    return constrain(logits, acts, "logits")


def forward_hidden(params, tokens, cfg: TransformerConfig, layer_fn=layer_fwd,
                   attn_backend: Optional[str] = None, acts=None):
    """tokens -> final hidden states [B, S, D] (pre-unembed)."""
    from repro.distributed.actshard import constrain

    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    x = constrain(x, acts, "res")
    s = tokens.shape[1]
    cos, sin = L.rope_freqs(cfg.head_dim, s, cfg.rope_theta)

    def body(x, lp):
        return constrain(
            layer_fn(lp, x, cfg, cos, sin, attn_backend=attn_backend), acts, "res"
        ), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.rmsnorm(x, params["ln_f"])


def loss_fn(params, batch, cfg: TransformerConfig, layer_fn=layer_fwd, acts=None):
    x = forward_hidden(params, batch["tokens"], cfg, layer_fn=layer_fn, acts=acts)
    unemb = params.get("unembed", None)
    w = unemb if unemb is not None else params["embed"].T
    return L.lm_loss_fused(
        x[:, :-1], w, batch["labels"][:, 1:], cfg.z_loss, acts=acts
    )




def cache_update_add(cache, new, pos):
    """Write `new` [B, H, D] into `cache` [B, H, S, D] at position `pos`.

    Implemented as a one-hot masked add instead of dynamic_update_slice:
    DUS on a sequence-sharded cache makes GSPMD gather the whole cache
    (-9 GiB/device on grok-1 decode_32k when switched; EXPERIMENTS §Perf).
    Contract: unwritten cache slots are zero-initialized.
    """
    s = cache.shape[2]
    onehot = (jnp.arange(s) == pos).astype(cache.dtype)
    return cache + new[:, :, None, :] * onehot[None, None, :, None]


# ---------------------------- serving ---------------------------------- #
def prefill(params, tokens, cfg: TransformerConfig, acts=None):
    """Run the prompt, return (kv_cache, last-token logits).

    kv cache: dict of k/v stacked [L, B, Hkv, S, D] (layer-major for scan).
    """
    from repro.distributed.actshard import constrain

    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    x = constrain(x, acts, "res")
    b, s = tokens.shape
    cos, sin = L.rope_freqs(cfg.head_dim, s, cfg.rope_theta)

    def body(x, lp):
        q, k, v, _ = _qkv(lp, x, cfg, None, cos, sin)
        o = attention(q, k, v, causal=True, local_window=cfg.local_window,
                      q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk)
        o = o.swapaxes(1, 2).reshape(b, s, cfg.n_heads * cfg.head_dim)
        x = x + o @ lp["wo"].astype(cfg.cdtype)
        xn = L.rmsnorm(x, lp["ln2"])
        x = x + L.swiglu(xn, lp["w_gate"].astype(cfg.cdtype),
                         lp["w_up"].astype(cfg.cdtype), lp["w_down"].astype(cfg.cdtype))
        return constrain(x, acts, "res"), (k, v)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(x, params["ln_f"])
    unemb = params.get("unembed", None)
    w = unemb if unemb is not None else params["embed"].T
    logits = (x[:, -1] @ w.astype(cfg.cdtype)).astype(jnp.float32)
    return {"k": ks, "v": vs}, constrain(logits, acts, "logits")


def decode_step(params, token, kv, pos, cfg: TransformerConfig, acts=None):
    """One token for the whole batch against a full KV cache.

    token: int32 [B]; kv: {"k","v": [L, B, Hkv, S, D]}; pos: int32 scalar
    (current length).  Returns (logits [B, V], updated kv).
    """
    from repro.distributed.actshard import constrain
    from repro.kernels.flash_attention.ref import decode_ref

    b = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.cdtype)[:, None, :]
    x = constrain(x, acts, "res")
    smax = kv["k"].shape[3]
    cos, sin = L.rope_freqs(cfg.head_dim, smax, cfg.rope_theta)
    positions = jnp.full((1,), pos, jnp.int32)

    def body(carry, inp):
        x = carry
        lp, kc, vc = inp
        q, k, v, _ = _qkv(lp, x, cfg, positions, cos, sin)
        kc = cache_update_add(kc, k[:, :, 0], pos)
        vc = cache_update_add(vc, v[:, :, 0], pos)
        o = decode_ref(q[:, :, 0], kc, vc, pos + 1, window=cfg.local_window)
        o = o.reshape(b, 1, cfg.n_heads * cfg.head_dim)
        x = x + o @ lp["wo"].astype(cfg.cdtype)
        xn = L.rmsnorm(x, lp["ln2"])
        x = x + L.swiglu(xn, lp["w_gate"].astype(cfg.cdtype),
                         lp["w_up"].astype(cfg.cdtype), lp["w_down"].astype(cfg.cdtype))
        return constrain(x, acts, "res"), (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], kv["k"], kv["v"]))
    x = L.rmsnorm(x, params["ln_f"])
    unemb = params.get("unembed", None)
    w = unemb if unemb is not None else params["embed"].T
    logits = (x[:, 0] @ w.astype(cfg.cdtype)).astype(jnp.float32)
    return constrain(logits, acts, "logits"), {"k": ks, "v": vs}
