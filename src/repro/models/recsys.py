"""Factorization Machine (Rendle, ICDM'10) with sharded embedding tables.

The assigned recsys arch: 39 sparse fields, embed_dim 10, 2-way FM
interaction via the O(nk) sum-square trick (``repro/kernels/fm_interaction``).

EmbeddingBag is built from primitives (JAX has no native one): gather +
segment-sum — the same kernel family as the paper's query plan.  Tables are
a single fused [total_rows, K] matrix row-sharded over the "model" mesh axis
(mod-hash row placement); lookups are plain takes that GSPMD turns into
all-to-all-free gathers when the batch is DP-sharded.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FMConfig:
    name: str
    n_fields: int = 39
    embed_dim: int = 10
    table_sizes: Tuple[int, ...] = ()
    param_dtype: str = "float32"

    @property
    def total_rows(self) -> int:
        return int(sum(self.table_sizes))

    @property
    def offsets(self):
        import numpy as np

        off = np.zeros(self.n_fields, np.int64)
        np.cumsum(self.table_sizes[:-1], out=off[1:])
        return off

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)


def default_table_sizes(n_fields: int = 39, big: int = 1_000_000,
                        small: int = 10_000) -> Tuple[int, ...]:
    """Criteo-shaped: a few huge ID tables, many small categorical ones."""
    sizes = []
    for f in range(n_fields):
        sizes.append(big if f % 5 == 0 else small)
    return tuple(sizes)


def init(key, cfg: FMConfig):
    k1, k2 = jax.random.split(key)
    return {
        "emb": (jax.random.normal(k1, (cfg.total_rows, cfg.embed_dim), jnp.float32) * 0.01).astype(cfg.pdtype),
        "w1": (jax.random.normal(k2, (cfg.total_rows,), jnp.float32) * 0.01).astype(cfg.pdtype),
        "bias": jnp.zeros((), cfg.pdtype),
    }


def _rows(cfg: FMConfig, x):
    """x: int32 [B, F] raw ids -> global row ids (mod-hash into each table).

    uint32 arithmetic keeps this exact without x64 mode (total_rows < 2^31).
    """
    sizes = jnp.asarray(cfg.table_sizes, jnp.uint32)
    offs = jnp.asarray(cfg.offsets, jnp.uint32)
    return (offs[None, :] + (x.astype(jnp.uint32) % sizes[None, :])).astype(jnp.int32)


def forward(params, x, cfg: FMConfig, use_pallas_fm: bool = False):
    """x: int32 [B, F] -> logits [B]."""
    rows = _rows(cfg, x)
    emb = jnp.take(params["emb"], rows, axis=0)  # [B, F, K]
    lin = jnp.sum(jnp.take(params["w1"], rows, axis=0), axis=-1)  # [B]
    if use_pallas_fm:
        from repro.kernels.fm_interaction.ops import fm_second_order

        inter = fm_second_order(emb.astype(jnp.float32))
    else:
        from repro.kernels.fm_interaction.ref import fm_interaction_ref

        inter = fm_interaction_ref(emb.astype(jnp.float32))
    return params["bias"].astype(jnp.float32) + lin.astype(jnp.float32) + inter


def loss_fn(params, batch, cfg: FMConfig):
    logits = forward(params, batch["x"], cfg)
    y = batch["y"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def embedding_bag(table, ids, bag_ids, num_bags, weights=None, mode="sum"):
    """General EmbeddingBag (multi-hot fields): gather + segment-sum.

    table: [R, K]; ids: [N] rows; bag_ids: [N] sorted; -> [num_bags, K].
    """
    g = jnp.take(table, ids, axis=0)
    if weights is not None:
        g = g * weights[:, None]
    out = jax.ops.segment_sum(g, bag_ids, num_segments=num_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(bag_ids, g.dtype), bag_ids, num_segments=num_bags)
        out = out / jnp.maximum(cnt[:, None], 1.0)
    return out


def retrieval_scores(params, query_x, cand_rows, cfg: FMConfig):
    """Score 1 query against N candidate items: batched dot in embedding
    space (no per-candidate loop).  cand_rows: int32 [N] embedding rows."""
    rows = _rows(cfg, query_x)  # [1, F]
    q = jnp.take(params["emb"], rows[0], axis=0).sum(axis=0)  # [K]
    cand = jnp.take(params["emb"], cand_rows, axis=0)  # [N, K]
    return cand @ q
