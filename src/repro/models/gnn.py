"""GNN zoo on the segment-reduce substrate (DESIGN.md §4).

Message passing is everywhere the *same primitive the paper's query plan
uses*: gather rows by edge endpoint, segment-reduce into the destination —
so GCN / GraphSAGE / GAT / MeshGraphNet all ride
``jax.ops.segment_sum`` (XLA) or the Pallas tiled plan (TPU, static graphs).

Inputs are padded edge lists (``DeviceGraph`` layout: edges sorted by dst,
padding edges point at the sink row ``n``) so every step is pjit-static.

Integration of the paper's technique: ``khop_aggregate`` evaluates a k-hop
window sum over node features using a prebuilt DBIndex plan — GraphSAGE-like
neighborhood statistics at the cost of two segment-sums instead of a k-step
propagation (used by the graphsage config's window-feature variant and
benchmarked in §Perf).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str  # gcn | gat | sage | meshgraphnet
    n_layers: int
    d_in: int
    d_hidden: int
    d_out: int
    n_heads: int = 1
    aggregator: str = "mean"  # mean | sum | attn
    mlp_layers: int = 2
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


def _constrain(x, spec):
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ------------------------- message passing ----------------------------- #
def scatter_mean(messages, dst, n):
    s = jax.ops.segment_sum(messages, dst, num_segments=n + 1)[:n]
    cnt = jax.ops.segment_sum(jnp.ones_like(dst, messages.dtype), dst, num_segments=n + 1)[:n]
    return s / jnp.maximum(cnt[:, None], 1.0)


def scatter_sum(messages, dst, n):
    return jax.ops.segment_sum(messages, dst, num_segments=n + 1)[:n]


def edge_softmax(scores, dst, n):
    """scores: [E, H] -> softmax over incoming edges per (dst, head)."""
    m = jax.ops.segment_max(scores, dst, num_segments=n + 1)[:n]
    m = jnp.nan_to_num(jnp.take(m, jnp.minimum(dst, n - 1), axis=0), neginf=0.0)
    e = jnp.exp(scores - m)
    z = jax.ops.segment_sum(e, dst, num_segments=n + 1)[:n]
    z = jnp.take(z, jnp.minimum(dst, n - 1), axis=0)
    return e / jnp.maximum(z, 1e-16)


# ------------------------------ models --------------------------------- #
def gcn_init(key, cfg: GNNConfig):
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.d_out]
    ks = jax.random.split(key, cfg.n_layers)
    return {"w": [L.dense_init(k, a, b, cfg.pdtype) for k, a, b in zip(ks, dims[:-1], dims[1:])]}


def gcn_forward(params, feats, edge_src, edge_dst, edge_w, n, cfg: GNNConfig,
                node_spec=None):
    """Sym-normalized GCN.  edge_w = 1/sqrt(deg_s * deg_d) precomputed."""
    h = feats.astype(cfg.cdtype)
    for i, w in enumerate(params["w"]):
        msg = jnp.take(h, jnp.minimum(edge_src, n - 1), axis=0) * edge_w[:, None]
        agg = scatter_sum(jnp.where((edge_dst < n)[:, None], msg, 0), jnp.minimum(edge_dst, n), n)
        h = _constrain(agg @ w.astype(cfg.cdtype), node_spec)
        if i < len(params["w"]) - 1:
            h = jax.nn.relu(h)
    return h


def sage_init(key, cfg: GNNConfig):
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.d_out]
    ks = jax.random.split(key, 2 * cfg.n_layers)
    return {
        "w_self": [L.dense_init(k, a, b, cfg.pdtype) for k, a, b in zip(ks[::2], dims[:-1], dims[1:])],
        "w_nbr": [L.dense_init(k, a, b, cfg.pdtype) for k, a, b in zip(ks[1::2], dims[:-1], dims[1:])],
    }


def sage_forward(params, feats, edge_src, edge_dst, n, cfg: GNNConfig,
                 node_spec=None):
    h = feats.astype(cfg.cdtype)
    for i, (ws, wn) in enumerate(zip(params["w_self"], params["w_nbr"])):
        msg = jnp.take(h, jnp.minimum(edge_src, n - 1), axis=0)
        msg = jnp.where((edge_dst < n)[:, None], msg, 0)
        agg = scatter_mean(msg, jnp.minimum(edge_dst, n), n)
        h = _constrain(h @ ws.astype(cfg.cdtype) + agg @ wn.astype(cfg.cdtype), node_spec)
        if i < len(params["w_self"]) - 1:
            h = jax.nn.relu(h)
    return h


def gat_init(key, cfg: GNNConfig):
    ks = jax.random.split(key, 3 * cfg.n_layers)
    ws, al, ar = [], [], []
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        d_out = cfg.d_out if i == cfg.n_layers - 1 else cfg.d_hidden
        ws.append(L.dense_init(ks[3 * i], d_in, cfg.n_heads * d_out, cfg.pdtype))
        al.append(L.dense_init(ks[3 * i + 1], d_out, cfg.n_heads, cfg.pdtype, scale=0.1))
        ar.append(L.dense_init(ks[3 * i + 2], d_out, cfg.n_heads, cfg.pdtype, scale=0.1))
        d_in = cfg.n_heads * d_out if i < cfg.n_layers - 1 else d_out
    return {"w": ws, "a_l": al, "a_r": ar}


def gat_forward(params, feats, edge_src, edge_dst, n, cfg: GNNConfig,
                node_spec=None):
    h = feats.astype(cfg.cdtype)
    nl = len(params["w"])
    for i in range(nl):
        d_out = cfg.d_out if i == nl - 1 else cfg.d_hidden
        hw = (h @ params["w"][i].astype(cfg.cdtype)).reshape(n, cfg.n_heads, d_out)
        # a_l/a_r: [d_out, H] -> per-(node, head) scalars
        sl = jnp.einsum("nhd,dh->nh", hw, params["a_l"][i].astype(cfg.cdtype))
        sr = jnp.einsum("nhd,dh->nh", hw, params["a_r"][i].astype(cfg.cdtype))
        es = jnp.minimum(edge_src, n - 1)
        ed = jnp.minimum(edge_dst, n - 1)
        scores = jax.nn.leaky_relu(
            jnp.take(sl, es, axis=0) + jnp.take(sr, ed, axis=0), 0.2
        )
        valid = (edge_dst < n)[:, None]
        scores = jnp.where(valid, scores, -1e30)
        alpha = edge_softmax(scores, ed, n)  # [E, H]
        msg = jnp.take(hw, es, axis=0) * alpha[..., None]
        msg = jnp.where(valid[..., None], msg, 0)
        agg = jax.ops.segment_sum(
            msg.reshape(-1, cfg.n_heads * d_out), ed, num_segments=n
        )
        agg = _constrain(agg, node_spec)
        if i < nl - 1:
            h = jax.nn.elu(agg)
        else:
            h = agg.reshape(n, cfg.n_heads, d_out).mean(axis=1)
    return h


def mgn_init(key, cfg: GNNConfig, d_edge: int = 3):
    """MeshGraphNet: encoder/decoder MLPs + `n_layers` processor steps
    (stacked for lax.scan)."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    hid = cfg.d_hidden
    mk = lambda k, dims: L.mlp_init(k, dims, cfg.pdtype)
    proc_keys = jax.random.split(k3, cfg.n_layers)

    def proc_init(k):
        ka, kb = jax.random.split(k)
        return {
            "edge_mlp": mk(ka, [3 * hid, hid, hid]),
            "node_mlp": mk(kb, [2 * hid, hid, hid]),
        }

    stacked = jax.vmap(proc_init)(proc_keys)
    return {
        "node_enc": mk(k1, [cfg.d_in, hid, hid]),
        "edge_enc": mk(k2, [d_edge, hid, hid]),
        "proc": stacked,
        "node_dec": mk(k4, [hid, hid, cfg.d_out]),
    }


def mgn_forward(params, feats, edge_feats, edge_src, edge_dst, n, cfg: GNNConfig,
                remat_chunk: int = 3, node_spec=None):
    h = L.mlp_apply(params["node_enc"], feats.astype(cfg.cdtype))
    e = L.mlp_apply(params["edge_enc"], edge_feats.astype(cfg.cdtype))
    es = jnp.minimum(edge_src, n - 1)
    ed = jnp.minimum(edge_dst, n - 1)
    valid = (edge_dst < n)[:, None]

    def step(carry, lp):
        h, e = carry
        inp = jnp.concatenate([e, jnp.take(h, es, axis=0), jnp.take(h, ed, axis=0)], -1)
        e2 = e + L.mlp_apply(lp["edge_mlp"], inp)
        agg = scatter_sum(jnp.where(valid, e2, 0), ed, n)
        h2 = _constrain(h + L.mlp_apply(lp["node_mlp"], jnp.concatenate([h, agg], -1)),
                        node_spec)
        return (h2, e2), None

    # nested remat: the (h, e) carry of every processor step is the bwd
    # footprint (e alone is |E|*d floats); checkpointing chunks of
    # `remat_chunk` steps keeps only every 3rd carry and recomputes the
    # rest (-13x temp on meshgraphnet x ogb_products; §Perf iteration A1).
    nl = cfg.n_layers
    chunk = remat_chunk if nl % remat_chunk == 0 else 1
    if chunk > 1:
        stacked = jax.tree_util.tree_map(
            lambda x: x.reshape(nl // chunk, chunk, *x.shape[1:]), params["proc"]
        )

        @jax.checkpoint
        def chunk_step(carry, lps):
            return jax.lax.scan(step, carry, lps)

        (h, e), _ = jax.lax.scan(chunk_step, (h, e), stacked)
    else:
        (h, e), _ = jax.lax.scan(jax.checkpoint(step), (h, e), params["proc"])
    return L.mlp_apply(params["node_dec"], h)


# ---------------- paper-technique integration ------------------------- #
def khop_aggregate(plan, node_values):
    """k-hop window SUM of node features via the DBIndex plan — the paper's
    shared two-stage aggregation as a GNN feature operator."""
    from repro.core.engine_jax import query_dbindex

    return query_dbindex(plan, node_values, "sum", use_pallas=False)
