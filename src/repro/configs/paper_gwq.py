"""paper-gwq — the paper's own workload as a servable architecture.

Graph window queries over a LiveJournal/Orkut-scale graph: the sharded
two-stage DBIndex data plane (pass 1 blocks, psum T, pass 2 owners).  Plan
dimensions are extrapolated from measured index statistics at bench scale
(members ~= total window size, links ~= 1.5/vertex, blocks ~= n/2 — see
EXPERIMENTS.md §Dry-run).

Shapes:
* query_lj    — LiveJournal1 (4.0M vertices), 2-hop windows, avg |W|=214
* query_orkut — Orkut (3.07M vertices), 2-hop windows, avg |W|=650
* query_1b    — extrapolated 1e9-member plan (pod-scale stress)
"""

from repro.configs.registry import ArchSpec, ShapeCase

SHAPES = {
    "query_lj": ShapeCase(
        "query_lj", "serve",
        dict(n=3_997_962, nb=2_000_000, m=855_000_000 // 16, l=6_000_000),
        "members scaled 1/16 (matches measured dense-block compression at k=2)",
    ),
    "query_orkut": ShapeCase(
        "query_orkut", "serve",
        dict(n=3_072_441, nb=1_536_000, m=1_997_000_000 // 16, l=4_600_000),
    ),
    "query_1b": ShapeCase(
        "query_1b", "serve",
        dict(n=100_000_000, nb=50_000_000, m=1_000_000_000, l=150_000_000),
        "pod-scale stress plan",
    ),
    # §Perf iteration B1: blocks co-located with their owner shards (the
    # MinHash clusters ARE locality groups), so only the boundary fraction
    # of block partials and owner results crosses devices.
    "query_1b_part": ShapeCase(
        "query_1b_part", "serve",
        dict(n=100_000_000, nb=50_000_000, m=1_000_000_000, l=150_000_000,
             boundary_frac=10),
        "locality-partitioned plan: 1/10 of blocks/owners are boundary",
    ),
}


def spec() -> ArchSpec:
    return ArchSpec(
        name="paper-gwq",
        family="paper",
        model_cfg=dict(SHAPES),
        smoke_cfg=None,
        shapes=SHAPES,
        skip={},
        notes="the paper's contribution as a first-class servable workload",
    )
