"""grok-1-314b — 8-expert top-2 MoE [hf:xai-org/grok-1; unverified].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
Sharding: experts TP-sharded on d_ff over "model" (8 experts don't divide
the 16-way axis); params+Adafactor state FSDP over the full mesh.
"""

from repro.configs.registry import LM_SHAPES, ArchSpec
from repro.models.moe import MoEConfig

CONFIG = MoEConfig(
    name="grok-1-314b",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    n_experts=8,
    top_k=2,
    # 314B posture: bf16 params + Adafactor f32 accumulators (T5X-style
    # master-less training) — halves weight HBM and removes the stacked
    # f32->bf16 weight converts from the step (§Perf iteration C2).
    param_dtype="bfloat16",
    attn_kv_chunk=2048,
)

SMOKE = MoEConfig(
    name="grok-1-smoke",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=4,
    d_ff=256,
    vocab=512,
    n_experts=4,
    top_k=2,
    remat=False,
)


def spec() -> ArchSpec:
    return ArchSpec(
        name="grok-1-314b",
        family="lm-moe",
        model_cfg=CONFIG,
        smoke_cfg=SMOKE,
        shapes=LM_SHAPES,
        skip={"long_500k": "pure full-attention arch; see DESIGN.md §4"},
    )
