"""minitron-4b — pruned Nemotron [arXiv:2407.14679; hf].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.  Pure full
attention -> long_500k skipped per assignment (DESIGN.md §4).
"""

from repro.configs.registry import LM_SHAPES, ArchSpec
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="minitron-4b",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab=256000,
)

SMOKE = TransformerConfig(
    name="minitron-4b-smoke",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=4,
    d_ff=384,
    vocab=512,
    remat=False,
)


def spec() -> ArchSpec:
    return ArchSpec(
        name="minitron-4b",
        family="lm-dense",
        model_cfg=CONFIG,
        smoke_cfg=SMOKE,
        shapes=LM_SHAPES,
        skip={"long_500k": "pure full-attention arch; sub-quadratic attention "
                           "required for 500k decode per assignment (bonus row "
                           "with local_window=4096 reported separately)"},
    )
