"""gcn-cora — [arXiv:1609.02907; paper].

2 layers, d_hidden=16, mean/sym-norm aggregator.
"""

import dataclasses

from repro.configs.registry import GNN_SHAPES, ArchSpec
from repro.models.gnn import GNNConfig

TEMPLATE = GNNConfig(
    name="gcn-cora",
    kind="gcn",
    n_layers=2,
    d_in=-1,
    d_hidden=16,
    d_out=-1,
    aggregator="mean",
)

SMOKE = GNNConfig(
    name="gcn-smoke", kind="gcn", n_layers=2, d_in=12, d_hidden=8, d_out=3,
)


def cfg_for(dims) -> GNNConfig:
    return dataclasses.replace(TEMPLATE, d_in=dims["d_feat"], d_out=dims["classes"])


def spec() -> ArchSpec:
    return ArchSpec(
        name="gcn-cora",
        family="gnn",
        model_cfg=TEMPLATE,
        smoke_cfg=SMOKE,
        shapes=GNN_SHAPES,
        skip={},
        notes="1-hop window with sym-norm weights == GCN propagate",
    )
