"""Architecture registry: 10 assigned archs + the paper's own GWQ workload."""

from repro.configs.registry import ARCHS, get_arch, ShapeCase  # noqa: F401
