"""minitron-8b — pruned Nemotron [arXiv:2407.14679; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""

from repro.configs.registry import LM_SHAPES, ArchSpec
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="minitron-8b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab=256000,
)

SMOKE = TransformerConfig(
    name="minitron-8b-smoke",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=512,
    vocab=512,
    remat=False,
)


def spec() -> ArchSpec:
    return ArchSpec(
        name="minitron-8b",
        family="lm-dense",
        model_cfg=CONFIG,
        smoke_cfg=SMOKE,
        shapes=LM_SHAPES,
        skip={"long_500k": "pure full-attention arch; see DESIGN.md §4"},
    )
