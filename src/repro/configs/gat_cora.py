"""gat-cora — [arXiv:1710.10903; paper].

2 layers, d_hidden=8, 8 heads, attention aggregator.  Per-edge attention
weights invalidate the paper's partial-aggregate sharing (DESIGN.md §4);
the window/bitset machinery is still used for neighborhood extraction.
"""

import dataclasses

from repro.configs.registry import GNN_SHAPES, ArchSpec
from repro.models.gnn import GNNConfig

TEMPLATE = GNNConfig(
    name="gat-cora",
    kind="gat",
    n_layers=2,
    d_in=-1,
    d_hidden=8,
    d_out=-1,
    n_heads=8,
    aggregator="attn",
)

SMOKE = GNNConfig(
    name="gat-smoke", kind="gat", n_layers=2, d_in=12, d_hidden=8, d_out=3,
    n_heads=4, aggregator="attn",
)


def cfg_for(dims) -> GNNConfig:
    return dataclasses.replace(TEMPLATE, d_in=dims["d_feat"], d_out=dims["classes"])


def spec() -> ArchSpec:
    return ArchSpec(
        name="gat-cora",
        family="gnn",
        model_cfg=TEMPLATE,
        smoke_cfg=SMOKE,
        shapes=GNN_SHAPES,
        skip={},
        notes="block sharing inapplicable (per-edge attention weights)",
    )
