"""qwen3-0.6b — qk_norm + GQA [hf:Qwen/Qwen3-8B family; hf].

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.
"""

from repro.configs.registry import LM_SHAPES, ArchSpec
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen3-0.6b",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = TransformerConfig(
    name="qwen3-0.6b-smoke",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=3,
    d_ff=256,
    vocab=512,
    qk_norm=True,
    tie_embeddings=True,
    remat=False,
)


def spec() -> ArchSpec:
    return ArchSpec(
        name="qwen3-0.6b",
        family="lm-dense",
        model_cfg=CONFIG,
        smoke_cfg=SMOKE,
        shapes=LM_SHAPES,
        skip={"long_500k": "pure full-attention arch; see DESIGN.md §4"},
    )
