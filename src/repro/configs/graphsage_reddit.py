"""graphsage-reddit — [arXiv:1706.02216; paper].

2 layers, d_hidden=128, mean aggregator, sample sizes 25-10 (training uses
the shape table's 15-10 fanout for the sampled subgraph dims).
d_in / d_out are shape-dependent (each GNN shape carries its own d_feat /
classes), so the model config is a template instantiated per shape.

Paper-technique hook: the window-feature variant augments node inputs with
DBIndex-shared k-hop aggregates (models.gnn.khop_aggregate) — this is the
assigned arch where the paper's contribution lands most directly.
"""

import dataclasses

from repro.configs.registry import GNN_SHAPES, ArchSpec
from repro.models.gnn import GNNConfig

TEMPLATE = GNNConfig(
    name="graphsage-reddit",
    kind="sage",
    n_layers=2,
    d_in=-1,  # per shape
    d_hidden=128,
    d_out=-1,
    aggregator="mean",
)

SMOKE = GNNConfig(
    name="graphsage-smoke", kind="sage", n_layers=2, d_in=16, d_hidden=8, d_out=3,
    aggregator="mean",
)


def cfg_for(dims) -> GNNConfig:
    return dataclasses.replace(TEMPLATE, d_in=dims["d_feat"], d_out=dims["classes"])


def spec() -> ArchSpec:
    return ArchSpec(
        name="graphsage-reddit",
        family="gnn",
        model_cfg=TEMPLATE,
        smoke_cfg=SMOKE,
        shapes=GNN_SHAPES,
        skip={},
        notes="paper technique applies directly (k-hop window aggregation)",
    )
