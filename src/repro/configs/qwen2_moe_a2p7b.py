"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) d_ff=1408 (per expert) vocab=151936.
Shared experts fused into one SwiGLU of width 4*1408=5632 (public config's
shared_expert_intermediate_size).  EP hillclimb knob: pad 60->64 experts so
the expert dim shards 16-way.
"""

from repro.configs.registry import LM_SHAPES, ArchSpec
from repro.models.moe import MoEConfig

CONFIG = MoEConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    d_ff_shared=5632,
)

# EP variant used by the §Perf hillclimb
CONFIG_EP = MoEConfig(
    name="qwen2-moe-a2.7b-ep",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    d_ff_shared=5632,
    pad_experts_to=64,
)

SMOKE = MoEConfig(
    name="qwen2-moe-smoke",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=8,
    d_ff=96,
    vocab=512,
    n_experts=6,
    top_k=4,
    n_shared_experts=2,
    d_ff_shared=192,
    remat=False,
)


def spec() -> ArchSpec:
    return ArchSpec(
        name="qwen2-moe-a2.7b",
        family="lm-moe",
        model_cfg=CONFIG,
        smoke_cfg=SMOKE,
        shapes=LM_SHAPES,
        skip={"long_500k": "pure full-attention arch; see DESIGN.md §4"},
    )
