"""meshgraphnet — [arXiv:2010.03409; unverified].

15 processor layers, d_hidden=128, sum aggregator, 2-layer MLPs.
Regression head (node targets); near-regular mesh graphs mean the paper's
dense-block sharing gain is small here (DESIGN.md §4) — supported, measured.
"""

import dataclasses

from repro.configs.registry import GNN_SHAPES, ArchSpec
from repro.models.gnn import GNNConfig

TEMPLATE = GNNConfig(
    name="meshgraphnet",
    kind="meshgraphnet",
    n_layers=15,
    d_in=-1,
    d_hidden=128,
    d_out=2,
    aggregator="sum",
    mlp_layers=2,
)

SMOKE = GNNConfig(
    name="meshgraphnet-smoke", kind="meshgraphnet", n_layers=3, d_in=8,
    d_hidden=16, d_out=2, aggregator="sum",
)


def cfg_for(dims) -> GNNConfig:
    return dataclasses.replace(TEMPLATE, d_in=dims["d_feat"])


def spec() -> ArchSpec:
    return ArchSpec(
        name="meshgraphnet",
        family="gnn",
        model_cfg=TEMPLATE,
        smoke_cfg=SMOKE,
        shapes=GNN_SHAPES,
        skip={},
    )
