"""fm — pairwise FM via the O(nk) sum-square trick [ICDM'10 (Rendle); paper].

39 sparse fields, embed_dim=10, Criteo-shaped tables (8 ID tables of 10M
rows + 31 categorical tables of 10k rows -> 80.3M rows), row-sharded over
the "model" mesh axis.  EmbeddingBag = gather + segment-sum (the paper's
primitive).
"""

from repro.configs.registry import RECSYS_SHAPES, ArchSpec
from repro.models.recsys import FMConfig, default_table_sizes

CONFIG = FMConfig(
    name="fm",
    n_fields=39,
    embed_dim=10,
    table_sizes=default_table_sizes(39, big=10_000_000, small=10_000),
)

SMOKE = FMConfig(
    name="fm-smoke",
    n_fields=8,
    embed_dim=10,
    table_sizes=default_table_sizes(8, big=1000, small=100),
)


def spec() -> ArchSpec:
    return ArchSpec(
        name="fm",
        family="recsys",
        model_cfg=CONFIG,
        smoke_cfg=SMOKE,
        shapes=RECSYS_SHAPES,
        skip={},
        notes="embedding lookup is the hot path; FM interaction kernel fused",
    )
