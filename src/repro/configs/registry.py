"""Central architecture registry.

Every assigned architecture registers an :class:`ArchSpec`:

* ``model_cfg``   — the exact public config (full scale),
* ``smoke_cfg``   — reduced same-family config for CPU smoke tests,
* ``shapes``      — the arch's own input-shape set (``ShapeCase``),
* ``skip``        — shape -> reason (e.g. long_500k on pure full-attention),
* ``input_specs(shape)``  — ShapeDtypeStruct stand-ins (no allocation),
* ``build_step(shape, mesh, dp_axes)`` — (fn, in_shardings, out_shardings,
  arg ShapeDtypeStructs) ready for ``jax.jit(...).lower(...)``.

Step construction itself lives in :mod:`repro.launch.steps` (one builder per
family); config modules stay declarative.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, Optional

ARCH_MODULES = {
    "minitron-4b": "repro.configs.minitron_4b",
    "qwen3-0.6b": "repro.configs.qwen3_0p6b",
    "minitron-8b": "repro.configs.minitron_8b",
    "grok-1-314b": "repro.configs.grok1_314b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2p7b",
    "graphsage-reddit": "repro.configs.graphsage_reddit",
    "meshgraphnet": "repro.configs.meshgraphnet",
    "gcn-cora": "repro.configs.gcn_cora",
    "gat-cora": "repro.configs.gat_cora",
    "fm": "repro.configs.fm_criteo",
    "paper-gwq": "repro.configs.paper_gwq",
}


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    kind: str  # train | prefill | decode | serve | retrieval
    dims: Dict[str, int]
    comment: str = ""


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str  # lm-dense | lm-moe | gnn | recsys | paper
    model_cfg: Any
    smoke_cfg: Any
    shapes: Dict[str, ShapeCase]
    skip: Dict[str, str]
    notes: str = ""


_cache: Dict[str, ArchSpec] = {}


def get_arch(name: str) -> ArchSpec:
    if name not in _cache:
        mod = importlib.import_module(ARCH_MODULES[name])
        _cache[name] = mod.spec()
    return _cache[name]


def ARCHS():
    return list(ARCH_MODULES)


# ----------------------- shared shape tables --------------------------- #
LM_SHAPES = {
    "train_4k": ShapeCase("train_4k", "train", dict(seq=4096, batch=256)),
    "prefill_32k": ShapeCase("prefill_32k", "prefill", dict(seq=32768, batch=32)),
    "decode_32k": ShapeCase("decode_32k", "decode", dict(seq=32768, batch=128)),
    "long_500k": ShapeCase("long_500k", "decode", dict(seq=524288, batch=1),
                           "long-context decode; needs sub-quadratic attention"),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeCase(
        "full_graph_sm", "train", dict(n=2708, e=10556, d_feat=1433, classes=7)
    ),
    "minibatch_lg": ShapeCase(
        "minibatch_lg", "train",
        dict(n=232965, e=114615892, batch_nodes=1024, fan1=15, fan2=10,
             d_feat=602, classes=41,
             sub_n=1024 * (1 + 15 + 150), sub_e=1024 * 15 + 1024 * 150),
        "sampled training: device sees the padded sampled subgraph",
    ),
    "ogb_products": ShapeCase(
        "ogb_products", "train", dict(n=2449029, e=61859140, d_feat=100, classes=47)
    ),
    "molecule": ShapeCase(
        "molecule", "train", dict(n=30, e=64, batch=128, d_feat=16, classes=1)
    ),
}

RECSYS_SHAPES = {
    "train_batch": ShapeCase("train_batch", "train", dict(batch=65536)),
    "serve_p99": ShapeCase("serve_p99", "serve", dict(batch=512)),
    "serve_bulk": ShapeCase("serve_bulk", "serve", dict(batch=262144)),
    "retrieval_cand": ShapeCase(
        "retrieval_cand", "retrieval", dict(batch=1, n_candidates=1_000_000)
    ),
}
