"""Analytic roofline terms per (arch x shape) — the primary §Roofline
numbers.

Why analytic: XLA's ``cost_analysis()`` counts a ``while``-loop body once,
and every model here scans over layers (plus inner flash/loss/dispatch
scans), so raw HLO flops/bytes undercount by ~L.  The HLO numbers stay in
the report as per-iteration diagnostics; the terms below use standard
first-principles models (PaLM-appendix-style for LMs), stated explicitly:

LM train   : flops = 8*N_active*T (6ND + remat refwd 2ND)
             + attention 12*L*T*(S/2)*d_model (fwd+bwd+remat)
             bytes = weights 2 reads + 1 write (bf16 compute copies)
             + opt state rw (f32/bf16) + activations ~14*L*T*d bytes
             coll  = FSDP allgather 2P + grad RS/AG 6P (bf16)
             + TP psum 4*L*T*d/chips (bf16, ring-counted once)
LM prefill : flops = 2*N_active*T + 6*L*T*(S/2)*d; no opt traffic
LM decode  : flops = 2*N_active*B + 4*L*B*S*d (cache read dominates bytes:
             2*L*B*S*hkv*hd*2 per step)
GNN train  : flops = 3 * L * (4*E*d + 2*N*d_in*d_out) (fwd+bwd)
             bytes = 3 * L * (2*E*d*4 + 3*N*d*4)
             coll  = L * N * d * 4 * 2 (edge-sharded psum per layer)
FM train   : flops = 3 * (2*B*F*K + B*F); bytes = 3*B*F*(K+1)*4*2
             coll  = B*F*K*4 (row-sharded gather) + B*4
paper-gwq  : flops = 2*(m + l)/chips adds; bytes = (m+l)*8 + n*8
             coll  = 2*(nb + n)*4 (two psums)

All terms are per chip, in seconds, at TPU v5e constants (197 TFLOP/s bf16,
819 GB/s HBM, 50 GB/s/link ICI).
"""

from __future__ import annotations

from typing import Dict

from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS


def analytic_terms(arch_name: str, shape_name: str, chips: int) -> Dict:
    from repro.configs.registry import get_arch
    from repro.models.moe import MoEConfig

    arch = get_arch(arch_name)
    case = arch.shapes[shape_name]
    dims = case.dims
    fam = arch.family

    if fam in ("lm-dense", "lm-moe"):
        cfg = arch.model_cfg
        n_active = cfg.n_active_params() if isinstance(cfg, MoEConfig) else cfg.n_params()
        n_total = cfg.n_params()
        L, d = cfg.n_layers, cfg.d_model
        hkv, hd = cfg.n_kv_heads, cfg.head_dim
        if case.kind == "train":
            T = dims["batch"] * dims["seq"]
            S = dims["seq"]
            flops = 8.0 * n_active * T + 12.0 * L * T * (S / 2) * d
            bytes_ = (
                3 * n_total * 2  # weight traffic (bf16 compute copies)
                + n_total * (4 + 2 + 2 + 4)  # opt read/write (f32 + bf16 moments)
                + 14.0 * L * T * d * 2 / 1  # activations (bf16, remat-bounded)
            )
            coll = 2 * n_total * 2 + 6 * n_total * 2 + 4.0 * L * T * d * 2 / chips
            return _pack(flops / chips, bytes_ / chips, coll / chips, chips)
        if case.kind == "prefill":
            T = dims["batch"] * dims["seq"]
            S = dims["seq"]
            flops = 2.0 * n_active * T + 6.0 * L * T * (S / 2) * d
            bytes_ = n_total * 2 + 6.0 * L * T * d * 2
            coll = n_total * 2 / 4 + 2.0 * L * T * d * 2 / chips
            return _pack(flops / chips, bytes_ / chips, coll / chips, chips)
        if case.kind == "decode":
            B = dims["batch"]
            S = dims["seq"]
            flops = 2.0 * n_active * B + 4.0 * L * B * S * hkv * hd
            cache = 2.0 * L * B * S * hkv * hd * 2
            bytes_ = n_total * 2 + cache
            coll = 2.0 * L * B * d * 2  # per-layer TP psums of the token
            return _pack(flops / chips, bytes_ / chips, coll / chips, chips)

    if fam == "gnn":
        import importlib

        mod = importlib.import_module(
            {
                "graphsage-reddit": "repro.configs.graphsage_reddit",
                "meshgraphnet": "repro.configs.meshgraphnet",
                "gcn-cora": "repro.configs.gcn_cora",
                "gat-cora": "repro.configs.gat_cora",
            }[arch_name]
        )
        cfg = mod.cfg_for(dims)
        n = dims.get("sub_n", dims["n"] * dims.get("batch", 1))
        e = dims.get("sub_e", dims["e"] * dims.get("batch", 1))
        L, dh = cfg.n_layers, cfg.d_hidden
        flops = 3.0 * L * (4.0 * e * dh + 2.0 * n * dh * dh) + 3.0 * 2 * n * dims["d_feat"] * dh
        bytes_ = 3.0 * L * (2.0 * e * dh * 4 + 3.0 * n * dh * 4) + n * dims["d_feat"] * 4
        coll = L * n * dh * 4 * 2
        return _pack(flops / chips, bytes_ / chips, coll / chips, chips)

    if fam == "recsys":
        cfg = arch.model_cfg
        B = dims.get("batch", 1)
        F, K = cfg.n_fields, cfg.embed_dim
        mult = 3.0 if case.kind == "train" else 1.0
        if case.kind == "retrieval":
            nc = dims["n_candidates"]
            flops = 2.0 * nc * K
            bytes_ = nc * K * 4
            coll = nc * 4
        else:
            flops = mult * (2.0 * B * F * K + B * F)
            bytes_ = mult * B * F * (K + 1) * 4 * 2
            coll = B * F * K * 4 + B * 4
        return _pack(flops / chips, bytes_ / chips, coll / chips, chips)

    if fam == "paper":
        m, l, n, nb = dims["m"], dims["l"], dims["n"], dims["nb"]
        flops = 2.0 * (m + l)
        bytes_ = (m + l) * 8.0 + n * 8.0
        coll = 2.0 * (nb + n) * 4.0
        return _pack(flops / chips, bytes_ / chips, coll / chips, chips)

    raise ValueError((arch_name, shape_name))


def _pack(flops, bytes_, coll_bytes, chips):
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_l = coll_bytes / ICI_BW
    dominant = max(
        ("compute", t_c), ("memory", t_m), ("collective", t_l), key=lambda kv: kv[1]
    )[0]
    bound = max(t_c, t_m, t_l)
    return {
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_,
        "coll_bytes_per_chip": coll_bytes,
        "terms": {"compute_s": t_c, "memory_s": t_m, "collective_s": t_l},
        "dominant": dominant,
        "roofline_bound_s": bound,
        "roofline_fraction": t_c / bound if bound > 0 else 0.0,  # compute utilization at the bound
    }
