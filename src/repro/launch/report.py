"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the jsonl
reports (``python -m repro.launch.report``)."""

from __future__ import annotations

import json
from pathlib import Path


def load(path):
    rows = {}
    if not Path(path).exists():
        return rows
    for line in open(path):
        r = json.loads(line)
        rows[(r["arch"], r["shape"])] = r  # last write wins
    return rows


def fmt_bytes(b):
    return f"{b/2**30:.2f}" if b else "-"


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 0.1:
        return f"{x:.3f}"
    if x >= 1e-4:
        return f"{x*1e3:.2f}m"
    return f"{x*1e6:.1f}u"


def render(report_dir="reports"):
    one = load(Path(report_dir) / "dryrun_1pod.jsonl")
    two = load(Path(report_dir) / "dryrun_2pod.jsonl")
    lines = []
    lines.append("### Dry-run matrix (status | args GiB/dev | temp GiB/dev; 1-pod 16x16 / 2-pod 2x16x16)\n")
    lines.append("| arch | shape | 1pod | 2pod | args/dev | temp/dev (1pod) |")
    lines.append("|---|---|---|---|---|---|")
    for key in sorted(one):
        a, s = key
        r1, r2 = one[key], two.get(key, {})
        st1, st2 = r1["status"], r2.get("status", "-")
        if st1 == "skipped":
            lines.append(f"| {a} | {s} | skip | skip | - | - ({r1['reason'][:40]}...) |")
            continue
        lines.append(
            f"| {a} | {s} | {st1} | {st2} | "
            f"{fmt_bytes(r1.get('argument_bytes'))} | {fmt_bytes(r1.get('bytes_per_device'))} |"
        )
    lines.append("")
    lines.append("### Roofline terms (single-pod 256 chips, per device; seconds)\n")
    lines.append("Analytic terms are primary (XLA cost_analysis counts scan bodies "
                 "once — see EXPERIMENTS §Roofline methodology); HLO column = "
                 "measured per-iteration diagnostic.\n")
    lines.append("| arch | shape | compute | memory | collective | dominant | roofline frac (compute/bound) | HLO coll bytes |")
    lines.append("|---|---|---|---|---|---|---|---|")
    from repro.launch.analytic import analytic_terms

    for key in sorted(one):
        r = one[key]
        if r["status"] != "ok":
            continue
        try:
            an = analytic_terms(key[0], key[1], 256)
        except Exception:
            continue
        t = an["terms"]
        hlo_coll = r["roofline"]["collective_bytes_total"]
        lines.append(
            f"| {key[0]} | {key[1]} | {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} | "
            f"{fmt_s(t['collective_s'])} | **{an['dominant']}** | "
            f"{an['roofline_fraction']:.2f} | {hlo_coll/2**20:.0f}M |"
        )
    lines.append("")
    lines.append("### Collective breakdown (1-pod, bytes summed over HLO)\n")
    lines.append("| arch | shape | all-reduce | all-gather | reduce-scatter | all-to-all | permute |")
    lines.append("|---|---|---|---|---|---|---|")
    for key in sorted(one):
        r = one[key]
        if r["status"] != "ok":
            continue
        cb = r["roofline"]["collective_breakdown"]
        g = lambda k: f"{cb.get(k,0)/2**20:.0f}M" if cb.get(k) else "-"
        lines.append(
            f"| {key[0]} | {key[1]} | {g('all-reduce')} | {g('all-gather')} | "
            f"{g('reduce-scatter')} | {g('all-to-all')} | {g('collective-permute')} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(render())
