"""Step builders: (arch, shape, mesh) -> jit-able fn + shardings + arg specs.

Everything here works on ``jax.ShapeDtypeStruct`` stand-ins — params, opt
state and batches are *never allocated*; ``jax.eval_shape`` over the init
functions produces the shape trees the dry-run lowers against.

One builder per family:

* LM train   — value_and_grad(loss) + optimizer update (AdamW-bf16 for the
  <10B archs, Adafactor for grok-1), FSDP×TP shardings.
* LM prefill — prompt pass returning (kv cache, last logits).
* LM decode  — one token against a full KV cache (seq sharded over model).
* GNN train  — full-batch or sampled-subgraph step, edges sharded over dp.
* recsys     — train / serve / bulk / retrieval.
* paper-gwq  — the sharded two-stage window query (the paper's data plane).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding_rules as SR
from repro.launch.mesh import dp_axes_of
from repro.models import gnn as G
from repro.models import moe as MoE
from repro.models import recsys as R
from repro.models import transformer as T
from repro.optim.optimizers import adafactor, adamw
from repro.optim.schedules import cosine_schedule


@dataclasses.dataclass
class BuiltStep:
    fn: Callable
    args: Tuple[Any, ...]  # ShapeDtypeStructs (pytrees)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    static_argnums: Tuple[int, ...] = ()

    def lower(self, mesh):
        jitted = jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )
        with mesh:
            return jitted.lower(*self.args)


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _dp_spec(dp_axes):
    return dp_axes if len(dp_axes) > 1 else dp_axes[0]


def _shapes_of(fn, *args):
    return jax.eval_shape(fn, *args)


# ---------------------------------------------------------------------- #
#  LM family
# ---------------------------------------------------------------------- #
def _lm_module(cfg):
    return MoE if isinstance(cfg, MoE.MoEConfig) else T


def _lm_optimizer(cfg):
    if cfg.n_params() > 20e9:  # grok-1: factored state is the memory floor
        return adafactor(cosine_schedule(1e-4, 200, 10_000))
    return adamw(cosine_schedule(3e-4, 200, 10_000))


def _lm_param_specs(cfg, dp_axes):
    if isinstance(cfg, MoE.MoEConfig):
        ep = cfg.pad_experts_to is not None
        return SR.moe_param_specs(cfg, dp_axes, expert_parallel=ep)
    return SR.lm_param_specs(cfg, dp_axes)


def build_lm_train(cfg, mesh, shape_dims) -> BuiltStep:
    dp_axes = dp_axes_of(mesh)
    mod = _lm_module(cfg)
    opt = _lm_optimizer(cfg)
    params_s = _shapes_of(lambda: mod.init(jax.random.PRNGKey(0), cfg))
    opt_s = _shapes_of(opt.init, params_s)
    b, s = shape_dims["batch"], shape_dims["seq"]
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }

    from repro.distributed.actshard import lm_train_acts

    acts = lm_train_acts(dp_axes, mesh)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: mod.loss_fn(p, batch, cfg, acts=acts)
        )(params)
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "gnorm": gnorm}

    pspec = _lm_param_specs(cfg, dp_axes)
    ospec = SR.opt_state_specs(pspec, opt_s)
    bspec = SR.lm_batch_specs(dp_axes)
    return BuiltStep(
        fn=train_step,
        args=(params_s, opt_s, batch),
        in_shardings=(_named(mesh, pspec), _named(mesh, ospec), _named(mesh, bspec)),
        out_shardings=(
            _named(mesh, pspec),
            _named(mesh, ospec),
            {"loss": NamedSharding(mesh, P()), "gnorm": NamedSharding(mesh, P())},
        ),
        donate_argnums=(0, 1),
    )


def build_lm_prefill(cfg, mesh, shape_dims) -> BuiltStep:
    dp_axes = dp_axes_of(mesh)
    mod = _lm_module(cfg)
    params_s = _shapes_of(lambda: mod.init(jax.random.PRNGKey(0), cfg))
    b, s = shape_dims["batch"], shape_dims["seq"]
    tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)

    from repro.distributed.actshard import lm_prefill_acts

    acts = lm_prefill_acts(dp_axes, mesh)

    def prefill_step(params, tokens):
        return mod.prefill(params, tokens, cfg, acts=acts)

    pspec = _lm_param_specs(cfg, dp_axes)
    d = _dp_spec(dp_axes)
    kv_spec = {"k": P(None, d, None, "model", None), "v": P(None, d, None, "model", None)}
    return BuiltStep(
        fn=prefill_step,
        args=(params_s, tokens),
        in_shardings=(_named(mesh, pspec), NamedSharding(mesh, P(d, None))),
        out_shardings=(
            _named(mesh, kv_spec),
            NamedSharding(mesh, P(d, "model")),
        ),
    )


def build_lm_decode(cfg, mesh, shape_dims) -> BuiltStep:
    dp_axes = dp_axes_of(mesh)
    mod = _lm_module(cfg)
    params_s = _shapes_of(lambda: mod.init(jax.random.PRNGKey(0), cfg))
    b, s = shape_dims["batch"], shape_dims["seq"]
    hd = cfg.head_dim
    kv = {
        "k": jax.ShapeDtypeStruct((cfg.n_layers, b, cfg.n_kv_heads, s, hd), cfg.cdtype),
        "v": jax.ShapeDtypeStruct((cfg.n_layers, b, cfg.n_kv_heads, s, hd), cfg.cdtype),
    }
    token = jax.ShapeDtypeStruct((b,), jnp.int32)

    from repro.distributed.actshard import lm_decode_acts

    acts = lm_decode_acts(dp_axes, mesh)

    def decode(params, token, kv):
        return mod.decode_step(params, token, kv, s - 1, cfg, acts=acts)

    pspec = _lm_param_specs(cfg, dp_axes)
    d = _dp_spec(dp_axes)
    ndp = int(np.prod([mesh.shape[a] for a in dp_axes]))
    if b >= ndp:
        tok_spec = P(d)
        kv_spec = {"k": P(None, d, None, "model", None),
                   "v": P(None, d, None, "model", None)}
        logit_spec = P(d, "model")
    else:
        # long-context single-sequence decode (long_500k): batch cannot
        # shard, so the KV sequence shards over the ENTIRE mesh
        flat = tuple(dp_axes) + ("model",)
        tok_spec = P()
        kv_spec = {"k": P(None, None, None, flat, None),
                   "v": P(None, None, None, flat, None)}
        logit_spec = P(None, "model")
    return BuiltStep(
        fn=decode,
        args=(params_s, token, kv),
        in_shardings=(
            _named(mesh, pspec),
            NamedSharding(mesh, tok_spec),
            _named(mesh, kv_spec),
        ),
        out_shardings=(
            NamedSharding(mesh, logit_spec),
            _named(mesh, kv_spec),
        ),
        donate_argnums=(2,),
    )


# ---------------------------------------------------------------------- #
#  GNN family
# ---------------------------------------------------------------------- #
def _gnn_init_and_fwd(cfg: G.GNNConfig):
    if cfg.kind == "gcn":
        return G.gcn_init, "gcn"
    if cfg.kind == "sage":
        return G.sage_init, "sage"
    if cfg.kind == "gat":
        return G.gat_init, "gat"
    if cfg.kind == "meshgraphnet":
        return lambda k, c: G.mgn_init(k, c), "mgn"
    raise ValueError(cfg.kind)


def gnn_loss(params, batch, cfg: G.GNNConfig, n: int, node_spec=None):
    es, ed = batch["edge_src"], batch["edge_dst"]
    feats = batch["feats"]
    if cfg.kind == "gcn":
        out = G.gcn_forward(params, feats, es, ed, batch["edge_w"], n, cfg,
                            node_spec=node_spec)
    elif cfg.kind == "sage":
        out = G.sage_forward(params, feats, es, ed, n, cfg, node_spec=node_spec)
    elif cfg.kind == "gat":
        out = G.gat_forward(params, feats, es, ed, n, cfg, node_spec=node_spec)
    else:
        out = G.mgn_forward(params, feats, batch["edge_feats"], es, ed, n, cfg,
                            node_spec=node_spec)
    if cfg.kind == "meshgraphnet":
        # regression on node targets
        return jnp.mean(jnp.square(out - batch["targets"]))
    labels = batch["labels"]
    mask = batch.get("label_mask", None)
    logits = out.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def build_gnn_train(cfg: G.GNNConfig, mesh, dims: Dict[str, int]) -> BuiltStep:
    dp_axes = dp_axes_of(mesh)
    # edges shard over the ENTIRE mesh (all axes): message passing is
    # edge-bound, so using only the dp axes left 16x parallelism (and 16x
    # per-device edge memory) on the table (§Perf iteration A2)
    d = tuple(dp_axes) + ("model",)
    init_fn, _ = _gnn_init_and_fwd(cfg)
    params_s = _shapes_of(lambda: init_fn(jax.random.PRNGKey(0), cfg))
    opt = adamw(cosine_schedule(1e-3, 100, 10_000))
    opt_s = _shapes_of(opt.init, params_s)

    n = dims.get("sub_n", dims["n"] * dims.get("batch", 1))
    e = dims.get("sub_e", dims["e"] * dims.get("batch", 1))
    # pad edge count to a lane multiple and the full mesh extent
    ndev = int(np.prod([mesh.shape[a] for a in d]))
    e_pad = -(-e // (128 * ndev)) * (128 * ndev)
    n_total = n
    batch = {
        "feats": jax.ShapeDtypeStruct((n_total, dims["d_feat"]), jnp.float32),
        "edge_src": jax.ShapeDtypeStruct((e_pad,), jnp.int32),
        "edge_dst": jax.ShapeDtypeStruct((e_pad,), jnp.int32),
    }
    bspec = {"feats": P(), "edge_src": P(d), "edge_dst": P(d)}
    if cfg.kind == "gcn":
        batch["edge_w"] = jax.ShapeDtypeStruct((e_pad,), jnp.float32)
        bspec["edge_w"] = P(d)
    if cfg.kind == "meshgraphnet":
        batch["edge_feats"] = jax.ShapeDtypeStruct((e_pad, 3), jnp.float32)
        batch["targets"] = jax.ShapeDtypeStruct((n_total, cfg.d_out), jnp.float32)
        bspec["edge_feats"] = P(d, None)
        bspec["targets"] = P()
    else:
        batch["labels"] = jax.ShapeDtypeStruct((n_total,), jnp.int32)
        batch["label_mask"] = jax.ShapeDtypeStruct((n_total,), jnp.float32)
        bspec["labels"] = P()
        bspec["label_mask"] = P()

    # node states shard over the full mesh too: replicated [N, d] carries
    # were the residual memory hog on ogb_products (§Perf iteration A3)
    node_spec = P(d, None)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: gnn_loss(p, batch, cfg, n_total, node_spec=node_spec)
        )(params)
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "gnorm": gnorm}

    pspec = jax.tree_util.tree_map(lambda _: P(), params_s)
    ospec = SR.opt_state_specs(pspec, opt_s)
    return BuiltStep(
        fn=train_step,
        args=(params_s, opt_s, batch),
        in_shardings=(_named(mesh, pspec), _named(mesh, ospec), _named(mesh, bspec)),
        out_shardings=(
            _named(mesh, pspec),
            _named(mesh, ospec),
            {"loss": NamedSharding(mesh, P()), "gnorm": NamedSharding(mesh, P())},
        ),
        donate_argnums=(0, 1),
    )


# ---------------------------------------------------------------------- #
#  recsys family
# ---------------------------------------------------------------------- #
def build_fm_step(cfg: R.FMConfig, mesh, case_kind: str, dims) -> BuiltStep:
    dp_axes = dp_axes_of(mesh)
    d = _dp_spec(dp_axes)
    params_s = _shapes_of(lambda: R.init(jax.random.PRNGKey(0), cfg))
    pspec = {"emb": P("model", None), "w1": P("model"), "bias": P()}

    if case_kind == "train":
        opt = adamw(cosine_schedule(1e-3, 100, 10_000))
        opt_s = _shapes_of(opt.init, params_s)
        batch = {
            "x": jax.ShapeDtypeStruct((dims["batch"], cfg.n_fields), jnp.int32),
            "y": jax.ShapeDtypeStruct((dims["batch"],), jnp.float32),
        }
        bspec = {"x": P(d, None), "y": P(d)}

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(lambda p: R.loss_fn(p, batch, cfg))(params)
            params, opt_state, gnorm = opt.update(grads, opt_state, params)
            return params, opt_state, {"loss": loss, "gnorm": gnorm}

        ospec = SR.opt_state_specs(pspec, opt_s)
        return BuiltStep(
            fn=train_step,
            args=(params_s, opt_s, batch),
            in_shardings=(_named(mesh, pspec), _named(mesh, ospec), _named(mesh, bspec)),
            out_shardings=(
                _named(mesh, pspec),
                _named(mesh, ospec),
                {"loss": NamedSharding(mesh, P()), "gnorm": NamedSharding(mesh, P())},
            ),
            donate_argnums=(0, 1),
        )
    if case_kind == "serve":
        x = jax.ShapeDtypeStruct((dims["batch"], cfg.n_fields), jnp.int32)

        def serve_step(params, x):
            return R.forward(params, x, cfg)

        return BuiltStep(
            fn=serve_step,
            args=(params_s, x),
            in_shardings=(_named(mesh, pspec), NamedSharding(mesh, P(d, None))),
            out_shardings=NamedSharding(mesh, P(d)),
        )
    if case_kind == "retrieval":
        x = jax.ShapeDtypeStruct((1, cfg.n_fields), jnp.int32)
        cand = jax.ShapeDtypeStruct((dims["n_candidates"],), jnp.int32)

        def retrieve(params, x, cand_rows):
            return R.retrieval_scores(params, x, cand_rows, cfg)

        return BuiltStep(
            fn=retrieve,
            args=(params_s, x, cand),
            in_shardings=(
                _named(mesh, pspec),
                NamedSharding(mesh, P(None, None)),
                NamedSharding(mesh, P(d)),
            ),
            out_shardings=NamedSharding(mesh, P(d)),
        )
    raise ValueError(case_kind)


# ---------------------------------------------------------------------- #
#  paper-gwq family: the sharded window-query data plane
# ---------------------------------------------------------------------- #
def build_gwq_step(plan_dims: Dict[str, int], mesh) -> BuiltStep:
    """Sharded two-stage DBIndex query at production scale.

    plan_dims: n (vertices), nb (blocks), m (member rows), l (link rows).
    Inputs are the tile-plan arrays as ShapeDtypeStructs; the step is the
    shard_map'd two-pass segment-sum with psum combine (engine_jax).
    """
    dp_axes = dp_axes_of(mesh)
    d = _dp_spec(dp_axes)
    n, nb = plan_dims["n"], plan_dims["nb"]
    m, l = plan_dims["m"], plan_dims["l"]
    ndev = int(np.prod([mesh.shape[a] for a in dp_axes]))
    m_pad = -(-m // (128 * ndev)) * (128 * ndev)
    l_pad = -(-l // (128 * ndev)) * (128 * ndev)

    args = (
        jax.ShapeDtypeStruct((m_pad,), jnp.int32),  # p1 gather (member ids)
        jax.ShapeDtypeStruct((m_pad,), jnp.int32),  # p1 seg (block ids)
        jax.ShapeDtypeStruct((l_pad,), jnp.int32),  # p2 gather (block ids)
        jax.ShapeDtypeStruct((l_pad,), jnp.int32),  # p2 seg (owner ids)
        jax.ShapeDtypeStruct((n,), jnp.float32),  # vertex attribute
    )

    bf = plan_dims.get("boundary_frac")

    def gwq_query(p1g, p1s, p2g, p2s, vals):
        ok1 = p1s >= 0
        t = jax.ops.segment_sum(
            jnp.where(ok1, jnp.take(vals, p1g), 0.0),
            jnp.where(ok1, p1s, nb),
            num_segments=nb + 1,
        )[:nb]
        ok2 = p2s >= 0
        out = jax.ops.segment_sum(
            jnp.where(ok2, jnp.take(t, p2g), 0.0),
            jnp.where(ok2, p2s, n),
            num_segments=n + 1,
        )[:n]
        return out

    def gwq_query_partitioned(p1g, p1s, p2g, p2s, vals):
        """Blocks/owners co-located with their rows (MinHash clusters are
        locality groups): pass-1/pass-2 segment sums run shard-locally
        under shard_map; only the 1/bf boundary slices are psum'd."""
        from jax.experimental.shard_map import shard_map

        nb_b = nb // bf
        n_b = n // bf
        nb_loc = nb - nb_b
        n_loc = n - n_b

        def local(p1g_l, p1s_l, p2g_l, p2s_l, vals_l):
            ok1 = p1s_l >= 0
            t_all = jax.ops.segment_sum(
                jnp.where(ok1, jnp.take(vals_l, p1g_l), 0.0),
                jnp.where(ok1, p1s_l, nb),
                num_segments=nb + 1,
            )[:nb]
            # interior blocks stay local; boundary slice is combined
            t_boundary = jax.lax.psum(t_all[nb_loc:], axes)
            t = jnp.concatenate([t_all[:nb_loc], t_boundary])
            ok2 = p2s_l >= 0
            out_all = jax.ops.segment_sum(
                jnp.where(ok2, jnp.take(t, p2g_l), 0.0),
                jnp.where(ok2, p2s_l, n),
                num_segments=n + 1,
            )[:n]
            out_boundary = jax.lax.psum(out_all[n_loc:], axes)
            return jnp.concatenate([out_all[:n_loc], out_boundary])

        fn = shard_map(
            local, mesh=mesh,
            in_specs=(P(axes), P(axes), P(axes), P(axes), P()),
            out_specs=P(), check_rep=False,
        )
        return fn(p1g, p1s, p2g, p2s, vals)

    axes = (d,) if isinstance(d, str) else tuple(d)
    row = NamedSharding(mesh, P(d))
    rep = NamedSharding(mesh, P())
    return BuiltStep(
        fn=gwq_query_partitioned if bf else gwq_query,
        args=args,
        in_shardings=(row, row, row, row, rep),
        out_shardings=rep,
    )
