"""Roofline analysis from compiled dry-run artifacts (no hardware).

Three terms per (arch x shape x mesh), all in seconds (TPU v5e targets):

  compute   = HLO_FLOPs               / (chips * 197e12 FLOP/s bf16)
  memory    = HLO_bytes_accessed      / (chips * 819e9  B/s HBM)
  collective= collective_bytes        / (chips * 50e9   B/s per ICI link)

``cost_analysis()`` supplies flops / bytes accessed.  Collective bytes are
NOT in cost_analysis: we parse the post-optimization HLO text and sum the
shape bytes of every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute operand.  MODEL_FLOPS (6*N*D dense, 6*N_active*D MoE) is
attached per LM arch so the "useful compute" ratio is visible.
"""

from __future__ import annotations

import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9_\[\]{}, ]+?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind from HLO text."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        if b:
            out[kind] = out.get(kind, 0) + b
    return out


def model_flops_for(arch_name: str, shape_name: str, dims: Dict) -> Optional[float]:
    """6*N*D (dense) / 6*N_active*D (MoE) for LM train; 2*N*D for inference."""
    try:
        from repro.configs.registry import get_arch

        arch = get_arch(arch_name)
        if arch.family == "lm-dense":
            n = arch.model_cfg.n_params()
        elif arch.family == "lm-moe":
            n = arch.model_cfg.n_active_params()
        else:
            return None
        tokens = dims.get("batch", 1) * dims.get("seq", 1)
        case = arch.shapes[shape_name]
        if case.kind == "train":
            return 6.0 * n * tokens
        if case.kind == "prefill":
            return 2.0 * n * tokens
        if case.kind == "decode":
            return 2.0 * n * dims.get("batch", 1)
    except Exception:  # noqa: BLE001
        return None
    return None


def analyze_compiled(compiled, mesh, arch_name: str, shape_name: str) -> Dict:
    chips = mesh.devices.size
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0) or 0.0)
    # bytes accessed: sum every "bytes accessed*" key (operands + outputs)
    bytes_accessed = 0.0
    for k, v in cost.items():
        if k.startswith("bytes accessed"):
            bytes_accessed = max(bytes_accessed, float(v))
    try:
        hlo = compiled.as_text()
    except Exception:  # noqa: BLE001
        hlo = ""
    coll = collective_bytes_from_hlo(hlo)
    coll_total = float(sum(coll.values()))

    # cost_analysis flops on the host backend are per-program (already
    # partitioned).  Treat them as per-device numbers.
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_coll = (coll_total / chips) / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    from repro.configs.registry import get_arch

    dims = get_arch(arch_name).shapes[shape_name].dims
    mf = model_flops_for(arch_name, shape_name, dims)
    useful = (mf / chips) / flops if (mf and flops) else None
    return {
        "chips": int(chips),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_bytes_total": coll_total,
        "collective_breakdown": coll,
        "terms": {
            "compute_s": t_compute,
            "memory_s": t_memory,
            "collective_s": t_coll,
        },
        "dominant": dominant,
        "model_flops": mf,
        "useful_compute_ratio": useful,
    }
