"""End-to-end training driver (CPU-scale by default).

``python -m repro.launch.train --arch qwen3-0.6b --steps 200 --smoke``
trains the reduced config of the chosen arch for a few hundred steps with
checkpointing + fault-tolerance monitoring — deliverable (b)'s end-to-end
example rides this module (examples/train_lm.py).
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.data.pipeline import RecsysStream, TokenStream
from repro.models import moe as MoE
from repro.models import recsys as R
from repro.models import transformer as T
from repro.optim.optimizers import adamw
from repro.optim.schedules import cosine_schedule
from repro.train.fault_tolerance import FaultToleranceMonitor
from repro.train.trainer import TrainConfig, Trainer


def build_trainer(arch_name: str, *, smoke: bool = True, batch: int = 8,
                  seq: int = 64, steps: int = 100, ckpt_dir=None,
                  microbatch: int = 1, grad_compression: bool = False) -> Trainer:
    arch = get_arch(arch_name)
    cfg = arch.smoke_cfg if smoke else arch.model_cfg
    if arch.family in ("lm-dense", "lm-moe"):
        mod = MoE if isinstance(cfg, MoE.MoEConfig) else T
        params = mod.init(jax.random.PRNGKey(0), cfg)
        data = TokenStream(vocab=cfg.vocab, batch=batch, seq=seq)
        loss = lambda p, b: mod.loss_fn(p, b, cfg)
    elif arch.family == "recsys":
        params = R.init(jax.random.PRNGKey(0), cfg)
        data = RecsysStream(n_fields=cfg.n_fields, batch=batch)
        loss = lambda p, b: R.loss_fn(p, b, cfg)
    else:
        raise ValueError(f"use examples/gnn_train.py for GNN archs ({arch_name})")
    opt = adamw(cosine_schedule(3e-4, 20, max(steps, 21)))
    tc = TrainConfig(
        total_steps=steps,
        microbatch=microbatch,
        checkpoint_every=max(steps // 4, 1),
        checkpoint_dir=ckpt_dir,
        grad_compression=grad_compression,
    )
    return Trainer(loss, opt, params, data, tc, FaultToleranceMonitor())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args(argv)
    tr = build_trainer(
        args.arch, smoke=True, batch=args.batch, seq=args.seq,
        steps=args.steps, ckpt_dir=args.ckpt_dir, microbatch=args.microbatch,
        grad_compression=args.grad_compression,
    )
    out = tr.run()
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    print(json.dumps({"steps": out["step"], "loss_first": first, "loss_last": last}))
    assert np.isfinite(last)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
