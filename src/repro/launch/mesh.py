"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (jax locks the device count on first backend init; dryrun.py must set
XLA_FLAGS before any jax call).
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; 2 pods for the multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> Tuple[str, ...]:
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for unit tests (requires host-platform device override)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
