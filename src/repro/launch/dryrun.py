import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the entry point (``python -m repro.launch.dryrun``) — the XLA_FLAGS
override above runs before any other import so the 512 placeholder devices
exist when jax initializes.

For every cell:
  * build the step (ShapeDtypeStruct args — zero allocation),
  * ``.lower()`` then ``.compile()`` under the production mesh,
  * print ``memory_analysis()`` (fits-per-device proof) and
    ``cost_analysis()`` (FLOPs/bytes for the roofline),
  * parse the post-optimization HLO for collective bytes,
  * append a JSON record to ``reports/dryrun_<mesh>.jsonl``.

Usage:
  python -m repro.launch.dryrun --arch minitron-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--cells a:s,b:t]
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.configs.registry import ARCHS, get_arch  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import analyze_compiled  # noqa: E402


def build_step_for(arch_name: str, shape_name: str, mesh):
    from repro.launch import steps

    arch = get_arch(arch_name)
    case = arch.shapes[shape_name]
    if arch.family in ("lm-dense", "lm-moe"):
        cfg = arch.model_cfg
        if case.kind == "train":
            return steps.build_lm_train(cfg, mesh, case.dims)
        if case.kind == "prefill":
            return steps.build_lm_prefill(cfg, mesh, case.dims)
        if case.kind == "decode":
            return steps.build_lm_decode(cfg, mesh, case.dims)
    if arch.family == "gnn":
        import importlib

        mod = importlib.import_module(
            {
                "graphsage-reddit": "repro.configs.graphsage_reddit",
                "meshgraphnet": "repro.configs.meshgraphnet",
                "gcn-cora": "repro.configs.gcn_cora",
                "gat-cora": "repro.configs.gat_cora",
            }[arch_name]
        )
        cfg = mod.cfg_for(case.dims)
        return steps.build_gnn_train(cfg, mesh, case.dims)
    if arch.family == "recsys":
        return steps.build_fm_step(arch.model_cfg, mesh, case.kind, case.dims)
    if arch.family == "paper":
        return steps.build_gwq_step(case.dims, mesh)
    raise ValueError((arch_name, shape_name))


def run_cell(arch_name: str, shape_name: str, mesh, mesh_tag: str,
             report_dir: Path, verbose: bool = True):
    arch = get_arch(arch_name)
    rec = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": mesh_tag,
        "status": "",
    }
    if shape_name in arch.skip:
        rec["status"] = "skipped"
        rec["reason"] = arch.skip[shape_name]
        if verbose:
            print(f"[SKIP] {arch_name} x {shape_name}: {rec['reason']}")
        return rec
    t0 = time.perf_counter()
    try:
        built = build_step_for(arch_name, shape_name, mesh)
        with mesh:
            lowered = built.lower(mesh)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        roof = analyze_compiled(compiled, mesh, arch_name, shape_name)
        rec.update(
            status="ok",
            t_lower_s=round(t_lower, 1),
            t_compile_s=round(t_compile, 1),
            bytes_per_device=getattr(mem, "temp_size_in_bytes", None),
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            flops=cost.get("flops") if isinstance(cost, dict) else None,
            roofline=roof,
        )
        if verbose:
            print(
                f"[OK]   {arch_name} x {shape_name} ({mesh_tag}) "
                f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
                f"args/dev {rec['argument_bytes'] and rec['argument_bytes']/2**30:.2f} GiB "
                f"temp/dev {rec['bytes_per_device'] and rec['bytes_per_device']/2**30:.2f} GiB | "
                f"flops {rec['flops'] and rec['flops']:.3g}"
            )
            print("       roofline:", json.dumps(roof.get("terms", {})))
    except Exception as e:  # noqa: BLE001
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"[:500]
        if verbose:
            print(f"[FAIL] {arch_name} x {shape_name}: {rec['error']}")
            traceback.print_exc(limit=4)
    report_dir.mkdir(parents=True, exist_ok=True)
    with open(report_dir / f"dryrun_{mesh_tag}.jsonl", "a") as f:
        f.write(json.dumps(rec) + "\n")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--cells", default=None, help="comma list arch:shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--report-dir", default="reports")
    args = ap.parse_args(argv)

    meshes = []
    if args.both_meshes:
        meshes = [(make_production_mesh(), "1pod"),
                  (make_production_mesh(multi_pod=True), "2pod")]
    else:
        mp = args.multi_pod
        meshes = [(make_production_mesh(multi_pod=mp), "2pod" if mp else "1pod")]

    cells = []
    if args.cells:
        for c in args.cells.split(","):
            a, s = c.split(":")
            cells.append((a, s))
    elif args.all:
        for a in ARCHS():
            arch = get_arch(a)
            for s in arch.shapes:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    report_dir = Path(args.report_dir)
    n_ok = n_fail = n_skip = 0
    for mesh, tag in meshes:
        for a, s in cells:
            rec = run_cell(a, s, mesh, tag, report_dir)
            n_ok += rec["status"] == "ok"
            n_fail += rec["status"] == "fail"
            n_skip += rec["status"] == "skipped"
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
