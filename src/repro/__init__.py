"""repro: Graph Window Analytics over Large-scale Dynamic Graphs, on JAX/TPU.

Implements Fan, Wang, Chan, Tan (2015): Graph Window Queries (k-hop and
topological windows), the Dense Block Index (DBIndex, MC/EMC construction),
the Inheritance Index (I-Index), the EAGR baseline, and a production
training/serving substrate that runs the assigned architecture pool on
single-pod (16x16) and multi-pod (2x16x16) TPU meshes.

Public API is re-exported lazily to keep `import repro` cheap (no jax device
initialization at import time).
"""

__version__ = "1.0.0"

_LAZY = {
    "Graph": "repro.core.graph",
    "DeviceGraph": "repro.core.graph",
    "KHopWindow": "repro.core.windows",
    "TopologicalWindow": "repro.core.windows",
    "KHop": "repro.core.windows",
    "Topo": "repro.core.windows",
    "Union": "repro.core.windows",
    "Intersect": "repro.core.windows",
    "Diff": "repro.core.windows",
    "Filter": "repro.core.windows",
    "WindowExpr": "repro.core.windows",
    "canonicalize": "repro.core.windows",
    "GraphWindowQuery": "repro.core.query",
    "DBIndex": "repro.core.dbindex",
    "build_dbindex": "repro.core.dbindex",
    "IIndex": "repro.core.iindex",
    "build_iindex": "repro.core.iindex",
    "AGGREGATES": "repro.core.aggregates",
    "register_aggregate": "repro.core.aggregates",
    "QuerySpec": "repro.core.api",
    "Session": "repro.core.api",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib

        mod = importlib.import_module(_LAZY[name])
        return getattr(mod, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
