"""Graph data substrate: generators, neighbor sampling, device partitioning."""

from repro.graphs.generators import (  # noqa: F401
    erdos_renyi,
    barabasi_albert,
    random_dag,
    grid_mesh,
    batched_molecules,
    with_random_attrs,
)
