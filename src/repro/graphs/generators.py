"""Synthetic graph generators.

Mirrors the paper's experimental setup: SNAP-style Erdős–Rényi graphs of a
given average degree (§6.2.2 "Degree means average degree... Erdos-Renyi
model"), power-law (Barabási–Albert) social-network-shaped graphs, and
DAGGER-style random DAGs (§6.3).  Plus the shapes the assigned architecture
pool needs: 2-D triangulated meshes (MeshGraphNet), batched small molecule
graphs, and Cora/Reddit/OGB-shaped stand-ins.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.graph import Graph


def _dedupe(src: np.ndarray, dst: np.ndarray, n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Drop self-loops and duplicate edges."""
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src.astype(np.int64) * n + dst
    _, idx = np.unique(key, return_index=True)
    return src[np.sort(idx)], dst[np.sort(idx)]


def erdos_renyi(n: int, avg_degree: float, directed: bool = False, seed: int = 0) -> Graph:
    """G(n, m) with m = n*avg_degree/(2 if undirected else 1) edges."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / (1 if directed else 2))
    src = rng.integers(0, n, size=int(m * 1.15), dtype=np.int64).astype(np.int32)
    dst = rng.integers(0, n, size=int(m * 1.15), dtype=np.int64).astype(np.int32)
    src, dst = _dedupe(src, dst, n)
    src, dst = src[:m], dst[:m]
    return Graph(n=n, src=src, dst=dst, directed=directed)


def barabasi_albert(n: int, m_attach: int = 4, seed: int = 0) -> Graph:
    """Preferential attachment (power-law degrees) — social-network shaped."""
    rng = np.random.default_rng(seed)
    src_l, dst_l = [], []
    targets = list(range(m_attach))
    repeated: list = list(range(m_attach))
    for v in range(m_attach, n):
        chosen = rng.choice(len(repeated), size=m_attach, replace=False)
        chosen_t = {repeated[c] for c in chosen}
        for t in chosen_t:
            src_l.append(v)
            dst_l.append(t)
            repeated.append(t)
            repeated.append(v)
    src = np.array(src_l, dtype=np.int32)
    dst = np.array(dst_l, dtype=np.int32)
    return Graph(n=n, src=src, dst=dst, directed=False)


def random_dag(n: int, avg_degree: float, seed: int = 0, locality: int = 0) -> Graph:
    """DAGGER-style random DAG: edges go from lower to higher topological
    rank.  `locality` > 0 limits edge span (pathway-graph shaped)."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree)
    lo = rng.integers(0, n - 1, size=int(m * 1.2), dtype=np.int64)
    if locality > 0:
        span = rng.integers(1, locality + 1, size=lo.size)
        hi = np.minimum(lo + span, n - 1)
    else:
        hi = rng.integers(1, n, size=lo.size, dtype=np.int64)
        lo, hi = np.minimum(lo, hi), np.maximum(lo, hi)
    keep = lo != hi
    lo, hi = lo[keep], hi[keep]
    src, dst = _dedupe(lo.astype(np.int32), hi.astype(np.int32), n)
    src, dst = src[:m], dst[:m]
    # random relabel so vertex id != topological rank
    perm = rng.permutation(n).astype(np.int32)
    return Graph(n=n, src=perm[src], dst=perm[dst], directed=True)


def grid_mesh(rows: int, cols: int) -> Graph:
    """Triangulated 2-D grid (MeshGraphNet-shaped)."""
    n = rows * cols
    idx = np.arange(n).reshape(rows, cols)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 0)
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 0)
    diag = np.stack([idx[:-1, :-1].ravel(), idx[1:, 1:].ravel()], 0)
    e = np.concatenate([right, down, diag], axis=1).astype(np.int32)
    return Graph(n=n, src=e[0], dst=e[1], directed=False)


def batched_molecules(
    batch: int, nodes_per: int = 30, edges_per: int = 64, seed: int = 0
) -> Tuple[Graph, np.ndarray]:
    """`batch` disjoint small random graphs; returns (graph, graph_id[n])."""
    rng = np.random.default_rng(seed)
    srcs, dsts = [], []
    for b in range(batch):
        s = rng.integers(0, nodes_per, size=edges_per * 2, dtype=np.int64)
        d = rng.integers(0, nodes_per, size=edges_per * 2, dtype=np.int64)
        s, d = _dedupe(s.astype(np.int32), d.astype(np.int32), nodes_per)
        s, d = s[:edges_per], d[:edges_per]
        srcs.append(s + b * nodes_per)
        dsts.append(d + b * nodes_per)
    g = Graph(
        n=batch * nodes_per,
        src=np.concatenate(srcs),
        dst=np.concatenate(dsts),
        directed=False,
    )
    graph_id = np.repeat(np.arange(batch, dtype=np.int32), nodes_per)
    return g, graph_id


def with_random_attrs(g: Graph, seed: int = 0, names=("val",)) -> Graph:
    rng = np.random.default_rng(seed)
    for name in names:
        g = g.with_attr(name, rng.integers(0, 100, size=g.n).astype(np.float64))
    return g
