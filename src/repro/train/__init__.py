"""Training substrate: trainer loop, checkpointing, fault tolerance."""

from repro.train.checkpoints import CheckpointManager  # noqa: F401
from repro.train.trainer import Trainer, TrainConfig  # noqa: F401
