"""Fault tolerance: preemption handling, auto-restart, straggler policy.

CPU-container simulation of the pod-scale failure model:

* **Preemption/crash** — the trainer installs a step-boundary "fuse" that a
  test (or SIGTERM) can trip; the run exits after the in-flight step, and
  ``resume()`` restores params/opt/data-cursor/rng from the latest atomic
  checkpoint and replays to an *identical* loss trajectory (tested).
* **Straggler mitigation** — per-step wall-clock watchdog: a step exceeding
  ``straggler_factor`` x the trailing-median triggers a recorded event; at
  pod scale the action is re-slicing the collective group (here: logged +
  counted so tests can assert the policy fires).  Hardware re-slicing is a
  runtime concern; the *policy layer* is what's portable.
* **Elastic resize** — restoring under a different mesh reshards every leaf
  via device_put (see CheckpointManager.restore).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from collections import deque
from typing import Callable, Deque, List, Optional


@dataclasses.dataclass
class FTEvents:
    preemptions: int = 0
    restarts: int = 0
    stragglers: List[dict] = dataclasses.field(default_factory=list)


class FaultToleranceMonitor:
    def __init__(self, straggler_factor: float = 3.0, window: int = 32,
                 install_signal_handler: bool = False):
        self.straggler_factor = straggler_factor
        self._times: Deque[float] = deque(maxlen=window)
        self.events = FTEvents()
        self._preempt_requested = False
        if install_signal_handler:
            signal.signal(signal.SIGTERM, self._on_sigterm)

    # ------------------------- preemption ----------------------------- #
    def _on_sigterm(self, *_):
        self.request_preemption()

    def request_preemption(self):
        """Called by the infra (or a test) — finish the current step, then
        checkpoint and exit cleanly."""
        self._preempt_requested = True
        self.events.preemptions += 1

    @property
    def should_stop(self) -> bool:
        return self._preempt_requested

    def note_restart(self):
        self.events.restarts += 1
        self._preempt_requested = False

    # ------------------------- stragglers ----------------------------- #
    def observe_step(self, step: int, seconds: float):
        if len(self._times) >= 8:
            med = sorted(self._times)[len(self._times) // 2]
            if seconds > self.straggler_factor * med:
                self.events.stragglers.append(
                    {"step": step, "seconds": seconds, "median": med}
                )
        self._times.append(seconds)

    def straggler_count(self) -> int:
        return len(self.events.stragglers)
