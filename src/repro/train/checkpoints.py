"""Checkpointing: atomic, step-indexed, reshard-on-restore.

Layout:  <dir>/step_<N>/  with one ``.npy`` per flattened pytree leaf plus
``manifest.json`` (tree structure, shapes, dtypes, data-cursor, rng state).
Writes go to ``step_<N>.tmp`` and are renamed only after fsync — a killed
writer never corrupts the latest checkpoint (restart always finds either
the previous or the completed new one; fault-tolerance contract).

Restore is *reshard-aware*: leaves are loaded host-side and re-placed with
``jax.device_put`` under the (possibly different) target mesh/sharding, so
an elastic resize (e.g. 2-pod -> 1-pod) is just "restore under new mesh".
"""

from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------------ #
    def save(self, step: int, state: Dict[str, Any], extra: Optional[Dict] = None):
        """state: pytree of arrays. extra: JSON-serializable metadata
        (data cursors, rng, mesh shape) stored in the manifest."""
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves, _ = _flatten_with_paths(state)
        manifest = {"step": step, "leaves": [], "extra": extra or {}}
        for i, (key, leaf) in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            logical_dtype = str(arr.dtype)
            if logical_dtype == "bfloat16":  # npy has no bf16: store raw bits
                arr = arr.view(np.uint16)
            fname = f"leaf_{i}.npy"
            np.save(tmp / fname, arr)
            manifest["leaves"].append(
                {"key": key, "file": fname, "shape": list(arr.shape),
                 "dtype": logical_dtype}
            )
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    def steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------ #
    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Any:
        """template: pytree with the same structure (shapes may be used for
        validation).  shardings: optional matching pytree of NamedSharding
        for reshard-on-restore (elastic resize)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoints found")
        d = self.dir / f"step_{step}"
        with open(d / "manifest.json") as f:
            manifest = json.load(f)
        leaves, treedef = _flatten_with_paths(template)
        assert len(leaves) == len(manifest["leaves"]), (
            f"leaf count mismatch: template {len(leaves)} vs "
            f"checkpoint {len(manifest['leaves'])}"
        )
        shard_leaves = None
        if shardings is not None:
            shard_leaves = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec")
            )
        out = []
        for i, ((key, tmpl), rec) in enumerate(zip(leaves, manifest["leaves"])):
            arr = np.load(d / rec["file"])
            if rec["dtype"] == "bfloat16":
                import ml_dtypes

                arr = arr.view(ml_dtypes.bfloat16)
            if shard_leaves is not None:
                arr = jax.device_put(arr, shard_leaves[i])
            else:
                arr = jax.numpy.asarray(arr)
            out.append(arr)
        restored = jax.tree_util.tree_unflatten(treedef, out)
        return restored, manifest["extra"], step
