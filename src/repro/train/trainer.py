"""Trainer: jit'd step + microbatch accumulation + checkpoints + FT hooks.

Single-process version of the pod driver: the same step functions the
dry-run lowers at 256/512 chips run here on whatever mesh the host has.
Features that matter at scale and are exercised by tests:

* gradient accumulation (microbatching) with identical semantics to one
  large batch,
* deterministic resume (params + opt + data cursor + rng) to an identical
  loss trajectory after a simulated preemption,
* optional int8 gradient compression with error feedback on the DP
  reduction,
* straggler watchdog events.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.grad_compress import init_error_feedback, int8_compress_hook
from repro.optim.optimizers import Optimizer
from repro.train.checkpoints import CheckpointManager
from repro.train.fault_tolerance import FaultToleranceMonitor


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    microbatch: int = 1  # gradient-accumulation chunks per step
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    grad_compression: bool = False
    log_every: int = 10


class Trainer:
    def __init__(
        self,
        loss_fn: Callable,  # (params, batch) -> scalar
        optimizer: Optimizer,
        params,
        data,  # stream with .next()/.state()/.restore()
        cfg: TrainConfig,
        monitor: Optional[FaultToleranceMonitor] = None,
    ):
        self.loss_fn = loss_fn
        self.opt = optimizer
        self.params = params
        self.opt_state = optimizer.init(params)
        self.data = data
        self.cfg = cfg
        self.monitor = monitor or FaultToleranceMonitor()
        self.step = 0
        self.history: list = []
        self.err_fb = init_error_feedback(params) if cfg.grad_compression else None
        self.ckpt = (
            CheckpointManager(cfg.checkpoint_dir) if cfg.checkpoint_dir else None
        )
        self._jit_step = jax.jit(self._step_impl)

    # ------------------------------------------------------------------ #
    def _step_impl(self, params, opt_state, err_fb, batches):
        """batches: pytree with leading [microbatch, ...] axis."""

        def micro(carry, mb):
            acc = carry
            loss, grads = jax.value_and_grad(self.loss_fn)(params, mb)
            return (
                (acc[0] + loss, jax.tree_util.tree_map(jnp.add, acc[1], grads)),
                None,
            )

        zero = (
            jnp.zeros(()),
            jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        )
        (loss_sum, grad_sum), _ = jax.lax.scan(micro, zero, batches)
        nmb = jax.tree_util.tree_leaves(batches)[0].shape[0]
        grads = jax.tree_util.tree_map(lambda g: g / nmb, grad_sum)
        if err_fb is not None:
            grads, err_fb = int8_compress_hook(grads, err_fb)
        params, opt_state, gnorm = self.opt.update(grads, opt_state, params)
        return params, opt_state, err_fb, loss_sum / nmb, gnorm

    def _stack_microbatches(self):
        mbs = [self.data.next() for _ in range(self.cfg.microbatch)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *mbs)

    # ------------------------------------------------------------------ #
    def run(self, steps: Optional[int] = None) -> Dict[str, Any]:
        steps = steps if steps is not None else self.cfg.total_steps
        target = self.step + steps
        while self.step < target:
            if self.monitor.should_stop:  # preempted before starting a step
                break
            t0 = time.perf_counter()
            batches = self._stack_microbatches()
            (self.params, self.opt_state, self.err_fb, loss, gnorm) = self._jit_step(
                self.params, self.opt_state, self.err_fb, batches
            )
            self.step += 1
            dt = time.perf_counter() - t0
            self.monitor.observe_step(self.step, dt)
            self.history.append({"step": self.step, "loss": float(loss),
                                 "gnorm": float(gnorm), "dt": dt})
            if self.ckpt and self.step % self.cfg.checkpoint_every == 0:
                self.save()
            if self.monitor.should_stop:
                if self.ckpt:
                    self.save()
                break
        return {"step": self.step, "history": self.history}

    # ------------------------------------------------------------------ #
    def save(self):
        state = {"params": self.params, "opt": self.opt_state}
        if self.err_fb is not None:
            state["err_fb"] = self.err_fb
        extra = {"data": self.data.state(), "step": self.step}
        self.ckpt.save(self.step, state, extra)

    def resume(self, shardings=None):
        template = {"params": self.params, "opt": self.opt_state}
        if self.err_fb is not None:
            template["err_fb"] = self.err_fb
        state, extra, step = self.ckpt.restore(template, shardings=shardings)
        self.params = state["params"]
        self.opt_state = state["opt"]
        if self.err_fb is not None:
            self.err_fb = state["err_fb"]
        self.data.restore(extra["data"])
        self.step = int(extra["step"])
        self.monitor.note_restart()
        return step
