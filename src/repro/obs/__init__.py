"""Observability for the serving stack: metrics, tracing, SLO accounting.

Off by default.  The module-level registry/tracer are the null
implementations until :func:`enable` swaps in live ones, so the serving
hot path pays one no-op method call per event site and tier-1 perf is
untouched.  Instrumented classes capture the globals at construction
(``obs=None`` / ``tracer=None`` params fall back to them); call
:func:`enable` *before* building sessions/services you want observed.

Typical use::

    from repro import obs
    reg, tracer = obs.enable()
    ...  # build Session / WindowService / WAL — they pick up the globals
    print(reg.prometheus())
    tracer.dump("trace.json")          # load in chrome://tracing / Perfetto
    obs.disable()

Setting ``REPRO_OBS=1`` in the environment enables live instrumentation
at import time — handy for running existing test suites instrumented.

Metric-name schema (keep future PRs consistent):

* prefix ``repro_``; counters end ``_total``; durations are histograms
  ending ``_seconds``; sizes end ``_bytes`` / ``_records``; gauges are
  bare nouns (``repro_service_pressure``).
* label keys in use: ``cls`` (request class), ``outcome`` (ok|error|shed),
  ``reason`` (fill|deadline|manual), ``action`` (maintenance decision),
  ``kind`` (index kind), ``event`` (cache hit|miss|invalidate|evict).
* one family per concept — prefer a label over a name suffix
  (``repro_flushes_total{reason=...}``, not three counters).
"""

from __future__ import annotations

import os
from typing import Optional, Tuple

from .metrics import (  # noqa: F401
    DEFAULT_LATENCY_BUCKETS_S,
    DEFAULT_SIZE_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullRegistry,
)
from .slo import SLOTracker  # noqa: F401
from .tracing import NullTracer, Span, Tracer  # noqa: F401

__all__ = [
    "MetricsRegistry", "NullRegistry", "Counter", "Gauge", "Histogram",
    "Tracer", "NullTracer", "Span", "SLOTracker",
    "DEFAULT_LATENCY_BUCKETS_S", "DEFAULT_SIZE_BUCKETS",
    "get_registry", "get_tracer", "enable", "disable",
    "explain_session", "analyze_session", "PlanReport", "AnalyzeReport",
]


def __getattr__(name):
    # lazy: explain/profile pull in jax via the plan classes they inspect;
    # keep plain `import repro.obs` cheap and dependency-free
    if name in ("explain_session", "PlanReport"):
        from . import explain as _explain
        return getattr(_explain, name)
    if name in ("analyze_session", "AnalyzeReport"):
        from . import profile as _profile
        return getattr(_profile, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

_NULL_REGISTRY = NullRegistry()
_NULL_TRACER = NullTracer()

_registry = _NULL_REGISTRY
_tracer = _NULL_TRACER


def get_registry():
    """The process-wide default registry (Null until :func:`enable`)."""
    return _registry


def get_tracer():
    """The process-wide default tracer (Null until :func:`enable`)."""
    return _tracer


def enable(registry: Optional[MetricsRegistry] = None,
           tracer: Optional[Tracer] = None) -> Tuple[MetricsRegistry, Tracer]:
    """Install live defaults (fresh ones unless passed in) and return them.

    Only affects objects constructed afterwards — instrumented classes
    capture the registry/tracer once, at ``__init__``.
    """
    global _registry, _tracer
    _registry = registry if registry is not None else MetricsRegistry()
    _tracer = tracer if tracer is not None else Tracer()
    _install_collectors(_registry, _tracer)
    return _registry, _tracer


def _install_collectors(reg, tracer) -> None:
    """Collect-on-scrape gauges: values that live outside the registry are
    pulled fresh at every ``snapshot()``/``prometheus()`` instead of
    relying on the last manual fold."""
    if not getattr(reg, "enabled", False):
        return

    def _collect_recompiles(r):
        # lazy import: core.api imports repro.obs at module top, so a
        # top-level import here would be circular.  recompile_count() is
        # itself lazy (sys.modules probe) and never initialises jax.
        from repro.core import api as _api
        r.gauge(
            "repro_recompiles",
            help="total jit cache entries across tracked executors",
        ).set(_api.recompile_count())

    # the drop-delta high-water marks live on the *registry*, keyed per
    # tracer: re-running enable() with the same registry + tracer must not
    # reset the seen-state (a fresh closure restarting at 0 would fold the
    # whole historical drop count in again — double counting).  collect()
    # itself replaces by name, so the collector never stacks either.
    seen_map = reg.__dict__.setdefault("_trace_drop_seen", {})
    seen_map.setdefault(id(tracer), 0)

    def _collect_trace_drops(r):
        r.counter(
            "repro_trace_spans_dropped_total",
            help="trace events evicted from the ring buffer on overflow",
        )
        # counters are monotonic: fold in only the delta since last scrape
        now = int(getattr(tracer, "dropped_hint", 0))
        if now > seen_map[id(tracer)]:
            r.counter("repro_trace_spans_dropped_total").inc(
                now - seen_map[id(tracer)])
            seen_map[id(tracer)] = now

    reg.collect(_collect_recompiles, name="recompiles")
    reg.collect(_collect_trace_drops, name="trace_drops")


def disable() -> None:
    """Restore the no-op defaults (existing live handles keep recording)."""
    global _registry, _tracer
    _registry = _NULL_REGISTRY
    _tracer = _NULL_TRACER


# REPRO_OBS=1 enables live instrumentation at import time — the switch for
# running whole existing suites instrumented (bit-identity under obs):
#   REPRO_OBS=1 PYTHONPATH=src python -m pytest -q -m "not sharded"
# Tests that assert on a *fresh* registry (tests/test_obs.py) manage their
# own enable/disable and are unaffected by the startup default.
if os.environ.get("REPRO_OBS", "") not in ("", "0"):
    enable()
