"""Span-based request tracing with a Chrome ``trace_event`` exporter.

A :class:`Tracer` records the lifecycle of work as **spans** — named,
timed intervals with parent/child links.  Two shapes:

* ``with tracer.span("flush", pending=12):`` — synchronous spans nest via
  a per-thread stack, so the parent link is implicit and a flush that
  launches three groups which each run two term queries shows up as a
  three-level tree.
* ``sp = tracer.start_span("request", detached=True)`` … ``sp.finish()``
  — detached spans for work that crosses threads (a ticket is submitted
  on a client thread and completed by the flusher); they never touch the
  stack, and the caller may pass ``parent=`` explicitly.

Completed spans land in a **ring buffer** (``collections.deque(maxlen)``,
append is thread-safe under the GIL), so a long-running service keeps the
most recent window of activity at O(1) cost and bounded memory.

Export is Chrome ``trace_event`` JSON (the ``chrome://tracing`` /
Perfetto format): each span is one complete ``"ph": "X"`` event with
``ts``/``dur`` in microseconds, and ``args`` carrying ``span_id`` /
``parent_id`` plus any user args, so tooling that doesn't infer nesting
from timestamps can still reconstruct the tree.  :meth:`Tracer.dump`
writes a loadable file; :meth:`Tracer.max_depth` reports the deepest
parent chain (the demo asserts >= 4 levels across
request → flush → launch → maintenance).

:class:`NullTracer` is the compile-out twin: ``span`` returns one shared
re-entrant no-op context manager, so un-enabled tracing costs one method
call per span site.
"""

from __future__ import annotations

import itertools
import json
import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional

__all__ = ["Span", "Tracer", "NullTracer"]


class Span:
    """One open interval.  ``set(**args)`` attaches data mid-flight;
    ``finish()`` records it (idempotent).  Prefer ``tracer.span(...)`` —
    the context-manager form — unless the span crosses threads."""

    __slots__ = ("tracer", "id", "parent_id", "name", "cat", "t0", "args",
                 "tid", "_on_stack", "_done")

    def __init__(self, tracer: "Tracer", span_id: int,
                 parent_id: Optional[int], name: str, cat: str,
                 args: Dict, on_stack: bool):
        self.tracer = tracer
        self.id = span_id
        self.parent_id = parent_id
        self.name = name
        self.cat = cat
        self.args = args
        self.t0 = tracer._now()
        self.tid = threading.get_ident()
        self._on_stack = on_stack
        self._done = False

    def set(self, **args) -> "Span":
        self.args.update(args)
        return self

    def finish(self) -> None:
        if self._done:
            return
        self._done = True
        self.tracer._record(self)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.args.setdefault("error", exc_type.__name__)
        if self._on_stack:
            self.tracer._pop(self)
        self.finish()


class Tracer:
    """Ring-buffered span recorder.  ``capacity`` bounds retained events;
    the oldest fall off first.  All methods are thread-safe."""

    def __init__(self, capacity: int = 65536):
        self._events: deque = deque(maxlen=int(capacity))
        self._ids = itertools.count(1)  # C-level next(): thread-safe
        self._tls = threading.local()
        self._epoch = time.perf_counter()
        self.dropped_hint = 0  # events appended beyond capacity (approx.)
        # recorded thread names, by ident: threads register themselves via
        # name_thread() so the export stays legible even after they exit
        # (threading.enumerate() only sees live threads)
        self._thread_names: Dict[int, str] = {}

    # ------------------------------------------------------------------ #
    def _now(self) -> float:
        return time.perf_counter() - self._epoch

    def _stack(self) -> List[Span]:
        try:
            return self._tls.stack
        except AttributeError:
            s = self._tls.stack = []
            return s

    def span(self, name: str, cat: str = "repro", **args) -> Span:
        """Open a nested span (parent = the thread's innermost open span).
        Use as a context manager."""
        stack = self._stack()
        parent = stack[-1].id if stack else None
        sp = Span(self, next(self._ids), parent, name, cat, args,
                  on_stack=True)
        stack.append(sp)
        return sp

    def start_span(self, name: str, cat: str = "repro",
                   parent: Optional[int] = None, **args) -> Span:
        """Open a detached span (cross-thread lifecycle; finish manually).
        ``parent`` links it explicitly; it never joins the thread stack."""
        return Span(self, next(self._ids), parent, name, cat, args,
                    on_stack=False)

    def name_thread(self, name: Optional[str] = None,
                    tid: Optional[int] = None) -> None:
        """Register a thread's display name for the Chrome export (a
        ``"ph": "M"`` metadata row in Perfetto).  Call with no arguments
        from a worker's run loop to self-register under its
        ``threading.Thread`` name — flusher, replica-tail, scrubber and
        auditor threads all do."""
        if tid is None:
            tid = threading.get_ident()
        if name is None:
            name = threading.current_thread().name
        self._thread_names[int(tid)] = str(name)

    def instant(self, name: str, cat: str = "repro", **args) -> None:
        """A zero-duration marker event."""
        if len(self._events) == self._events.maxlen:
            self.dropped_hint += 1
        self._events.append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": self._now() * 1e6, "pid": os.getpid(),
            "tid": threading.get_ident(), "args": args,
        })

    # ------------------------------------------------------------------ #
    def _pop(self, sp: Span) -> None:
        stack = self._stack()
        if stack and stack[-1] is sp:
            stack.pop()
        elif sp in stack:  # tolerate out-of-order exits
            stack.remove(sp)

    def _record(self, sp: Span) -> None:
        if len(self._events) == self._events.maxlen:
            self.dropped_hint += 1
        args = dict(sp.args)
        args["span_id"] = sp.id
        if sp.parent_id is not None:
            args["parent_id"] = sp.parent_id
        self._events.append({
            "name": sp.name, "cat": sp.cat, "ph": "X",
            "ts": sp.t0 * 1e6, "dur": (self._now() - sp.t0) * 1e6,
            "pid": os.getpid(), "tid": sp.tid, "args": args,
        })

    # ------------------------------------------------------------------ #
    def events(self) -> List[Dict]:
        return list(self._events)

    def __len__(self) -> int:
        return len(self._events)

    def clear(self) -> None:
        self._events.clear()

    def max_depth(self) -> int:
        """Deepest recorded parent chain (1 = only root spans)."""
        evs = [e for e in self._events if e["ph"] == "X"]
        parent = {e["args"]["span_id"]: e["args"].get("parent_id")
                  for e in evs}
        best = 0
        for sid in parent:
            d, cur = 0, sid
            while cur is not None and d <= len(parent):
                d += 1
                cur = parent.get(cur)
            best = max(best, d)
        return best

    def chrome_trace(self) -> Dict:
        """The Chrome/Perfetto ``trace_event`` JSON object."""
        evs = self.events()
        # thread-name metadata rows make the viewer legible: live threads
        # from the runtime, overlaid by name_thread() registrations (the
        # recorded name survives the thread — and wins, since a worker
        # knows its role better than a default "Thread-7")
        names = {}
        for th in threading.enumerate():
            names[th.ident] = th.name
        names.update(self._thread_names)
        meta = [{"name": "process_name", "ph": "M", "pid": os.getpid(),
                 "tid": 0, "args": {"name": "repro-serving"}}]
        meta += [
            {"name": "thread_name", "ph": "M", "pid": os.getpid(),
             "tid": tid, "args": {"name": names.get(tid, f"thread-{tid}")}}
            for tid in sorted({e["tid"] for e in evs}
                              | set(self._thread_names))
        ]
        return {"traceEvents": meta + evs, "displayTimeUnit": "ms"}

    def dump(self, path) -> str:
        """Write the Chrome trace JSON to ``path``; returns the path."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return os.fspath(path)


# ---------------------------------------------------------------------- #
class _NullSpan:
    """Shared no-op span/context-manager.  Re-entrant and stateless, so a
    single instance serves every call site and thread."""

    __slots__ = ()
    id = None
    parent_id = None

    def set(self, **args) -> "_NullSpan":
        return self

    def finish(self) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


class NullTracer:
    """No-op tracer (the default): every span site costs one method call
    returning the shared null span."""

    enabled = False
    dropped_hint = 0

    def span(self, name: str, cat: str = "repro", **args) -> _NullSpan:
        return _NULL_SPAN

    def start_span(self, name: str, cat: str = "repro", parent=None,
                   **args) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, cat: str = "repro", **args) -> None:
        pass

    def name_thread(self, name=None, tid=None) -> None:
        pass

    def events(self) -> List:
        return []

    def __len__(self) -> int:
        return 0

    def clear(self) -> None:
        pass

    def max_depth(self) -> int:
        return 0

    def chrome_trace(self) -> Dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def dump(self, path) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return os.fspath(path)


Tracer.enabled = True
