"""Zero-dependency, thread-safe metrics registry for the serving stack.

Three instrument kinds, the classic trio:

* :class:`Counter` — monotonically increasing totals (requests served,
  bytes written, recompiles).
* :class:`Gauge` — last-write-wins point-in-time values (replica lag,
  staleness pressure, queue depth).
* :class:`Histogram` — fixed-bucket distributions (request latency, fsync
  latency, group-commit sizes) with quantile estimation by linear
  interpolation inside the landing bucket.

The write path is designed for the serving hot path: counters and
histograms accumulate into **per-thread shards** (a plain attribute add on
a cell only its owning thread ever writes), so concurrent writers never
contend on a lock and never lose updates — ``+=`` on a shared float is NOT
atomic across CPython bytecodes, but a per-thread cell is single-writer by
construction.  The only lock is taken on a thread's *first* touch of an
instrument (shard creation) and on reads (merge over shards).  Gauges are
last-write-wins and use a single atomic attribute store.

Labels follow the Prometheus model: an instrument family is declared once
with ``labelnames``; :meth:`_Family.labels` returns (and memoizes) the
child for one label-value tuple.  A family declared with no labels *is*
its own child — ``registry.counter("x").inc()`` just works.

:class:`NullRegistry` is the compile-it-out switch: the same API where
every method is a no-op returning a shared singleton, so instrumented code
pays one dict-free method call per event and the tier-1 fast path stays
untouched.  ``registry.enabled`` distinguishes the two.

Exports: :meth:`MetricsRegistry.snapshot` (nested, JSON-able dict) and
:meth:`MetricsRegistry.prometheus` (text exposition format).
"""

from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NullRegistry",
    "DEFAULT_LATENCY_BUCKETS_S",
    "DEFAULT_SIZE_BUCKETS",
]

#: latency histogram bound defaults, in seconds: 100us .. 10s, log-ish
DEFAULT_LATENCY_BUCKETS_S = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 10.0,
)

#: size/count histogram bound defaults (records per commit, batch sizes, …)
DEFAULT_SIZE_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 1024, 4096, 16384)


# ---------------------------------------------------------------------- #
#  Per-thread shard cells
# ---------------------------------------------------------------------- #
class _Cell:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0


class _HistCell:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, nbuckets: int):
        self.counts = [0] * nbuckets
        self.sum = 0.0
        self.count = 0


# ---------------------------------------------------------------------- #
#  Children (one per label-value tuple)
# ---------------------------------------------------------------------- #
class Counter:
    """Sharded monotonic counter.  ``inc`` is lock-free after a thread's
    first touch (its shard cell is single-writer)."""

    __slots__ = ("_lock", "_cells", "_local")

    def __init__(self):
        self._lock = threading.Lock()
        self._cells: List[_Cell] = []
        self._local = threading.local()

    def _bind(self) -> _Cell:
        cell = _Cell()
        with self._lock:
            self._cells.append(cell)
        self._local.cell = cell
        return cell

    def inc(self, v: float = 1.0) -> None:
        try:
            cell = self._local.cell
        except AttributeError:
            cell = self._bind()
        cell.value += v

    @property
    def value(self) -> float:
        with self._lock:
            return sum(c.value for c in self._cells)


class Gauge:
    """Last-write-wins gauge: ``set`` is one atomic attribute store (no
    read-modify-write on the fast path); ``inc``/``dec`` take the lock."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self._value += v

    def dec(self, v: float = 1.0) -> None:
        self.inc(-v)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket sharded histogram.

    ``buckets`` are the inclusive upper bounds of the finite buckets; one
    overflow bucket (+Inf) is implicit.  ``observe`` costs one bisect plus
    three single-writer cell updates.  Quantiles are estimated by linear
    interpolation inside the landing bucket (exact at bucket edges), which
    is the standard fixed-bucket trade: cheap, mergeable, and bounded error
    set by the bucket layout.
    """

    __slots__ = ("buckets", "_lock", "_cells", "_local")

    def __init__(self, buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S):
        b = tuple(float(x) for x in buckets)
        assert b and all(b[i] < b[i + 1] for i in range(len(b) - 1)), \
            "histogram buckets must be strictly increasing"
        self.buckets = b
        self._lock = threading.Lock()
        self._cells: List[_HistCell] = []
        self._local = threading.local()

    def _bind(self) -> _HistCell:
        cell = _HistCell(len(self.buckets) + 1)
        with self._lock:
            self._cells.append(cell)
        self._local.cell = cell
        return cell

    def observe(self, x: float) -> None:
        try:
            cell = self._local.cell
        except AttributeError:
            cell = self._bind()
        cell.counts[bisect_left(self.buckets, x)] += 1
        cell.sum += x
        cell.count += 1

    # ------------------------------ reads ----------------------------- #
    def merged(self) -> Tuple[List[int], float, int]:
        """(per-bucket counts incl. overflow, sum, count) over all shards."""
        counts = [0] * (len(self.buckets) + 1)
        total, n = 0.0, 0
        with self._lock:
            for cell in self._cells:
                for i, c in enumerate(cell.counts):
                    counts[i] += c
                total += cell.sum
                n += cell.count
        return counts, total, n

    @property
    def count(self) -> int:
        return self.merged()[2]

    @property
    def sum(self) -> float:
        return self.merged()[1]

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (q in [0, 1]); 0.0 when empty.  Values in
        the overflow bucket clamp to the last finite bound."""
        counts, _, n = self.merged()
        if n == 0:
            return 0.0
        target = q * n
        seen = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if seen + c >= target:
                if i >= len(self.buckets):  # overflow bucket
                    return self.buckets[-1]
                lo = 0.0 if i == 0 else self.buckets[i - 1]
                hi = self.buckets[i]
                frac = (target - seen) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            seen += c
        return self.buckets[-1]


_CHILD_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


# ---------------------------------------------------------------------- #
#  Families (name + labelnames -> children)
# ---------------------------------------------------------------------- #
class _Family:
    """One named instrument family.  With ``labels=()`` the family proxies
    its single default child, so unlabeled metrics skip the lookup."""

    __slots__ = ("name", "kind", "help", "labelnames", "_children", "_lock",
                 "_default", "_hist_buckets")

    def __init__(self, name: str, kind: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 buckets: Optional[Sequence[float]] = None):
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = tuple(labelnames)
        self._hist_buckets = buckets
        self._children: Dict[Tuple[str, ...], object] = {}
        self._lock = threading.Lock()
        self._default = self._make() if not self.labelnames else None

    def _make(self):
        if self.kind == "histogram":
            return Histogram(self._hist_buckets or DEFAULT_LATENCY_BUCKETS_S)
        return _CHILD_TYPES[self.kind]()

    def labels(self, *values, **kw):
        """The child for one label-value tuple (memoized)."""
        if kw:
            values = tuple(kw[n] for n in self.labelnames)
        key = tuple(str(v) for v in values)
        if len(key) != len(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, got {key}")
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, self._make())
        return child

    # unlabeled convenience: the family IS its default child
    def inc(self, v: float = 1.0) -> None:
        self._default.inc(v)

    def dec(self, v: float = 1.0) -> None:
        self._default.dec(v)

    def set(self, v: float) -> None:
        self._default.set(v)

    def observe(self, x: float) -> None:
        self._default.observe(x)

    @property
    def value(self):
        return self._default.value

    def quantile(self, q: float) -> float:
        return self._default.quantile(q)

    @property
    def count(self) -> int:
        return self._default.count

    @property
    def sum(self) -> float:
        return self._default.sum

    def merged(self):
        return self._default.merged()

    def children(self) -> Dict[Tuple[str, ...], object]:
        if self._default is not None:
            return {(): self._default}
        with self._lock:
            return dict(self._children)


# ---------------------------------------------------------------------- #
#  Registries
# ---------------------------------------------------------------------- #
class MetricsRegistry:
    """The live registry.  Declaring the same name twice returns the same
    family (so call sites need no shared setup); re-declaring with a
    different kind or label set raises — a schema clash must fail loudly.
    """

    enabled = True

    def __init__(self):
        self._families: Dict[str, _Family] = {}
        self._lock = threading.Lock()
        self._collectors: Dict[str, object] = {}

    # ----------------------------- collect ---------------------------- #
    def collect(self, fn, name: str = "") -> None:
        """Register ``fn(registry)`` to run at the top of every
        :meth:`snapshot` / :meth:`prometheus` call.

        This is the collect-on-scrape hook for values that live outside
        the registry (jit-cache recompile counts, tracer drop counters):
        instead of relying on call sites remembering to fold the latest
        value in, the export path pulls a fresh reading.  ``name`` dedupes
        — re-registering the same name replaces the previous collector, so
        repeated ``enable()`` round-trips don't stack duplicates.
        """
        key = name or f"anon-{id(fn)}"
        with self._lock:
            self._collectors[key] = fn

    def _run_collectors(self) -> None:
        with self._lock:
            collectors = list(self._collectors.values())
        for fn in collectors:
            try:
                fn(self)
            except Exception:
                # a broken collector must not take down the scrape path
                pass

    # ----------------------------- declare ---------------------------- #
    def _get(self, name: str, kind: str, help: str, labels: Sequence[str],
             buckets=None) -> _Family:
        fam = self._families.get(name)  # dict read: safe under the GIL
        if fam is None:
            with self._lock:
                fam = self._families.get(name)
                if fam is None:
                    fam = _Family(name, kind, help=help, labelnames=labels,
                                  buckets=buckets)
                    self._families[name] = fam
        if fam.kind != kind or fam.labelnames != tuple(labels):
            raise ValueError(
                f"metric {name!r} already declared as {fam.kind}"
                f"{fam.labelnames}, redeclared as {kind}{tuple(labels)}")
        return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> _Family:
        return self._get(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> _Family:
        return self._get(name, "gauge", help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = None) -> _Family:
        return self._get(name, "histogram", help, labels, buckets=buckets)

    # ----------------------------- export ----------------------------- #
    def snapshot(self) -> Dict:
        """Nested JSON-able dict: ``{name: {type, help, values: [{labels,
        ...}]}}``.  Histogram entries carry count/sum/buckets plus p50/p95/
        p99 estimates so the snapshot is self-contained in bench artifacts.
        """
        self._run_collectors()
        out: Dict = {}
        with self._lock:
            families = list(self._families.values())
        for fam in sorted(families, key=lambda f: f.name):
            values = []
            for key, child in sorted(fam.children().items()):
                labels = dict(zip(fam.labelnames, key))
                if fam.kind == "histogram":
                    counts, total, n = child.merged()
                    cum, buckets = 0, {}
                    for bound, c in zip(child.buckets, counts):
                        cum += c
                        buckets[repr(bound)] = cum
                    buckets["+Inf"] = n
                    values.append({
                        "labels": labels, "count": n, "sum": total,
                        "buckets": buckets,
                        "p50": child.quantile(0.50),
                        "p95": child.quantile(0.95),
                        "p99": child.quantile(0.99),
                    })
                else:
                    values.append({"labels": labels, "value": child.value})
            out[fam.name] = {"type": fam.kind, "help": fam.help,
                             "values": values}
        return out

    def prometheus(self) -> str:
        """Prometheus text exposition format (one scrape body)."""
        self._run_collectors()
        lines: List[str] = []
        with self._lock:
            families = list(self._families.values())
        for fam in sorted(families, key=lambda f: f.name):
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key, child in sorted(fam.children().items()):
                pairs = list(zip(fam.labelnames, key))
                if fam.kind == "histogram":
                    counts, total, n = child.merged()
                    cum = 0
                    for bound, c in zip(child.buckets, counts):
                        cum += c
                        lab = _fmt_labels(pairs + [("le", _fmt_num(bound))])
                        lines.append(f"{fam.name}_bucket{lab} {cum}")
                    lab = _fmt_labels(pairs + [("le", "+Inf")])
                    lines.append(f"{fam.name}_bucket{lab} {n}")
                    lines.append(
                        f"{fam.name}_sum{_fmt_labels(pairs)} {_fmt_num(total)}")
                    lines.append(f"{fam.name}_count{_fmt_labels(pairs)} {n}")
                else:
                    lines.append(
                        f"{fam.name}{_fmt_labels(pairs)} "
                        f"{_fmt_num(child.value)}")
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------- #
class _NullMetric:
    """Absorbs every instrument call; ``labels`` returns itself, so one
    shared instance serves every family, child, and label combination."""

    __slots__ = ()

    def labels(self, *a, **kw):
        return self

    def inc(self, v: float = 1.0) -> None:
        pass

    def dec(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, x: float) -> None:
        pass

    @property
    def value(self) -> float:
        return 0.0

    @property
    def count(self) -> int:
        return 0

    @property
    def sum(self) -> float:
        return 0.0

    def quantile(self, q: float) -> float:
        return 0.0

    def merged(self):
        return [], 0.0, 0


_NULL_METRIC = _NullMetric()


class NullRegistry:
    """The no-op registry: same surface as :class:`MetricsRegistry`, every
    instrument is the shared null metric.  Instrumented code constructed
    against it pays one attribute call per event and records nothing —
    this is the default, so un-enabled obs never touches tier-1 perf."""

    enabled = False

    def counter(self, name: str, help: str = "", labels=()) -> _NullMetric:
        return _NULL_METRIC

    def gauge(self, name: str, help: str = "", labels=()) -> _NullMetric:
        return _NULL_METRIC

    def histogram(self, name: str, help: str = "", labels=(),
                  buckets=None) -> _NullMetric:
        return _NULL_METRIC

    def collect(self, fn, name: str = "") -> None:
        pass

    def snapshot(self) -> Dict:
        return {}

    def prometheus(self) -> str:
        return ""


# ---------------------------------------------------------------------- #
def _fmt_num(v) -> str:
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _escape_label_value(v) -> str:
    """Escape one label value per the Prometheus text exposition format:
    backslash first (so the other escapes aren't double-escaped), then
    double-quote, then newline — a raw newline inside a label value would
    otherwise split the sample line and corrupt the whole scrape body."""
    return (str(v).replace("\\", r"\\").replace('"', r"\"")
            .replace("\n", r"\n"))


def _unescape_label_value(v: str) -> str:
    """Inverse of :func:`_escape_label_value` (round-trip tests / parsers)."""
    out, i = [], 0
    while i < len(v):
        c = v[i]
        if c == "\\" and i + 1 < len(v):
            nxt = v[i + 1]
            out.append({"\\": "\\", '"': '"', "n": "\n"}.get(nxt, c + nxt))
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out)


def _fmt_labels(pairs) -> str:
    if not pairs:
        return ""
    body = ",".join(
        '{}="{}"'.format(k, _escape_label_value(v)) for k, v in pairs
    )
    return "{" + body + "}"
